"""AOT lowering sanity: HLO text interchange + manifest + golden file."""

import os

import numpy as np

from compile import aot


def test_hlo_text_contains_entry():
    text = aot.to_hlo_text(aot.lower_freshness(128))
    assert "ENTRY" in text
    assert "HloModule" in text


def test_crawl_value_lowering_small():
    text = aot.to_hlo_text(aot.lower_crawl_value(256, 2))
    assert "ENTRY" in text
    # 7 f32[256] params
    assert text.count("f32[256]") >= 7


def test_mle_lowering():
    text = aot.to_hlo_text(aot.lower_mle(512))
    assert "ENTRY" in text
    assert "f32[512,2]" in text


def test_golden_file_roundtrip(tmp_path):
    path = os.path.join(tmp_path, "golden.csv")
    aot.write_golden(path, rows=32)
    with open(path) as f:
        header = f.readline().strip().split(",")
        rows = [line.strip().split(",") for line in f]
    assert header == ["iota", "delta", "mu", "lam", "nu", "terms",
                      "value", "psi", "w"]
    assert len(rows) == 32 * 3  # three term levels
    vals = np.array([[float(c) for c in r] for r in rows])
    assert np.all(np.isfinite(vals))
    assert np.all(vals[:, 6] >= -1e-12)  # values nonnegative
