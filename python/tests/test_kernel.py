"""Pallas kernel vs pure-jnp oracle — the CORE correctness signal.

The kernel runs in f32 interpret mode; the oracle runs the same math in
f32 (apples-to-apples) and in f64 (absolute accuracy budget). Hypothesis
sweeps shapes and the full parameter space including the degenerate
corners (no CIS, noiseless CIS, lam -> 1).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.crawl_value import (
    BETA_CAP,
    crawl_value_pallas,
    _crawl_value_block,
)


def derived_f32(delta, mu, lam, nu):
    """Derived params as the rust coordinator feeds them: f64 derivation,
    beta capped to BETA_CAP, cast to f32."""
    a, b, g = ref.derived_params(
        jnp.asarray(delta, jnp.float64),
        jnp.asarray(mu, jnp.float64),
        jnp.asarray(lam, jnp.float64),
        jnp.asarray(nu, jnp.float64),
    )
    b = jnp.minimum(b, BETA_CAP)
    return (jnp.asarray(a, jnp.float32), jnp.asarray(b, jnp.float32),
            jnp.asarray(g, jnp.float32))


def random_env(rng, n):
    delta = rng.uniform(0.01, 2.0, n)
    mu = rng.uniform(0.0, 1.0, n)
    lam = rng.uniform(0.0, 1.0, n)
    nu = rng.uniform(0.0, 1.0, n)
    # degenerate corners in every batch
    lam[: n // 8] = 0.0
    nu[: n // 8] = 0.0
    nu[n // 8 : n // 4] = 0.0
    iota = 10.0 ** rng.uniform(-3, 1.5, n)
    return iota, delta, mu, lam, nu


def run_kernel(iota, delta, mu, lam, nu, terms, block):
    a, b, g = derived_f32(delta, mu, lam, nu)
    f = lambda x: jnp.asarray(x, jnp.float32)
    return np.asarray(
        crawl_value_pallas(f(iota), a, b, g, f(nu), f(delta), f(mu),
                           terms=terms, block=block)
    )


@pytest.mark.parametrize("terms", [1, 2, 8])
@pytest.mark.parametrize("n,block", [(256, 256), (1024, 256), (2048, 2048)])
def test_kernel_matches_f64_oracle(terms, n, block):
    rng = np.random.default_rng(42 + terms + n)
    iota, delta, mu, lam, nu = random_env(rng, n)
    got = run_kernel(iota, delta, mu, lam, nu, terms, block)
    want = np.asarray(
        ref.crawl_value(
            jnp.asarray(iota, jnp.float64), jnp.asarray(delta, jnp.float64),
            jnp.asarray(mu, jnp.float64), jnp.asarray(lam, jnp.float64),
            jnp.asarray(nu, jnp.float64), terms=terms,
        )
    )
    # f32 kernel against f64 truth: 1e-4 relative on a value scale of ~mu/delta
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=5e-6)


@given(
    n_blocks=st.integers(1, 4),
    block=st.sampled_from([128, 256, 512]),
    terms=st.sampled_from([1, 2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(deadline=None, max_examples=25)
def test_kernel_shape_sweep(n_blocks, block, terms, seed):
    n = n_blocks * block
    rng = np.random.default_rng(seed)
    iota, delta, mu, lam, nu = random_env(rng, n)
    got = run_kernel(iota, delta, mu, lam, nu, terms, block)
    assert got.shape == (n,)
    assert np.all(np.isfinite(got))
    want = np.asarray(
        ref.crawl_value(
            jnp.asarray(iota, jnp.float64), jnp.asarray(delta, jnp.float64),
            jnp.asarray(mu, jnp.float64), jnp.asarray(lam, jnp.float64),
            jnp.asarray(nu, jnp.float64), terms=terms,
        )
    )
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=1e-5)


def test_padding_sentinel_is_zero():
    """mu == 0 sentinel pages must produce exactly 0 so padded lanes can
    never win the fused argmax."""
    n = 256
    rng = np.random.default_rng(7)
    iota, delta, mu, lam, nu = random_env(rng, n)
    mu[n // 2 :] = 0.0
    got = run_kernel(iota, delta, mu, lam, nu, 8, 256)
    assert np.all(got[n // 2 :] == 0.0)
    assert np.all(got[: n // 2] >= 0.0)


def test_kernel_values_nonnegative_and_bounded():
    """0 <= V <= mu * w(inf) <= mu/delta * (1 + nu/delta)... use the loose
    bound V <= mu/min(alpha+..): simply check V >= 0 and V <= mu/delta + 1."""
    n = 2048
    rng = np.random.default_rng(3)
    iota, delta, mu, lam, nu = random_env(rng, n)
    got = run_kernel(iota, delta, mu, lam, nu, 8, 2048)
    assert np.all(got >= -1e-6)
    assert np.all(got <= mu / delta + 1.0)


def test_block_helper_equals_pallas_path():
    """The shared jnp block body and the pallas_call path must agree to
    f32 roundoff (XLA fusion inside jit may contract mul+add)."""
    n = 512
    rng = np.random.default_rng(11)
    iota, delta, mu, lam, nu = random_env(rng, n)
    a, b, g = derived_f32(delta, mu, lam, nu)
    f = lambda x: jnp.asarray(x, jnp.float32)
    direct = np.asarray(
        _crawl_value_block(f(iota), a, b, g, f(nu), f(delta), f(mu), terms=4)
    )
    kern = run_kernel(iota, delta, mu, lam, nu, 4, 512)
    np.testing.assert_allclose(direct, kern, rtol=1e-4, atol=1e-6)


def test_beta_cap_masks_higher_terms():
    """With beta = BETA_CAP (noiseless CIS), only the i = 0 term may
    contribute: terms=1 and terms=8 must agree."""
    n = 128
    rng = np.random.default_rng(13)
    iota, delta, mu, lam, _ = random_env(rng, n)
    nu = np.zeros(n)
    v1 = run_kernel(iota, delta, mu, lam, nu, 1, 128)
    v8 = run_kernel(iota, delta, mu, lam, nu, 8, 128)
    np.testing.assert_array_equal(v1, v8)
