"""Shared test config: f64 for the oracle regardless of module import
order (test files use explicit jnp.float32 where f32 is under test)."""

import jax

jax.config.update("jax_enable_x64", True)
