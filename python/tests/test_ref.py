"""Properties of the pure-jnp oracle itself (f64).

These pin down the *mathematical* identities from the paper, so that the
oracle is trustworthy before anything else is tested against it:
  - R^i(x) = P(i+1, x): bounds, monotonicity in x, anti-monotonicity in i,
    derivative identity (3): d/dx R^i = R^{i-1} - R^i = x^i e^{-x}/i!
  - gamma -> 0 recovers V_GREEDY = (mu/delta) R^1(delta iota)
  - nu -> 0 recovers V_GREEDY_CIS
  - Lemma 2: V monotone increasing, f monotone decreasing in iota
  - Lemma 3: w'(x) = exp(-alpha x) psi'(x)
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref

floats01 = st.floats(min_value=0.01, max_value=0.99)
rates = st.floats(min_value=0.05, max_value=2.0)
iotas = st.floats(min_value=1e-3, max_value=50.0)


@given(x=st.floats(min_value=0.0, max_value=100.0), i=st.integers(0, 8))
@settings(deadline=None, max_examples=200)
def test_residual_bounds(x, i):
    r = float(ref.exp_residual(i, jnp.float64(x)))
    assert 0.0 <= r <= 1.0


@given(x=st.floats(min_value=1e-6, max_value=60.0), i=st.integers(0, 6))
@settings(deadline=None, max_examples=200)
def test_residual_decreasing_in_order(x, i):
    hi = float(ref.exp_residual(i, jnp.float64(x)))
    lo = float(ref.exp_residual(i + 1, jnp.float64(x)))
    assert lo <= hi + 1e-12


@given(x=st.floats(min_value=1e-4, max_value=50.0), i=st.integers(0, 5))
@settings(deadline=None, max_examples=100)
def test_residual_derivative_identity(x, i):
    """(3): d/dx R^i(x) = x^i exp(-x) / i!"""
    h = 1e-6 * max(1.0, x)
    num = (
        float(ref.exp_residual(i, jnp.float64(x + h)))
        - float(ref.exp_residual(i, jnp.float64(x - h)))
    ) / (2 * h)
    fact = 1.0
    for j in range(1, i + 1):
        fact *= j
    exact = x**i * np.exp(-x) / fact
    assert num == pytest.approx(exact, rel=1e-3, abs=1e-9)


def test_residual_small_x_series_accuracy():
    # direct evaluation in f32 catastrophically cancels here; the series
    # branch must stay accurate
    x = jnp.float64(1e-4)
    r1 = float(ref.exp_residual(1, x))
    exact = 1.0 - np.exp(-1e-4) * (1 + 1e-4)
    assert r1 == pytest.approx(exact, rel=1e-6)


@given(iota=iotas, delta=rates, mu=floats01)
@settings(deadline=None, max_examples=100)
def test_gamma_zero_recovers_greedy(iota, delta, mu):
    v = float(ref.crawl_value(jnp.float64(iota), delta, mu, 0.0, 0.0, terms=8))
    vg = float(ref.value_greedy(jnp.float64(iota), delta, mu))
    assert v == pytest.approx(vg, rel=2e-5, abs=1e-12)


@given(iota=iotas, delta=rates, mu=floats01, lam=floats01)
@settings(deadline=None, max_examples=100)
def test_nu_zero_recovers_cis(iota, delta, mu, lam):
    """nu = 0 means beta = inf: only the i=0 term, matching V_GREEDY_CIS
    evaluated with the true gamma = lam*delta."""
    v = float(ref.crawl_value(jnp.float64(iota), delta, mu, lam, 0.0, terms=8))
    gamma = lam * delta
    vc = float(ref.value_cis(jnp.float64(iota), delta, mu, gamma))
    assert v == pytest.approx(vc, rel=2e-4, abs=1e-12)


@given(delta=rates, mu=floats01, lam=floats01,
       nu=st.floats(min_value=0.05, max_value=1.0))
@settings(deadline=None, max_examples=60)
def test_lemma2_monotonicity(delta, mu, lam, nu):
    iotas_grid = jnp.linspace(0.05, 40.0, 120, dtype=jnp.float64)
    v = np.asarray(ref.crawl_value(iotas_grid, delta, mu, lam, nu, terms=16))
    f = np.asarray(ref.crawl_frequency(iotas_grid, delta, mu, lam, nu, terms=16))
    assert np.all(np.diff(v) >= -1e-10), "V must be nondecreasing in iota"
    assert np.all(np.diff(f) <= 1e-10), "f must be nonincreasing in iota"


@given(delta=rates, mu=floats01, lam=floats01,
       nu=st.floats(min_value=0.05, max_value=1.0), iota=iotas)
@settings(deadline=None, max_examples=60)
def test_lemma3_derivative_identity(delta, mu, lam, nu, iota):
    """w'(x) = exp(-alpha x) psi'(x), checked by central differences away
    from the kinks at multiples of beta."""
    alpha, beta, gamma = ref.derived_params(delta, mu, lam, nu)
    b = float(beta)
    if np.isfinite(b):
        # keep clear of the non-differentiable kinks
        frac = (iota % b) / b
        if frac < 0.05 or frac > 0.95:
            return
    h = 1e-5 * max(1.0, iota)

    def pw(x):
        return ref.psi_w(jnp.float64(x), alpha, beta, gamma, nu, delta, 32)

    p_hi, w_hi = pw(iota + h)
    p_lo, w_lo = pw(iota - h)
    dpsi = (float(p_hi) - float(p_lo)) / (2 * h)
    dw = (float(w_hi) - float(w_lo)) / (2 * h)
    assert dw == pytest.approx(float(np.exp(-float(alpha) * iota)) * dpsi,
                               rel=5e-3, abs=1e-8)


def test_value_saturates_at_w_infinity():
    """V(iota -> inf) -> mu * w(inf); for nu=0 that's mu/delta."""
    v = float(ref.value_cis(jnp.float64(np.inf), 0.5, 0.7, 0.2))
    assert v == pytest.approx(0.7 / 0.5)


def test_effective_time_cap():
    t = ref.effective_time(5.0, 3.0, 0.5, 0.8, 0.0)
    assert float(t) == pytest.approx(1e9)  # beta = inf capped
    t2 = ref.effective_time(5.0, 0.0, 0.5, 0.8, 0.0)
    assert float(t2) == pytest.approx(5.0)


def test_freshness_matches_eq1():
    delta, lam, nu = 0.8, 0.6, 0.3
    gamma = lam * delta + nu
    alpha = (1 - lam) * delta
    f = float(ref.freshness(2.0, 2.0, delta, lam, nu))
    assert f == pytest.approx(np.exp(-alpha * 2.0) * (nu / gamma) ** 2, rel=1e-9)
