"""L2 model graphs: fused argmax, freshness, MLE estimator step."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref
from compile.kernels.crawl_value import BETA_CAP


def make_batch(rng, n):
    delta = rng.uniform(0.05, 2.0, n)
    mu = rng.uniform(0.01, 1.0, n)
    lam = rng.uniform(0.0, 1.0, n)
    nu = rng.uniform(0.0, 1.0, n)
    iota = 10.0 ** rng.uniform(-2, 1.5, n)
    a, b, g = ref.derived_params(delta, mu, lam, nu)
    b = jnp.minimum(b, BETA_CAP)
    f = lambda x: jnp.asarray(x, jnp.float32)
    return f(iota), f(a), f(b), f(g), f(nu), f(delta), f(mu)


@pytest.mark.parametrize("n", [256, 2048])
def test_argmax_fusion(n):
    rng = np.random.default_rng(5)
    args = make_batch(rng, n)
    values, idx, best = model.crawl_value_batch(*args, terms=4,
                                                block=min(n, 2048))
    values = np.asarray(values)
    assert int(idx[0]) == int(np.argmax(values))
    assert float(best[0]) == pytest.approx(float(values.max()))


def test_argmax_ignores_padding():
    n = 256
    rng = np.random.default_rng(6)
    iota, a, b, g, nu, delta, mu = make_batch(rng, n)
    mu = mu.at[: n - 8].set(0.0)  # only the last 8 pages are real
    _, idx, _ = model.crawl_value_batch(iota, a, b, g, nu, delta, mu,
                                        terms=4, block=n)
    assert int(idx[0]) >= n - 8


def test_freshness_batch():
    tau = jnp.asarray([0.0, 1.0, 2.0], jnp.float32)
    n = jnp.asarray([0.0, 1.0, 3.0], jnp.float32)
    alpha = jnp.asarray([0.5, 0.5, 0.5], jnp.float32)
    logr = jnp.asarray([0.0, -1.0, -1.0], jnp.float32)
    (f,) = model.freshness_batch(tau, n, alpha, logr)
    want = np.exp(-0.5 * np.array([0.0, 1.0, 2.0]) + np.array([0, 1, 3]) *
                  np.array([0.0, -1.0, -1.0]))
    np.testing.assert_allclose(np.asarray(f), want, rtol=1e-6)


def _simulate_observations(rng, alpha, beta, n):
    """Crawl intervals with known (alpha, beta): tau ~ U[0.5, 4], n_cis ~
    Poisson(1), z ~ Ber(1 - exp(-(alpha tau + alpha beta n)))."""
    tau = rng.uniform(0.5, 4.0, n)
    n_cis = rng.poisson(1.0, n).astype(np.float64)
    p_change = 1.0 - np.exp(-(alpha * tau + alpha * beta * n_cis))
    z = (rng.uniform(0, 1, n) < p_change).astype(np.float64)
    x = np.stack([tau, n_cis], axis=1)
    return x, z


@given(alpha=st.floats(0.1, 0.8), beta=st.floats(0.3, 3.0),
       seed=st.integers(0, 10_000))
@settings(deadline=None, max_examples=15)
def test_mle_step_recovers_parameters(alpha, beta, seed):
    """Iterating mle_step must recover (alpha, alpha*beta) from 4096
    synthetic observations to ~10% (statistical error at this sample
    size), mirroring Appendix E / Figure 11."""
    rng = np.random.default_rng(seed)
    x, z = _simulate_observations(rng, alpha, beta, 4096)
    f32 = lambda v: jnp.asarray(v, jnp.float32)
    theta = f32([0.5, 0.5])
    w = f32(np.ones(4096))
    nll_prev = np.inf
    for _ in range(60):
        theta, nll = model.mle_step(theta, f32(x), f32(z), w)
        nll = float(nll[0])
    assert nll <= nll_prev or abs(nll - nll_prev) < 1e-3
    got_alpha, got_ab = float(theta[0]), float(theta[1])
    assert got_alpha == pytest.approx(alpha, rel=0.25, abs=0.05)
    assert got_ab == pytest.approx(alpha * beta, rel=0.25, abs=0.08)


def test_mle_step_respects_weights():
    """Padding rows (weight 0) must not influence the fit."""
    rng = np.random.default_rng(0)
    x, z = _simulate_observations(rng, 0.4, 1.0, 2048)
    f32 = lambda v: jnp.asarray(v, jnp.float32)
    # garbage padding rows
    x_pad = np.concatenate([x, np.full((2048, 2), 50.0)])
    z_pad = np.concatenate([z, np.zeros(2048)])
    w_pad = np.concatenate([np.ones(2048), np.zeros(2048)])
    t1 = f32([0.5, 0.5])
    t2 = f32([0.5, 0.5])
    for _ in range(20):
        t1, _ = model.mle_step(t1, f32(x), f32(z), f32(np.ones(2048)))
        t2, _ = model.mle_step(t2, f32(x_pad), f32(z_pad), f32(w_pad))
    np.testing.assert_allclose(np.asarray(t1), np.asarray(t2), rtol=1e-4)


def test_mle_theta_stays_positive():
    rng = np.random.default_rng(1)
    x, z = _simulate_observations(rng, 0.05, 0.2, 1024)
    f32 = lambda v: jnp.asarray(v, jnp.float32)
    theta = f32([2.0, 2.0])  # start far away
    for _ in range(40):
        theta, _ = model.mle_step(theta, f32(x), f32(z), f32(np.ones(1024)))
        assert float(theta[0]) > 0 and float(theta[1]) > 0
