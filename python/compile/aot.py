"""AOT: lower the L2 graphs to HLO *text* artifacts for the rust runtime.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (proto.id() <= INT_MAX); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/load_hlo/.

Also emits:
  - artifacts/manifest.txt   one line per artifact, `key=value` pairs,
    consumed by rust/src/runtime/artifacts.rs
  - artifacts/golden_value.csv  f64 reference crawl values for the rust
    native implementation's cross-language golden test

Run via `make artifacts` (no-op when inputs are unchanged).
"""

from __future__ import annotations

import argparse
import os

import jax

jax.config.update("jax_enable_x64", True)  # golden vectors in f64

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from compile import model  # noqa: E402
from compile.kernels import ref  # noqa: E402
from compile.kernels.crawl_value import BETA_CAP  # noqa: E402

# (batch, terms) configurations for the crawl-value executable. 2048 is the
# single-block latency-oriented variant; 16384 the throughput variant.
CRAWL_VALUE_CONFIGS = [(2048, 2), (2048, 8), (16384, 2), (16384, 8)]
FRESHNESS_BATCH = 16384
MLE_BATCH = 4096


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple=True so the
    rust side always unwraps a tuple, regardless of output arity)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def lower_crawl_value(batch: int, terms: int):
    spec = f32((batch,))
    fn = lambda *a: model.crawl_value_batch(*a, terms=terms, block=min(batch, 2048))
    return jax.jit(fn).lower(spec, spec, spec, spec, spec, spec, spec)


def lower_freshness(batch: int):
    spec = f32((batch,))
    return jax.jit(model.freshness_batch).lower(spec, spec, spec, spec)


def lower_mle(batch: int):
    return jax.jit(model.mle_step).lower(
        f32((2,)), f32((batch, 2)), f32((batch,)), f32((batch,))
    )


def write_golden(path: str, rows: int = 512) -> None:
    """Reference crawl values over a broad parameter grid, in f64."""
    key = jax.random.PRNGKey(20250710)
    k = jax.random.split(key, 5)
    iota = 10.0 ** jax.random.uniform(k[0], (rows,), minval=-3.0, maxval=2.0)
    delta = jax.random.uniform(k[1], (rows,), minval=0.01, maxval=2.0)
    mu = jax.random.uniform(k[2], (rows,), minval=0.0, maxval=1.0)
    lam = jax.random.uniform(k[3], (rows,), minval=0.0, maxval=1.0)
    nu = jax.random.uniform(k[4], (rows,), minval=0.0, maxval=1.0)
    # exercise the no-CIS and noiseless corners explicitly
    lam = lam.at[: rows // 8].set(0.0)
    nu = nu.at[: rows // 16].set(0.0)
    nu = nu.at[rows // 8 : rows // 4].set(0.0)
    with open(path, "w") as f:
        f.write("iota,delta,mu,lam,nu,terms,value,psi,w\n")
        for terms in (1, 2, 8):
            v = ref.crawl_value(iota, delta, mu, lam, nu, terms=terms)
            a, b, g = ref.derived_params(delta, mu, lam, nu)
            psi, w = ref.psi_w(iota, a, b, g, nu, delta, terms)
            for r in range(rows):
                f.write(
                    f"{iota[r]:.17g},{delta[r]:.17g},{mu[r]:.17g},"
                    f"{lam[r]:.17g},{nu[r]:.17g},{terms},"
                    f"{v[r]:.17g},{psi[r]:.17g},{w[r]:.17g}\n"
                )


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument("--golden-rows", type=int, default=512)
    args = p.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = []

    for batch, terms in CRAWL_VALUE_CONFIGS:
        name = f"crawl_value_n{batch}_j{terms}"
        text = to_hlo_text(lower_crawl_value(batch, terms))
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        manifest.append(
            f"kind=crawl_value name={name} file={fname} batch={batch} "
            f"terms={terms} inputs=7 outputs=3 beta_cap={BETA_CAP:g}"
        )
        print(f"wrote {fname} ({len(text)} chars)")

    name, fname = "freshness", "freshness.hlo.txt"
    text = to_hlo_text(lower_freshness(FRESHNESS_BATCH))
    with open(os.path.join(args.out_dir, fname), "w") as f:
        f.write(text)
    manifest.append(
        f"kind=freshness name={name} file={fname} batch={FRESHNESS_BATCH} "
        f"inputs=4 outputs=1"
    )
    print(f"wrote {fname} ({len(text)} chars)")

    name, fname = "mle_step", "mle_step.hlo.txt"
    text = to_hlo_text(lower_mle(MLE_BATCH))
    with open(os.path.join(args.out_dir, fname), "w") as f:
        f.write(text)
    manifest.append(
        f"kind=mle_step name={name} file={fname} batch={MLE_BATCH} "
        f"inputs=4 outputs=2"
    )
    print(f"wrote {fname} ({len(text)} chars)")

    golden = os.path.join(args.out_dir, "golden_value.csv")
    write_golden(golden, args.golden_rows)
    print(f"wrote {golden}")

    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote manifest with {len(manifest)} artifacts")


if __name__ == "__main__":
    main()
