"""L2: the jax compute graphs the rust coordinator executes via PJRT.

Three graphs are AOT-lowered by ``aot.py``:

1. ``crawl_value_batch`` — the request-path hot spot. Takes the scheduler
   state (effective elapsed times) and page parameters, calls the L1
   Pallas kernel for the values and fuses the argmax reduction into the
   same executable (one device roundtrip per tick batch).
2. ``freshness_batch`` — expected-freshness probabilities (eq. 1), used
   for freshness reporting / accuracy estimation.
3. ``mle_step`` — one damped Newton step of the Appendix-E estimator for
   theta = (alpha, alpha*beta) on logged (tau_elap, n_cis, changed)
   observations. The coordinator iterates this to convergence.

All graphs are shape-monomorphic: ``aot.py`` lowers one artifact per
(batch, terms) configuration listed in its manifest.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels.crawl_value import crawl_value_pallas


def crawl_value_batch(iota, alpha, beta, gamma, nu, delta, mu,
                      terms: int = 8, block: int = 2048):
    """Values for N pages plus the fused argmax.

    Returns (values[N] f32, argmax[1] i32, max_value[1] f32). Padded
    sentinel pages must carry mu == 0 so their value is exactly 0 and can
    never win the argmax against a real candidate (values of real pages
    are > 0 for iota > 0).
    """
    values = crawl_value_pallas(iota, alpha, beta, gamma, nu, delta, mu,
                                terms=terms, block=block)
    idx = jnp.argmax(values).astype(jnp.int32).reshape((1,))
    best = jnp.max(values).reshape((1,))
    return values, idx, best


def freshness_batch(tau_elap, n_cis, alpha, log_fp_ratio):
    """P[fresh] = exp(-alpha*tau + n * log(nu/gamma)) per page (eq. 1).

    ``log_fp_ratio`` is log(nu/gamma) <= 0, precomputed by the coordinator
    (0 for pages without CIS so the n term vanishes with n == 0).
    """
    return (jnp.exp(-alpha * tau_elap + n_cis * log_fp_ratio),)


def _mle_nll(theta, x, z, weight):
    """NLL of z_i ~ Ber(1 - exp(-<theta, x_i>)) (see ref.mle_nll)."""
    s = x @ theta
    p_nochange = jnp.clip(jnp.exp(-s), 1e-12, 1.0 - 1e-12)
    ll = jnp.where(z > 0.5, jnp.log1p(-p_nochange), -s)
    return -jnp.sum(weight * ll)


def mle_step(theta, x, z, weight):
    """One damped Newton step on the Appendix-E likelihood.

    theta: [2] (alpha, alpha*beta); x: [N,2] (tau_elap, n_cis); z: [N]
    in {0,1}; weight: [N] (0 for padding rows). Returns (theta', nll).
    Newton with Levenberg damping + positivity projection: theta must stay
    in (0, inf)^2 for the model to be a valid Bernoulli parametrization.
    """
    g = jax.grad(_mle_nll)(theta, x, z, weight)
    h = jax.hessian(_mle_nll)(theta, x, z, weight)
    h = h + 1e-6 * jnp.eye(2, dtype=theta.dtype)
    # closed-form 2x2 solve: jnp.linalg.solve lowers to a LAPACK
    # custom-call with API_VERSION_TYPED_FFI, which xla_extension 0.5.1
    # (the version the rust `xla` crate links) cannot compile
    det = h[0, 0] * h[1, 1] - h[0, 1] * h[1, 0]
    det = jnp.where(jnp.abs(det) < 1e-30, 1e-30, det)
    step = jnp.stack(
        [
            (h[1, 1] * g[0] - h[0, 1] * g[1]) / det,
            (-h[1, 0] * g[0] + h[0, 0] * g[1]) / det,
        ]
    )
    # backtracking-free damping: clip the step to at most 50% of theta
    max_rel = jnp.max(jnp.abs(step) / jnp.maximum(jnp.abs(theta), 1e-8))
    scale = jnp.minimum(1.0, 0.5 / jnp.maximum(max_rel, 1e-12))
    new_theta = jnp.maximum(theta - scale * step, 1e-8)
    return new_theta, _mle_nll(new_theta, x, z, weight).reshape((1,))
