"""Pure-jnp reference oracle for the crawl-value computation.

This module is the *correctness anchor* of the whole stack: the Pallas
kernel (``crawl_value.py``), the L2 model graphs (``model.py``) and the
rust-native f64 implementation (``rust/src/policy/value.rs``) are all
tested against these functions.

Notation follows the paper (Busa-Fekete et al., WWW 2025):

    delta  : change rate of the page's Poisson change process
    mu     : normalized importance (request-rate weight), mu-tilde
    lam    : recall of the CI signal (P[a change emits a signal])
    nu     : rate of the false-positive CIS Poisson process

Derived:

    gamma = lam * delta + nu          observed CIS rate
    alpha = (1 - lam) * delta         unsignalled change rate
    beta  = -log(nu / gamma) / alpha  time-equivalent of one CIS

Crawl value (Theorem 1), with R^i the normalized Taylor residual of exp:

    psi(iota) = sum_{i=0}^{floor(iota/beta)} (1/gamma) R^i(gamma (iota - i beta))
    w(iota)   = sum_{i=0}^{floor(iota/beta)} nu^i/(delta+nu)^{i+1}
                                             R^i((alpha+gamma)(iota - i beta))
    f(iota)   = 1 / psi(iota)
    V(iota)   = mu (w(iota) - exp(-alpha iota) psi(iota))

The APPROX-J family truncates the sums at ``min(J-1, floor(iota/beta))``
terms (Appendix A.1).
"""

from __future__ import annotations

import jax.numpy as jnp


def exp_residual(i: int, x):
    """Normalized residual of the i-th Taylor approximation of exp.

    R^i(x) = (exp(x) - sum_{j<=i} x^j/j!) / exp(x)
           = 1 - exp(-x) * sum_{j<=i} x^j/j!

    Equals the regularized lower incomplete gamma P(i+1, x) for x >= 0.
    Uses a small-x series branch to avoid catastrophic cancellation in f32:

    R^i(x) = exp(-x) * sum_{j>i} x^j/j!
           = exp(-x) * x^{i+1}/(i+1)! * (1 + x/(i+2) + x^2/((i+2)(i+3)) + ...)
    """
    x = jnp.asarray(x)
    # direct branch: 1 - exp(-x) * partial sum. The partial sum would
    # overflow for huge x (x^j/j! -> inf, times exp(-x) -> 0*inf = NaN),
    # so clamp the argument: for x > 2i + 60 the result is 1 to f64
    # accuracy (Poisson left tail < 1e-20) and the clamped sum is finite.
    saturated = x > 2.0 * i + 60.0
    xs = jnp.where(saturated, 2.0 * i + 60.0, x)
    term = jnp.ones_like(x)
    s = jnp.ones_like(x)
    for j in range(1, i + 1):
        term = term * xs / j
        s = s + term
    direct = jnp.where(saturated, 1.0, 1.0 - jnp.exp(-xs) * s)
    # series branch for small x (12 tail terms: truncation < 1e-12 at the
    # x = 0.5 branch point, so both branches agree to f64-level accuracy)
    fact = 1.0
    for j in range(1, i + 2):
        fact *= j
    lead = x ** (i + 1) / fact
    ser = jnp.zeros_like(x)
    t = jnp.ones_like(x)
    for k in range(12):
        if k > 0:
            t = t * x / (i + 1 + k)
        ser = ser + t
    series = jnp.exp(-x) * lead * ser
    small = x < 0.5
    out = jnp.where(small, series, direct)
    # residual is only defined/used for x >= 0; clamp negatives to 0
    return jnp.where(x < 0.0, 0.0, out)


def derived_params(delta, mu, lam, nu):
    """Map raw page parameters to the (alpha, beta, gamma) parametrization.

    Degenerate corners are regularized exactly as the rust side does
    (``params.rs``): gamma == 0 means "no CIS at all" (pure GREEDY limit)
    and beta is +inf; alpha == 0 (lam == 1) is clamped so the
    (alpha, beta) parametrization stays finite.
    """
    delta = jnp.asarray(delta)
    gamma = lam * delta + nu
    alpha = (1.0 - lam) * delta
    alpha = jnp.maximum(alpha, 1e-6 * jnp.maximum(delta, 1e-30))
    # beta = -log(nu/gamma)/alpha ; nu == 0 -> +inf
    safe_gamma = jnp.where(gamma > 0, gamma, 1.0)
    ratio = jnp.where(gamma > 0, nu / safe_gamma, 1.0)
    beta = jnp.where(
        (gamma > 0) & (nu > 0), -jnp.log(jnp.maximum(ratio, 1e-38)) / alpha, jnp.inf
    )
    return alpha, beta, gamma


def psi_w(iota, alpha, beta, gamma, nu, delta, terms: int):
    """psi (expected crawl interval) and w (cumulative freshness), truncated
    at ``terms`` residual terms. Term i is masked out when i*beta > iota.

    The gamma -> 0 (no CIS) limit is handled explicitly:
        psi -> R^0(...)/gamma -> iota,  w -> R^0(alpha*iota)/alpha
    (with alpha == delta in that limit).
    """
    iota = jnp.asarray(iota)
    no_cis = gamma <= 0.0
    g = jnp.where(no_cis, 1.0, gamma)  # safe divisor
    ag = alpha + g
    dn = delta + nu
    psi = jnp.zeros_like(iota)
    w = jnp.zeros_like(iota)
    # running coefficient nu^i / (delta+nu)^{i+1}
    coef = 1.0 / dn
    big = jnp.finfo(jnp.asarray(iota).dtype).max / 4
    for i in range(terms):
        off = iota - i * jnp.where(jnp.isinf(beta), big, beta)
        mask = off >= 0.0
        offc = jnp.where(mask, off, 0.0)
        psi = psi + jnp.where(mask, exp_residual(i, g * offc) / g, 0.0)
        w = w + jnp.where(mask, coef * exp_residual(i, ag * offc), 0.0)
        coef = coef * nu / dn
    # GREEDY limit
    psi = jnp.where(no_cis, iota, psi)
    w = jnp.where(no_cis, exp_residual(0, alpha * iota) / alpha, w)
    return psi, w


def crawl_value(iota, delta, mu, lam, nu, terms: int = 8):
    """V_{G_NCIS-APPROX-J} with J = ``terms`` (exact once terms > iota/beta).

    Returns mu * (w(iota) - exp(-alpha*iota) * psi(iota)).
    """
    alpha, beta, gamma = derived_params(delta, mu, lam, nu)
    psi, w = psi_w(iota, alpha, beta, gamma, nu, delta, terms)
    return mu * (w - jnp.exp(-alpha * jnp.asarray(iota)) * psi)


def crawl_frequency(iota, delta, mu, lam, nu, terms: int = 8):
    """f(iota; E) = 1/psi(iota; E) for the thresholded policy."""
    alpha, beta, gamma = derived_params(delta, mu, lam, nu)
    psi, _ = psi_w(iota, alpha, beta, gamma, nu, delta, terms)
    return 1.0 / psi


def value_greedy(iota, delta, mu):
    """Closed form V_GREEDY = (mu/delta) R^1(delta * iota) (no CIS)."""
    return mu / delta * exp_residual(1, delta * jnp.asarray(iota))


def value_cis(iota, delta, mu, gamma):
    """Closed form V_GREEDY_CIS (noiseless CIS assumption, beta = inf).

    alpha-hat = delta - gamma (clamped), nu-hat = 0; only the i = 0 term
    survives. At iota = inf the value saturates at mu/delta.
    """
    iota = jnp.asarray(iota)
    alpha = jnp.maximum(delta - gamma, 1e-6 * delta)
    ag = alpha + gamma
    v = mu * (
        exp_residual(0, ag * iota) / ag
        - jnp.exp(-alpha * iota) * exp_residual(0, gamma * iota) / gamma
    )
    return jnp.where(jnp.isinf(iota), mu / delta, v)


def freshness(tau_elap, n_cis, delta, lam, nu):
    """P[page fresh | history] = exp(-alpha tau) * (nu/gamma)^n  (eq. 1)."""
    alpha, _, gamma = derived_params(delta, 0.0, lam, nu)
    safe_gamma = jnp.where(gamma > 0, gamma, 1.0)
    log_ratio = jnp.where(
        gamma > 0, jnp.log(jnp.maximum(nu / safe_gamma, 1e-38)), 0.0
    )
    return jnp.exp(-alpha * tau_elap + n_cis * log_ratio)


def effective_time(tau_elap, n_cis, delta, lam, nu, cap: float = 1e9):
    """tau_EFF = tau_ELAP + beta * n_CIS, capped so downstream f32 math
    stays finite (cap is far above any threshold that matters)."""
    _, beta, _ = derived_params(delta, 0.0, lam, nu)
    b = jnp.where(jnp.isinf(beta), cap, beta)
    return jnp.minimum(tau_elap + b * n_cis, cap)


def mle_nll(theta, x, z, weight):
    """Negative log-likelihood of the Appendix-E change model.

    z_i ~ Bernoulli(1 - p_i) with p_i = exp(-<theta, x_i>) the probability
    of *no* change in interval i; x_i = (tau_elap, n_cis), theta = (alpha,
    alpha*beta). ``z_i = 1`` indicates a change was observed at crawl i.
    """
    s = x @ theta  # [N]
    p_nochange = jnp.exp(-s)
    p_nochange = jnp.clip(p_nochange, 1e-12, 1.0 - 1e-12)
    ll = jnp.where(z > 0.5, jnp.log1p(-p_nochange), -s)
    return -jnp.sum(weight * ll)
