"""L1 Pallas kernel: batched crawl-value V_{G_NCIS-APPROX-J}.

The compute hot-spot of the paper's Algorithm 1 is evaluating the crawl
value V(tau_EFF; E) for every candidate page at every tick. This kernel
evaluates a block of pages at once.

TPU mapping (DESIGN.md `Hardware-Adaptation`): the computation is pure
elementwise VPU work (exp, mul/add, selects) with a short unrolled J-term
inner loop; pages are tiled into VMEM-resident blocks via BlockSpec. The
kernel streams 7 input f32 lanes and 1 output lane per page (32 B/page),
so on real hardware it is HBM-bandwidth bound. We therefore optimize for
(a) a single exp per residual argument, (b) running-product recursions for
x^j/j! and nu^i/(delta+nu)^{i+1} (no pow, no factorial tables), and (c) no
scratch beyond two accumulators.

The kernel MUST run with interpret=True on this image: real-TPU lowering
emits a Mosaic custom-call the CPU PJRT plugin cannot execute.

Inputs are the *derived* parametrization (alpha, beta, gamma) plus
(nu, delta, mu); the coordinator precomputes those in f64 and feeds f32.
``beta`` must be pre-capped to a large finite value (BETA_CAP) instead of
+inf so that ``iota - i*beta`` never produces 0*inf = NaN.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Finite stand-in for beta = +inf (noiseless CIS); any iota of interest is
# far below this, so terms i >= 1 are masked out exactly as for inf.
BETA_CAP = 1e30
# Default page-block size: 2048 f32 lanes x 8 arrays = 64 KiB of VMEM.
DEFAULT_BLOCK = 2048


def _residual_terms(i: int, x, exp_neg_x):
    """R^i(x) given precomputed exp(-x), via the two-branch scheme of
    ref.exp_residual (direct for x >= 0.5, 6-term tail series below).
    Saturates to 1 for x > 2i + 60 — in f32 the partial sum overflows far
    earlier than in f64 (x^j -> inf at x ~ 1e5 for j >= 8), and huge x
    arise from lambda -> 1 pages (beta ~ 1e6) with several pending CIS."""
    saturated = x > 2.0 * i + 60.0
    xs = jnp.where(saturated, 2.0 * i + 60.0, x)
    term = jnp.ones_like(x)
    s = jnp.ones_like(x)
    for j in range(1, i + 1):
        term = term * xs / j
        s = s + term
    direct = jnp.where(saturated, 1.0, 1.0 - exp_neg_x * s)
    fact = 1.0
    for j in range(1, i + 2):
        fact *= j
    lead = x ** (i + 1) / fact
    ser = jnp.zeros_like(x)
    t = jnp.ones_like(x)
    for k in range(6):
        if k > 0:
            t = t * x / (i + 1 + k)
        ser = ser + t
    series = exp_neg_x * lead * ser
    out = jnp.where(x < 0.5, series, direct)
    return jnp.where(x < 0.0, 0.0, out)


def _crawl_value_block(iota, alpha, beta, gamma, nu, delta, mu, *, terms: int):
    """Crawl value for one block; plain jnp so it can be shared between the
    Pallas body and unit tests against ref.crawl_value."""
    no_cis = gamma <= 0.0
    g = jnp.where(no_cis, 1.0, gamma)
    ag = alpha + g
    dn = delta + nu
    psi = jnp.zeros_like(iota)
    w = jnp.zeros_like(iota)
    coef = 1.0 / dn
    for i in range(terms):
        off = iota - i * beta
        mask = off >= 0.0
        offc = jnp.where(mask, off, 0.0)
        # one exp per argument, shared by both branches of the residual
        eg = jnp.exp(-g * offc)
        eag = jnp.exp(-ag * offc)
        psi = psi + jnp.where(mask, _residual_terms(i, g * offc, eg) / g, 0.0)
        w = w + jnp.where(mask, coef * _residual_terms(i, ag * offc, eag), 0.0)
        coef = coef * nu / dn
    ea = jnp.exp(-alpha * iota)
    psi = jnp.where(no_cis, iota, psi)
    w = jnp.where(no_cis, _residual_terms(0, alpha * iota, ea) / alpha, w)
    return mu * (w - ea * psi)


def _kernel(iota_ref, alpha_ref, beta_ref, gamma_ref, nu_ref, delta_ref,
            mu_ref, out_ref, *, terms: int):
    out_ref[...] = _crawl_value_block(
        iota_ref[...], alpha_ref[...], beta_ref[...], gamma_ref[...],
        nu_ref[...], delta_ref[...], mu_ref[...], terms=terms,
    )


@functools.partial(jax.jit, static_argnames=("terms", "block"))
def crawl_value_pallas(iota, alpha, beta, gamma, nu, delta, mu,
                       terms: int = 8, block: int = DEFAULT_BLOCK):
    """Batched crawl value via pallas_call (interpret mode).

    All inputs are rank-1 f32 arrays of the same length N; N must be a
    multiple of ``block`` (the coordinator pads with sentinel pages whose
    mu == 0, making their value exactly 0).
    """
    (n,) = iota.shape
    assert n % block == 0, f"N={n} not a multiple of block={block}"
    grid = (n // block,)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    return pl.pallas_call(
        functools.partial(_kernel, terms=terms),
        out_shape=jax.ShapeDtypeStruct((n,), iota.dtype),
        grid=grid,
        in_specs=[spec] * 7,
        out_specs=spec,
        interpret=True,
    )(iota, alpha, beta, gamma, nu, delta, mu)
