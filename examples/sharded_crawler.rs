//! The deployable topology: a streaming, sharded crawler fed by a CIS
//! event stream through bounded queues (backpressure), with the PJRT
//! value engine exercised on the side for batched re-scoring.
//!
//! ```bash
//! make artifacts && cargo run --release --example sharded_crawler
//! ```

use ncis_crawl::coordinator::pipeline::{run_pipeline, PipelineConfig};
use ncis_crawl::figures::common::ExperimentSpec;
use ncis_crawl::params::DerivedParams;
use ncis_crawl::policy::PolicyKind;
use ncis_crawl::rngkit::{self, Rng};
use ncis_crawl::runtime::{PjrtEngine, ValueBatch};
use ncis_crawl::{CrawlerBuilder, Strategy};

fn main() -> ncis_crawl::Result<()> {
    let m = 20_000;
    let horizon = 10.0;
    let bandwidth = 2_000.0;
    let mut rng = Rng::new(7);
    let spec = ExperimentSpec::section6(m, 1).with_partial_cis().with_false_positives();
    let inst = spec.gen_instance(&mut rng).normalized();

    // CIS stream for the pipeline
    let mut cis: Vec<(f64, usize)> = Vec::new();
    for (i, p) in inst.pages.iter().enumerate() {
        let gamma = p.lam * p.delta + p.nu;
        for t in rngkit::poisson_process(&mut rng, gamma, horizon) {
            cis.push((t, i));
        }
    }
    cis.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    println!("pages={m} cis_events={} horizon={horizon}s R={bandwidth}/s", cis.len());

    // per-shard schedulers are stamped from one builder template: swap
    // the strategy or backend here and every shard follows
    let scheduler = CrawlerBuilder::new()
        .policy(PolicyKind::GreedyNcis)
        .strategy(Strategy::Lazy);
    for shards in [1usize, 2, 4, 8] {
        let cfg = PipelineConfig { shards, queue_depth: 128, bandwidth, horizon };
        let report = run_pipeline(&inst.pages, &scheduler, &cis, &cfg)?;
        println!(
            "shards={shards}: crawls={} stalls={} wall={:?} ({:.0} crawls/s real time)",
            report.total_crawls,
            report.backpressure_stalls,
            report.wall,
            report.total_crawls as f64 / report.wall.as_secs_f64(),
        );
    }

    // Batched re-scoring through the AOT Pallas kernel (PJRT), if built.
    match PjrtEngine::load(std::path::Path::new("artifacts")) {
        Ok(engine) => {
            let mut batch = ValueBatch::with_capacity(m);
            for (i, p) in inst.pages.iter().enumerate() {
                let d = DerivedParams::from_raw(p);
                batch.push(0.1 + (i % 100) as f64 * 0.05, &d);
            }
            let t0 = std::time::Instant::now();
            let (values, idx, best) = engine.crawl_values_argmax(8, &batch)?;
            println!(
                "\nPJRT batched re-score: {} pages in {:?}; top page {idx} V={best:.3e} \
                 (finite={} )",
                values.len(),
                t0.elapsed(),
                values.iter().all(|v| v.is_finite()),
            );
        }
        Err(e) => println!("\n(skip PJRT demo: {e}; run `make artifacts`)"),
    }
    Ok(())
}
