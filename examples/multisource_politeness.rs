//! Extension features demo: multi-source CIS (paper §3 footnote 2) and
//! per-host politeness rate limiting.
//!
//! ```bash
//! cargo run --release --example multisource_politeness
//! ```

use ncis_crawl::coordinator::hosts::{zipf_host_sizes, HostMap, PoliteScheduler};
use ncis_crawl::policy::multisource::{CisSource, MultiSourcePage};
use ncis_crawl::policy::PolicyKind;
use ncis_crawl::rngkit::Rng;
use ncis_crawl::sim::{generate_traces, simulate, CisDelay, SimConfig};
use ncis_crawl::{CrawlerBuilder, Strategy};

fn main() -> ncis_crawl::Result<()> {
    // --- multi-source CIS: a sitemap (precise, low recall) + a CDN ping
    // (noisy, high recall) merge into one equivalent observation process
    let page = MultiSourcePage {
        delta: 0.5,
        mu: 0.4,
        sources: vec![
            CisSource { lam: 0.35, nu: 0.02 }, // sitemap
            CisSource { lam: 0.80, nu: 0.60 }, // CDN ping
        ],
    };
    let merged = page.merged();
    let betas = page.source_betas()?;
    println!("multi-source page: merged lam={:.3} nu={:.3}", merged.lam, merged.nu);
    println!("per-source time-equivalents beta: sitemap={:.2} cdn={:.2}", betas[0], betas[1]);
    println!(
        "freshness after 1 sitemap ping: {:.4}  vs 1 cdn ping: {:.4}\n",
        page.freshness(2.0, &[1, 0])?,
        page.freshness(2.0, &[0, 1])?
    );

    // --- politeness: Zipf host sizes, per-host cool-down, accuracy cost
    let m = 400;
    let mut rng = Rng::new(42);
    let sizes = zipf_host_sizes(m, 12, &mut rng);
    println!("host sizes (Zipf): {sizes:?}");
    let pages: Vec<ncis_crawl::params::PageParams> = (0..m)
        .map(|_| ncis_crawl::params::PageParams {
            delta: rng.range(0.05, 1.0),
            mu: rng.range(0.05, 1.0),
            lam: 0.5,
            nu: 0.2,
        })
        .collect();
    let horizon = 200.0;
    let cfg = SimConfig::new(20.0, horizon)?;
    let mut trng = Rng::new(7);
    let traces = generate_traces(&pages, horizon, CisDelay::None, &mut trng);

    let crawler = CrawlerBuilder::new()
        .policy(PolicyKind::GreedyNcis)
        .strategy(Strategy::Exact)
        .pages(&pages);
    let mut plain = crawler.build()?;
    let acc_plain = simulate(&traces, &cfg, plain.as_mut()).accuracy;
    for min_interval in [0.0, 0.2, 1.0] {
        let map = HostMap::from_sizes(&sizes, min_interval);
        let inner = crawler.build()?;
        let mut polite = PoliteScheduler::new(inner, map);
        let res = simulate(&traces, &cfg, &mut polite);
        println!(
            "politeness {min_interval:>4}: accuracy {:.4} (plain {:.4}), vetoes {}, idle {}",
            res.accuracy, acc_plain, polite.vetoes, polite.idle_ticks
        );
    }
    println!("\nPoliteness trades a little freshness for per-host courtesy —");
    println!("the greedy argmax automatically reroutes budget to other hosts.");
    Ok(())
}
