//! Appendix-D demo: the discrete GREEDY policy adapts to bandwidth
//! changes with zero recomputation — the crawl-value argmax simply
//! starts being asked more (or less) often.
//!
//! ```bash
//! cargo run --release --example bandwidth_adaptation
//! ```

use ncis_crawl::figures::common::ExperimentSpec;
use ncis_crawl::policy::PolicyKind;
use ncis_crawl::rngkit::Rng;
use ncis_crawl::sim::engine::{BandwidthSchedule, SimConfig};
use ncis_crawl::sim::{generate_traces, simulate, CisDelay};
use ncis_crawl::{CrawlerBuilder, Strategy};

fn main() -> ncis_crawl::Result<()> {
    let spec = ExperimentSpec::section6(1000, 1);
    let mut rng = Rng::new(spec.seed);
    let inst = spec.gen_instance(&mut rng).normalized();
    let horizon = 400.0;

    let schedule =
        BandwidthSchedule::new(vec![(0.0, 100.0), (133.0, 150.0), (266.0, 100.0)])?;
    let cfg = SimConfig {
        bandwidth: schedule,
        horizon,
        cis_discard_window: None,
        timeline_window: Some(1000),
    };
    let mut trng = Rng::new(9);
    let traces = generate_traces(&inst.pages, horizon, CisDelay::None, &mut trng);
    let mut sched = CrawlerBuilder::new()
        .policy(PolicyKind::Greedy)
        .strategy(Strategy::Exact)
        .pages(&inst.pages)
        .build()?;
    let res = simulate(&traces, &cfg, sched.as_mut());

    println!("bandwidth schedule: 100 -> 150 @ t=133 -> 100 @ t=266  (m=1000)");
    println!("rolling accuracy over the last 1000 requests:\n");
    // print a coarse sparkline-style table
    let mut next_mark = 20.0;
    for &(t, acc) in &res.timeline {
        if t >= next_mark {
            let bars = (acc * 60.0).round() as usize;
            println!("t={t:6.0}  acc={acc:.3}  {}", "#".repeat(bars));
            next_mark += 20.0;
        }
    }
    println!("\ntotal crawls: {} over {} ticks", res.crawl_counts.iter().map(|&c| c as u64).sum::<u64>(), res.ticks);
    println!("accuracy rises after t=133 and falls back after t=266 — no re-solve needed.");
    Ok(())
}
