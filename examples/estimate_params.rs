//! Appendix-E demo: estimating CIS precision/recall from crawl logs.
//!
//! Compares the naive interval-counting estimator (biased, Fig 10) with
//! the MLE of (α, αβ) (Fig 11) — running the MLE both natively and, if
//! artifacts are built, through the AOT `mle_step` PJRT executable.
//!
//! ```bash
//! make artifacts && cargo run --release --example estimate_params
//! ```

use ncis_crawl::estimation::{
    empirical_gamma, generate_observations, mle_precision_recall, naive_precision_recall,
    quality_from_theta,
};
use ncis_crawl::params::PageParams;
use ncis_crawl::rngkit::Rng;
use ncis_crawl::runtime::PjrtEngine;

fn main() -> ncis_crawl::Result<()> {
    let mut rng = Rng::new(11);
    println!("{:>10} {:>10} | {:>10} {:>10} | {:>10} {:>10}",
             "true_prec", "true_rec", "naive_prec", "naive_rec", "mle_prec", "mle_rec");
    let engine = PjrtEngine::load(std::path::Path::new("artifacts")).ok();
    for &(tp, tr) in &[(0.3, 0.4), (0.5, 0.6), (0.7, 0.8), (0.9, 0.5)] {
        let page = PageParams::from_quality(0.25, 0.1, tp, tr);
        let obs = generate_observations(&page, 0.5, 100_000.0, &mut rng);
        let (np, nr) = naive_precision_recall(&obs);
        let (mp, mr) = mle_precision_recall(&obs, 60);
        println!("{tp:>10.3} {tr:>10.3} | {np:>10.3} {nr:>10.3} | {mp:>10.3} {mr:>10.3}");
        if let Some(eng) = &engine {
            // same fit through the AOT Newton-step artifact
            let pairs: Vec<(f64, f64)> = obs.iter().map(|o| (o.tau, o.n_cis)).collect();
            let z: Vec<f64> = obs.iter().map(|o| o.changed).collect();
            let n = pairs.len().min(4096);
            let (a, k) = eng.mle_fit(&pairs[..n], &z[..n], 50)?;
            let (pp, pr) = quality_from_theta(a, k, empirical_gamma(&obs));
            println!("{:>10} {:>10} | {:>10} {:>10} | {pp:>10.3} {pr:>10.3}  (PJRT mle_step)",
                     "", "", "", "");
        }
    }
    println!("\nThe naive estimator is biased (Fig 10); the MLE is not (Fig 11).");
    Ok(())
}
