//! End-to-end driver (the repository's headline validation run):
//! the full §6.7 semi-synthetic pipeline on a real generated workload.
//!
//! Pipeline: synthesize the Kolobov-style population → subsample →
//! derive CIS parameters from (precision, recall) → corrupt the policy's
//! quality beliefs at p ∈ {0, 0.1, 0.2} → run GREEDY / GREEDY-NCIS /
//! GREEDY-CIS+ through the lazy coordinator → report the paper's
//! headline metric (accuracy, with the NCIS lift over GREEDY).
//!
//! ```bash
//! cargo run --release --example semi_synthetic            # scaled default
//! cargo run --release --example semi_synthetic -- --full  # paper-sized (100k URLs)
//! ```
//!
//! The run is recorded in EXPERIMENTS.md §Fig5.

use ncis_crawl::figures::semisynth::{fig05, SemiSynthSpec};

fn main() -> ncis_crawl::Result<()> {
    let full = std::env::args().any(|a| a == "--full");
    let spec = if full {
        SemiSynthSpec { n_urls: 100_000, budget: 5_000.0, steps: 200.0, reps: 10, ..Default::default() }
    } else {
        SemiSynthSpec::default()
    };
    println!(
        "semi-synthetic e2e: {} URLs, budget {}/step, {} steps, {} reps{}",
        spec.n_urls,
        spec.budget,
        spec.steps,
        spec.reps,
        if full { " (paper-sized)" } else { " (scaled; pass --full for paper-sized)" }
    );
    let t0 = std::time::Instant::now();
    fig05(&spec)?;
    println!("completed in {:?}", t0.elapsed());
    println!("series written to target/figures/fig05_semisynthetic.csv");
    Ok(())
}
