//! Quickstart: build a small synthetic crawl problem, run the paper's
//! GREEDY-NCIS discrete policy against plain GREEDY, and compare both
//! to the optimal continuous baseline.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use ncis_crawl::coordinator::crawler::ValueBackend;
use ncis_crawl::params::{Instance, PageParams};
use ncis_crawl::policy::PolicyKind;
use ncis_crawl::rngkit::{self, Rng};
use ncis_crawl::sim::{generate_traces, simulate, CisDelay, SimConfig};
use ncis_crawl::solver;
use ncis_crawl::{CrawlerBuilder, Strategy};

fn main() -> ncis_crawl::Result<()> {
    // 1. A problem instance: 200 pages, Δ, μ ~ U[0,1], noisy CIS with
    //    bimodal observability (the paper's §6.6 setting).
    let mut rng = Rng::new(42);
    let pages: Vec<PageParams> = (0..200)
        .map(|_| PageParams {
            delta: rng.range(0.01, 1.0),
            mu: rng.range(0.01, 1.0),
            lam: rngkit::beta(&mut rng, 0.25, 0.25),
            nu: rng.range(0.1, 0.6),
        })
        .collect();
    let inst = Instance { pages, bandwidth: 20.0 }.normalized();

    // 2. The analytical baseline: the optimal continuous policy (no CIS).
    let baseline = solver::baseline_accuracy(&inst)?;
    println!("BASELINE (optimal continuous, no CIS): {baseline:.4}");

    // 3. Simulate the discrete policies over 5 trace realizations.
    let horizon = 500.0;
    let cfg = SimConfig::new(inst.bandwidth, horizon)?;
    for kind in [PolicyKind::Greedy, PolicyKind::GreedyCis, PolicyKind::GreedyNcis] {
        // every strategy/backend combination is built through the same
        // facade; swap Strategy::Lazy or a PJRT backend freely
        let mut sched = CrawlerBuilder::new()
            .policy(kind)
            .strategy(Strategy::Exact)
            .backend(ValueBackend::Native)
            .pages(&inst.pages)
            .build()?;
        let mut total = 0.0;
        let reps = 5;
        for rep in 0..reps {
            let mut trng = Rng::new(1000 + rep);
            let traces = generate_traces(&inst.pages, horizon, CisDelay::None, &mut trng);
            total += simulate(&traces, &cfg, sched.as_mut()).accuracy;
        }
        println!("{:<14} accuracy: {:.4}", kind.name(), total / reps as f64);
    }
    println!("\nGREEDY-NCIS exploits the noisy signals; GREEDY-CIS trusts them blindly.");
    Ok(())
}
