//! Minimal TOML-subset configuration parser (the image has no `serde`).
//!
//! Supports what the experiment configs need: `[section]` headers,
//! `key = value` with string / f64 / i64 / bool / homogeneous arrays,
//! `#` comments. Keys are addressed as `"section.key"` (top-level keys
//! have no prefix).

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// A parsed configuration value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// Quoted string.
    Str(String),
    /// Float (any number with `.` / `e`).
    Float(f64),
    /// Integer.
    Int(i64),
    /// Boolean.
    Bool(bool),
    /// Array of values.
    Array(Vec<Value>),
}

impl Value {
    /// As f64 (ints coerce).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    /// As i64.
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    /// As &str.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// As vec of f64.
    pub fn as_f64_array(&self) -> Option<Vec<f64>> {
        match self {
            Value::Array(vs) => vs.iter().map(|v| v.as_f64()).collect(),
            _ => None,
        }
    }
}

/// Parsed config: flat `section.key → value` map.
#[derive(Debug, Clone, Default)]
pub struct Config {
    map: BTreeMap<String, Value>,
}

fn parse_scalar(tok: &str, line_no: usize) -> Result<Value> {
    let tok = tok.trim();
    if tok.starts_with('"') && tok.ends_with('"') && tok.len() >= 2 {
        return Ok(Value::Str(tok[1..tok.len() - 1].to_string()));
    }
    if tok == "true" {
        return Ok(Value::Bool(true));
    }
    if tok == "false" {
        return Ok(Value::Bool(false));
    }
    if !tok.contains('.') && !tok.contains('e') && !tok.contains('E') {
        if let Ok(i) = tok.parse::<i64>() {
            return Ok(Value::Int(i));
        }
    }
    tok.parse::<f64>()
        .map(Value::Float)
        .map_err(|_| Error::Config(format!("line {line_no}: cannot parse value `{tok}`")))
}

fn parse_value(tok: &str, line_no: usize) -> Result<Value> {
    let tok = tok.trim();
    if tok.starts_with('[') {
        if !tok.ends_with(']') {
            return Err(Error::Config(format!("line {line_no}: unterminated array")));
        }
        let inner = &tok[1..tok.len() - 1];
        if inner.trim().is_empty() {
            return Ok(Value::Array(vec![]));
        }
        let items = inner
            .split(',')
            .map(|s| parse_scalar(s, line_no))
            .collect::<Result<Vec<_>>>()?;
        return Ok(Value::Array(items));
    }
    parse_scalar(tok, line_no)
}

impl Config {
    /// Parse config text.
    pub fn parse(text: &str) -> Result<Self> {
        let mut map = BTreeMap::new();
        let mut section = String::new();
        for (ln, raw) in text.lines().enumerate() {
            let line_no = ln + 1;
            // strip comments (naive: not inside strings — acceptable for
            // our configs, which never put '#' in strings)
            let line = match raw.find('#') {
                Some(pos) => &raw[..pos],
                None => raw,
            }
            .trim();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') {
                    return Err(Error::Config(format!("line {line_no}: bad section header")));
                }
                section = line[1..line.len() - 1].trim().to_string();
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| Error::Config(format!("line {line_no}: expected key = value")))?;
            let full_key = if section.is_empty() {
                key.trim().to_string()
            } else {
                format!("{section}.{}", key.trim())
            };
            map.insert(full_key, parse_value(value, line_no)?);
        }
        Ok(Self { map })
    }

    /// Load from a file.
    pub fn load(path: &std::path::Path) -> Result<Self> {
        Self::parse(&std::fs::read_to_string(path)?)
    }

    /// Raw lookup.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.map.get(key)
    }

    /// f64 with default.
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Value::as_f64).unwrap_or(default)
    }

    /// usize with default.
    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(Value::as_i64).map(|i| i as usize).unwrap_or(default)
    }

    /// String with default.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).and_then(Value::as_str).unwrap_or(default).to_string()
    }

    /// bool with default.
    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Value::as_bool).unwrap_or(default)
    }

    /// All keys (for diagnostics).
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.map.keys().map(String::as_str)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment definition
title = "fig4"
reps = 100

[instance]
m = 1000
bandwidth = 100.0
horizon = 1e3
lambda_beta = [0.25, 0.25]
nu_range = [0.1, 0.6]
use_cis = true
policies = ["GREEDY", "GREEDY-NCIS"]
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.str_or("title", ""), "fig4");
        assert_eq!(c.usize_or("reps", 0), 100);
        assert_eq!(c.usize_or("instance.m", 0), 1000);
        assert_eq!(c.f64_or("instance.bandwidth", 0.0), 100.0);
        assert_eq!(c.f64_or("instance.horizon", 0.0), 1000.0);
        assert!(c.bool_or("instance.use_cis", false));
        assert_eq!(
            c.get("instance.lambda_beta").unwrap().as_f64_array().unwrap(),
            vec![0.25, 0.25]
        );
        match c.get("instance.policies").unwrap() {
            Value::Array(v) => assert_eq!(v.len(), 2),
            _ => panic!(),
        }
    }

    #[test]
    fn defaults_apply() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.f64_or("nope", 7.5), 7.5);
        assert_eq!(c.str_or("nope", "x"), "x");
    }

    #[test]
    fn errors_on_bad_lines() {
        assert!(Config::parse("[unterminated").is_err());
        assert!(Config::parse("key value").is_err());
        assert!(Config::parse("key = [1, 2").is_err());
        assert!(Config::parse("key = what").is_err());
    }

    #[test]
    fn comments_stripped() {
        let c = Config::parse("a = 1 # trailing\n# full line\nb = 2").unwrap();
        assert_eq!(c.usize_or("a", 0), 1);
        assert_eq!(c.usize_or("b", 0), 2);
    }
}
