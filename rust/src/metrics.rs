//! Observability substrate: a small metrics registry (counters, gauges,
//! time histograms) with text exposition, used by the coordinator and
//! the streaming pipeline. Thread-safe via atomics so shard workers can
//! record without locks.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// A monotonically increasing counter.
#[derive(Debug, Default)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    /// Increment by 1.
    pub fn inc(&self) {
        self.v.fetch_add(1, Ordering::Relaxed);
    }

    /// Increment by `n`.
    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// A gauge (set-to-latest f64, stored as bits).
#[derive(Debug, Default)]
pub struct Gauge {
    bits: AtomicU64,
}

impl Gauge {
    /// Set the value.
    pub fn set(&self, x: f64) {
        self.bits.store(x.to_bits(), Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }
}

/// Fixed-bucket duration histogram (log-spaced from 1µs to ~17s).
#[derive(Debug)]
pub struct DurationHisto {
    buckets: Vec<AtomicU64>,
    sum_ns: AtomicU64,
    count: AtomicU64,
}

const HISTO_BUCKETS: usize = 25; // 2^i µs, i=0..24, plus one overflow slot

impl Default for DurationHisto {
    fn default() -> Self {
        Self {
            // one extra slot past the largest finite bucket: durations
            // beyond ~17s saturate there instead of aliasing into the
            // top power-of-two bucket
            buckets: (0..=HISTO_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            sum_ns: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }
}

impl DurationHisto {
    /// Record a duration. Durations past the largest finite bucket
    /// edge (2^25 µs ≈ 33.5s) land in a dedicated overflow slot.
    pub fn observe(&self, d: std::time::Duration) {
        let us = d.as_micros() as u64;
        let b = (64 - us.max(1).leading_zeros() as usize - 1).min(HISTO_BUCKETS);
        self.buckets[b].fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    /// Time a closure, recording its duration.
    pub fn time<T>(&self, f: impl FnOnce() -> T) -> T {
        let t0 = std::time::Instant::now();
        let out = f();
        self.observe(t0.elapsed());
        out
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean duration in seconds.
    pub fn mean_s(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return f64::NAN;
        }
        self.sum_ns.load(Ordering::Relaxed) as f64 / n as f64 / 1e9
    }

    /// Approximate quantile from the log buckets (upper bucket edge).
    /// Bucket counts are exact in f64 (far below 2^53), so the shared
    /// scan reproduces the pre-dedupe integer walk bit-for-bit; the
    /// within-bucket fraction is discarded — this histogram's contract
    /// is the conservative upper edge.
    pub fn quantile_s(&self, q: f64) -> f64 {
        let n = self.count();
        if n == 0 {
            return f64::NAN;
        }
        let target = (q.clamp(0.0, 1.0) * n as f64).ceil();
        let masses = self.buckets.iter().map(|b| b.load(Ordering::Relaxed) as f64);
        match crate::stats::cum_mass_bucket(masses, target) {
            Some((b, _)) if b < HISTO_BUCKETS => (1u64 << (b + 1)) as f64 / 1e6,
            // the target mass sits in the overflow slot: the true
            // duration has no finite bucket edge, so saturate instead
            // of reporting the aliased top edge
            _ => f64::INFINITY,
        }
    }
}

/// A named registry for exposition.
#[derive(Debug, Default)]
pub struct Registry {
    counters: Mutex<Vec<(String, std::sync::Arc<Counter>)>>,
    gauges: Mutex<Vec<(String, std::sync::Arc<Gauge>)>>,
    histos: Mutex<Vec<(String, std::sync::Arc<DurationHisto>)>>,
}

/// Lock a registry mutex, surviving poison: a panicked worker must not
/// also take down metrics exposition — the stored `Arc`s are always
/// structurally valid, so the poisoned state is safely recoverable.
fn lock_resilient<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

impl Registry {
    /// Register (or create) a counter.
    pub fn counter(&self, name: &str) -> std::sync::Arc<Counter> {
        let mut cs = lock_resilient(&self.counters);
        if let Some((_, c)) = cs.iter().find(|(n, _)| n == name) {
            return c.clone();
        }
        let c = std::sync::Arc::new(Counter::default());
        cs.push((name.to_string(), c.clone()));
        c
    }

    /// Register (or create) a gauge.
    pub fn gauge(&self, name: &str) -> std::sync::Arc<Gauge> {
        let mut gs = lock_resilient(&self.gauges);
        if let Some((_, g)) = gs.iter().find(|(n, _)| n == name) {
            return g.clone();
        }
        let g = std::sync::Arc::new(Gauge::default());
        gs.push((name.to_string(), g.clone()));
        g
    }

    /// Register (or create) a duration histogram.
    pub fn histo(&self, name: &str) -> std::sync::Arc<DurationHisto> {
        let mut hs = lock_resilient(&self.histos);
        if let Some((_, h)) = hs.iter().find(|(n, _)| n == name) {
            return h.clone();
        }
        let h = std::sync::Arc::new(DurationHisto::default());
        hs.push((name.to_string(), h.clone()));
        h
    }

    /// Text exposition (Prometheus-flavoured, `name value` lines).
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (n, c) in lock_resilient(&self.counters).iter() {
            out.push_str(&format!("{n} {}\n", c.get()));
        }
        for (n, g) in lock_resilient(&self.gauges).iter() {
            out.push_str(&format!("{n} {}\n", g.get()));
        }
        for (n, h) in lock_resilient(&self.histos).iter() {
            out.push_str(&format!(
                "{n}_count {}\n{n}_mean_seconds {:.9}\n{n}_p99_seconds {:.9}\n",
                h.count(),
                h.mean_s(),
                h.quantile_s(0.99)
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge() {
        let r = Registry::default();
        let c = r.counter("crawls_total");
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        // same name returns the same counter
        r.counter("crawls_total").inc();
        assert_eq!(c.get(), 6);
        let g = r.gauge("lambda_estimate");
        g.set(0.125);
        assert_eq!(g.get(), 0.125);
    }

    #[test]
    fn histogram_quantiles_and_mean() {
        let h = DurationHisto::default();
        for us in [1u64, 10, 100, 1000, 10_000] {
            h.observe(std::time::Duration::from_micros(us));
        }
        assert_eq!(h.count(), 5);
        assert!(h.mean_s() > 0.0);
        let p50 = h.quantile_s(0.5);
        let p99 = h.quantile_s(0.99);
        assert!(p50 <= p99);
        assert!(p99 >= 0.01, "p99 {p99} should cover the 10ms sample");
    }

    #[test]
    fn histogram_overflow_saturates_instead_of_aliasing() {
        let h = DurationHisto::default();
        // 60s > 2^25 µs: must land in the overflow slot, not the top
        // finite bucket
        h.observe(std::time::Duration::from_secs(60));
        assert_eq!(h.count(), 1);
        assert!(h.quantile_s(0.99).is_infinite());
        // a duration inside the top finite bucket still reports its
        // finite upper edge
        let h2 = DurationHisto::default();
        h2.observe(std::time::Duration::from_secs(20)); // in [2^24, 2^25) µs
        assert!(h2.quantile_s(0.99).is_finite());
        assert!((h2.quantile_s(0.99) - (1u64 << 25) as f64 / 1e6).abs() < 1e-9);
    }

    #[test]
    fn histogram_time_helper() {
        let h = DurationHisto::default();
        let out = h.time(|| 21 * 2);
        assert_eq!(out, 42);
        assert_eq!(h.count(), 1);
    }

    #[test]
    fn render_exposition() {
        let r = Registry::default();
        r.counter("a_total").add(3);
        r.gauge("b").set(1.5);
        r.histo("lat").observe(std::time::Duration::from_micros(5));
        let text = r.render();
        assert!(text.contains("a_total 3"));
        assert!(text.contains("b 1.5"));
        assert!(text.contains("lat_count 1"));
        assert!(text.contains("lat_p99_seconds"));
    }

    #[test]
    fn registry_survives_a_poisoned_lock() {
        let r = Registry::default();
        r.counter("a_total").add(2);
        // poison the counters mutex the way a panicking worker would
        let poisoned = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _guard = r.counters.lock().unwrap();
            panic!("worker died while registering");
        }));
        assert!(poisoned.is_err());
        assert!(r.counters.is_poisoned());
        // registration and exposition still work after the poison
        r.counter("a_total").inc();
        assert_eq!(r.counter("a_total").get(), 3);
        assert!(r.render().contains("a_total 3"));
    }

    #[test]
    fn thread_safety() {
        let r = std::sync::Arc::new(Registry::default());
        let c = r.counter("shared");
        std::thread::scope(|s| {
            for _ in 0..4 {
                let c = c.clone();
                s.spawn(move || {
                    for _ in 0..1000 {
                        c.inc();
                    }
                });
            }
        });
        assert_eq!(c.get(), 4000);
    }
}
