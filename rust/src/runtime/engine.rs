//! The PJRT execution engine.
//!
//! Loads every HLO-text artifact from the manifest, compiles it once on a
//! PJRT CPU client (`xla` crate), and exposes typed entry points used on
//! the coordinator's hot path. Pattern follows
//! `/opt/xla-example/load_hlo/`: `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `client.compile` → `execute`.
//!
//! The `xla` crate is not part of the default dependency-free build: the
//! real engine only compiles under the `pjrt` cargo feature (which
//! expects a vendored `xla` crate, see EXPERIMENTS.md §PJRT). Without it
//! a stub with the same API is substituted whose `load` always fails, so
//! every call site (benches, examples, the `artifacts` CLI command, the
//! parity tests) takes its existing "artifacts unavailable" fallback and
//! the native f64 engine serves the hot path.

#[cfg(feature = "pjrt")]
mod xla_engine {
    use std::collections::HashMap;
    use std::path::Path;

    use crate::error::{Error, Result};
    use crate::runtime::artifacts::Manifest;
    use crate::runtime::ValueBatch;

    struct LoadedExec {
        exe: xla::PjRtLoadedExecutable,
        batch: usize,
    }

    /// PJRT engine over the AOT artifacts.
    pub struct PjrtEngine {
        #[allow(dead_code)]
        client: xla::PjRtClient,
        /// crawl_value executables keyed by (terms, batch).
        crawl: HashMap<(u32, usize), LoadedExec>,
        freshness: Option<LoadedExec>,
        mle: Option<LoadedExec>,
        manifest: Manifest,
    }

    impl std::fmt::Debug for PjrtEngine {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            f.debug_struct("PjrtEngine")
                .field("crawl_execs", &self.crawl.len())
                .field("freshness", &self.freshness.is_some())
                .field("mle", &self.mle.is_some())
                .finish()
        }
    }

    impl PjrtEngine {
        /// Load + compile every artifact under `dir` (expects
        /// `dir/manifest.txt`).
        pub fn load(dir: &Path) -> Result<Self> {
            let manifest = Manifest::load(dir)?;
            let client = xla::PjRtClient::cpu()?;
            let mut crawl = HashMap::new();
            let mut freshness = None;
            let mut mle = None;
            for spec in &manifest.specs {
                let proto = xla::HloModuleProto::from_text_file(&spec.path)?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client.compile(&comp)?;
                let loaded = LoadedExec { exe, batch: spec.batch };
                match spec.kind.as_str() {
                    "crawl_value" => {
                        let terms = spec.terms.ok_or_else(|| {
                            Error::Manifest(format!("{}: missing terms", spec.name))
                        })?;
                        crawl.insert((terms, spec.batch), loaded);
                    }
                    "freshness" => freshness = Some(loaded),
                    "mle_step" => mle = Some(loaded),
                    other => {
                        return Err(Error::Manifest(format!("unknown artifact kind {other}")));
                    }
                }
            }
            Ok(Self { client, crawl, freshness, mle, manifest })
        }

        /// Artifact manifest that was loaded.
        pub fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        /// Available (terms, batch) crawl-value configurations.
        pub fn crawl_configs(&self) -> Vec<(u32, usize)> {
            let mut v: Vec<(u32, usize)> = self.crawl.keys().copied().collect();
            v.sort_unstable();
            v
        }

        fn pick_crawl(&self, terms: u32, n: usize) -> Result<&LoadedExec> {
            // smallest batch that fits n, else the largest (chunked execution)
            let mut best: Option<&LoadedExec> = None;
            let mut largest: Option<&LoadedExec> = None;
            for ((t, b), le) in &self.crawl {
                if *t != terms {
                    continue;
                }
                if largest.map_or(true, |l| *b > l.batch) {
                    largest = Some(le);
                }
                if *b >= n && best.map_or(true, |x| *b < x.batch) {
                    best = Some(le);
                }
            }
            best.or(largest).ok_or_else(|| {
                Error::Runtime(format!("no crawl_value artifact with terms={terms}"))
            })
        }

        /// Batched crawl values. Executes in chunks of the artifact batch
        /// size (padding the tail with μ=0 sentinels) and returns exactly
        /// `batch.len()` values.
        pub fn crawl_values(&self, terms: u32, batch: &ValueBatch) -> Result<Vec<f32>> {
            let n = batch.len();
            if n == 0 {
                return Ok(Vec::new());
            }
            let le = self.pick_crawl(terms, n)?;
            let b = le.batch;
            let mut out = Vec::with_capacity(n);
            let mut chunk = ValueBatch::with_capacity(b);
            let mut start = 0;
            while start < n {
                let end = (start + b).min(n);
                chunk.clear();
                chunk.iota.extend_from_slice(&batch.iota[start..end]);
                chunk.alpha.extend_from_slice(&batch.alpha[start..end]);
                chunk.beta.extend_from_slice(&batch.beta[start..end]);
                chunk.gamma.extend_from_slice(&batch.gamma[start..end]);
                chunk.nu.extend_from_slice(&batch.nu[start..end]);
                chunk.delta.extend_from_slice(&batch.delta[start..end]);
                chunk.mu.extend_from_slice(&batch.mu[start..end]);
                chunk.pad_to(b);
                let (values, _, _) = self.execute_crawl(le, &chunk)?;
                out.extend_from_slice(&values[..end - start]);
                start = end;
            }
            Ok(out)
        }

        /// Batched crawl values plus the argmax (index into `batch`). For a
        /// single-chunk batch the argmax comes fused from the device; for
        /// chunked batches it is reduced across chunk maxima host-side.
        pub fn crawl_values_argmax(
            &self,
            terms: u32,
            batch: &ValueBatch,
        ) -> Result<(Vec<f32>, usize, f32)> {
            let n = batch.len();
            if n == 0 {
                return Err(Error::Runtime("empty batch".into()));
            }
            let le = self.pick_crawl(terms, n)?;
            if n <= le.batch {
                let mut chunk;
                let cref = if n == le.batch {
                    batch
                } else {
                    chunk = batch.clone();
                    chunk.pad_to(le.batch);
                    &chunk
                };
                let (values, idx, best) = self.execute_crawl(le, cref)?;
                let idx = idx.min(n - 1);
                return Ok((values[..n].to_vec(), idx, best));
            }
            let values = self.crawl_values(terms, batch)?;
            let (mut bi, mut bv) = (0usize, f32::NEG_INFINITY);
            for (i, &v) in values.iter().enumerate() {
                if v > bv {
                    bv = v;
                    bi = i;
                }
            }
            Ok((values, bi, bv))
        }

        fn execute_crawl(
            &self,
            le: &LoadedExec,
            chunk: &ValueBatch,
        ) -> Result<(Vec<f32>, usize, f32)> {
            debug_assert_eq!(chunk.len(), le.batch);
            let args = [
                xla::Literal::vec1(&chunk.iota),
                xla::Literal::vec1(&chunk.alpha),
                xla::Literal::vec1(&chunk.beta),
                xla::Literal::vec1(&chunk.gamma),
                xla::Literal::vec1(&chunk.nu),
                xla::Literal::vec1(&chunk.delta),
                xla::Literal::vec1(&chunk.mu),
            ];
            let result = le.exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
            let (values_l, idx_l, best_l) = result.to_tuple3()?;
            let values = values_l.to_vec::<f32>()?;
            let idx = idx_l.to_vec::<i32>()?[0] as usize;
            let best = best_l.to_vec::<f32>()?[0];
            Ok((values, idx, best))
        }

        /// Batched freshness probabilities (eq. 1): inputs are per-page
        /// `tau_elap`, `n_cis`, `alpha`, `log(ν/γ)`.
        pub fn freshness(
            &self,
            tau_elap: &[f32],
            n_cis: &[f32],
            alpha: &[f32],
            log_fp_ratio: &[f32],
        ) -> Result<Vec<f32>> {
            let le = self
                .freshness
                .as_ref()
                .ok_or_else(|| Error::Runtime("no freshness artifact".into()))?;
            let n = tau_elap.len();
            let b = le.batch;
            let mut out = Vec::with_capacity(n);
            let pad = |s: &[f32], fill: f32| -> Vec<f32> {
                let mut v = s.to_vec();
                v.resize(b, fill);
                v
            };
            let mut start = 0;
            while start < n {
                let end = (start + b).min(n);
                let args = [
                    xla::Literal::vec1(&pad(&tau_elap[start..end], 0.0)),
                    xla::Literal::vec1(&pad(&n_cis[start..end], 0.0)),
                    xla::Literal::vec1(&pad(&alpha[start..end], 1.0)),
                    xla::Literal::vec1(&pad(&log_fp_ratio[start..end], 0.0)),
                ];
                let result = le.exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
                let fr = result.to_tuple1()?.to_vec::<f32>()?;
                out.extend_from_slice(&fr[..end - start]);
                start = end;
            }
            Ok(out)
        }

        /// Fit the Appendix-E model by iterating the AOT Newton step.
        /// `obs` rows are `(tau_elap, n_cis)`, `z` ∈ {0,1} marks observed
        /// changes. Returns `theta = (alpha, alpha*beta)`.
        pub fn mle_fit(&self, obs: &[(f64, f64)], z: &[f64], iters: usize) -> Result<(f64, f64)> {
            let le = self
                .mle
                .as_ref()
                .ok_or_else(|| Error::Runtime("no mle_step artifact".into()))?;
            let b = le.batch;
            if obs.len() != z.len() {
                return Err(Error::Runtime("obs/z length mismatch".into()));
            }
            // pack (truncating to one batch: callers subsample; weight-0 pads)
            let n = obs.len().min(b);
            let mut x = vec![0f32; b * 2];
            let mut zz = vec![0f32; b];
            let mut w = vec![0f32; b];
            for i in 0..n {
                x[i * 2] = obs[i].0 as f32;
                x[i * 2 + 1] = obs[i].1 as f32;
                zz[i] = z[i] as f32;
                w[i] = 1.0;
            }
            let x_lit = xla::Literal::vec1(&x).reshape(&[b as i64, 2])?;
            let z_lit = xla::Literal::vec1(&zz);
            let w_lit = xla::Literal::vec1(&w);
            let mut theta = [0.5f32, 0.5f32];
            for _ in 0..iters {
                let t_lit = xla::Literal::vec1(&theta[..]);
                let args = [t_lit, x_lit.clone(), z_lit.clone(), w_lit.clone()];
                let result = le.exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
                let (t_new, _nll) = result.to_tuple2()?;
                let tv = t_new.to_vec::<f32>()?;
                theta = [tv[0], tv[1]];
            }
            Ok((theta[0] as f64, theta[1] as f64))
        }
    }
}

#[cfg(feature = "pjrt")]
pub use xla_engine::PjrtEngine;

#[cfg(not(feature = "pjrt"))]
mod stub {
    use std::path::Path;

    use crate::error::{Error, Result};
    use crate::runtime::artifacts::Manifest;
    use crate::runtime::ValueBatch;

    /// Stub PJRT engine: the API of the real engine with a `load` that
    /// always fails, so it can never be instantiated. Callers uniformly
    /// treat a failed `load` as "artifacts unavailable" and fall back to
    /// [`crate::runtime::NativeEngine`].
    #[derive(Debug)]
    pub struct PjrtEngine {
        manifest: Manifest,
    }

    const DISABLED: &str =
        "ncis_crawl was built without the `pjrt` feature; declare a vendored \
         `xla` crate in rust/Cargo.toml and rebuild with `--features pjrt` \
         (EXPERIMENTS.md §PJRT)";

    impl PjrtEngine {
        /// Always fails: PJRT support is not compiled in.
        pub fn load(_dir: &Path) -> Result<Self> {
            Err(Error::Runtime(DISABLED.into()))
        }

        /// Artifact manifest that was loaded.
        pub fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        /// Available (terms, batch) crawl-value configurations.
        pub fn crawl_configs(&self) -> Vec<(u32, usize)> {
            Vec::new()
        }

        /// Unreachable in practice (no instance can exist).
        pub fn crawl_values(&self, _terms: u32, _batch: &ValueBatch) -> Result<Vec<f32>> {
            Err(Error::Runtime(DISABLED.into()))
        }

        /// Unreachable in practice (no instance can exist).
        pub fn crawl_values_argmax(
            &self,
            _terms: u32,
            _batch: &ValueBatch,
        ) -> Result<(Vec<f32>, usize, f32)> {
            Err(Error::Runtime(DISABLED.into()))
        }

        /// Unreachable in practice (no instance can exist).
        pub fn freshness(
            &self,
            _tau_elap: &[f32],
            _n_cis: &[f32],
            _alpha: &[f32],
            _log_fp_ratio: &[f32],
        ) -> Result<Vec<f32>> {
            Err(Error::Runtime(DISABLED.into()))
        }

        /// Unreachable in practice (no instance can exist).
        pub fn mle_fit(&self, _obs: &[(f64, f64)], _z: &[f64], _iters: usize) -> Result<(f64, f64)> {
            Err(Error::Runtime(DISABLED.into()))
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use stub::PjrtEngine;

#[cfg(test)]
mod tests {
    //! Engine tests live in `tests/pjrt_parity.rs` (they need the
    //! artifacts directory built by `make artifacts` and the `pjrt`
    //! feature). The stub's load-failure path is exercised there too:
    //! every parity test SKIPs cleanly when `load` errors.

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_load_fails_with_guidance() {
        let err = super::PjrtEngine::load(std::path::Path::new("artifacts")).unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
