//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts.
//!
//! The request path is rust-only: `python/compile/aot.py` ran once at
//! `make artifacts` and emitted HLO *text* (the interchange format that
//! round-trips through xla_extension 0.5.1 — serialized jax ≥ 0.5 protos
//! do not). [`engine::PjrtEngine`] compiles every artifact listed in the
//! manifest on a PJRT CPU client and exposes typed entry points;
//! [`native`] is the pure-rust f64 fallback with the same API, used for
//! parity tests and when `artifacts/` is absent.

pub mod artifacts;
pub mod engine;
pub mod native;

pub use artifacts::{ArtifactSpec, Manifest};
pub use engine::PjrtEngine;
pub use native::NativeEngine;

/// Finite stand-in for β = ∞ fed to the f32 kernels (keep in sync with
/// `python/compile/kernels/crawl_value.py::BETA_CAP`).
pub const BETA_CAP: f64 = 1e30;

/// A batched crawl-value request: parallel arrays, one entry per page.
#[derive(Debug, Clone, Default)]
pub struct ValueBatch {
    /// Effective elapsed times ι (β·n_CIS already folded in, ∞-capped).
    pub iota: Vec<f32>,
    /// Unsignalled change rates α.
    pub alpha: Vec<f32>,
    /// CIS time-equivalents β (capped at [`BETA_CAP`]).
    pub beta: Vec<f32>,
    /// Observed CIS rates γ.
    pub gamma: Vec<f32>,
    /// False-positive rates ν.
    pub nu: Vec<f32>,
    /// Change rates Δ.
    pub delta: Vec<f32>,
    /// Importance weights μ̃ (0 ⇒ sentinel/padding page).
    pub mu: Vec<f32>,
}

impl ValueBatch {
    /// Empty batch with capacity for `n` pages.
    pub fn with_capacity(n: usize) -> Self {
        Self {
            iota: Vec::with_capacity(n),
            alpha: Vec::with_capacity(n),
            beta: Vec::with_capacity(n),
            gamma: Vec::with_capacity(n),
            nu: Vec::with_capacity(n),
            delta: Vec::with_capacity(n),
            mu: Vec::with_capacity(n),
        }
    }

    /// Number of pages in the batch.
    pub fn len(&self) -> usize {
        self.iota.len()
    }

    /// Is the batch empty?
    pub fn is_empty(&self) -> bool {
        self.iota.is_empty()
    }

    /// Append one page.
    pub fn push(&mut self, iota: f64, d: &crate::params::DerivedParams) {
        self.iota.push(iota.min(BETA_CAP) as f32);
        self.alpha.push(d.alpha as f32);
        self.beta.push(d.beta_capped() as f32);
        self.gamma.push(d.gamma as f32);
        self.nu.push(d.nu as f32);
        self.delta.push(d.delta as f32);
        self.mu.push(d.mu as f32);
    }

    /// Clear all arrays (capacity preserved).
    pub fn clear(&mut self) {
        self.iota.clear();
        self.alpha.clear();
        self.beta.clear();
        self.gamma.clear();
        self.nu.clear();
        self.delta.clear();
        self.mu.clear();
    }

    /// Pad to `n` pages with μ = 0 sentinels (value exactly 0).
    pub fn pad_to(&mut self, n: usize) {
        while self.len() < n {
            self.iota.push(1.0);
            self.alpha.push(1.0);
            self.beta.push(BETA_CAP as f32);
            self.gamma.push(0.0);
            self.nu.push(0.0);
            self.delta.push(1.0);
            self.mu.push(0.0);
        }
    }
}
