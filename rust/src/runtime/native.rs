//! Pure-rust f64 engine with the same API surface as [`super::PjrtEngine`].
//!
//! Used (a) as the reference in PJRT-parity tests, (b) as the fallback
//! when `artifacts/` has not been built, and (c) by the lazy scheduler
//! for single-page evaluations where a device roundtrip would dominate.

use crate::params::DerivedParams;
use crate::policy::value;
use crate::runtime::ValueBatch;

/// Native (host, f64) evaluation engine.
#[derive(Debug, Clone, Default)]
pub struct NativeEngine;

impl NativeEngine {
    /// Batched crawl values, mirroring `PjrtEngine::crawl_values`.
    pub fn crawl_values(&self, terms: u32, batch: &ValueBatch) -> Vec<f32> {
        (0..batch.len()).map(|i| self.value_at(terms, batch, i) as f32).collect()
    }

    /// Batched values + argmax, mirroring `PjrtEngine::crawl_values_argmax`.
    pub fn crawl_values_argmax(&self, terms: u32, batch: &ValueBatch) -> (Vec<f32>, usize, f32) {
        let values = self.crawl_values(terms, batch);
        let (mut bi, mut bv) = (0usize, f32::NEG_INFINITY);
        for (i, &v) in values.iter().enumerate() {
            if v > bv {
                bv = v;
                bi = i;
            }
        }
        (values, bi, bv)
    }

    fn value_at(&self, terms: u32, b: &ValueBatch, i: usize) -> f64 {
        let d = DerivedParams {
            alpha: b.alpha[i] as f64,
            beta: b.beta[i] as f64,
            gamma: b.gamma[i] as f64,
            nu: b.nu[i] as f64,
            delta: b.delta[i] as f64,
            mu: b.mu[i] as f64,
        };
        value::value_ncis(b.iota[i] as f64, &d, terms)
    }

    /// Batched freshness (eq. 1).
    pub fn freshness(
        &self,
        tau_elap: &[f32],
        n_cis: &[f32],
        alpha: &[f32],
        log_fp_ratio: &[f32],
    ) -> Vec<f32> {
        tau_elap
            .iter()
            .zip(n_cis)
            .zip(alpha.iter().zip(log_fp_ratio))
            .map(|((&t, &n), (&a, &lr))| {
                ((-a as f64 * t as f64) + n as f64 * lr as f64).exp() as f32
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::PageParams;

    fn batch() -> ValueBatch {
        let mut b = ValueBatch::with_capacity(4);
        for (delta, mu, lam, nu, iota) in [
            (0.5, 0.8, 0.6, 0.3, 1.0),
            (1.0, 0.2, 0.0, 0.0, 4.0),
            (0.8, 0.5, 0.9, 0.0, 2.0),
            (0.3, 0.9, 0.2, 0.6, 0.5),
        ] {
            let d = PageParams { delta, mu, lam, nu }.derive().unwrap();
            b.push(iota, &d);
        }
        b
    }

    #[test]
    fn native_matches_value_fn() {
        let b = batch();
        let eng = NativeEngine;
        let values = eng.crawl_values(8, &b);
        assert_eq!(values.len(), 4);
        // spot check page 1 (pure GREEDY page)
        let want = value::value_greedy(4.0, 1.0, 0.2);
        assert!((values[1] as f64 - want).abs() < 1e-6);
    }

    #[test]
    fn argmax_consistent() {
        let b = batch();
        let eng = NativeEngine;
        let (values, idx, best) = eng.crawl_values_argmax(8, &b);
        let want = values
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        assert_eq!(idx, want.0);
        assert_eq!(best, *want.1);
    }

    #[test]
    fn padded_sentinels_are_zero() {
        let mut b = batch();
        b.pad_to(8);
        let eng = NativeEngine;
        let values = eng.crawl_values(8, &b);
        assert!(values[4..].iter().all(|&v| v == 0.0));
    }
}
