//! Artifact manifest: discovery of the AOT outputs under `artifacts/`.
//!
//! The manifest is a plain text file, one artifact per line, `key=value`
//! pairs separated by whitespace (written by `python/compile/aot.py`):
//!
//! ```text
//! kind=crawl_value name=crawl_value_n2048_j8 file=crawl_value_n2048_j8.hlo.txt batch=2048 terms=8 inputs=7 outputs=3 beta_cap=1e+30
//! ```

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};

/// One artifact entry.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactSpec {
    /// Artifact kind: `crawl_value`, `freshness` or `mle_step`.
    pub kind: String,
    /// Unique name.
    pub name: String,
    /// HLO text file (absolute, resolved against the manifest dir).
    pub path: PathBuf,
    /// Batch size the graph was lowered at.
    pub batch: usize,
    /// Approximation level J (crawl_value only).
    pub terms: Option<u32>,
    /// Number of inputs / outputs (sanity checks).
    pub inputs: usize,
    /// Number of outputs.
    pub outputs: usize,
}

/// Parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    /// All artifact entries.
    pub specs: Vec<ArtifactSpec>,
}

impl Manifest {
    /// Parse `manifest.txt` in `dir`.
    pub fn load(dir: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(dir.join("manifest.txt"))
            .map_err(|e| Error::Manifest(format!("read {}: {e}", dir.display())))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text; `dir` resolves relative file names.
    pub fn parse(text: &str, dir: &Path) -> Result<Self> {
        let mut specs = Vec::new();
        for (ln, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let kv: HashMap<&str, &str> = line
                .split_whitespace()
                .filter_map(|tok| tok.split_once('='))
                .collect();
            let get = |k: &str| -> Result<&str> {
                kv.get(k)
                    .copied()
                    .ok_or_else(|| Error::Manifest(format!("line {}: missing {k}", ln + 1)))
            };
            let spec = ArtifactSpec {
                kind: get("kind")?.to_string(),
                name: get("name")?.to_string(),
                path: dir.join(get("file")?),
                batch: get("batch")?
                    .parse()
                    .map_err(|e| Error::Manifest(format!("line {}: batch: {e}", ln + 1)))?,
                terms: kv.get("terms").map(|t| t.parse()).transpose().map_err(|e| {
                    Error::Manifest(format!("line {}: terms: {e}", ln + 1))
                })?,
                inputs: get("inputs")?
                    .parse()
                    .map_err(|e| Error::Manifest(format!("line {}: inputs: {e}", ln + 1)))?,
                outputs: get("outputs")?
                    .parse()
                    .map_err(|e| Error::Manifest(format!("line {}: outputs: {e}", ln + 1)))?,
            };
            specs.push(spec);
        }
        if specs.is_empty() {
            return Err(Error::Manifest("manifest is empty".into()));
        }
        Ok(Self { specs })
    }

    /// All crawl-value specs with the given approximation level, sorted
    /// by batch size ascending.
    pub fn crawl_values(&self, terms: u32) -> Vec<&ArtifactSpec> {
        let mut v: Vec<&ArtifactSpec> = self
            .specs
            .iter()
            .filter(|s| s.kind == "crawl_value" && s.terms == Some(terms))
            .collect();
        v.sort_by_key(|s| s.batch);
        v
    }

    /// The unique spec of a kind (freshness / mle_step).
    pub fn unique(&self, kind: &str) -> Result<&ArtifactSpec> {
        self.specs
            .iter()
            .find(|s| s.kind == kind)
            .ok_or_else(|| Error::Manifest(format!("no {kind} artifact")))
    }

    /// Available crawl-value approximation levels.
    pub fn term_levels(&self) -> Vec<u32> {
        let mut v: Vec<u32> = self
            .specs
            .iter()
            .filter(|s| s.kind == "crawl_value")
            .filter_map(|s| s.terms)
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
kind=crawl_value name=cv_a file=a.hlo.txt batch=2048 terms=8 inputs=7 outputs=3
kind=crawl_value name=cv_b file=b.hlo.txt batch=16384 terms=8 inputs=7 outputs=3
kind=crawl_value name=cv_c file=c.hlo.txt batch=2048 terms=2 inputs=7 outputs=3
kind=freshness name=fr file=f.hlo.txt batch=16384 inputs=4 outputs=1
kind=mle_step name=mle file=m.hlo.txt batch=4096 inputs=4 outputs=2
";

    #[test]
    fn parses_and_indexes() {
        let m = Manifest::parse(SAMPLE, Path::new("/x")).unwrap();
        assert_eq!(m.specs.len(), 5);
        let cv8 = m.crawl_values(8);
        assert_eq!(cv8.len(), 2);
        assert_eq!(cv8[0].batch, 2048);
        assert_eq!(cv8[1].batch, 16384);
        assert_eq!(m.unique("mle_step").unwrap().batch, 4096);
        assert_eq!(m.term_levels(), vec![2, 8]);
        assert_eq!(cv8[0].path, PathBuf::from("/x/a.hlo.txt"));
    }

    #[test]
    fn missing_key_is_error() {
        let bad = "kind=crawl_value name=x batch=2 inputs=7 outputs=3";
        assert!(Manifest::parse(bad, Path::new(".")).is_err());
    }

    #[test]
    fn empty_manifest_is_error() {
        assert!(Manifest::parse("# only comments\n", Path::new(".")).is_err());
    }

    #[test]
    fn unknown_kind_query_is_error() {
        let m = Manifest::parse(SAMPLE, Path::new(".")).unwrap();
        assert!(m.unique("nope").is_err());
    }
}
