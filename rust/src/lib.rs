//! # ncis-crawl
//!
//! Production-quality reproduction of *"A Scalable Crawling Algorithm
//! Utilizing Noisy Change-Indicating Signals"* (Busa-Fekete et al.,
//! WWW 2025) as a three-layer Rust + JAX + Pallas system.
//!
//! The crate is the Layer-3 coordinator: it owns the scheduling policies
//! (Algorithm 1 and all baselines), the continuous-policy optimality
//! theory (Theorem 1), the Poisson event simulator the paper evaluates
//! on, the semi-synthetic dataset substrate, and a PJRT runtime that
//! executes the AOT-compiled JAX/Pallas crawl-value graphs from
//! `artifacts/` on the hot path.
//!
//! Architecture map (see `DESIGN.md` for the full inventory):
//!
//! - [`special`] — stable evaluation of the exp Taylor residual
//!   `R^i(x) = P(i+1, x)` underlying every crawl-value formula.
//! - [`rngkit`] — deterministic RNG + distribution substrate
//!   (xoshiro256++, exponential/Poisson/beta/Pareto samplers).
//! - [`params`] — page parametrization `(Δ, μ̃, λ, ν) → (α, β, γ)`.
//! - [`policy`] — crawl-value functions `V_GREEDY`, `V_GREEDY_CIS`,
//!   `V_GREEDY_NCIS`, `V_G_NCIS-APPROX-J` and the thresholded policy.
//! - [`solver`] — optimal continuous policies via Lagrange line search.
//! - [`lds`] — the low-discrepancy discrete scheduler of Azar et al.
//! - [`sim`] — Poisson event streams, the discrete-tick simulator and
//!   accuracy/rate metrics.
//! - [`estimation`] — Appendix-E estimators for CIS precision/recall.
//! - [`dataset`] — semi-synthetic stand-in for the (non-public)
//!   Kolobov et al. dataset.
//! - [`coordinator`] — Algorithm-1 crawler drivers: exact argmax, the
//!   §5.2 lazy/tiered scheduler, sharding, streaming pipeline.
//! - [`runtime`] — PJRT engine loading `artifacts/*.hlo.txt`.
//! - [`figures`] — regeneration of every figure in the paper.

pub mod benchkit;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod dataset;
pub mod error;
pub mod estimation;
pub mod figures;
pub mod lds;
pub mod metrics;
pub mod params;
pub mod policy;
pub mod report;
pub mod rngkit;
pub mod runtime;
pub mod sim;
pub mod solver;
pub mod special;
pub mod stats;
pub mod testkit;
pub mod util;

pub use error::{Error, Result};
pub use params::{DerivedParams, PageParams};
pub use policy::PolicyKind;

mod app;
pub use app::run_cli;
