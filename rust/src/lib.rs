//! # ncis-crawl
//!
//! Production-quality reproduction of *"A Scalable Crawling Algorithm
//! Utilizing Noisy Change-Indicating Signals"* (Busa-Fekete et al.,
//! WWW 2025) as a three-layer Rust + JAX + Pallas system.
//!
//! The crate is the Layer-3 coordinator: it owns the scheduling policies
//! (Algorithm 1 and all baselines), the continuous-policy optimality
//! theory (Theorem 1), the Poisson event simulator the paper evaluates
//! on, the semi-synthetic dataset substrate, and a PJRT runtime that
//! executes the AOT-compiled JAX/Pallas crawl-value graphs from
//! `artifacts/` on the hot path.
//!
//! Scheduling is event-driven: every driver (sim engine, streaming
//! pipeline, sharded coordinator) speaks the [`sched::CrawlScheduler`]
//! trait — `on_start` / `on_cis` / `on_crawl` lifecycle hooks plus
//! `select(t)` — and schedulers own their incremental per-page state
//! ([`sched::PageTracker`]). Construction goes through one facade,
//! [`CrawlerBuilder`]: `policy(..) × strategy(Exact|Lazy|Sharded|Lds)
//! × backend(Native|Pjrt) × pages(..)`.
//!
//! Architecture map (see `DESIGN.md` at the repository root for the
//! full inventory and the API-migration notes):
//!
//! - [`special`] — stable evaluation of the exp Taylor residual
//!   `R^i(x) = P(i+1, x)` underlying every crawl-value formula.
//! - [`rngkit`] — deterministic RNG + distribution substrate
//!   (xoshiro256++, exponential/Poisson/beta/Pareto samplers).
//! - [`params`] — page parametrization `(Δ, μ̃, λ, ν) → (α, β, γ)`.
//! - [`policy`] — crawl-value functions `V_GREEDY`, `V_GREEDY_CIS`,
//!   `V_GREEDY_NCIS`, `V_G_NCIS-APPROX-J`, the [`policy::BeliefModel`]
//!   projection shared by the native and batched value paths, and the
//!   round-trippable policy names ([`PolicyKind`] /
//!   [`policy::PolicyUnderTest`]).
//! - [`sched`] — the event-driven [`sched::CrawlScheduler`] API, the
//!   [`sched::PageTracker`] state bookkeeping and the hierarchical
//!   [`sched::wheel::TimingWheel`] wake calendar.
//! - [`solver`] — optimal continuous policies via Lagrange line search.
//! - [`lds`] — the low-discrepancy discrete scheduler of Azar et al.
//! - [`sim`] — Poisson event streams, the discrete-tick simulator
//!   (streaming k-way merge + merged-sort parity oracle) and
//!   accuracy/rate metrics.
//! - [`scenario`] — the dynamic-world engine: scripted timelines of
//!   page churn, parameter drift, CIS outages and bandwidth shifts
//!   ([`Scenario`] / [`WorldEvent`]), merged into the streaming
//!   simulator with slot recycling + generation counters, plus
//!   composable stress-pattern generators, the adversarial-world
//!   scenario DSL ([`scenario::dsl`]), the reusable engine-invariant
//!   audit ([`scenario::WorldAudit`]) and the deterministic replay
//!   fuzzer ([`scenario::fuzz`]).
//! - [`fault`] — fault injection and resilience: deterministic
//!   [`fault::FaultModel`] (transient errors, timeouts, correlated
//!   host outages, dead pages), [`fault::RetryPolicy`] with
//!   deterministic backoff jitter, the fault-aware merge engine with
//!   bandwidth-conserving retry accounting, and degraded-mode metrics.
//! - [`serving`] — the request-side serving layer: heavy-tailed
//!   [`serving::RequestTraffic`] (Zipf popularity, diurnal cycles,
//!   flash crowds), the [`serving::FreshnessCache`] answering requests
//!   from the last crawled copy, and fairness-at-request metrics
//!   (staleness percentiles per CIS-quality / popularity decile).
//! - [`estimation`] — Appendix-E estimators for CIS precision/recall
//!   plus the online [`estimation::EstimatorBank`] behind
//!   [`Knowledge::Learned`] (streaming change-rate MLE, trust gating,
//!   divergence guardrails).
//! - [`dataset`] — semi-synthetic stand-in for the (non-public)
//!   Kolobov et al. dataset.
//! - [`trace`] — sim-time flight recorder and decision-trace layer:
//!   per-shard ring-buffer event log ([`trace::FlightRecorder`]) with
//!   JSONL exposition, engine-phase span timing into
//!   [`metrics::Registry`], and dump-on-violation diagnostics.
//! - [`coordinator`] — Algorithm-1 crawler drivers behind
//!   [`CrawlerBuilder`]: exact argmax, the §5.2 lazy/tiered scheduler,
//!   N-way sharding, the threaded streaming pipeline, politeness.
//! - [`runtime`] — PJRT engine loading `artifacts/*.hlo.txt`.
//! - [`figures`] — regeneration of every figure in the paper.

pub mod benchkit;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod dataset;
pub mod error;
pub mod estimation;
pub mod fault;
pub mod figures;
pub mod lds;
pub mod metrics;
pub mod params;
pub mod policy;
pub mod report;
pub mod rngkit;
pub mod runtime;
pub mod scenario;
pub mod sched;
pub mod serving;
pub mod sim;
pub mod solver;
pub mod special;
pub mod stats;
pub mod testkit;
pub mod trace;
pub mod util;

pub use coordinator::{CrawlerBuilder, Knowledge, Strategy};
pub use error::{Error, Result};
pub use estimation::{EstimationStats, EstimatorConfig};
pub use params::{DerivedParams, PageParams};
pub use policy::{PolicyKind, PolicyUnderTest};
pub use scenario::{parse_world, CompiledWorld, Scenario, WorldAudit, WorldEvent, WorldSpec};
pub use sched::{CrawlScheduler, PageTracker};
pub use trace::{FlightRecorder, TraceEvent, TraceHandle, TraceSink};

mod app;
pub use app::run_cli;
