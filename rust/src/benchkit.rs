//! Measurement harness for `benches/` (the image has no `criterion`).
//!
//! Provides warmup + repeated-sample timing with mean ± stderr, and a
//! figure-output helper that writes the regenerated paper series as CSV
//! under `target/figures/` plus an aligned text table to stdout.

use std::io::Write;
use std::time::Instant;

use crate::stats::{summarize, Summary};

/// One timing measurement.
#[derive(Debug, Clone, Copy)]
pub struct Measurement {
    /// Mean seconds per iteration.
    pub mean_s: f64,
    /// Standard error.
    pub stderr_s: f64,
    /// Iterations per sample.
    pub iters: u64,
    /// Samples taken.
    pub samples: usize,
}

impl Measurement {
    /// Throughput given items processed per iteration.
    pub fn per_second(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.mean_s
    }
}

impl std::fmt::Display for Measurement {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let (v, unit) = scale(self.mean_s);
        let (e, _) = (self.stderr_s / self.mean_s * v, "");
        write!(f, "{v:9.3} {unit} ± {e:.3}")
    }
}

fn scale(s: f64) -> (f64, &'static str) {
    if s < 1e-6 {
        (s * 1e9, "ns")
    } else if s < 1e-3 {
        (s * 1e6, "µs")
    } else if s < 1.0 {
        (s * 1e3, "ms")
    } else {
        (s, "s ")
    }
}

/// Time `f`, auto-calibrating the iteration count so each sample runs at
/// least `min_sample_s`.
pub fn measure<F: FnMut()>(mut f: F, samples: usize, min_sample_s: f64) -> Measurement {
    // calibrate
    let mut iters = 1u64;
    loop {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        let dt = t0.elapsed().as_secs_f64();
        if dt >= min_sample_s || iters >= 1 << 24 {
            break;
        }
        let grow = (min_sample_s / dt.max(1e-9) * 1.3).ceil() as u64;
        iters = (iters * grow.max(2)).min(1 << 24);
    }
    let mut per_iter = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        per_iter.push(t0.elapsed().as_secs_f64() / iters as f64);
    }
    let Summary { mean, stderr, .. } = summarize(&per_iter);
    Measurement { mean_s: mean, stderr_s: stderr, iters, samples }
}

/// Print a labelled measurement line.
pub fn report(name: &str, m: &Measurement) {
    println!("{name:<44} {m}  ({} iters x {} samples)", m.iters, m.samples);
}

/// Writer for a regenerated figure: CSV under `target/figures/` plus an
/// aligned table echoed to stdout.
pub struct FigureOutput {
    name: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl FigureOutput {
    /// New figure with CSV column names.
    pub fn new(name: &str, header: &[&str]) -> Self {
        Self {
            name: name.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row.
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Append a row of displayable values.
    pub fn rowf(&mut self, cells: &[f64]) {
        self.row(&cells.iter().map(|c| format!("{c:.6}")).collect::<Vec<_>>());
    }

    /// Write CSV and print the table. Returns the CSV path.
    pub fn finish(&self) -> std::io::Result<std::path::PathBuf> {
        let dir = std::path::Path::new("target/figures");
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{}.csv", self.name));
        let mut f = std::fs::File::create(&path)?;
        writeln!(f, "{}", self.header.join(","))?;
        for r in &self.rows {
            writeln!(f, "{}", r.join(","))?;
        }
        // aligned echo
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (w, c) in widths.iter_mut().zip(r) {
                *w = (*w).max(c.len());
            }
        }
        println!("\n== {} ==", self.name);
        let hdr: Vec<String> =
            self.header.iter().zip(&widths).map(|(h, w)| format!("{h:>w$}")).collect();
        println!("{}", hdr.join("  "));
        for r in &self.rows {
            let line: Vec<String> =
                r.iter().zip(&widths).map(|(c, w)| format!("{c:>w$}")).collect();
            println!("{}", line.join("  "));
        }
        println!("-> {}", path.display());
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_something_cheap() {
        let mut x = 0u64;
        let m = measure(
            || {
                x = x.wrapping_add(1);
                std::hint::black_box(x);
            },
            3,
            0.001,
        );
        assert!(m.mean_s > 0.0);
        assert!(m.iters >= 1);
        assert!(m.per_second(1.0) > 1000.0);
    }

    #[test]
    fn figure_output_roundtrip() {
        let mut fig = FigureOutput::new("test_fig", &["m", "acc"]);
        fig.rowf(&[100.0, 0.5]);
        fig.rowf(&[200.0, 0.4]);
        let path = fig.finish().unwrap();
        let text = std::fs::read_to_string(path).unwrap();
        assert!(text.starts_with("m,acc\n"));
        assert_eq!(text.lines().count(), 3);
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut fig = FigureOutput::new("bad", &["a", "b"]);
        fig.rowf(&[1.0]);
    }
}
