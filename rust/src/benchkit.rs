//! Measurement harness for `benches/` (the image has no `criterion`).
//!
//! Provides warmup + repeated-sample timing with mean ± stderr, a
//! figure-output helper that writes the regenerated paper series as CSV
//! under `target/figures/` plus an aligned text table to stdout, and
//! [`BenchJson`], a machine-readable results writer (`BENCH_<name>.json`)
//! so successive PRs have a perf trajectory to compare against.

use std::io::Write;
use std::time::Instant;

use crate::stats::{summarize, Summary};

pub mod mem {
    //! Peak-memory and allocation instrumentation for the memory
    //! benches (`gen_{materialized,streamed}` lanes).
    //!
    //! [`CountingAlloc`] is a [`System`]-wrapping global allocator
    //! that tracks live bytes, a resettable live-bytes peak, and
    //! allocation counters. It is *not* installed by the library —
    //! a bench binary opts in with
    //! `#[global_allocator] static A: CountingAlloc = CountingAlloc;`
    //! (see `benches/perf.rs`); without that, the counters simply stay
    //! at zero. [`peak_rss_bytes`] additionally reads the process
    //! high-water RSS (`VmHWM`, Linux) — process-lifetime, not
    //! resettable, reported alongside the per-lane counters.

    use std::alloc::{GlobalAlloc, Layout, System};
    use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

    static CURRENT: AtomicUsize = AtomicUsize::new(0);
    static PEAK: AtomicUsize = AtomicUsize::new(0);
    static TOTAL_ALLOCS: AtomicU64 = AtomicU64::new(0);
    static TOTAL_BYTES: AtomicU64 = AtomicU64::new(0);

    #[inline]
    fn record_alloc(size: usize) {
        TOTAL_ALLOCS.fetch_add(1, Ordering::Relaxed);
        TOTAL_BYTES.fetch_add(size as u64, Ordering::Relaxed);
        let live = CURRENT.fetch_add(size, Ordering::Relaxed) + size;
        PEAK.fetch_max(live, Ordering::Relaxed);
    }

    #[inline]
    fn record_dealloc(size: usize) {
        // saturating: a foreign free racing a reset can never wrap the
        // live counter negative
        let mut cur = CURRENT.load(Ordering::Relaxed);
        loop {
            let next = cur.saturating_sub(size);
            match CURRENT.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Counting wrapper around the system allocator.
    pub struct CountingAlloc;

    // SAFETY: defers every allocation to `System` verbatim; the
    // counters are side effects only.
    unsafe impl GlobalAlloc for CountingAlloc {
        unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
            let p = System.alloc(layout);
            if !p.is_null() {
                record_alloc(layout.size());
            }
            p
        }

        unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
            let p = System.alloc_zeroed(layout);
            if !p.is_null() {
                record_alloc(layout.size());
            }
            p
        }

        unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
            System.dealloc(ptr, layout);
            record_dealloc(layout.size());
        }

        unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
            let p = System.realloc(ptr, layout, new_size);
            if !p.is_null() {
                record_dealloc(layout.size());
                record_alloc(new_size);
            }
            p
        }
    }

    /// Bytes currently live (allocated − freed since process start).
    pub fn live_bytes() -> usize {
        CURRENT.load(Ordering::Relaxed)
    }

    /// High-water of [`live_bytes`] since the last [`reset_peak`].
    pub fn peak_bytes() -> usize {
        PEAK.load(Ordering::Relaxed)
    }

    /// Reset the live-bytes peak to the current live level.
    pub fn reset_peak() {
        PEAK.store(CURRENT.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// Total allocation calls since process start (monotone).
    pub fn alloc_count() -> u64 {
        TOTAL_ALLOCS.load(Ordering::Relaxed)
    }

    /// Total bytes ever allocated since process start (monotone).
    pub fn alloc_bytes_total() -> u64 {
        TOTAL_BYTES.load(Ordering::Relaxed)
    }

    /// Measurement span: captures the live level (and resets the peak)
    /// at construction so a lane can report *its own* peak allocation
    /// footprint and allocation count.
    #[derive(Debug, Clone, Copy)]
    pub struct MemSpan {
        start_live: usize,
        start_allocs: u64,
    }

    impl MemSpan {
        /// Begin a span (resets the peak to the current live level).
        pub fn begin() -> Self {
            reset_peak();
            Self { start_live: live_bytes(), start_allocs: alloc_count() }
        }

        /// Peak bytes the span added above its starting live level.
        pub fn peak_delta(&self) -> usize {
            peak_bytes().saturating_sub(self.start_live)
        }

        /// Allocation calls since the span began.
        pub fn allocs(&self) -> u64 {
            alloc_count() - self.start_allocs
        }
    }

    /// Process peak RSS (`VmHWM` from `/proc/self/status`), if the
    /// platform exposes it. Process-lifetime — pair with [`MemSpan`]
    /// for per-lane numbers.
    pub fn peak_rss_bytes() -> Option<u64> {
        let status = std::fs::read_to_string("/proc/self/status").ok()?;
        for line in status.lines() {
            if let Some(rest) = line.strip_prefix("VmHWM:") {
                let kb: u64 = rest.trim().trim_end_matches("kB").trim().parse().ok()?;
                return Some(kb * 1024);
            }
        }
        None
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn counter_math_tracks_live_and_peak() {
            // drive the recorders directly (the test binary does not
            // install the allocator, so these statics are ours alone)
            let base = live_bytes();
            reset_peak();
            record_alloc(1000);
            record_alloc(500);
            assert_eq!(live_bytes(), base + 1500);
            assert!(peak_bytes() >= base + 1500);
            record_dealloc(500);
            assert_eq!(live_bytes(), base + 1000);
            assert!(peak_bytes() >= base + 1500, "peak must not shrink on free");
            let span = MemSpan::begin();
            assert_eq!(span.peak_delta(), 0);
            record_alloc(2000);
            record_dealloc(2000);
            assert_eq!(span.peak_delta(), 2000, "span peak sees the transient");
            assert_eq!(span.allocs(), 1);
            record_dealloc(1000); // restore balance for other tests
        }

        #[cfg(target_os = "linux")]
        #[test]
        fn peak_rss_reads_proc_status() {
            let rss = peak_rss_bytes().expect("VmHWM should exist on Linux");
            assert!(rss > 0);
        }
    }
}

/// One timing measurement.
#[derive(Debug, Clone, Copy)]
pub struct Measurement {
    /// Mean seconds per iteration.
    pub mean_s: f64,
    /// Standard error.
    pub stderr_s: f64,
    /// Iterations per sample.
    pub iters: u64,
    /// Samples taken.
    pub samples: usize,
}

impl Measurement {
    /// Throughput given items processed per iteration.
    pub fn per_second(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.mean_s
    }
}

impl std::fmt::Display for Measurement {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let (v, unit) = scale(self.mean_s);
        let (e, _) = (self.stderr_s / self.mean_s * v, "");
        write!(f, "{v:9.3} {unit} ± {e:.3}")
    }
}

fn scale(s: f64) -> (f64, &'static str) {
    if s < 1e-6 {
        (s * 1e9, "ns")
    } else if s < 1e-3 {
        (s * 1e6, "µs")
    } else if s < 1.0 {
        (s * 1e3, "ms")
    } else {
        (s, "s ")
    }
}

/// Time `f`, auto-calibrating the iteration count so each sample runs at
/// least `min_sample_s`.
pub fn measure<F: FnMut()>(mut f: F, samples: usize, min_sample_s: f64) -> Measurement {
    // calibrate
    let mut iters = 1u64;
    loop {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        let dt = t0.elapsed().as_secs_f64();
        if dt >= min_sample_s || iters >= 1 << 24 {
            break;
        }
        let grow = (min_sample_s / dt.max(1e-9) * 1.3).ceil() as u64;
        iters = (iters * grow.max(2)).min(1 << 24);
    }
    let mut per_iter = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        per_iter.push(t0.elapsed().as_secs_f64() / iters as f64);
    }
    let Summary { mean, stderr, .. } = summarize(&per_iter);
    Measurement { mean_s: mean, stderr_s: stderr, iters, samples }
}

/// Print a labelled measurement line.
pub fn report(name: &str, m: &Measurement) {
    println!("{name:<44} {m}  ({} iters x {} samples)", m.iters, m.samples);
}

/// Writer for a regenerated figure: CSV under `target/figures/` plus an
/// aligned table echoed to stdout.
pub struct FigureOutput {
    name: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl FigureOutput {
    /// New figure with CSV column names.
    pub fn new(name: &str, header: &[&str]) -> Self {
        Self {
            name: name.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row.
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Append a row of displayable values.
    pub fn rowf(&mut self, cells: &[f64]) {
        self.row(&cells.iter().map(|c| format!("{c:.6}")).collect::<Vec<_>>());
    }

    /// Write CSV and print the table. Returns the CSV path.
    pub fn finish(&self) -> std::io::Result<std::path::PathBuf> {
        let dir = std::path::Path::new("target/figures");
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{}.csv", self.name));
        let mut f = std::fs::File::create(&path)?;
        writeln!(f, "{}", self.header.join(","))?;
        for r in &self.rows {
            writeln!(f, "{}", r.join(","))?;
        }
        // aligned echo
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (w, c) in widths.iter_mut().zip(r) {
                *w = (*w).max(c.len());
            }
        }
        println!("\n== {} ==", self.name);
        let hdr: Vec<String> =
            self.header.iter().zip(&widths).map(|(h, w)| format!("{h:>w$}")).collect();
        println!("{}", hdr.join("  "));
        for r in &self.rows {
            let line: Vec<String> =
                r.iter().zip(&widths).map(|(c, w)| format!("{c:>w$}")).collect();
            println!("{}", line.join("  "));
        }
        println!("-> {}", path.display());
        Ok(path)
    }
}

/// Machine-readable bench results: named lanes of numeric fields,
/// serialized to `BENCH_<name>.json` (hand-rolled JSON — no `serde` in
/// the image). Non-finite values serialize as `null`.
#[derive(Debug, Clone)]
pub struct BenchJson {
    name: String,
    lanes: Vec<(String, Vec<(String, f64)>)>,
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn json_num(v: f64) -> String {
    if v.is_finite() {
        // Debug formatting gives the shortest round-trip representation
        // (valid JSON: `0.25`, `1e300`, ...)
        format!("{v:?}")
    } else {
        "null".into()
    }
}

impl BenchJson {
    /// New result set; `name` becomes the `BENCH_<name>.json` file stem.
    pub fn new(name: &str) -> Self {
        Self { name: name.to_string(), lanes: Vec::new() }
    }

    /// Append one lane of `(field, value)` measurements. Re-using a lane
    /// name appends a second object under a suffixed key.
    pub fn lane(&mut self, lane: &str, fields: &[(&str, f64)]) {
        let mut name = lane.to_string();
        let n = self.lanes.iter().filter(|(l, _)| l == lane || l.starts_with(&format!("{lane}#"))).count();
        if n > 0 {
            name = format!("{lane}#{n}");
        }
        self.lanes.push((
            name,
            fields.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
        ));
    }

    /// Does a lane of exactly this name exist? (Used by the bench
    /// binaries' declared-lane self-check: CI fails if an acceptance
    /// lane was skipped.)
    pub fn has_lane(&self, lane: &str) -> bool {
        self.lanes.iter().any(|(l, _)| l == lane)
    }

    /// Serialize to a JSON string.
    pub fn render(&self) -> String {
        let unix = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs())
            .unwrap_or(0);
        let mut out = String::from("{\n");
        out.push_str(&format!("  \"bench\": \"{}\",\n", json_escape(&self.name)));
        out.push_str(&format!("  \"generated_unix\": {unix},\n"));
        out.push_str("  \"lanes\": {\n");
        for (li, (lane, fields)) in self.lanes.iter().enumerate() {
            out.push_str(&format!("    \"{}\": {{", json_escape(lane)));
            for (fi, (k, v)) in fields.iter().enumerate() {
                if fi > 0 {
                    out.push_str(", ");
                }
                out.push_str(&format!("\"{}\": {}", json_escape(k), json_num(*v)));
            }
            out.push_str(if li + 1 < self.lanes.len() { "},\n" } else { "}\n" });
        }
        out.push_str("  }\n}\n");
        out
    }

    /// Write `BENCH_<name>.json` into `dir`; returns the path.
    pub fn finish_in(&self, dir: &std::path::Path) -> std::io::Result<std::path::PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("BENCH_{}.json", self.name));
        std::fs::write(&path, self.render())?;
        Ok(path)
    }

    /// Write `BENCH_<name>.json` into the current directory (under
    /// `cargo bench` that is the *package* dir, not the workspace root —
    /// pass `finish_in(CARGO_MANIFEST_DIR/..)` for a stable location);
    /// returns the path.
    pub fn finish(&self) -> std::io::Result<std::path::PathBuf> {
        self.finish_in(std::path::Path::new("."))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_something_cheap() {
        let mut x = 0u64;
        let m = measure(
            || {
                x = x.wrapping_add(1);
                std::hint::black_box(x);
            },
            3,
            0.001,
        );
        assert!(m.mean_s > 0.0);
        assert!(m.iters >= 1);
        assert!(m.per_second(1.0) > 1000.0);
    }

    #[test]
    fn figure_output_roundtrip() {
        let mut fig = FigureOutput::new("test_fig", &["m", "acc"]);
        fig.rowf(&[100.0, 0.5]);
        fig.rowf(&[200.0, 0.4]);
        let path = fig.finish().unwrap();
        let text = std::fs::read_to_string(path).unwrap();
        assert!(text.starts_with("m,acc\n"));
        assert_eq!(text.lines().count(), 3);
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut fig = FigureOutput::new("bad", &["a", "b"]);
        fig.rowf(&[1.0]);
    }

    #[test]
    fn has_lane_matches_exact_names() {
        let mut j = BenchJson::new("t");
        j.lane("gen_streamed_m1000", &[("x", 1.0)]);
        assert!(j.has_lane("gen_streamed_m1000"));
        assert!(!j.has_lane("gen_streamed_m100"));
        assert!(!j.has_lane("gen_streamed"));
    }

    #[test]
    fn bench_json_roundtrip() {
        let mut j = BenchJson::new("unit_test");
        j.lane("alpha", &[("mean_s", 0.25), ("per_s", 4.0)]);
        j.lane("beta", &[("speedup_x", 3.5), ("bad", f64::NAN)]);
        j.lane("beta", &[("speedup_x", 1.0)]); // duplicate -> suffixed
        let text = j.render();
        assert!(text.contains("\"bench\": \"unit_test\""));
        assert!(text.contains("\"alpha\": {\"mean_s\": 0.25, \"per_s\": 4.0}"));
        assert!(text.contains("\"bad\": null"));
        assert!(text.contains("\"beta#1\""));
        let dir = std::env::temp_dir().join("ncis_benchjson_test");
        let path = j.finish_in(&dir).unwrap();
        let disk = std::fs::read_to_string(&path).unwrap();
        assert!(disk.starts_with('{') && disk.trim_end().ends_with('}'));
        assert_eq!(path.file_name().unwrap(), "BENCH_unit_test.json");
    }

    #[test]
    fn json_escaping_and_numbers() {
        assert_eq!(json_escape("a\"b\\c"), "a\\\"b\\\\c");
        assert_eq!(json_num(1e300), "1e300");
        assert_eq!(json_num(f64::INFINITY), "null");
    }
}
