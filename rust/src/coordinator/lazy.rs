//! The §5.2 lazy/tiered scheduler.
//!
//! Exact argmax re-evaluates every page at every tick — `O(m)` work that
//! the paper explicitly calls out as unnecessary: *"only the comparison
//! between the pages with the top crawl values matters … we can estimate
//! the crawl value threshold where a page is likely to be selected …
//! and estimate the next time when the crawl value of a page needs to be
//! recomputed."*
//!
//! Design (exploiting Lemma 2: crawl values are monotone nondecreasing
//! between crawls, and bounded by `μ̃/Δ`):
//!
//! - **Cold pages** (value far below the running threshold estimate
//!   `Λ̂`) live in a *wake calendar*: the earliest time a page could
//!   reach `margin·Λ̂` is found by inverting the monotone `V`
//!   (`policy::value::inverse_value`); the page is not touched again
//!   until then. CIS arrivals jump the value, so they re-queue an
//!   immediate wake. The calendar is a hierarchical
//!   [`TimingWheel`](crate::sched::wheel::TimingWheel) — O(1) amortized
//!   schedule/advance with version-stamped lazy deletion — instead of a
//!   `BinaryHeap` with O(log m) churn per operation.
//! - **Hot pages** live in a max-heap keyed by their *last computed*
//!   value (a lower bound — values only grow). Selection pops the heap
//!   top, recomputes its exact value, and accepts it once it dominates
//!   the next entry's stored bound; otherwise the refreshed entry is
//!   pushed back and the next is tried (bounded number of refreshes per
//!   tick — the classic lazy re-evaluation of index policies).
//!
//! Stale heap entries are handled by versioning (lazy deletion). The
//! scheduler is *approximate* only through bound staleness; the
//! `lazy_parity` test and the `perf` bench quantify the accuracy parity
//! and the per-tick evaluation savings.
//!
//! The scheduler is event-driven ([`CrawlScheduler`]): per-page state
//! lives in its own [`PageTracker`], and single-page evaluations go
//! through the configured [`ValueBackend`] — native f64 by default, or
//! the batched PJRT engine (one-page batches; the batch path exists for
//! API parity and device-resident deployments, not single-eval speed).

use std::collections::BinaryHeap;

use crate::coordinator::crawler::ValueBackend;
use crate::params::PageParams;
use crate::policy::{value, BeliefModel, PolicyKind};
use crate::runtime::ValueBatch;
use crate::sched::wheel::{TimingWheel, WheelEntry};
use crate::sched::{CrawlScheduler, PageTracker};
use crate::util::OrdF64;

/// Max refreshes per tick before we accept the best value seen so far.
const MAX_REFRESH: usize = 24;

/// Default hot/cold margin (see [`LazyGreedyScheduler::with_margin`]).
pub const DEFAULT_MARGIN: f64 = 0.7;

/// Level-0 bucket width of the wake calendar. Sized so a sim tick at
/// the bench bandwidths advances O(1) buckets; correctness does not
/// depend on the choice (due-ness is checked against exact times).
const WHEEL_TICK: f64 = 1.0 / 64.0;

/// Lazy Algorithm-1 scheduler with a pluggable value backend.
pub struct LazyGreedyScheduler {
    /// Shared belief projection (native values + wake-time inversion).
    model: BeliefModel,
    /// Where single-page value evaluations run.
    backend: ValueBackend,
    /// Incremental per-page crawl state (event-driven).
    tracker: PageTracker,
    /// Scratch for PJRT one-page evaluations.
    batch: ValueBatch,
    /// Wake calendar (timing wheel) of (wake time, version, page) —
    /// cold pages; stale entries are version-skipped on drain.
    wakes: TimingWheel,
    /// Reusable drain scratch for `process_wakes`.
    due: Vec<WheelEntry>,
    /// Reusable veto-deferral scratch for the force-wake fallback
    /// (was a per-`select` allocation).
    deferred: Vec<WheelEntry>,
    /// Reusable hot-page gather + batched-evaluation scratch for
    /// `rekey_hot` (was a per-call `Vec` collect).
    rekey_pages: Vec<u32>,
    rekey_tau: Vec<f64>,
    rekey_ncis: Vec<u32>,
    rekey_vals: Vec<f64>,
    /// max-heap of (stored value, version, page) — hot pages
    hot: BinaryHeap<(OrdF64, u32, usize)>,
    /// entry version per page (stale heap entries are skipped)
    version: Vec<u32>,
    /// current wake time per cold page (for O(1) CIS wake shifts)
    wake_at: Vec<f64>,
    /// whether the page currently belongs to the hot heap
    is_hot: Vec<bool>,
    /// whether the slot holds a live page (dynamic worlds retire
    /// slots; a retired slot must ignore stray events — a late CIS
    /// routed by a driver without liveness tracking must not resurrect
    /// it via the cold-path wake reschedule)
    live: Vec<bool>,
    /// tick time of the page's last politeness veto: the force-wake
    /// fallback skips pages vetoed at the CURRENT tick so a retry
    /// progresses to a different candidate instead of re-popping them
    veto_tick: Vec<f64>,
    /// running threshold estimate Λ̂ (EMA of selected values)
    lambda: f64,
    /// hot/cold margin in (0, 1]
    margin: f64,
    /// Pristine construction-time population, snapshotted lazily at
    /// the FIRST dynamic-world hook (static runs never pay the copy)
    /// so `on_start` can rebuild after a dynamic run mutated the model.
    initial_pages: Vec<PageParams>,
    /// Any dynamic-world hook fired since construction/reset.
    world_mutated: bool,
    /// diagnostics: value evaluations performed
    pub evals: u64,
    /// diagnostics: evaluations from wake processing
    pub wake_evals: u64,
    /// diagnostics: evaluations from CIS notifications
    pub cis_evals: u64,
    /// diagnostics: evaluations from the hot-heap refresh loop
    pub refresh_evals: u64,
    /// diagnostics: ticks served
    pub ticks: u64,
    /// hot-heap keys are re-computed in bulk every this many ticks —
    /// stale lower-bound keys otherwise starve pages whose value grew
    /// without an external (CIS) refresh trigger
    rekey_period: u64,
    /// diagnostics: demote calls
    pub demotes: u64,
    /// diagnostics: immediate wakes (wake_time <= t at demote)
    pub immediate_wakes: u64,
}

impl LazyGreedyScheduler {
    /// Build with the default margin and the native backend.
    pub fn new(policy: PolicyKind, pages: &[PageParams]) -> Self {
        Self::with_backend(policy, pages, DEFAULT_MARGIN, ValueBackend::Native)
    }

    /// Build with an explicit hot/cold margin in (0, 1] (native backend).
    pub fn with_margin(policy: PolicyKind, pages: &[PageParams], margin: f64) -> Self {
        Self::with_backend(policy, pages, margin, ValueBackend::Native)
    }

    /// Build with an explicit margin and value backend.
    pub fn with_backend(
        policy: PolicyKind,
        pages: &[PageParams],
        margin: f64,
        backend: ValueBackend,
    ) -> Self {
        assert!(margin > 0.0 && margin <= 1.0);
        let model = BeliefModel::new(policy, pages);
        let m = model.len();
        let mut wakes = TimingWheel::new(WHEEL_TICK);
        for i in 0..m {
            wakes.schedule(0.0, 0, i as u32);
        }
        Self {
            model,
            backend,
            tracker: PageTracker::new(m),
            batch: ValueBatch::with_capacity(1),
            wakes,
            due: Vec::new(),
            deferred: Vec::new(),
            rekey_pages: Vec::new(),
            rekey_tau: Vec::new(),
            rekey_ncis: Vec::new(),
            rekey_vals: Vec::new(),
            hot: BinaryHeap::with_capacity(m),
            version: vec![0; m],
            wake_at: vec![0.0; m],
            is_hot: vec![false; m],
            live: vec![true; m],
            veto_tick: vec![f64::NEG_INFINITY; m],
            lambda: 0.0,
            margin,
            initial_pages: Vec::new(),
            world_mutated: false,
            rekey_period: 32,
            evals: 0,
            demotes: 0,
            immediate_wakes: 0,
            wake_evals: 0,
            cis_evals: 0,
            refresh_evals: 0,
            ticks: 0,
        }
    }

    /// The policy whose value function drives the threshold logic.
    pub fn policy(&self) -> PolicyKind {
        self.model.policy()
    }

    /// First dynamic-world hook of a run: snapshot the still-pristine
    /// population before mutating anything, so `on_start` can rebuild.
    fn note_world_mutation(&mut self) {
        if !self.world_mutated {
            self.initial_pages = self.model.raw_pages().to_vec();
            self.world_mutated = true;
        }
    }

    #[inline]
    fn value(&mut self, i: usize, t: f64) -> f64 {
        self.evals += 1;
        let tau = self.tracker.tau_elap(i, t);
        let n = self.tracker.n_cis(i);
        let v = match &self.backend {
            ValueBackend::Native => self.model.value(i, tau, n),
            ValueBackend::Pjrt { engine, terms } => {
                self.batch.clear();
                let iota = self.model.effective_time(i, tau, n);
                self.batch.push(iota, &self.model.belief(i));
                let values = engine
                    .crawl_values(*terms, &self.batch)
                    .unwrap_or_else(|e| panic!("pjrt crawl value execution failed: {e}"));
                values[0] as f64
            }
        };
        debug_assert!(!v.is_nan(), "NaN crawl value for page {i}");
        v
    }

    #[inline]
    fn threshold(&self) -> f64 {
        self.margin * self.lambda
    }

    /// Earliest time page `i` could reach `target` (monotone inverse in
    /// effective time; CIS jumps handled by `on_cis` re-queues).
    fn wake_time(&self, i: usize, t: f64, target: f64) -> f64 {
        // invert the value function the policy actually uses: the BELIEF
        // projection (V_GREEDY for GREEDY, V_CIS for GREEDY-CIS, ...)
        let d = self.model.belief(i);
        let iota_now =
            self.model.effective_time(i, self.tracker.tau_elap(i, t), self.tracker.n_cis(i));
        match value::inverse_value(target, &d, self.model.terms()) {
            // target unreachable (sup V < target): nap until the value
            // has saturated anyway, then re-check the (moving) threshold
            None => t + 8.0 / d.delta,
            Some(iota_target) if iota_target <= iota_now => t,
            Some(iota_target) => t + (iota_target - iota_now),
        }
    }

    /// Move a page into the hot heap with a freshly computed value.
    fn promote(&mut self, i: usize, v: f64) {
        self.version[i] = self.version[i].wrapping_add(1);
        self.is_hot[i] = true;
        self.hot.push((OrdF64(v), self.version[i], i));
    }

    /// Put a page to sleep until it could plausibly matter.
    ///
    /// The wake target is the FULL threshold estimate Λ̂ (not the
    /// hysteresis margin `margin·Λ̂` used for promotion): a page waking
    /// at V ≈ Λ̂ clears the promotion bar comfortably, so each
    /// sleep/wake cycle costs exactly one evaluation instead of
    /// oscillating with the EMA drift of Λ̂.
    fn demote(&mut self, i: usize, t: f64) {
        self.version[i] = self.version[i].wrapping_add(1);
        self.is_hot[i] = false;
        let target = self.lambda.max(1e-12);
        let wt = self.wake_time(i, t, target);
        self.demotes += 1;
        if wt <= t + 1e-6 {
            self.immediate_wakes += 1;
        }
        let wake = wt.max(t + 1e-9);
        self.wake_at[i] = wake;
        self.wakes.schedule(wake, self.version[i], i as u32);
    }

    /// Promote due pages from the wake calendar. Entries scheduled
    /// during processing (demotes) land strictly after `t`, so a single
    /// drain sees every due page; processing is order-independent
    /// (promote/demote touch only the entry's own page and `Λ̂` is not
    /// updated here), so the wheel's bucket yield order is fine.
    fn process_wakes(&mut self, t: f64) {
        self.due.clear();
        let mut due = std::mem::take(&mut self.due);
        self.wakes.drain_due_into(t, &mut due);
        for e in &due {
            let i = e.page as usize;
            if e.version != self.version[i] || self.is_hot[i] {
                continue; // stale entry (lazy deletion)
            }
            let v = self.value(i, t);
            self.wake_evals += 1;
            if v >= self.threshold() || self.lambda == 0.0 {
                self.promote(i, v);
            } else {
                self.demote(i, t);
            }
        }
        due.clear();
        self.due = due; // hand the scratch back for reuse
    }

    /// Recompute every hot page's heap key (bulk re-keying): stored keys
    /// are lower bounds that only a CIS event would otherwise refresh,
    /// so policies that ignore CIS (or noiseless environments) would
    /// starve growing pages without this. The native backend re-keys
    /// through the batched columnar kernel over reusable scratch (one
    /// gather + one `values_into` for the whole hot set, no per-call
    /// allocation after warm-up).
    fn rekey_hot(&mut self, t: f64) {
        self.rekey_pages.clear();
        for i in 0..self.is_hot.len() {
            if self.is_hot[i] {
                self.rekey_pages.push(i as u32);
            }
        }
        if self.rekey_pages.is_empty() {
            return;
        }
        self.hot.clear();
        if matches!(self.backend, ValueBackend::Native) {
            let n = self.rekey_pages.len();
            self.rekey_tau.clear();
            self.rekey_ncis.clear();
            let tracker = &self.tracker;
            for &ip in &self.rekey_pages {
                let i = ip as usize;
                self.rekey_tau.push(tracker.tau_elap(i, t));
                self.rekey_ncis.push(tracker.n_cis(i));
            }
            self.rekey_vals.clear();
            self.rekey_vals.resize(n, 0.0);
            self.model.values_into(
                &self.rekey_pages,
                &self.rekey_tau,
                &self.rekey_ncis,
                &mut self.rekey_vals,
            );
            self.evals += n as u64;
            for (&ip, &v) in self.rekey_pages.iter().zip(&self.rekey_vals) {
                let i = ip as usize;
                self.version[i] = self.version[i].wrapping_add(1);
                self.hot.push((OrdF64(v), self.version[i], i));
            }
        } else {
            // PJRT: one-page device evaluations (self.value needs &mut
            // self, so the gather list is walked by index)
            #[allow(clippy::needless_range_loop)]
            for k in 0..self.rekey_pages.len() {
                let i = self.rekey_pages[k] as usize;
                let v = self.value(i, t);
                self.version[i] = self.version[i].wrapping_add(1);
                self.hot.push((OrdF64(v), self.version[i], i));
            }
        }
    }
}

impl CrawlScheduler for LazyGreedyScheduler {
    fn on_start(&mut self, m: usize) {
        if self.world_mutated {
            // a dynamic run grew/retired/drifted the model: rebuild
            // wholesale from the pristine construction-time population
            // (reuse == fresh; the wheel, tracker slots and scratch all
            // re-dimension through the constructor)
            let policy = self.model.policy();
            let backend = self.backend.clone();
            let margin = self.margin;
            let pages = std::mem::take(&mut self.initial_pages);
            *self = Self::with_backend(policy, &pages, margin, backend);
        }
        debug_assert_eq!(m, self.model.len(), "page count changed between runs");
        let m = self.model.len();
        self.tracker.reset(m);
        self.wakes.reset();
        for i in 0..m {
            self.wakes.schedule(0.0, 0, i as u32);
        }
        self.due.clear();
        self.deferred.clear();
        self.hot.clear();
        self.version.iter_mut().for_each(|v| *v = 0);
        self.wake_at.iter_mut().for_each(|w| *w = 0.0);
        self.is_hot.iter_mut().for_each(|h| *h = false);
        self.live.iter_mut().for_each(|l| *l = true);
        self.veto_tick.iter_mut().for_each(|v| *v = f64::NEG_INFINITY);
        self.lambda = 0.0;
        self.evals = 0;
        self.wake_evals = 0;
        self.cis_evals = 0;
        self.refresh_evals = 0;
        self.ticks = 0;
        self.demotes = 0;
        self.immediate_wakes = 0;
    }

    fn select(&mut self, t: f64) -> Option<usize> {
        self.ticks += 1;
        if self.ticks % self.rekey_period == 0 {
            self.rekey_hot(t);
        }
        self.process_wakes(t);
        // lazy re-evaluation over the hot heap
        let mut best: Option<(f64, usize)> = None;
        let mut refreshes = 0usize;
        loop {
            let Some(&(OrdF64(stored), ver, i)) = self.hot.peek() else { break };
            if ver != self.version[i] || !self.is_hot[i] {
                self.hot.pop();
                continue;
            }
            if let Some((bv, _)) = best {
                // stored values are lower bounds of CURRENT values, but
                // they upper-bound what we last *measured*; once our best
                // freshly-measured value dominates the next stored bound
                // grown by nothing (values only grow — so this is a
                // heuristic cutoff), accept.
                if bv >= stored || refreshes >= MAX_REFRESH {
                    break;
                }
            }
            self.hot.pop();
            let v = self.value(i, t);
            self.refresh_evals += 1;
            refreshes += 1;
            if v < self.threshold() {
                // fell below the (risen) threshold: back to the calendar
                self.demote(i, t);
                continue;
            }
            // re-insert with the refreshed value
            self.version[i] = self.version[i].wrapping_add(1);
            self.hot.push((OrdF64(v), self.version[i], i));
            match best {
                Some((bv, _)) if bv >= v => {}
                _ => best = Some((v, i)),
            }
        }
        // fallback: nothing hot — force-wake the earliest calendar entries
        if best.is_none() {
            // entries vetoed at THIS tick are kept queued but skipped,
            // so a politeness retry reaches a different candidate (and
            // returns None once only just-vetoed pages remain); the
            // deferral buffer is reusable struct scratch, not a
            // per-select allocation
            self.deferred.clear();
            while let Some(entry) = self.wakes.pop_earliest() {
                let i = entry.page as usize;
                if entry.version != self.version[i] || self.is_hot[i] {
                    continue;
                }
                if self.veto_tick[i] == t {
                    self.deferred.push(entry);
                    continue;
                }
                let v = self.value(i, t);
                best = Some((v, i));
                break;
            }
            let (deferred, wakes) = (&self.deferred, &mut self.wakes);
            for e in deferred {
                wakes.schedule(e.time, e.version, e.page);
            }
            self.deferred.clear();
        }
        let (bv, bi) = best?;
        // threshold update; the driver fires on_crawl next, which resets
        // the page and schedules its wake from the zero state
        const A: f64 = 0.05;
        self.lambda = if self.lambda == 0.0 { bv } else { (1.0 - A) * self.lambda + A * bv };
        Some(bi)
    }

    fn on_crawl(&mut self, page: usize, t: f64) {
        self.tracker.on_crawl(page, t);
        // the page restarts from the zero state: leave the hot heap and
        // sleep until its value could reach the threshold again
        self.version[page] = self.version[page].wrapping_add(1);
        self.is_hot[page] = false;
        let d = self.model.belief(page);
        let target = self.lambda.max(1e-12);
        let iota_target =
            value::inverse_value(target, &d, self.model.terms()).unwrap_or(8.0 / d.delta);
        let wake = t + iota_target.max(1e-9);
        self.wake_at[page] = wake;
        self.wakes.schedule(wake, self.version[page], page as u32);
    }

    fn on_veto(&mut self, page: usize, t: f64) {
        // a decorator (politeness) rejected the pick: take it out of
        // the hot heap so an immediate retry yields the next-best page
        // (the pre-redesign lazy sidelined the pick inside select as a
        // side effect of scheduling its wake). demote inverts from the
        // page's CURRENT state, so a high-value page re-wakes promptly.
        // Unconditional: a pick surfaced by the force-wake fallback is
        // cold with its calendar entry consumed — demote re-queues it,
        // so a vetoed fallback pick is never orphaned. veto_tick makes
        // the fallback skip it for the remainder of THIS tick.
        self.veto_tick[page] = t;
        self.demote(page, t);
    }

    fn on_page_added(&mut self, page: usize, params: &PageParams, t: f64) {
        self.note_world_mutation();
        if page == self.model.len() {
            // growth: one past the end
            self.model.push_page(params);
            self.version.push(0);
            self.wake_at.push(t);
            self.is_hot.push(false);
            self.live.push(true);
            self.veto_tick.push(f64::NEG_INFINITY);
        } else {
            // recycling: scrub every trace of the previous occupant —
            // the version bump stales any calendar/heap entry it left
            // (including one resident in the wheel's overflow bin)
            self.model.set_page(page, params);
            self.version[page] = self.version[page].wrapping_add(1);
            self.is_hot[page] = false;
            self.live[page] = true;
            self.veto_tick[page] = f64::NEG_INFINITY;
            self.wake_at[page] = t;
        }
        self.tracker.add_page(page, t);
        // the newcomer gets evaluated at the next tick and then finds
        // its own hot/cold tier
        self.wakes.schedule(t, self.version[page], page as u32);
    }

    fn on_page_removed(&mut self, page: usize, _t: f64) {
        self.note_world_mutation();
        // version bump = lazy deletion from both the timing wheel and
        // the hot heap; `live` guards the event hooks so a stray CIS
        // (a driver without liveness tracking) can never re-schedule
        // the dead slot — it ceases to exist for the selection loop
        self.version[page] = self.version[page].wrapping_add(1);
        self.is_hot[page] = false;
        self.live[page] = false;
        self.tracker.remove_page(page);
    }

    fn on_params_changed(&mut self, page: usize, params: &PageParams, t: f64) {
        if !self.live[page] {
            return; // stray event for a retired slot
        }
        self.note_world_mutation();
        // belief re-projection: truth columns, belief projection and
        // value dispatch all recompute under the new parameters
        self.model.set_page(page, params);
        if self.is_hot[page] {
            // the stored heap key was computed under the old belief —
            // re-key immediately so the jump (either way) is visible
            let v = self.value(page, t);
            self.promote(page, v);
        } else {
            // cold: the old wake time inverted the old value curve;
            // wake immediately and let one evaluation re-tier the page
            self.version[page] = self.version[page].wrapping_add(1);
            self.wake_at[page] = t;
            self.wakes.schedule(t, self.version[page], page as u32);
        }
    }

    fn on_cis(&mut self, page: usize, t: f64) {
        if !self.live[page] {
            // a stray CIS for a retired slot must not touch the
            // tracker or re-schedule a wake: the cold-path reschedule
            // below would otherwise stamp a CURRENT-version calendar
            // entry and resurrect the dead page into the selection loop
            return;
        }
        self.tracker.on_cis(page);
        if !self.model.policy().uses_cis() {
            return;
        }
        if self.is_hot[page] {
            // its stored value is now a stale lower bound; refresh so the
            // jump is visible to the selection loop promptly
            self.cis_evals += 1;
            let v = self.value(page, t);
            self.promote(page, v);
        } else {
            // a CIS advances the effective time by exactly β, so the
            // (monotone) value reaches its wake target β earlier — shift
            // the wake without evaluating anything (O(log) push). Uses
            // the BELIEF β (the GREEDY belief has γ = 0: no shift at all).
            if self.model.belief(page).gamma <= 0.0 {
                return;
            }
            let beta = self.model.belief(page).beta;
            let new_wake = if beta.is_finite() {
                (self.wake_at[page] - beta).max(t + 1e-9)
            } else {
                t + 1e-9 // noiseless CIS: value saturates immediately
            };
            if new_wake < self.wake_at[page] {
                self.version[page] = self.version[page].wrapping_add(1);
                self.wake_at[page] = new_wake;
                self.wakes.schedule(new_wake, self.version[page], page as u32);
            }
        }
    }

    fn name(&self) -> String {
        format!("{}-LAZY", self.model.policy().name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::crawler::GreedyScheduler;
    use crate::rngkit::Rng;
    use crate::sim::{generate_traces, simulate, CisDelay, SimConfig};

    fn pages(m: usize, seed: u64) -> Vec<PageParams> {
        let mut rng = Rng::new(seed);
        (0..m)
            .map(|_| PageParams {
                delta: rng.range(0.01, 1.0),
                mu: rng.range(0.01, 1.0),
                lam: crate::rngkit::beta(&mut rng, 0.25, 0.25),
                nu: rng.range(0.1, 0.6),
            })
            .collect()
    }

    #[test]
    fn lazy_parity_with_exact() {
        let ps = pages(150, 1);
        let horizon = 200.0;
        let cfg = SimConfig::new(10.0, horizon).unwrap();
        let mut acc_exact = 0.0;
        let mut acc_lazy = 0.0;
        let reps = 4;
        for rep in 0..reps {
            let mut rng = Rng::new(50 + rep);
            let traces = generate_traces(&ps, horizon, CisDelay::None, &mut rng);
            let mut ex = GreedyScheduler::new(PolicyKind::GreedyNcis, &ps, ValueBackend::Native);
            let mut lz = LazyGreedyScheduler::new(PolicyKind::GreedyNcis, &ps);
            acc_exact += simulate(&traces, &cfg, &mut ex).accuracy;
            acc_lazy += simulate(&traces, &cfg, &mut lz).accuracy;
        }
        acc_exact /= reps as f64;
        acc_lazy /= reps as f64;
        assert!(
            (acc_exact - acc_lazy).abs() < 0.02,
            "exact {acc_exact} vs lazy {acc_lazy}"
        );
    }

    #[test]
    fn lazy_parity_tight_bandwidth() {
        // the regime that previously degenerated: many pages, few crawls
        let ps = pages(800, 9);
        let horizon = 100.0;
        let cfg = SimConfig::new(5.0, horizon).unwrap();
        let mut rng = Rng::new(10);
        let traces = generate_traces(&ps, horizon, CisDelay::None, &mut rng);
        let mut ex = GreedyScheduler::new(PolicyKind::GreedyNcis, &ps, ValueBackend::Native);
        let mut lz = LazyGreedyScheduler::new(PolicyKind::GreedyNcis, &ps);
        let a = simulate(&traces, &cfg, &mut ex).accuracy;
        let b = simulate(&traces, &cfg, &mut lz).accuracy;
        assert!((a - b).abs() < 0.03, "exact {a} vs lazy {b}");
    }

    #[test]
    fn lazy_saves_evaluations() {
        let ps = pages(400, 2);
        let horizon = 100.0;
        let cfg = SimConfig::new(10.0, horizon).unwrap();
        let mut rng = Rng::new(3);
        let traces = generate_traces(&ps, horizon, CisDelay::None, &mut rng);
        let mut lz = LazyGreedyScheduler::new(PolicyKind::GreedyNcis, &ps);
        let res = simulate(&traces, &cfg, &mut lz);
        eprintln!(
            "diag: wake={} cis={} refresh={} total={} ticks={} demotes={} immediate={}",
            lz.wake_evals, lz.cis_evals, lz.refresh_evals, lz.evals, lz.ticks,
            lz.demotes, lz.immediate_wakes
        );
        let exact_evals = res.ticks as f64 * ps.len() as f64;
        assert!(
            (lz.evals as f64) < 0.25 * exact_evals,
            "lazy evals {} vs exact {}",
            lz.evals,
            exact_evals
        );
    }

    #[test]
    fn every_tick_crawls_something() {
        let ps = pages(30, 4);
        let cfg = SimConfig::new(5.0, 50.0).unwrap();
        let mut rng = Rng::new(5);
        let traces = generate_traces(&ps, 50.0, CisDelay::None, &mut rng);
        let mut lz = LazyGreedyScheduler::new(PolicyKind::GreedyNcis, &ps);
        let res = simulate(&traces, &cfg, &mut lz);
        let total: u64 = res.crawl_counts.iter().map(|&c| c as u64).sum();
        assert_eq!(total, res.ticks);
    }

    #[test]
    fn works_for_all_policy_kinds() {
        let ps = pages(40, 6);
        let cfg = SimConfig::new(4.0, 40.0).unwrap();
        for kind in [
            PolicyKind::Greedy,
            PolicyKind::GreedyCis,
            PolicyKind::GreedyNcis,
            PolicyKind::NcisApprox(2),
            PolicyKind::GreedyCisPlus,
        ] {
            let mut rng = Rng::new(7);
            let traces = generate_traces(&ps, 40.0, CisDelay::None, &mut rng);
            let mut lz = LazyGreedyScheduler::new(kind, &ps);
            let res = simulate(&traces, &cfg, &mut lz);
            assert!((0.0..=1.0).contains(&res.accuracy), "{}", lz.name());
        }
    }

    #[test]
    fn vetoing_every_page_idles_the_tick_without_orphaning() {
        use crate::sched::CrawlScheduler;
        // veto every pick at one tick: each retry must surface a NEW
        // page (never a just-vetoed one, even via the force-wake
        // fallback); once all pages are vetoed the tick idles; and at
        // the next tick the pages come back (nothing is orphaned)
        let ps = pages(3, 11);
        let mut lz = LazyGreedyScheduler::new(PolicyKind::GreedyNcis, &ps);
        lz.on_start(ps.len());
        let t = 1.0;
        let mut seen = [false; 3];
        for k in 0..3 {
            let pick = lz.select(t).unwrap_or_else(|| panic!("pick {k} missing"));
            assert!(!seen[pick], "retry {k} re-surfaced vetoed page {pick}");
            seen[pick] = true;
            lz.on_veto(pick, t);
        }
        assert_eq!(lz.select(t), None, "all pages vetoed: tick must idle");
        assert!(lz.select(2.0).is_some(), "vetoed pages were orphaned");
    }

    #[test]
    fn dynamic_lifecycle_drives_selection_correctly() {
        // retire the running scheduler's pages one by one; the retired
        // ones must never surface again, and a newcomer recycled into a
        // dead slot must get picked up by the selection loop
        let ps = pages(6, 20);
        let mut lz = LazyGreedyScheduler::new(PolicyKind::GreedyNcis, &ps);
        lz.on_start(ps.len());
        for step in 1..=10 {
            let t = step as f64;
            if let Some(i) = lz.select(t) {
                lz.on_crawl(i, t);
            }
        }
        lz.on_page_removed(2, 10.5);
        lz.on_page_removed(4, 10.5);
        for step in 11..=40 {
            let t = step as f64;
            if let Some(i) = lz.select(t) {
                assert!(i != 2 && i != 4, "retired page {i} selected at t={t}");
                lz.on_crawl(i, t);
            }
        }
        // rebirth into slot 2 with a dominant page: it must win soon
        let hot = PageParams { delta: 0.9, mu: 50.0, lam: 0.0, nu: 0.0 };
        lz.on_page_added(2, &hot, 40.5);
        let mut crawled_newcomer = false;
        for step in 41..=60 {
            let t = step as f64;
            if let Some(i) = lz.select(t) {
                assert_ne!(i, 4, "still-dead page selected");
                if i == 2 {
                    crawled_newcomer = true;
                }
                lz.on_crawl(i, t);
            }
        }
        assert!(crawled_newcomer, "recycled newcomer was never crawled");
    }

    #[test]
    fn stray_events_after_retirement_do_not_resurrect() {
        // a driver without liveness tracking (the streaming pipeline
        // forwards CIS by index alone) may deliver events for a slot
        // the scheduler already retired: they must be inert — the
        // cold-path CIS wake reschedule would otherwise stamp a
        // current-version calendar entry and bring the dead page back
        let ps = pages(4, 22);
        let mut lz = LazyGreedyScheduler::new(PolicyKind::GreedyNcis, &ps);
        lz.on_start(ps.len());
        for step in 1..=5 {
            let t = step as f64;
            if let Some(i) = lz.select(t) {
                lz.on_crawl(i, t);
            }
        }
        lz.on_page_removed(1, 5.5);
        lz.on_cis(1, 6.0);
        lz.on_params_changed(1, &ps[0], 6.5);
        for step in 7..=40 {
            let t = step as f64;
            if let Some(i) = lz.select(t) {
                assert_ne!(i, 1, "stray post-retirement event resurrected the page at t={t}");
                lz.on_crawl(i, t);
            }
        }
    }

    #[test]
    fn params_change_reprojects_beliefs_promptly() {
        // two pages; page 1 starts negligible, then drifts to dominate:
        // the scheduler must start crawling it without a CIS nudge
        let ps = vec![
            PageParams { delta: 0.5, mu: 0.5, lam: 0.0, nu: 0.0 },
            PageParams { delta: 0.5, mu: 0.001, lam: 0.0, nu: 0.0 },
        ];
        let mut lz = LazyGreedyScheduler::new(PolicyKind::GreedyNcis, &ps);
        lz.on_start(ps.len());
        for step in 1..=20 {
            let t = step as f64 * 0.5;
            if let Some(i) = lz.select(t) {
                lz.on_crawl(i, t);
            }
        }
        lz.on_params_changed(1, &PageParams { delta: 0.5, mu: 50.0, lam: 0.0, nu: 0.0 }, 10.2);
        let mut picked = 0u32;
        for step in 21..=40 {
            let t = step as f64 * 0.5;
            if let Some(i) = lz.select(t) {
                if i == 1 {
                    picked += 1;
                }
                lz.on_crawl(i, t);
            }
        }
        assert!(picked >= 10, "drifted page picked only {picked}/20 times");
    }

    #[test]
    fn reuse_after_dynamic_run_matches_fresh() {
        // a lazy scheduler that lived through churn must reset to the
        // pristine population on on_start (reuse == fresh, bit-exact)
        let ps = pages(40, 21);
        let cfg = SimConfig::new(5.0, 40.0).unwrap();
        let mut reused = LazyGreedyScheduler::new(PolicyKind::GreedyNcis, &ps);
        // dynamic episode outside any engine: grow, retire, drift
        reused.on_start(ps.len());
        reused.on_page_added(40, &PageParams { delta: 0.7, mu: 0.7, lam: 0.3, nu: 0.1 }, 1.0);
        reused.on_page_removed(5, 2.0);
        reused.on_params_changed(9, &PageParams { delta: 1.3, mu: 0.2, lam: 0.5, nu: 0.2 }, 3.0);
        let _ = reused.select(4.0);
        // a plain static rep afterwards must equal a fresh scheduler
        let mut rng = Rng::new(90);
        let traces = generate_traces(&ps, 40.0, CisDelay::None, &mut rng);
        let mut fresh = LazyGreedyScheduler::new(PolicyKind::GreedyNcis, &ps);
        let a = simulate(&traces, &cfg, &mut reused);
        let b = simulate(&traces, &cfg, &mut fresh);
        assert_eq!(a.accuracy.to_bits(), b.accuracy.to_bits());
        assert_eq!(a.crawl_counts, b.crawl_counts);
    }

    #[test]
    fn reuse_across_runs_matches_fresh() {
        // on_start must fully reset the calendar/heap/threshold state
        let ps = pages(60, 8);
        let cfg = SimConfig::new(5.0, 60.0).unwrap();
        let mut reused = LazyGreedyScheduler::new(PolicyKind::GreedyNcis, &ps);
        for rep in 0..3u64 {
            let mut rng = Rng::new(70 + rep);
            let traces = generate_traces(&ps, 60.0, CisDelay::None, &mut rng);
            let mut fresh = LazyGreedyScheduler::new(PolicyKind::GreedyNcis, &ps);
            let a = simulate(&traces, &cfg, &mut reused);
            let b = simulate(&traces, &cfg, &mut fresh);
            assert_eq!(a.accuracy.to_bits(), b.accuracy.to_bits(), "rep {rep}");
            assert_eq!(a.crawl_counts, b.crawl_counts, "rep {rep}");
        }
    }
}
