//! The Layer-3 coordination contribution: drivers of Algorithm 1.
//!
//! Every driver here implements (or constructs) the event-driven
//! [`crate::sched::CrawlScheduler`] trait; [`builder::CrawlerBuilder`]
//! is the single entry point that wires policy × strategy × backend:
//!
//! - [`builder`] — the `CrawlerBuilder` facade (and [`builder::Strategy`]).
//! - [`crawler`] — the exact discrete greedy policy (`argmax_i V`), with
//!   pluggable value backends (native f64 or the PJRT batched engine).
//! - [`lazy`] — the §5.2 production scheduler: threshold tracking + wake
//!   calendar so most pages are *not* re-evaluated at every tick.
//! - [`shard`] — N-way sharding with 1/N bandwidth per shard (§5.2),
//!   load rebalancing, and the [`shard::ShardedScheduler`] composite.
//! - [`pipeline`] — a threaded streaming orchestrator (event ingestion,
//!   bounded queues / backpressure, worker shards) used by the
//!   `serve-shards` CLI and the Appendix-G scale experiment.
//! - [`hosts`] — per-host politeness decoration over any scheduler.
//! - [`learned`] — the oracle-free knowledge decorator: learns page
//!   parameters online from crawl outcomes ([`crate::estimation`]) and
//!   re-projects beliefs into the wrapped scheduler on a bounded
//!   budget, withholding scenario ground truth.

pub mod builder;
pub mod crawler;
pub mod hosts;
pub mod lazy;
pub mod learned;
pub mod pipeline;
pub mod shard;

pub use builder::{CrawlerBuilder, Knowledge, Strategy};
pub use crawler::{belief_params, GreedyScheduler, LdsAdapter, ValueBackend};
pub use lazy::LazyGreedyScheduler;
pub use learned::LearnedScheduler;
pub use pipeline::{run_serving_pipeline, ServingPipelineReport};
pub use shard::{rebalance, ShardPlan, ShardedRun, ShardedScheduler};
