//! §5.2 sharding: split pages into N shards, give each 1/N of the
//! bandwidth, schedule independently in parallel, and rebalance by
//! estimated load.

use crate::params::PageParams;
use crate::policy::PolicyKind;
use crate::rngkit::Rng;
use crate::sim::engine::{SimConfig, SimResult};
use crate::sim::{generate_traces, simulate, CisDelay};

/// Assignment of pages to shards.
#[derive(Debug, Clone)]
pub struct ShardPlan {
    /// `assignment[i]` = shard of page `i`.
    pub assignment: Vec<usize>,
    /// Number of shards.
    pub shards: usize,
}

impl ShardPlan {
    /// Round-robin assignment.
    pub fn round_robin(m: usize, shards: usize) -> Self {
        assert!(shards > 0);
        Self { assignment: (0..m).map(|i| i % shards).collect(), shards }
    }

    /// Per-shard page index lists.
    pub fn shard_members(&self) -> Vec<Vec<usize>> {
        let mut out = vec![Vec::new(); self.shards];
        for (i, &s) in self.assignment.iter().enumerate() {
            out[s].push(i);
        }
        out
    }
}

/// Greedy load rebalancing (largest-first into least-loaded shard):
/// `loads[i]` is the estimated crawl demand of page `i` (e.g. the
/// continuous solver's rate). Returns a plan whose shard loads differ by
/// at most the largest single page load.
pub fn rebalance(loads: &[f64], shards: usize) -> ShardPlan {
    assert!(shards > 0);
    let mut order: Vec<usize> = (0..loads.len()).collect();
    order.sort_by(|&a, &b| loads[b].partial_cmp(&loads[a]).unwrap());
    let mut shard_load = vec![0.0f64; shards];
    let mut assignment = vec![0usize; loads.len()];
    for &i in &order {
        let (s, _) = shard_load
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        assignment[i] = s;
        shard_load[s] += loads[i].max(0.0);
    }
    ShardPlan { assignment, shards }
}

/// Result of a sharded simulation run.
#[derive(Debug, Clone)]
pub struct ShardedRun {
    /// Request-weighted overall accuracy.
    pub accuracy: f64,
    /// Per-shard results.
    pub per_shard: Vec<SimResult>,
}

/// Simulate all shards (each with bandwidth `R/N` and its own trace
/// stream) in parallel via scoped threads, and merge accuracy.
pub fn run_sharded(
    pages: &[PageParams],
    plan: &ShardPlan,
    policy: PolicyKind,
    bandwidth: f64,
    horizon: f64,
    seed: u64,
) -> ShardedRun {
    let members = plan.shard_members();
    let shard_r = bandwidth / plan.shards as f64;
    let mut results: Vec<Option<SimResult>> = vec![None; plan.shards];
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for (s, member) in members.iter().enumerate() {
            let pages_s: Vec<PageParams> = member.iter().map(|&i| pages[i]).collect();
            handles.push(scope.spawn(move || {
                if pages_s.is_empty() {
                    return None;
                }
                let mut rng = Rng::new(seed ^ (s as u64).wrapping_mul(0x9E37_79B9));
                let traces = generate_traces(&pages_s, horizon, CisDelay::None, &mut rng);
                let cfg = SimConfig::new(shard_r, horizon);
                let mut sched =
                    crate::coordinator::lazy::LazyGreedyScheduler::new(policy, &pages_s);
                Some(simulate(&traces, &cfg, &mut sched))
            }));
        }
        for (s, h) in handles.into_iter().enumerate() {
            results[s] = h.join().expect("shard thread panicked");
        }
    });
    let per_shard: Vec<SimResult> = results.into_iter().flatten().collect();
    let total_req: u64 = per_shard.iter().map(|r| r.requests).sum();
    let fresh: u64 = per_shard.iter().map(|r| r.fresh_hits).sum();
    ShardedRun {
        accuracy: if total_req > 0 { fresh as f64 / total_req as f64 } else { f64::NAN },
        per_shard,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_conserves_pages() {
        let plan = ShardPlan::round_robin(103, 8);
        let members = plan.shard_members();
        let total: usize = members.iter().map(|m| m.len()).sum();
        assert_eq!(total, 103);
        // sizes within 1
        let min = members.iter().map(|m| m.len()).min().unwrap();
        let max = members.iter().map(|m| m.len()).max().unwrap();
        assert!(max - min <= 1);
    }

    #[test]
    fn rebalance_conserves_and_balances() {
        let mut rng = Rng::new(1);
        let loads: Vec<f64> = (0..200).map(|_| rng.range(0.0, 1.0)).collect();
        let plan = rebalance(&loads, 4);
        let members = plan.shard_members();
        let total: usize = members.iter().map(|m| m.len()).sum();
        assert_eq!(total, 200);
        let shard_loads: Vec<f64> = members
            .iter()
            .map(|m| m.iter().map(|&i| loads[i]).sum::<f64>())
            .collect();
        let min = shard_loads.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = shard_loads.iter().cloned().fold(0.0f64, f64::max);
        let biggest = loads.iter().cloned().fold(0.0f64, f64::max);
        assert!(max - min <= biggest + 1e-9, "spread {} > {}", max - min, biggest);
    }

    #[test]
    fn sharded_accuracy_close_to_single() {
        let mut rng = Rng::new(2);
        let pages: Vec<PageParams> = (0..120)
            .map(|_| PageParams {
                delta: rng.range(0.05, 1.0),
                mu: rng.range(0.05, 1.0),
                lam: 0.5,
                nu: 0.2,
            })
            .collect();
        let single = run_sharded(
            &pages,
            &ShardPlan::round_robin(pages.len(), 1),
            PolicyKind::GreedyNcis,
            10.0,
            150.0,
            7,
        );
        let sharded = run_sharded(
            &pages,
            &ShardPlan::round_robin(pages.len(), 4),
            PolicyKind::GreedyNcis,
            10.0,
            150.0,
            7,
        );
        assert!(
            (single.accuracy - sharded.accuracy).abs() < 0.05,
            "single {} vs sharded {}",
            single.accuracy,
            sharded.accuracy
        );
    }
}
