//! §5.2 sharding: split pages into N shards, give each 1/N of the
//! bandwidth, schedule independently in parallel, and rebalance by
//! estimated load.
//!
//! [`ShardedScheduler`] is the single-process composite: N per-shard
//! lazy schedulers behind one [`CrawlScheduler`] face, ticks fanned
//! round-robin (each shard sees 1/N of the ticks — the same topology
//! the threaded `pipeline` runs across worker threads). It is what
//! `CrawlerBuilder::strategy(Strategy::Sharded {..})` constructs, with
//! any [`ValueBackend`] plugged into every shard.

use crate::coordinator::crawler::ValueBackend;
use crate::coordinator::lazy::{LazyGreedyScheduler, DEFAULT_MARGIN};
use crate::params::PageParams;
use crate::policy::PolicyKind;
use crate::rngkit::Rng;
use crate::sched::CrawlScheduler;
use crate::sim::engine::{SimConfig, SimResult};
use crate::sim::{generate_traces, simulate, CisDelay};

/// Assignment of pages to shards.
#[derive(Debug, Clone)]
pub struct ShardPlan {
    /// `assignment[i]` = shard of page `i`.
    pub assignment: Vec<usize>,
    /// Number of shards.
    pub shards: usize,
}

impl ShardPlan {
    /// Round-robin assignment.
    pub fn round_robin(m: usize, shards: usize) -> Self {
        assert!(shards > 0);
        Self { assignment: (0..m).map(|i| i % shards).collect(), shards }
    }

    /// Per-shard page index lists.
    pub fn shard_members(&self) -> Vec<Vec<usize>> {
        let mut out = vec![Vec::new(); self.shards];
        for (i, &s) in self.assignment.iter().enumerate() {
            out[s].push(i);
        }
        out
    }
}

/// Greedy load rebalancing (largest-first into least-loaded shard):
/// `loads[i]` is the estimated crawl demand of page `i` (e.g. the
/// continuous solver's rate). Returns a plan whose shard loads differ by
/// at most the largest single page load.
pub fn rebalance(loads: &[f64], shards: usize) -> ShardPlan {
    assert!(shards > 0);
    let mut order: Vec<usize> = (0..loads.len()).collect();
    // total_cmp: a NaN load sorts deterministically instead of aborting
    order.sort_by(|&a, &b| loads[b].total_cmp(&loads[a]));
    let mut shard_load = vec![0.0f64; shards];
    let mut assignment = vec![0usize; loads.len()];
    for &i in &order {
        let (s, _) = shard_load
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.total_cmp(b.1))
            .unwrap_or((0, &0.0));
        assignment[i] = s;
        shard_load[s] += loads[i].max(0.0);
    }
    ShardPlan { assignment, shards }
}

/// N independently-scheduled shards behind one scheduler face.
///
/// Ticks are fanned round-robin, one shard per tick — the same 1/N
/// bandwidth split as the threaded pipeline, with empty or idling
/// shards forfeiting their tick. CIS and crawl events are routed by the
/// shard plan; picks are translated back to global page indices.
/// Per-shard scheduling runs through the §5.2 lazy scheduler with the
/// given value backend.
pub struct ShardedScheduler {
    inner: Vec<LazyGreedyScheduler>,
    plan: ShardPlan,
    /// Per-shard global-page-index lists (`members[s][local] = global`).
    members: Vec<Vec<usize>>,
    /// Local index of each global page within its shard.
    local_index: Vec<usize>,
    next_shard: usize,
    /// Construction-time inputs, kept so `on_start` can rebuild after
    /// a dynamic-world run changed the membership. Unlike the
    /// per-scheduler lazy snapshots (Greedy/Lazy snapshot their model's
    /// raw pages at the first mutation), the composite must keep the
    /// global population eagerly — it owns no model to recover it from.
    policy: PolicyKind,
    backend: ValueBackend,
    initial_pages: Vec<PageParams>,
    world_mutated: bool,
    /// Attached trace handle, kept so the post-dynamic-run rebuild can
    /// re-attach it to the fresh shard schedulers.
    trace: Option<crate::trace::TraceHandle>,
}

impl ShardedScheduler {
    /// Round-robin shard the pages and build one lazy scheduler (with
    /// `backend`) per non-trivial shard.
    pub fn new(
        policy: PolicyKind,
        pages: &[PageParams],
        shards: usize,
        backend: ValueBackend,
    ) -> Self {
        assert!(shards > 0, "at least one shard required");
        let plan = ShardPlan::round_robin(pages.len(), shards);
        let members = plan.shard_members();
        let mut local_index = vec![0usize; pages.len()];
        for member in &members {
            for (li, &gi) in member.iter().enumerate() {
                local_index[gi] = li;
            }
        }
        let inner = members
            .iter()
            .map(|member| {
                let pages_s: Vec<PageParams> = member.iter().map(|&i| pages[i]).collect();
                LazyGreedyScheduler::with_backend(
                    policy,
                    &pages_s,
                    DEFAULT_MARGIN,
                    backend.clone(),
                )
            })
            .collect();
        Self {
            inner,
            plan,
            members,
            local_index,
            next_shard: 0,
            policy,
            backend,
            initial_pages: pages.to_vec(),
            world_mutated: false,
            trace: None,
        }
    }

    /// Number of shards.
    pub fn shards(&self) -> usize {
        self.plan.shards
    }

    /// The page → shard assignment in use.
    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }
}

impl CrawlScheduler for ShardedScheduler {
    fn on_start(&mut self, m: usize) {
        if self.world_mutated {
            // a dynamic run grew the membership: rebuild the plan and
            // every shard scheduler from the pristine population (the
            // trace handle is a capability, not state — it survives)
            let policy = self.policy;
            let backend = self.backend.clone();
            let shards = self.plan.shards;
            let pages = std::mem::take(&mut self.initial_pages);
            let trace = self.trace.take();
            *self = Self::new(policy, &pages, shards, backend);
            if let Some(tr) = trace {
                self.attach_trace(tr);
            }
        }
        debug_assert_eq!(m, self.local_index.len(), "page count changed between runs");
        self.next_shard = 0;
        for (s, inner) in self.inner.iter_mut().enumerate() {
            inner.on_start(self.members[s].len());
        }
    }

    fn on_cis(&mut self, page: usize, t: f64) {
        let s = self.plan.assignment[page];
        self.inner[s].on_cis(self.local_index[page], t);
    }

    fn on_crawl(&mut self, page: usize, t: f64) {
        let s = self.plan.assignment[page];
        self.inner[s].on_crawl(self.local_index[page], t);
    }

    fn on_veto(&mut self, page: usize, t: f64) {
        let s = self.plan.assignment[page];
        self.inner[s].on_veto(self.local_index[page], t);
    }

    fn on_crawl_failed(&mut self, page: usize, t: f64, outcome: crate::fault::CrawlOutcome) {
        let s = self.plan.assignment[page];
        self.inner[s].on_crawl_failed(self.local_index[page], t, outcome);
    }

    fn on_fetch_observed(&mut self, page: usize, t: f64, changed: bool) {
        let s = self.plan.assignment[page];
        self.inner[s].on_fetch_observed(self.local_index[page], t, changed);
    }

    fn on_page_added(&mut self, page: usize, params: &PageParams, t: f64) {
        self.world_mutated = true;
        if page == self.plan.assignment.len() {
            // growth: route consistently with the round-robin plan
            // (`page % shards`), so any driver — this composite, the
            // threaded pipeline, a future distributed router — sends
            // the same newborn to the same shard
            let s = page % self.plan.shards;
            self.plan.assignment.push(s);
            let local = self.members[s].len();
            self.members[s].push(page);
            self.local_index.push(local);
            self.inner[s].on_page_added(local, params, t);
        } else {
            // recycled slot: its shard and local slot persist, the
            // shard scheduler recycles its local slot in turn
            let s = self.plan.assignment[page];
            self.inner[s].on_page_added(self.local_index[page], params, t);
        }
    }

    fn on_page_removed(&mut self, page: usize, t: f64) {
        self.world_mutated = true;
        let s = self.plan.assignment[page];
        self.inner[s].on_page_removed(self.local_index[page], t);
    }

    fn on_params_changed(&mut self, page: usize, params: &PageParams, t: f64) {
        self.world_mutated = true;
        let s = self.plan.assignment[page];
        self.inner[s].on_params_changed(self.local_index[page], params, t);
    }

    fn select(&mut self, t: f64) -> Option<usize> {
        // one tick → one shard, round-robin — exactly the threaded
        // pipeline's topology: every shard gets 1/N of the ticks and an
        // empty or idling shard forfeits its tick (so the two drivers
        // measure the same bandwidth allocation)
        let s = self.next_shard;
        self.next_shard = (self.next_shard + 1) % self.inner.len();
        if self.members[s].is_empty() {
            return None;
        }
        self.inner[s].select(t).map(|local| self.members[s][local])
    }

    fn attach_trace(&mut self, tr: crate::trace::TraceHandle) {
        for inner in &mut self.inner {
            inner.attach_trace(tr.clone());
        }
        self.trace = Some(tr);
    }

    fn name(&self) -> String {
        let policy = self
            .inner
            .first()
            .map(|s| s.policy().name())
            .unwrap_or_else(|| "EMPTY".into());
        format!("{policy}-SHARDED{}", self.plan.shards)
    }
}

/// Result of a sharded simulation run.
#[derive(Debug, Clone)]
pub struct ShardedRun {
    /// Request-weighted overall accuracy.
    pub accuracy: f64,
    /// Per-shard results.
    pub per_shard: Vec<SimResult>,
}

/// Simulate all shards (each with bandwidth `R/N` and its own trace
/// stream) in parallel via scoped threads, and merge accuracy. Per-shard
/// schedulers are constructed through [`crate::CrawlerBuilder`] (lazy
/// strategy, native backend).
///
/// Construction problems (bad bandwidth, invalid scheduler template)
/// surface as `Err` *before* any thread spawns; a shard thread that
/// panics mid-run surfaces as [`crate::Error::WorkerFailed`] with the
/// surviving shards' crawl totals salvaged — no path aborts the
/// process.
pub fn run_sharded(
    pages: &[PageParams],
    plan: &ShardPlan,
    policy: PolicyKind,
    bandwidth: f64,
    horizon: f64,
    seed: u64,
) -> crate::Result<ShardedRun> {
    let members = plan.shard_members();
    let shard_r = bandwidth / plan.shards as f64;
    let cfg = SimConfig::new(shard_r, horizon)?;
    // build every shard's scheduler up front: template errors are Err
    // here, before any thread exists
    let mut jobs: Vec<(usize, Vec<PageParams>, Box<dyn CrawlScheduler + Send>)> = Vec::new();
    for (s, member) in members.iter().enumerate() {
        let pages_s: Vec<PageParams> = member.iter().map(|&i| pages[i]).collect();
        if pages_s.is_empty() {
            continue;
        }
        let sched = crate::coordinator::builder::CrawlerBuilder::new()
            .policy(policy)
            .strategy(crate::coordinator::builder::Strategy::Lazy)
            .pages(&pages_s)
            .build()?;
        jobs.push((s, pages_s, sched));
    }
    let mut results: Vec<Option<SimResult>> = vec![None; plan.shards];
    let mut failed: Vec<(usize, String)> = Vec::new();
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for (s, pages_s, mut sched) in jobs {
            let cfg = &cfg;
            handles.push((
                s,
                scope.spawn(move || {
                    let mut rng = Rng::new(seed ^ (s as u64).wrapping_mul(0x9E37_79B9));
                    let traces = generate_traces(&pages_s, horizon, CisDelay::None, &mut rng);
                    simulate(&traces, cfg, sched.as_mut())
                }),
            ));
        }
        for (s, h) in handles {
            match h.join() {
                Ok(r) => results[s] = Some(r),
                Err(payload) => {
                    let msg = payload
                        .downcast_ref::<&str>()
                        .map(|m| (*m).to_string())
                        .or_else(|| payload.downcast_ref::<String>().cloned())
                        .unwrap_or_else(|| "non-string panic payload".into());
                    failed.push((s, msg));
                }
            }
        }
    });
    if !failed.is_empty() {
        let crawls_per_shard = results
            .iter()
            .map(|r| {
                r.as_ref()
                    .map(|r| r.crawl_counts.iter().map(|&c| c as u64).sum())
                    .unwrap_or(0)
            })
            .collect();
        return Err(crate::Error::WorkerFailed { failed, crawls_per_shard });
    }
    let per_shard: Vec<SimResult> = results.into_iter().flatten().collect();
    let total_req: u64 = per_shard.iter().map(|r| r.requests).sum();
    let fresh: u64 = per_shard.iter().map(|r| r.fresh_hits).sum();
    Ok(ShardedRun {
        accuracy: if total_req > 0 { fresh as f64 / total_req as f64 } else { f64::NAN },
        per_shard,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_conserves_pages() {
        let plan = ShardPlan::round_robin(103, 8);
        let members = plan.shard_members();
        let total: usize = members.iter().map(|m| m.len()).sum();
        assert_eq!(total, 103);
        // sizes within 1
        let min = members.iter().map(|m| m.len()).min().unwrap();
        let max = members.iter().map(|m| m.len()).max().unwrap();
        assert!(max - min <= 1);
    }

    #[test]
    fn rebalance_conserves_and_balances() {
        let mut rng = Rng::new(1);
        let loads: Vec<f64> = (0..200).map(|_| rng.range(0.0, 1.0)).collect();
        let plan = rebalance(&loads, 4);
        let members = plan.shard_members();
        let total: usize = members.iter().map(|m| m.len()).sum();
        assert_eq!(total, 200);
        let shard_loads: Vec<f64> = members
            .iter()
            .map(|m| m.iter().map(|&i| loads[i]).sum::<f64>())
            .collect();
        let min = shard_loads.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = shard_loads.iter().cloned().fold(0.0f64, f64::max);
        let biggest = loads.iter().cloned().fold(0.0f64, f64::max);
        assert!(max - min <= biggest + 1e-9, "spread {} > {}", max - min, biggest);
    }

    fn test_pages(m: usize, seed: u64) -> Vec<PageParams> {
        let mut rng = Rng::new(seed);
        (0..m)
            .map(|_| PageParams {
                delta: rng.range(0.05, 1.0),
                mu: rng.range(0.05, 1.0),
                lam: 0.5,
                nu: 0.2,
            })
            .collect()
    }

    #[test]
    fn sharded_accuracy_close_to_single() {
        let pages = test_pages(120, 2);
        let single = run_sharded(
            &pages,
            &ShardPlan::round_robin(pages.len(), 1),
            PolicyKind::GreedyNcis,
            10.0,
            150.0,
            7,
        )
        .expect("single-shard run");
        let sharded = run_sharded(
            &pages,
            &ShardPlan::round_robin(pages.len(), 4),
            PolicyKind::GreedyNcis,
            10.0,
            150.0,
            7,
        )
        .expect("4-shard run");
        assert!(
            (single.accuracy - sharded.accuracy).abs() < 0.05,
            "single {} vs sharded {}",
            single.accuracy,
            sharded.accuracy
        );
    }

    #[test]
    fn sharded_scheduler_crawls_every_tick_and_spreads_load() {
        let pages = test_pages(64, 3);
        let mut sched =
            ShardedScheduler::new(PolicyKind::GreedyNcis, &pages, 4, ValueBackend::Native);
        assert_eq!(sched.shards(), 4);
        let mut rng = Rng::new(4);
        let traces = generate_traces(&pages, 50.0, CisDelay::None, &mut rng);
        let cfg = SimConfig::new(20.0, 50.0).unwrap();
        let res = simulate(&traces, &cfg, &mut sched);
        let total: u64 = res.crawl_counts.iter().map(|&c| c as u64).sum();
        assert_eq!(total, res.ticks, "every tick must crawl");
        // round-robin tick fan-out: per-shard crawl totals within one
        let members = sched.plan().shard_members();
        let per_shard: Vec<u64> = members
            .iter()
            .map(|m| m.iter().map(|&i| res.crawl_counts[i] as u64).sum())
            .collect();
        let min = per_shard.iter().min().unwrap();
        let max = per_shard.iter().max().unwrap();
        assert!(max - min <= 1, "unbalanced tick fan-out: {per_shard:?}");
    }

    #[test]
    fn sharded_scheduler_accuracy_close_to_unsharded_lazy() {
        let pages = test_pages(100, 5);
        let horizon = 120.0;
        let cfg = SimConfig::new(10.0, horizon).unwrap();
        let mut rng = Rng::new(6);
        let traces = generate_traces(&pages, horizon, CisDelay::None, &mut rng);
        let mut lazy = LazyGreedyScheduler::new(PolicyKind::GreedyNcis, &pages);
        let a = simulate(&traces, &cfg, &mut lazy).accuracy;
        let mut rng = Rng::new(6);
        let traces = generate_traces(&pages, horizon, CisDelay::None, &mut rng);
        let mut sharded =
            ShardedScheduler::new(PolicyKind::GreedyNcis, &pages, 4, ValueBackend::Native);
        let b = simulate(&traces, &cfg, &mut sharded).accuracy;
        assert!((a - b).abs() < 0.05, "lazy {a} vs sharded {b}");
    }

    #[test]
    fn births_route_round_robin_consistently() {
        let pages = test_pages(8, 9);
        let mut sched =
            ShardedScheduler::new(PolicyKind::GreedyNcis, &pages, 4, ValueBackend::Native);
        sched.on_start(pages.len());
        // growth: global indices 8, 9, 10 land on shards 0, 1, 2 —
        // exactly the round-robin plan extended
        for k in 0..3usize {
            let g = 8 + k;
            sched.on_page_added(g, &pages[k], 1.0);
            assert_eq!(sched.plan().assignment[g], g % 4, "birth routed off-plan");
        }
        // retire + recycle: the slot keeps its shard
        sched.on_page_removed(5, 2.0);
        sched.on_page_added(5, &pages[1], 3.0);
        assert_eq!(sched.plan().assignment[5], 5 % 4);
        // selection still maps local picks back to global indices
        let mut any = false;
        for step in 0..40 {
            let t = 4.0 + step as f64;
            if let Some(i) = sched.select(t) {
                assert!(i < 11, "pick {i} outside the grown population");
                sched.on_crawl(i, t);
                any = true;
            }
        }
        assert!(any, "grown sharded scheduler never crawled");
    }

    #[test]
    fn reuse_after_dynamic_run_matches_fresh() {
        let pages = test_pages(30, 11);
        let cfg = SimConfig::new(5.0, 30.0).unwrap();
        let mut reused =
            ShardedScheduler::new(PolicyKind::GreedyNcis, &pages, 3, ValueBackend::Native);
        reused.on_start(pages.len());
        reused.on_page_added(30, &pages[0], 1.0); // grow
        reused.on_page_removed(4, 2.0);
        reused.on_params_changed(7, &pages[1], 3.0);
        let _ = reused.select(4.0);
        // a plain static rep afterwards must equal a fresh scheduler
        let mut rng = Rng::new(12);
        let traces = generate_traces(&pages, 30.0, CisDelay::None, &mut rng);
        let mut fresh =
            ShardedScheduler::new(PolicyKind::GreedyNcis, &pages, 3, ValueBackend::Native);
        let a = simulate(&traces, &cfg, &mut reused);
        let b = simulate(&traces, &cfg, &mut fresh);
        assert_eq!(a.accuracy.to_bits(), b.accuracy.to_bits());
        assert_eq!(a.crawl_counts, b.crawl_counts);
    }

    #[test]
    fn more_shards_than_pages_idles_like_the_pipeline() {
        // 3 pages over 8 shards: the 5 empty shards forfeit their tick
        // share, exactly as the threaded pipeline's round-robin does
        let pages = test_pages(3, 7);
        let mut sched =
            ShardedScheduler::new(PolicyKind::GreedyNcis, &pages, 8, ValueBackend::Native);
        let mut rng = Rng::new(8);
        let traces = generate_traces(&pages, 20.0, CisDelay::None, &mut rng);
        let cfg = SimConfig::new(2.0, 20.0).unwrap();
        let res = simulate(&traces, &cfg, &mut sched);
        let total: u64 = res.crawl_counts.iter().map(|&c| c as u64).sum();
        assert_eq!(total, res.ticks * 3 / 8, "populated shards keep 3/8 of ticks");
    }
}
