//! Exact discrete greedy crawler (Algorithm 1) and the LDS adapter.
//!
//! Both implement the event-driven [`CrawlScheduler`] API: the greedy
//! crawler owns its per-page state in a [`PageTracker`] (updated from
//! `on_cis`/`on_crawl`) and projects beliefs through a shared
//! [`BeliefModel`], so the same scheduler runs on the native f64 path
//! or the batched PJRT path by swapping the [`ValueBackend`].

use std::sync::Arc;

use crate::lds::LdsScheduler;
use crate::params::PageParams;
use crate::policy::belief::VALUE_CHUNK;
use crate::policy::{BeliefModel, PolicyKind};
use crate::runtime::{PjrtEngine, ValueBatch};
use crate::sched::{CrawlScheduler, PageTracker};

pub use crate::policy::belief::belief_params;

/// Where crawl values are computed.
#[derive(Clone)]
pub enum ValueBackend {
    /// Pure-rust f64 evaluation (exact; per-page).
    Native,
    /// Batched f32 evaluation on the PJRT engine (the AOT Pallas kernel);
    /// `terms` selects the approximation-level artifact.
    Pjrt {
        /// Shared engine.
        engine: Arc<PjrtEngine>,
        /// Approximation level of the artifact to use.
        terms: u32,
    },
}

impl std::fmt::Debug for ValueBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ValueBackend::Native => write!(f, "Native"),
            ValueBackend::Pjrt { terms, .. } => write!(f, "Pjrt(terms={terms})"),
        }
    }
}

/// Algorithm 1 with an exact argmax over all pages at every tick.
///
/// The native path is a *batched, bound-pruned* argmax: pages are
/// visited in descending order of their static value upper bound
/// `μ̃/Δ`, evaluated in [`VALUE_CHUNK`]-wide chunks through the columnar
/// kernel ([`BeliefModel::values_into`]), and the scan stops as soon as
/// the next chunk's best possible value cannot beat the best value
/// already measured. The pick is provably identical to the full O(m)
/// scalar scan ([`Self::select_scalar_reference`], kept in-tree as the
/// parity oracle and bench baseline): skipped pages satisfy
/// `V_i ≤ ub_safe_i < best`, and ties resolve to the smallest index
/// exactly as the ascending scan does.
pub struct GreedyScheduler {
    model: BeliefModel,
    backend: ValueBackend,
    tracker: PageTracker,
    batch: ValueBatch,
    /// Tick time of each page's last politeness veto: pages vetoed at
    /// the CURRENT tick are masked out of the argmax so a decorator's
    /// retry reaches the next-best page instead of re-picking.
    veto_tick: Vec<f64>,
    /// Newest veto tick (cheap "any veto active at t?" probe).
    last_veto_t: f64,
    /// LIVE page indices sorted by descending upper bound (ties:
    /// ascending index) — the pruned argmax's visit order. Rebuilt
    /// lazily (`dirty`) after dynamic-world membership/parameter
    /// changes, so the argmax always scans exactly the live set.
    by_ub: Vec<u32>,
    /// Numerically safe per-page value upper bounds: `μ̃/Δ` inflated by
    /// 1e-9 relative + 1e-12 absolute. The value formulas stay below
    /// `μ̃/Δ` to within a few ulps (~1e-14 relative; the property suite
    /// pins `V ≤ μ̃/Δ + 1e-9`), so the inflation makes `V_i ≤ ub_safe_i`
    /// unconditional while costing no measurable pruning power.
    ub_safe: Vec<f64>,
    /// Liveness per slot (dynamic worlds retire/recycle slots; static
    /// runs never clear a flag).
    live: Vec<bool>,
    /// Retired-slot count (fast "anything dead?" probe for the PJRT
    /// argmax path).
    dead: usize,
    /// Visit order / bounds stale after a dynamic-world hook.
    dirty: bool,
    /// Pristine construction-time population, snapshotted lazily at
    /// the FIRST dynamic-world hook (static runs never pay the copy)
    /// so `on_start` can rebuild after a dynamic run mutated the model.
    initial_pages: Vec<PageParams>,
    /// Any dynamic-world hook fired since construction/reset.
    world_mutated: bool,
    /// Crawl values computed at the last tick (exposed for rate plots).
    /// With the pruned native argmax only *evaluated* pages refresh;
    /// entries for pruned pages keep their last computed value (a lower
    /// bound — values only grow between crawls).
    pub last_values: Vec<f64>,
    /// EMA of selected crawl values — the paper's estimate of the
    /// stationary threshold Λ (exposed for diagnostics / lazy parity).
    pub lambda_estimate: f64,
    /// Optional decision-trace handle: when attached (and recording),
    /// the native argmax emits one `Decision` event per pick with its
    /// bound-pruning stats. Strictly observational — no pick, belief
    /// or RNG state depends on it.
    trace: Option<crate::trace::TraceHandle>,
}

impl GreedyScheduler {
    /// Build from raw page parameters (importance should be normalized).
    pub fn new(policy: PolicyKind, pages: &[PageParams], backend: ValueBackend) -> Self {
        let model = BeliefModel::new(policy, pages);
        let m = model.len();
        let mut s = Self {
            model,
            backend,
            tracker: PageTracker::new(m),
            batch: ValueBatch::with_capacity(m),
            veto_tick: vec![f64::NEG_INFINITY; m],
            last_veto_t: f64::NEG_INFINITY,
            by_ub: Vec::with_capacity(m),
            ub_safe: vec![0.0; m],
            live: vec![true; m],
            dead: 0,
            dirty: false,
            initial_pages: Vec::new(),
            world_mutated: false,
            last_values: vec![0.0; m],
            lambda_estimate: 0.0,
            trace: None,
        };
        s.rebuild_order();
        s
    }

    /// First dynamic-world hook of a run: snapshot the still-pristine
    /// population before mutating anything, so `on_start` can rebuild.
    fn note_world_mutation(&mut self) {
        if !self.world_mutated {
            self.initial_pages = self.model.raw_pages().to_vec();
            self.world_mutated = true;
        }
    }

    /// The policy whose value function drives the argmax.
    pub fn policy(&self) -> PolicyKind {
        self.model.policy()
    }

    /// The belief model backing the argmax (diagnostics / audits).
    pub fn model(&self) -> &BeliefModel {
        &self.model
    }

    /// Is slot `page` currently live?
    pub fn is_live(&self, page: usize) -> bool {
        self.live[page]
    }

    /// Recompute the safe bounds of the live pages and re-sort the
    /// visit order over exactly the live set. The inflation map
    /// `u ↦ u + (u·1e-9 + 1e-12)` is strictly increasing, so sorting
    /// by the safe bound yields the same permutation the raw-`μ̃/Δ`
    /// sort did.
    fn rebuild_order(&mut self) {
        self.by_ub.clear();
        for i in 0..self.model.len() {
            if self.live[i] {
                let u = self.model.value_upper_bound(i);
                self.ub_safe[i] = u + (u * 1e-9 + 1e-12);
                self.by_ub.push(i as u32);
            }
        }
        let ub_safe = &self.ub_safe;
        self.by_ub.sort_by(|&a, &b| {
            ub_safe[b as usize].total_cmp(&ub_safe[a as usize]).then(a.cmp(&b))
        });
        self.dirty = false;
    }

    /// Batched native argmax (see the type docs for the equivalence
    /// argument). Chunks gather `(τ_ELAP, n_CIS)` into stack scratch,
    /// evaluate through the columnar kernel, and fuse the veto-masked
    /// argmax; the scan breaks once the next chunk's largest safe upper
    /// bound is below the best measured value.
    fn select_native(&mut self, t: f64) -> Option<usize> {
        if self.dirty {
            self.rebuild_order();
        }
        let masked = self.last_veto_t == t;
        let mut best = f64::NEG_INFINITY;
        let mut best_i = usize::MAX;
        let mut tau = [0.0f64; VALUE_CHUNK];
        let mut ncis = [0u32; VALUE_CHUNK];
        let mut vals = [0.0f64; VALUE_CHUNK];
        let mut chunks_visited = 0u32;
        let mut scanned = 0u32;
        let mut early_break = false;
        for chunk in self.by_ub.chunks(VALUE_CHUNK) {
            // chunk[0] carries the chunk's largest bound (sorted order):
            // once it cannot beat `best`, no later page can win or tie
            if self.ub_safe[chunk[0] as usize] < best {
                early_break = true;
                break;
            }
            let n = chunk.len();
            chunks_visited += 1;
            scanned += n as u32;
            for (j, &ip) in chunk.iter().enumerate() {
                let i = ip as usize;
                tau[j] = self.tracker.tau_elap(i, t);
                ncis[j] = self.tracker.n_cis(i);
            }
            self.model.values_into(chunk, &tau[..n], &ncis[..n], &mut vals[..n]);
            for (j, &ip) in chunk.iter().enumerate() {
                let i = ip as usize;
                let v = vals[j];
                debug_assert!(
                    v <= self.ub_safe[i],
                    "crawl value {v} above safe bound {} for page {i}",
                    self.ub_safe[i]
                );
                self.last_values[i] = v;
                if masked && self.veto_tick[i] == t {
                    continue; // vetoed at this tick: next-best instead
                }
                // first-max semantics of the ascending reference scan:
                // strictly greater wins; an exact tie goes to the
                // smaller page index
                if v > best || (v == best && i < best_i) {
                    best = v;
                    best_i = i;
                }
            }
        }
        if best_i == usize::MAX {
            return None;
        }
        self.update_lambda(best);
        crate::trace::emit(self.trace.as_ref(), || crate::trace::TraceEvent::Decision {
            t,
            page: best_i as u32,
            value: best,
            chunks: chunks_visited,
            scanned,
            early_break,
        });
        Some(best_i)
    }

    /// The pre-columnar native argmax, verbatim: a full O(m) scalar
    /// scan through the per-page value dispatch. Kept as the in-tree
    /// parity oracle (`tests/columnar_parity.rs` pins pick-for-pick
    /// equality with the batched path) and as the reference lane of
    /// `benches/perf.rs`.
    pub fn select_scalar_reference(&mut self, t: f64) -> Option<usize> {
        if self.dirty {
            self.rebuild_order();
        }
        let masked = self.last_veto_t == t;
        let mut best = f64::NEG_INFINITY;
        let mut arg = None;
        for i in 0..self.model.len() {
            if !self.live[i] {
                continue; // retired slot: not a candidate
            }
            let v = self.model.value(i, self.tracker.tau_elap(i, t), self.tracker.n_cis(i));
            self.last_values[i] = v;
            if masked && self.veto_tick[i] == t {
                continue; // vetoed at this tick: next-best instead
            }
            if v > best {
                best = v;
                arg = Some(i);
            }
        }
        if let Some(i) = arg {
            self.update_lambda(self.last_values[i]);
        }
        arg
    }

    fn select_pjrt(&mut self, engine: &PjrtEngine, terms: u32, t: f64) -> Option<usize> {
        self.batch.clear();
        for i in 0..self.model.len() {
            // effective time under the policy's OWN beliefs: a pending
            // CIS saturates a noiseless-belief page (β̂ = ∞ → capped)
            let iota =
                self.model.effective_time(i, self.tracker.tau_elap(i, t), self.tracker.n_cis(i));
            self.batch.push(iota, &self.model.belief(i));
        }
        if self.last_veto_t == t || self.dead > 0 {
            // masked path: fetch the batch values and argmax on the
            // host, skipping pages vetoed at this tick and retired
            // slots (the device-side argmax cannot mask either)
            let values = engine
                .crawl_values(terms, &self.batch)
                .unwrap_or_else(|e| panic!("pjrt crawl value execution failed: {e}"));
            let mut best = f32::NEG_INFINITY;
            let mut arg = None;
            for (i, &v) in values.iter().enumerate() {
                if !self.live[i] {
                    continue;
                }
                self.last_values[i] = v as f64;
                if self.veto_tick[i] == t {
                    continue;
                }
                if v > best {
                    best = v;
                    arg = Some(i);
                }
            }
            if let Some(i) = arg {
                self.update_lambda(self.last_values[i]);
            }
            return arg;
        }
        let (values, idx, best) = engine
            .crawl_values_argmax(terms, &self.batch)
            .unwrap_or_else(|e| panic!("pjrt crawl value execution failed: {e}"));
        for (dst, &v) in self.last_values.iter_mut().zip(&values) {
            *dst = v as f64;
        }
        self.update_lambda(best as f64);
        Some(idx)
    }

    fn update_lambda(&mut self, selected: f64) {
        const A: f64 = 0.05;
        self.lambda_estimate = if self.lambda_estimate == 0.0 {
            selected
        } else {
            (1.0 - A) * self.lambda_estimate + A * selected
        };
    }
}

impl CrawlScheduler for GreedyScheduler {
    fn on_start(&mut self, m: usize) {
        if self.world_mutated {
            // a dynamic run grew/retired/drifted the model: rebuild
            // from the pristine construction-time population, exactly
            // as a fresh scheduler would be (reuse == fresh); the trace
            // handle is a capability, not belief state, so it survives
            let policy = self.model.policy();
            let backend = self.backend.clone();
            let pages = std::mem::take(&mut self.initial_pages);
            let trace = self.trace.take();
            *self = Self::new(policy, &pages, backend);
            self.trace = trace;
        }
        debug_assert_eq!(m, self.model.len(), "page count changed between runs");
        self.tracker.reset(self.model.len());
        self.veto_tick.iter_mut().for_each(|v| *v = f64::NEG_INFINITY);
        self.last_veto_t = f64::NEG_INFINITY;
        self.last_values.iter_mut().for_each(|v| *v = 0.0);
        self.lambda_estimate = 0.0;
    }

    fn on_cis(&mut self, page: usize, _t: f64) {
        self.tracker.on_cis(page);
    }

    fn on_crawl(&mut self, page: usize, t: f64) {
        self.tracker.on_crawl(page, t);
    }

    fn on_veto(&mut self, page: usize, t: f64) {
        self.veto_tick[page] = t;
        self.last_veto_t = t;
        crate::trace::emit(self.trace.as_ref(), || crate::trace::TraceEvent::Veto {
            t,
            page: page as u32,
        });
    }

    fn on_page_added(&mut self, page: usize, params: &PageParams, t: f64) {
        self.note_world_mutation();
        if page == self.model.len() {
            // growth: one past the end
            self.model.push_page(params);
            self.live.push(true);
            self.veto_tick.push(f64::NEG_INFINITY);
            self.last_values.push(0.0);
            self.ub_safe.push(0.0); // filled by the next rebuild
        } else {
            // recycling: the slot must currently be dead
            debug_assert!(!self.live[page], "on_page_added into a live slot {page}");
            self.model.set_page(page, params);
            self.live[page] = true;
            self.dead -= 1;
            self.veto_tick[page] = f64::NEG_INFINITY;
            self.last_values[page] = 0.0;
        }
        self.tracker.add_page(page, t);
        self.dirty = true;
    }

    fn on_page_removed(&mut self, page: usize, _t: f64) {
        self.note_world_mutation();
        debug_assert!(self.live[page], "on_page_removed for a dead slot {page}");
        self.live[page] = false;
        self.dead += 1;
        self.tracker.remove_page(page);
        self.dirty = true;
    }

    fn on_params_changed(&mut self, page: usize, params: &PageParams, _t: f64) {
        self.note_world_mutation();
        self.model.set_page(page, params);
        self.dirty = true; // the page's μ̃/Δ bound (and sort slot) moved
    }

    fn select(&mut self, t: f64) -> Option<usize> {
        match &self.backend {
            ValueBackend::Native => self.select_native(t),
            ValueBackend::Pjrt { engine, terms } => {
                let engine = Arc::clone(engine);
                let terms = *terms;
                self.select_pjrt(&engine, terms, t)
            }
        }
    }

    fn attach_trace(&mut self, tr: crate::trace::TraceHandle) {
        self.trace = Some(tr);
    }

    fn name(&self) -> String {
        self.model.policy().name()
    }
}

/// Adapter: drives the precomputed LDS schedule as a [`CrawlScheduler`].
pub struct LdsAdapter {
    rates: Vec<f64>,
    inner: LdsScheduler,
}

impl LdsAdapter {
    /// From continuous per-page rates (the solver's output).
    pub fn new(rates: &[f64]) -> Self {
        Self { rates: rates.to_vec(), inner: LdsScheduler::new(rates) }
    }
}

impl CrawlScheduler for LdsAdapter {
    fn on_start(&mut self, _m: usize) {
        // restart the low-discrepancy sequence from its initial phase
        self.inner = LdsScheduler::new(&self.rates);
    }

    fn select(&mut self, _t: f64) -> Option<usize> {
        self.inner.next()
    }

    fn name(&self) -> String {
        "LDS".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngkit::Rng;
    use crate::sim::{generate_traces, simulate, CisDelay, SimConfig};

    fn pages(m: usize, seed: u64, with_cis: bool) -> Vec<PageParams> {
        let mut rng = Rng::new(seed);
        (0..m)
            .map(|_| PageParams {
                delta: rng.range(0.01, 1.0),
                mu: rng.range(0.01, 1.0),
                lam: if with_cis { crate::rngkit::beta(&mut rng, 0.25, 0.25) } else { 0.0 },
                nu: if with_cis { rng.range(0.1, 0.6) } else { 0.0 },
            })
            .collect()
    }

    #[test]
    fn greedy_crawls_every_tick() {
        let ps = pages(20, 1, false);
        let mut rng = Rng::new(2);
        let traces = generate_traces(&ps, 50.0, CisDelay::None, &mut rng);
        let cfg = SimConfig::new(5.0, 50.0).unwrap();
        let mut sched = GreedyScheduler::new(PolicyKind::Greedy, &ps, ValueBackend::Native);
        let res = simulate(&traces, &cfg, &mut sched);
        assert_eq!(res.crawl_counts.iter().map(|&c| c as u64).sum::<u64>(), res.ticks);
    }

    #[test]
    fn greedy_beats_random_pages_with_high_importance() {
        // the most important fast-changing page must be crawled most
        let ps = vec![
            PageParams { delta: 1.0, mu: 0.9, lam: 0.0, nu: 0.0 },
            PageParams { delta: 0.05, mu: 0.02, lam: 0.0, nu: 0.0 },
            PageParams { delta: 0.05, mu: 0.02, lam: 0.0, nu: 0.0 },
        ];
        let mut rng = Rng::new(3);
        let traces = generate_traces(&ps, 200.0, CisDelay::None, &mut rng);
        let cfg = SimConfig::new(2.0, 200.0).unwrap();
        let mut sched = GreedyScheduler::new(PolicyKind::Greedy, &ps, ValueBackend::Native);
        let res = simulate(&traces, &cfg, &mut sched);
        assert!(res.crawl_counts[0] > res.crawl_counts[1] * 2);
    }

    #[test]
    fn ncis_uses_signals_to_improve_accuracy() {
        // strongly-signalled environment: NCIS should beat GREEDY
        let mut rng = Rng::new(4);
        let ps: Vec<PageParams> = (0..50)
            .map(|_| PageParams {
                delta: rng.range(0.2, 1.0),
                mu: rng.range(0.1, 1.0),
                lam: 0.9,
                nu: 0.05,
            })
            .collect();
        let horizon = 300.0;
        let cfg = SimConfig::new(5.0, horizon).unwrap();
        let mut acc = [0.0f64; 2];
        for rep in 0..5 {
            let mut trng = Rng::new(100 + rep);
            let traces = generate_traces(&ps, horizon, CisDelay::None, &mut trng);
            let mut g = GreedyScheduler::new(PolicyKind::Greedy, &ps, ValueBackend::Native);
            let mut n = GreedyScheduler::new(PolicyKind::GreedyNcis, &ps, ValueBackend::Native);
            acc[0] += simulate(&traces, &cfg, &mut g).accuracy;
            acc[1] += simulate(&traces, &cfg, &mut n).accuracy;
        }
        assert!(
            acc[1] > acc[0],
            "NCIS {} should beat GREEDY {}",
            acc[1] / 5.0,
            acc[0] / 5.0
        );
    }

    #[test]
    fn veto_masks_page_for_the_current_tick_only() {
        let ps = pages(10, 7, true);
        let mut s = GreedyScheduler::new(PolicyKind::GreedyNcis, &ps, ValueBackend::Native);
        s.on_start(ps.len());
        let t = 2.0;
        let first = s.select(t).unwrap();
        s.on_veto(first, t);
        let second = s.select(t).unwrap();
        assert_ne!(first, second, "retry after veto re-picked the vetoed page");
        // the mask expires with the tick: immediately after (no crawl
        // happened, values essentially unchanged) the page is eligible
        // again and wins the argmax
        let next = s.select(t + 1e-6).unwrap();
        assert_eq!(next, first, "veto must not outlive its tick");
        // vetoing every page idles the tick instead of looping
        let t2 = 3.0;
        for k in 0..ps.len() {
            let p = s.select(t2).unwrap_or_else(|| panic!("pick {k} missing"));
            s.on_veto(p, t2);
        }
        assert_eq!(s.select(t2), None, "all pages vetoed: tick must idle");
    }

    #[test]
    fn batched_argmax_matches_scalar_reference_per_tick() {
        // drive both paths on identical state through a synthetic event
        // stream and compare every single pick (incl. veto retries)
        for kind in [
            PolicyKind::Greedy,
            PolicyKind::GreedyCis,
            PolicyKind::GreedyNcis,
            PolicyKind::NcisApprox(2),
            PolicyKind::GreedyCisPlus,
        ] {
            let ps = pages(150, 21, true);
            let mut fast = GreedyScheduler::new(kind, &ps, ValueBackend::Native);
            let mut slow = GreedyScheduler::new(kind, &ps, ValueBackend::Native);
            fast.on_start(ps.len());
            slow.on_start(ps.len());
            let mut rng = Rng::new(22);
            for step in 1..=400 {
                let t = step as f64 * 0.25;
                if rng.f64() < 0.4 {
                    let p = (rng.f64() * ps.len() as f64) as usize;
                    fast.on_cis(p, t);
                    slow.on_cis(p, t);
                }
                let a = fast.select(t);
                let b = slow.select_scalar_reference(t);
                assert_eq!(a, b, "{kind:?} step {step}: pick diverged");
                assert_eq!(
                    fast.lambda_estimate.to_bits(),
                    slow.lambda_estimate.to_bits(),
                    "{kind:?} step {step}: lambda diverged"
                );
                if let Some(i) = a {
                    if rng.f64() < 0.1 {
                        // politeness veto: both must re-pick identically
                        fast.on_veto(i, t);
                        slow.on_veto(i, t);
                        let a2 = fast.select(t);
                        let b2 = slow.select_scalar_reference(t);
                        assert_eq!(a2, b2, "{kind:?} step {step}: retry diverged");
                        if let Some(j) = a2 {
                            fast.on_crawl(j, t);
                            slow.on_crawl(j, t);
                        }
                    } else {
                        fast.on_crawl(i, t);
                        slow.on_crawl(i, t);
                    }
                }
            }
        }
    }

    #[test]
    fn dynamic_hooks_keep_batched_and_scalar_argmax_in_lockstep() {
        // drive births, retirements and drifts through both argmax
        // paths on identical state: picks must stay equal and retired
        // slots must never be selected by either
        let ps = pages(60, 31, true);
        let mut fast = GreedyScheduler::new(PolicyKind::GreedyNcis, &ps, ValueBackend::Native);
        let mut slow = GreedyScheduler::new(PolicyKind::GreedyNcis, &ps, ValueBackend::Native);
        fast.on_start(ps.len());
        slow.on_start(ps.len());
        let mut rng = Rng::new(32);
        let mut live: Vec<bool> = vec![true; ps.len()];
        let mut next_new = ps.len();
        for step in 1..=300 {
            let t = step as f64 * 0.2;
            match (rng.f64() * 10.0) as usize {
                0 => {
                    // retire a random live page
                    let candidates: Vec<usize> =
                        (0..live.len()).filter(|&i| live[i]).collect();
                    if candidates.len() > 1 {
                        let victim = candidates[(rng.f64() * candidates.len() as f64) as usize];
                        live[victim] = false;
                        fast.on_page_removed(victim, t);
                        slow.on_page_removed(victim, t);
                    }
                }
                1 => {
                    // birth: recycle a dead slot if any, else grow
                    let p = PageParams {
                        delta: rng.range(0.05, 1.0),
                        mu: rng.range(0.05, 1.0),
                        lam: rng.f64(),
                        nu: rng.range(0.1, 0.5),
                    };
                    let slot = (0..live.len()).find(|&i| !live[i]).unwrap_or_else(|| {
                        live.push(false);
                        next_new += 1;
                        next_new - 1
                    });
                    live[slot] = true;
                    fast.on_page_added(slot, &p, t);
                    slow.on_page_added(slot, &p, t);
                }
                2 => {
                    // drift a random live page
                    let candidates: Vec<usize> =
                        (0..live.len()).filter(|&i| live[i]).collect();
                    let page = candidates[(rng.f64() * candidates.len() as f64) as usize];
                    let p = PageParams {
                        delta: rng.range(0.05, 1.5),
                        mu: rng.range(0.05, 1.5),
                        lam: rng.f64(),
                        nu: rng.range(0.0, 0.5),
                    };
                    fast.on_params_changed(page, &p, t);
                    slow.on_params_changed(page, &p, t);
                }
                _ => {}
            }
            if rng.f64() < 0.4 {
                let candidates: Vec<usize> = (0..live.len()).filter(|&i| live[i]).collect();
                let p = candidates[(rng.f64() * candidates.len() as f64) as usize];
                fast.on_cis(p, t);
                slow.on_cis(p, t);
            }
            let a = fast.select(t);
            let b = slow.select_scalar_reference(t);
            assert_eq!(a, b, "step {step}: dynamic pick diverged");
            if let Some(i) = a {
                assert!(live[i], "step {step}: retired slot {i} was selected");
                fast.on_crawl(i, t);
                slow.on_crawl(i, t);
            }
        }
    }

    #[test]
    fn reuse_after_dynamic_run_equals_fresh() {
        // a scheduler that lived through churn must, after on_start,
        // behave exactly like a freshly built one
        let ps = pages(20, 33, true);
        let mut reused = GreedyScheduler::new(PolicyKind::GreedyNcis, &ps, ValueBackend::Native);
        reused.on_start(ps.len());
        // simulate a dynamic rep: retire, grow, drift
        reused.on_page_removed(3, 1.0);
        reused.on_page_added(3, &PageParams { delta: 0.9, mu: 0.9, lam: 0.2, nu: 0.1 }, 2.0);
        reused.on_page_added(20, &PageParams { delta: 0.4, mu: 0.8, lam: 0.6, nu: 0.2 }, 3.0);
        reused.on_params_changed(7, &PageParams { delta: 1.2, mu: 0.1, lam: 0.3, nu: 0.3 }, 4.0);
        let _ = reused.select(5.0);
        // next rep: the reused scheduler must match a fresh twin tick
        // for tick
        reused.on_start(ps.len());
        let mut fresh = GreedyScheduler::new(PolicyKind::GreedyNcis, &ps, ValueBackend::Native);
        fresh.on_start(ps.len());
        let mut rng = Rng::new(34);
        for step in 1..=120 {
            let t = step as f64 * 0.5;
            if rng.f64() < 0.5 {
                let p = (rng.f64() * ps.len() as f64) as usize;
                reused.on_cis(p, t);
                fresh.on_cis(p, t);
            }
            let a = reused.select(t);
            let b = fresh.select(t);
            assert_eq!(a, b, "step {step}: reused-after-dynamic diverged from fresh");
            assert_eq!(
                reused.lambda_estimate.to_bits(),
                fresh.lambda_estimate.to_bits(),
                "step {step}: lambda diverged"
            );
            if let Some(i) = a {
                reused.on_crawl(i, t);
                fresh.on_crawl(i, t);
            }
        }
    }

    #[test]
    fn lds_adapter_respects_rates() {
        let rates = [4.0, 1.0];
        let mut a = LdsAdapter::new(&rates);
        let mut counts = [0usize; 2];
        for j in 0..500 {
            let i = a.select(j as f64).unwrap();
            counts[i] += 1;
        }
        assert!((counts[0] as f64 - 400.0).abs() <= 2.0, "{counts:?}");
    }

    #[test]
    fn lds_adapter_restarts_on_start() {
        let rates = [3.0, 1.0];
        let mut a = LdsAdapter::new(&rates);
        let first: Vec<Option<usize>> = (0..20).map(|j| a.select(j as f64)).collect();
        a.on_start(2);
        let second: Vec<Option<usize>> = (0..20).map(|j| a.select(j as f64)).collect();
        assert_eq!(first, second);
    }

    #[test]
    fn lambda_estimate_converges_positive() {
        let ps = pages(30, 5, true);
        let mut rng = Rng::new(6);
        let traces = generate_traces(&ps, 100.0, CisDelay::None, &mut rng);
        let cfg = SimConfig::new(5.0, 100.0).unwrap();
        let mut sched = GreedyScheduler::new(PolicyKind::GreedyNcis, &ps, ValueBackend::Native);
        simulate(&traces, &cfg, &mut sched);
        assert!(sched.lambda_estimate > 0.0);
    }
}
