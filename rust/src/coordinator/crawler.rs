//! Exact discrete greedy crawler (Algorithm 1) and the LDS adapter.

use std::sync::Arc;

use crate::lds::LdsScheduler;
use crate::params::{DerivedParams, PageParams};
use crate::policy::PolicyKind;
use crate::runtime::{PjrtEngine, ValueBatch};
use crate::sim::engine::{PageState, Scheduler};

/// Where crawl values are computed.
pub enum ValueBackend {
    /// Pure-rust f64 evaluation (exact; per-page).
    Native,
    /// Batched f32 evaluation on the PJRT engine (the AOT Pallas kernel);
    /// `terms` selects the approximation-level artifact.
    Pjrt {
        /// Shared engine.
        engine: Arc<PjrtEngine>,
        /// Approximation level of the artifact to use.
        terms: u32,
    },
}

impl std::fmt::Debug for ValueBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ValueBackend::Native => write!(f, "Native"),
            ValueBackend::Pjrt { terms, .. } => write!(f, "Pjrt(terms={terms})"),
        }
    }
}

/// Project a policy's *beliefs* about the CIS process onto the general
/// NCIS parametrization the kernel evaluates (§5.1 special cases):
/// GREEDY believes there is no CIS process at all; GREEDY-CIS believes
/// signals are noiseless (β = ∞, α̂ = Δ − γ); NCIS variants use the true
/// derived parameters.
pub fn belief_params(policy: PolicyKind, raw: &PageParams, d: &DerivedParams) -> DerivedParams {
    match policy {
        PolicyKind::Greedy => DerivedParams {
            alpha: d.delta,
            beta: f64::INFINITY,
            gamma: 0.0,
            nu: 0.0,
            delta: d.delta,
            mu: d.mu,
        },
        PolicyKind::GreedyCis => DerivedParams {
            alpha: (d.delta - d.gamma).max(1e-6 * d.delta),
            beta: f64::INFINITY,
            gamma: d.gamma,
            nu: 0.0,
            delta: d.delta,
            mu: d.mu,
        },
        PolicyKind::GreedyCisPlus => {
            if raw.precision() > 0.7 && raw.recall() > 0.6 {
                belief_params(PolicyKind::GreedyCis, raw, d)
            } else {
                belief_params(PolicyKind::Greedy, raw, d)
            }
        }
        PolicyKind::GreedyNcis | PolicyKind::NcisApprox(_) => *d,
    }
}

/// Algorithm 1 with an exact argmax over all pages at every tick.
pub struct GreedyScheduler {
    policy: PolicyKind,
    raw: Vec<PageParams>,
    envs: Vec<DerivedParams>,
    /// Per-page belief projection (what the kernel is fed).
    beliefs: Vec<DerivedParams>,
    backend: ValueBackend,
    batch: ValueBatch,
    /// Crawl values computed at the last tick (exposed for rate plots).
    pub last_values: Vec<f64>,
    /// EMA of selected crawl values — the paper's estimate of the
    /// stationary threshold Λ (exposed for diagnostics / lazy parity).
    pub lambda_estimate: f64,
}

impl GreedyScheduler {
    /// Build from raw page parameters (importance should be normalized).
    pub fn new(policy: PolicyKind, pages: &[PageParams], backend: ValueBackend) -> Self {
        let envs: Vec<DerivedParams> = pages.iter().map(DerivedParams::from_raw).collect();
        let beliefs = pages
            .iter()
            .zip(&envs)
            .map(|(p, d)| belief_params(policy, p, d))
            .collect();
        Self {
            policy,
            raw: pages.to_vec(),
            envs,
            beliefs,
            backend,
            batch: ValueBatch::with_capacity(pages.len()),
            last_values: vec![0.0; pages.len()],
            lambda_estimate: 0.0,
        }
    }

    fn select_native(&mut self, t: f64, states: &[PageState]) -> Option<usize> {
        let mut best = f64::NEG_INFINITY;
        let mut arg = None;
        for (i, (d, p)) in self.envs.iter().zip(&self.raw).enumerate() {
            let v = self.policy.crawl_value(p, d, states[i].tau_elap(t), states[i].n_cis);
            self.last_values[i] = v;
            if v > best {
                best = v;
                arg = Some(i);
            }
        }
        if let Some(i) = arg {
            self.update_lambda(self.last_values[i]);
        }
        arg
    }

    fn select_pjrt(&mut self, engine: &PjrtEngine, terms: u32, t: f64, states: &[PageState]) -> Option<usize> {
        self.batch.clear();
        for (i, b) in self.beliefs.iter().enumerate() {
            // effective time under the policy's OWN beliefs: a pending
            // CIS saturates a noiseless-belief page (β̂ = ∞ → capped)
            let iota = b.effective_time(states[i].tau_elap(t), states[i].n_cis);
            self.batch.push(iota, b);
        }
        let (values, idx, best) = engine
            .crawl_values_argmax(terms, &self.batch)
            .expect("pjrt crawl value execution failed");
        for (dst, &v) in self.last_values.iter_mut().zip(&values) {
            *dst = v as f64;
        }
        self.update_lambda(best as f64);
        Some(idx)
    }

    fn update_lambda(&mut self, selected: f64) {
        const A: f64 = 0.05;
        self.lambda_estimate = if self.lambda_estimate == 0.0 {
            selected
        } else {
            (1.0 - A) * self.lambda_estimate + A * selected
        };
    }
}

impl Scheduler for GreedyScheduler {
    fn select(&mut self, t: f64, states: &[PageState]) -> Option<usize> {
        match &self.backend {
            ValueBackend::Native => self.select_native(t, states),
            ValueBackend::Pjrt { engine, terms } => {
                let engine = Arc::clone(engine);
                let terms = *terms;
                self.select_pjrt(&engine, terms, t, states)
            }
        }
    }

    fn name(&self) -> String {
        self.policy.name()
    }
}

/// Adapter: drives the precomputed LDS schedule as a [`Scheduler`].
pub struct LdsAdapter {
    inner: LdsScheduler,
}

impl LdsAdapter {
    /// From continuous per-page rates (the solver's output).
    pub fn new(rates: &[f64]) -> Self {
        Self { inner: LdsScheduler::new(rates) }
    }
}

impl Scheduler for LdsAdapter {
    fn select(&mut self, _t: f64, _states: &[PageState]) -> Option<usize> {
        self.inner.next()
    }

    fn name(&self) -> String {
        "LDS".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngkit::Rng;
    use crate::sim::{generate_traces, simulate, CisDelay, SimConfig};

    fn pages(m: usize, seed: u64, with_cis: bool) -> Vec<PageParams> {
        let mut rng = Rng::new(seed);
        (0..m)
            .map(|_| PageParams {
                delta: rng.range(0.01, 1.0),
                mu: rng.range(0.01, 1.0),
                lam: if with_cis { crate::rngkit::beta(&mut rng, 0.25, 0.25) } else { 0.0 },
                nu: if with_cis { rng.range(0.1, 0.6) } else { 0.0 },
            })
            .collect()
    }

    #[test]
    fn greedy_crawls_every_tick() {
        let ps = pages(20, 1, false);
        let mut rng = Rng::new(2);
        let traces = generate_traces(&ps, 50.0, CisDelay::None, &mut rng);
        let cfg = SimConfig::new(5.0, 50.0);
        let mut sched = GreedyScheduler::new(PolicyKind::Greedy, &ps, ValueBackend::Native);
        let res = simulate(&traces, &cfg, &mut sched);
        assert_eq!(res.crawl_counts.iter().map(|&c| c as u64).sum::<u64>(), res.ticks);
    }

    #[test]
    fn greedy_beats_random_pages_with_high_importance() {
        // the most important fast-changing page must be crawled most
        let ps = vec![
            PageParams { delta: 1.0, mu: 0.9, lam: 0.0, nu: 0.0 },
            PageParams { delta: 0.05, mu: 0.02, lam: 0.0, nu: 0.0 },
            PageParams { delta: 0.05, mu: 0.02, lam: 0.0, nu: 0.0 },
        ];
        let mut rng = Rng::new(3);
        let traces = generate_traces(&ps, 200.0, CisDelay::None, &mut rng);
        let cfg = SimConfig::new(2.0, 200.0);
        let mut sched = GreedyScheduler::new(PolicyKind::Greedy, &ps, ValueBackend::Native);
        let res = simulate(&traces, &cfg, &mut sched);
        assert!(res.crawl_counts[0] > res.crawl_counts[1] * 2);
    }

    #[test]
    fn ncis_uses_signals_to_improve_accuracy() {
        // strongly-signalled environment: NCIS should beat GREEDY
        let mut rng = Rng::new(4);
        let ps: Vec<PageParams> = (0..50)
            .map(|_| PageParams {
                delta: rng.range(0.2, 1.0),
                mu: rng.range(0.1, 1.0),
                lam: 0.9,
                nu: 0.05,
            })
            .collect();
        let horizon = 300.0;
        let cfg = SimConfig::new(5.0, horizon);
        let mut acc = [0.0f64; 2];
        for rep in 0..5 {
            let mut trng = Rng::new(100 + rep);
            let traces = generate_traces(&ps, horizon, CisDelay::None, &mut trng);
            let mut g = GreedyScheduler::new(PolicyKind::Greedy, &ps, ValueBackend::Native);
            let mut n = GreedyScheduler::new(PolicyKind::GreedyNcis, &ps, ValueBackend::Native);
            acc[0] += simulate(&traces, &cfg, &mut g).accuracy;
            acc[1] += simulate(&traces, &cfg, &mut n).accuracy;
        }
        assert!(
            acc[1] > acc[0],
            "NCIS {} should beat GREEDY {}",
            acc[1] / 5.0,
            acc[0] / 5.0
        );
    }

    #[test]
    fn lds_adapter_respects_rates() {
        let rates = [4.0, 1.0];
        let mut a = LdsAdapter::new(&rates);
        let mut counts = [0usize; 2];
        for j in 0..500 {
            let i = a.select(j as f64, &[]).unwrap();
            counts[i] += 1;
        }
        assert!((counts[0] as f64 - 400.0).abs() <= 2.0, "{counts:?}");
    }

    #[test]
    fn lambda_estimate_converges_positive() {
        let ps = pages(30, 5, true);
        let mut rng = Rng::new(6);
        let traces = generate_traces(&ps, 100.0, CisDelay::None, &mut rng);
        let cfg = SimConfig::new(5.0, 100.0);
        let mut sched = GreedyScheduler::new(PolicyKind::GreedyNcis, &ps, ValueBackend::Native);
        simulate(&traces, &cfg, &mut sched);
        assert!(sched.lambda_estimate > 0.0);
    }
}
