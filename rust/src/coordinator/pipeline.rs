//! Threaded streaming orchestrator — the deployable shape of the system.
//!
//! A production crawler is a pipeline, not a batch simulation: CIS and
//! request events *stream in*, shard workers keep their scheduler state
//! warm, and a ticker thread asks each shard for its next crawl. This
//! module wires that topology with `std::sync::mpsc` bounded channels
//! (backpressure: a slow shard throttles ingestion rather than dropping
//! signals), and reports shard-level throughput metrics.
//!
//! Shard workers drive any `Box<dyn CrawlScheduler + Send>`; per-shard
//! schedulers are stamped from a [`CrawlerBuilder`] template, so every
//! strategy × backend combination (lazy native, exact PJRT, …) can run
//! the streaming topology — nothing is hard-coded to one scheduler.
//!
//! Used by the `serve-shards` CLI command and the Appendix-G scale bench.
//!
//! Limitation: shard workers carry no ground-truth freshness state (the
//! world lives in the driver's event sources), so the pipeline never
//! fires [`CrawlScheduler::on_fetch_observed`] — a
//! [`crate::Knowledge::Learned`] scheduler runs here but stays on its
//! uninformative priors. Learned-mode evaluation uses the simulation
//! engines (`sim`, `scenario`, `fault`), which all fire the hook.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::Arc;

use crate::coordinator::builder::CrawlerBuilder;
use crate::params::PageParams;
use crate::rngkit::Rng;
use crate::sched::{CrawlScheduler, IdleScheduler};
use crate::serving::{RequestTraffic, ServingMetrics, ServingSession};
use crate::sim::engine::{SimConfig, SimResult, SimWorkspace, KIND_CIS};
use crate::sim::{simulate_streamed_traced_with, CisDelay, PageEventSource, StreamedSource};
use crate::trace::TraceHandle;
use crate::util::OrdF64;

/// A message into a shard worker.
#[derive(Debug, Clone, Copy)]
pub enum ShardMsg {
    /// CIS delivery for local page index at time t.
    Cis {
        /// Local page index within the shard.
        page: usize,
        /// Delivery time.
        t: f64,
    },
    /// Tick: crawl one page at time t.
    Tick {
        /// Tick time.
        t: f64,
    },
    /// A page was born into this shard (local slot `page`).
    Born {
        /// Local page index within the shard (== current shard size
        /// for growth).
        page: usize,
        /// Raw parameters of the newborn.
        params: PageParams,
        /// Birth time.
        t: f64,
    },
    /// Local page `page` was retired.
    Retired {
        /// Local page index within the shard.
        page: usize,
        /// Retirement time.
        t: f64,
    },
    /// Local page `page` drifted to new parameters.
    Params {
        /// Local page index within the shard.
        page: usize,
        /// The new raw parameters.
        params: PageParams,
        /// Shift time.
        t: f64,
    },
    /// Drain and stop.
    Shutdown,
}

/// A dynamic-world event for the streaming pipeline, named by *global*
/// page index. Births append to the global population (the pipeline
/// does not recycle indices — the scenario engine does; here a new
/// page is simply the next index) and route to shard
/// `index % shards`, consistent with the round-robin plan and
/// [`crate::coordinator::shard::ShardedScheduler`].
#[derive(Debug, Clone, Copy)]
pub enum WorldMsg {
    /// A new page joins the crawl frontier.
    PageBorn {
        /// Raw parameters of the newborn.
        params: PageParams,
    },
    /// Global page `page` is retired.
    PageRetired {
        /// Global page index.
        page: usize,
    },
    /// Global page `page` drifted.
    ParamsChanged {
        /// Global page index.
        page: usize,
        /// The new raw parameters.
        params: PageParams,
    },
}

/// Counters shared with the driver.
#[derive(Debug, Default)]
pub struct PipelineMetrics {
    /// Crawls executed.
    pub crawls: AtomicU64,
    /// CIS messages applied.
    pub cis_applied: AtomicU64,
    /// World (lifecycle) messages applied by shard workers.
    pub world_applied: AtomicU64,
    /// Ingestion stalls caused by a full shard queue (backpressure).
    pub backpressure_stalls: AtomicU64,
    /// Messages dropped because a shard's receiver was gone (the worker
    /// died mid-run). Nonzero only in degraded runs — the multiplexer
    /// keeps the surviving shards fed instead of hanging.
    pub channel_drops: AtomicU64,
}

/// One shard worker: owns its event-driven scheduler, consumes its queue.
fn shard_worker(
    rx: Receiver<ShardMsg>,
    mut scheduler: Box<dyn CrawlScheduler + Send>,
    m: usize,
    metrics: Arc<PipelineMetrics>,
) -> Vec<u32> {
    scheduler.on_start(m);
    let mut crawl_counts = vec![0u32; m];
    while let Ok(msg) = rx.recv() {
        match msg {
            ShardMsg::Cis { page, t } => {
                scheduler.on_cis(page, t);
                metrics.cis_applied.fetch_add(1, Ordering::Relaxed);
            }
            ShardMsg::Tick { t } => {
                if let Some(i) = scheduler.select(t) {
                    crawl_counts[i] += 1;
                    scheduler.on_crawl(i, t);
                    metrics.crawls.fetch_add(1, Ordering::Relaxed);
                }
            }
            ShardMsg::Born { page, params, t } => {
                if page == crawl_counts.len() {
                    crawl_counts.push(0);
                }
                scheduler.on_page_added(page, &params, t);
                metrics.world_applied.fetch_add(1, Ordering::Relaxed);
            }
            ShardMsg::Retired { page, t } => {
                scheduler.on_page_removed(page, t);
                metrics.world_applied.fetch_add(1, Ordering::Relaxed);
            }
            ShardMsg::Params { page, params, t } => {
                scheduler.on_params_changed(page, &params, t);
                metrics.world_applied.fetch_add(1, Ordering::Relaxed);
            }
            ShardMsg::Shutdown => break,
        }
    }
    crawl_counts
}

/// Blocking send with backpressure accounting. A disconnected receiver
/// (its worker died) drops the message — counted in
/// [`PipelineMetrics::channel_drops`] so degraded runs are visible —
/// rather than hanging the multiplexer; the dead worker itself surfaces
/// as [`crate::Error::WorkerFailed`] at join time.
fn send_backpressured(
    tx: &SyncSender<ShardMsg>,
    msg: ShardMsg,
    metrics: &PipelineMetrics,
) {
    let mut m = msg;
    loop {
        match tx.try_send(m) {
            Ok(()) => return,
            Err(TrySendError::Full(back)) => {
                metrics.backpressure_stalls.fetch_add(1, Ordering::Relaxed);
                m = back;
                std::thread::yield_now();
            }
            Err(TrySendError::Disconnected(_)) => {
                metrics.channel_drops.fetch_add(1, Ordering::Relaxed);
                return;
            }
        }
    }
}

/// Lazy CIS supply for the streaming pipeline: one [`PageEventSource`]
/// per page, restricted to its CIS channel (changes are consumed
/// internally to drive signalled deliveries, and the request process
/// is built with μ = 0 — the pipeline has no freshness accounting, so
/// only deliveries leave the feed), merged through a small binary heap.
/// `O(m)` state instead of a pre-drawn `O(total events)` vector, and
/// the deliveries come from the *generative* model (per-change
/// Bernoulli(λ) signals + Poisson(ν) false positives + delivery
/// delays), not a collapsed hazard-rate approximation.
///
/// Iterate it (`Iterator<Item = (time, page)>`) — deliveries arrive in
/// global time order.
#[derive(Debug)]
pub struct CisFeed {
    sources: Vec<PageEventSource>,
    heap: BinaryHeap<Reverse<(OrdF64, u32)>>,
    horizon: f64,
    delay: CisDelay,
}

/// Advance `s` past non-CIS events to its next CIS delivery, if any.
fn next_cis_of(s: &mut PageEventSource, horizon: f64, delay: CisDelay) -> Option<f64> {
    loop {
        let (t, k) = s.next(horizon, delay)?;
        if k == KIND_CIS {
            return Some(t);
        }
        s.consume(k, horizon, delay);
    }
}

impl CisFeed {
    /// Build the per-page sources over `[0, horizon)` (same per-page
    /// master keying as `generate_traces` / `StreamedSource`).
    pub fn new(
        pages: &[PageParams],
        horizon: f64,
        delay: CisDelay,
        rng: &mut Rng,
    ) -> crate::Result<Self> {
        delay.validate()?;
        let mut sources: Vec<PageEventSource> = pages
            .iter()
            .enumerate()
            .map(|(i, p)| {
                let mut prng = rng.split(i as u64);
                // μ = 0: requests ride their own substream, so turning
                // them off leaves the change/CIS realization
                // bit-identical while skipping ~m·T·μ wasted draws the
                // feed would only discard
                let cis_only = PageParams { mu: 0.0, ..*p };
                PageEventSource::new(&cis_only, 0.0, horizon, delay, &mut prng)
            })
            .collect();
        let mut heap = BinaryHeap::with_capacity(sources.len());
        for (i, s) in sources.iter_mut().enumerate() {
            if let Some(t) = next_cis_of(s, horizon, delay) {
                heap.push(Reverse((OrdF64(t), i as u32)));
            }
        }
        Ok(Self { sources, heap, horizon, delay })
    }
}

impl Iterator for CisFeed {
    type Item = (f64, usize);

    fn next(&mut self) -> Option<(f64, usize)> {
        let Reverse((OrdF64(t), page)) = self.heap.pop()?;
        let s = &mut self.sources[page as usize];
        s.consume(KIND_CIS, self.horizon, self.delay);
        if let Some(nt) = next_cis_of(s, self.horizon, self.delay) {
            self.heap.push(Reverse((OrdF64(nt), page)));
        }
        Some((t, page as usize))
    }
}

/// Configuration of a streaming run.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    /// Number of shard workers.
    pub shards: usize,
    /// Bounded queue depth per shard (backpressure horizon).
    pub queue_depth: usize,
    /// Global bandwidth R (ticks/sec of simulated time).
    pub bandwidth: f64,
    /// Simulated horizon.
    pub horizon: f64,
}

/// Outcome of a streaming run.
#[derive(Debug)]
pub struct PipelineReport {
    /// Crawls per shard.
    pub crawls_per_shard: Vec<u64>,
    /// Total crawls.
    pub total_crawls: u64,
    /// CIS applied.
    pub cis_applied: u64,
    /// World (lifecycle) events applied by shard workers.
    pub world_applied: u64,
    /// Backpressure stalls observed.
    pub backpressure_stalls: u64,
    /// Messages dropped on dead shard channels (degraded runs only).
    pub channel_drops: u64,
    /// Wall-clock duration of the run.
    pub wall: std::time::Duration,
}

/// Drive a full streaming run: pages are round-robin sharded, a CIS
/// stream (precomputed event times) and the tick clock are multiplexed
/// into per-shard bounded queues in simulated-time order. Each shard's
/// scheduler is stamped from the `scheduler` builder template (its
/// `pages(..)` are overridden with the shard's members); an invalid
/// template surfaces as `Err` before any worker thread spawns.
pub fn run_pipeline(
    pages: &[PageParams],
    scheduler: &CrawlerBuilder,
    cis_events: &[(f64, usize)], // (time, global page), sorted by time
    cfg: &PipelineConfig,
) -> crate::Result<PipelineReport> {
    run_pipeline_with_world(pages, scheduler, cis_events, &[], cfg)
}

/// [`run_pipeline`] over a dynamic world: `world_events` (sorted by
/// time, global page indices) are multiplexed into the shard queues in
/// simulated-time order — before CIS and ticks at the same instant —
/// and routed consistently: a birth takes the next global index and
/// lands on shard `index % shards` (the round-robin plan extended),
/// retirements/drifts follow the page's existing shard. Limitations,
/// by design of the streaming topology: global indices are never
/// recycled here (that is the scenario engine's job), and a shard that
/// starts empty (`shards > pages`) runs an [`IdleScheduler`] and stays
/// idle even if births later route to it.
pub fn run_pipeline_with_world(
    pages: &[PageParams],
    scheduler: &CrawlerBuilder,
    cis_events: &[(f64, usize)], // (time, global page), sorted by time
    world_events: &[(f64, WorldMsg)], // sorted by time
    cfg: &PipelineConfig,
) -> crate::Result<PipelineReport> {
    run_pipeline_events(pages, scheduler, cis_events.iter().copied(), world_events, cfg)
}

/// [`run_pipeline_with_world`] fed by a lazy [`CisFeed`] instead of a
/// pre-drawn event vector: the multiplexer pulls each CIS delivery on
/// demand, so a serve run holds `O(m)` state however long the horizon.
pub fn run_pipeline_streamed(
    pages: &[PageParams],
    scheduler: &CrawlerBuilder,
    feed: CisFeed,
    world_events: &[(f64, WorldMsg)], // sorted by time
    cfg: &PipelineConfig,
) -> crate::Result<PipelineReport> {
    run_pipeline_events(pages, scheduler, feed, world_events, cfg)
}

/// Shared driver: the multiplexer consumes any time-sorted CIS
/// iterator (a materialized slice or the lazy feed).
fn run_pipeline_events<I: Iterator<Item = (f64, usize)>>(
    pages: &[PageParams],
    scheduler: &CrawlerBuilder,
    cis_events: I,
    world_events: &[(f64, WorldMsg)], // sorted by time
    cfg: &PipelineConfig,
) -> crate::Result<PipelineReport> {
    if cfg.shards == 0 {
        return Err(crate::Error::Usage(
            "run_pipeline: at least one shard required".into(),
        ));
    }
    let plan = crate::coordinator::shard::ShardPlan::round_robin(pages.len(), cfg.shards);
    let members = plan.shard_members();
    // stamp every shard scheduler up front: template errors return Err
    // here, before any thread exists; shards > pages leaves some shards
    // empty and they idle their ticks away instead of failing validation.
    // shard_template remaps pages AND (for Lds templates) global rates
    // to shard-local indices, so workers always see local picks. A
    // trace handle on the template is re-pointed at the worker's own
    // ring (`h.shard(s)`) so concurrent shards never interleave events
    // and the drain stays deterministic in shard-index order.
    let mut scheds: Vec<Box<dyn CrawlScheduler + Send>> = Vec::with_capacity(cfg.shards);
    for (s, member) in members.iter().enumerate() {
        scheds.push(if member.is_empty() {
            Box::new(IdleScheduler)
        } else {
            let mut tpl = scheduler.shard_template(pages, member);
            if let Some(h) = scheduler.trace_handle() {
                tpl = tpl.with_trace(h.shard(s));
            }
            tpl.build()?
        });
    }
    run_pipeline_with_schedulers(pages, scheds, cis_events, world_events, cfg)
}

/// The topology with caller-built shard schedulers — one
/// `Box<dyn CrawlScheduler + Send>` per shard, pages round-robin
/// sharded as everywhere else. This is the injection point for
/// resilience tests (and custom decorators the builder doesn't know):
/// a worker whose scheduler panics is caught at join time and surfaced
/// as [`crate::Error::WorkerFailed`] carrying the panic payloads plus
/// the *salvaged* per-shard crawl totals of the surviving shards — the
/// process never aborts and sibling work is never discarded.
pub fn run_pipeline_with_schedulers<I: Iterator<Item = (f64, usize)>>(
    pages: &[PageParams],
    scheds: Vec<Box<dyn CrawlScheduler + Send>>,
    cis_events: I,
    world_events: &[(f64, WorldMsg)], // sorted by time
    cfg: &PipelineConfig,
) -> crate::Result<PipelineReport> {
    if cfg.shards == 0 {
        return Err(crate::Error::Usage(
            "run_pipeline: at least one shard required".into(),
        ));
    }
    if scheds.len() != cfg.shards {
        return Err(crate::Error::Usage(format!(
            "run_pipeline: {} schedulers for {} shards",
            scheds.len(),
            cfg.shards
        )));
    }
    let metrics = Arc::new(PipelineMetrics::default());
    let plan = crate::coordinator::shard::ShardPlan::round_robin(pages.len(), cfg.shards);
    let members = plan.shard_members();
    // page → shard and local-slot maps; mutable because births extend
    // them mid-run
    let mut assignment = plan.assignment.clone();
    let mut member_count: Vec<usize> = members.iter().map(|m| m.len()).collect();
    let mut local_index = vec![0usize; pages.len()];
    for member in &members {
        for (li, &gi) in member.iter().enumerate() {
            local_index[gi] = li;
        }
    }
    let start = std::time::Instant::now();
    let mut crawls_per_shard = vec![0u64; cfg.shards];
    let failed: Vec<(usize, String)> = std::thread::scope(|scope| {
        let mut senders: Vec<SyncSender<ShardMsg>> = Vec::with_capacity(cfg.shards);
        let mut handles = Vec::with_capacity(cfg.shards);
        for (member, sched) in members.iter().zip(scheds) {
            let (tx, rx) = sync_channel::<ShardMsg>(cfg.queue_depth);
            senders.push(tx);
            let mcount = member.len();
            let metrics = Arc::clone(&metrics);
            handles.push(scope.spawn(move || shard_worker(rx, sched, mcount, metrics)));
        }
        // multiplex: ticks round-robin across shards at global rate R
        // (integer tick index — accumulating f64 drifts past the
        // horizon); world events take precedence over CIS and ticks at
        // the same instant so lifecycle state is in place before the
        // events that depend on it
        let tick_dt = 1.0 / cfg.bandwidth;
        let total_ticks = (cfg.horizon * cfg.bandwidth).round() as u64;
        let mut tick_idx = 1u64;
        let mut tick_shard = 0usize;
        let mut cis = cis_events.peekable();
        let mut wev = 0usize;
        while tick_idx <= total_ticks || cis.peek().is_some() || wev < world_events.len() {
            let next_tick =
                if tick_idx <= total_ticks { tick_idx as f64 * tick_dt } else { f64::INFINITY };
            let next_cis = cis.peek().map(|e| e.0).unwrap_or(f64::INFINITY);
            let next_world = world_events.get(wev).map(|e| e.0).unwrap_or(f64::INFINITY);
            if wev < world_events.len() && next_world <= next_cis && next_world <= next_tick {
                let (t, msg) = world_events[wev];
                if t <= cfg.horizon {
                    match msg {
                        WorldMsg::PageBorn { params } => {
                            let g = assignment.len();
                            let s = g % cfg.shards;
                            assignment.push(s);
                            let local = member_count[s];
                            member_count[s] += 1;
                            local_index.push(local);
                            send_backpressured(
                                &senders[s],
                                ShardMsg::Born { page: local, params, t },
                                &metrics,
                            );
                        }
                        WorldMsg::PageRetired { page } if page < assignment.len() => {
                            let s = assignment[page];
                            send_backpressured(
                                &senders[s],
                                ShardMsg::Retired { page: local_index[page], t },
                                &metrics,
                            );
                        }
                        WorldMsg::ParamsChanged { page, params } if page < assignment.len() => {
                            let s = assignment[page];
                            send_backpressured(
                                &senders[s],
                                ShardMsg::Params { page: local_index[page], params, t },
                                &metrics,
                            );
                        }
                        // out-of-range page: a script bug, dropped
                        WorldMsg::PageRetired { .. } | WorldMsg::ParamsChanged { .. } => {}
                    }
                }
                wev += 1;
            } else if next_cis.is_finite() && next_cis <= next_tick {
                if let Some((t, gpage)) = cis.next() {
                    if t <= cfg.horizon && gpage < assignment.len() {
                        let s = assignment[gpage];
                        send_backpressured(
                            &senders[s],
                            ShardMsg::Cis { page: local_index[gpage], t },
                            &metrics,
                        );
                    }
                }
            } else {
                if tick_idx > total_ticks {
                    break;
                }
                send_backpressured(&senders[tick_shard], ShardMsg::Tick { t: next_tick }, &metrics);
                tick_shard = (tick_shard + 1) % cfg.shards;
                tick_idx += 1;
            }
        }
        for tx in &senders {
            let _ = tx.send(ShardMsg::Shutdown);
        }
        drop(senders);
        // graceful degradation: a panicked worker is recorded (payload
        // stringified), its siblings' counts are salvaged — never abort
        let mut failed: Vec<(usize, String)> = Vec::new();
        for (s, h) in handles.into_iter().enumerate() {
            match h.join() {
                Ok(counts) => crawls_per_shard[s] = counts.iter().map(|&c| c as u64).sum(),
                Err(payload) => {
                    let msg = payload
                        .downcast_ref::<&str>()
                        .map(|m| (*m).to_string())
                        .or_else(|| payload.downcast_ref::<String>().cloned())
                        .unwrap_or_else(|| "non-string panic payload".into());
                    failed.push((s, msg));
                }
            }
        }
        failed
    });
    if !failed.is_empty() {
        return Err(crate::Error::WorkerFailed { failed, crawls_per_shard });
    }
    Ok(PipelineReport {
        total_crawls: crawls_per_shard.iter().sum(),
        crawls_per_shard,
        cis_applied: metrics.cis_applied.load(Ordering::Relaxed),
        world_applied: metrics.world_applied.load(Ordering::Relaxed),
        backpressure_stalls: metrics.backpressure_stalls.load(Ordering::Relaxed),
        channel_drops: metrics.channel_drops.load(Ordering::Relaxed),
        wall: start.elapsed(),
    })
}

/// Outcome of a sharded serving run: crawl-side counters plus the
/// deterministic cross-shard reduction of the per-shard
/// [`ServingMetrics`].
#[derive(Debug)]
pub struct ServingPipelineReport {
    /// Crawls per shard.
    pub crawls_per_shard: Vec<u64>,
    /// Total crawls.
    pub total_crawls: u64,
    /// Trace-side requests replayed (freshness accounting).
    pub requests: u64,
    /// Trace-side requests that were fresh.
    pub fresh_hits: u64,
    /// Merged serving metrics (merged in shard-index order, so two
    /// runs with the same inputs produce bit-identical sums).
    pub metrics: ServingMetrics,
    /// Wall-clock duration of the run.
    pub wall: std::time::Duration,
}

/// Sharded serving fan-out: pages are round-robin sharded exactly as
/// [`run_pipeline`], each shard runs the *served* streamed engine over
/// its members at `bandwidth / shards` with its own slice of the user
/// traffic, and the per-shard [`ServingMetrics`] reduce in shard-index
/// order (the log-bucket counts are `u64` and order-free; the stale-age
/// sums are `f64`, so a fixed reduction order keeps two same-input runs
/// bit-identical).
///
/// The traffic split mirrors the page split: each shard's base rate is
/// the global rate scaled by its member fraction, its Zipf law runs
/// over shard-local popularity ranks (round-robin members are in
/// ascending global rank, so local rank order matches global), its
/// seed is derived from the global traffic seed and the shard index,
/// and a flash crowd rides with the shard that owns its target page.
/// Stamping errors (invalid template, bad traffic) surface as `Err`
/// before any worker thread spawns.
pub fn run_serving_pipeline(
    pages: &[PageParams],
    scheduler: &CrawlerBuilder,
    traffic: &RequestTraffic,
    cfg: &PipelineConfig,
    trace_seed: u64,
) -> crate::Result<ServingPipelineReport> {
    if cfg.shards == 0 {
        return Err(crate::Error::Usage(
            "run_serving_pipeline: at least one shard required".into(),
        ));
    }
    let plan = crate::coordinator::shard::ShardPlan::round_robin(pages.len(), cfg.shards);
    let members = plan.shard_members();
    let m = pages.len().max(1);
    // stamp every shard's scheduler, traffic slice and serving session
    // up front: misconfiguration is an Err here, not a panic inside
    // thread::scope; empty shards (shards > pages) simply sit out
    type Job =
        (Vec<PageParams>, Box<dyn CrawlScheduler + Send>, ServingSession, Option<TraceHandle>);
    let mut jobs: Vec<Option<Job>> = Vec::with_capacity(cfg.shards);
    for (s, member) in members.iter().enumerate() {
        if member.is_empty() {
            jobs.push(None);
            continue;
        }
        let shard_pages: Vec<PageParams> = member.iter().map(|&i| pages[i]).collect();
        // per-shard trace handle: each worker records into its own ring
        let tr = scheduler.trace_handle().map(|h| h.shard(s));
        let mut tpl = scheduler.shard_template(pages, member);
        if let Some(h) = &tr {
            tpl = tpl.with_trace(h.clone());
        }
        let sched = tpl.build()?;
        let frac = shard_pages.len() as f64 / m as f64;
        let mut shard_traffic = RequestTraffic::new(
            traffic.rate() * frac,
            traffic.zipf_s(),
            traffic.seed() ^ 0x9E37_79B9_7F4A_7C15u64.wrapping_mul(s as u64 + 1),
        )?;
        for f in traffic.flashes() {
            if let Some(local) = member.iter().position(|&g| g == f.page) {
                shard_traffic =
                    shard_traffic.with_flash(f.t0, f.duration, local, f.extra_rate)?;
            }
        }
        let session = ServingSession::new(&shard_traffic, &shard_pages, cfg.horizon);
        jobs.push(Some((shard_pages, sched, session, tr)));
    }
    let sim_cfg = SimConfig::new(cfg.bandwidth / cfg.shards as f64, cfg.horizon)?;
    let start = std::time::Instant::now();
    let results: Vec<Option<(SimResult, ServingMetrics)>> = std::thread::scope(|scope| {
        let sim_cfg = &sim_cfg;
        let handles: Vec<_> = jobs
            .into_iter()
            .enumerate()
            .map(|(s, job)| {
                scope.spawn(move || {
                    job.map(|(shard_pages, mut sched, mut session, tr)| {
                        let mut rng = Rng::new(trace_seed).split(s as u64);
                        let source = StreamedSource::new(
                            &shard_pages,
                            sim_cfg.horizon,
                            CisDelay::None,
                            &mut rng,
                        )
                        .expect("CisDelay::None always validates");
                        let mut ws = SimWorkspace::new();
                        let res = simulate_streamed_traced_with(
                            &mut ws,
                            source,
                            sim_cfg,
                            sched.as_mut(),
                            Some(&mut session),
                            tr.as_ref(),
                        );
                        (res, session.into_metrics())
                    })
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("serving shard worker panicked"))
            .collect()
    });
    // deterministic reduction: shard-index order, always
    let mut metrics = ServingMetrics::new();
    let mut crawls_per_shard = vec![0u64; cfg.shards];
    let mut requests = 0u64;
    let mut fresh_hits = 0u64;
    for (s, r) in results.into_iter().enumerate() {
        if let Some((res, shard_metrics)) = r {
            crawls_per_shard[s] = res.crawl_counts.iter().map(|&c| c as u64).sum();
            requests += res.requests;
            fresh_hits += res.fresh_hits;
            metrics.merge(&shard_metrics);
        }
    }
    Ok(ServingPipelineReport {
        total_crawls: crawls_per_shard.iter().sum(),
        crawls_per_shard,
        requests,
        fresh_hits,
        metrics,
        wall: start.elapsed(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::builder::Strategy;
    use crate::policy::PolicyKind;
    use crate::rngkit::Rng;

    fn pages(m: usize) -> Vec<PageParams> {
        let mut rng = Rng::new(1);
        (0..m)
            .map(|_| PageParams {
                delta: rng.range(0.05, 1.0),
                mu: rng.range(0.05, 1.0),
                lam: 0.5,
                nu: 0.2,
            })
            .collect()
    }

    fn lazy_ncis() -> CrawlerBuilder {
        CrawlerBuilder::new().policy(PolicyKind::GreedyNcis).strategy(Strategy::Lazy)
    }

    #[test]
    fn pipeline_executes_all_ticks() {
        let ps = pages(64);
        let cfg = PipelineConfig { shards: 4, queue_depth: 16, bandwidth: 20.0, horizon: 50.0 };
        let report = run_pipeline(&ps, &lazy_ncis(), &[], &cfg).unwrap();
        // 20 ticks/sec * 50s = 1000 ticks total
        assert_eq!(report.total_crawls, 1000);
        // round-robin across 4 shards => 250 each
        assert!(report.crawls_per_shard.iter().all(|&c| c == 250));
    }

    #[test]
    fn pipeline_applies_cis_in_order() {
        let ps = pages(16);
        let mut rng = Rng::new(2);
        let mut cis: Vec<(f64, usize)> = (0..500)
            .map(|_| (rng.range(0.0, 40.0), rng.below(16) as usize))
            .collect();
        cis.sort_by(|a, b| a.0.total_cmp(&b.0));
        let cfg = PipelineConfig { shards: 2, queue_depth: 8, bandwidth: 10.0, horizon: 40.0 };
        let report = run_pipeline(&ps, &lazy_ncis(), &cis, &cfg).unwrap();
        assert_eq!(report.cis_applied, 500);
        assert_eq!(report.total_crawls, 400);
    }

    #[test]
    fn tiny_queue_exerts_backpressure_without_loss() {
        let ps = pages(32);
        let mut rng = Rng::new(3);
        let mut cis: Vec<(f64, usize)> = (0..5_000)
            .map(|_| (rng.range(0.0, 10.0), rng.below(32) as usize))
            .collect();
        cis.sort_by(|a, b| a.0.total_cmp(&b.0));
        let cfg = PipelineConfig { shards: 2, queue_depth: 2, bandwidth: 50.0, horizon: 10.0 };
        let report = run_pipeline(&ps, &lazy_ncis(), &cis, &cfg).unwrap();
        assert_eq!(report.cis_applied, 5_000, "no CIS may be dropped");
        assert_eq!(report.total_crawls, 500);
    }

    #[test]
    fn more_shards_than_pages_idles_empty_shards() {
        // 3 pages over 8 shards: shards 3..7 are empty and must idle
        // their ticks rather than panic at construction
        let ps = pages(3);
        let cfg = PipelineConfig { shards: 8, queue_depth: 4, bandwidth: 8.0, horizon: 10.0 };
        let report = run_pipeline(&ps, &lazy_ncis(), &[], &cfg).unwrap();
        // 80 ticks round-robin over 8 shards; only the 3 populated
        // shards crawl (10 ticks each)
        assert_eq!(report.total_crawls, 30);
        assert!(report.crawls_per_shard[3..].iter().all(|&c| c == 0));
    }

    #[test]
    fn lds_template_rates_are_remapped_per_shard() {
        // an Lds template carries GLOBAL rates; each shard must get its
        // members' slice so worker-local indices stay in range
        let ps = pages(12);
        let rates: Vec<f64> = (0..12).map(|i| 1.0 + (i % 4) as f64).collect();
        let lds = CrawlerBuilder::new().strategy(Strategy::Lds).lds_rates(&rates);
        let cfg = PipelineConfig { shards: 3, queue_depth: 8, bandwidth: 12.0, horizon: 10.0 };
        let report = run_pipeline(&ps, &lds, &[], &cfg).unwrap();
        // LDS always has a next pick, so every tick crawls
        assert_eq!(report.total_crawls, 120);
        assert!(report.crawls_per_shard.iter().all(|&c| c == 40));
    }

    #[test]
    fn zero_shards_is_an_error_not_a_panic() {
        let ps = pages(4);
        let cfg = PipelineConfig { shards: 0, queue_depth: 4, bandwidth: 5.0, horizon: 1.0 };
        assert!(run_pipeline(&ps, &lazy_ncis(), &[], &cfg).is_err());
    }

    #[test]
    fn invalid_template_errs_before_spawning() {
        // an Lds template without rates cannot build per shard: the
        // error must surface as Err, not a panic inside thread::scope
        let ps = pages(8);
        let bad = CrawlerBuilder::new().strategy(Strategy::Lds);
        let cfg = PipelineConfig { shards: 2, queue_depth: 4, bandwidth: 5.0, horizon: 1.0 };
        assert!(run_pipeline(&ps, &bad, &[], &cfg).is_err());
    }

    #[test]
    fn world_events_route_and_apply_in_order() {
        // 8 pages over 2 shards; births at t=2 and t=3 land on shards
        // 0 and 1 (global indices 8, 9), a retirement and a drift
        // route to the pages' existing shards — all without losing a
        // single tick
        let ps = pages(8);
        let newcomer = PageParams { delta: 0.8, mu: 2.0, lam: 0.5, nu: 0.2 };
        let world = vec![
            (2.0, WorldMsg::PageBorn { params: newcomer }),
            (3.0, WorldMsg::PageBorn { params: newcomer }),
            (4.0, WorldMsg::PageRetired { page: 3 }),
            (5.0, WorldMsg::ParamsChanged { page: 2, params: newcomer }),
        ];
        let cfg = PipelineConfig { shards: 2, queue_depth: 8, bandwidth: 10.0, horizon: 20.0 };
        let report = run_pipeline_with_world(&ps, &lazy_ncis(), &[], &world, &cfg).unwrap();
        assert_eq!(report.world_applied, 4, "every world event must reach its worker");
        assert_eq!(report.total_crawls, 200, "world routing must not cost ticks");
        assert_eq!(report.crawls_per_shard, vec![100, 100]);
    }

    #[test]
    fn cis_feed_is_time_ordered_and_complete() {
        let ps = pages(24);
        let horizon = 50.0;
        let mut rng = Rng::new(7);
        let feed = CisFeed::new(&ps, horizon, CisDelay::None, &mut rng).unwrap();
        let events: Vec<(f64, usize)> = feed.collect();
        assert!(!events.is_empty());
        assert!(events.windows(2).all(|w| w[0].0 <= w[1].0), "feed must be time-sorted");
        assert!(events.iter().all(|&(t, p)| (0.0..horizon).contains(&t) && p < ps.len()));
        // scale sanity: E[cis] = Σ (λΔ + ν) · T
        let expect: f64 = ps.iter().map(|p| (p.lam * p.delta + p.nu) * horizon).sum();
        let n = events.len() as f64;
        assert!(
            (n - expect).abs() < 5.0 * expect.sqrt().max(1.0),
            "feed count {n} far from expectation {expect}"
        );
        // determinism
        let mut rng2 = Rng::new(7);
        let feed2 = CisFeed::new(&ps, horizon, CisDelay::None, &mut rng2).unwrap();
        let events2: Vec<(f64, usize)> = feed2.collect();
        assert_eq!(events.len(), events2.len());
        assert!(events
            .iter()
            .zip(&events2)
            .all(|(a, b)| a.0.to_bits() == b.0.to_bits() && a.1 == b.1));
    }

    #[test]
    fn streamed_pipeline_matches_slice_pipeline() {
        // the same feed, pre-collected into a slice vs pulled lazily,
        // must drive identical pipeline outcomes
        let ps = pages(16);
        let horizon = 30.0;
        let mut rng = Rng::new(9);
        let collected: Vec<(f64, usize)> =
            CisFeed::new(&ps, horizon, CisDelay::None, &mut rng).unwrap().collect();
        let mut rng2 = Rng::new(9);
        let feed = CisFeed::new(&ps, horizon, CisDelay::None, &mut rng2).unwrap();
        let cfg = PipelineConfig { shards: 2, queue_depth: 8, bandwidth: 10.0, horizon };
        let a = run_pipeline(&ps, &lazy_ncis(), &collected, &cfg).unwrap();
        let b = run_pipeline_streamed(&ps, &lazy_ncis(), feed, &[], &cfg).unwrap();
        assert_eq!(a.cis_applied, b.cis_applied);
        assert_eq!(a.total_crawls, b.total_crawls);
        assert_eq!(a.crawls_per_shard, b.crawls_per_shard);
    }

    /// Round-robin over local pages; panics at the `fuse` tick if set.
    struct FusedRoundRobin {
        m: usize,
        next: usize,
        ticks: u64,
        fuse: Option<u64>,
    }
    impl FusedRoundRobin {
        fn new(fuse: Option<u64>) -> Self {
            Self { m: 0, next: 0, ticks: 0, fuse }
        }
    }
    impl CrawlScheduler for FusedRoundRobin {
        fn on_start(&mut self, m: usize) {
            self.m = m;
            self.next = 0;
            self.ticks = 0;
        }
        fn select(&mut self, _t: f64) -> Option<usize> {
            self.ticks += 1;
            if self.fuse.is_some_and(|f| self.ticks >= f) {
                panic!("injected shard failure");
            }
            let i = self.next;
            self.next = (self.next + 1) % self.m;
            Some(i)
        }
    }

    #[test]
    fn injected_worker_panic_yields_err_with_salvage() {
        // 4 shards, shard 2's scheduler blows up on its 10th tick: the
        // run must surface Err(WorkerFailed) — not abort — and salvage
        // the full tick counts of the three surviving shards
        let ps = pages(16);
        let cfg = PipelineConfig { shards: 4, queue_depth: 8, bandwidth: 20.0, horizon: 50.0 };
        let scheds: Vec<Box<dyn CrawlScheduler + Send>> = (0..4)
            .map(|s| {
                Box::new(FusedRoundRobin::new((s == 2).then_some(10)))
                    as Box<dyn CrawlScheduler + Send>
            })
            .collect();
        let err = run_pipeline_with_schedulers(&ps, scheds, std::iter::empty(), &[], &cfg)
            .expect_err("a panicked worker must surface as Err");
        match err {
            crate::Error::WorkerFailed { failed, crawls_per_shard } => {
                assert_eq!(failed.len(), 1);
                assert_eq!(failed[0].0, 2);
                assert!(failed[0].1.contains("injected shard failure"), "{}", failed[0].1);
                // 1000 ticks round-robin over 4 shards = 250 each; the
                // dead shard reports 0, siblings keep their full count
                assert_eq!(crawls_per_shard[0], 250);
                assert_eq!(crawls_per_shard[1], 250);
                assert_eq!(crawls_per_shard[3], 250);
                assert_eq!(crawls_per_shard[2], 0, "failed shard salvages nothing");
            }
            other => panic!("expected WorkerFailed, got {other}"),
        }
    }

    #[test]
    fn caller_built_schedulers_run_the_full_topology() {
        let ps = pages(12);
        let cfg = PipelineConfig { shards: 3, queue_depth: 8, bandwidth: 12.0, horizon: 10.0 };
        let scheds: Vec<Box<dyn CrawlScheduler + Send>> = (0..3)
            .map(|_| Box::new(FusedRoundRobin::new(None)) as Box<dyn CrawlScheduler + Send>)
            .collect();
        let report =
            run_pipeline_with_schedulers(&ps, scheds, std::iter::empty(), &[], &cfg).unwrap();
        assert_eq!(report.total_crawls, 120);
        assert_eq!(report.channel_drops, 0, "healthy run drops nothing");
        // scheduler-count mismatch is a usage error, not a panic
        let one: Vec<Box<dyn CrawlScheduler + Send>> =
            vec![Box::new(FusedRoundRobin::new(None))];
        assert!(
            run_pipeline_with_schedulers(&ps, one, std::iter::empty(), &[], &cfg).is_err()
        );
    }

    #[test]
    fn serving_pipeline_reduces_deterministically() {
        let ps = pages(32);
        let traffic = RequestTraffic::new(40.0, 1.1, 0xD1CE)
            .unwrap()
            .with_flash(5.0, 3.0, 2, 60.0)
            .unwrap();
        let cfg = PipelineConfig { shards: 4, queue_depth: 8, bandwidth: 16.0, horizon: 25.0 };
        let a = run_serving_pipeline(&ps, &lazy_ncis(), &traffic, &cfg, 77).unwrap();
        assert!(a.metrics.served > 0);
        assert_eq!(a.metrics.fresh_serves + a.metrics.stale_serves, a.metrics.served);
        // 16 ticks/s over 4 shards = 4/s each; 25s horizon = 100 ticks
        // per shard, and a lazy scheduler crawls every tick
        assert_eq!(a.total_crawls, 400);
        assert!(a.crawls_per_shard.iter().all(|&c| c == 100));
        // same inputs => bit-identical reduction (shard-index order)
        let b = run_serving_pipeline(&ps, &lazy_ncis(), &traffic, &cfg, 77).unwrap();
        assert_eq!(a.metrics.served, b.metrics.served);
        assert_eq!(a.metrics.overall.count(), b.metrics.overall.count());
        assert_eq!(a.metrics.overall.mean().to_bits(), b.metrics.overall.mean().to_bits());
        assert_eq!(a.requests, b.requests);
        assert_eq!(a.fresh_hits, b.fresh_hits);
        // zero shards is a usage error, not a panic
        let z = PipelineConfig { shards: 0, ..cfg.clone() };
        assert!(run_serving_pipeline(&ps, &lazy_ncis(), &traffic, &z, 77).is_err());
        // more shards than pages: empty shards sit out without failing
        let few = pages(3);
        let wide = PipelineConfig { shards: 8, queue_depth: 4, bandwidth: 8.0, horizon: 10.0 };
        let w = run_serving_pipeline(&few, &lazy_ncis(), &traffic, &wide, 77).unwrap();
        assert!(w.crawls_per_shard[3..].iter().all(|&c| c == 0));
    }

    #[test]
    fn pipeline_runs_exact_strategy_too() {
        // the topology is scheduler-agnostic: exact argmax per shard
        let ps = pages(24);
        let exact = CrawlerBuilder::new()
            .policy(PolicyKind::GreedyNcis)
            .strategy(Strategy::Exact);
        let cfg = PipelineConfig { shards: 3, queue_depth: 8, bandwidth: 12.0, horizon: 10.0 };
        let report = run_pipeline(&ps, &exact, &[], &cfg).unwrap();
        assert_eq!(report.total_crawls, 120);
    }
}
