//! Threaded streaming orchestrator — the deployable shape of the system.
//!
//! A production crawler is a pipeline, not a batch simulation: CIS and
//! request events *stream in*, shard workers keep their scheduler state
//! warm, and a ticker thread asks each shard for its next crawl. This
//! module wires that topology with `std::sync::mpsc` bounded channels
//! (backpressure: a slow shard throttles ingestion rather than dropping
//! signals), and reports shard-level throughput metrics.
//!
//! Used by the `serve-shards` CLI command and the Appendix-G scale bench.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::Arc;

use crate::params::PageParams;
use crate::policy::PolicyKind;
use crate::sim::engine::{PageState, Scheduler};

/// A message into a shard worker.
#[derive(Debug, Clone, Copy)]
pub enum ShardMsg {
    /// CIS delivery for local page index at time t.
    Cis {
        /// Local page index within the shard.
        page: usize,
        /// Delivery time.
        t: f64,
    },
    /// Tick: crawl one page at time t.
    Tick {
        /// Tick time.
        t: f64,
    },
    /// Drain and stop.
    Shutdown,
}

/// Counters shared with the driver.
#[derive(Debug, Default)]
pub struct PipelineMetrics {
    /// Crawls executed.
    pub crawls: AtomicU64,
    /// CIS messages applied.
    pub cis_applied: AtomicU64,
    /// Ingestion stalls caused by a full shard queue (backpressure).
    pub backpressure_stalls: AtomicU64,
}

/// One shard worker: owns scheduler + state, consumes its queue.
fn shard_worker(
    rx: Receiver<ShardMsg>,
    mut scheduler: Box<dyn Scheduler + Send>,
    m: usize,
    metrics: Arc<PipelineMetrics>,
) -> Vec<u32> {
    let mut states = vec![PageState { last_crawl: 0.0, n_cis: 0 }; m];
    let mut crawl_counts = vec![0u32; m];
    while let Ok(msg) = rx.recv() {
        match msg {
            ShardMsg::Cis { page, t } => {
                states[page].n_cis = states[page].n_cis.saturating_add(1);
                scheduler.on_cis(page, t, &states);
                metrics.cis_applied.fetch_add(1, Ordering::Relaxed);
            }
            ShardMsg::Tick { t } => {
                if let Some(i) = scheduler.select(t, &states) {
                    states[i] = PageState { last_crawl: t, n_cis: 0 };
                    crawl_counts[i] += 1;
                    scheduler.on_crawl(i, t, &states);
                    metrics.crawls.fetch_add(1, Ordering::Relaxed);
                }
            }
            ShardMsg::Shutdown => break,
        }
    }
    crawl_counts
}

/// Blocking send with backpressure accounting.
fn send_backpressured(
    tx: &SyncSender<ShardMsg>,
    msg: ShardMsg,
    metrics: &PipelineMetrics,
) {
    let mut m = msg;
    loop {
        match tx.try_send(m) {
            Ok(()) => return,
            Err(TrySendError::Full(back)) => {
                metrics.backpressure_stalls.fetch_add(1, Ordering::Relaxed);
                m = back;
                std::thread::yield_now();
            }
            Err(TrySendError::Disconnected(_)) => return,
        }
    }
}

/// Configuration of a streaming run.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    /// Number of shard workers.
    pub shards: usize,
    /// Bounded queue depth per shard (backpressure horizon).
    pub queue_depth: usize,
    /// Global bandwidth R (ticks/sec of simulated time).
    pub bandwidth: f64,
    /// Simulated horizon.
    pub horizon: f64,
}

/// Outcome of a streaming run.
#[derive(Debug)]
pub struct PipelineReport {
    /// Crawls per shard.
    pub crawls_per_shard: Vec<u64>,
    /// Total crawls.
    pub total_crawls: u64,
    /// CIS applied.
    pub cis_applied: u64,
    /// Backpressure stalls observed.
    pub backpressure_stalls: u64,
    /// Wall-clock duration of the run.
    pub wall: std::time::Duration,
}

/// Drive a full streaming run: pages are round-robin sharded, a CIS
/// stream (precomputed event times) and the tick clock are multiplexed
/// into per-shard bounded queues in simulated-time order.
pub fn run_pipeline(
    pages: &[PageParams],
    policy: PolicyKind,
    cis_events: &[(f64, usize)], // (time, global page), sorted by time
    cfg: &PipelineConfig,
) -> PipelineReport {
    assert!(cfg.shards > 0);
    let metrics = Arc::new(PipelineMetrics::default());
    let plan = crate::coordinator::shard::ShardPlan::round_robin(pages.len(), cfg.shards);
    let members = plan.shard_members();
    // local index of each global page within its shard
    let mut local_index = vec![0usize; pages.len()];
    for member in &members {
        for (li, &gi) in member.iter().enumerate() {
            local_index[gi] = li;
        }
    }
    let start = std::time::Instant::now();
    let mut crawls_per_shard = vec![0u64; cfg.shards];
    std::thread::scope(|scope| {
        let mut senders: Vec<SyncSender<ShardMsg>> = Vec::with_capacity(cfg.shards);
        let mut handles = Vec::with_capacity(cfg.shards);
        for member in &members {
            let (tx, rx) = sync_channel::<ShardMsg>(cfg.queue_depth);
            senders.push(tx);
            let pages_s: Vec<PageParams> = member.iter().map(|&i| pages[i]).collect();
            let mcount = pages_s.len();
            let metrics = Arc::clone(&metrics);
            let sched: Box<dyn Scheduler + Send> =
                Box::new(crate::coordinator::lazy::LazyGreedyScheduler::new(policy, &pages_s));
            handles.push(scope.spawn(move || shard_worker(rx, sched, mcount, metrics)));
        }
        // multiplex: ticks round-robin across shards at global rate R
        // (integer tick index — accumulating f64 drifts past the horizon)
        let tick_dt = 1.0 / cfg.bandwidth;
        let total_ticks = (cfg.horizon * cfg.bandwidth).round() as u64;
        let mut tick_idx = 1u64;
        let mut tick_shard = 0usize;
        let mut ev = 0usize;
        while tick_idx <= total_ticks || ev < cis_events.len() {
            let next_tick =
                if tick_idx <= total_ticks { tick_idx as f64 * tick_dt } else { f64::INFINITY };
            let next_cis = cis_events.get(ev).map(|e| e.0).unwrap_or(f64::INFINITY);
            if next_cis <= next_tick && ev < cis_events.len() {
                let (t, gpage) = cis_events[ev];
                if t <= cfg.horizon {
                    let s = plan.assignment[gpage];
                    send_backpressured(
                        &senders[s],
                        ShardMsg::Cis { page: local_index[gpage], t },
                        &metrics,
                    );
                }
                ev += 1;
            } else {
                if tick_idx > total_ticks {
                    break;
                }
                send_backpressured(&senders[tick_shard], ShardMsg::Tick { t: next_tick }, &metrics);
                tick_shard = (tick_shard + 1) % cfg.shards;
                tick_idx += 1;
            }
        }
        for tx in &senders {
            let _ = tx.send(ShardMsg::Shutdown);
        }
        drop(senders);
        for (s, h) in handles.into_iter().enumerate() {
            let counts = h.join().expect("shard worker panicked");
            crawls_per_shard[s] = counts.iter().map(|&c| c as u64).sum();
        }
    });
    PipelineReport {
        total_crawls: crawls_per_shard.iter().sum(),
        crawls_per_shard,
        cis_applied: metrics.cis_applied.load(Ordering::Relaxed),
        backpressure_stalls: metrics.backpressure_stalls.load(Ordering::Relaxed),
        wall: start.elapsed(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngkit::Rng;

    fn pages(m: usize) -> Vec<PageParams> {
        let mut rng = Rng::new(1);
        (0..m)
            .map(|_| PageParams {
                delta: rng.range(0.05, 1.0),
                mu: rng.range(0.05, 1.0),
                lam: 0.5,
                nu: 0.2,
            })
            .collect()
    }

    #[test]
    fn pipeline_executes_all_ticks() {
        let ps = pages(64);
        let cfg = PipelineConfig { shards: 4, queue_depth: 16, bandwidth: 20.0, horizon: 50.0 };
        let report = run_pipeline(&ps, PolicyKind::GreedyNcis, &[], &cfg);
        // 20 ticks/sec * 50s = 1000 ticks total
        assert_eq!(report.total_crawls, 1000);
        // round-robin across 4 shards => 250 each
        assert!(report.crawls_per_shard.iter().all(|&c| c == 250));
    }

    #[test]
    fn pipeline_applies_cis_in_order() {
        let ps = pages(16);
        let mut rng = Rng::new(2);
        let mut cis: Vec<(f64, usize)> = (0..500)
            .map(|_| (rng.range(0.0, 40.0), rng.below(16) as usize))
            .collect();
        cis.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let cfg = PipelineConfig { shards: 2, queue_depth: 8, bandwidth: 10.0, horizon: 40.0 };
        let report = run_pipeline(&ps, PolicyKind::GreedyNcis, &cis, &cfg);
        assert_eq!(report.cis_applied, 500);
        assert_eq!(report.total_crawls, 400);
    }

    #[test]
    fn tiny_queue_exerts_backpressure_without_loss() {
        let ps = pages(32);
        let mut rng = Rng::new(3);
        let mut cis: Vec<(f64, usize)> = (0..5_000)
            .map(|_| (rng.range(0.0, 10.0), rng.below(32) as usize))
            .collect();
        cis.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let cfg = PipelineConfig { shards: 2, queue_depth: 2, bandwidth: 50.0, horizon: 10.0 };
        let report = run_pipeline(&ps, PolicyKind::GreedyNcis, &cis, &cfg);
        assert_eq!(report.cis_applied, 5_000, "no CIS may be dropped");
        assert_eq!(report.total_crawls, 500);
    }
}
