//! Host model + politeness rate limiting.
//!
//! Real crawlers (and the paper's Appendix-G production experiment,
//! whose populations are drawn per *host*) cannot hammer a single web
//! host even when its pages dominate the crawl values: politeness
//! demands a per-host minimum interval between fetches. This module
//! groups pages into hosts and wraps any inner [`CrawlScheduler`] with
//! a politeness filter that skips hosts inside their cool-down window,
//! falling back to the next-best candidate.

use crate::sched::CrawlScheduler;

/// The round-robin page → host convention shared by every layer that
/// groups pages into hosts: [`HostMap::round_robin`], the fault
/// model's outage topology ([`crate::fault::FaultModel`]), the
/// scenario outage generator
/// ([`crate::scenario::generators::add_correlated_outages`]) and the
/// DSL's host-level directives. One definition, so a host-targeted
/// directive can never darken a different page set than the engine
/// maps.
#[inline]
pub fn host_of(page: usize, hosts: usize) -> usize {
    debug_assert!(hosts > 0, "host_of requires at least one host");
    page % hosts
}

/// Page → host assignment plus per-host politeness interval.
#[derive(Debug, Clone)]
pub struct HostMap {
    /// `host[i]` = host id of page `i`.
    pub host: Vec<usize>,
    /// Minimum time between two crawls of the same host.
    pub min_interval: f64,
    /// Number of hosts.
    pub hosts: usize,
}

impl HostMap {
    /// Assign pages to hosts round-robin (uniform host sizes).
    pub fn round_robin(m: usize, hosts: usize, min_interval: f64) -> Self {
        assert!(hosts > 0);
        Self { host: (0..m).map(|i| host_of(i, hosts)).collect(), min_interval, hosts }
    }

    /// Assign by explicit host sizes (e.g. Zipf-distributed host
    /// populations from the dataset generator).
    pub fn from_sizes(sizes: &[usize], min_interval: f64) -> Self {
        let mut host = Vec::with_capacity(sizes.iter().sum());
        for (h, &n) in sizes.iter().enumerate() {
            host.extend(std::iter::repeat(h).take(n));
        }
        Self { host, min_interval, hosts: sizes.len() }
    }
}

/// A scheduler decorator enforcing per-host politeness.
///
/// Selection: ask the inner scheduler for its pick; if the pick's host
/// is cooling down, notify the inner scheduler via `on_veto` and retry
/// a bounded number of times — both the exact argmax (tick-scoped veto
/// mask) and the lazy scheduler (hot-heap sideline) then yield their
/// next-best candidate. A vetoed pick never receives `on_crawl`, so
/// the inner scheduler's event-driven state stays consistent with a
/// "skip" and the page is re-eligible at the next tick; a fully-vetoed
/// tick idles (see the politeness ablation for the freshness cost).
pub struct PoliteScheduler<S> {
    inner: S,
    map: HostMap,
    last_host_crawl: Vec<f64>,
    /// diagnostics: picks vetoed by politeness
    pub vetoes: u64,
    /// diagnostics: ticks where no allowed page was found (idle)
    pub idle_ticks: u64,
}

impl<S: CrawlScheduler> PoliteScheduler<S> {
    /// Wrap `inner` with the host map.
    pub fn new(inner: S, map: HostMap) -> Self {
        let hosts = map.hosts;
        Self {
            inner,
            map,
            last_host_crawl: vec![f64::NEG_INFINITY; hosts],
            vetoes: 0,
            idle_ticks: 0,
        }
    }

    fn allowed(&self, page: usize, t: f64) -> bool {
        let h = self.map.host[page];
        t - self.last_host_crawl[h] >= self.map.min_interval
    }

    /// Access the wrapped scheduler.
    pub fn inner(&self) -> &S {
        &self.inner
    }
}

impl<S: CrawlScheduler> CrawlScheduler for PoliteScheduler<S> {
    fn on_start(&mut self, m: usize) {
        self.inner.on_start(m);
        self.last_host_crawl.iter_mut().for_each(|t| *t = f64::NEG_INFINITY);
        self.vetoes = 0;
        self.idle_ticks = 0;
    }

    fn select(&mut self, t: f64) -> Option<usize> {
        const MAX_RETRIES: usize = 8;
        for _ in 0..MAX_RETRIES {
            let pick = self.inner.select(t)?;
            if self.allowed(pick, t) {
                self.last_host_crawl[self.map.host[pick]] = t;
                return Some(pick);
            }
            self.vetoes += 1;
            // tell the inner scheduler so a retry yields its next-best
            // candidate (the lazy scheduler sidelines the page)
            self.inner.on_veto(pick, t);
        }
        self.idle_ticks += 1;
        None
    }

    fn on_cis(&mut self, page: usize, t: f64) {
        self.inner.on_cis(page, t);
    }

    fn on_crawl(&mut self, page: usize, t: f64) {
        self.inner.on_crawl(page, t);
    }

    fn on_veto(&mut self, page: usize, t: f64) {
        self.inner.on_veto(page, t);
    }

    fn on_crawl_failed(&mut self, page: usize, t: f64, outcome: crate::fault::CrawlOutcome) {
        self.inner.on_crawl_failed(page, t, outcome);
    }

    fn on_fetch_observed(&mut self, page: usize, t: f64, changed: bool) {
        self.inner.on_fetch_observed(page, t, changed);
    }

    fn on_page_added(&mut self, page: usize, params: &crate::params::PageParams, t: f64) {
        // a slot already covered by the map keeps its host: recycled
        // slots stay put, and a caller with a non-round-robin layout
        // (e.g. `HostMap::from_sizes` Zipf hosts) can pre-extend
        // `map.host` past the initial population to control where
        // births land. Only an UNMAPPED newborn falls back to the
        // round-robin convention ([`host_of`]), matching
        // `HostMap::round_robin` and the sharded/pipeline birth
        // routing.
        if page == self.map.host.len() {
            self.map.host.push(host_of(page, self.map.hosts));
        }
        self.inner.on_page_added(page, params, t);
    }

    fn on_page_removed(&mut self, page: usize, t: f64) {
        self.inner.on_page_removed(page, t);
    }

    fn on_params_changed(&mut self, page: usize, params: &crate::params::PageParams, t: f64) {
        self.inner.on_params_changed(page, params, t);
    }

    fn attach_trace(&mut self, tr: crate::trace::TraceHandle) {
        self.inner.attach_trace(tr);
    }

    fn name(&self) -> String {
        format!("{}-POLITE", self.inner.name())
    }
}

/// Zipf-ish host sizes for `m` pages over `hosts` hosts (a few giant
/// hosts, a long tail — the shape of real crawl frontiers). The
/// harmonic weights come from the shared [`crate::stats::Zipf`]
/// distribution at `s = 1` — its `(h+1)^{-1}` masses are exactly the
/// `1/(h+1)` weights this function always used; only the integer
/// apportionment (floor + remainder juggling) lives here.
pub fn zipf_host_sizes(m: usize, hosts: usize, rng: &mut crate::rngkit::Rng) -> Vec<usize> {
    assert!(hosts > 0 && m >= hosts);
    let zipf = crate::stats::Zipf::new(hosts, 1.0);
    let mut sizes: Vec<usize> =
        (0..hosts).map(|h| (zipf.pmf(h) * m as f64).floor() as usize).collect();
    // every host at least one page, then distribute the remainder
    for s in sizes.iter_mut() {
        if *s == 0 {
            *s = 1;
        }
    }
    let mut assigned: usize = sizes.iter().sum();
    while assigned > m {
        let h = rng.below(hosts as u64) as usize;
        if sizes[h] > 1 {
            sizes[h] -= 1;
            assigned -= 1;
        }
    }
    while assigned < m {
        let h = rng.below(hosts as u64) as usize;
        sizes[h] += 1;
        assigned += 1;
    }
    sizes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::crawler::{GreedyScheduler, ValueBackend};
    use crate::params::PageParams;
    use crate::policy::PolicyKind;
    use crate::rngkit::Rng;
    use crate::sim::{generate_traces, simulate, CisDelay, SimConfig};

    fn pages(m: usize) -> Vec<PageParams> {
        let mut rng = Rng::new(1);
        (0..m)
            .map(|_| PageParams {
                delta: rng.range(0.05, 1.0),
                mu: rng.range(0.05, 1.0),
                lam: 0.5,
                nu: 0.2,
            })
            .collect()
    }

    #[test]
    fn politeness_enforced_exactly() {
        let ps = pages(40);
        let map = HostMap::round_robin(40, 4, 1.0);
        let inner = GreedyScheduler::new(PolicyKind::GreedyNcis, &ps, ValueBackend::Native);
        let mut polite = PoliteScheduler::new(inner, map.clone());
        let mut rng = Rng::new(2);
        let traces = generate_traces(&ps, 50.0, CisDelay::None, &mut rng);
        let cfg = SimConfig::new(10.0, 50.0).unwrap();
        // track host crawl times through the simulation result
        let res = simulate(&traces, &cfg, &mut polite);
        // re-derive: with min_interval=1.0 and R=10, each host can absorb
        // at most ~horizon/min_interval crawls
        let mut per_host = vec![0u32; 4];
        for (i, &c) in res.crawl_counts.iter().enumerate() {
            per_host[map.host[i]] += c;
        }
        for (h, &c) in per_host.iter().enumerate() {
            assert!(
                c as f64 <= 50.0 / 1.0 + 1.0,
                "host {h} crawled {c} times > politeness cap"
            );
        }
    }

    #[test]
    fn vetoes_happen_under_tight_politeness() {
        let ps = pages(8);
        // single host, long cooldown, fast ticks: most picks vetoed
        let map = HostMap::round_robin(8, 1, 2.0);
        let inner = GreedyScheduler::new(PolicyKind::GreedyNcis, &ps, ValueBackend::Native);
        let mut polite = PoliteScheduler::new(inner, map);
        let mut rng = Rng::new(3);
        let traces = generate_traces(&ps, 30.0, CisDelay::None, &mut rng);
        let cfg = SimConfig::new(5.0, 30.0).unwrap();
        let res = simulate(&traces, &cfg, &mut polite);
        assert!(polite.vetoes + polite.idle_ticks > 0);
        let total: u32 = res.crawl_counts.iter().sum();
        assert!(
            (total as f64) <= 30.0 / 2.0 + 1.0,
            "single host crawled {total} > cap"
        );
    }

    #[test]
    fn zero_interval_is_transparent() {
        let ps = pages(20);
        let map = HostMap::round_robin(20, 4, 0.0);
        let mut rng = Rng::new(4);
        let traces = generate_traces(&ps, 30.0, CisDelay::None, &mut rng);
        let cfg = SimConfig::new(5.0, 30.0).unwrap();
        let mut plain = GreedyScheduler::new(PolicyKind::GreedyNcis, &ps, ValueBackend::Native);
        let acc_plain = simulate(&traces, &cfg, &mut plain).accuracy;
        let inner = GreedyScheduler::new(PolicyKind::GreedyNcis, &ps, ValueBackend::Native);
        let mut polite = PoliteScheduler::new(inner, map);
        let acc_polite = simulate(&traces, &cfg, &mut polite).accuracy;
        assert_eq!(acc_plain, acc_polite);
        assert_eq!(polite.vetoes, 0);
    }

    #[test]
    fn lazy_inner_yields_next_best_after_veto() {
        use crate::coordinator::lazy::LazyGreedyScheduler;
        // drive the hooks directly: after a veto the lazy scheduler
        // must surface a DIFFERENT page on immediate retry, and the
        // vetoed page must not be orphaned (it gets crawled later)
        let ps = pages(6);
        let mut lz = LazyGreedyScheduler::new(PolicyKind::GreedyNcis, &ps);
        lz.on_start(ps.len());
        let t = 1.0;
        let first = lz.select(t).expect("non-empty population");
        lz.on_veto(first, t);
        let second = lz.select(t).expect("retry must yield a pick");
        assert_ne!(first, second, "retry after veto re-picked the vetoed page");
        // no orphaning: the vetoed page had the top crawl value, so it
        // must come back and get crawled within the next few ticks
        let mut crawled = vec![false; ps.len()];
        crawled[second] = true;
        lz.on_crawl(second, t);
        for j in 2..50 {
            let tj = j as f64;
            let pick = lz.select(tj).expect("lazy always crawls");
            crawled[pick] = true;
            lz.on_crawl(pick, tj);
        }
        assert!(crawled[first], "vetoed page was orphaned");
    }

    #[test]
    fn boxed_inner_scheduler_works() {
        // decorators compose with builder-produced trait objects
        let ps = pages(12);
        let map = HostMap::round_robin(12, 3, 0.1);
        let inner: Box<dyn CrawlScheduler + Send> =
            Box::new(GreedyScheduler::new(PolicyKind::GreedyNcis, &ps, ValueBackend::Native));
        let mut polite = PoliteScheduler::new(inner, map);
        let mut rng = Rng::new(9);
        let traces = generate_traces(&ps, 20.0, CisDelay::None, &mut rng);
        let cfg = SimConfig::new(4.0, 20.0).unwrap();
        let res = simulate(&traces, &cfg, &mut polite);
        assert!((0.0..=1.0).contains(&res.accuracy));
        assert!(polite.name().ends_with("-POLITE"));
    }

    #[test]
    fn host_map_builders() {
        let m = HostMap::from_sizes(&[3, 1, 2], 0.5);
        assert_eq!(m.host, vec![0, 0, 0, 1, 2, 2]);
        assert_eq!(m.hosts, 3);
        let mut rng = Rng::new(5);
        let sizes = zipf_host_sizes(1000, 20, &mut rng);
        assert_eq!(sizes.iter().sum::<usize>(), 1000);
        assert!(sizes.iter().all(|&s| s >= 1));
        assert!(sizes[0] > sizes[19], "head host should dominate tail");
    }
}
