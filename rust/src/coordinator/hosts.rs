//! Host model + politeness rate limiting.
//!
//! Real crawlers (and the paper's Appendix-G production experiment,
//! whose populations are drawn per *host*) cannot hammer a single web
//! host even when its pages dominate the crawl values: politeness
//! demands a per-host minimum interval between fetches. This module
//! groups pages into hosts and wraps any inner [`Scheduler`] with a
//! politeness filter that skips hosts inside their cool-down window,
//! falling back to the next-best candidate.

use std::collections::HashMap;

use crate::sim::engine::{PageState, Scheduler};

/// Page → host assignment plus per-host politeness interval.
#[derive(Debug, Clone)]
pub struct HostMap {
    /// `host[i]` = host id of page `i`.
    pub host: Vec<usize>,
    /// Minimum time between two crawls of the same host.
    pub min_interval: f64,
    /// Number of hosts.
    pub hosts: usize,
}

impl HostMap {
    /// Assign pages to hosts round-robin (uniform host sizes).
    pub fn round_robin(m: usize, hosts: usize, min_interval: f64) -> Self {
        assert!(hosts > 0);
        Self { host: (0..m).map(|i| i % hosts).collect(), min_interval, hosts }
    }

    /// Assign by explicit host sizes (e.g. Zipf-distributed host
    /// populations from the dataset generator).
    pub fn from_sizes(sizes: &[usize], min_interval: f64) -> Self {
        let mut host = Vec::with_capacity(sizes.iter().sum());
        for (h, &n) in sizes.iter().enumerate() {
            host.extend(std::iter::repeat(h).take(n));
        }
        Self { host, min_interval, hosts: sizes.len() }
    }
}

/// A scheduler decorator enforcing per-host politeness.
///
/// Selection: ask the inner scheduler for its pick; if the pick's host
/// is cooling down, temporarily mask the page... but an arbitrary inner
/// scheduler has no masking interface, so the decorator instead retries
/// the inner selection a bounded number of times while remembering
/// vetoed pages, and finally falls back to the best *allowed* page seen.
/// With the [`crate::coordinator::crawler::GreedyScheduler`] the retry
/// naturally yields the next-highest crawl value.
pub struct PoliteScheduler<S> {
    inner: S,
    map: HostMap,
    last_host_crawl: Vec<f64>,
    /// diagnostics: picks vetoed by politeness
    pub vetoes: u64,
    /// diagnostics: ticks where no allowed page was found (idle)
    pub idle_ticks: u64,
}

impl<S: Scheduler> PoliteScheduler<S> {
    /// Wrap `inner` with the host map.
    pub fn new(inner: S, map: HostMap) -> Self {
        let hosts = map.hosts;
        Self {
            inner,
            map,
            last_host_crawl: vec![f64::NEG_INFINITY; hosts],
            vetoes: 0,
            idle_ticks: 0,
        }
    }

    fn allowed(&self, page: usize, t: f64) -> bool {
        let h = self.map.host[page];
        t - self.last_host_crawl[h] >= self.map.min_interval
    }

    /// Access the wrapped scheduler.
    pub fn inner(&self) -> &S {
        &self.inner
    }
}

impl<S: Scheduler> Scheduler for PoliteScheduler<S> {
    fn select(&mut self, t: f64, states: &[PageState]) -> Option<usize> {
        const MAX_RETRIES: usize = 8;
        // The inner scheduler believes each returned page was crawled
        // (greedy variants reset their bookkeeping on_crawl); to veto we
        // simply do not report the crawl to the engine but DO notify the
        // inner scheduler so its internal state stays consistent with a
        // "skip". For the greedy/lazy schedulers on_crawl is a no-op
        // (the engine's state array is the source of truth), so a vetoed
        // pick is safely re-eligible next tick.
        for _ in 0..MAX_RETRIES {
            let pick = self.inner.select(t, states)?;
            if self.allowed(pick, t) {
                self.last_host_crawl[self.map.host[pick]] = t;
                return Some(pick);
            }
            self.vetoes += 1;
        }
        self.idle_ticks += 1;
        None
    }

    fn on_cis(&mut self, page: usize, t: f64, states: &[PageState]) {
        self.inner.on_cis(page, t, states);
    }

    fn on_crawl(&mut self, page: usize, t: f64, states: &[PageState]) {
        self.inner.on_crawl(page, t, states);
    }

    fn name(&self) -> String {
        format!("{}-POLITE", self.inner.name())
    }
}

/// Zipf-ish host sizes for `m` pages over `hosts` hosts (a few giant
/// hosts, a long tail — the shape of real crawl frontiers).
pub fn zipf_host_sizes(m: usize, hosts: usize, rng: &mut crate::rngkit::Rng) -> Vec<usize> {
    assert!(hosts > 0 && m >= hosts);
    let weights: Vec<f64> = (0..hosts).map(|h| 1.0 / (h as f64 + 1.0)).collect();
    let total: f64 = weights.iter().sum();
    let mut sizes: Vec<usize> =
        weights.iter().map(|w| ((w / total) * m as f64).floor() as usize).collect();
    // every host at least one page, then distribute the remainder
    for s in sizes.iter_mut() {
        if *s == 0 {
            *s = 1;
        }
    }
    let mut assigned: usize = sizes.iter().sum();
    while assigned > m {
        let h = rng.below(hosts as u64) as usize;
        if sizes[h] > 1 {
            sizes[h] -= 1;
            assigned -= 1;
        }
    }
    while assigned < m {
        let h = rng.below(hosts as u64) as usize;
        sizes[h] += 1;
        assigned += 1;
    }
    sizes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::crawler::{GreedyScheduler, ValueBackend};
    use crate::params::PageParams;
    use crate::policy::PolicyKind;
    use crate::rngkit::Rng;
    use crate::sim::{generate_traces, simulate, CisDelay, SimConfig};

    fn pages(m: usize) -> Vec<PageParams> {
        let mut rng = Rng::new(1);
        (0..m)
            .map(|_| PageParams {
                delta: rng.range(0.05, 1.0),
                mu: rng.range(0.05, 1.0),
                lam: 0.5,
                nu: 0.2,
            })
            .collect()
    }

    #[test]
    fn politeness_enforced_exactly() {
        let ps = pages(40);
        let map = HostMap::round_robin(40, 4, 1.0);
        let inner = GreedyScheduler::new(PolicyKind::GreedyNcis, &ps, ValueBackend::Native);
        let mut polite = PoliteScheduler::new(inner, map.clone());
        let mut rng = Rng::new(2);
        let traces = generate_traces(&ps, 50.0, CisDelay::None, &mut rng);
        let cfg = SimConfig::new(10.0, 50.0);
        // track host crawl times through the simulation result
        let res = simulate(&traces, &cfg, &mut polite);
        // re-derive: with min_interval=1.0 and R=10, each host can absorb
        // at most ~horizon/min_interval crawls
        let mut per_host = vec![0u32; 4];
        for (i, &c) in res.crawl_counts.iter().enumerate() {
            per_host[map.host[i]] += c;
        }
        for (h, &c) in per_host.iter().enumerate() {
            assert!(
                c as f64 <= 50.0 / 1.0 + 1.0,
                "host {h} crawled {c} times > politeness cap"
            );
        }
    }

    #[test]
    fn vetoes_happen_under_tight_politeness() {
        let ps = pages(8);
        // single host, long cooldown, fast ticks: most picks vetoed
        let map = HostMap::round_robin(8, 1, 2.0);
        let inner = GreedyScheduler::new(PolicyKind::GreedyNcis, &ps, ValueBackend::Native);
        let mut polite = PoliteScheduler::new(inner, map);
        let mut rng = Rng::new(3);
        let traces = generate_traces(&ps, 30.0, CisDelay::None, &mut rng);
        let cfg = SimConfig::new(5.0, 30.0);
        let res = simulate(&traces, &cfg, &mut polite);
        assert!(polite.vetoes + polite.idle_ticks > 0);
        let total: u32 = res.crawl_counts.iter().sum();
        assert!(
            (total as f64) <= 30.0 / 2.0 + 1.0,
            "single host crawled {total} > cap"
        );
    }

    #[test]
    fn zero_interval_is_transparent() {
        let ps = pages(20);
        let map = HostMap::round_robin(20, 4, 0.0);
        let mut rng = Rng::new(4);
        let traces = generate_traces(&ps, 30.0, CisDelay::None, &mut rng);
        let cfg = SimConfig::new(5.0, 30.0);
        let mut plain = GreedyScheduler::new(PolicyKind::GreedyNcis, &ps, ValueBackend::Native);
        let acc_plain = simulate(&traces, &cfg, &mut plain).accuracy;
        let inner = GreedyScheduler::new(PolicyKind::GreedyNcis, &ps, ValueBackend::Native);
        let mut polite = PoliteScheduler::new(inner, map);
        let acc_polite = simulate(&traces, &cfg, &mut polite).accuracy;
        assert_eq!(acc_plain, acc_polite);
        assert_eq!(polite.vetoes, 0);
    }

    #[test]
    fn host_map_builders() {
        let m = HostMap::from_sizes(&[3, 1, 2], 0.5);
        assert_eq!(m.host, vec![0, 0, 0, 1, 2, 2]);
        assert_eq!(m.hosts, 3);
        let mut rng = Rng::new(5);
        let sizes = zipf_host_sizes(1000, 20, &mut rng);
        assert_eq!(sizes.iter().sum::<usize>(), 1000);
        assert!(sizes.iter().all(|&s| s >= 1));
        assert!(sizes[0] > sizes[19], "head host should dominate tail");
    }
}
