//! One entry point for constructing crawl schedulers.
//!
//! [`CrawlerBuilder`] wires any policy × strategy × value-backend
//! combination behind the event-driven
//! [`CrawlScheduler`](crate::sched::CrawlScheduler) trait:
//!
//! ```
//! use ncis_crawl::{CrawlScheduler, CrawlerBuilder, PolicyKind, Strategy};
//! use ncis_crawl::coordinator::crawler::ValueBackend;
//! # let pages = vec![ncis_crawl::PageParams { delta: 0.5, mu: 0.5, lam: 0.3, nu: 0.1 }];
//!
//! let mut crawler = CrawlerBuilder::new()
//!     .policy(PolicyKind::GreedyNcis)
//!     .strategy(Strategy::Lazy)
//!     .backend(ValueBackend::Native)
//!     .pages(&pages)
//!     .build()
//!     .unwrap();
//! # let _ = crawler.select(1.0);
//! ```
//!
//! Every scheduling strategy — exact argmax, §5.2 lazy, N-way sharded —
//! accepts either backend (native f64 or the batched PJRT engine), so a
//! backend swap never forces a strategy change and vice versa. The
//! builder is `Clone`: drivers that construct one scheduler per shard
//! or per repetition (`figures::common::run_cell`, the streaming
//! pipeline) keep a pages-less template and stamp `pages(..)` per use.

use crate::coordinator::crawler::{GreedyScheduler, LdsAdapter, ValueBackend};
use crate::coordinator::lazy::{LazyGreedyScheduler, DEFAULT_MARGIN};
use crate::coordinator::learned::{prior_params, LearnedScheduler};
use crate::coordinator::shard::ShardedScheduler;
use crate::error::Error;
use crate::estimation::EstimatorConfig;
use crate::params::PageParams;
use crate::policy::{PolicyKind, PolicyUnderTest};
use crate::rngkit::Rng;
use crate::scenario::{
    simulate_scenario_streamed_traced_with, simulate_scenario_traced_with, Scenario,
    ScenarioWorkspace,
};
use crate::sched::CrawlScheduler;
use crate::serving::{RequestTraffic, ServingMetrics, ServingSession};
use crate::sim::engine::{SimConfig, SimResult, SimWorkspace};
use crate::sim::{
    generate_traces, simulate_streamed_traced_with, simulate_traced_with, CisDelay,
    StreamedSource, TraceMode,
};
use crate::trace::TraceHandle;
use crate::Result;

/// Which scheduling strategy drives the policy's value function.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Strategy {
    /// Algorithm 1 with an exact argmax over all pages at every tick.
    Exact,
    /// The §5.2 lazy/tiered scheduler (default hot/cold margin).
    Lazy,
    /// Lazy with an explicit hot/cold margin in (0, 1].
    LazyWithMargin(f64),
    /// N-way sharded lazy scheduling: ticks fan round-robin, each shard
    /// sees 1/N of the bandwidth (the single-process analogue of the
    /// threaded pipeline).
    Sharded {
        /// Number of shards.
        shards: usize,
    },
    /// Low-discrepancy schedule over precomputed continuous rates
    /// (requires [`CrawlerBuilder::lds_rates`]).
    Lds,
}

/// Where the scheduler's knowledge of page parameters comes from.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum Knowledge {
    /// Ground-truth page parameters, as every pre-existing scheduler
    /// consumed them (the default — bit-identical to not having the
    /// knob at all).
    #[default]
    Oracle,
    /// Oracle-free crawling: the scheduler is constructed over
    /// uninformative priors and a [`LearnedScheduler`] decorator learns
    /// (Δ̂, precision, recall) online from crawl outcomes, re-projecting
    /// beliefs on a bounded per-tick budget. Scenario ground-truth
    /// events never reach the wrapped scheduler (only the observable
    /// importance weight μ crosses). With [`Strategy::Lds`] the
    /// decorator still attaches, but the adapter replays its
    /// caller-provided rates and ignores re-projections — an
    /// oracle-rate baseline, documented rather than forbidden.
    Learned(EstimatorConfig),
}

/// Builder facade over every scheduler in the coordinator layer.
#[derive(Debug, Clone)]
pub struct CrawlerBuilder {
    policy: PolicyKind,
    strategy: Strategy,
    backend: ValueBackend,
    pages: Vec<PageParams>,
    lds_rates: Vec<f64>,
    scenario: Option<Scenario>,
    trace_mode: TraceMode,
    traffic: Option<RequestTraffic>,
    knowledge: Knowledge,
    trace: Option<TraceHandle>,
}

/// Shared construction body of [`CrawlerBuilder::build`] and
/// [`CrawlerBuilder::build_local`]: each match arm's box coerces to the
/// caller's return type (`+ Send` or not), keeping the two entry points
/// in lockstep without duplicating validation.
macro_rules! construct_scheduler {
    ($b:expr) => {{
        let b = $b;
        if b.pages.is_empty() && !matches!(b.strategy, Strategy::Lds) {
            return Err(Error::Usage("CrawlerBuilder: pages(..) must be non-empty".into()));
        }
        Ok(match b.strategy {
            Strategy::Exact => {
                Box::new(GreedyScheduler::new(b.policy, &b.pages, b.backend.clone()))
            }
            Strategy::Lazy => Box::new(LazyGreedyScheduler::with_backend(
                b.policy,
                &b.pages,
                DEFAULT_MARGIN,
                b.backend.clone(),
            )),
            Strategy::LazyWithMargin(margin) => {
                if !(margin > 0.0 && margin <= 1.0) {
                    return Err(Error::Usage(format!(
                        "CrawlerBuilder: lazy margin must be in (0, 1], got {margin}"
                    )));
                }
                Box::new(LazyGreedyScheduler::with_backend(
                    b.policy,
                    &b.pages,
                    margin,
                    b.backend.clone(),
                ))
            }
            Strategy::Sharded { shards } => {
                if shards == 0 {
                    return Err(Error::Usage(
                        "CrawlerBuilder: at least one shard required".into(),
                    ));
                }
                Box::new(ShardedScheduler::new(b.policy, &b.pages, shards, b.backend.clone()))
            }
            Strategy::Lds => {
                if b.lds_rates.is_empty() {
                    return Err(Error::Usage(
                        "CrawlerBuilder: Strategy::Lds requires lds_rates(..)".into(),
                    ));
                }
                Box::new(LdsAdapter::new(&b.lds_rates))
            }
        })
    }};
}

impl Default for CrawlerBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl CrawlerBuilder {
    /// Defaults: GREEDY-NCIS policy, exact strategy, native backend.
    pub fn new() -> Self {
        Self {
            policy: PolicyKind::GreedyNcis,
            strategy: Strategy::Exact,
            backend: ValueBackend::Native,
            pages: Vec::new(),
            lds_rates: Vec::new(),
            scenario: None,
            trace_mode: TraceMode::default(),
            traffic: None,
            knowledge: Knowledge::Oracle,
            trace: None,
        }
    }

    /// Attach a trace handle: schedulers built by this builder emit
    /// decision events into it, and [`Self::run_scenario`] /
    /// [`Self::run_traffic`] drive the traced engine entry points.
    /// Tracing is strictly observational — picks, RNG draws and results
    /// are bit-identical to the untraced run (`tests/trace_parity.rs`).
    pub fn with_trace(mut self, trace: TraceHandle) -> Self {
        self.trace = Some(trace);
        self
    }

    /// The attached trace handle, if any.
    pub fn trace_handle(&self) -> Option<&TraceHandle> {
        self.trace.as_ref()
    }

    /// Knowledge source: [`Knowledge::Oracle`] (ground truth, the
    /// default) or [`Knowledge::Learned`] (online estimation from crawl
    /// outcomes with trust-gated degradation).
    pub fn knowledge(mut self, knowledge: Knowledge) -> Self {
        self.knowledge = knowledge;
        self
    }

    /// How [`Self::run_scenario`] produces per-repetition event
    /// streams: [`TraceMode::Streamed`] (the default — lazy per-page
    /// sources, `O(m)` memory) or [`TraceMode::Materialized`] (the
    /// pre-built-trace oracle path, a different seed-keyed realization
    /// of the same process).
    pub fn trace_mode(mut self, mode: TraceMode) -> Self {
        self.trace_mode = mode;
        self
    }

    /// Crawl-value policy (ignored by [`Strategy::Lds`]).
    pub fn policy(mut self, policy: PolicyKind) -> Self {
        self.policy = policy;
        self
    }

    /// Scheduling strategy.
    pub fn strategy(mut self, strategy: Strategy) -> Self {
        self.strategy = strategy;
        self
    }

    /// Value backend (native f64 or batched PJRT), honoured by every
    /// strategy.
    pub fn backend(mut self, backend: ValueBackend) -> Self {
        self.backend = backend;
        self
    }

    /// Page population (raw parameters; importance should be normalized).
    pub fn pages(mut self, pages: &[PageParams]) -> Self {
        self.pages = pages.to_vec();
        self
    }

    /// Continuous per-page rates for the LDS strategy.
    pub fn lds_rates(mut self, rates: &[f64]) -> Self {
        self.lds_rates = rates.to_vec();
        self
    }

    /// Run against a dynamic world: the scenario's initial population
    /// becomes the builder's `pages(..)` (so `build()` constructs a
    /// scheduler over it) and [`Self::run_scenario`] drives the
    /// scripted timeline. Every policy × strategy × backend combination
    /// the builder can construct runs the dynamic world through the
    /// same entry point.
    pub fn with_scenario(mut self, scenario: Scenario) -> Self {
        self.pages = scenario.initial_pages().to_vec();
        self.scenario = Some(scenario);
        self
    }

    /// The configured scenario, if any.
    pub fn scenario(&self) -> Option<&Scenario> {
        self.scenario.as_ref()
    }

    /// Attach user request traffic: [`Self::run_traffic`] then answers
    /// every request from the serving layer's
    /// [`crate::serving::FreshnessCache`] and returns
    /// fairness-at-request [`ServingMetrics`] alongside the crawl
    /// result. An [`RequestTraffic::off`] configuration is pinned
    /// bit-identical to the plain engines (`tests/serving_parity.rs`).
    pub fn with_traffic(mut self, traffic: RequestTraffic) -> Self {
        self.traffic = Some(traffic);
        self
    }

    /// The configured request traffic, if any.
    pub fn traffic(&self) -> Option<&RequestTraffic> {
        self.traffic.as_ref()
    }

    /// Build the scheduler and run one repetition with the serving
    /// layer attached: crawl events replay exactly as
    /// [`Self::run_scenario`] (dynamic world) or the static engines
    /// (no scenario) would — the traffic stream draws from its own RNG,
    /// so the crawl-side result is bit-identical to the traffic-less
    /// run — while user requests are answered from the freshness cache.
    /// Requires [`Self::with_traffic`].
    pub fn run_traffic(
        &self,
        cfg: &SimConfig,
        trace_seed: u64,
    ) -> Result<(SimResult, ServingMetrics)> {
        let traffic = self.traffic.as_ref().ok_or_else(|| {
            Error::Usage("CrawlerBuilder: run_traffic requires with_traffic(..)".into())
        })?;
        let mut sched = self.build()?;
        if let Some(scenario) = self.scenario.as_ref() {
            if self.pages != scenario.initial_pages() {
                return Err(Error::Usage(
                    "CrawlerBuilder: pages(..) diverged from the scenario's initial \
                     population — call with_scenario(..) last, or drop the pages(..) override"
                        .into(),
                ));
            }
            scenario.delay().validate()?;
            let mut serving =
                ServingSession::new(traffic, scenario.initial_pages(), cfg.horizon);
            let mut ws = ScenarioWorkspace::new();
            let res = match self.trace_mode {
                TraceMode::Streamed => simulate_scenario_streamed_traced_with(
                    &mut ws,
                    cfg,
                    scenario,
                    trace_seed,
                    sched.as_mut(),
                    Some(&mut serving),
                    self.trace.as_ref(),
                )?,
                TraceMode::Materialized => {
                    let mut rng = Rng::new(trace_seed);
                    let traces = generate_traces(
                        scenario.initial_pages(),
                        cfg.horizon,
                        scenario.delay(),
                        &mut rng,
                    );
                    simulate_scenario_traced_with(
                        &mut ws,
                        &traces,
                        cfg,
                        scenario,
                        sched.as_mut(),
                        Some(&mut serving),
                        self.trace.as_ref(),
                    )
                }
            };
            Ok((res, serving.into_metrics()))
        } else {
            let mut serving = ServingSession::new(traffic, &self.pages, cfg.horizon);
            let mut ws = SimWorkspace::new();
            let mut rng = Rng::new(trace_seed);
            let res = match self.trace_mode {
                TraceMode::Streamed => {
                    let source =
                        StreamedSource::new(&self.pages, cfg.horizon, CisDelay::None, &mut rng)?;
                    simulate_streamed_traced_with(
                        &mut ws,
                        source,
                        cfg,
                        sched.as_mut(),
                        Some(&mut serving),
                        self.trace.as_ref(),
                    )
                }
                TraceMode::Materialized => {
                    let traces =
                        generate_traces(&self.pages, cfg.horizon, CisDelay::None, &mut rng);
                    simulate_traced_with(
                        &mut ws,
                        &traces,
                        cfg,
                        sched.as_mut(),
                        Some(&mut serving),
                        self.trace.as_ref(),
                    )
                }
            };
            Ok((res, serving.into_metrics()))
        }
    }

    /// Build the scheduler and run one repetition against the
    /// configured scenario: initial traces are generated from
    /// `trace_seed` (exactly as a static run would), the world evolves
    /// per the scenario script. Requires [`Self::with_scenario`].
    pub fn run_scenario(&self, cfg: &SimConfig, trace_seed: u64) -> Result<SimResult> {
        let mut ws = ScenarioWorkspace::new();
        self.run_scenario_with(&mut ws, cfg, trace_seed)
    }

    /// [`Self::run_scenario`] with caller-owned scratch (repetition
    /// loops reuse one workspace; `ws.stats` reports what the world
    /// did afterwards).
    pub fn run_scenario_with(
        &self,
        ws: &mut ScenarioWorkspace,
        cfg: &SimConfig,
        trace_seed: u64,
    ) -> Result<SimResult> {
        let scenario = self.scenario.as_ref().ok_or_else(|| {
            Error::Usage("CrawlerBuilder: run_scenario requires with_scenario(..)".into())
        })?;
        // a later .pages(..) call must not silently desynchronize the
        // scheduler from the world it is about to run (the engine
        // would deliver events for pages the scheduler never had)
        if self.pages != scenario.initial_pages() {
            return Err(Error::Usage(
                "CrawlerBuilder: pages(..) diverged from the scenario's initial \
                 population — call with_scenario(..) last, or drop the pages(..) override"
                    .into(),
            ));
        }
        // reject a bad delay identically in both trace modes (the
        // streamed engine validates internally; the materialized
        // generator assumes validity)
        scenario.delay().validate()?;
        let mut sched = self.build()?;
        match self.trace_mode {
            TraceMode::Streamed => simulate_scenario_streamed_traced_with(
                ws,
                cfg,
                scenario,
                trace_seed,
                sched.as_mut(),
                None,
                self.trace.as_ref(),
            ),
            TraceMode::Materialized => {
                let mut rng = Rng::new(trace_seed);
                let traces = generate_traces(
                    scenario.initial_pages(),
                    cfg.horizon,
                    scenario.delay(),
                    &mut rng,
                );
                Ok(simulate_scenario_traced_with(
                    ws,
                    &traces,
                    cfg,
                    scenario,
                    sched.as_mut(),
                    None,
                    self.trace.as_ref(),
                ))
            }
        }
    }

    /// Apply a [`PolicyUnderTest`] (policy + strategy in one value, as
    /// parsed from the CLI / experiment configs).
    pub fn policy_under_test(mut self, put: PolicyUnderTest) -> Self {
        match put {
            PolicyUnderTest::Greedy(kind) => {
                self.policy = kind;
                self.strategy = Strategy::Exact;
            }
            PolicyUnderTest::Lazy(kind) => {
                self.policy = kind;
                self.strategy = Strategy::Lazy;
            }
            PolicyUnderTest::Lds => {
                self.strategy = Strategy::Lds;
            }
        }
        self
    }

    /// Construct the scheduler as a `Send` trait object, so drivers can
    /// ship it across threads (pipeline shard workers, rep workers).
    ///
    /// This requires the value backend to be `Send`. The native backend
    /// and the default (stub) PJRT engine are; a vendored XLA client
    /// that is not `Send` must be wrapped `Send` at vendoring time (see
    /// EXPERIMENTS.md §PJRT) — single-thread drivers can then take
    /// [`Self::build_local`] instead.
    pub fn build(&self) -> Result<Box<dyn CrawlScheduler + Send>> {
        let built: Result<Box<dyn CrawlScheduler + Send>> = match self.knowledge {
            Knowledge::Oracle => construct_scheduler!(self),
            Knowledge::Learned(cfg) => {
                let eff = self.prior_projected(&cfg);
                let inner: Result<Box<dyn CrawlScheduler + Send>> = construct_scheduler!(&eff);
                let mus: Vec<f64> = self.pages.iter().map(|p| p.mu).collect();
                Ok(Box::new(LearnedScheduler::new(inner?, mus, cfg)))
            }
        };
        let mut sched = built?;
        if let Some(h) = &self.trace {
            sched.attach_trace(h.clone());
        }
        Ok(sched)
    }

    /// [`Self::build`] without the `Send` bound — for single-thread
    /// drivers whose backend engine cannot cross threads. Independent
    /// construction path (not a coercion of `build`), so it stays
    /// usable when `build` must be feature-gated away for a non-`Send`
    /// engine.
    pub fn build_local(&self) -> Result<Box<dyn CrawlScheduler>> {
        let built: Result<Box<dyn CrawlScheduler>> = match self.knowledge {
            Knowledge::Oracle => construct_scheduler!(self),
            Knowledge::Learned(cfg) => {
                let eff = self.prior_projected(&cfg);
                let inner: Result<Box<dyn CrawlScheduler>> = construct_scheduler!(&eff);
                let mus: Vec<f64> = self.pages.iter().map(|p| p.mu).collect();
                Ok(Box::new(LearnedScheduler::new(inner?, mus, cfg)))
            }
        };
        let mut sched = built?;
        if let Some(h) = &self.trace {
            sched.attach_trace(h.clone());
        }
        Ok(sched)
    }

    /// The builder whose pages are this one's projected through the
    /// uninformative prior (observable importance only) — what a
    /// Learned-mode inner scheduler is constructed over. Ground truth
    /// (Δ, λ, ν) never reaches it.
    fn prior_projected(&self, cfg: &EstimatorConfig) -> CrawlerBuilder {
        let mut eff = self.clone();
        eff.pages = self.pages.iter().map(|p| prior_params(cfg, p.mu)).collect();
        eff.knowledge = Knowledge::Oracle;
        eff
    }

    /// Stamp a shard-local copy of this template over the members of
    /// one shard: selects `pages[i]` for each member and — for an
    /// [`Strategy::Lds`] template — the matching slice of its global
    /// `lds_rates`, so per-shard scheduler indices stay local. An Lds
    /// template whose rates don't cover every member is left rate-less
    /// (its `build` then reports the misconfiguration as `Err`).
    pub fn shard_template(&self, pages: &[PageParams], members: &[usize]) -> CrawlerBuilder {
        let pages_s: Vec<PageParams> = members.iter().map(|&i| pages[i]).collect();
        let rates_s: Vec<f64> = members
            .iter()
            .filter_map(|&i| self.lds_rates.get(i).copied())
            .collect();
        let mut stamped = self.clone().pages(&pages_s);
        stamped.lds_rates =
            if rates_s.len() == members.len() { rates_s } else { Vec::new() };
        stamped
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngkit::Rng;
    use crate::sim::{generate_traces, simulate, CisDelay, SimConfig};

    fn pages(m: usize, seed: u64) -> Vec<PageParams> {
        let mut rng = Rng::new(seed);
        (0..m)
            .map(|_| PageParams {
                delta: rng.range(0.05, 1.0),
                mu: rng.range(0.05, 1.0),
                lam: rng.f64(),
                nu: rng.range(0.1, 0.6),
            })
            .collect()
    }

    #[test]
    fn builds_every_strategy() {
        let ps = pages(24, 1);
        for (strategy, suffix) in [
            (Strategy::Exact, ""),
            (Strategy::Lazy, "-LAZY"),
            (Strategy::LazyWithMargin(0.5), "-LAZY"),
            (Strategy::Sharded { shards: 3 }, "-SHARDED3"),
        ] {
            let mut sched = CrawlerBuilder::new()
                .policy(PolicyKind::GreedyNcis)
                .strategy(strategy)
                .pages(&ps)
                .build()
                .unwrap();
            assert_eq!(sched.name(), format!("GREEDY-NCIS{suffix}"));
            let mut rng = Rng::new(2);
            let traces = generate_traces(&ps, 20.0, CisDelay::None, &mut rng);
            let cfg = SimConfig::new(4.0, 20.0).unwrap();
            let res = simulate(&traces, &cfg, sched.as_mut());
            assert!((0.0..=1.0).contains(&res.accuracy), "{strategy:?}");
        }
    }

    #[test]
    fn builds_lds_from_rates() {
        let rates = [2.0, 1.0, 1.0];
        let mut sched =
            CrawlerBuilder::new().strategy(Strategy::Lds).lds_rates(&rates).build().unwrap();
        assert_eq!(sched.name(), "LDS");
        assert!(sched.select(0.0).is_some());
    }

    #[test]
    fn rejects_invalid_configurations() {
        let ps = pages(4, 3);
        assert!(CrawlerBuilder::new().build().is_err(), "no pages");
        assert!(
            CrawlerBuilder::new().strategy(Strategy::Lds).build().is_err(),
            "LDS without rates"
        );
        assert!(
            CrawlerBuilder::new()
                .strategy(Strategy::Sharded { shards: 0 })
                .pages(&ps)
                .build()
                .is_err(),
            "zero shards"
        );
        assert!(
            CrawlerBuilder::new()
                .strategy(Strategy::LazyWithMargin(1.5))
                .pages(&ps)
                .build()
                .is_err(),
            "margin out of range"
        );
    }

    #[test]
    fn policy_under_test_maps_to_strategy() {
        let ps = pages(10, 4);
        let g = CrawlerBuilder::new()
            .policy_under_test(PolicyUnderTest::Greedy(PolicyKind::Greedy))
            .pages(&ps)
            .build()
            .unwrap();
        assert_eq!(g.name(), "GREEDY");
        let l = CrawlerBuilder::new()
            .policy_under_test(PolicyUnderTest::Lazy(PolicyKind::GreedyCis))
            .pages(&ps)
            .build()
            .unwrap();
        assert_eq!(l.name(), "GREEDY-CIS-LAZY");
        let d = CrawlerBuilder::new()
            .policy_under_test(PolicyUnderTest::Lds)
            .lds_rates(&[1.0, 1.0])
            .build()
            .unwrap();
        assert_eq!(d.name(), "LDS");
    }

    #[test]
    fn build_local_mirrors_build() {
        let ps = pages(8, 7);
        let mut local = CrawlerBuilder::new()
            .policy(PolicyKind::GreedyNcis)
            .strategy(Strategy::Lazy)
            .pages(&ps)
            .build_local()
            .unwrap();
        assert_eq!(local.name(), "GREEDY-NCIS-LAZY");
        local.on_start(ps.len());
        assert!(local.select(1.0).is_some());
    }

    #[test]
    fn with_scenario_runs_every_strategy() {
        use crate::scenario::generators::{add_steady_churn, BornPageSpec};
        use crate::scenario::Scenario;
        let ps = pages(30, 9);
        let mut sc = Scenario::new(ps, 41);
        add_steady_churn(&mut sc, 0.01, 30.0, &BornPageSpec::default(), 42);
        for strategy in [
            Strategy::Exact,
            Strategy::Lazy,
            Strategy::Sharded { shards: 3 },
        ] {
            let builder = CrawlerBuilder::new()
                .policy(PolicyKind::GreedyNcis)
                .strategy(strategy)
                .with_scenario(sc.clone());
            let cfg = crate::sim::SimConfig::new(5.0, 30.0).unwrap();
            let res = builder.run_scenario(&cfg, 43).unwrap();
            assert!((0.0..=1.0).contains(&res.accuracy), "{strategy:?}");
            assert_eq!(res.ticks, 150);
        }
        // without a scenario, run_scenario is a usage error
        let bare = CrawlerBuilder::new().pages(&pages(4, 10));
        assert!(bare.run_scenario(&crate::sim::SimConfig::new(1.0, 1.0).unwrap(), 1).is_err());
    }

    #[test]
    fn trace_mode_knob_selects_the_engine() {
        use crate::scenario::{simulate_scenario_with, Scenario, ScenarioWorkspace};
        use crate::sim::TraceMode;
        let ps = pages(20, 11);
        let sc = Scenario::new(ps.clone(), 51);
        let cfg = crate::sim::SimConfig::new(4.0, 25.0).unwrap();
        let base = CrawlerBuilder::new()
            .policy(PolicyKind::GreedyNcis)
            .strategy(Strategy::Lazy)
            .with_scenario(sc.clone());
        // both modes run; same tick clock, different realizations
        let streamed = base.clone().run_scenario(&cfg, 7).unwrap();
        let materialized =
            base.clone().trace_mode(TraceMode::Materialized).run_scenario(&cfg, 7).unwrap();
        assert_eq!(streamed.ticks, materialized.ticks);
        assert!((0.0..=1.0).contains(&streamed.accuracy));
        assert!((0.0..=1.0).contains(&materialized.accuracy));
        // the materialized knob reproduces the direct materialized
        // entry point bit-for-bit
        let mut rng = Rng::new(7);
        let traces = generate_traces(&ps, cfg.horizon, sc.delay(), &mut rng);
        let mut ws = ScenarioWorkspace::new();
        let mut sched = base.build().unwrap();
        let direct = simulate_scenario_with(&mut ws, &traces, &cfg, &sc, sched.as_mut());
        assert_eq!(materialized.accuracy.to_bits(), direct.accuracy.to_bits());
        assert_eq!(materialized.crawl_counts, direct.crawl_counts);
    }

    #[test]
    fn run_traffic_serves_and_preserves_the_crawl_result() {
        use crate::serving::RequestTraffic;
        use crate::sim::{simulate_streamed_with, StreamedSource};
        use crate::sim::engine::SimWorkspace;
        let ps = pages(16, 21);
        let cfg = SimConfig::new(4.0, 30.0).unwrap();
        let base = CrawlerBuilder::new()
            .policy(PolicyKind::GreedyNcis)
            .strategy(Strategy::Lazy)
            .pages(&ps);
        // off traffic: the crawl result bit-matches the plain engine
        // and nothing is served
        let (off, m_off) =
            base.clone().with_traffic(RequestTraffic::off()).run_traffic(&cfg, 5).unwrap();
        let mut sched = base.build().unwrap();
        let mut rng = Rng::new(5);
        let source = StreamedSource::new(&ps, cfg.horizon, CisDelay::None, &mut rng).unwrap();
        let mut ws = SimWorkspace::new();
        let plain = simulate_streamed_with(&mut ws, source, &cfg, sched.as_mut());
        assert_eq!(off.accuracy.to_bits(), plain.accuracy.to_bits());
        assert_eq!(off.crawl_counts, plain.crawl_counts);
        assert_eq!(m_off.served, 0);
        // loaded traffic: serves land and conservation holds, while the
        // crawl side is still bit-identical (traffic owns its own RNG)
        let traffic = RequestTraffic::new(20.0, 1.0, 0xAB).unwrap();
        let (on, m_on) = base.clone().with_traffic(traffic).run_traffic(&cfg, 5).unwrap();
        assert_eq!(on.accuracy.to_bits(), plain.accuracy.to_bits());
        assert!(m_on.served > 0);
        assert_eq!(m_on.fresh_serves + m_on.stale_serves, m_on.served);
        // without with_traffic, run_traffic is a usage error
        assert!(base.run_traffic(&cfg, 5).is_err());
    }

    #[test]
    fn run_traffic_through_a_dynamic_world() {
        use crate::scenario::generators::{add_steady_churn, BornPageSpec};
        use crate::serving::RequestTraffic;
        let ps = pages(20, 23);
        let mut sc = Scenario::new(ps, 61);
        add_steady_churn(&mut sc, 0.02, 25.0, &BornPageSpec::default(), 62);
        let cfg = SimConfig::new(5.0, 25.0).unwrap();
        let traffic = RequestTraffic::new(30.0, 1.0, 0x5E).unwrap();
        for mode in [TraceMode::Streamed, TraceMode::Materialized] {
            let builder = CrawlerBuilder::new()
                .policy(PolicyKind::GreedyNcis)
                .strategy(Strategy::Lazy)
                .with_scenario(sc.clone())
                .with_traffic(traffic.clone())
                .trace_mode(mode);
            let (res, metrics) = builder.run_traffic(&cfg, 63).unwrap();
            assert!((0.0..=1.0).contains(&res.accuracy), "{mode:?}");
            assert!(metrics.served > 0, "{mode:?}");
            assert_eq!(
                metrics.fresh_serves + metrics.stale_serves,
                metrics.served,
                "{mode:?}"
            );
            // the crawl result matches the traffic-less scenario run
            let bare = CrawlerBuilder::new()
                .policy(PolicyKind::GreedyNcis)
                .strategy(Strategy::Lazy)
                .with_scenario(sc.clone())
                .trace_mode(mode)
                .run_scenario(&cfg, 63)
                .unwrap();
            assert_eq!(res.accuracy.to_bits(), bare.accuracy.to_bits(), "{mode:?}");
            assert_eq!(res.crawl_counts, bare.crawl_counts, "{mode:?}");
        }
    }

    #[test]
    fn learned_knowledge_wraps_and_oracle_stays_default() {
        let ps = pages(12, 31);
        let oracle =
            CrawlerBuilder::new().policy(PolicyKind::GreedyNcis).pages(&ps).build().unwrap();
        assert_eq!(oracle.name(), "GREEDY-NCIS", "default is oracle, no wrapper");
        let learned = CrawlerBuilder::new()
            .policy(PolicyKind::GreedyNcis)
            .pages(&ps)
            .knowledge(Knowledge::Learned(EstimatorConfig::default()))
            .build()
            .unwrap();
        assert_eq!(learned.name(), "LEARNED(GREEDY-NCIS)");
        let local = CrawlerBuilder::new()
            .policy(PolicyKind::GreedyNcis)
            .strategy(Strategy::Lazy)
            .pages(&ps)
            .knowledge(Knowledge::Learned(EstimatorConfig::default()))
            .build_local()
            .unwrap();
        assert_eq!(local.name(), "LEARNED(GREEDY-NCIS-LAZY)");
        // misconfiguration errors surface through the learned path too
        assert!(CrawlerBuilder::new()
            .knowledge(Knowledge::Learned(EstimatorConfig::default()))
            .build()
            .is_err());
    }

    #[test]
    fn template_reuse_stamps_pages_per_build() {
        // the pipeline idiom: one pages-less template, one build per shard
        let template = CrawlerBuilder::new()
            .policy(PolicyKind::GreedyNcis)
            .strategy(Strategy::Lazy);
        let a = pages(6, 5);
        let b = pages(9, 6);
        let sa = template.clone().pages(&a).build().unwrap();
        let sb = template.clone().pages(&b).build().unwrap();
        assert_eq!(sa.name(), sb.name());
    }
}
