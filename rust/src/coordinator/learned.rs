//! Oracle-free knowledge: the [`LearnedScheduler`] decorator.
//!
//! Wraps any [`CrawlScheduler`] and replaces its source of page
//! knowledge: the inner scheduler is constructed over *uninformative
//! priors* (see [`crate::CrawlerBuilder`] with
//! [`crate::Knowledge::Learned`]) and this decorator feeds it beliefs
//! learned purely from crawl outcomes via an
//! [`EstimatorBank`](crate::estimation::EstimatorBank):
//!
//! - [`CrawlScheduler::on_fetch_observed`] — the only learning signal:
//!   a successful fetch contributes one `(τ, n_CIS, changed)`
//!   observation.
//! - [`CrawlScheduler::on_crawl_failed`] — recorded as *no* change
//!   observation (the interval keeps running), so failed fetches never
//!   poison estimates.
//! - [`CrawlScheduler::on_params_changed`] — **ground truth is
//!   withheld**. Scenario drift events update only the page's
//!   importance weight μ (observable from request logs in a real
//!   deployment) and bump `EstimationStats::suppressed_truth`; the
//!   true (Δ, λ, ν) never reach the inner scheduler.
//!
//! Re-projection is budgeted: dirty pages queue FIFO and each `select`
//! tick flushes at most `EstimatorConfig::reproject_budget` of them
//! through the inner scheduler's `on_params_changed` (which lands in
//! `BeliefModel::set_page` for the greedy family) — O(budget) extra
//! work per tick, never O(m). Projections that would repeat the
//! previous belief bit-for-bit are skipped.

use std::collections::VecDeque;

use crate::estimation::{EstimationStats, EstimatorBank, EstimatorConfig};
use crate::params::PageParams;
use crate::sched::CrawlScheduler;

/// The uninformative-prior projection of a page: prior change rate, no
/// CIS channel, observable importance only.
pub(crate) fn prior_params(cfg: &EstimatorConfig, mu: f64) -> PageParams {
    let mu = if mu.is_finite() && mu >= 0.0 { mu } else { 0.0 };
    PageParams { delta: cfg.prior_delta, mu, lam: 0.0, nu: 0.0 }
}

/// Knowledge decorator: learns page parameters online and re-projects
/// them into the wrapped scheduler on a bounded per-tick budget.
#[derive(Debug)]
pub struct LearnedScheduler<S> {
    inner: S,
    cfg: EstimatorConfig,
    bank: EstimatorBank,
    /// Pristine importance weights, restored by `on_start`.
    initial_mus: Vec<f64>,
    /// Current (observable) importance per slot.
    mus: Vec<f64>,
    last_fetch: Vec<f64>,
    cis_count: Vec<u32>,
    live: Vec<bool>,
    dirty: Vec<bool>,
    queue: VecDeque<usize>,
    last_projected: Vec<Option<PageParams>>,
    /// Optional decision-trace handle: re-projections and trust-gate
    /// flips are recorded here. Observational only — no belief or
    /// projection depends on it.
    trace: Option<crate::trace::TraceHandle>,
}

impl<S: CrawlScheduler> LearnedScheduler<S> {
    /// Wrap `inner` (already constructed over prior-projected pages).
    /// `mus` are the observable importance weights of the initial
    /// population; everything else starts cold.
    pub fn new(inner: S, mus: Vec<f64>, cfg: EstimatorConfig) -> Self {
        let m = mus.len();
        Self {
            inner,
            cfg,
            bank: EstimatorBank::new(m, cfg),
            initial_mus: mus.clone(),
            mus,
            last_fetch: vec![0.0; m],
            cis_count: vec![0; m],
            live: vec![true; m],
            dirty: vec![false; m],
            queue: VecDeque::new(),
            last_projected: vec![None; m],
            trace: None,
        }
    }

    /// Estimation-loop counters (exact, seed-reproducible).
    pub fn stats(&self) -> &EstimationStats {
        self.bank.stats()
    }

    /// The underlying estimator bank (read-only).
    pub fn bank(&self) -> &EstimatorBank {
        &self.bank
    }

    /// The belief most recently projected into the inner scheduler for
    /// `page` (`None` before the first projection).
    pub fn projected(&self, page: usize) -> Option<PageParams> {
        self.last_projected.get(page).copied().flatten()
    }

    /// The wrapped scheduler.
    pub fn inner(&self) -> &S {
        &self.inner
    }

    fn ensure_slot(&mut self, page: usize) {
        if page >= self.mus.len() {
            let n = page + 1;
            self.mus.resize(n, 0.0);
            self.last_fetch.resize(n, 0.0);
            self.cis_count.resize(n, 0);
            self.live.resize(n, false);
            self.dirty.resize(n, false);
            self.last_projected.resize(n, None);
        }
    }

    fn mark_dirty(&mut self, page: usize) {
        if !self.dirty[page] {
            self.dirty[page] = true;
            self.queue.push_back(page);
        }
    }

    /// Flush up to `reproject_budget` dirty pages into the inner
    /// scheduler; count what the budget left behind.
    fn flush_dirty(&mut self, t: f64) {
        let t0 = self.trace.as_ref().and_then(crate::trace::TraceHandle::span_clock);
        let mut budget = self.cfg.reproject_budget;
        while budget > 0 {
            let Some(page) = self.queue.pop_front() else { break };
            self.dirty[page] = false;
            if !self.live[page] {
                continue;
            }
            budget -= 1;
            let params = self.bank.estimate(page, self.mus[page]);
            if self.last_projected[page] == Some(params) {
                continue;
            }
            // trust gate: the projected CIS rate λ̂ crossing zero is
            // the bank starting/stopping to trust the page's signals
            let was_open = self.last_projected[page].is_some_and(|p| p.lam > 0.0);
            if was_open != (params.lam > 0.0) {
                crate::trace::emit(self.trace.as_ref(), || crate::trace::TraceEvent::TrustGate {
                    t,
                    page: page as u32,
                    open: params.lam > 0.0,
                });
            }
            crate::trace::emit(self.trace.as_ref(), || crate::trace::TraceEvent::Reproject {
                t,
                page: page as u32,
            });
            self.inner.on_params_changed(page, &params, t);
            self.last_projected[page] = Some(params);
            self.bank.stats_mut().reprojections += 1;
        }
        self.bank.stats_mut().deferred += self.queue.len() as u64;
        if let Some(h) = &self.trace {
            h.span_observe(crate::trace::SpanKind::Reproject, t0);
        }
    }
}

impl<S: CrawlScheduler> CrawlScheduler for LearnedScheduler<S> {
    fn on_start(&mut self, m: usize) {
        self.inner.on_start(m);
        let mut mus = self.initial_mus.clone();
        mus.resize(m, 0.0);
        self.mus = mus;
        self.bank.reset(m);
        self.last_fetch.clear();
        self.last_fetch.resize(m, 0.0);
        self.cis_count.clear();
        self.cis_count.resize(m, 0);
        self.live.clear();
        self.live.resize(m, true);
        self.dirty.clear();
        self.dirty.resize(m, false);
        self.queue.clear();
        self.last_projected.clear();
        self.last_projected.resize(m, None);
    }

    fn on_cis(&mut self, page: usize, t: f64) {
        self.ensure_slot(page);
        self.cis_count[page] = self.cis_count[page].saturating_add(1);
        self.inner.on_cis(page, t);
    }

    fn on_crawl(&mut self, page: usize, t: f64) {
        self.ensure_slot(page);
        self.inner.on_crawl(page, t);
        self.last_fetch[page] = t;
        self.cis_count[page] = 0;
    }

    fn on_veto(&mut self, page: usize, t: f64) {
        self.inner.on_veto(page, t);
    }

    fn on_crawl_failed(&mut self, page: usize, t: f64, outcome: crate::fault::CrawlOutcome) {
        self.ensure_slot(page);
        // a failed fetch observes nothing about the content: the
        // crawl interval keeps running and no change indicator lands
        self.bank.note_failed(page);
        self.inner.on_crawl_failed(page, t, outcome);
    }

    fn on_fetch_observed(&mut self, page: usize, t: f64, changed: bool) {
        self.ensure_slot(page);
        if !self.live[page] {
            return;
        }
        let tau = t - self.last_fetch[page];
        self.bank.observe(page, tau, self.cis_count[page], changed);
        self.mark_dirty(page);
        self.inner.on_fetch_observed(page, t, changed);
    }

    fn on_page_added(&mut self, page: usize, params: &PageParams, t: f64) {
        self.ensure_slot(page);
        self.mus[page] = params.mu;
        self.bank.add_page(page);
        self.live[page] = true;
        self.last_fetch[page] = t;
        self.cis_count[page] = 0;
        self.last_projected[page] = None;
        // the inner scheduler sees only the observable part of the
        // newborn: importance, under the uninformative prior
        let projected = prior_params(&self.cfg, params.mu);
        self.inner.on_page_added(page, &projected, t);
    }

    fn on_page_removed(&mut self, page: usize, t: f64) {
        self.ensure_slot(page);
        self.live[page] = false;
        self.bank.remove_page(page);
        self.inner.on_page_removed(page, t);
    }

    fn on_params_changed(&mut self, page: usize, params: &PageParams, t: f64) {
        self.ensure_slot(page);
        let _ = t;
        // ground truth stays outside: only the observable importance
        // weight crosses, and the belief refresh rides the normal
        // budgeted re-projection path
        self.bank.stats_mut().suppressed_truth += 1;
        self.mus[page] = params.mu;
        self.mark_dirty(page);
    }

    fn select(&mut self, t: f64) -> Option<usize> {
        self.flush_dirty(t);
        self.inner.select(t)
    }

    fn attach_trace(&mut self, tr: crate::trace::TraceHandle) {
        self.inner.attach_trace(tr.clone());
        self.trace = Some(tr);
    }

    fn name(&self) -> String {
        format!("LEARNED({})", self.inner.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fault::CrawlOutcome;

    /// Inner-scheduler probe that records every `on_params_changed`.
    #[derive(Default)]
    struct Probe {
        projected: Vec<(usize, PageParams, f64)>,
        started: usize,
    }

    impl CrawlScheduler for Probe {
        fn on_start(&mut self, _m: usize) {
            self.started += 1;
            self.projected.clear();
        }
        fn on_params_changed(&mut self, page: usize, params: &PageParams, t: f64) {
            self.projected.push((page, *params, t));
        }
        fn select(&mut self, _t: f64) -> Option<usize> {
            None
        }
    }

    fn cfg() -> EstimatorConfig {
        EstimatorConfig { reproject_budget: 2, ..EstimatorConfig::default() }
    }

    #[test]
    fn truth_events_never_reach_the_inner_scheduler() {
        let mut sched = LearnedScheduler::new(Probe::default(), vec![0.5, 0.5], cfg());
        let truth = PageParams { delta: 7.0, mu: 0.9, lam: 0.8, nu: 0.3 };
        sched.on_params_changed(0, &truth, 1.0);
        assert_eq!(sched.stats().suppressed_truth, 1);
        // the flush projects a belief — but it is the cold prior with
        // the observable μ, never the true (Δ, λ, ν)
        sched.select(2.0);
        let (page, p, _) = sched.inner().projected[0];
        assert_eq!(page, 0);
        assert_eq!(p.mu, 0.9, "importance is observable and crosses");
        assert_eq!(p.delta, cfg().prior_delta, "true delta must not leak");
        assert_eq!((p.lam, p.nu), (0.0, 0.0), "true CIS quality must not leak");
    }

    #[test]
    fn reprojection_budget_defers_excess_pages() {
        let mut sched = LearnedScheduler::new(Probe::default(), vec![0.2; 5], cfg());
        for page in 0..5 {
            let truth = PageParams { delta: 1.0, mu: 0.1 * (page + 1) as f64, lam: 0.0, nu: 0.0 };
            sched.on_params_changed(page, &truth, 1.0);
        }
        sched.select(2.0);
        assert_eq!(sched.inner().projected.len(), 2, "budget is 2 per tick");
        assert_eq!(sched.stats().deferred, 3);
        sched.select(3.0);
        assert_eq!(sched.inner().projected.len(), 4);
        sched.select(4.0);
        assert_eq!(sched.inner().projected.len(), 5, "queue drains FIFO");
        assert_eq!(sched.stats().reprojections, 5);
    }

    #[test]
    fn identical_beliefs_are_not_reprojected() {
        let mut sched = LearnedScheduler::new(Probe::default(), vec![0.5], cfg());
        let truth = PageParams { delta: 3.0, mu: 0.5, lam: 0.1, nu: 0.1 };
        sched.on_params_changed(0, &truth, 1.0);
        sched.select(2.0);
        assert_eq!(sched.inner().projected.len(), 1);
        // same observable state again: dirty, but the projection is
        // bit-identical and must be skipped
        sched.on_params_changed(0, &truth, 3.0);
        sched.select(4.0);
        assert_eq!(sched.inner().projected.len(), 1);
        assert_eq!(sched.stats().reprojections, 1);
    }

    #[test]
    fn fetch_observations_feed_the_bank_and_failures_do_not() {
        let mut sched = LearnedScheduler::new(Probe::default(), vec![0.5], cfg());
        sched.on_cis(0, 0.5);
        sched.on_cis(0, 0.8);
        sched.on_fetch_observed(0, 1.0, true);
        sched.on_crawl(0, 1.0);
        assert_eq!(sched.stats().observations, 1);
        assert_eq!(sched.bank().rate_obs(0), 1);
        sched.on_crawl_failed(0, 2.0, CrawlOutcome::TransientError);
        assert_eq!(sched.stats().skipped_failed, 1);
        assert_eq!(sched.bank().rate_obs(0), 1, "failure recorded no observation");
        // the next successful fetch spans the failure: interval runs
        // from the last SUCCESSFUL crawl
        sched.on_fetch_observed(0, 4.0, false);
        sched.on_crawl(0, 4.0);
        assert_eq!(sched.stats().observations, 2);
    }

    #[test]
    fn removed_pages_stop_observing_until_rebirth() {
        let mut sched = LearnedScheduler::new(Probe::default(), vec![0.5, 0.4], cfg());
        sched.on_fetch_observed(1, 1.0, true);
        sched.on_crawl(1, 1.0);
        sched.on_page_removed(1, 2.0);
        sched.on_fetch_observed(1, 3.0, true);
        assert_eq!(sched.stats().observations, 1, "retired slot observes nothing");
        let born = PageParams { delta: 2.0, mu: 0.7, lam: 0.5, nu: 0.2 };
        sched.on_page_added(1, &born, 5.0);
        assert_eq!(sched.bank().rate_obs(1), 0, "reborn slot is cold");
        // the inner scheduler saw the newborn under the prior, not truth
        sched.on_fetch_observed(1, 6.0, false);
        sched.on_crawl(1, 6.0);
        assert_eq!(sched.stats().observations, 2);
    }

    #[test]
    fn on_start_restores_a_pristine_decorator() {
        let mut sched = LearnedScheduler::new(Probe::default(), vec![0.5, 0.4], cfg());
        sched.on_cis(0, 0.2);
        sched.on_fetch_observed(0, 1.0, true);
        sched.on_crawl(0, 1.0);
        sched.on_params_changed(1, &PageParams { delta: 9.0, mu: 0.9, lam: 0.0, nu: 0.0 }, 1.5);
        sched.on_start(2);
        assert_eq!(sched.inner().started, 1);
        assert_eq!(*sched.stats(), EstimationStats::default());
        assert_eq!(sched.bank().rate_obs(0), 0);
        assert_eq!(sched.projected(1), None);
        // the restored importance is the pristine one
        sched.select(0.5);
        assert!(sched.inner().projected.is_empty(), "nothing dirty after reset");
    }

    #[test]
    fn name_reflects_learned_mode() {
        let sched = LearnedScheduler::new(Probe::default(), vec![0.5], cfg());
        assert!(sched.name().starts_with("LEARNED("));
    }
}
