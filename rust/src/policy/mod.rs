//! Crawl-value functions and the thresholded policy family.
//!
//! [`value`] implements the analytical machinery of Theorem 1 / §5.1:
//! `ψ`, `w`, `f`, and the crawl value `V` for every policy variant.
//! [`PolicyKind`] selects which *beliefs* a discrete greedy policy holds
//! about the CIS process (the paper's GREEDY / GREEDY-CIS / GREEDY-NCIS /
//! G-NCIS-APPROX-J / GREEDY-CIS+ line-up), and maps scheduler state
//! (elapsed time + CIS count) to a crawl value.

pub mod multisource;
pub mod value;

use crate::params::{DerivedParams, PageParams};

/// Which crawl-value function a discrete greedy policy uses (§5.1, §6.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyKind {
    /// `V_GREEDY`: ignores CIS entirely (Cho & Garcia-Molina setting).
    Greedy,
    /// `V_GREEDY_CIS`: assumes CIS are noiseless (β = ∞); any pending
    /// signal saturates the page's value at μ̃/Δ.
    GreedyCis,
    /// `V_GREEDY_NCIS`: the exact noisy-CIS value (sum until the mask
    /// `i·β ≤ ι` runs out, capped at [`value::MAX_TERMS`]).
    GreedyNcis,
    /// `V_G_NCIS-APPROX-J`: truncate the sum at `j` terms (Appendix A.1).
    NcisApprox(u32),
    /// GREEDY-CIS+ (§6.7): GREEDY-CIS for high-quality-CIS pages
    /// (precision > 0.7 and recall > 0.6), plain GREEDY otherwise.
    GreedyCisPlus,
}

impl PolicyKind {
    /// Human-readable name matching the paper's plots.
    pub fn name(&self) -> String {
        match self {
            PolicyKind::Greedy => "GREEDY".into(),
            PolicyKind::GreedyCis => "GREEDY-CIS".into(),
            PolicyKind::GreedyNcis => "GREEDY-NCIS".into(),
            PolicyKind::NcisApprox(j) => format!("G-NCIS-APPROX-{j}"),
            PolicyKind::GreedyCisPlus => "GREEDY-CIS+".into(),
        }
    }

    /// Does this policy consume CIS events at all?
    pub fn uses_cis(&self) -> bool {
        !matches!(self, PolicyKind::Greedy)
    }

    /// Crawl value for a page in scheduler state `(tau_elap, n_cis)`.
    ///
    /// `raw`/`d` describe the *true* environment; each policy projects
    /// them onto its own beliefs (e.g. GREEDY-CIS pretends ν = 0).
    pub fn crawl_value(
        &self,
        raw: &PageParams,
        d: &DerivedParams,
        tau_elap: f64,
        n_cis: u32,
    ) -> f64 {
        match self {
            PolicyKind::Greedy => value::value_greedy(tau_elap, d.delta, d.mu),
            PolicyKind::GreedyCis => value::value_cis_state(d, tau_elap, n_cis),
            PolicyKind::GreedyNcis => {
                let iota = d.effective_time(tau_elap, n_cis);
                value::value_ncis(iota, d, value::MAX_TERMS)
            }
            PolicyKind::NcisApprox(j) => {
                let iota = d.effective_time(tau_elap, n_cis);
                value::value_ncis(iota, d, *j)
            }
            PolicyKind::GreedyCisPlus => {
                if raw.precision() > 0.7 && raw.recall() > 0.6 {
                    value::value_cis_state(d, tau_elap, n_cis)
                } else {
                    value::value_greedy(tau_elap, d.delta, d.mu)
                }
            }
        }
    }

    /// Upper bound on this page's crawl value, `μ̃ · w(∞) = μ̃/Δ`
    /// (geometric sum of the `w` coefficients). Used by the lazy
    /// scheduler to prune pages that can never reach the threshold.
    pub fn value_upper_bound(&self, d: &DerivedParams) -> f64 {
        d.mu / d.delta
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn env(lam: f64, nu: f64) -> (PageParams, DerivedParams) {
        let p = PageParams { delta: 0.8, mu: 0.5, lam, nu };
        let d = p.derive().unwrap();
        (p, d)
    }

    #[test]
    fn greedy_ignores_cis() {
        let (p, d) = env(0.6, 0.3);
        let v0 = PolicyKind::Greedy.crawl_value(&p, &d, 2.0, 0);
        let v3 = PolicyKind::Greedy.crawl_value(&p, &d, 2.0, 3);
        assert_eq!(v0, v3);
    }

    #[test]
    fn cis_saturates_on_signal() {
        let (p, d) = env(0.8, 0.0);
        let v = PolicyKind::GreedyCis.crawl_value(&p, &d, 0.5, 1);
        assert!((v - d.mu / d.delta).abs() < 1e-12);
        let v0 = PolicyKind::GreedyCis.crawl_value(&p, &d, 0.5, 0);
        assert!(v0 < v);
    }

    #[test]
    fn ncis_value_increases_with_signals() {
        let (p, d) = env(0.6, 0.3);
        let v0 = PolicyKind::GreedyNcis.crawl_value(&p, &d, 1.0, 0);
        let v1 = PolicyKind::GreedyNcis.crawl_value(&p, &d, 1.0, 1);
        let v2 = PolicyKind::GreedyNcis.crawl_value(&p, &d, 1.0, 2);
        assert!(v0 < v1 && v1 < v2, "{v0} {v1} {v2}");
    }

    #[test]
    fn approx_converges_to_exact() {
        let (p, d) = env(0.6, 0.5);
        let tau = 3.0;
        let exact = PolicyKind::GreedyNcis.crawl_value(&p, &d, tau, 2);
        let a1 = PolicyKind::NcisApprox(1).crawl_value(&p, &d, tau, 2);
        let a8 = PolicyKind::NcisApprox(8).crawl_value(&p, &d, tau, 2);
        assert!((a8 - exact).abs() <= (a1 - exact).abs() + 1e-15);
    }

    #[test]
    fn cis_plus_splits_on_quality() {
        // high quality: precision 0.9, recall 0.8
        let hp = PageParams::from_quality(0.8, 0.5, 0.9, 0.8);
        let hd = hp.derive().unwrap();
        let v_plus = PolicyKind::GreedyCisPlus.crawl_value(&hp, &hd, 1.0, 1);
        let v_cis = PolicyKind::GreedyCis.crawl_value(&hp, &hd, 1.0, 1);
        assert_eq!(v_plus, v_cis);
        // low quality falls back to GREEDY
        let lp = PageParams::from_quality(0.8, 0.5, 0.1, 0.3);
        let ld = lp.derive().unwrap();
        let v_plus = PolicyKind::GreedyCisPlus.crawl_value(&lp, &ld, 1.0, 4);
        let v_greedy = PolicyKind::Greedy.crawl_value(&lp, &ld, 1.0, 0);
        assert_eq!(v_plus, v_greedy);
    }

    #[test]
    fn upper_bound_holds() {
        let (p, d) = env(0.6, 0.3);
        let ub = PolicyKind::GreedyNcis.value_upper_bound(&d);
        for n in 0..10 {
            for k in 0..60 {
                let v = PolicyKind::GreedyNcis.crawl_value(&p, &d, k as f64 * 0.5, n);
                assert!(v <= ub + 1e-9, "V={v} > ub={ub} at n={n} k={k}");
            }
        }
    }
}
