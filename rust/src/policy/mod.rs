//! Crawl-value functions and the thresholded policy family.
//!
//! [`value`] implements the analytical machinery of Theorem 1 / §5.1:
//! `ψ`, `w`, `f`, and the crawl value `V` for every policy variant.
//! [`PolicyKind`] selects which *beliefs* a discrete greedy policy holds
//! about the CIS process (the paper's GREEDY / GREEDY-CIS / GREEDY-NCIS /
//! G-NCIS-APPROX-J / GREEDY-CIS+ line-up), and maps scheduler state
//! (elapsed time + CIS count) to a crawl value. [`belief::BeliefModel`]
//! carries the per-page belief projection shared by the native and
//! batched (PJRT) value paths, and [`PolicyUnderTest`] names a full
//! policy-under-test configuration (value function × scheduling
//! strategy) with a round-trippable textual form.

pub mod belief;
pub mod multisource;
pub mod value;

use std::fmt;
use std::str::FromStr;

use crate::error::Error;
use crate::params::{DerivedParams, PageParams};

pub use belief::{belief_params, BeliefModel};

/// GREEDY-CIS+ trusts a page's signals only above this precision (§6.7).
pub const CIS_PLUS_MIN_PRECISION: f64 = 0.7;
/// GREEDY-CIS+ trusts a page's signals only above this recall (§6.7).
pub const CIS_PLUS_MIN_RECALL: f64 = 0.6;

/// Does GREEDY-CIS+ treat this page's CIS as trustworthy?
/// (precision > 0.7 and recall > 0.6, the §6.7 thresholds.)
#[inline]
pub fn cis_plus_trusts(raw: &PageParams) -> bool {
    raw.precision() > CIS_PLUS_MIN_PRECISION && raw.recall() > CIS_PLUS_MIN_RECALL
}

/// Which crawl-value function a discrete greedy policy uses (§5.1, §6.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyKind {
    /// `V_GREEDY`: ignores CIS entirely (Cho & Garcia-Molina setting).
    Greedy,
    /// `V_GREEDY_CIS`: assumes CIS are noiseless (β = ∞); any pending
    /// signal saturates the page's value at μ̃/Δ.
    GreedyCis,
    /// `V_GREEDY_NCIS`: the exact noisy-CIS value (sum until the mask
    /// `i·β ≤ ι` runs out, capped at [`value::MAX_TERMS`]).
    GreedyNcis,
    /// `V_G_NCIS-APPROX-J`: truncate the sum at `j` terms (Appendix A.1).
    NcisApprox(u32),
    /// GREEDY-CIS+ (§6.7): GREEDY-CIS for high-quality-CIS pages (see
    /// [`cis_plus_trusts`]), plain GREEDY otherwise.
    GreedyCisPlus,
}

impl PolicyKind {
    /// Human-readable name matching the paper's plots.
    pub fn name(&self) -> String {
        self.to_string()
    }

    /// Does this policy consume CIS events at all?
    pub fn uses_cis(&self) -> bool {
        !matches!(self, PolicyKind::Greedy)
    }

    /// Crawl value for a page in scheduler state `(tau_elap, n_cis)`.
    ///
    /// `raw`/`d` describe the *true* environment; each policy projects
    /// them onto its own beliefs (e.g. GREEDY-CIS pretends ν = 0).
    pub fn crawl_value(
        &self,
        raw: &PageParams,
        d: &DerivedParams,
        tau_elap: f64,
        n_cis: u32,
    ) -> f64 {
        match self {
            PolicyKind::Greedy => value::value_greedy(tau_elap, d.delta, d.mu),
            PolicyKind::GreedyCis => value::value_cis_state(d, tau_elap, n_cis),
            PolicyKind::GreedyNcis => {
                let iota = d.effective_time(tau_elap, n_cis);
                value::value_ncis(iota, d, value::MAX_TERMS)
            }
            PolicyKind::NcisApprox(j) => {
                let iota = d.effective_time(tau_elap, n_cis);
                value::value_ncis(iota, d, *j)
            }
            PolicyKind::GreedyCisPlus => {
                if cis_plus_trusts(raw) {
                    value::value_cis_state(d, tau_elap, n_cis)
                } else {
                    value::value_greedy(tau_elap, d.delta, d.mu)
                }
            }
        }
    }

    /// Upper bound on this page's crawl value, `μ̃ · w(∞) = μ̃/Δ`
    /// (geometric sum of the `w` coefficients). Used by the lazy
    /// scheduler to prune pages that can never reach the threshold.
    pub fn value_upper_bound(&self, d: &DerivedParams) -> f64 {
        d.mu / d.delta
    }
}

impl fmt::Display for PolicyKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PolicyKind::Greedy => write!(f, "GREEDY"),
            PolicyKind::GreedyCis => write!(f, "GREEDY-CIS"),
            PolicyKind::GreedyNcis => write!(f, "GREEDY-NCIS"),
            PolicyKind::NcisApprox(j) => write!(f, "G-NCIS-APPROX-{j}"),
            PolicyKind::GreedyCisPlus => write!(f, "GREEDY-CIS+"),
        }
    }
}

impl FromStr for PolicyKind {
    type Err = Error;

    fn from_str(s: &str) -> Result<Self, Error> {
        match s {
            "GREEDY" => Ok(PolicyKind::Greedy),
            "GREEDY-CIS" => Ok(PolicyKind::GreedyCis),
            "GREEDY-NCIS" => Ok(PolicyKind::GreedyNcis),
            "GREEDY-CIS+" => Ok(PolicyKind::GreedyCisPlus),
            other => {
                if let Some(j) = other.strip_prefix("G-NCIS-APPROX-") {
                    let j: u32 = j.parse().map_err(|_| {
                        Error::Usage(format!("bad approximation level in {other}"))
                    })?;
                    Ok(PolicyKind::NcisApprox(j))
                } else {
                    Err(Error::Usage(format!("unknown policy `{other}`")))
                }
            }
        }
    }
}

/// Which discrete policy implementation an experiment cell runs: a
/// value function plus the scheduling strategy that drives it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyUnderTest {
    /// Algorithm 1 with the given value function (exact argmax).
    Greedy(PolicyKind),
    /// Algorithm 1 via the §5.2 lazy scheduler.
    Lazy(PolicyKind),
    /// LDS over the no-CIS continuous optimum (Azar et al.).
    Lds,
}

impl PolicyUnderTest {
    /// Display name (as printed in the paper's plots).
    pub fn name(&self) -> String {
        self.to_string()
    }
}

impl fmt::Display for PolicyUnderTest {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PolicyUnderTest::Greedy(k) => write!(f, "{k}"),
            PolicyUnderTest::Lazy(k) => write!(f, "{k}-LAZY"),
            PolicyUnderTest::Lds => write!(f, "LDS"),
        }
    }
}

impl FromStr for PolicyUnderTest {
    type Err = Error;

    fn from_str(s: &str) -> Result<Self, Error> {
        let (base, lazy) = match s.strip_suffix("-LAZY") {
            Some(b) => (b, true),
            None => (s, false),
        };
        if base == "LDS" {
            if lazy {
                return Err(Error::Usage("LDS has no lazy variant".into()));
            }
            return Ok(PolicyUnderTest::Lds);
        }
        let kind: PolicyKind = base.parse()?;
        Ok(if lazy { PolicyUnderTest::Lazy(kind) } else { PolicyUnderTest::Greedy(kind) })
    }
}

/// Parse a policy name (as printed in the paper's plots); thin wrapper
/// over the [`FromStr`] impl for call sites that prefer a function.
pub fn parse_policy(name: &str) -> crate::Result<PolicyUnderTest> {
    name.parse()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn env(lam: f64, nu: f64) -> (PageParams, DerivedParams) {
        let p = PageParams { delta: 0.8, mu: 0.5, lam, nu };
        let d = p.derive().unwrap();
        (p, d)
    }

    #[test]
    fn greedy_ignores_cis() {
        let (p, d) = env(0.6, 0.3);
        let v0 = PolicyKind::Greedy.crawl_value(&p, &d, 2.0, 0);
        let v3 = PolicyKind::Greedy.crawl_value(&p, &d, 2.0, 3);
        assert_eq!(v0, v3);
    }

    #[test]
    fn cis_saturates_on_signal() {
        let (p, d) = env(0.8, 0.0);
        let v = PolicyKind::GreedyCis.crawl_value(&p, &d, 0.5, 1);
        assert!((v - d.mu / d.delta).abs() < 1e-12);
        let v0 = PolicyKind::GreedyCis.crawl_value(&p, &d, 0.5, 0);
        assert!(v0 < v);
    }

    #[test]
    fn ncis_value_increases_with_signals() {
        let (p, d) = env(0.6, 0.3);
        let v0 = PolicyKind::GreedyNcis.crawl_value(&p, &d, 1.0, 0);
        let v1 = PolicyKind::GreedyNcis.crawl_value(&p, &d, 1.0, 1);
        let v2 = PolicyKind::GreedyNcis.crawl_value(&p, &d, 1.0, 2);
        assert!(v0 < v1 && v1 < v2, "{v0} {v1} {v2}");
    }

    #[test]
    fn approx_converges_to_exact() {
        let (p, d) = env(0.6, 0.5);
        let tau = 3.0;
        let exact = PolicyKind::GreedyNcis.crawl_value(&p, &d, tau, 2);
        let a1 = PolicyKind::NcisApprox(1).crawl_value(&p, &d, tau, 2);
        let a8 = PolicyKind::NcisApprox(8).crawl_value(&p, &d, tau, 2);
        assert!((a8 - exact).abs() <= (a1 - exact).abs() + 1e-15);
    }

    #[test]
    fn cis_plus_splits_on_quality() {
        // high quality: precision 0.9, recall 0.8
        let hp = PageParams::from_quality(0.8, 0.5, 0.9, 0.8);
        let hd = hp.derive().unwrap();
        assert!(cis_plus_trusts(&hp));
        let v_plus = PolicyKind::GreedyCisPlus.crawl_value(&hp, &hd, 1.0, 1);
        let v_cis = PolicyKind::GreedyCis.crawl_value(&hp, &hd, 1.0, 1);
        assert_eq!(v_plus, v_cis);
        // low quality falls back to GREEDY
        let lp = PageParams::from_quality(0.8, 0.5, 0.1, 0.3);
        let ld = lp.derive().unwrap();
        assert!(!cis_plus_trusts(&lp));
        let v_plus = PolicyKind::GreedyCisPlus.crawl_value(&lp, &ld, 1.0, 4);
        let v_greedy = PolicyKind::Greedy.crawl_value(&lp, &ld, 1.0, 0);
        assert_eq!(v_plus, v_greedy);
    }

    #[test]
    fn quality_thresholds_are_the_shared_consts() {
        // just above both thresholds: trusted; at a threshold: not
        // (strict inequalities, as in §6.7)
        let above = PageParams::from_quality(
            0.8,
            0.5,
            CIS_PLUS_MIN_PRECISION + 0.01,
            CIS_PLUS_MIN_RECALL + 0.01,
        );
        assert!(cis_plus_trusts(&above));
        let at = PageParams::from_quality(0.8, 0.5, CIS_PLUS_MIN_PRECISION, CIS_PLUS_MIN_RECALL);
        assert!(!cis_plus_trusts(&at));
    }

    #[test]
    fn upper_bound_holds() {
        let (p, d) = env(0.6, 0.3);
        let ub = PolicyKind::GreedyNcis.value_upper_bound(&d);
        for n in 0..10 {
            for k in 0..60 {
                let v = PolicyKind::GreedyNcis.crawl_value(&p, &d, k as f64 * 0.5, n);
                assert!(v <= ub + 1e-9, "V={v} > ub={ub} at n={n} k={k}");
            }
        }
    }

    #[test]
    fn policy_names_round_trip() {
        // every policy name the CLI accepts must round-trip through
        // FromStr -> Display, including -LAZY suffixes and the
        // G-NCIS-APPROX-j family
        for name in [
            "GREEDY",
            "GREEDY-CIS",
            "GREEDY-NCIS",
            "GREEDY-CIS+",
            "G-NCIS-APPROX-1",
            "G-NCIS-APPROX-2",
            "G-NCIS-APPROX-7",
            "G-NCIS-APPROX-64",
            "LDS",
            "GREEDY-LAZY",
            "GREEDY-CIS-LAZY",
            "GREEDY-NCIS-LAZY",
            "GREEDY-CIS+-LAZY",
            "G-NCIS-APPROX-3-LAZY",
        ] {
            let put: PolicyUnderTest = name.parse().unwrap();
            assert_eq!(put.to_string(), name, "round trip of {name}");
            assert_eq!(put.name(), name);
            // parse_policy is the same parser
            assert_eq!(parse_policy(name).unwrap(), put);
        }
        // PolicyKind round-trips on its own for the non-strategy names
        for name in ["GREEDY", "GREEDY-CIS", "GREEDY-NCIS", "GREEDY-CIS+", "G-NCIS-APPROX-5"] {
            let kind: PolicyKind = name.parse().unwrap();
            assert_eq!(kind.to_string(), name);
            assert_eq!(kind.name(), name);
        }
    }

    #[test]
    fn bad_policy_names_rejected() {
        assert!("NOPE".parse::<PolicyUnderTest>().is_err());
        assert!("G-NCIS-APPROX-x".parse::<PolicyUnderTest>().is_err());
        assert!("LDS-LAZY".parse::<PolicyUnderTest>().is_err());
        assert!("greedy".parse::<PolicyKind>().is_err());
        assert!("".parse::<PolicyUnderTest>().is_err());
    }
}
