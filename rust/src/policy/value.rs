//! Analytical crawl-value machinery (Theorem 1, Lemma 4, §5.1).
//!
//! All functions take the derived parametrization [`DerivedParams`] and
//! mirror the Python oracle (`ref.py`) so golden tests agree to f64
//! accuracy. The native implementations here are also the fallback value
//! engine when PJRT artifacts are not available.

use crate::params::{DerivedParams, ParamColumns};
use crate::special::exp_residual;

/// Hard cap on the number of residual terms: `R^i(x)` for `i ≥ 64` is
/// numerically 0 for every argument that can survive the `i·β ≤ ι` mask
/// in a realistic environment.
pub const MAX_TERMS: u32 = 64;

/// `ψ(ι; E)` and `w(ι; E)` of Lemma 4, truncated at `terms` residual
/// terms (the exact values once `terms > ι/β`).
///
/// ```text
/// ψ(ι) = Σ_{i=0}^{⌊ι/β⌋} (1/γ) R^i(γ(ι − iβ))        expected crawl interval
/// w(ι) = Σ_{i=0}^{⌊ι/β⌋} ν^i/(Δ+ν)^{i+1} R^i((α+γ)(ι − iβ))
/// ```
///
/// The no-CIS limit `γ → 0` degenerates to `ψ = ι`, `w = R^0(αι)/α`.
pub fn psi_w(iota: f64, d: &DerivedParams, terms: u32) -> (f64, f64) {
    if iota <= 0.0 {
        return (0.0, 0.0);
    }
    if d.gamma <= 0.0 {
        // GREEDY limit
        let w = exp_residual(0, d.alpha * iota) / d.alpha;
        return (iota, w);
    }
    let ag = d.alpha + d.gamma;
    let dn = d.delta + d.nu;
    let mut psi = 0.0;
    let mut w = 0.0;
    let mut coef = 1.0 / dn; // ν^i / (Δ+ν)^{i+1}
    let terms = terms.min(MAX_TERMS);
    for i in 0..terms {
        let off = if d.beta.is_finite() {
            iota - i as f64 * d.beta
        } else if i == 0 {
            iota
        } else {
            break;
        };
        if off < 0.0 {
            break;
        }
        psi += exp_residual(i, d.gamma * off) / d.gamma;
        w += coef * exp_residual(i, ag * off);
        coef *= d.nu / dn;
    }
    (psi, w)
}

/// Crawl frequency `f(ι; E) = 1/ψ(ι; E)` of the thresholded policy.
pub fn frequency(iota: f64, d: &DerivedParams, terms: u32) -> f64 {
    if iota == f64::INFINITY {
        return 0.0;
    }
    let (psi, _) = psi_w(iota, d, terms);
    if psi <= 0.0 {
        f64::INFINITY
    } else {
        1.0 / psi
    }
}

/// General crawl value `V(ι; E) = μ̃ (w(ι) − e^{−αι} ψ(ι))`.
///
/// `terms = MAX_TERMS` gives `V_GREEDY_NCIS` (exact); smaller `terms`
/// gives `V_G_NCIS-APPROX-J`. `ι = ∞` saturates at `μ̃ w(∞) = μ̃/Δ`…
/// truncated to `terms` coefficients of the geometric series.
pub fn value_ncis(iota: f64, d: &DerivedParams, terms: u32) -> f64 {
    if iota <= 0.0 {
        return 0.0;
    }
    if iota == f64::INFINITY {
        // lim V = μ̃ w(∞): Σ_{i<terms} ν^i/(Δ+ν)^{i+1}
        let dn = d.delta + d.nu;
        if d.gamma <= 0.0 || !d.beta.is_finite() {
            // no CIS (γ=0, α=Δ) or noiseless CIS: single term 1/(Δ+ν)=1/Δ
            return d.mu / if d.gamma <= 0.0 { d.delta } else { dn };
        }
        let r = d.nu / dn;
        let k = terms.min(MAX_TERMS);
        let geo = if r < 1.0 - 1e-12 {
            (1.0 - r.powi(k as i32)) / (1.0 - r)
        } else {
            k as f64
        };
        return d.mu * geo / dn;
    }
    // Inline ψ/w accumulation with rigorous early termination — the
    // scheduler hot path. Tail bounds (all residuals ≤ 1):
    //   w-tail   ≤ Σ_{j>i} ν^j/(Δ+ν)^{j+1} = coef_{i+1} / (1 − ν/(Δ+ν))
    //   ψ-tail   ≤ (remaining term count) / γ
    // so once (w_tail + e^{−αι}·ψ_tail) < 1e-14·w the remaining terms
    // cannot move V at f64 accuracy. Cuts the 64-term worst case to a
    // handful of terms for long-elapsed pages (see EXPERIMENTS.md §Perf).
    if d.gamma <= 0.0 {
        let (psi, w) = psi_w(iota, d, terms);
        return d.mu * (w - (-d.alpha * iota).exp() * psi);
    }
    let ag = d.alpha + d.gamma;
    let dn = d.delta + d.nu;
    let ratio = d.nu / dn;
    let ea = (-d.alpha * iota).exp();
    // β = 0 fast path (λ = 0 pages: signals carry no information, every
    // term shares the same argument): one exp per sum instead of one per
    // term. Restricted to the direct-branch regime x ≥ 0.5 where the
    // shared partial-sum evaluation is exact.
    if d.beta == 0.0 && d.gamma * iota >= 0.5 {
        let n = terms.min(MAX_TERMS);
        let (w, psi) = crate::special::exp_residual_geom_sum(
            n,
            d.gamma * iota,
            1.0 / dn,
            ratio,
            ag * iota,
        );
        return d.mu * (w - ea * psi / d.gamma);
    }
    let max_i = if d.beta.is_finite() {
        ((iota / d.beta) as u32).saturating_add(1).min(terms.min(MAX_TERMS))
    } else {
        1
    };
    let mut psi = 0.0;
    let mut w = 0.0;
    let mut coef = 1.0 / dn;
    let mut i = 0u32;
    while i < max_i {
        let off = if d.beta.is_finite() { iota - i as f64 * d.beta } else { iota };
        if off < 0.0 {
            break;
        }
        // high-order negligibility cutoff: R^i(y) = P(i+1, y) with
        // y < 0.135 (i+1) is below e^{-(i+1)} by Chernoff
        // (ratio e·y/(i+1) < 1/e), so for i ≥ 40 both residuals are
        // < 1e-17 and every later term is smaller still (arguments only
        // shrink with i). One compare per term — this is what caps the
        // O(i) partial-sum work for long-elapsed pages.
        if i >= 40 && ag * off < 0.135 * (i as f64 + 1.0) {
            break;
        }
        let (rx, ry) = crate::special::exp_residual_pair(i, d.gamma * off, ag * off);
        psi += rx / d.gamma;
        w += coef * ry;
        coef *= ratio;
        i += 1;
        if w > 0.0 {
            let w_tail = coef / (1.0 - ratio).max(1e-300);
            let psi_tail = ea * (max_i - i) as f64 / d.gamma;
            if w_tail + psi_tail < 1e-14 * w {
                break;
            }
        }
    }
    d.mu * (w - ea * psi)
}

/// Batched crawl values over columnar parameters (the native hot-path
/// kernel): for every `k`,
///
/// ```text
/// out[k] = value_ncis(iotas[k], &cols.get(pages[k]), terms)
/// ```
///
/// **bit-identically** — the scalar [`value_ncis`] is the parity oracle
/// (see `tests/columnar_parity.rs`), and each page runs the exact same
/// operation sequence, including the per-page early-termination tail
/// bound. The batched form buys the schedulers column-gather locality
/// and a branch-predictable chunk loop with zero per-call allocation
/// (callers own `out`); the transcendental core stays scalar precisely
/// so the oracle equality holds to the last bit.
///
/// `pages[k]` indexes into `cols` (a gather), so callers can evaluate
/// an arbitrary subset — the exact scheduler's pruned argmax chunks and
/// the lazy scheduler's hot-set re-key both do.
pub fn values_ncis_into(
    out: &mut [f64],
    iotas: &[f64],
    pages: &[u32],
    cols: &ParamColumns,
    terms: u32,
) {
    assert_eq!(out.len(), iotas.len(), "values_ncis_into: out/iotas length mismatch");
    assert_eq!(out.len(), pages.len(), "values_ncis_into: out/pages length mismatch");
    for ((o, &iota), &p) in out.iter_mut().zip(iotas).zip(pages) {
        let d = cols.get(p as usize);
        *o = value_ncis(iota, &d, terms);
    }
}

/// Expected objective contribution `o(ι; E) = μ̃ · w(ι) · f(ι)` — the
/// importance-weighted long-run freshness of a page crawled at threshold
/// `ι` (used to score continuous policies analytically).
pub fn objective(iota: f64, d: &DerivedParams, terms: u32) -> f64 {
    if iota <= 0.0 {
        return d.mu; // crawl continuously: always fresh
    }
    if iota == f64::INFINITY {
        return 0.0;
    }
    let (psi, w) = psi_w(iota, d, terms);
    if psi <= 0.0 {
        d.mu
    } else {
        d.mu * w / psi
    }
}

/// `V_GREEDY(ι) = (μ̃/Δ) R^1(Δι)` — no CIS (§5.1).
pub fn value_greedy(iota: f64, delta: f64, mu: f64) -> f64 {
    if iota <= 0.0 {
        return 0.0;
    }
    if iota == f64::INFINITY {
        return mu / delta;
    }
    mu / delta * exp_residual(1, delta * iota)
}

/// `V_GREEDY_CIS(ι)` — noiseless-CIS belief (§5.1): β̂ = ∞ and
/// `α̂ = max(Δ − γ, ε)` (the policy attributes every observed signal to a
/// real change). A pending signal saturates the value at `μ̃/Δ`.
pub fn value_cis(iota: f64, delta: f64, mu: f64, gamma: f64) -> f64 {
    if iota <= 0.0 {
        return 0.0;
    }
    if gamma <= 0.0 {
        return value_greedy(iota, delta, mu);
    }
    if iota == f64::INFINITY {
        return mu / delta;
    }
    let alpha = (delta - gamma).max(1e-6 * delta);
    let ag = alpha + gamma;
    mu * (exp_residual(0, ag * iota) / ag
        - (-alpha * iota).exp() * exp_residual(0, gamma * iota) / gamma)
}

/// GREEDY-CIS evaluated on scheduler state: saturated if any CIS is
/// pending, else `value_cis` of the elapsed time.
pub fn value_cis_state(d: &DerivedParams, tau_elap: f64, n_cis: u32) -> f64 {
    if n_cis > 0 {
        d.mu / d.delta
    } else {
        value_cis(tau_elap, d.delta, d.mu, d.gamma)
    }
}

/// Inverse of `V(·; E)` (monotone increasing by Lemma 2): smallest `ι`
/// with `V(ι) ≥ target`, or `None` if the target exceeds `sup V`.
/// Exponential bracket + bisection.
pub fn inverse_value(target: f64, d: &DerivedParams, terms: u32) -> Option<f64> {
    if target <= 0.0 {
        return Some(0.0);
    }
    let sup = value_ncis(f64::INFINITY, d, terms);
    if target >= sup {
        return None;
    }
    let mut hi = 1.0 / d.delta.max(1e-12);
    let mut lo = 0.0;
    let mut iters = 0;
    while value_ncis(hi, d, terms) < target {
        lo = hi;
        hi *= 2.0;
        iters += 1;
        if iters > 200 {
            return None; // target is numerically at the sup
        }
    }
    for _ in 0..100 {
        let mid = 0.5 * (lo + hi);
        if value_ncis(mid, d, terms) < target {
            lo = mid;
        } else {
            hi = mid;
        }
        if hi - lo <= 1e-12 * hi.max(1.0) {
            break;
        }
    }
    Some(0.5 * (lo + hi))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::PageParams;

    fn derived(delta: f64, mu: f64, lam: f64, nu: f64) -> DerivedParams {
        PageParams { delta, mu, lam, nu }.derive().unwrap()
    }

    #[test]
    fn greedy_limit_matches_closed_form() {
        let d = derived(0.8, 0.5, 0.0, 0.0);
        for &iota in &[0.1, 1.0, 5.0, 20.0] {
            let v = value_ncis(iota, &d, MAX_TERMS);
            let vg = value_greedy(iota, 0.8, 0.5);
            assert!((v - vg).abs() < 1e-9, "iota={iota}: {v} vs {vg}");
        }
    }

    #[test]
    fn noiseless_limit_matches_cis_form() {
        // nu = 0 => beta = inf => only i=0 term; belief alpha-hat = Δ−γ
        // coincides with the true alpha here.
        let d = derived(1.0, 0.5, 0.6, 0.0);
        for &iota in &[0.1, 1.0, 5.0] {
            let v = value_ncis(iota, &d, MAX_TERMS);
            let vc = value_cis(iota, 1.0, 0.5, 0.6);
            assert!((v - vc).abs() < 1e-6, "iota={iota}: {v} vs {vc}");
        }
    }

    #[test]
    fn value_monotone_and_bounded() {
        let d = derived(0.8, 0.5, 0.6, 0.3);
        let mut prev = -1.0;
        for k in 1..300 {
            let iota = k as f64 * 0.1;
            let v = value_ncis(iota, &d, MAX_TERMS);
            assert!(v >= prev - 1e-12, "V not monotone at {iota}");
            assert!(v <= d.mu / d.delta + 1e-9);
            prev = v;
        }
        assert!((value_ncis(f64::INFINITY, &d, MAX_TERMS) - d.mu / d.delta).abs() < 1e-9);
    }

    #[test]
    fn frequency_monotone_decreasing() {
        let d = derived(0.8, 0.5, 0.6, 0.3);
        let mut prev = f64::INFINITY;
        for k in 1..200 {
            let f = frequency(k as f64 * 0.1, &d, MAX_TERMS);
            assert!(f <= prev + 1e-12);
            prev = f;
        }
    }

    #[test]
    fn frequency_no_cis_is_inverse_iota() {
        let d = derived(0.8, 0.5, 0.0, 0.0);
        assert!((frequency(4.0, &d, MAX_TERMS) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn lemma3_derivative_identity() {
        // w'(x) = exp(-alpha x) psi'(x), away from the kinks at i*beta
        let d = derived(0.9, 0.4, 0.5, 0.4);
        let x = 0.37 * d.beta; // safely inside (0, beta)
        let h = 1e-6;
        let (p1, w1) = psi_w(x + h, &d, MAX_TERMS);
        let (p0, w0) = psi_w(x - h, &d, MAX_TERMS);
        let dpsi = (p1 - p0) / (2.0 * h);
        let dw = (w1 - w0) / (2.0 * h);
        let want = (-d.alpha * x).exp() * dpsi;
        assert!((dw - want).abs() < 1e-6 * want.abs().max(1e-6), "{dw} vs {want}");
    }

    #[test]
    fn psi_matches_single_interval_closed_form() {
        // For iota <= beta: psi = (1 - exp(-gamma iota))/gamma (proof of Lemma 4)
        let d = derived(1.0, 0.5, 0.5, 0.5);
        let iota = 0.8 * d.beta.min(2.0);
        let (psi, _) = psi_w(iota, &d, MAX_TERMS);
        let want = (1.0 - (-d.gamma * iota).exp()) / d.gamma;
        assert!((psi - want).abs() < 1e-12);
    }

    #[test]
    fn objective_decreasing_in_iota() {
        let d = derived(0.8, 0.5, 0.6, 0.3);
        let mut prev = f64::INFINITY;
        for k in 1..100 {
            let o = objective(k as f64 * 0.2, &d, MAX_TERMS);
            assert!(o <= prev + 1e-12, "objective must fall as crawls rarify");
            prev = o;
        }
    }

    #[test]
    fn inverse_value_roundtrip() {
        let d = derived(0.8, 0.5, 0.6, 0.3);
        for &iota in &[0.2, 1.0, 4.0, 15.0] {
            let v = value_ncis(iota, &d, MAX_TERMS);
            let back = inverse_value(v, &d, MAX_TERMS).unwrap();
            assert!((back - iota).abs() < 1e-6 * iota, "{back} vs {iota}");
        }
        // above the sup
        assert!(inverse_value(d.mu / d.delta * 1.01, &d, MAX_TERMS).is_none());
    }

    #[test]
    fn approx_truncation_error_shrinks() {
        let d = derived(1.0, 0.5, 0.5, 0.8); // smallish beta => many terms
        let iota = 6.0 * d.beta;
        let exact = value_ncis(iota, &d, MAX_TERMS);
        let mut prev_err = f64::INFINITY;
        for j in 1..7 {
            let err = (value_ncis(iota, &d, j) - exact).abs();
            assert!(err <= prev_err + 1e-15, "j={j}");
            prev_err = err;
        }
    }

    #[test]
    fn batched_kernel_is_bit_identical_to_scalar() {
        // edge regimes on purpose: γ = 0, β = 0, β = ∞, plus a generic
        // noisy page; iotas include 0, tiny, large and ∞
        let envs: Vec<DerivedParams> = [
            (0.8, 0.5, 0.0, 0.0), // γ = 0 (GREEDY limit)
            (0.4, 0.9, 0.0, 0.2), // β = 0 (λ = 0, ν > 0)
            (1.0, 0.5, 0.6, 0.0), // β = ∞ (noiseless CIS)
            (0.8, 0.5, 0.6, 0.3), // generic noisy CIS
        ]
        .iter()
        .map(|&(delta, mu, lam, nu)| PageParams { delta, mu, lam, nu }.derive().unwrap())
        .collect();
        let cols = ParamColumns::from_derived(&envs);
        let iotas = [0.0, 1e-9, 0.3, 2.0, 40.0, f64::INFINITY];
        for terms in [1u32, 2, 8, MAX_TERMS] {
            let mut flat_iotas = Vec::new();
            let mut flat_pages = Vec::new();
            for (i, _) in envs.iter().enumerate() {
                for &iota in &iotas {
                    flat_iotas.push(iota);
                    flat_pages.push(i as u32);
                }
            }
            let mut out = vec![0.0; flat_iotas.len()];
            values_ncis_into(&mut out, &flat_iotas, &flat_pages, &cols, terms);
            for (k, &got) in out.iter().enumerate() {
                let want = value_ncis(flat_iotas[k], &envs[flat_pages[k] as usize], terms);
                assert_eq!(
                    want.to_bits(),
                    got.to_bits(),
                    "terms={terms} page={} iota={}",
                    flat_pages[k],
                    flat_iotas[k]
                );
            }
        }
    }

    #[test]
    fn value_at_zero_is_zero() {
        let d = derived(0.8, 0.5, 0.6, 0.3);
        assert_eq!(value_ncis(0.0, &d, MAX_TERMS), 0.0);
        assert_eq!(value_greedy(0.0, 0.8, 0.5), 0.0);
        assert_eq!(value_cis(0.0, 0.8, 0.5, 0.3), 0.0);
    }
}
