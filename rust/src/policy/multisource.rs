//! Multiple independent CIS sources per page (paper §3, footnote 2:
//! *"It is straightforward to extend the model to multiple independent
//! sources of CI signals. We consider a single signal for the sake of
//! presentation."*).
//!
//! This module makes that extension concrete. Page `i` receives signals
//! from `K` independent sources; source `k` covers a fraction `λ_k` of
//! changes and adds false positives at rate `ν_k`. Under the paper's
//! independence assumptions the *joint* observation process is again of
//! the single-source form, with:
//!
//! ```text
//! λ = 1 − Π_k (1 − λ_k)        (a change is signalled by ≥1 source)
//! ν = Σ_k ν_k                   (false positives superpose)
//! γ = λΔ + ν
//! ```
//!
//! …but signals are no longer exchangeable: a signal from a
//! high-precision source moves the freshness belief more than one from a
//! noisy source. The per-source time-equivalent is
//! `β_k = −log(ν_k,eff/γ_k)/α` where the *effective* per-source split
//! attributes to source `k` the changes only it could have signalled.
//! For scheduling we track per-source counts `n_k` and use
//! `τ_EFF = τ_ELAP + Σ_k β_k n_k`.

use crate::error::{Error, Result};
use crate::params::{DerivedParams, PageParams};

/// One CIS source's quality for a page.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CisSource {
    /// Recall of this source (fraction of changes it signals).
    pub lam: f64,
    /// False-positive rate of this source.
    pub nu: f64,
}

/// A page observed through multiple independent CIS sources.
#[derive(Debug, Clone)]
pub struct MultiSourcePage {
    /// Change rate Δ.
    pub delta: f64,
    /// Importance μ̃.
    pub mu: f64,
    /// The sources.
    pub sources: Vec<CisSource>,
}

impl MultiSourcePage {
    /// Validate the source parameters.
    pub fn validate(&self) -> Result<()> {
        if !(self.delta > 0.0) {
            return Err(Error::InvalidParam(format!("delta must be > 0, got {}", self.delta)));
        }
        for (k, s) in self.sources.iter().enumerate() {
            if !(0.0..=1.0).contains(&s.lam) {
                return Err(Error::InvalidParam(format!("source {k}: lam {}", s.lam)));
            }
            if s.nu < 0.0 {
                return Err(Error::InvalidParam(format!("source {k}: nu {}", s.nu)));
            }
        }
        Ok(())
    }

    /// Collapse to the equivalent single-source page (the merged
    /// process): used wherever only the aggregate matters (the crawl
    /// value's ψ/w structure, the solver, the LDS reduction).
    pub fn merged(&self) -> PageParams {
        let miss: f64 = self.sources.iter().map(|s| 1.0 - s.lam).product();
        let lam = 1.0 - miss;
        let nu: f64 = self.sources.iter().map(|s| s.nu).sum();
        PageParams { delta: self.delta, mu: self.mu, lam, nu }
    }

    /// Per-source observed signal rate `γ_k = λ_k Δ + ν_k`.
    pub fn source_gamma(&self, k: usize) -> f64 {
        self.sources[k].lam * self.delta + self.sources[k].nu
    }

    /// Per-source time-equivalents `β_k`: a signal from source `k`
    /// multiplies the freshness belief by its own false-positive odds
    /// `ν_k/γ_k`, hence `β_k = −log(ν_k/γ_k)/α` with the merged α.
    pub fn source_betas(&self) -> Result<Vec<f64>> {
        self.validate()?;
        let merged = self.merged().derive()?;
        Ok((0..self.sources.len())
            .map(|k| {
                let gk = self.source_gamma(k);
                if gk <= 0.0 || self.sources[k].nu <= 0.0 {
                    f64::INFINITY
                } else {
                    (-(self.sources[k].nu / gk).max(1e-38).ln() / merged.alpha).max(0.0)
                }
            })
            .collect())
    }

    /// Merged derived parameters.
    pub fn derived(&self) -> Result<DerivedParams> {
        self.merged().derive()
    }

    /// Effective elapsed time given per-source signal counts.
    pub fn effective_time(&self, tau_elap: f64, counts: &[u32]) -> Result<f64> {
        let betas = self.source_betas()?;
        if counts.len() != betas.len() {
            return Err(Error::InvalidParam(format!(
                "counts arity {} != sources {}",
                counts.len(),
                betas.len()
            )));
        }
        let mut t = tau_elap;
        for (&n, &b) in counts.iter().zip(&betas) {
            if n > 0 {
                if !b.is_finite() {
                    return Ok(f64::INFINITY);
                }
                t += b * n as f64;
            }
        }
        Ok(t)
    }

    /// Freshness belief given per-source counts (the K-source analogue
    /// of eq. 1): `exp(−α τ) Π_k (ν_k/γ_k)^{n_k}`.
    pub fn freshness(&self, tau_elap: f64, counts: &[u32]) -> Result<f64> {
        let d = self.derived()?;
        let mut log_p = -d.alpha * tau_elap;
        for (k, &n) in counts.iter().enumerate() {
            if n == 0 {
                continue;
            }
            let gk = self.source_gamma(k);
            if self.sources[k].nu <= 0.0 || gk <= 0.0 {
                return Ok(0.0); // noiseless source: signal ⇒ stale
            }
            log_p += n as f64 * (self.sources[k].nu / gk).ln();
        }
        Ok(log_p.exp())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn page2() -> MultiSourcePage {
        MultiSourcePage {
            delta: 0.8,
            mu: 0.5,
            sources: vec![
                CisSource { lam: 0.6, nu: 0.1 }, // high-precision source
                CisSource { lam: 0.3, nu: 0.5 }, // noisy source
            ],
        }
    }

    #[test]
    fn merged_rates() {
        let p = page2().merged();
        assert!((p.lam - (1.0 - 0.4 * 0.7)).abs() < 1e-12); // 0.72
        assert!((p.nu - 0.6).abs() < 1e-12);
    }

    #[test]
    fn single_source_reduces_to_base_model() {
        let ms = MultiSourcePage {
            delta: 0.8,
            mu: 0.5,
            sources: vec![CisSource { lam: 0.6, nu: 0.3 }],
        };
        let d_ms = ms.derived().unwrap();
        let d = PageParams { delta: 0.8, mu: 0.5, lam: 0.6, nu: 0.3 }.derive().unwrap();
        assert_eq!(d_ms, d);
        let betas = ms.source_betas().unwrap();
        assert!((betas[0] - d.beta).abs() < 1e-12);
        assert!(
            (ms.effective_time(2.0, &[3]).unwrap() - d.effective_time(2.0, 3)).abs() < 1e-9
        );
    }

    #[test]
    fn precise_source_moves_belief_more() {
        let ms = page2();
        let betas = ms.source_betas().unwrap();
        assert!(
            betas[0] > betas[1],
            "high-precision source must have larger beta: {betas:?}"
        );
        let f_precise = ms.freshness(1.0, &[1, 0]).unwrap();
        let f_noisy = ms.freshness(1.0, &[0, 1]).unwrap();
        assert!(f_precise < f_noisy, "{f_precise} vs {f_noisy}");
    }

    #[test]
    fn freshness_consistent_with_effective_time() {
        let ms = page2();
        let d = ms.derived().unwrap();
        for counts in [[0u32, 0], [1, 0], [0, 2], [2, 3]] {
            let via_eff = (-d.alpha * ms.effective_time(1.5, &counts).unwrap()).exp();
            let direct = ms.freshness(1.5, &counts).unwrap();
            assert!(
                (via_eff - direct).abs() < 1e-9,
                "counts {counts:?}: {via_eff} vs {direct}"
            );
        }
    }

    #[test]
    fn noiseless_source_signal_means_stale() {
        let ms = MultiSourcePage {
            delta: 1.0,
            mu: 0.1,
            sources: vec![CisSource { lam: 0.5, nu: 0.0 }, CisSource { lam: 0.2, nu: 0.4 }],
        };
        assert_eq!(ms.freshness(1.0, &[1, 0]).unwrap(), 0.0);
        assert!(ms.freshness(1.0, &[0, 1]).unwrap() > 0.0);
        assert_eq!(ms.effective_time(1.0, &[1, 0]).unwrap(), f64::INFINITY);
    }

    #[test]
    fn arity_and_validation_errors() {
        let ms = page2();
        assert!(ms.effective_time(1.0, &[1]).is_err());
        let bad = MultiSourcePage {
            delta: 0.0,
            mu: 0.1,
            sources: vec![CisSource { lam: 0.5, nu: 0.1 }],
        };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn more_sources_never_reduce_recall() {
        let mut ms = page2();
        let lam2 = ms.merged().lam;
        ms.sources.push(CisSource { lam: 0.4, nu: 0.2 });
        assert!(ms.merged().lam >= lam2);
    }
}
