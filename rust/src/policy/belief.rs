//! Belief projection: one model of "what this policy thinks the CIS
//! process is", shared by the native f64 and PJRT/batched value paths.
//!
//! Pre-redesign this logic lived twice — once inside
//! `PolicyKind::crawl_value` (native, per-page dispatch) and once as
//! `belief_params` in `coordinator/crawler.rs` (the projection the
//! batched kernel is fed). [`BeliefModel`] owns both: it precomputes
//! the true derived environments *and* the per-policy belief
//! projections at construction, serves native values through the exact
//! `crawl_value` dispatch, and hands the batched backends the projected
//! `DerivedParams` the kernel evaluates.

use crate::params::{DerivedParams, PageParams, ParamColumns};
use crate::policy::{cis_plus_trusts, value, PolicyKind};

/// Project a policy's *beliefs* about the CIS process onto the general
/// NCIS parametrization the batched kernel evaluates (§5.1 special
/// cases): GREEDY believes there is no CIS process at all; GREEDY-CIS
/// believes signals are noiseless (β = ∞, α̂ = Δ − γ); NCIS variants use
/// the true derived parameters.
pub fn belief_params(policy: PolicyKind, raw: &PageParams, d: &DerivedParams) -> DerivedParams {
    match policy {
        PolicyKind::Greedy => DerivedParams {
            alpha: d.delta,
            beta: f64::INFINITY,
            gamma: 0.0,
            nu: 0.0,
            delta: d.delta,
            mu: d.mu,
        },
        PolicyKind::GreedyCis => DerivedParams {
            alpha: (d.delta - d.gamma).max(1e-6 * d.delta),
            beta: f64::INFINITY,
            gamma: d.gamma,
            nu: 0.0,
            delta: d.delta,
            mu: d.mu,
        },
        PolicyKind::GreedyCisPlus => {
            if cis_plus_trusts(raw) {
                belief_params(PolicyKind::GreedyCis, raw, d)
            } else {
                belief_params(PolicyKind::Greedy, raw, d)
            }
        }
        PolicyKind::GreedyNcis | PolicyKind::NcisApprox(_) => *d,
    }
}

/// Per-page value dispatch, resolved once at construction so the
/// batched path never re-matches on `PolicyKind` per page (GREEDY-CIS+
/// is the only policy whose dispatch genuinely varies by page).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ValueKind {
    /// `value_greedy(τ_ELAP, Δ, μ̃)` — ignores CIS.
    Greedy,
    /// `value_cis_state` — noiseless-CIS belief, saturates on a signal.
    CisState,
    /// `value_ncis(ι_EFF, E, terms)` — the general noisy-CIS value.
    Ncis,
}

/// Chunk width of the batched value paths: small enough that the gather
/// scratch lives on the stack, large enough to amortize dispatch.
pub const VALUE_CHUNK: usize = 64;

/// A policy's per-page view of the environment: the true derived
/// parameters (what the native value dispatch consumes) plus the belief
/// projection (what batched backends and wake-time inversion consume).
///
/// Storage is columnar (struct-of-arrays, [`ParamColumns`]): the
/// schedulers' batched hot paths ([`Self::values_into`]) stream flat
/// `f64` columns instead of pointer-hopping `Vec<DerivedParams>`.
/// `env(i)` / `belief(i)` reconstruct the exact structs that were
/// pushed, so every scalar path stays bit-identical to the
/// pre-columnar layout.
#[derive(Debug, Clone)]
pub struct BeliefModel {
    policy: PolicyKind,
    raw: Vec<PageParams>,
    envs: ParamColumns,
    beliefs: ParamColumns,
    /// Per-page resolved value dispatch (varies only for GREEDY-CIS+).
    kinds: Vec<ValueKind>,
}

/// Resolve the per-page value dispatch for `policy` (only GREEDY-CIS+
/// genuinely varies by page).
fn resolve_kind(policy: PolicyKind, p: &PageParams) -> ValueKind {
    match policy {
        PolicyKind::Greedy => ValueKind::Greedy,
        PolicyKind::GreedyCis => ValueKind::CisState,
        PolicyKind::GreedyNcis | PolicyKind::NcisApprox(_) => ValueKind::Ncis,
        PolicyKind::GreedyCisPlus => {
            if cis_plus_trusts(p) {
                ValueKind::CisState
            } else {
                ValueKind::Greedy
            }
        }
    }
}

impl BeliefModel {
    /// Precompute environments and belief projections for every page.
    pub fn new(policy: PolicyKind, pages: &[PageParams]) -> Self {
        let mut envs = ParamColumns::with_capacity(pages.len());
        let mut beliefs = ParamColumns::with_capacity(pages.len());
        let mut kinds = Vec::with_capacity(pages.len());
        for p in pages {
            let d = DerivedParams::from_raw(p);
            beliefs.push(&belief_params(policy, p, &d));
            envs.push(&d);
            kinds.push(resolve_kind(policy, p));
        }
        Self { policy, raw: pages.to_vec(), envs, beliefs, kinds }
    }

    /// Append one page (dynamic-world growth): derives the true
    /// environment, re-projects the policy belief and resolves the
    /// value dispatch exactly as construction does, so a model grown
    /// page-by-page is indistinguishable from one built in one shot.
    pub fn push_page(&mut self, p: &PageParams) {
        let d = DerivedParams::from_raw(p);
        self.beliefs.push(&belief_params(self.policy, p, &d));
        self.envs.push(&d);
        self.kinds.push(resolve_kind(self.policy, p));
        self.raw.push(*p);
    }

    /// Overwrite page `i` in place (dynamic-world parameter drift or
    /// slot recycling): truth columns, belief projection and value
    /// dispatch are all recomputed from the new raw parameters.
    pub fn set_page(&mut self, i: usize, p: &PageParams) {
        let d = DerivedParams::from_raw(p);
        self.beliefs.set(i, &belief_params(self.policy, p, &d));
        self.envs.set(i, &d);
        self.kinds[i] = resolve_kind(self.policy, p);
        self.raw[i] = *p;
    }

    /// Number of pages.
    pub fn len(&self) -> usize {
        self.raw.len()
    }

    /// Is the model empty?
    pub fn is_empty(&self) -> bool {
        self.raw.is_empty()
    }

    /// The policy whose beliefs are modeled.
    pub fn policy(&self) -> PolicyKind {
        self.policy
    }

    /// Raw parameters of page `i`.
    pub fn raw(&self, i: usize) -> &PageParams {
        &self.raw[i]
    }

    /// Raw parameters of every page (reflects in-place mutations; a
    /// scheduler that needs the pristine construction-time population
    /// snapshots this before its first mutation).
    pub fn raw_pages(&self) -> &[PageParams] {
        &self.raw
    }

    /// True derived environment of page `i` (reconstructed from the
    /// columns, bit-identical to the original derivation).
    #[inline]
    pub fn env(&self, i: usize) -> DerivedParams {
        self.envs.get(i)
    }

    /// Belief projection of page `i` (feed this to batched kernels).
    #[inline]
    pub fn belief(&self, i: usize) -> DerivedParams {
        self.beliefs.get(i)
    }

    /// The true-environment columns (the batched native kernel's input).
    pub fn env_columns(&self) -> &ParamColumns {
        &self.envs
    }

    /// The belief-projection columns.
    pub fn belief_columns(&self) -> &ParamColumns {
        &self.beliefs
    }

    /// Crawl value of page `i` in scheduler state `(tau_elap, n_cis)`
    /// — the exact native f64 path.
    #[inline]
    pub fn value(&self, i: usize, tau_elap: f64, n_cis: u32) -> f64 {
        self.policy.crawl_value(&self.raw[i], &self.envs.get(i), tau_elap, n_cis)
    }

    /// Batched crawl values through the columnar native kernel:
    /// `out[k] = self.value(pages[k], tau_elap[k], n_cis[k])`,
    /// **bit-identically** (the scalar dispatch is the parity oracle —
    /// `tests/columnar_parity.rs` pins the equality per policy and edge
    /// regime). `pages` is a gather: callers pass an arbitrary subset —
    /// the exact scheduler's pruned argmax chunks, the lazy scheduler's
    /// hot-set re-key — and own all buffers, so the hot path allocates
    /// nothing.
    pub fn values_into(&self, pages: &[u32], tau_elap: &[f64], n_cis: &[u32], out: &mut [f64]) {
        assert_eq!(pages.len(), out.len(), "values_into: pages/out length mismatch");
        assert_eq!(tau_elap.len(), out.len(), "values_into: tau/out length mismatch");
        assert_eq!(n_cis.len(), out.len(), "values_into: n_cis/out length mismatch");
        match self.policy {
            PolicyKind::Greedy => {
                for ((o, &tau), &ip) in out.iter_mut().zip(tau_elap).zip(pages) {
                    let i = ip as usize;
                    *o = value::value_greedy(tau, self.envs.delta[i], self.envs.mu[i]);
                }
            }
            PolicyKind::GreedyCis => {
                for (((o, &tau), &n), &ip) in
                    out.iter_mut().zip(tau_elap).zip(n_cis).zip(pages)
                {
                    let d = self.envs.get(ip as usize);
                    *o = value::value_cis_state(&d, tau, n);
                }
            }
            PolicyKind::GreedyNcis | PolicyKind::NcisApprox(_) => {
                let terms = self.terms();
                let mut iot = [0.0f64; VALUE_CHUNK];
                for (((chunk, tau_c), n_c), out_c) in pages
                    .chunks(VALUE_CHUNK)
                    .zip(tau_elap.chunks(VALUE_CHUNK))
                    .zip(n_cis.chunks(VALUE_CHUNK))
                    .zip(out.chunks_mut(VALUE_CHUNK))
                {
                    let n = chunk.len();
                    for (j, (&ip, (&tau, &nc))) in
                        chunk.iter().zip(tau_c.iter().zip(n_c)).enumerate()
                    {
                        let i = ip as usize;
                        // inline DerivedParams::effective_time on the
                        // true-env columns (same operations, same bits)
                        iot[j] = if nc == 0 || self.envs.gamma[i] <= 0.0 {
                            tau
                        } else if self.envs.beta[i].is_finite() {
                            tau + self.envs.beta[i] * nc as f64
                        } else {
                            f64::INFINITY
                        };
                    }
                    value::values_ncis_into(out_c, &iot[..n], chunk, &self.envs, terms);
                }
            }
            PolicyKind::GreedyCisPlus => {
                for (((o, &tau), &n), &ip) in
                    out.iter_mut().zip(tau_elap).zip(n_cis).zip(pages)
                {
                    let i = ip as usize;
                    *o = match self.kinds[i] {
                        ValueKind::CisState => {
                            let d = self.envs.get(i);
                            value::value_cis_state(&d, tau, n)
                        }
                        _ => value::value_greedy(tau, self.envs.delta[i], self.envs.mu[i]),
                    };
                }
            }
        }
    }

    /// Effective elapsed time of page `i` under the policy's OWN
    /// beliefs: a pending CIS saturates a noiseless-belief page
    /// (β̂ = ∞ → capped), while a GREEDY belief (γ̂ = 0) ignores it.
    #[inline]
    pub fn effective_time(&self, i: usize, tau_elap: f64, n_cis: u32) -> f64 {
        self.beliefs.get(i).effective_time(tau_elap, n_cis)
    }

    /// Upper bound on page `i`'s crawl value (`μ̃/Δ`).
    pub fn value_upper_bound(&self, i: usize) -> f64 {
        self.policy.value_upper_bound(&self.envs.get(i))
    }

    /// Approximation level for sum-based evaluations of this policy
    /// (`j` for `G-NCIS-APPROX-j`, [`value::MAX_TERMS`] otherwise).
    pub fn terms(&self) -> u32 {
        match self.policy {
            PolicyKind::NcisApprox(j) => j,
            _ => value::MAX_TERMS,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngkit::Rng;

    fn pages(m: usize, seed: u64) -> Vec<PageParams> {
        let mut rng = Rng::new(seed);
        (0..m)
            .map(|_| PageParams {
                delta: rng.range(0.05, 1.0),
                mu: rng.range(0.05, 1.0),
                lam: rng.f64(),
                nu: rng.range(0.0, 0.6),
            })
            .collect()
    }

    #[test]
    fn native_value_matches_crawl_value_dispatch() {
        let ps = pages(20, 1);
        for kind in [
            PolicyKind::Greedy,
            PolicyKind::GreedyCis,
            PolicyKind::GreedyNcis,
            PolicyKind::NcisApprox(2),
            PolicyKind::GreedyCisPlus,
        ] {
            let model = BeliefModel::new(kind, &ps);
            for (i, p) in ps.iter().enumerate() {
                let d = DerivedParams::from_raw(p);
                for (tau, n) in [(0.5, 0u32), (2.0, 1), (7.5, 3)] {
                    let want = kind.crawl_value(p, &d, tau, n);
                    let got = model.value(i, tau, n);
                    assert_eq!(want.to_bits(), got.to_bits(), "{kind:?} page {i}");
                }
            }
        }
    }

    #[test]
    fn batched_values_into_matches_scalar_dispatch() {
        // spans more than one chunk so the chunked NCIS arm is exercised
        let ps = pages(3 * VALUE_CHUNK + 7, 4);
        let mut rng = Rng::new(5);
        for kind in [
            PolicyKind::Greedy,
            PolicyKind::GreedyCis,
            PolicyKind::GreedyNcis,
            PolicyKind::NcisApprox(3),
            PolicyKind::GreedyCisPlus,
        ] {
            let model = BeliefModel::new(kind, &ps);
            let pages_idx: Vec<u32> = (0..ps.len() as u32).rev().collect(); // gather order
            let tau: Vec<f64> = pages_idx.iter().map(|_| rng.range(0.0, 20.0)).collect();
            let n: Vec<u32> = pages_idx.iter().map(|_| (rng.f64() * 4.0) as u32).collect();
            let mut out = vec![0.0; ps.len()];
            model.values_into(&pages_idx, &tau, &n, &mut out);
            for (k, &v) in out.iter().enumerate() {
                let want = model.value(pages_idx[k] as usize, tau[k], n[k]);
                assert_eq!(want.to_bits(), v.to_bits(), "{kind:?} k={k}");
            }
        }
    }

    #[test]
    fn greedy_belief_ignores_cis() {
        let ps = pages(5, 2);
        let model = BeliefModel::new(PolicyKind::Greedy, &ps);
        for i in 0..ps.len() {
            assert_eq!(model.belief(i).gamma, 0.0);
            assert_eq!(model.effective_time(i, 3.0, 4), 3.0);
        }
    }

    #[test]
    fn cis_plus_belief_splits_on_quality() {
        let hi = PageParams::from_quality(0.8, 0.5, 0.9, 0.8);
        let lo = PageParams::from_quality(0.8, 0.5, 0.2, 0.3);
        let model = BeliefModel::new(PolicyKind::GreedyCisPlus, &[hi, lo]);
        // trusted page projects to the GREEDY-CIS belief (γ̂ carried over)
        assert!(model.belief(0).gamma > 0.0);
        assert!(model.belief(0).beta.is_infinite());
        // untrusted page projects to the plain GREEDY belief
        assert_eq!(model.belief(1).gamma, 0.0);
    }

    #[test]
    fn grown_and_mutated_model_matches_one_shot_construction() {
        let ps = pages(10, 7);
        let extra = pages(3, 8);
        let drift = PageParams { delta: 1.7, mu: 0.33, lam: 0.9, nu: 0.02 };
        for kind in [
            PolicyKind::Greedy,
            PolicyKind::GreedyCis,
            PolicyKind::GreedyNcis,
            PolicyKind::NcisApprox(2),
            PolicyKind::GreedyCisPlus,
        ] {
            // grow page-by-page, then drift one page in place
            let mut grown = BeliefModel::new(kind, &ps);
            for p in &extra {
                grown.push_page(p);
            }
            grown.set_page(4, &drift);
            // the one-shot equivalent population
            let mut all = ps.clone();
            all.extend_from_slice(&extra);
            all[4] = drift;
            let oneshot = BeliefModel::new(kind, &all);
            assert_eq!(grown.len(), oneshot.len());
            for i in 0..all.len() {
                for (tau, n) in [(0.5, 0u32), (3.0, 2)] {
                    assert_eq!(
                        grown.value(i, tau, n).to_bits(),
                        oneshot.value(i, tau, n).to_bits(),
                        "{kind:?} page {i}"
                    );
                }
                assert_eq!(
                    grown.belief(i).gamma.to_bits(),
                    oneshot.belief(i).gamma.to_bits(),
                    "{kind:?} belief γ page {i}"
                );
                assert_eq!(
                    grown.value_upper_bound(i).to_bits(),
                    oneshot.value_upper_bound(i).to_bits(),
                    "{kind:?} ub page {i}"
                );
            }
        }
    }

    #[test]
    fn terms_reflect_approximation_level() {
        let ps = pages(3, 3);
        assert_eq!(BeliefModel::new(PolicyKind::NcisApprox(4), &ps).terms(), 4);
        assert_eq!(BeliefModel::new(PolicyKind::GreedyNcis, &ps).terms(), value::MAX_TERMS);
    }
}
