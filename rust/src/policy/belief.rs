//! Belief projection: one model of "what this policy thinks the CIS
//! process is", shared by the native f64 and PJRT/batched value paths.
//!
//! Pre-redesign this logic lived twice — once inside
//! `PolicyKind::crawl_value` (native, per-page dispatch) and once as
//! `belief_params` in `coordinator/crawler.rs` (the projection the
//! batched kernel is fed). [`BeliefModel`] owns both: it precomputes
//! the true derived environments *and* the per-policy belief
//! projections at construction, serves native values through the exact
//! `crawl_value` dispatch, and hands the batched backends the projected
//! `DerivedParams` the kernel evaluates.

use crate::params::{DerivedParams, PageParams};
use crate::policy::{cis_plus_trusts, value, PolicyKind};

/// Project a policy's *beliefs* about the CIS process onto the general
/// NCIS parametrization the batched kernel evaluates (§5.1 special
/// cases): GREEDY believes there is no CIS process at all; GREEDY-CIS
/// believes signals are noiseless (β = ∞, α̂ = Δ − γ); NCIS variants use
/// the true derived parameters.
pub fn belief_params(policy: PolicyKind, raw: &PageParams, d: &DerivedParams) -> DerivedParams {
    match policy {
        PolicyKind::Greedy => DerivedParams {
            alpha: d.delta,
            beta: f64::INFINITY,
            gamma: 0.0,
            nu: 0.0,
            delta: d.delta,
            mu: d.mu,
        },
        PolicyKind::GreedyCis => DerivedParams {
            alpha: (d.delta - d.gamma).max(1e-6 * d.delta),
            beta: f64::INFINITY,
            gamma: d.gamma,
            nu: 0.0,
            delta: d.delta,
            mu: d.mu,
        },
        PolicyKind::GreedyCisPlus => {
            if cis_plus_trusts(raw) {
                belief_params(PolicyKind::GreedyCis, raw, d)
            } else {
                belief_params(PolicyKind::Greedy, raw, d)
            }
        }
        PolicyKind::GreedyNcis | PolicyKind::NcisApprox(_) => *d,
    }
}

/// A policy's per-page view of the environment: the true derived
/// parameters (what the native value dispatch consumes) plus the belief
/// projection (what batched backends and wake-time inversion consume).
#[derive(Debug, Clone)]
pub struct BeliefModel {
    policy: PolicyKind,
    raw: Vec<PageParams>,
    envs: Vec<DerivedParams>,
    beliefs: Vec<DerivedParams>,
}

impl BeliefModel {
    /// Precompute environments and belief projections for every page.
    pub fn new(policy: PolicyKind, pages: &[PageParams]) -> Self {
        let envs: Vec<DerivedParams> = pages.iter().map(DerivedParams::from_raw).collect();
        let beliefs = pages
            .iter()
            .zip(&envs)
            .map(|(p, d)| belief_params(policy, p, d))
            .collect();
        Self { policy, raw: pages.to_vec(), envs, beliefs }
    }

    /// Number of pages.
    pub fn len(&self) -> usize {
        self.raw.len()
    }

    /// Is the model empty?
    pub fn is_empty(&self) -> bool {
        self.raw.is_empty()
    }

    /// The policy whose beliefs are modeled.
    pub fn policy(&self) -> PolicyKind {
        self.policy
    }

    /// Raw parameters of page `i`.
    pub fn raw(&self, i: usize) -> &PageParams {
        &self.raw[i]
    }

    /// True derived environment of page `i`.
    pub fn env(&self, i: usize) -> &DerivedParams {
        &self.envs[i]
    }

    /// Belief projection of page `i` (feed this to batched kernels).
    pub fn belief(&self, i: usize) -> &DerivedParams {
        &self.beliefs[i]
    }

    /// Crawl value of page `i` in scheduler state `(tau_elap, n_cis)`
    /// — the exact native f64 path.
    #[inline]
    pub fn value(&self, i: usize, tau_elap: f64, n_cis: u32) -> f64 {
        self.policy.crawl_value(&self.raw[i], &self.envs[i], tau_elap, n_cis)
    }

    /// Effective elapsed time of page `i` under the policy's OWN
    /// beliefs: a pending CIS saturates a noiseless-belief page
    /// (β̂ = ∞ → capped), while a GREEDY belief (γ̂ = 0) ignores it.
    #[inline]
    pub fn effective_time(&self, i: usize, tau_elap: f64, n_cis: u32) -> f64 {
        self.beliefs[i].effective_time(tau_elap, n_cis)
    }

    /// Upper bound on page `i`'s crawl value (`μ̃/Δ`).
    pub fn value_upper_bound(&self, i: usize) -> f64 {
        self.policy.value_upper_bound(&self.envs[i])
    }

    /// Approximation level for sum-based evaluations of this policy
    /// (`j` for `G-NCIS-APPROX-j`, [`value::MAX_TERMS`] otherwise).
    pub fn terms(&self) -> u32 {
        match self.policy {
            PolicyKind::NcisApprox(j) => j,
            _ => value::MAX_TERMS,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngkit::Rng;

    fn pages(m: usize, seed: u64) -> Vec<PageParams> {
        let mut rng = Rng::new(seed);
        (0..m)
            .map(|_| PageParams {
                delta: rng.range(0.05, 1.0),
                mu: rng.range(0.05, 1.0),
                lam: rng.f64(),
                nu: rng.range(0.0, 0.6),
            })
            .collect()
    }

    #[test]
    fn native_value_matches_crawl_value_dispatch() {
        let ps = pages(20, 1);
        for kind in [
            PolicyKind::Greedy,
            PolicyKind::GreedyCis,
            PolicyKind::GreedyNcis,
            PolicyKind::NcisApprox(2),
            PolicyKind::GreedyCisPlus,
        ] {
            let model = BeliefModel::new(kind, &ps);
            for (i, p) in ps.iter().enumerate() {
                let d = DerivedParams::from_raw(p);
                for (tau, n) in [(0.5, 0u32), (2.0, 1), (7.5, 3)] {
                    let want = kind.crawl_value(p, &d, tau, n);
                    let got = model.value(i, tau, n);
                    assert_eq!(want.to_bits(), got.to_bits(), "{kind:?} page {i}");
                }
            }
        }
    }

    #[test]
    fn greedy_belief_ignores_cis() {
        let ps = pages(5, 2);
        let model = BeliefModel::new(PolicyKind::Greedy, &ps);
        for i in 0..ps.len() {
            assert_eq!(model.belief(i).gamma, 0.0);
            assert_eq!(model.effective_time(i, 3.0, 4), 3.0);
        }
    }

    #[test]
    fn cis_plus_belief_splits_on_quality() {
        let hi = PageParams::from_quality(0.8, 0.5, 0.9, 0.8);
        let lo = PageParams::from_quality(0.8, 0.5, 0.2, 0.3);
        let model = BeliefModel::new(PolicyKind::GreedyCisPlus, &[hi, lo]);
        // trusted page projects to the GREEDY-CIS belief (γ̂ carried over)
        assert!(model.belief(0).gamma > 0.0);
        assert!(model.belief(0).beta.is_infinite());
        // untrusted page projects to the plain GREEDY belief
        assert_eq!(model.belief(1).gamma, 0.0);
    }

    #[test]
    fn terms_reflect_approximation_level() {
        let ps = pages(3, 3);
        assert_eq!(BeliefModel::new(PolicyKind::NcisApprox(4), &ps).terms(), 4);
        assert_eq!(BeliefModel::new(PolicyKind::GreedyNcis, &ps).terms(), value::MAX_TERMS);
    }
}
