//! Serving-side accounting: log-bucket staleness-age histograms with
//! fairness breakdowns by CIS-quality decile and popularity decile,
//! plus the cross-repetition accumulator.
//!
//! Everything here is built for *deterministic reduction*: histogram
//! state is integer bucket counts (plus one f64 running sum for means),
//! so [`ServingMetrics::merge`] over per-shard partials — folded in
//! shard-index order by the pipeline — produces the same bits
//! regardless of which shard finished first. Percentiles reuse the
//! shared [`crate::stats::cum_mass_bucket`] scan and report the
//! conservative **upper bucket edge**, the same contract as
//! `metrics::DurationHisto`.

use crate::stats::{cum_mass_bucket, summarize, Summary};

/// Smallest resolvable staleness age: serves at or below this age land
/// in the dedicated zero bucket and report a 0.0 quantile.
pub const AGE_RESOLUTION: f64 = 1e-6;

/// Number of power-of-two age buckets above the zero bucket
/// (upper edge of the last bucket: `AGE_RESOLUTION · 2^44 ≈ 1.8e7`
/// time units — far beyond any simulated horizon).
pub const AGE_BUCKETS: usize = 44;

/// Number of fairness deciles (CIS quality and popularity).
pub const DECILES: usize = 10;

/// Log-bucket histogram over staleness-at-request ages.
#[derive(Debug, Clone, PartialEq)]
pub struct AgeHisto {
    /// Serves with age ≤ [`AGE_RESOLUTION`] (fresh serves included).
    zero: u64,
    /// Bucket `j` holds ages in `[R·2^j, R·2^(j+1))`.
    counts: Vec<u64>,
    /// Running age sum (for the mean; merged in shard-index order).
    sum: f64,
}

impl Default for AgeHisto {
    fn default() -> Self {
        Self { zero: 0, counts: vec![0; AGE_BUCKETS], sum: 0.0 }
    }
}

impl AgeHisto {
    /// Empty histogram.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one serve's staleness age (fresh serves record age 0).
    pub fn observe(&mut self, age: f64) {
        self.sum += age;
        if age <= AGE_RESOLUTION {
            self.zero += 1;
        } else {
            let b = (age / AGE_RESOLUTION).log2().floor() as usize;
            self.counts[b.min(AGE_BUCKETS - 1)] += 1;
        }
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.zero + self.counts.iter().sum::<u64>()
    }

    /// Mean staleness age (`NaN` when empty).
    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            f64::NAN
        } else {
            self.sum / n as f64
        }
    }

    /// Quantile from the log buckets: 0.0 inside the zero bucket,
    /// otherwise the conservative upper bucket edge; `NaN` when empty.
    pub fn quantile(&self, q: f64) -> f64 {
        let n = self.count();
        if n == 0 {
            return f64::NAN;
        }
        let target = (q.clamp(0.0, 1.0) * n as f64).ceil();
        let masses = std::iter::once(self.zero as f64)
            .chain(self.counts.iter().map(|&c| c as f64));
        match cum_mass_bucket(masses, target) {
            Some((0, _)) => 0.0,
            Some((b, _)) => AGE_RESOLUTION * (1u64 << b) as f64,
            None => AGE_RESOLUTION * 2f64.powi(AGE_BUCKETS as i32),
        }
    }

    /// Fold `other` into `self` (commutative on the integer counts;
    /// callers fold in shard-index order so the f64 sum is
    /// deterministic too).
    pub fn merge(&mut self, other: &AgeHisto) {
        self.zero += other.zero;
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.sum += other.sum;
    }
}

/// Full serving-side accounting for one run (or one shard of one run).
#[derive(Debug, Clone, PartialEq)]
pub struct ServingMetrics {
    /// Requests served from a live page slot.
    pub served: u64,
    /// Serves that hit a fresh copy.
    pub fresh_serves: u64,
    /// Serves that hit a stale copy.
    pub stale_serves: u64,
    /// Requests aimed at retired or never-born slots (excluded from
    /// the age histograms — there is no copy to age).
    pub dead_serves: u64,
    /// Staleness ages over all live serves.
    pub overall: AgeHisto,
    /// Ages split by CIS-quality decile (0 = worst signals).
    pub by_quality: Vec<AgeHisto>,
    /// Ages split by popularity decile (0 = most requested head).
    pub by_popularity: Vec<AgeHisto>,
}

impl Default for ServingMetrics {
    fn default() -> Self {
        Self {
            served: 0,
            fresh_serves: 0,
            stale_serves: 0,
            dead_serves: 0,
            overall: AgeHisto::new(),
            by_quality: vec![AgeHisto::new(); DECILES],
            by_popularity: vec![AgeHisto::new(); DECILES],
        }
    }
}

impl ServingMetrics {
    /// Empty accounting.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one live serve.
    pub fn record(&mut self, fresh: bool, age: f64, quality_decile: usize, pop_decile: usize) {
        self.served += 1;
        if fresh {
            self.fresh_serves += 1;
        } else {
            self.stale_serves += 1;
        }
        self.overall.observe(age);
        self.by_quality[quality_decile.min(DECILES - 1)].observe(age);
        self.by_popularity[pop_decile.min(DECILES - 1)].observe(age);
    }

    /// Record a request that found no live page behind its slot.
    pub fn record_dead(&mut self) {
        self.dead_serves += 1;
    }

    /// Fraction of live serves that were stale (`NaN` when none).
    pub fn stale_fraction(&self) -> f64 {
        if self.served == 0 {
            f64::NAN
        } else {
            self.stale_serves as f64 / self.served as f64
        }
    }

    /// Publish the serving summary into a metrics registry under the
    /// `serving_` prefix. Counters *add* (repetition loops accumulate
    /// across runs); the staleness gauges are overwritten with this
    /// summary's values.
    pub fn export(&self, registry: &crate::metrics::Registry) {
        registry.counter("serving_served").add(self.served);
        registry.counter("serving_fresh").add(self.fresh_serves);
        registry.counter("serving_stale").add(self.stale_serves);
        registry.counter("serving_dead").add(self.dead_serves);
        registry.gauge("serving_age_mean_seconds").set(self.overall.mean());
        registry.gauge("serving_age_p50_seconds").set(self.overall.quantile(0.50));
        registry.gauge("serving_age_p95_seconds").set(self.overall.quantile(0.95));
        registry.gauge("serving_age_p99_seconds").set(self.overall.quantile(0.99));
    }

    /// Fold `other` into `self` (see [`AgeHisto::merge`] for the
    /// determinism contract).
    pub fn merge(&mut self, other: &ServingMetrics) {
        self.served += other.served;
        self.fresh_serves += other.fresh_serves;
        self.stale_serves += other.stale_serves;
        self.dead_serves += other.dead_serves;
        self.overall.merge(&other.overall);
        for (a, b) in self.by_quality.iter_mut().zip(&other.by_quality) {
            a.merge(b);
        }
        for (a, b) in self.by_popularity.iter_mut().zip(&other.by_popularity) {
            a.merge(b);
        }
    }
}

/// Serving companion to [`crate::sim::metrics::RepAccumulator`]:
/// collects per-repetition [`ServingMetrics`], exposing merged totals
/// plus mean ± stderr summaries of the per-rep staleness percentiles.
#[derive(Debug, Clone, Default)]
pub struct ServingRepAccumulator {
    totals: ServingMetrics,
    p50: Vec<f64>,
    p95: Vec<f64>,
    p99: Vec<f64>,
    stale_fractions: Vec<f64>,
}

impl ServingRepAccumulator {
    /// Empty accumulator.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one repetition's serving metrics.
    pub fn push(&mut self, m: &ServingMetrics) {
        self.totals.merge(m);
        self.p50.push(m.overall.quantile(0.5));
        self.p95.push(m.overall.quantile(0.95));
        self.p99.push(m.overall.quantile(0.99));
        self.stale_fractions.push(m.stale_fraction());
    }

    /// Metrics merged across all repetitions.
    pub fn totals(&self) -> &ServingMetrics {
        &self.totals
    }

    /// p50 staleness-at-request summary across reps.
    pub fn p50(&self) -> Summary {
        summarize(&self.p50)
    }

    /// p95 staleness-at-request summary across reps.
    pub fn p95(&self) -> Summary {
        summarize(&self.p95)
    }

    /// p99 staleness-at-request summary across reps.
    pub fn p99(&self) -> Summary {
        summarize(&self.p99)
    }

    /// Stale-serve fraction summary across reps.
    pub fn stale_fraction(&self) -> Summary {
        summarize(&self.stale_fractions)
    }

    /// Number of repetitions recorded.
    pub fn reps(&self) -> usize {
        self.p50.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histo_quantiles_are_monotone_and_cover_samples() {
        let mut h = AgeHisto::new();
        for age in [0.0, 1e-7, 0.001, 0.01, 0.1, 1.0, 10.0] {
            h.observe(age);
        }
        assert_eq!(h.count(), 7);
        let qs: Vec<f64> = [0.1, 0.5, 0.9, 0.99].iter().map(|&q| h.quantile(q)).collect();
        for w in qs.windows(2) {
            assert!(w[0] <= w[1], "quantiles must be monotone: {qs:?}");
        }
        // p99 must cover the 10.0 sample (upper-edge contract)
        assert!(qs[3] >= 10.0);
        // the two ≤-resolution samples land in the zero bucket
        assert_eq!(h.quantile(0.0), 0.0);
        assert!(h.mean() > 0.0);
    }

    #[test]
    fn empty_histo_is_nan() {
        let h = AgeHisto::new();
        assert!(h.quantile(0.5).is_nan());
        assert!(h.mean().is_nan());
        assert_eq!(h.count(), 0);
    }

    #[test]
    fn merge_is_order_independent_on_counts() {
        let mut a = AgeHisto::new();
        let mut b = AgeHisto::new();
        for age in [0.0, 0.5, 2.0] {
            a.observe(age);
        }
        for age in [0.25, 4.0] {
            b.observe(age);
        }
        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        assert_eq!(ab.count(), 5);
        assert_eq!(ab.zero, ba.zero);
        assert_eq!(ab.counts, ba.counts);
        assert_eq!(ab.quantile(0.5).to_bits(), ba.quantile(0.5).to_bits());
    }

    #[test]
    fn metrics_record_and_merge() {
        let mut m = ServingMetrics::new();
        m.record(true, 0.0, 0, 9);
        m.record(false, 1.5, 9, 0);
        m.record_dead();
        assert_eq!(m.served, 2);
        assert_eq!(m.fresh_serves, 1);
        assert_eq!(m.stale_serves, 1);
        assert_eq!(m.dead_serves, 1);
        assert!((m.stale_fraction() - 0.5).abs() < 1e-12);
        assert_eq!(m.by_quality[0].count(), 1);
        assert_eq!(m.by_quality[9].count(), 1);
        assert_eq!(m.by_popularity[9].count(), 1);

        let mut other = ServingMetrics::new();
        other.record(false, 3.0, 9, 9);
        m.merge(&other);
        assert_eq!(m.served, 3);
        assert_eq!(m.stale_serves, 2);
        assert_eq!(m.by_quality[9].count(), 2);
    }

    #[test]
    fn out_of_range_deciles_clamp_to_tail() {
        let mut m = ServingMetrics::new();
        m.record(false, 1.0, 99, 99);
        assert_eq!(m.by_quality[9].count(), 1);
        assert_eq!(m.by_popularity[9].count(), 1);
    }

    #[test]
    fn rep_accumulator_summarizes_percentiles() {
        let mut acc = ServingRepAccumulator::new();
        for stale_age in [1.0, 2.0] {
            let mut m = ServingMetrics::new();
            m.record(true, 0.0, 0, 0);
            m.record(false, stale_age, 5, 5);
            acc.push(&m);
        }
        assert_eq!(acc.reps(), 2);
        assert_eq!(acc.totals().served, 4);
        let p99 = acc.p99();
        assert_eq!(p99.n, 2);
        assert!(p99.mean >= 1.0);
        assert!((acc.stale_fraction().mean - 0.5).abs() < 1e-12);
    }
}
