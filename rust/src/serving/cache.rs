//! Freshness cache: answers each user request from the last crawled
//! copy and accounts staleness-at-request.
//!
//! The cache mirrors the engine's freshness bit exactly — the engine
//! forwards its own `on_change` / `on_crawl` transitions, so "fresh"
//! here is *defined* as the engine's `!changed[i]` (a request at the
//! exact instant of a change is stale, matching the shared
//! `(time, kind, page)` total order). On top of the bit it keeps the
//! *first* un-crawled change time per page (`dirty_since`), which turns
//! every serve into a staleness **age**: how long the served copy had
//! been out of date at request time. A crawl resets the page to clean;
//! later changes re-arm the clock at their own timestamp.

/// Per-page freshness state plus serve counters.
#[derive(Debug, Clone, Default)]
pub struct FreshnessCache {
    /// Time of the first change since the last crawl; `INFINITY` =
    /// clean (the crawled copy is current).
    dirty_since: Vec<f64>,
    /// Total serves per page.
    serves: Vec<u64>,
    /// Stale serves per page.
    stale_serves: Vec<u64>,
}

impl FreshnessCache {
    /// Cache over `m` pages, all clean.
    pub fn new(m: usize) -> Self {
        Self {
            dirty_since: vec![f64::INFINITY; m],
            serves: vec![0; m],
            stale_serves: vec![0; m],
        }
    }

    /// Number of tracked slots.
    pub fn len(&self) -> usize {
        self.dirty_since.len()
    }

    /// Is the cache empty?
    pub fn is_empty(&self) -> bool {
        self.dirty_since.is_empty()
    }

    /// Grow (never shrink) so slot `i` exists — the dynamic-world
    /// newborn path. New slots start clean with zeroed counters.
    pub fn ensure_slot(&mut self, i: usize) {
        if i >= self.dirty_since.len() {
            self.dirty_since.resize(i + 1, f64::INFINITY);
            self.serves.resize(i + 1, 0);
            self.stale_serves.resize(i + 1, 0);
        }
    }

    /// Reset slot `i` to clean with zeroed counters (slot reuse when a
    /// retired page's slot is handed to a newborn).
    pub fn reset_slot(&mut self, i: usize) {
        self.ensure_slot(i);
        self.dirty_since[i] = f64::INFINITY;
        self.serves[i] = 0;
        self.stale_serves[i] = 0;
    }

    /// The page changed at `t`: arm the staleness clock if it was clean
    /// (later changes before a crawl keep the *first* dirty time — the
    /// served copy has been stale since then).
    #[inline]
    pub fn on_change(&mut self, i: usize, t: f64) {
        if i < self.dirty_since.len() && self.dirty_since[i].is_infinite() {
            self.dirty_since[i] = t;
        }
    }

    /// The page was crawled: the cached copy is current again.
    #[inline]
    pub fn on_crawl(&mut self, i: usize) {
        if i < self.dirty_since.len() {
            self.dirty_since[i] = f64::INFINITY;
        }
    }

    /// Serve page `i` at time `t`: returns `(fresh, age)` where `age`
    /// is the staleness-at-request (0 for a fresh serve; a request at
    /// the exact change instant is stale with age 0).
    #[inline]
    pub fn serve(&mut self, i: usize, t: f64) -> (bool, f64) {
        self.serves[i] += 1;
        let since = self.dirty_since[i];
        if since.is_infinite() {
            (true, 0.0)
        } else {
            self.stale_serves[i] += 1;
            (false, (t - since).max(0.0))
        }
    }

    /// Total serves recorded for page `i`.
    pub fn serves(&self, i: usize) -> u64 {
        self.serves[i]
    }

    /// Stale serves recorded for page `i`.
    pub fn stale_serves(&self, i: usize) -> u64 {
        self.stale_serves[i]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clean_page_serves_fresh_with_zero_age() {
        let mut c = FreshnessCache::new(3);
        assert_eq!(c.serve(1, 5.0), (true, 0.0));
        assert_eq!(c.serves(1), 1);
        assert_eq!(c.stale_serves(1), 0);
    }

    #[test]
    fn age_runs_from_first_change_until_crawl() {
        let mut c = FreshnessCache::new(1);
        c.on_change(0, 2.0);
        c.on_change(0, 3.0); // later change does not reset the clock
        let (fresh, age) = c.serve(0, 5.0);
        assert!(!fresh);
        assert_eq!(age, 3.0);
        c.on_crawl(0);
        assert_eq!(c.serve(0, 6.0), (true, 0.0));
        // a fresh change after the crawl re-arms at its own time
        c.on_change(0, 7.0);
        assert_eq!(c.serve(0, 7.5), (false, 0.5));
        assert_eq!(c.serves(0), 3);
        assert_eq!(c.stale_serves(0), 2);
    }

    #[test]
    fn request_at_change_instant_is_stale_with_zero_age() {
        let mut c = FreshnessCache::new(1);
        c.on_change(0, 4.0);
        assert_eq!(c.serve(0, 4.0), (false, 0.0));
    }

    #[test]
    fn slots_grow_and_reset_for_the_dynamic_world() {
        let mut c = FreshnessCache::new(2);
        c.ensure_slot(5);
        assert_eq!(c.len(), 6);
        c.on_change(5, 1.0);
        assert_eq!(c.serve(5, 2.0), (false, 1.0));
        c.reset_slot(5);
        assert_eq!(c.serve(5, 3.0), (true, 0.0));
        assert_eq!(c.serves(5), 1, "reset zeroes the counters");
        // out-of-range hooks are ignored rather than panicking
        c.on_change(99, 1.0);
        c.on_crawl(99);
    }
}
