//! Request-side serving layer: heavy-tailed user traffic, the
//! freshness cache it is answered from, and fairness-at-request
//! metrics.
//!
//! The crawl policies in this repo optimize *freshness at request
//! time* (the objective the source paper inherits from Azar et al.'s
//! request-weighted staleness), but until this module requests only
//! existed as trace events folded into a scalar accuracy. The serving
//! layer closes the loop:
//!
//! - [`traffic`] generates user demand as a lazy stream —
//!   [`RequestTraffic`] composes per-page Zipf popularity (shared
//!   [`crate::stats::Zipf`] sampler), diurnal modulation and
//!   flash-crowd spikes, sampled by Lewis–Shedler thinning at O(1) per
//!   event from a traffic-owned RNG;
//! - [`cache`] is the [`FreshnessCache`] answering each request from
//!   the last crawled copy, recording hit-freshness and
//!   staleness-at-request age per page;
//! - [`metrics`] accumulates [`ServingMetrics`]: log-bucket staleness
//!   percentiles plus fairness breakdowns by CIS-quality decile and
//!   popularity decile, with a deterministic cross-shard
//!   [`ServingMetrics::merge`].
//!
//! [`ServingSession`] bundles the three into the single handle the
//! engines thread through their merge loops (`sim::engine` and
//! `scenario::engine` both take an `Option<&mut ServingSession>`; the
//! `None` / empty-traffic configuration is pinned bit-identical to the
//! plain engines by `tests/serving_parity.rs`, the same discipline as
//! the scenario and fault subsystems).
//!
//! ## Fairness deciles
//!
//! The fairness claim under test is "comparable staleness regardless
//! of CIS quality". Pages are ranked once, at session construction,
//! by the scalar CIS-quality score `precision · recall` (see
//! [`crate::params::PageParams`]); decile 0 holds the worst-signalled
//! tenth, decile 9 the best. Popularity deciles come straight from the
//! Zipf law: page index *is* popularity rank, so decile 0 is the
//! most-requested head. Pages born mid-run (dynamic world) are slotted
//! by score against the initial population's ladder.

pub mod cache;
pub mod metrics;
pub mod traffic;

pub use cache::FreshnessCache;
pub use metrics::{AgeHisto, ServingMetrics, ServingRepAccumulator, AGE_BUCKETS, AGE_RESOLUTION, DECILES};
pub use traffic::{FlashCrowd, RequestTraffic, TrafficStream};

use crate::params::PageParams;
use traffic::TrafficStream as Stream;

/// One run's serving state: the pending-request stream, the freshness
/// cache, decile assignments and the metrics sink. Built fresh per
/// repetition (the stream is single-pass), threaded through an engine
/// by mutable reference, then read out via [`ServingSession::metrics`].
#[derive(Debug, Clone)]
pub struct ServingSession {
    stream: Stream,
    cache: FreshnessCache,
    metrics: ServingMetrics,
    /// CIS-quality decile per page slot (0 = worst signals).
    qdecile: Vec<u8>,
    /// Initial population's quality scores, ascending — the ladder
    /// newborn pages are slotted against.
    score_ladder: Vec<f64>,
    /// Initial population size (fixes the popularity-decile scale).
    m0: usize,
}

impl ServingSession {
    /// Session over the initial population `pages` with traffic
    /// `traffic` up to `horizon`.
    pub fn new(traffic: &RequestTraffic, pages: &[PageParams], horizon: f64) -> Self {
        let m = pages.len();
        let scores: Vec<f64> = pages.iter().map(Self::score).collect();
        // rank-based decile assignment: sort by (score, index), decile
        // = rank·10/m — exactly m/10-sized cohorts up to rounding
        let mut order: Vec<usize> = (0..m).collect();
        order.sort_by(|&a, &b| scores[a].total_cmp(&scores[b]).then(a.cmp(&b)));
        let mut qdecile = vec![0u8; m];
        for (rank, &i) in order.iter().enumerate() {
            qdecile[i] = ((rank * DECILES) / m.max(1)).min(DECILES - 1) as u8;
        }
        let mut score_ladder = scores;
        score_ladder.sort_by(f64::total_cmp);
        Self {
            stream: traffic.stream(m, horizon),
            cache: FreshnessCache::new(m),
            metrics: ServingMetrics::new(),
            qdecile,
            score_ladder,
            m0: m,
        }
    }

    /// The scalar CIS-quality score pages are ranked by.
    #[inline]
    fn score(p: &PageParams) -> f64 {
        p.precision() * p.recall()
    }

    /// Time of the next pending request (`INFINITY` when drained).
    #[inline]
    pub fn next_time(&self) -> f64 {
        self.stream.next_time()
    }

    /// Consume the pending request.
    #[inline]
    pub fn pop(&mut self) -> Option<(f64, usize)> {
        self.stream.pop()
    }

    /// Engine hook: page `i` changed at `t`.
    #[inline]
    pub fn on_change(&mut self, i: usize, t: f64) {
        self.cache.on_change(i, t);
    }

    /// Engine hook: page `i` was crawled.
    #[inline]
    pub fn on_crawl(&mut self, i: usize) {
        self.cache.on_crawl(i);
    }

    /// Dynamic-world hook: a page was born (or reborn) into slot `i`.
    /// The slot's cache state resets and its quality decile is
    /// re-assigned by score against the initial population's ladder.
    pub fn on_page_added(&mut self, i: usize, params: &PageParams) {
        self.cache.reset_slot(i);
        if i >= self.qdecile.len() {
            self.qdecile.resize(i + 1, 0);
        }
        let s = Self::score(params);
        let rank = self.score_ladder.partition_point(|&x| x < s);
        let n = self.score_ladder.len().max(1);
        self.qdecile[i] = ((rank * DECILES) / n).min(DECILES - 1) as u8;
    }

    /// Serve a request for slot `i` at time `t`. `live` is the
    /// engine's view of whether a page currently occupies the slot;
    /// requests into retired or never-born slots count as dead serves
    /// and stay out of the age histograms.
    ///
    /// Returns `Some(fresh)` for a live serve and `None` for a dead
    /// one, so tracing callers can report the outcome without a second
    /// cache probe. Untraced engines ignore the return value.
    pub fn serve(&mut self, i: usize, t: f64, live: bool) -> Option<bool> {
        if !live || i >= self.cache.len() {
            self.metrics.record_dead();
            return None;
        }
        let (fresh, age) = self.cache.serve(i, t);
        let qd = self.qdecile.get(i).copied().unwrap_or(0) as usize;
        let pd = if self.m0 == 0 { 0 } else { ((i * DECILES) / self.m0).min(DECILES - 1) };
        self.metrics.record(fresh, age, qd, pd);
        Some(fresh)
    }

    /// The accumulated serving metrics.
    pub fn metrics(&self) -> &ServingMetrics {
        &self.metrics
    }

    /// Consume the session, returning its metrics.
    pub fn into_metrics(self) -> ServingMetrics {
        self.metrics
    }

    /// The per-page cache (serve counters, freshness state).
    pub fn cache(&self) -> &FreshnessCache {
        &self.cache
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quality_page(precision: f64, recall: f64) -> PageParams {
        PageParams::from_quality(0.5, 0.1, precision, recall)
    }

    #[test]
    fn quality_deciles_are_rank_based_cohorts() {
        // 20 pages with strictly increasing quality score: two per decile
        let pages: Vec<PageParams> =
            (0..20).map(|k| quality_page(0.05 + 0.045 * k as f64, 0.9)).collect();
        let s = ServingSession::new(&RequestTraffic::off(), &pages, 10.0);
        for (i, &d) in s.qdecile.iter().enumerate() {
            assert_eq!(d as usize, i / 2, "page {i}");
        }
    }

    #[test]
    fn serve_routes_ages_into_the_right_deciles() {
        let pages: Vec<PageParams> =
            (0..10).map(|k| quality_page(0.1 + 0.08 * k as f64, 0.9)).collect();
        let mut s = ServingSession::new(&RequestTraffic::off(), &pages, 10.0);
        s.on_change(9, 1.0); // best-quality page goes stale
        s.serve(9, 3.0, true); // stale, age 2, quality decile 9, pop decile 9
        s.serve(0, 3.0, true); // fresh, quality decile 0, pop decile 0
        s.serve(4, 3.0, false); // retired slot -> dead
        let m = s.metrics();
        assert_eq!(m.served, 2);
        assert_eq!(m.stale_serves, 1);
        assert_eq!(m.dead_serves, 1);
        assert_eq!(m.by_quality[9].count(), 1);
        assert_eq!(m.by_quality[0].count(), 1);
        assert_eq!(m.by_popularity[9].count(), 1);
        assert!((m.by_quality[9].mean() - 2.0).abs() < 1e-12);
        // crawl cleans the page again
        s.on_crawl(9);
        s.serve(9, 4.0, true);
        assert_eq!(s.metrics().fresh_serves, 2);
    }

    #[test]
    fn newborn_pages_slot_by_score_against_the_initial_ladder() {
        let pages: Vec<PageParams> =
            (0..10).map(|k| quality_page(0.1 + 0.08 * k as f64, 0.9)).collect();
        let mut s = ServingSession::new(&RequestTraffic::off(), &pages, 10.0);
        // newborn with a near-perfect signal lands in the top decile,
        // one with hopeless signals at the bottom; both slots serve
        s.on_page_added(3, &quality_page(0.99, 1.0));
        assert_eq!(s.qdecile[3], 9);
        s.on_page_added(12, &quality_page(0.01, 0.05));
        assert_eq!(s.qdecile[12], 0);
        s.serve(12, 1.0, true);
        assert_eq!(s.metrics().served, 1);
        // slot reuse resets the cache: old dirt is gone
        s.on_change(3, 0.5);
        s.on_page_added(3, &quality_page(0.5, 0.5));
        s.serve(3, 2.0, true);
        assert_eq!(s.metrics().stale_serves, 0);
    }

    #[test]
    fn out_of_range_serves_count_dead() {
        let pages = vec![quality_page(0.5, 0.5); 4];
        let mut s = ServingSession::new(&RequestTraffic::off(), &pages, 10.0);
        s.serve(17, 1.0, true); // slot never existed
        assert_eq!(s.metrics().dead_serves, 1);
        assert_eq!(s.metrics().served, 0);
    }

    #[test]
    fn session_streams_traffic_in_time_order() {
        let pages = vec![quality_page(0.5, 0.5); 8];
        let traffic = RequestTraffic::new(50.0, 1.0, 0xCAFE).unwrap();
        let mut s = ServingSession::new(&traffic, &pages, 20.0);
        let mut prev = 0.0;
        let mut n = 0usize;
        while let Some((t, page)) = s.pop() {
            assert!(t >= prev && t <= 20.0);
            assert!(page < 8);
            prev = t;
            n += 1;
        }
        assert!(n > 100, "expected substantial traffic, got {n}");
        assert!(s.next_time().is_infinite());
    }
}
