//! Heavy-tailed request traffic: per-page Zipf popularity, diurnal
//! modulation, and flash-crowd spikes.
//!
//! [`RequestTraffic`] is the validated *configuration* (rate, Zipf
//! exponent, seed, optional diurnal cycle, flash crowds);
//! [`TrafficStream`] is the lazy per-repetition *stream* built from it:
//! a Lewis–Shedler thinning sampler over the non-homogeneous aggregate
//! rate λ(t) = base·(1 + A·sin(2πt/P)) + Σ active flash extras, drawing
//! every variate from a traffic-owned [`Rng`] so attaching traffic to
//! an engine perturbs **zero** draws of the trace or scenario RNG
//! streams (the zero-traffic bit-parity discipline of
//! `tests/serving_parity.rs`). Page attribution on acceptance splits
//! proportionally: with probability base(t)/λ(t) the request lands on
//! the Zipf popularity law (page 0 most popular), otherwise on the
//! flash crowd whose extra rate covers the draw. Each emitted event
//! costs O(1) expected work (thinning acceptance is bounded below by
//! min λ(t) / λ_max, a constant of the configuration).

use crate::error::Error;
use crate::rngkit::{exponential, Rng};
use crate::stats::Zipf;

/// A flash-crowd spike: `extra_rate` additional requests per unit time
/// aimed at a single page over `[t0, t0 + duration)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FlashCrowd {
    /// Spike onset time.
    pub t0: f64,
    /// Spike duration (the spike is active on `[t0, t0 + duration)`).
    pub duration: f64,
    /// Target page index.
    pub page: usize,
    /// Additional aggregate request rate while active.
    pub extra_rate: f64,
}

impl FlashCrowd {
    #[inline]
    fn active(&self, t: f64) -> bool {
        t >= self.t0 && t < self.t0 + self.duration
    }
}

/// Validated request-traffic configuration.
///
/// `Default` (and [`RequestTraffic::off`]) is the zero-traffic
/// configuration: no base rate, no flash crowds — attaching it to any
/// engine is bit-identical to running without a serving layer.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RequestTraffic {
    rate: f64,
    zipf_s: f64,
    seed: u64,
    diurnal: Option<(f64, f64)>,
    flashes: Vec<FlashCrowd>,
}

impl RequestTraffic {
    /// Base traffic: aggregate rate `rate` requests per unit time,
    /// pages drawn from a Zipf(`zipf_s`) popularity law (page index =
    /// popularity rank), variates keyed by `seed`.
    pub fn new(rate: f64, zipf_s: f64, seed: u64) -> crate::Result<Self> {
        if !(rate >= 0.0) || !rate.is_finite() {
            return Err(Error::InvalidParam(format!(
                "traffic rate must be finite and >= 0, got {rate}"
            )));
        }
        if !(zipf_s >= 0.0) || !zipf_s.is_finite() {
            return Err(Error::InvalidParam(format!(
                "traffic Zipf exponent must be finite and >= 0, got {zipf_s}"
            )));
        }
        Ok(Self { rate, zipf_s, seed, diurnal: None, flashes: Vec::new() })
    }

    /// The zero-traffic configuration (no requests ever).
    pub fn off() -> Self {
        Self::default()
    }

    /// Add a diurnal cycle: the base rate is modulated by
    /// `1 + amplitude·sin(2πt/period)`; `amplitude ∈ [0, 1]` keeps the
    /// instantaneous rate non-negative.
    pub fn with_diurnal(mut self, period: f64, amplitude: f64) -> crate::Result<Self> {
        if !(period > 0.0) || !period.is_finite() {
            return Err(Error::InvalidParam(format!(
                "diurnal period must be finite and > 0, got {period}"
            )));
        }
        if !(0.0..=1.0).contains(&amplitude) {
            return Err(Error::InvalidParam(format!(
                "diurnal amplitude must be in [0, 1], got {amplitude}"
            )));
        }
        self.diurnal = Some((period, amplitude));
        Ok(self)
    }

    /// Add a flash-crowd spike aimed at `page`.
    pub fn with_flash(
        mut self,
        t0: f64,
        duration: f64,
        page: usize,
        extra_rate: f64,
    ) -> crate::Result<Self> {
        if !(t0 >= 0.0) || !t0.is_finite() {
            return Err(Error::InvalidParam(format!(
                "flash onset must be finite and >= 0, got {t0}"
            )));
        }
        if !(duration > 0.0) || !duration.is_finite() {
            return Err(Error::InvalidParam(format!(
                "flash duration must be finite and > 0, got {duration}"
            )));
        }
        if !(extra_rate > 0.0) || !extra_rate.is_finite() {
            return Err(Error::InvalidParam(format!(
                "flash extra rate must be finite and > 0, got {extra_rate}"
            )));
        }
        self.flashes.push(FlashCrowd { t0, duration, page, extra_rate });
        Ok(self)
    }

    /// True when this configuration can never emit a request.
    pub fn is_off(&self) -> bool {
        self.rate <= 0.0 && self.flashes.is_empty()
    }

    /// Base aggregate rate.
    pub fn rate(&self) -> f64 {
        self.rate
    }

    /// Zipf popularity exponent.
    pub fn zipf_s(&self) -> f64 {
        self.zipf_s
    }

    /// Traffic RNG seed.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Configured diurnal cycle as `(period, amplitude)`, if any. The
    /// DSL renderer needs this to round-trip a traffic block.
    pub fn diurnal(&self) -> Option<(f64, f64)> {
        self.diurnal
    }

    /// Configured flash crowds.
    pub fn flashes(&self) -> &[FlashCrowd] {
        &self.flashes
    }

    /// Build the lazy per-repetition stream over `m` pages up to
    /// `horizon`. Single-pass: build a fresh stream per repetition.
    pub fn stream(&self, m: usize, horizon: f64) -> TrafficStream {
        let off = self.is_off() || m == 0 || horizon <= 0.0;
        let (amp_bound, zipf) = if off {
            (0.0, None)
        } else {
            let amp = self.diurnal.map(|(_, a)| a).unwrap_or(0.0);
            (amp, if self.rate > 0.0 { Some(Zipf::new(m, self.zipf_s)) } else { None })
        };
        let rate_max = if off {
            0.0
        } else {
            self.rate * (1.0 + amp_bound)
                + self.flashes.iter().map(|f| f.extra_rate).sum::<f64>()
        };
        let mut stream = TrafficStream {
            base_rate: self.rate,
            rate_max,
            diurnal: self.diurnal,
            flashes: self.flashes.clone(),
            zipf,
            rng: Rng::new(self.seed),
            horizon,
            t: 0.0,
            pending: None,
        };
        stream.advance();
        stream
    }
}

/// Lazy request-arrival stream: O(1) state, one pending `(time, page)`
/// event regenerated on [`TrafficStream::pop`].
#[derive(Debug, Clone)]
pub struct TrafficStream {
    base_rate: f64,
    rate_max: f64,
    diurnal: Option<(f64, f64)>,
    flashes: Vec<FlashCrowd>,
    zipf: Option<Zipf>,
    rng: Rng,
    horizon: f64,
    t: f64,
    pending: Option<(f64, usize)>,
}

impl TrafficStream {
    /// Time of the pending request, `INFINITY` when the stream is
    /// exhausted (or the configuration is off).
    #[inline]
    pub fn next_time(&self) -> f64 {
        match self.pending {
            Some((t, _)) => t,
            None => f64::INFINITY,
        }
    }

    /// Consume the pending request and sample the next one.
    pub fn pop(&mut self) -> Option<(f64, usize)> {
        let ev = self.pending.take();
        if ev.is_some() {
            self.advance();
        }
        ev
    }

    /// Instantaneous base rate at `t` (diurnal-modulated).
    #[inline]
    fn base_at(&self, t: f64) -> f64 {
        match self.diurnal {
            Some((period, amp)) => {
                self.base_rate * (1.0 + amp * (std::f64::consts::TAU * t / period).sin())
            }
            None => self.base_rate,
        }
    }

    /// Sum of active flash extras at `t`.
    #[inline]
    fn flash_at(&self, t: f64) -> f64 {
        self.flashes.iter().filter(|f| f.active(t)).map(|f| f.extra_rate).sum()
    }

    /// Lewis–Shedler thinning: propose at `rate_max`, accept with
    /// probability λ(t)/rate_max, then attribute the accepted request
    /// proportionally to the base law or an active flash.
    fn advance(&mut self) {
        self.pending = None;
        if self.rate_max <= 0.0 {
            return;
        }
        loop {
            self.t += exponential(&mut self.rng, self.rate_max);
            if self.t > self.horizon {
                return;
            }
            let base = self.base_at(self.t);
            let flash = self.flash_at(self.t);
            let lam = base + flash;
            if lam <= 0.0 {
                continue;
            }
            if self.rng.f64() * self.rate_max < lam {
                let u = self.rng.f64() * lam;
                let page = if u < base {
                    match &self.zipf {
                        Some(z) => z.sample(&mut self.rng),
                        None => 0,
                    }
                } else {
                    self.flash_target(self.t, u - base)
                };
                self.pending = Some((self.t, page));
                return;
            }
        }
    }

    /// Pick the active flash whose extra-rate span covers `u`.
    fn flash_target(&self, t: f64, mut u: f64) -> usize {
        let mut last = 0usize;
        for f in self.flashes.iter().filter(|f| f.active(t)) {
            last = f.page;
            if u < f.extra_rate {
                return f.page;
            }
            u -= f.extra_rate;
        }
        // float-edge fallback: attribute to the last active flash
        last
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain(mut s: TrafficStream) -> Vec<(f64, usize)> {
        let mut out = Vec::new();
        while let Some(ev) = s.pop() {
            out.push(ev);
        }
        out
    }

    #[test]
    fn off_stream_emits_nothing() {
        let t = RequestTraffic::off();
        assert!(t.is_off());
        let s = t.stream(100, 50.0);
        assert!(s.next_time().is_infinite());
        assert!(drain(s).is_empty());
        // zero-rate with no flashes is also off
        assert!(RequestTraffic::new(0.0, 1.0, 7).unwrap().is_off());
    }

    #[test]
    fn validation_rejects_bad_configs() {
        assert!(RequestTraffic::new(-1.0, 1.0, 0).is_err());
        assert!(RequestTraffic::new(f64::NAN, 1.0, 0).is_err());
        assert!(RequestTraffic::new(1.0, -0.5, 0).is_err());
        let t = RequestTraffic::new(1.0, 1.0, 0).unwrap();
        assert!(t.clone().with_diurnal(0.0, 0.5).is_err());
        assert!(t.clone().with_diurnal(10.0, 1.5).is_err());
        assert!(t.clone().with_flash(-1.0, 1.0, 0, 5.0).is_err());
        assert!(t.clone().with_flash(1.0, 0.0, 0, 5.0).is_err());
        assert!(t.with_flash(1.0, 1.0, 0, 0.0).is_err());
    }

    #[test]
    fn arrivals_are_ordered_within_horizon_and_deterministic() {
        let cfg = RequestTraffic::new(20.0, 1.1, 0xBEEF)
            .unwrap()
            .with_diurnal(10.0, 0.5)
            .unwrap()
            .with_flash(5.0, 2.0, 3, 30.0)
            .unwrap();
        let a = drain(cfg.stream(50, 40.0));
        let b = drain(cfg.stream(50, 40.0));
        assert_eq!(a, b, "same config + seed must replay identically");
        assert!(!a.is_empty());
        let mut prev = 0.0;
        for &(t, page) in &a {
            assert!(t >= prev && t <= 40.0, "ordered within horizon, got {t}");
            assert!(page < 50);
            prev = t;
        }
        // a different seed gives a different realization
        let c = drain(RequestTraffic::new(20.0, 1.1, 0xF00D).unwrap().stream(50, 40.0));
        assert_ne!(a, c);
    }

    #[test]
    fn zipf_popularity_favours_low_indices() {
        let cfg = RequestTraffic::new(200.0, 1.2, 11).unwrap();
        let evs = drain(cfg.stream(64, 100.0));
        let head = evs.iter().filter(|&&(_, p)| p < 8).count();
        // Zipf(1.2) over 64 pages puts well over half the mass on the
        // first 8 ranks; 20k+ samples make this a >5σ-safe bound
        assert!(evs.len() > 5_000);
        assert!(head * 2 > evs.len(), "head {head} of {}", evs.len());
    }

    #[test]
    fn flash_crowd_concentrates_on_target_during_window() {
        let cfg = RequestTraffic::new(5.0, 1.0, 3)
            .unwrap()
            .with_flash(10.0, 5.0, 42, 200.0)
            .unwrap();
        let evs = drain(cfg.stream(100, 30.0));
        let in_window: Vec<_> =
            evs.iter().filter(|&&(t, _)| (10.0..15.0).contains(&t)).collect();
        let on_target = in_window.iter().filter(|&&&(_, p)| p == 42).count();
        assert!(in_window.len() > 500, "spike volume {}", in_window.len());
        assert!(
            on_target * 10 > in_window.len() * 9,
            "flash target should dominate the window: {on_target}/{}",
            in_window.len()
        );
        // outside the window the target is just an ordinary tail page
        let outside_on_target =
            evs.iter().filter(|&&(t, p)| !(10.0..15.0).contains(&t) && p == 42).count();
        assert!(outside_on_target * 10 < evs.len());
    }

    #[test]
    fn diurnal_modulation_shifts_volume_between_half_periods() {
        // period 20: sin > 0 on (0, 10), sin < 0 on (10, 20)
        let cfg = RequestTraffic::new(100.0, 0.0, 9).unwrap().with_diurnal(20.0, 0.9).unwrap();
        let evs = drain(cfg.stream(10, 20.0));
        let first = evs.iter().filter(|&&(t, _)| t < 10.0).count();
        let second = evs.len() - first;
        assert!(
            first as f64 > 1.5 * second as f64,
            "peak half-period should dominate: {first} vs {second}"
        );
    }
}
