//! Mini property-testing kit + shared fixtures (the image has no
//! `proptest`; this provides the same invariant-checking workflow:
//! seeded random case generation, failure reporting with the offending
//! case, and a fixed regression corpus).

use crate::params::{Instance, PageParams};
use crate::rngkit::{self, Rng};

/// Run `prop` on `cases` random inputs from `gen`. Panics with the seed
/// and debug dump of the first failing case.
pub fn forall<T: std::fmt::Debug>(
    name: &str,
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> std::result::Result<(), String>,
) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let mut crng = rng.split(case as u64);
        let input = gen(&mut crng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property `{name}` failed at case {case} (seed {seed}):\n  {msg}\n  input: {input:?}"
            );
        }
    }
}

/// Random page parameters covering the degenerate corners.
pub fn arb_page(rng: &mut Rng) -> PageParams {
    let corner = rng.below(8);
    PageParams {
        delta: rng.range(1e-2, 2.0),
        mu: rng.range(0.0, 1.0),
        lam: match corner {
            0 => 0.0,
            1 => 1.0,
            _ => rng.f64(),
        },
        nu: match corner {
            0 | 2 => 0.0,
            _ => rng.range(0.0, 1.0),
        },
    }
}

/// Random instance in the paper's §6.1 style.
pub fn arb_instance(rng: &mut Rng, m: usize, bandwidth: f64, with_cis: bool) -> Instance {
    let pages = (0..m)
        .map(|_| PageParams {
            delta: rng.range(1e-3, 1.0),
            mu: rng.range(1e-3, 1.0),
            lam: if with_cis { rngkit::beta(rng, 0.25, 0.25) } else { 0.0 },
            nu: if with_cis { rng.range(0.1, 0.6) } else { 0.0 },
        })
        .collect();
    Instance { pages, bandwidth }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_valid_property() {
        forall(
            "pages validate",
            1,
            200,
            arb_page,
            |p| p.validate().map_err(|e| e.to_string()),
        );
    }

    #[test]
    #[should_panic(expected = "property `always fails`")]
    fn forall_reports_failures() {
        forall("always fails", 2, 10, |r| r.f64(), |_| Err("nope".into()));
    }

    #[test]
    fn arb_instance_shape() {
        let mut rng = Rng::new(3);
        let inst = arb_instance(&mut rng, 50, 10.0, true);
        assert_eq!(inst.pages.len(), 50);
        assert!(inst.pages.iter().all(|p| p.validate().is_ok()));
    }
}
