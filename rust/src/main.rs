//! `ncis-crawl` CLI — the leader entrypoint.

use ncis_crawl::cli::Args;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = ncis_crawl::run_cli(&args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
