//! Optimal continuous policies via Lagrange-multiplier line search.
//!
//! Two solvers:
//!
//! - [`solve_no_cis`] — the classical problem (5): maximize
//!   `Σ G(ξ_i; μ̃_i, Δ_i)` s.t. `Σ ξ_i ≤ R` with
//!   `G(ξ; μ̃, Δ) = (μ̃/Δ) ξ (1 − e^{−Δ/ξ})`. KKT: `∂G/∂ξ = (μ̃/Δ)R¹(Δ/ξ) = Λ`,
//!   solved per page by inverting `R¹`, with an outer bisection on `Λ`.
//!   This is the paper's BASELINE (optimal continuous policy, no CIS).
//!
//! - [`solve_with_cis`] — the general problem (4) of Theorem 1:
//!   per page find `ι_i` with `V(ι_i; E_i) = Λ` (line search on the
//!   monotone `V`), outer bisection on `Λ` until `Σ f(ι_i; E_i) = R`.
//!
//! Both return enough structure to (a) compute the analytical optimal
//! accuracy and (b) feed the LDS discretizer with per-page rates.

use crate::error::{Error, Result};
use crate::params::{DerivedParams, Instance};
use crate::policy::value;
#[cfg(test)]
use crate::policy::value::MAX_TERMS;
use crate::special::{exp_residual, inv_exp_residual1};

/// Solution of a continuous crawl-rate optimization.
#[derive(Debug, Clone)]
pub struct ContinuousSolution {
    /// Optimal crawl rate ξ_i* per page (0 = never crawl).
    pub rates: Vec<f64>,
    /// Optimal threshold ι_i* per page (∞ = never crawl).
    pub thresholds: Vec<f64>,
    /// The Lagrange multiplier Λ at the optimum.
    pub lambda: f64,
    /// Analytical objective value (expected fraction of fresh-served
    /// requests, assuming normalized importance).
    pub objective: f64,
}

/// `G(ξ; μ̃, Δ)`: long-run freshness of a page crawled at fixed rate ξ.
pub fn g_freshness(xi: f64, mu: f64, delta: f64) -> f64 {
    if xi <= 0.0 {
        return 0.0;
    }
    mu / delta * xi * (1.0 - (-delta / xi).exp())
}

/// `∂G/∂ξ = (μ̃/Δ) R¹(Δ/ξ)` — the no-CIS crawl value at rate ξ.
pub fn g_freshness_deriv(xi: f64, mu: f64, delta: f64) -> f64 {
    if xi <= 0.0 {
        return mu / delta; // sup as ξ → 0⁺
    }
    mu / delta * exp_residual(1, delta / xi)
}

fn rate_for_lambda(lambda: f64, mu: f64, delta: f64) -> f64 {
    // Solve (μ̃/Δ) R¹(Δ/ξ) = Λ  =>  R¹(Δ/ξ) = ΛΔ/μ̃.
    if mu <= 0.0 {
        return 0.0;
    }
    let y = lambda * delta / mu;
    if y >= 1.0 {
        return 0.0; // V < Λ everywhere: abandon the page
    }
    let x = inv_exp_residual1(y);
    if x <= 0.0 {
        f64::INFINITY
    } else {
        delta / x
    }
}

/// Solve the classical no-CIS problem (5) for a *normalized* instance.
pub fn solve_no_cis(inst: &Instance) -> Result<ContinuousSolution> {
    let pages = &inst.pages;
    let r = inst.bandwidth;
    if pages.is_empty() || r <= 0.0 {
        return Err(Error::Solver("empty instance or non-positive bandwidth".into()));
    }
    // Λ ∈ (0, max μ̃/Δ); Σξ(Λ) is decreasing in Λ.
    let lam_hi0 = pages
        .iter()
        .filter(|p| p.mu > 0.0)
        .map(|p| p.mu / p.delta)
        .fold(0.0f64, f64::max);
    if lam_hi0 <= 0.0 {
        return Err(Error::Solver("all pages have zero importance".into()));
    }
    let total = |lam: f64| -> f64 {
        pages.iter().map(|p| rate_for_lambda(lam, p.mu, p.delta)).sum()
    };
    let mut hi = lam_hi0 * (1.0 - 1e-12);
    let mut lo = lam_hi0 * 1e-18;
    if total(lo) < r {
        // even a tiny multiplier doesn't spend the budget: bandwidth is
        // effectively unconstrained; use the smallest Λ we can.
        hi = lo;
    }
    for _ in 0..200 {
        let mid = 0.5 * (lo + hi);
        if total(mid) > r {
            lo = mid;
        } else {
            hi = mid;
        }
        if (hi - lo) <= 1e-14 * hi.max(1e-300) {
            break;
        }
    }
    let lambda = 0.5 * (lo + hi);
    let rates: Vec<f64> = pages.iter().map(|p| rate_for_lambda(lambda, p.mu, p.delta)).collect();
    let objective = pages
        .iter()
        .zip(&rates)
        .map(|(p, &xi)| g_freshness(xi, p.mu, p.delta))
        .sum();
    let thresholds = rates.iter().map(|&xi| if xi > 0.0 { 1.0 / xi } else { f64::INFINITY }).collect();
    Ok(ContinuousSolution { rates, thresholds, lambda, objective })
}

/// Solve the general noisy-CIS problem (4)/Theorem 1 for a normalized
/// instance with derived parameters `envs` (one per page).
///
/// `terms` selects the value-function approximation level
/// (`MAX_TERMS` = exact GREEDY-NCIS).
pub fn solve_with_cis(
    inst: &Instance,
    envs: &[DerivedParams],
    terms: u32,
) -> Result<ContinuousSolution> {
    let r = inst.bandwidth;
    if envs.is_empty() || r <= 0.0 {
        return Err(Error::Solver("empty instance or non-positive bandwidth".into()));
    }
    // sup_ι V(ι; E) = μ̃/Δ, so Λ ∈ (0, max μ̃/Δ).
    let lam_hi0 = envs
        .iter()
        .filter(|d| d.mu > 0.0)
        .map(|d| d.mu / d.delta)
        .fold(0.0f64, f64::max);
    if lam_hi0 <= 0.0 {
        return Err(Error::Solver("all pages have zero importance".into()));
    }
    let freq_for_lambda = |lam: f64, d: &DerivedParams| -> f64 {
        match value::inverse_value(lam, d, terms) {
            None => 0.0, // V < Λ everywhere: never crawl
            Some(iota) => value::frequency(iota, d, terms),
        }
    };
    let total = |lam: f64| -> f64 { envs.iter().map(|d| freq_for_lambda(lam, d)).sum() };
    let mut hi = lam_hi0 * (1.0 - 1e-12);
    let mut lo = lam_hi0 * 1e-15;
    if total(lo) < r {
        hi = lo;
    }
    for _ in 0..100 {
        let mid = 0.5 * (lo + hi);
        if total(mid) > r {
            lo = mid;
        } else {
            hi = mid;
        }
        if (hi - lo) <= 1e-12 * hi.max(1e-300) {
            break;
        }
    }
    let lambda = 0.5 * (lo + hi);
    let thresholds: Vec<f64> = envs
        .iter()
        .map(|d| value::inverse_value(lambda, d, terms).unwrap_or(f64::INFINITY))
        .collect();
    let rates: Vec<f64> = envs
        .iter()
        .zip(&thresholds)
        .map(|(d, &iota)| value::frequency(iota, d, terms))
        .collect();
    let objective = envs
        .iter()
        .zip(&thresholds)
        .map(|(d, &iota)| value::objective(iota, d, terms))
        .sum();
    Ok(ContinuousSolution { rates, thresholds, lambda, objective })
}

/// Convenience: BASELINE accuracy of the paper's experiment sections —
/// the optimal continuous no-CIS policy on a normalized instance.
pub fn baseline_accuracy(inst: &Instance) -> Result<f64> {
    Ok(solve_no_cis(&inst.normalized())?.objective)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::PageParams;
    use crate::rngkit::Rng;

    fn uniform_instance(m: usize, r: f64, seed: u64) -> Instance {
        let mut rng = Rng::new(seed);
        let pages = (0..m)
            .map(|_| PageParams {
                delta: rng.range(1e-3, 1.0),
                mu: rng.range(1e-3, 1.0),
                lam: 0.0,
                nu: 0.0,
            })
            .collect();
        Instance { pages, bandwidth: r }
    }

    #[test]
    fn no_cis_budget_is_spent() {
        let inst = uniform_instance(200, 100.0, 1).normalized();
        let sol = solve_no_cis(&inst).unwrap();
        let total: f64 = sol.rates.iter().sum();
        assert!((total - 100.0).abs() < 0.1, "total={total}");
    }

    #[test]
    fn no_cis_kkt_conditions() {
        let inst = uniform_instance(50, 25.0, 2).normalized();
        let sol = solve_no_cis(&inst).unwrap();
        for (p, &xi) in inst.pages.iter().zip(&sol.rates) {
            if xi > 0.0 {
                let v = g_freshness_deriv(xi, p.mu, p.delta);
                assert!(
                    (v - sol.lambda).abs() < 1e-6 * sol.lambda,
                    "dG/dxi={v} lambda={}",
                    sol.lambda
                );
            } else {
                // abandoned page: sup dG/dξ = μ̃/Δ < Λ
                assert!(p.mu / p.delta <= sol.lambda + 1e-12);
            }
        }
    }

    #[test]
    fn no_cis_objective_in_unit_interval() {
        let inst = uniform_instance(300, 100.0, 3).normalized();
        let sol = solve_no_cis(&inst).unwrap();
        assert!(sol.objective > 0.0 && sol.objective <= 1.0, "{}", sol.objective);
    }

    #[test]
    fn more_bandwidth_cannot_hurt() {
        let base = uniform_instance(100, 0.0, 4);
        let mut prev = 0.0;
        for &r in &[10.0, 30.0, 100.0, 300.0] {
            let inst = Instance { pages: base.pages.clone(), bandwidth: r }.normalized();
            let sol = solve_no_cis(&inst).unwrap();
            assert!(sol.objective >= prev - 1e-9, "r={r}");
            prev = sol.objective;
        }
    }

    fn cis_instance(m: usize, r: f64, seed: u64) -> Instance {
        let mut rng = Rng::new(seed);
        let pages = (0..m)
            .map(|_| PageParams {
                delta: rng.range(1e-2, 1.0),
                mu: rng.range(1e-2, 1.0),
                lam: crate::rngkit::beta(&mut rng, 0.25, 0.25),
                nu: rng.range(0.1, 0.6),
            })
            .collect();
        Instance { pages, bandwidth: r }
    }

    #[test]
    fn with_cis_budget_is_spent() {
        let inst = cis_instance(100, 40.0, 5).normalized();
        let envs = inst.derived().unwrap();
        let sol = solve_with_cis(&inst, &envs, MAX_TERMS).unwrap();
        let total: f64 = sol.rates.iter().sum();
        assert!((total - 40.0).abs() < 0.2, "total={total}");
    }

    #[test]
    fn with_cis_kkt_value_equals_lambda() {
        let inst = cis_instance(60, 20.0, 6).normalized();
        let envs = inst.derived().unwrap();
        let sol = solve_with_cis(&inst, &envs, MAX_TERMS).unwrap();
        for (d, &iota) in envs.iter().zip(&sol.thresholds) {
            if iota.is_finite() {
                let v = value::value_ncis(iota, d, MAX_TERMS);
                assert!(
                    (v - sol.lambda).abs() < 1e-5 * sol.lambda.max(1e-12),
                    "V={v} lambda={}",
                    sol.lambda
                );
            }
        }
    }

    #[test]
    fn cis_solution_beats_or_matches_no_cis_objective() {
        // With CIS information the achievable continuous objective can
        // only improve (the no-CIS policy is in the feasible set).
        let inst = cis_instance(80, 25.0, 7).normalized();
        let envs = inst.derived().unwrap();
        let with = solve_with_cis(&inst, &envs, MAX_TERMS).unwrap();
        // evaluate the same thresholds ignoring CIS: compare to no-CIS optimum
        let no_cis_inst = Instance {
            pages: inst.pages.iter().map(|p| PageParams { lam: 0.0, nu: 0.0, ..*p }).collect(),
            bandwidth: inst.bandwidth,
        };
        let without = solve_no_cis(&no_cis_inst).unwrap();
        assert!(
            with.objective >= without.objective - 5e-3,
            "with={} without={}",
            with.objective,
            without.objective
        );
    }

    #[test]
    fn degenerate_inputs_rejected() {
        let inst = Instance { pages: vec![], bandwidth: 10.0 };
        assert!(solve_no_cis(&inst).is_err());
        let inst = uniform_instance(10, 0.0, 8);
        assert!(solve_no_cis(&inst).is_err());
    }
}
