//! Sim-time flight recorder and decision-trace layer.
//!
//! A zero-dependency structured tracing subsystem: engines and
//! schedulers emit compact [`TraceEvent`]s into a bounded per-shard
//! ring buffer ([`FlightRecorder`]) through a cloneable
//! [`TraceHandle`]. The handle is `Option`-gated at every call site —
//! exactly like the serving session — so the untraced branch structure
//! is identical to the traced one and crawl-side picks stay
//! bit-identical whether or not a recorder is attached.
//!
//! Three invariants keep tracing observational:
//!
//! 1. **No RNG.** Nothing in this module draws random numbers, so the
//!    engines' jitter/traffic/fault streams are untouched.
//! 2. **No sim-time feedback.** Events carry sim time but never feed
//!    back into scheduling; wall-clock span timings go only into
//!    [`metrics::Registry`] histograms, never into the JSONL log, so
//!    the drained log is a pure function of (instance, seed, config).
//! 3. **Bounded memory.** Each shard's ring holds at most `capacity`
//!    events and overwrites the oldest on overflow; draining walks
//!    shards in index order, each oldest→newest, which makes the JSONL
//!    output deterministic and byte-identical across same-seed runs.
//!
//! On invariant violation (see [`debug_check`]) the recorder dumps the
//! last [`DUMP_WINDOW`] events to stderr (or a caller-supplied writer)
//! before panicking, so the decision history leading up to the failure
//! is preserved.

use std::io::Write as IoWrite;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

use crate::metrics::{DurationHisto, Registry};

/// Events kept in the window written on invariant violation.
pub const DUMP_WINDOW: usize = 256;

/// Default per-shard ring capacity of a [`FlightRecorder`].
pub const DEFAULT_CAPACITY: usize = 65_536;

/// World-event kinds recorded by the scenario engine
/// (`TraceEvent::World { kind, .. }`).
pub mod world_kind {
    /// A page was born (possibly into a recycled slot).
    pub const BORN: u8 = 0;
    /// A page was retired.
    pub const RETIRED: u8 = 1;
    /// A page's change/importance parameters drifted.
    pub const PARAMS: u8 = 2;
    /// A page's CIS quality shifted.
    pub const QUALITY: u8 = 3;
    /// A CIS outage window toggled.
    pub const OUTAGE: u8 = 4;
}

/// One compact sim-time event. All payloads are `Copy` so the ring
/// buffer stores them inline with no allocation per event.
///
/// Times are sim-time seconds; they must be finite for the JSONL
/// exposition to be valid JSON.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TraceEvent {
    /// A change-indicating signal arrived for `page`.
    Cis { t: f64, page: u32 },
    /// A crawl was applied to `page`; `changed` is whether the copy
    /// was stale at crawl time.
    Crawl { t: f64, page: u32, changed: bool },
    /// The batched argmax chose `page` with score `value`, after
    /// scanning `scanned` candidates across `chunks` chunks;
    /// `early_break` is whether the bound-pruning loop exited before
    /// visiting every chunk.
    Decision {
        t: f64,
        page: u32,
        value: f64,
        chunks: u32,
        scanned: u32,
        early_break: bool,
    },
    /// The engine vetoed the scheduler's pick of `page`.
    Veto { t: f64, page: u32 },
    /// A crawl attempt on `page` failed; `outcome` is the
    /// `CrawlOutcome` discriminant (1 transient, 2 timeout, 3 gone).
    CrawlFailed { t: f64, page: u32, outcome: u8 },
    /// The retry calendar scheduled `page` for re-attempt at `due`.
    Retry { t: f64, page: u32, due: f64 },
    /// `page` exhausted its retry budget and was quarantined.
    Quarantine { t: f64, page: u32 },
    /// A tick was forfeited: its pick `page` was blocked by an outage.
    Forfeit { t: f64, page: u32 },
    /// A tick found nothing crawlable.
    Idle { t: f64 },
    /// The learned-knowledge trust gate for `page` transitioned
    /// (`open` = CIS now trusted / rate projected as positive).
    TrustGate { t: f64, page: u32, open: bool },
    /// The learned decorator re-projected `page`'s belief into the
    /// inner scheduler.
    Reproject { t: f64, page: u32 },
    /// A scenario world event of `kind` (see [`world_kind`]) hit
    /// `page`.
    World { t: f64, kind: u8, page: u32 },
    /// A request for `page` was served; `fresh` is cache freshness at
    /// serve time, `live` whether the page still exists.
    Serve {
        t: f64,
        page: u32,
        fresh: bool,
        live: bool,
    },
}

impl TraceEvent {
    /// Stable event name used in the JSONL exposition.
    pub fn name(&self) -> &'static str {
        match self {
            TraceEvent::Cis { .. } => "cis",
            TraceEvent::Crawl { .. } => "crawl",
            TraceEvent::Decision { .. } => "decision",
            TraceEvent::Veto { .. } => "veto",
            TraceEvent::CrawlFailed { .. } => "crawl_failed",
            TraceEvent::Retry { .. } => "retry",
            TraceEvent::Quarantine { .. } => "quarantine",
            TraceEvent::Forfeit { .. } => "forfeit",
            TraceEvent::Idle { .. } => "idle",
            TraceEvent::TrustGate { .. } => "trust_gate",
            TraceEvent::Reproject { .. } => "reproject",
            TraceEvent::World { .. } => "world",
            TraceEvent::Serve { .. } => "serve",
        }
    }

    /// Sim time the event was recorded at.
    pub fn time(&self) -> f64 {
        match *self {
            TraceEvent::Cis { t, .. }
            | TraceEvent::Crawl { t, .. }
            | TraceEvent::Decision { t, .. }
            | TraceEvent::Veto { t, .. }
            | TraceEvent::CrawlFailed { t, .. }
            | TraceEvent::Retry { t, .. }
            | TraceEvent::Quarantine { t, .. }
            | TraceEvent::Forfeit { t, .. }
            | TraceEvent::Idle { t }
            | TraceEvent::TrustGate { t, .. }
            | TraceEvent::Reproject { t, .. }
            | TraceEvent::World { t, .. }
            | TraceEvent::Serve { t, .. } => t,
        }
    }

    /// Append this event's JSONL object (no trailing newline) for
    /// `shard` to `out`. Floats use Rust's shortest-roundtrip
    /// `Display`, which is deterministic across runs and platforms.
    fn write_json(&self, shard: usize, out: &mut String) {
        use std::fmt::Write;
        let name = self.name();
        let _ = write!(out, "{{\"ev\":\"{name}\",\"shard\":{shard}");
        match *self {
            TraceEvent::Cis { t, page }
            | TraceEvent::Veto { t, page }
            | TraceEvent::Quarantine { t, page }
            | TraceEvent::Forfeit { t, page }
            | TraceEvent::Reproject { t, page } => {
                let _ = write!(out, ",\"t\":{t},\"page\":{page}");
            }
            TraceEvent::Crawl { t, page, changed } => {
                let _ = write!(out, ",\"t\":{t},\"page\":{page},\"changed\":{changed}");
            }
            TraceEvent::Decision {
                t,
                page,
                value,
                chunks,
                scanned,
                early_break,
            } => {
                let _ = write!(
                    out,
                    ",\"t\":{t},\"page\":{page},\"value\":{value},\"chunks\":{chunks},\"scanned\":{scanned},\"early_break\":{early_break}"
                );
            }
            TraceEvent::CrawlFailed { t, page, outcome } => {
                let _ = write!(out, ",\"t\":{t},\"page\":{page},\"outcome\":{outcome}");
            }
            TraceEvent::Retry { t, page, due } => {
                let _ = write!(out, ",\"t\":{t},\"page\":{page},\"due\":{due}");
            }
            TraceEvent::Idle { t } => {
                let _ = write!(out, ",\"t\":{t}");
            }
            TraceEvent::TrustGate { t, page, open } => {
                let _ = write!(out, ",\"t\":{t},\"page\":{page},\"open\":{open}");
            }
            TraceEvent::World { t, kind, page } => {
                let _ = write!(out, ",\"t\":{t},\"kind\":{kind},\"page\":{page}");
            }
            TraceEvent::Serve {
                t,
                page,
                fresh,
                live,
            } => {
                let _ = write!(out, ",\"t\":{t},\"page\":{page},\"fresh\":{fresh},\"live\":{live}");
            }
        }
        out.push('}');
    }
}

/// Destination for trace events. Implementations must be cheap to
/// query when disabled: callers gate event *construction* on
/// [`TraceSink::enabled`], so the disabled path is a single
/// well-predicted branch.
pub trait TraceSink {
    /// Whether `record` will actually store events. When `false`,
    /// callers may (and should) skip building the event entirely.
    fn enabled(&self) -> bool;
    /// Record one event.
    fn record(&self, ev: TraceEvent);
}

/// A sink that drops everything; its disabled path is branch-cheap
/// (`enabled()` is a constant `false`).
#[derive(Debug, Default, Clone, Copy)]
pub struct NullSink;

impl TraceSink for NullSink {
    #[inline]
    fn enabled(&self) -> bool {
        false
    }

    #[inline]
    fn record(&self, _ev: TraceEvent) {}
}

struct ShardRing {
    buf: Vec<TraceEvent>,
    /// Index of the oldest event once the ring is full; 0 before.
    head: usize,
    /// Events overwritten by newer ones.
    dropped: u64,
}

/// Bounded per-shard ring-buffer event store: fixed capacity per
/// shard, overwrite-oldest on overflow, drained in deterministic
/// shard-index order (each shard oldest→newest).
pub struct FlightRecorder {
    capacity: usize,
    shards: Vec<ShardRing>,
}

impl FlightRecorder {
    /// Create a recorder with `capacity` events per shard (min 1).
    pub fn new(capacity: usize) -> Self {
        Self {
            capacity: capacity.max(1),
            shards: Vec::new(),
        }
    }

    /// Per-shard ring capacity.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Number of shard streams seen so far.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Total events currently held (across shards).
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.buf.len()).sum()
    }

    /// Whether no events are held.
    pub fn is_empty(&self) -> bool {
        self.shards.iter().all(|s| s.buf.is_empty())
    }

    /// Total events overwritten by newer ones (across shards).
    pub fn dropped(&self) -> u64 {
        self.shards.iter().map(|s| s.dropped).sum()
    }

    /// Append `ev` to `shard`'s ring, overwriting the oldest event if
    /// the ring is full. Shard streams are created on demand.
    pub fn push(&mut self, shard: usize, ev: TraceEvent) {
        if shard >= self.shards.len() {
            self.shards.resize_with(shard + 1, || ShardRing {
                buf: Vec::new(),
                head: 0,
                dropped: 0,
            });
        }
        let ring = &mut self.shards[shard];
        if ring.buf.len() < self.capacity {
            ring.buf.push(ev);
        } else {
            ring.buf[ring.head] = ev;
            ring.head += 1;
            if ring.head == self.capacity {
                ring.head = 0;
            }
            ring.dropped += 1;
        }
    }

    /// All held events in drain order — shard-index order, each shard
    /// oldest→newest — without consuming them.
    pub fn snapshot(&self) -> Vec<(usize, TraceEvent)> {
        let mut out = Vec::with_capacity(self.len());
        for (s, ring) in self.shards.iter().enumerate() {
            for &ev in &ring.buf[ring.head..] {
                out.push((s, ev));
            }
            for &ev in &ring.buf[..ring.head] {
                out.push((s, ev));
            }
        }
        out
    }

    /// Drain the full log as JSONL text, one event per line, in drain
    /// order. Same-seed runs produce byte-identical output.
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        for (s, ev) in self.snapshot() {
            ev.write_json(s, &mut out);
            out.push('\n');
        }
        out
    }

    /// Write the last `last_n` events (in drain order) to `w` — the
    /// window dumped on invariant violation.
    pub fn dump<W: IoWrite>(&self, w: &mut W, last_n: usize) -> std::io::Result<()> {
        let snap = self.snapshot();
        let skip = snap.len().saturating_sub(last_n);
        let mut line = String::new();
        for (s, ev) in &snap[skip..] {
            line.clear();
            ev.write_json(*s, &mut line);
            writeln!(w, "{line}")?;
        }
        Ok(())
    }

    /// Discard all held events (shard streams are kept).
    pub fn clear(&mut self) {
        for ring in &mut self.shards {
            ring.buf.clear();
            ring.head = 0;
            ring.dropped = 0;
        }
    }
}

/// Lock the shared recorder, surviving poison: a panicking engine
/// thread must not make the flight log unreadable — the ring only
/// holds `Copy` events, so the poisoned state is structurally valid.
fn lock_resilient<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Which engine phase a wall-clock span covers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    /// Scheduler `select` per tick.
    Select,
    /// Event merge/pop + apply per tick.
    Events,
    /// Learned-knowledge belief re-projection flush.
    Reproject,
    /// Retry-calendar maintenance per tick.
    Retry,
}

/// Wall-clock span histograms for engine phases, registered into a
/// [`metrics::Registry`]. Timings never enter the JSONL log (they are
/// nondeterministic); they only feed the registry's text exposition.
pub struct EngineSpans {
    select: Arc<DurationHisto>,
    events: Arc<DurationHisto>,
    reproject: Arc<DurationHisto>,
    retry: Arc<DurationHisto>,
}

impl EngineSpans {
    /// Register the four phase histograms in `reg` (names
    /// `engine_select`, `engine_events`, `engine_reproject`,
    /// `engine_retry`).
    pub fn register(reg: &Registry) -> Self {
        Self {
            select: reg.histo("engine_select"),
            events: reg.histo("engine_events"),
            reproject: reg.histo("engine_reproject"),
            retry: reg.histo("engine_retry"),
        }
    }

    /// The histogram for `kind`.
    pub fn histo(&self, kind: SpanKind) -> &DurationHisto {
        match kind {
            SpanKind::Select => &self.select,
            SpanKind::Events => &self.events,
            SpanKind::Reproject => &self.reproject,
            SpanKind::Retry => &self.retry,
        }
    }

    /// Record one span duration.
    pub fn observe(&self, kind: SpanKind, d: std::time::Duration) {
        self.histo(kind).observe(d);
    }
}

/// Progress telemetry for `--verbose`: one stderr line every `stride`
/// ticks. The line's sim-time fields (tick, horizon fraction, event
/// and live-page counts) are deterministic per shard; only the
/// events/s rate is wall-clock dependent.
pub struct ProgressMeter {
    stride: u64,
    ticks: AtomicU64,
    start: std::time::Instant,
}

impl ProgressMeter {
    /// Create a meter emitting every `stride` ticks (min 1).
    pub fn new(stride: u64) -> Self {
        Self {
            stride: stride.max(1),
            ticks: AtomicU64::new(0),
            start: std::time::Instant::now(),
        }
    }

    fn tick(&self, shard: usize, t: f64, horizon: f64, events: u64, live: usize) {
        let n = self.ticks.fetch_add(1, Ordering::Relaxed) + 1;
        if n % self.stride != 0 {
            return;
        }
        let frac = if horizon > 0.0 { (t / horizon).clamp(0.0, 1.0) } else { 1.0 };
        let wall = self.start.elapsed().as_secs_f64().max(1e-9);
        eprintln!(
            "[progress s={shard}] t={t:.3}/{horizon:.3} ({:.1}%) events={events} live={live} ({:.0} ev/s)",
            frac * 100.0,
            events as f64 / wall
        );
    }
}

/// Cloneable capability handle threaded through engines and
/// schedulers. Carries an optional shared [`FlightRecorder`] (with
/// this handle's shard index), optional [`EngineSpans`], and an
/// optional [`ProgressMeter`] — each independently attachable, so
/// `--verbose` works without recording and vice versa.
#[derive(Clone, Default)]
pub struct TraceHandle {
    rec: Option<Arc<Mutex<FlightRecorder>>>,
    shard: usize,
    spans: Option<Arc<EngineSpans>>,
    progress: Option<Arc<ProgressMeter>>,
}

impl std::fmt::Debug for TraceHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TraceHandle")
            .field("shard", &self.shard)
            .field("recording", &self.rec.is_some())
            .field("spans", &self.spans.is_some())
            .field("progress", &self.progress.is_some())
            .finish()
    }
}

impl TraceHandle {
    /// A handle over a fresh [`FlightRecorder`] with `capacity` events
    /// per shard, writing to shard 0.
    pub fn recorder(capacity: usize) -> Self {
        Self::from_recorder(Arc::new(Mutex::new(FlightRecorder::new(capacity))))
    }

    /// A handle over an existing shared recorder, writing to shard 0.
    pub fn from_recorder(rec: Arc<Mutex<FlightRecorder>>) -> Self {
        Self {
            rec: Some(rec),
            shard: 0,
            spans: None,
            progress: None,
        }
    }

    /// A handle with no recorder attached (spans/progress can still be
    /// added); `enabled()` is `false`.
    pub fn disabled() -> Self {
        Self::default()
    }

    /// Attach engine-phase span timing, registering histograms in
    /// `reg`.
    pub fn with_spans(mut self, reg: &Registry) -> Self {
        self.spans = Some(Arc::new(EngineSpans::register(reg)));
        self
    }

    /// Attach a `--verbose` progress meter emitting every `stride`
    /// ticks.
    pub fn with_progress(mut self, stride: u64) -> Self {
        self.progress = Some(Arc::new(ProgressMeter::new(stride)));
        self
    }

    /// A clone of this handle writing to shard `shard` (recorder,
    /// spans and meter stay shared).
    pub fn shard(&self, shard: usize) -> Self {
        let mut h = self.clone();
        h.shard = shard;
        h
    }

    /// This handle's shard index.
    pub fn shard_index(&self) -> usize {
        self.shard
    }

    /// The shared recorder, if one is attached.
    pub fn recorder_arc(&self) -> Option<Arc<Mutex<FlightRecorder>>> {
        self.rec.clone()
    }

    /// Drain the attached recorder's full log as JSONL (empty string
    /// when no recorder is attached).
    pub fn drain_jsonl(&self) -> String {
        match &self.rec {
            Some(rec) => lock_resilient(rec).to_jsonl(),
            None => String::new(),
        }
    }

    /// Write the last `last_n` recorded events to `w` (no-op without a
    /// recorder).
    pub fn dump<W: IoWrite>(&self, w: &mut W, last_n: usize) -> std::io::Result<()> {
        match &self.rec {
            Some(rec) => lock_resilient(rec).dump(w, last_n),
            None => Ok(()),
        }
    }

    /// Start a wall-clock span if span timing is attached. Pass the
    /// result to [`TraceHandle::span_observe`]; when `None`, no clock
    /// is read at all.
    #[inline]
    pub fn span_clock(&self) -> Option<std::time::Instant> {
        self.spans.as_ref().map(|_| std::time::Instant::now())
    }

    /// Close a span started with [`TraceHandle::span_clock`].
    #[inline]
    pub fn span_observe(&self, kind: SpanKind, t0: Option<std::time::Instant>) {
        if let (Some(sp), Some(t0)) = (&self.spans, t0) {
            sp.observe(kind, t0.elapsed());
        }
    }

    /// Per-tick progress hook (no-op without a meter).
    #[inline]
    pub fn progress(&self, t: f64, horizon: f64, events: u64, live: usize) {
        if let Some(p) = &self.progress {
            p.tick(self.shard, t, horizon, events, live);
        }
    }
}

impl TraceSink for TraceHandle {
    #[inline]
    fn enabled(&self) -> bool {
        self.rec.is_some()
    }

    #[inline]
    fn record(&self, ev: TraceEvent) {
        if let Some(rec) = &self.rec {
            lock_resilient(rec).push(self.shard, ev);
        }
    }
}

// --- Option<&TraceHandle> call-site helpers -------------------------------
//
// Engines thread `tr: Option<&TraceHandle>`; these free functions keep
// every call site a single branch and defer event construction behind
// the enabled check.

/// Record the event built by `ev` iff a recording handle is attached.
#[inline]
pub fn emit(tr: Option<&TraceHandle>, ev: impl FnOnce() -> TraceEvent) {
    if let Some(h) = tr {
        if h.enabled() {
            h.record(ev());
        }
    }
}

/// Start a wall-clock span iff span timing is attached.
#[inline]
pub fn span_clock(tr: Option<&TraceHandle>) -> Option<std::time::Instant> {
    tr.and_then(TraceHandle::span_clock)
}

/// Close a span started with [`span_clock`].
#[inline]
pub fn span_observe(tr: Option<&TraceHandle>, kind: SpanKind, t0: Option<std::time::Instant>) {
    if let Some(h) = tr {
        h.span_observe(kind, t0);
    }
}

/// Per-tick progress hook.
#[inline]
pub fn progress(tr: Option<&TraceHandle>, t: f64, horizon: f64, events: u64, live: usize) {
    if let Some(h) = tr {
        h.progress(t, horizon, events, live);
    }
}

/// Debug-build invariant check with flight-recorder dump: when `cond`
/// is false in a debug build, dump the last [`DUMP_WINDOW`] events to
/// stderr and panic with `msg`. Release builds compile this to
/// nothing (wrap costly condition computations in
/// `if cfg!(debug_assertions)` at the call site).
#[inline]
pub fn debug_check(cond: bool, tr: Option<&TraceHandle>, msg: &str) {
    if cfg!(debug_assertions) && !cond {
        let mut err = std::io::stderr().lock();
        dump_and_panic(tr, &mut err, msg);
    }
}

/// Writer-parameterized variant of [`debug_check`]: the violation
/// window goes to `w` instead of stderr. Tests use this to capture and
/// assert on the dumped window.
pub fn check_or_dump<W: IoWrite>(cond: bool, tr: Option<&TraceHandle>, w: &mut W, msg: &str) {
    if cfg!(debug_assertions) && !cond {
        dump_and_panic(tr, w, msg);
    }
}

/// Always-on (release builds included) invariant check that reports
/// instead of panicking: when `cond` is false, the last
/// [`DUMP_WINDOW`] events are dumped to `w` and the violation message
/// is returned as `Err`, so the caller owns what happens next. This is
/// the fuzzer's check — a release-mode `fuzz` run must both keep
/// going after a violation (to collect every failing seed) and ship
/// the flight-recorder window in its repro bundle; `panic!` would
/// allow neither. [`debug_check`] / [`check_or_dump`] remain the
/// engines' hot-path checks (free in release builds).
pub fn verify_or_dump<W: IoWrite>(
    cond: bool,
    tr: Option<&TraceHandle>,
    w: &mut W,
    msg: &str,
) -> Result<(), String> {
    if cond {
        return Ok(());
    }
    if let Some(h) = tr {
        let _ = writeln!(
            w,
            "--- flight recorder: last {DUMP_WINDOW} events before violation ---"
        );
        let _ = h.dump(w, DUMP_WINDOW);
        let _ = w.flush();
    }
    Err(format!("invariant violated: {msg}"))
}

#[cold]
fn dump_and_panic<W: IoWrite>(tr: Option<&TraceHandle>, w: &mut W, msg: &str) -> ! {
    if let Some(h) = tr {
        let _ = writeln!(
            w,
            "--- flight recorder: last {DUMP_WINDOW} events before violation ---"
        );
        let _ = h.dump(w, DUMP_WINDOW);
        let _ = w.flush();
    }
    panic!("invariant violated: {msg}");
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idle(t: f64) -> TraceEvent {
        TraceEvent::Idle { t }
    }

    #[test]
    fn ring_respects_capacity_and_overwrites_oldest() {
        let mut rec = FlightRecorder::new(8);
        for k in 0..20 {
            rec.push(0, idle(f64::from(k)));
        }
        assert_eq!(rec.len(), 8);
        assert_eq!(rec.dropped(), 12);
        let times: Vec<f64> = rec.snapshot().iter().map(|(_, e)| e.time()).collect();
        // oldest→newest: 12..=19 survive
        assert_eq!(times, (12..20).map(f64::from).collect::<Vec<_>>());
    }

    #[test]
    fn drain_order_is_shard_index_then_oldest_first() {
        let mut rec = FlightRecorder::new(4);
        // interleave shards out of order; shard 2 created before shard 1
        rec.push(2, idle(20.0));
        rec.push(0, idle(0.0));
        rec.push(1, idle(10.0));
        rec.push(0, idle(1.0));
        rec.push(2, idle(21.0));
        let got: Vec<(usize, f64)> = rec
            .snapshot()
            .iter()
            .map(|&(s, e)| (s, e.time()))
            .collect();
        assert_eq!(
            got,
            vec![(0, 0.0), (0, 1.0), (1, 10.0), (2, 20.0), (2, 21.0)]
        );
    }

    #[test]
    fn jsonl_is_one_wellformed_object_per_line() {
        let mut rec = FlightRecorder::new(16);
        rec.push(0, TraceEvent::Cis { t: 0.5, page: 3 });
        rec.push(
            0,
            TraceEvent::Decision {
                t: 1.25,
                page: 7,
                value: 0.125,
                chunks: 2,
                scanned: 128,
                early_break: true,
            },
        );
        rec.push(
            1,
            TraceEvent::Serve {
                t: 2.0,
                page: 9,
                fresh: false,
                live: true,
            },
        );
        let text = rec.to_jsonl();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        for line in &lines {
            assert!(line.starts_with('{') && line.ends_with('}'), "line {line}");
            assert!(line.contains("\"ev\":\""), "line {line}");
            assert!(line.contains("\"shard\":"), "line {line}");
            assert!(line.contains("\"t\":"), "line {line}");
        }
        assert_eq!(
            lines[0],
            "{\"ev\":\"cis\",\"shard\":0,\"t\":0.5,\"page\":3}"
        );
        assert!(lines[1].contains("\"value\":0.125"));
        assert!(lines[1].contains("\"early_break\":true"));
        assert!(lines[2].contains("\"shard\":1"));
        assert!(lines[2].contains("\"fresh\":false"));
    }

    #[test]
    fn null_sink_is_disabled_and_droppy() {
        let s = NullSink;
        assert!(!s.enabled());
        s.record(idle(1.0)); // no-op, no panic
    }

    #[test]
    fn handle_records_into_its_shard_and_disabled_handle_is_inert() {
        let h = TraceHandle::recorder(16);
        assert!(h.enabled());
        h.record(idle(0.0));
        let h1 = h.shard(3);
        assert_eq!(h1.shard_index(), 3);
        h1.record(idle(1.0));
        let snap = match h.recorder_arc() {
            Some(rec) => lock_resilient(&rec).snapshot(),
            None => Vec::new(),
        };
        assert_eq!(
            snap.iter().map(|&(s, _)| s).collect::<Vec<_>>(),
            vec![0, 3]
        );

        let off = TraceHandle::disabled();
        assert!(!off.enabled());
        off.record(idle(2.0)); // no-op
        assert!(off.drain_jsonl().is_empty());
        // emit() must not even build the event without a recorder
        emit(Some(&off), || unreachable!("event built while disabled"));
        emit(None, || unreachable!("event built with no handle"));
    }

    #[test]
    fn drained_jsonl_is_reproducible() {
        let build = || {
            let h = TraceHandle::recorder(8);
            for k in 0..12u32 {
                h.record(TraceEvent::Crawl {
                    t: f64::from(k) * 0.25,
                    page: k,
                    changed: k % 2 == 0,
                });
            }
            h.drain_jsonl()
        };
        let a = build();
        let b = build();
        assert!(!a.is_empty());
        assert_eq!(a, b);
    }

    #[test]
    fn spans_feed_registry_histograms() {
        let reg = Registry::default();
        let h = TraceHandle::disabled().with_spans(&reg);
        let t0 = h.span_clock();
        assert!(t0.is_some());
        h.span_observe(SpanKind::Select, t0);
        assert_eq!(reg.histo("engine_select").count(), 1);
        assert_eq!(reg.histo("engine_events").count(), 0);
        // no spans attached → no clock read
        assert!(TraceHandle::disabled().span_clock().is_none());
    }

    #[test]
    fn violation_dumps_last_window_then_panics() {
        let h = TraceHandle::recorder(8);
        for k in 0..20 {
            h.record(idle(f64::from(k)));
        }
        let mut buf: Vec<u8> = Vec::new();
        let hit = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            check_or_dump(false, Some(&h), &mut buf, "deliberately broken invariant");
        }));
        if !cfg!(debug_assertions) {
            // release builds compile the check away entirely
            assert!(hit.is_ok());
            return;
        }
        assert!(hit.is_err(), "violation must panic in debug builds");
        let text = String::from_utf8_lossy(&buf);
        // ring capacity 8 → window holds t=12..=19 only
        assert!(text.contains("\"t\":12}"), "dump: {text}");
        assert!(text.contains("\"t\":19}"), "dump: {text}");
        assert!(!text.contains("\"t\":11}"), "dump: {text}");
        assert!(text.contains("flight recorder"), "dump: {text}");

        // a passing check neither dumps nor panics
        let mut quiet: Vec<u8> = Vec::new();
        check_or_dump(true, Some(&h), &mut quiet, "fine");
        assert!(quiet.is_empty());
    }
}
