//! Minimal CLI argument parser (the image has no `clap`).
//!
//! Grammar: `prog <subcommand> [--key value]... [--flag]... [positional]...`

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First positional token (the subcommand).
    pub command: Option<String>,
    /// `--key value` options.
    pub options: BTreeMap<String, String>,
    /// `--flag` booleans.
    pub flags: Vec<String>,
    /// Remaining positionals.
    pub positionals: Vec<String>,
}

impl Args {
    /// Parse from an iterator of tokens (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Result<Self> {
        let mut out = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    return Err(Error::Usage("bare `--` is not supported".into()));
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if let Some(v) =
                    it.next_if(|n| !n.starts_with("--"))
                {
                    out.options.insert(name.to_string(), v);
                } else {
                    out.flags.push(name.to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(tok);
            } else {
                out.positionals.push(tok);
            }
        }
        Ok(out)
    }

    /// Parse from the process environment.
    pub fn from_env() -> Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    /// Option as string.
    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    /// Option as f64 with default.
    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Usage(format!("--{key} expects a number, got `{v}`"))),
        }
    }

    /// Option as usize with default.
    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Usage(format!("--{key} expects an integer, got `{v}`"))),
        }
    }

    /// Option as u64 with default.
    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Usage(format!("--{key} expects an integer, got `{v}`"))),
        }
    }

    /// Is the flag present?
    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_options_flags() {
        // note the grammar: a bare `--flag` absorbs a following bare token
        // as its value, so positionals go before flags (or use `--k=v`).
        let a = parse("simulate extra1 extra2 --m 100 --policy GREEDY --verbose");
        assert_eq!(a.command.as_deref(), Some("simulate"));
        assert_eq!(a.opt("m"), Some("100"));
        assert_eq!(a.opt("policy"), Some("GREEDY"));
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positionals, vec!["extra1", "extra2"]);
    }

    #[test]
    fn equals_syntax() {
        let a = parse("run --m=42 --x=hello");
        assert_eq!(a.usize_or("m", 0).unwrap(), 42);
        assert_eq!(a.opt("x"), Some("hello"));
    }

    #[test]
    fn typed_accessors() {
        let a = parse("x --r 2.5");
        assert_eq!(a.f64_or("r", 0.0).unwrap(), 2.5);
        assert_eq!(a.f64_or("missing", 9.0).unwrap(), 9.0);
        assert!(parse("x --r nope").f64_or("r", 0.0).is_err());
    }

    #[test]
    fn trailing_flag_before_flag() {
        let a = parse("cmd --a --b v");
        assert!(a.has_flag("a"));
        assert_eq!(a.opt("b"), Some("v"));
    }
}
