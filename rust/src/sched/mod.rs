//! The event-driven scheduling API every crawl driver speaks.
//!
//! Pre-redesign, the simulator handed each policy the full
//! `&[PageState]` slice on every call — an O(m)-per-tick contract that
//! hard-wired full rescans into every implementation and blocked the
//! lazy/sharded paths from being truly incremental. [`CrawlScheduler`]
//! inverts that: the *driver* (sim engine, streaming pipeline, sharded
//! coordinator) pushes lifecycle events and each scheduler owns exactly
//! the per-page state it needs:
//!
//! - [`CrawlScheduler::on_start`] — a run begins over `m` pages; reset
//!   all mutable state (schedulers are reusable across repetitions).
//! - [`CrawlScheduler::on_cis`] — a change-indicating signal for `page`
//!   was delivered at time `t` (drivers apply any discard window first).
//! - [`CrawlScheduler::on_crawl`] — `page` was crawled at time `t`
//!   (always fired by the driver right after a `select` pick is acted
//!   on; schedulers reset their per-page beliefs here).
//! - [`CrawlScheduler::select`] — pick the page to crawl at tick `t`.
//!
//! [`PageTracker`] is the shared bookkeeping every stateful scheduler
//! embeds: last-crawl times and pending-CIS counts, updated from the
//! hooks with exactly the semantics the pre-redesign engine used for
//! its `PageState` slice (the `scheduler_parity` integration suite
//! asserts bit-identical behavior).
//!
//! Construction goes through [`crate::CrawlerBuilder`], which wires any
//! policy × strategy × value-backend combination behind this trait.
//!
//! [`wheel::TimingWheel`] is the shared wake-calendar substrate: a
//! hierarchical, tick-bucketed timer wheel with O(1) amortized
//! schedule/advance and version-stamped lazy deletion, used by the lazy
//! scheduler's cold-page calendar in place of a `BinaryHeap`.

pub mod wheel;

pub use wheel::{TimingWheel, WheelEntry};

/// A discrete crawling policy driven by lifecycle events.
///
/// Implementations own their per-page state (usually a [`PageTracker`])
/// and update it incrementally from the hooks; no driver ever hands
/// them a global state slice.
pub trait CrawlScheduler {
    /// A run over `m` pages begins. Implementations must reset every
    /// piece of mutable state so one scheduler instance can be reused
    /// across repetitions. Drivers call this exactly once per run,
    /// before any other hook.
    fn on_start(&mut self, m: usize) {
        let _ = m;
    }

    /// A CIS for `page` was delivered at time `t` (after the driver's
    /// discard window, if any, was applied).
    fn on_cis(&mut self, page: usize, t: f64) {
        let _ = (page, t);
    }

    /// `page` was crawled at time `t`. Fired by the driver immediately
    /// after it acts on a `select` pick.
    fn on_crawl(&mut self, page: usize, t: f64) {
        let _ = (page, t);
    }

    /// A `select` pick was rejected by a decorator (e.g. politeness
    /// cool-down) and will NOT be crawled this tick. Schedulers with
    /// internal candidate queues should sideline the page so an
    /// immediate retry yields the next-best pick.
    fn on_veto(&mut self, page: usize, t: f64) {
        let _ = (page, t);
    }

    /// Page to crawl at tick time `t` (`None` = idle tick).
    fn select(&mut self, t: f64) -> Option<usize>;

    /// Policy name for reports.
    fn name(&self) -> String {
        "scheduler".into()
    }
}

/// Boxed schedulers are schedulers (the pipeline ships
/// `Box<dyn CrawlScheduler + Send>` into shard workers; decorators like
/// `PoliteScheduler` wrap the box directly).
impl<S: CrawlScheduler + ?Sized> CrawlScheduler for Box<S> {
    fn on_start(&mut self, m: usize) {
        (**self).on_start(m)
    }
    fn on_cis(&mut self, page: usize, t: f64) {
        (**self).on_cis(page, t)
    }
    fn on_crawl(&mut self, page: usize, t: f64) {
        (**self).on_crawl(page, t)
    }
    fn on_veto(&mut self, page: usize, t: f64) {
        (**self).on_veto(page, t)
    }
    fn select(&mut self, t: f64) -> Option<usize> {
        (**self).select(t)
    }
    fn name(&self) -> String {
        (**self).name()
    }
}

/// The scheduler that never crawls: every tick idles.
///
/// Degraded-mode stand-in shared by the drivers — the streaming
/// pipeline runs it on empty shards (shards > pages) and the figure
/// harness runs it when a baseline solver yields no schedulable rates.
#[derive(Debug, Clone, Copy, Default)]
pub struct IdleScheduler;

impl CrawlScheduler for IdleScheduler {
    fn select(&mut self, _t: f64) -> Option<usize> {
        None
    }

    fn name(&self) -> String {
        "IDLE".into()
    }
}

/// Incremental per-page crawl state: last-crawl time and the number of
/// CIS delivered since (the two inputs of every crawl-value function).
///
/// Semantics mirror the pre-redesign engine slice exactly: pages start
/// fresh at `last_crawl = 0`, CIS counts saturate instead of wrapping,
/// and a crawl resets the count to zero.
#[derive(Debug, Clone, Default)]
pub struct PageTracker {
    last_crawl: Vec<f64>,
    n_cis: Vec<u32>,
}

impl PageTracker {
    /// Tracker over `m` pages, all fresh at t = 0.
    pub fn new(m: usize) -> Self {
        let mut tracker = Self::default();
        tracker.reset(m);
        tracker
    }

    /// Re-dimension to `m` pages and clear all state (the `on_start`
    /// contract); capacity is retained.
    pub fn reset(&mut self, m: usize) {
        self.last_crawl.clear();
        self.last_crawl.resize(m, 0.0);
        self.n_cis.clear();
        self.n_cis.resize(m, 0);
    }

    /// Number of tracked pages.
    pub fn len(&self) -> usize {
        self.last_crawl.len()
    }

    /// Is the tracker empty?
    pub fn is_empty(&self) -> bool {
        self.last_crawl.is_empty()
    }

    /// Record a delivered CIS (saturating, like the engine of old).
    #[inline]
    pub fn on_cis(&mut self, page: usize) {
        self.n_cis[page] = self.n_cis[page].saturating_add(1);
    }

    /// Record a crawl: the page is fresh again and its CIS count clears.
    #[inline]
    pub fn on_crawl(&mut self, page: usize, t: f64) {
        self.last_crawl[page] = t;
        self.n_cis[page] = 0;
    }

    /// Elapsed time since `page` was last crawled.
    #[inline]
    pub fn tau_elap(&self, page: usize, t: f64) -> f64 {
        t - self.last_crawl[page]
    }

    /// CIS delivered to `page` since its last crawl.
    #[inline]
    pub fn n_cis(&self, page: usize) -> u32 {
        self.n_cis[page]
    }

    /// Time of `page`'s last crawl (0 if never crawled).
    #[inline]
    pub fn last_crawl(&self, page: usize) -> f64 {
        self.last_crawl[page]
    }

    /// Test hook: seed a CIS count directly (saturation is unreachable
    /// through `on_cis` alone within a test's budget).
    #[cfg(test)]
    fn set_n_cis(&mut self, page: usize, n: u32) {
        self.n_cis[page] = n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracker_lifecycle() {
        let mut tr = PageTracker::new(3);
        assert_eq!(tr.len(), 3);
        assert_eq!(tr.tau_elap(1, 2.5), 2.5);
        tr.on_cis(1);
        tr.on_cis(1);
        assert_eq!(tr.n_cis(1), 2);
        assert_eq!(tr.n_cis(0), 0);
        tr.on_crawl(1, 3.0);
        assert_eq!(tr.n_cis(1), 0);
        assert_eq!(tr.last_crawl(1), 3.0);
        assert_eq!(tr.tau_elap(1, 4.0), 1.0);
    }

    #[test]
    fn reset_clears_and_redimensions() {
        let mut tr = PageTracker::new(2);
        tr.on_cis(0);
        tr.on_crawl(1, 9.0);
        tr.reset(4);
        assert_eq!(tr.len(), 4);
        for i in 0..4 {
            assert_eq!(tr.n_cis(i), 0);
            assert_eq!(tr.last_crawl(i), 0.0);
        }
    }

    #[test]
    fn cis_count_saturates_at_u32_max() {
        let mut tr = PageTracker::new(1);
        for k in 1..=3 {
            tr.on_cis(0);
            assert_eq!(tr.n_cis(0), k);
        }
        // the actual saturation semantics: at the ceiling, further CIS
        // must pin at u32::MAX (a plain `+ 1` would overflow here)
        tr.set_n_cis(0, u32::MAX - 1);
        tr.on_cis(0);
        assert_eq!(tr.n_cis(0), u32::MAX);
        tr.on_cis(0);
        assert_eq!(tr.n_cis(0), u32::MAX, "count must saturate, not wrap");
        // a crawl still clears a saturated count
        tr.on_crawl(0, 5.0);
        assert_eq!(tr.n_cis(0), 0);
    }

    #[test]
    fn boxed_scheduler_is_a_scheduler() {
        struct Fixed(usize);
        impl CrawlScheduler for Fixed {
            fn select(&mut self, _t: f64) -> Option<usize> {
                Some(self.0)
            }
            fn name(&self) -> String {
                "FIXED".into()
            }
        }
        let mut boxed: Box<dyn CrawlScheduler + Send> = Box::new(Fixed(7));
        assert_eq!(boxed.select(0.0), Some(7));
        assert_eq!(boxed.name(), "FIXED");
    }
}
