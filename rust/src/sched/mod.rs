//! The event-driven scheduling API every crawl driver speaks.
//!
//! Pre-redesign, the simulator handed each policy the full
//! `&[PageState]` slice on every call — an O(m)-per-tick contract that
//! hard-wired full rescans into every implementation and blocked the
//! lazy/sharded paths from being truly incremental. [`CrawlScheduler`]
//! inverts that: the *driver* (sim engine, streaming pipeline, sharded
//! coordinator) pushes lifecycle events and each scheduler owns exactly
//! the per-page state it needs:
//!
//! - [`CrawlScheduler::on_start`] — a run begins over `m` pages; reset
//!   all mutable state (schedulers are reusable across repetitions).
//! - [`CrawlScheduler::on_cis`] — a change-indicating signal for `page`
//!   was delivered at time `t` (drivers apply any discard window first).
//! - [`CrawlScheduler::on_crawl`] — `page` was crawled at time `t`
//!   (always fired by the driver right after a `select` pick is acted
//!   on; schedulers reset their per-page beliefs here).
//! - [`CrawlScheduler::select`] — pick the page to crawl at tick `t`.
//!
//! Dynamic worlds (the [`crate::scenario`] engine) add three more
//! lifecycle hooks, default no-ops so static schedulers are untouched:
//!
//! - [`CrawlScheduler::on_page_added`] — slot `page` now holds a live
//!   page with the given parameters (a fresh slot or a recycled one; a
//!   recycled slot must be treated as brand new — no state of the
//!   previous occupant may survive).
//! - [`CrawlScheduler::on_page_removed`] — slot `page` was retired; the
//!   scheduler must never select it again until a new occupant arrives.
//! - [`CrawlScheduler::on_params_changed`] — the true parameters of
//!   `page` shifted (drift, rate shift); schedulers that model beliefs
//!   re-project them here.
//!
//! The fault layer ([`crate::fault`]) adds one more, also a safe
//! default: [`CrawlScheduler::on_crawl_failed`] — a fetch attempt
//! failed (the tick was spent, the page was not fetched); by default
//! the failure is treated like a veto so the page is sidelined for an
//! immediate re-`select`.
//!
//! The estimation loop ([`crate::estimation`]) adds the last hook,
//! again a default no-op: [`CrawlScheduler::on_fetch_observed`] — a
//! successful fetch reported whether the page content had changed since
//! the previous fetch. Drivers fire it right before the matching
//! `on_crawl`; learned-knowledge schedulers turn the (interval,
//! changed?, CIS-count) triple into online parameter estimates.
//!
//! [`PageTracker`] is the shared bookkeeping every stateful scheduler
//! embeds: last-crawl times and pending-CIS counts, updated from the
//! hooks with exactly the semantics the pre-redesign engine used for
//! its `PageState` slice (the `scheduler_parity` integration suite
//! asserts bit-identical behavior).
//!
//! Construction goes through [`crate::CrawlerBuilder`], which wires any
//! policy × strategy × value-backend combination behind this trait.
//!
//! [`wheel::TimingWheel`] is the shared wake-calendar substrate: a
//! hierarchical, tick-bucketed timer wheel with O(1) amortized
//! schedule/advance and version-stamped lazy deletion, used by the lazy
//! scheduler's cold-page calendar in place of a `BinaryHeap`.

pub mod wheel;

pub use wheel::{TimingWheel, WheelEntry};

use crate::params::PageParams;

/// A discrete crawling policy driven by lifecycle events.
///
/// Implementations own their per-page state (usually a [`PageTracker`])
/// and update it incrementally from the hooks; no driver ever hands
/// them a global state slice.
pub trait CrawlScheduler {
    /// A run over `m` pages begins. Implementations must reset every
    /// piece of mutable state so one scheduler instance can be reused
    /// across repetitions. Drivers call this exactly once per run,
    /// before any other hook.
    fn on_start(&mut self, m: usize) {
        let _ = m;
    }

    /// A CIS for `page` was delivered at time `t` (after the driver's
    /// discard window, if any, was applied).
    fn on_cis(&mut self, page: usize, t: f64) {
        let _ = (page, t);
    }

    /// `page` was crawled at time `t`. Fired by the driver immediately
    /// after it acts on a `select` pick.
    fn on_crawl(&mut self, page: usize, t: f64) {
        let _ = (page, t);
    }

    /// A `select` pick was rejected by a decorator (e.g. politeness
    /// cool-down) and will NOT be crawled this tick. Schedulers with
    /// internal candidate queues should sideline the page so an
    /// immediate retry yields the next-best pick.
    fn on_veto(&mut self, page: usize, t: f64) {
        let _ = (page, t);
    }

    /// A crawl attempt on `page` at time `t` **failed** with the given
    /// outcome — the tick was spent but the page was NOT fetched, so
    /// its freshness state is unchanged and `on_crawl` will not fire.
    /// The fault engine (`crate::fault::engine`) owns the retry/backoff
    /// calendar; this hook is the scheduler's chance to re-score.
    /// Default: treat the failure like a veto (sideline the page so an
    /// immediate re-`select` yields the next-best candidate). Permanent
    /// failures additionally surface as [`Self::on_page_removed`] when
    /// the engine quarantines the page.
    fn on_crawl_failed(&mut self, page: usize, t: f64, outcome: crate::fault::CrawlOutcome) {
        let _ = outcome;
        self.on_veto(page, t);
    }

    /// The driver fetched `page` at time `t` and observed whether its
    /// content **changed** since the previous fetch. Fired immediately
    /// before the matching [`Self::on_crawl`] (same `page`, same `t`),
    /// and only for successful fetches — failed attempts surface
    /// through [`Self::on_crawl_failed`] instead and carry no change
    /// observation. This is the only channel through which learned-
    /// knowledge schedulers ([`crate::Knowledge::Learned`]) may learn
    /// about the world; ground-truth parameter events are withheld from
    /// them. Default: no-op (oracle schedulers don't need outcomes).
    fn on_fetch_observed(&mut self, page: usize, t: f64, changed: bool) {
        let _ = (page, t, changed);
    }

    /// Slot `page` now holds a live page with parameters `params`
    /// (born at time `t`). `page` is either one past the current
    /// population (growth) or a previously-retired slot (recycling);
    /// either way the slot must start from a completely fresh state.
    /// Default: no-op (static schedulers never see dynamic worlds).
    fn on_page_added(&mut self, page: usize, params: &PageParams, t: f64) {
        let _ = (page, params, t);
    }

    /// Slot `page` was retired at time `t`: drop it from all candidate
    /// structures and never select it again (until a new occupant
    /// arrives via [`Self::on_page_added`]). Default: no-op.
    fn on_page_removed(&mut self, page: usize, t: f64) {
        let _ = (page, t);
    }

    /// The true parameters of `page` shifted to `params` at time `t`
    /// (drift / rate shift, as surfaced by re-estimation). Schedulers
    /// that precompute beliefs re-project them here. Default: no-op.
    fn on_params_changed(&mut self, page: usize, params: &PageParams, t: f64) {
        let _ = (page, params, t);
    }

    /// Page to crawl at tick time `t` (`None` = idle tick).
    fn select(&mut self, t: f64) -> Option<usize>;

    /// Attach a trace handle ([`crate::trace::TraceHandle`]) so the
    /// scheduler can emit decision events (argmax stats, vetoes,
    /// trust-gate flips). Tracing is strictly observational: attaching
    /// a handle must not change any pick, belief, or RNG draw.
    /// Default: no-op (most schedulers emit nothing themselves —
    /// engine-side events still cover them).
    fn attach_trace(&mut self, tr: crate::trace::TraceHandle) {
        let _ = tr;
    }

    /// Policy name for reports.
    fn name(&self) -> String {
        "scheduler".into()
    }
}

/// Boxed schedulers are schedulers (the pipeline ships
/// `Box<dyn CrawlScheduler + Send>` into shard workers; decorators like
/// `PoliteScheduler` wrap the box directly).
impl<S: CrawlScheduler + ?Sized> CrawlScheduler for Box<S> {
    fn on_start(&mut self, m: usize) {
        (**self).on_start(m)
    }
    fn on_cis(&mut self, page: usize, t: f64) {
        (**self).on_cis(page, t)
    }
    fn on_crawl(&mut self, page: usize, t: f64) {
        (**self).on_crawl(page, t)
    }
    fn on_veto(&mut self, page: usize, t: f64) {
        (**self).on_veto(page, t)
    }
    fn on_crawl_failed(&mut self, page: usize, t: f64, outcome: crate::fault::CrawlOutcome) {
        (**self).on_crawl_failed(page, t, outcome)
    }
    fn on_fetch_observed(&mut self, page: usize, t: f64, changed: bool) {
        (**self).on_fetch_observed(page, t, changed)
    }
    fn on_page_added(&mut self, page: usize, params: &PageParams, t: f64) {
        (**self).on_page_added(page, params, t)
    }
    fn on_page_removed(&mut self, page: usize, t: f64) {
        (**self).on_page_removed(page, t)
    }
    fn on_params_changed(&mut self, page: usize, params: &PageParams, t: f64) {
        (**self).on_params_changed(page, params, t)
    }
    fn select(&mut self, t: f64) -> Option<usize> {
        (**self).select(t)
    }
    fn attach_trace(&mut self, tr: crate::trace::TraceHandle) {
        (**self).attach_trace(tr)
    }
    fn name(&self) -> String {
        (**self).name()
    }
}

/// The scheduler that never crawls: every tick idles.
///
/// Degraded-mode stand-in shared by the drivers — the streaming
/// pipeline runs it on empty shards (shards > pages) and the figure
/// harness runs it when a baseline solver yields no schedulable rates.
#[derive(Debug, Clone, Copy, Default)]
pub struct IdleScheduler;

impl CrawlScheduler for IdleScheduler {
    fn select(&mut self, _t: f64) -> Option<usize> {
        None
    }

    fn name(&self) -> String {
        "IDLE".into()
    }
}

/// Incremental per-page crawl state: last-crawl time and the number of
/// CIS delivered since (the two inputs of every crawl-value function).
///
/// Semantics mirror the pre-redesign engine slice exactly: pages start
/// fresh at `last_crawl = 0`, CIS counts saturate instead of wrapping,
/// and a crawl resets the count to zero.
///
/// Dynamic worlds recycle slots: [`Self::add_page`] /
/// [`Self::remove_page`] manage the lifecycle with a per-slot
/// *generation counter* that increments on every transition, so a
/// recycled index can never alias the previous occupant's state — the
/// counter proves the slot was scrubbed (`add_page` resets both fields
/// unconditionally) and lets holders of stale references detect that
/// their page is gone.
#[derive(Debug, Clone, Default)]
pub struct PageTracker {
    last_crawl: Vec<f64>,
    n_cis: Vec<u32>,
    generation: Vec<u32>,
}

impl PageTracker {
    /// Tracker over `m` pages, all fresh at t = 0.
    pub fn new(m: usize) -> Self {
        let mut tracker = Self::default();
        tracker.reset(m);
        tracker
    }

    /// Re-dimension to `m` pages and clear all state (the `on_start`
    /// contract — including the slot generations, so a run's dynamic
    /// history never leaks into the next repetition); capacity is
    /// retained.
    pub fn reset(&mut self, m: usize) {
        self.last_crawl.clear();
        self.last_crawl.resize(m, 0.0);
        self.n_cis.clear();
        self.n_cis.resize(m, 0);
        self.generation.clear();
        self.generation.resize(m, 0);
    }

    /// A page was born into slot `page` at time `t`: either one past
    /// the current population (the tracker grows) or a retired slot
    /// (recycled). Both fields are scrubbed unconditionally and the
    /// slot generation is bumped, so no state of a previous occupant
    /// can survive into the new page's lifetime.
    pub fn add_page(&mut self, page: usize, t: f64) {
        if page == self.last_crawl.len() {
            self.last_crawl.push(t);
            self.n_cis.push(0);
            self.generation.push(0);
        } else {
            assert!(page < self.last_crawl.len(), "add_page: slot {page} out of range");
            self.last_crawl[page] = t;
            self.n_cis[page] = 0;
            self.generation[page] = self.generation[page].wrapping_add(1);
        }
    }

    /// Slot `page` was retired: bump its generation so stale references
    /// are detectable. State is scrubbed again on the next `add_page`.
    pub fn remove_page(&mut self, page: usize) {
        self.generation[page] = self.generation[page].wrapping_add(1);
    }

    /// Lifecycle generation of slot `page` (0 for the original
    /// occupant; +1 per retirement and per rebirth).
    #[inline]
    pub fn generation(&self, page: usize) -> u32 {
        self.generation[page]
    }

    /// Number of tracked pages.
    pub fn len(&self) -> usize {
        self.last_crawl.len()
    }

    /// Is the tracker empty?
    pub fn is_empty(&self) -> bool {
        self.last_crawl.is_empty()
    }

    /// Record a delivered CIS (saturating, like the engine of old).
    #[inline]
    pub fn on_cis(&mut self, page: usize) {
        self.n_cis[page] = self.n_cis[page].saturating_add(1);
    }

    /// Record a crawl: the page is fresh again and its CIS count clears.
    #[inline]
    pub fn on_crawl(&mut self, page: usize, t: f64) {
        self.last_crawl[page] = t;
        self.n_cis[page] = 0;
    }

    /// Elapsed time since `page` was last crawled.
    #[inline]
    pub fn tau_elap(&self, page: usize, t: f64) -> f64 {
        t - self.last_crawl[page]
    }

    /// CIS delivered to `page` since its last crawl.
    #[inline]
    pub fn n_cis(&self, page: usize) -> u32 {
        self.n_cis[page]
    }

    /// Time of `page`'s last crawl (0 if never crawled).
    #[inline]
    pub fn last_crawl(&self, page: usize) -> f64 {
        self.last_crawl[page]
    }

    /// Test hook: seed a CIS count directly (saturation is unreachable
    /// through `on_cis` alone within a test's budget).
    #[cfg(test)]
    fn set_n_cis(&mut self, page: usize, n: u32) {
        self.n_cis[page] = n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracker_lifecycle() {
        let mut tr = PageTracker::new(3);
        assert_eq!(tr.len(), 3);
        assert_eq!(tr.tau_elap(1, 2.5), 2.5);
        tr.on_cis(1);
        tr.on_cis(1);
        assert_eq!(tr.n_cis(1), 2);
        assert_eq!(tr.n_cis(0), 0);
        tr.on_crawl(1, 3.0);
        assert_eq!(tr.n_cis(1), 0);
        assert_eq!(tr.last_crawl(1), 3.0);
        assert_eq!(tr.tau_elap(1, 4.0), 1.0);
    }

    #[test]
    fn reset_clears_and_redimensions() {
        let mut tr = PageTracker::new(2);
        tr.on_cis(0);
        tr.on_crawl(1, 9.0);
        tr.reset(4);
        assert_eq!(tr.len(), 4);
        for i in 0..4 {
            assert_eq!(tr.n_cis(i), 0);
            assert_eq!(tr.last_crawl(i), 0.0);
        }
    }

    #[test]
    fn cis_count_saturates_at_u32_max() {
        let mut tr = PageTracker::new(1);
        for k in 1..=3 {
            tr.on_cis(0);
            assert_eq!(tr.n_cis(0), k);
        }
        // the actual saturation semantics: at the ceiling, further CIS
        // must pin at u32::MAX (a plain `+ 1` would overflow here)
        tr.set_n_cis(0, u32::MAX - 1);
        tr.on_cis(0);
        assert_eq!(tr.n_cis(0), u32::MAX);
        tr.on_cis(0);
        assert_eq!(tr.n_cis(0), u32::MAX, "count must saturate, not wrap");
        // a crawl still clears a saturated count
        tr.on_crawl(0, 5.0);
        assert_eq!(tr.n_cis(0), 0);
    }

    #[test]
    fn recycled_slot_never_aliases_stale_state() {
        let mut tr = PageTracker::new(3);
        // slot 1 accumulates dynamic state, then retires
        tr.on_cis(1);
        tr.on_cis(1);
        tr.on_crawl(1, 4.0);
        tr.on_cis(1);
        assert_eq!(tr.generation(1), 0);
        tr.remove_page(1);
        assert_eq!(tr.generation(1), 1);
        // rebirth into the recycled slot at t = 9: brand-new state
        tr.add_page(1, 9.0);
        assert_eq!(tr.generation(1), 2, "each transition bumps the generation");
        assert_eq!(tr.n_cis(1), 0, "recycled slot inherited a stale CIS count");
        assert_eq!(tr.last_crawl(1), 9.0, "recycled slot starts fresh at its birth time");
        assert_eq!(tr.tau_elap(1, 11.5), 2.5);
        // growth path: add one past the end
        tr.add_page(3, 2.0);
        assert_eq!(tr.len(), 4);
        assert_eq!(tr.generation(3), 0);
        assert_eq!(tr.last_crawl(3), 2.0);
        // reset clears generations along with everything else
        tr.reset(4);
        assert_eq!(tr.generation(1), 0);
    }

    #[test]
    fn boxed_scheduler_is_a_scheduler() {
        struct Fixed(usize);
        impl CrawlScheduler for Fixed {
            fn select(&mut self, _t: f64) -> Option<usize> {
                Some(self.0)
            }
            fn name(&self) -> String {
                "FIXED".into()
            }
        }
        let mut boxed: Box<dyn CrawlScheduler + Send> = Box::new(Fixed(7));
        assert_eq!(boxed.select(0.0), Some(7));
        assert_eq!(boxed.name(), "FIXED");
    }
}
