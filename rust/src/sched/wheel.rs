//! Hierarchical timing wheel — the wake-calendar substrate of the §5.2
//! lazy scheduler.
//!
//! The lazy scheduler's cold-page calendar was a
//! `BinaryHeap<Reverse<(time, version, page)>>`: O(log m) per
//! schedule/pop with comparison-heavy sift churn on the hottest
//! scheduler loop. Discrete-event cores (the kernel timer wheel;
//! dslab-style simulators) use *tick-bucketed* calendars instead: time
//! is quantized into slots, scheduling is an O(1) bucket push, and
//! advancing drains whole buckets. [`TimingWheel`] is the hierarchical
//! variant: `LEVELS` wheels of `SLOTS` buckets each, level `L` covering
//! `SLOTS^L` base ticks per bucket, so a far-future wake costs the same
//! O(1) as a near one and cascades down a level at most `LEVELS - 1`
//! times over its lifetime (O(1) amortized). Entries beyond the top
//! level's span live in an overflow bin that is re-filed as the wheel
//! turns.
//!
//! Deletion is *lazy and version-stamped*, exactly like the heap it
//! replaces: the owner bumps a per-page version to invalidate an entry
//! and stale entries are dropped when their bucket drains. Due-entry
//! yield order within a bucket is insertion order (the lazy scheduler's
//! wake processing is order-independent); [`TimingWheel::pop_earliest`]
//! is canonical — strict `(time, version, page)` order, matching the
//! `BinaryHeap` tie-break bit-for-bit so the randomized heap-vs-wheel
//! equivalence suite can compare pops exactly.

/// Slots per level (power of two; `SLOT_BITS = log2(SLOTS)`).
const SLOTS: usize = 64;
const SLOT_BITS: u32 = 6;
/// Hierarchy depth. With a base tick of `1/64` the levels span
/// 1, 64, 4096 and 262144 time units; farther wakes overflow-bin.
const LEVELS: usize = 4;

/// One scheduled wake: `(time, version, page)`. The version stamp
/// realizes lazy deletion — the owner bumps its per-page version and
/// the stale entry is dropped when encountered.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WheelEntry {
    /// Absolute wake time.
    pub time: f64,
    /// Version stamp at scheduling time.
    pub version: u32,
    /// Page index.
    pub page: u32,
}

impl WheelEntry {
    /// Canonical `(time, version, page)` order — the `BinaryHeap`
    /// tie-break the wheel's `pop_earliest` reproduces.
    #[inline]
    fn key(&self) -> (f64, u32, u32) {
        (self.time, self.version, self.page)
    }
}

/// Hierarchical tick-bucketed timer wheel (see module docs).
///
/// Invariants: `cur` is monotone within a run (advances clamp below);
/// every stored entry was filed at the smallest level whose remaining
/// window covered it, so level-`L > 0` entries never sit in that
/// level's *current* slot and each level's first nonempty slot holds
/// the level minimum.
#[derive(Debug, Clone)]
pub struct TimingWheel {
    /// Level-0 slot width in time units.
    tick: f64,
    /// Current time (high-water of `drain_due_into` targets).
    cur: f64,
    /// Absolute slot index of `cur` per level:
    /// `cur_slot[L] == cur_slot[0] >> (SLOT_BITS * L)`.
    cur_slot: [u64; LEVELS],
    /// `LEVELS × SLOTS` buckets, flattened.
    slots: Vec<Vec<WheelEntry>>,
    /// Entries beyond the top level's span; re-filed as the wheel turns.
    overflow: Vec<WheelEntry>,
    /// Reusable cascade buffer (swapped with a bucket, then re-filed).
    cascade_scratch: Vec<WheelEntry>,
    len: usize,
}

impl TimingWheel {
    /// Wheel with the given level-0 slot width.
    pub fn new(tick: f64) -> Self {
        assert!(tick > 0.0 && tick.is_finite(), "wheel tick must be positive, got {tick}");
        Self {
            tick,
            cur: 0.0,
            cur_slot: [0; LEVELS],
            slots: vec![Vec::new(); LEVELS * SLOTS],
            overflow: Vec::new(),
            cascade_scratch: Vec::new(),
            len: 0,
        }
    }

    /// Number of stored entries (including stale ones not yet dropped).
    pub fn len(&self) -> usize {
        self.len
    }

    /// Is the wheel empty?
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Current time floor.
    pub fn now(&self) -> f64 {
        self.cur
    }

    /// Clear all entries and rewind to t = 0 (capacity preserved).
    pub fn reset(&mut self) {
        for s in &mut self.slots {
            s.clear();
        }
        self.overflow.clear();
        self.cur = 0.0;
        self.cur_slot = [0; LEVELS];
        self.len = 0;
    }

    /// Absolute level-0 slot of time `t` (saturating; `t ≥ 0`).
    #[inline]
    fn abs_slot0(&self, t: f64) -> u64 {
        (t / self.tick) as u64 // f64→u64 casts saturate, NaN → 0
    }

    /// Schedule a wake. O(1): one bucket push at the smallest level
    /// whose remaining window covers the time (times at or before the
    /// current slot clamp into it and come due on the next advance).
    pub fn schedule(&mut self, time: f64, version: u32, page: u32) {
        self.len += 1;
        let e = WheelEntry { time, version, page };
        self.file(e);
    }

    /// File an entry without touching `len` (shared by `schedule`,
    /// cascading and overflow re-filing).
    fn file(&mut self, e: WheelEntry) {
        let s0 = self.abs_slot0(e.time).max(self.cur_slot[0]);
        for l in 0..LEVELS {
            let sl = s0 >> (SLOT_BITS * l as u32);
            if sl < self.cur_slot[l] + SLOTS as u64 {
                self.slots[l * SLOTS + (sl % SLOTS as u64) as usize].push(e);
                return;
            }
        }
        self.overflow.push(e);
    }

    /// Advance the wheel to `t` (clamped monotone) and append every due
    /// entry (`time ≤ t`) to `out`. Whole past buckets — at *every*
    /// level — drain wholesale (a level-`L` bucket strictly before the
    /// level-`L` target slot lies entirely at or before `t`), cursors
    /// jump directly, newly-entered higher-level buckets cascade down,
    /// and the current partial level-0 bucket is filtered. Worst case
    /// O(`LEVELS·SLOTS` + due + cascaded) per call regardless of how far
    /// `t` jumps; O(1) amortized per entry lifecycle. Yield order is
    /// bucket order with insertion order within a bucket (the due *set*
    /// is what the calendar contract specifies; the lazy scheduler's
    /// wake processing is order-independent and the equivalence suite
    /// compares sorted sets).
    pub fn drain_due_into(&mut self, t: f64, out: &mut Vec<WheelEntry>) {
        let t = if t > self.cur { t } else { self.cur };
        let target0 = self.abs_slot0(t);
        let old = self.cur_slot;
        if target0 > old[0] {
            // 1) drain whole past buckets per level: bucket `s < target_L`
            //    at level L spans times < (s+1)·w_L ≤ target_L·w_L ≤ t,
            //    so everything in it is due. Each level has only SLOTS
            //    live buckets, which bounds the walk.
            for l in 0..LEVELS {
                let shift = SLOT_BITS * l as u32;
                let target_l = target0 >> shift;
                let to = target_l.min(old[l] + SLOTS as u64);
                for s in old[l]..to {
                    let idx = l * SLOTS + (s % SLOTS as u64) as usize;
                    if !self.slots[idx].is_empty() {
                        self.len -= self.slots[idx].len();
                        out.append(&mut self.slots[idx]);
                    }
                }
                self.cur_slot[l] = target_l;
            }
            // 2) cascade newly-entered current buckets top-down: their
            //    entries re-file strictly below their old level (an entry
            //    inside the current level-L bucket always fits in level
            //    L-1's window), so one top-down pass settles everything.
            for l in (1..LEVELS).rev() {
                if self.cur_slot[l] == old[l] {
                    continue;
                }
                let idx = l * SLOTS + (self.cur_slot[l] % SLOTS as u64) as usize;
                if self.slots[idx].is_empty() {
                    continue;
                }
                std::mem::swap(&mut self.cascade_scratch, &mut self.slots[idx]);
                while let Some(e) = self.cascade_scratch.pop() {
                    self.file(e);
                }
            }
            // 3) the top cursor moved ⇒ far-future entries may now be in
            //    range; re-file the eligible ones
            if self.cur_slot[LEVELS - 1] != old[LEVELS - 1] && !self.overflow.is_empty() {
                let top_shift = SLOT_BITS * (LEVELS - 1) as u32;
                let mut k = 0;
                while k < self.overflow.len() {
                    let e = self.overflow[k];
                    let st = (self.abs_slot0(e.time).max(self.cur_slot[0])) >> top_shift;
                    if st < self.cur_slot[LEVELS - 1] + SLOTS as u64 {
                        self.overflow.swap_remove(k);
                        self.file(e);
                    } else {
                        k += 1;
                    }
                }
            }
        }
        // current (partial) level-0 bucket: extract due, retain the rest
        let idx = (self.cur_slot[0] % SLOTS as u64) as usize;
        let mut k = 0;
        while k < self.slots[idx].len() {
            if self.slots[idx][k].time <= t {
                out.push(self.slots[idx].swap_remove(k));
                self.len -= 1;
            } else {
                k += 1;
            }
        }
        self.cur = t;
    }

    /// Remove and return the globally earliest entry in canonical
    /// `(time, version, page)` order, due or not — the force-wake
    /// fallback of the lazy scheduler.
    ///
    /// Cost: an O(SLOTS) empty-bucket walk per level, plus a scan of
    /// the first nonempty bucket per level it cannot rule out. In the
    /// common case (the earliest entry lives in a near-future level-0
    /// bucket that provably precedes every higher level's window) the
    /// scan short-circuits after that one bucket. Worst case is the
    /// population of one coarse bucket — a wheel trades the heap's
    /// globally-sorted O(log n) pop for O(1) inserts, so calendars
    /// whose entries cluster inside one coarse bucket pay a linear
    /// min-scan there. The lazy scheduler only reaches this path when
    /// its hot heap is empty (idle/fallback ticks), never on the
    /// process-wakes fast path.
    pub fn pop_earliest(&mut self) -> Option<WheelEntry> {
        let mut best: Option<(usize, usize)> = None; // (bucket index, position)
        let mut best_key = (f64::INFINITY, u32::MAX, u32::MAX);
        let mut scan_overflow = true;
        'levels: for l in 0..LEVELS {
            // within a level, buckets are time-ordered from the current
            // slot forward: the first nonempty bucket holds the level min
            for s in 0..SLOTS as u64 {
                let abs = self.cur_slot[l] + s;
                let idx = l * SLOTS + (abs % SLOTS as u64) as usize;
                if self.slots[idx].is_empty() {
                    continue;
                }
                for (pos, e) in self.slots[idx].iter().enumerate() {
                    let key = e.key();
                    if best.is_none() || key < best_key {
                        best = Some((idx, pos));
                        best_key = key;
                    }
                }
                // short-circuit: if this bucket ends at or before the
                // earliest slot any higher level (or the overflow bin —
                // later still) can populate, the minimum is already in
                // hand. `(cur_slot[L]+1)·w_L` grows with L, so beating
                // level l+1 beats everything above it.
                if l + 1 < LEVELS {
                    let shift = SLOT_BITS * l as u32;
                    let end0 = (abs + 1) << shift;
                    let next0 =
                        (self.cur_slot[l + 1] + 1) << (SLOT_BITS * (l + 1) as u32);
                    if end0 <= next0 {
                        scan_overflow = false;
                        break 'levels;
                    }
                }
                break; // rest of this level is strictly later
            }
        }
        if scan_overflow {
            for (pos, e) in self.overflow.iter().enumerate() {
                let key = e.key();
                if best.is_none() || key < best_key {
                    best = Some((usize::MAX, pos));
                    best_key = key;
                }
            }
        }
        let (idx, pos) = best?;
        self.len -= 1;
        Some(if idx == usize::MAX {
            self.overflow.swap_remove(pos)
        } else {
            self.slots[idx].swap_remove(pos)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngkit::Rng;
    use crate::util::OrdF64;
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;

    const TICK: f64 = 1.0 / 64.0;

    fn key_sorted(mut v: Vec<WheelEntry>) -> Vec<(u64, u32, u32)> {
        v.sort_by(|a, b| {
            a.time.partial_cmp(&b.time).unwrap().then(a.version.cmp(&b.version)).then(
                a.page.cmp(&b.page),
            )
        });
        v.into_iter().map(|e| (e.time.to_bits(), e.version, e.page)).collect()
    }

    #[test]
    fn due_exactly_when_time_leq_t() {
        let mut w = TimingWheel::new(TICK);
        w.schedule(0.5, 1, 0);
        w.schedule(1.5, 2, 1);
        w.schedule(1.5000001, 3, 2);
        let mut out = Vec::new();
        w.drain_due_into(1.5, &mut out);
        assert_eq!(key_sorted(out), vec![(0.5f64.to_bits(), 1, 0), (1.5f64.to_bits(), 2, 1)]);
        assert_eq!(w.len(), 1);
        let mut out = Vec::new();
        w.drain_due_into(2.0, &mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].page, 2);
        assert!(w.is_empty());
    }

    #[test]
    fn past_times_come_due_immediately() {
        let mut w = TimingWheel::new(TICK);
        let mut out = Vec::new();
        w.drain_due_into(10.0, &mut out);
        assert!(out.is_empty());
        // scheduled "in the past" relative to the wheel's current time
        w.schedule(3.0, 7, 4);
        let mut out = Vec::new();
        w.drain_due_into(10.0, &mut out); // t does not even advance
        assert_eq!(out.len(), 1);
        assert_eq!((out[0].version, out[0].page), (7, 4));
    }

    #[test]
    fn far_future_overflow_entries_eventually_drain() {
        let mut w = TimingWheel::new(TICK);
        // beyond the top level's span (tick * 64^4 = 262144)
        let far = 300000.0;
        w.schedule(far, 1, 9);
        w.schedule(0.25, 1, 1);
        let mut out = Vec::new();
        w.drain_due_into(1.0, &mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].page, 1);
        // jump most of the way in big steps, then cross the wake
        let mut out = Vec::new();
        w.drain_due_into(far + 1.0, &mut out);
        assert_eq!(out.len(), 1, "overflow entry never drained");
        assert_eq!(out[0].page, 9);
        assert!(w.is_empty());
    }

    #[test]
    fn pop_earliest_matches_heap_order_including_ties() {
        let mut w = TimingWheel::new(TICK);
        let mut h: BinaryHeap<Reverse<(OrdF64, u32, u32)>> = BinaryHeap::new();
        let entries = [
            (5.0, 3, 2),
            (5.0, 1, 7), // time tie → version breaks
            (5.0, 1, 3), // version tie → page breaks
            (0.125, 9, 0),
            (700.0, 0, 5),   // level ≥ 2
            (300000.0, 2, 6), // overflow
        ];
        for &(t, v, p) in &entries {
            w.schedule(t, v, p);
            h.push(Reverse((OrdF64(t), v, p)));
        }
        while let Some(Reverse((OrdF64(t), v, p))) = h.pop() {
            let e = w.pop_earliest().expect("wheel ran dry before heap");
            assert_eq!((e.time.to_bits(), e.version, e.page), (t.to_bits(), v, p));
        }
        assert!(w.pop_earliest().is_none());
        assert!(w.is_empty());
    }

    #[test]
    fn reset_clears_everything() {
        let mut w = TimingWheel::new(TICK);
        w.schedule(1.0, 1, 1);
        w.schedule(1e6, 1, 2);
        let mut out = Vec::new();
        w.drain_due_into(0.5, &mut out);
        w.reset();
        assert!(w.is_empty());
        assert_eq!(w.now(), 0.0);
        assert!(w.pop_earliest().is_none());
        // usable after reset, including times "before" the old cursor
        w.schedule(0.25, 2, 3);
        let mut out = Vec::new();
        w.drain_due_into(0.5, &mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].page, 3);
    }

    /// The satellite acceptance test: randomized schedule/advance/pop
    /// op-sequences must behave exactly like the `BinaryHeap` calendar
    /// the wheel replaces — identical due-sets at every advance and
    /// identical `(time, version, page)` pop order.
    #[test]
    fn randomized_equivalence_with_binary_heap_calendar() {
        for seed in 0..8u64 {
            let mut rng = Rng::new(100 + seed);
            let mut w = TimingWheel::new(TICK);
            let mut h: BinaryHeap<Reverse<(OrdF64, u32, u32)>> = BinaryHeap::new();
            let mut t = 0.0f64;
            let mut version = 0u32;
            for step in 0..400 {
                // a burst of schedules across every level of the wheel
                for _ in 0..(1 + (rng.f64() * 6.0) as usize) {
                    let horizon = match (rng.f64() * 4.0) as usize {
                        0 => rng.range(0.0, 0.9),        // level 0
                        1 => rng.range(0.9, 60.0),       // level 1
                        2 => rng.range(60.0, 4000.0),    // levels 2-3
                        _ => rng.range(4000.0, 400000.0), // top + overflow
                    };
                    let time = t + horizon;
                    version = version.wrapping_add(1);
                    let page = (rng.f64() * 64.0) as u32;
                    w.schedule(time, version, page);
                    h.push(Reverse((OrdF64(time), version, page)));
                }
                // occasionally pop the earliest like the force-wake path
                if step % 7 == 3 {
                    let want = h.pop().map(|Reverse((OrdF64(x), v, p))| (x.to_bits(), v, p));
                    let got = w.pop_earliest().map(|e| (e.time.to_bits(), e.version, e.page));
                    assert_eq!(want, got, "seed {seed} step {step}: pop_earliest");
                }
                // advance by a random (occasionally large) jump
                t += match (rng.f64() * 8.0) as usize {
                    0 => rng.range(0.0, TICK),      // sub-slot
                    7 => rng.range(100.0, 5000.0),  // multi-level jump
                    _ => rng.range(0.0, 3.0),
                };
                let mut due = Vec::new();
                w.drain_due_into(t, &mut due);
                let mut heap_due = Vec::new();
                while let Some(&Reverse((OrdF64(x), v, p))) = h.peek() {
                    if x > t {
                        break;
                    }
                    h.pop();
                    heap_due.push(WheelEntry { time: x, version: v, page: p });
                }
                assert_eq!(
                    key_sorted(heap_due),
                    key_sorted(due),
                    "seed {seed} step {step}: due-set at t={t}"
                );
                assert_eq!(w.len(), h.len(), "seed {seed} step {step}: len");
            }
            // drain to the end: both calendars must agree on the tail
            let mut due = Vec::new();
            w.drain_due_into(t + 500000.0, &mut due);
            let mut heap_due = Vec::new();
            while let Some(Reverse((OrdF64(x), v, p))) = h.pop() {
                heap_due.push(WheelEntry { time: x, version: v, page: p });
            }
            assert_eq!(key_sorted(heap_due), key_sorted(due), "seed {seed}: final drain");
            assert!(w.is_empty());
        }
    }

    #[test]
    fn overflow_repromotes_across_advances_larger_than_the_span() {
        // the wheel spans tick · 64^4 = 262144 time units; park entries
        // far beyond it and advance in jumps each LARGER than the whole
        // span — every entry must surface exactly once, at the first
        // advance whose target crosses its wake time
        let span = TICK * 64f64.powi(4);
        let mut w = TimingWheel::new(TICK);
        let far: Vec<f64> = (1..=6).map(|k| k as f64 * 1.7 * span + 13.5).collect();
        for (k, &t) in far.iter().enumerate() {
            w.schedule(t, k as u32, k as u32);
        }
        assert_eq!(w.len(), far.len());
        let mut seen = vec![0u32; far.len()];
        let mut t = 0.0;
        while !w.is_empty() {
            t += 2.0 * span; // every jump crosses the full span
            let mut due = Vec::new();
            w.drain_due_into(t, &mut due);
            for e in due {
                assert!(e.time <= t, "entry surfaced before it was due");
                assert!(
                    e.time > t - 2.0 * span,
                    "entry {} should have surfaced in an earlier advance",
                    e.page
                );
                seen[e.page as usize] += 1;
            }
        }
        assert_eq!(seen, vec![1; far.len()], "each overflow entry must drain exactly once");
    }

    #[test]
    fn schedule_in_the_past_clamps_and_comes_due_immediately() {
        let mut w = TimingWheel::new(TICK);
        let mut out = Vec::new();
        w.drain_due_into(1000.0, &mut out); // move the cursor far forward
        assert!(out.is_empty());
        // schedule at t = 0, mid-past, one tick behind, and (the
        // degenerate misuse) a negative time: all clamp into the
        // current slot and surface on the very next drain with their
        // ORIGINAL times intact
        w.schedule(0.0, 1, 0);
        w.schedule(500.0, 2, 1);
        w.schedule(1000.0 - TICK, 3, 2);
        w.schedule(-7.5, 4, 3);
        assert_eq!(w.len(), 4);
        // pop_earliest sees them in true (time, version, page) order
        let first = w.pop_earliest().unwrap();
        assert_eq!((first.time, first.version, first.page), (-7.5, 4, 3));
        w.schedule(-7.5, 4, 3); // put it back
        let mut due = Vec::new();
        w.drain_due_into(1000.0, &mut due); // t does not even advance
        assert_eq!(due.len(), 4, "past entries must come due immediately");
        assert!(w.is_empty());
        let mut pages: Vec<u32> = due.iter().map(|e| e.page).collect();
        pages.sort_unstable();
        assert_eq!(pages, vec![0, 1, 2, 3]);
        // times are reported verbatim, not clamped
        assert!(due.iter().any(|e| e.time == -7.5));
        assert!(due.iter().any(|e| e.time == 0.0));
    }

    #[test]
    fn version_stamp_cancels_page_retired_while_in_overflow() {
        // the lazy-scheduler retirement idiom: a page parks a far-future
        // wake in the overflow bin, is retired (owner bumps its version),
        // and the slot is recycled with a new wake. The wheel still
        // yields BOTH entries — deletion is lazy — but the version
        // stamps let the owner drop the stale one, and `len` stays
        // consistent through the whole lifecycle.
        let mut w = TimingWheel::new(TICK);
        let span = TICK * 64f64.powi(4);
        let mut version = vec![0u32; 8];
        // page 5 sleeps ~2 spans out (overflow bin), version 1
        version[5] = 1;
        w.schedule(2.0 * span, version[5], 5);
        // a near wake for another page keeps the wheel busy
        w.schedule(1.0, 1, 6);
        // retirement: the owner bumps the version; the entry stays
        version[5] = 2;
        // rebirth: the recycled slot schedules its own far wake
        version[5] = 3;
        w.schedule(2.5 * span, version[5], 5);
        assert_eq!(w.len(), 3);
        // advance across everything: the stale overflow entry and the
        // live one both surface; version filtering keeps exactly the live
        let mut due = Vec::new();
        w.drain_due_into(3.0 * span, &mut due);
        assert_eq!(due.len(), 3);
        assert!(w.is_empty());
        let live: Vec<&WheelEntry> =
            due.iter().filter(|e| e.page != 5 || e.version == version[5]).collect();
        assert_eq!(live.len(), 2, "exactly one page-5 entry survives the version filter");
        assert!(live.iter().any(|e| e.page == 5 && e.time == 2.5 * span));
        let stale: Vec<&WheelEntry> =
            due.iter().filter(|e| e.page == 5 && e.version != version[5]).collect();
        assert_eq!(stale.len(), 1);
        assert_eq!(stale[0].version, 1, "the cancelled occupant's stamp survives verbatim");
        // pop_earliest honours the same contract for overflow residents
        w.schedule(4.0 * span, 7, 5); // back into overflow
        version[5] = 8; // retire again before it drains
        let e = w.pop_earliest().unwrap();
        assert_eq!((e.page, e.version), (5, 7));
        assert_ne!(e.version, version[5], "stale by stamp: the owner drops it");
        assert!(w.is_empty());
    }

    #[test]
    fn len_tracks_through_all_paths() {
        let mut w = TimingWheel::new(TICK);
        assert!(w.is_empty());
        w.schedule(0.1, 1, 0);
        w.schedule(100.0, 1, 1);
        w.schedule(999999.0, 1, 2);
        assert_eq!(w.len(), 3);
        assert!(w.pop_earliest().is_some());
        assert_eq!(w.len(), 2);
        let mut out = Vec::new();
        w.drain_due_into(200.0, &mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(w.len(), 1);
    }
}
