//! Figure 9 (Appendix D): GREEDY automatically adapts to bandwidth
//! changes without recomputation — accuracy timeline under
//! R: 100 → 150 → 100 vs the constant-R references.

use crate::benchkit::FigureOutput;
use crate::coordinator::builder::{CrawlerBuilder, Strategy};
use crate::figures::common::ExperimentSpec;
use crate::policy::PolicyKind;
use crate::rngkit::Rng;
use crate::sim::engine::{BandwidthSchedule, SimConfig};
use crate::sim::{generate_traces, simulate, CisDelay};
use crate::Result;

fn timeline(
    inst_pages: &[crate::params::PageParams],
    schedule: BandwidthSchedule,
    horizon: f64,
    seed: u64,
) -> Result<Vec<(f64, f64)>> {
    let mut rng = Rng::new(seed);
    let traces = generate_traces(inst_pages, horizon, CisDelay::None, &mut rng);
    let cfg = SimConfig {
        bandwidth: schedule,
        horizon,
        cis_discard_window: None,
        timeline_window: Some(1000),
    };
    let mut sched = CrawlerBuilder::new()
        .policy(PolicyKind::Greedy)
        .strategy(Strategy::Exact)
        .pages(inst_pages)
        .build()?;
    Ok(simulate(&traces, &cfg, sched.as_mut()).timeline)
}

/// Resample a timeline onto a regular grid (nearest earlier sample).
/// Shared with the dynamic-world figure (`figures::scenario`).
pub(crate) fn resample(tl: &[(f64, f64)], grid: &[f64]) -> Vec<f64> {
    let mut out = Vec::with_capacity(grid.len());
    let mut j = 0usize;
    for &t in grid {
        while j + 1 < tl.len() && tl[j + 1].0 <= t {
            j += 1;
        }
        out.push(if tl.is_empty() { f64::NAN } else { tl[j].1 });
    }
    out
}

/// Figure 9: m = 1000 pages, T = 400; bandwidth switches 100 → 150 at
/// t = 133 and back to 100 at t = 266. Rolling accuracy over the last
/// 1000 requests for the dynamic run and both constant references.
pub fn fig09(_reps: usize) -> Result<()> {
    let spec = ExperimentSpec::section6(1000, 1);
    let mut rng = Rng::new(spec.seed);
    let inst = spec.gen_instance(&mut rng).normalized();
    let horizon = 400.0;
    let dynamic =
        BandwidthSchedule::new(vec![(0.0, 100.0), (133.0, 150.0), (266.0, 100.0)])?;
    let const100 = BandwidthSchedule::constant(100.0)?;
    let const150 = BandwidthSchedule::constant(150.0)?;
    let tl_dyn = timeline(&inst.pages, dynamic, horizon, 77)?;
    let tl_100 = timeline(&inst.pages, const100, horizon, 77)?;
    let tl_150 = timeline(&inst.pages, const150, horizon, 77)?;
    let grid: Vec<f64> = (1..=400).map(|k| k as f64).collect();
    let d = resample(&tl_dyn, &grid);
    let a = resample(&tl_100, &grid);
    let b = resample(&tl_150, &grid);
    let mut fig = FigureOutput::new(
        "fig09_bandwidth_change",
        &["t", "dynamic_100_150_100", "constant_100", "constant_150"],
    );
    for (k, &t) in grid.iter().enumerate() {
        fig.rowf(&[t, d[k], a[k], b[k]]);
    }
    fig.finish()?;
    Ok(())
}
