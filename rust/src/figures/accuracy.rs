//! Accuracy-vs-m figures: Fig 2 (GREEDY vs LDS, no CIS), Fig 3
//! (GREEDY vs GREEDY-CIS, partial observability), Fig 4 (all policies
//! with false positives), Fig 8 (delayed CIS + discard heuristic).

use crate::benchkit::FigureOutput;
use crate::figures::common::{run_cell, ExperimentSpec, PolicyUnderTest};
use crate::policy::PolicyKind;
use crate::sim::CisDelay;
use crate::Result;

/// For m above this, Algorithm 1 runs through the §5.2 lazy scheduler
/// (identical policy, sub-linear per-tick cost; accuracy parity is
/// tested at m = 150/800 to within 0.02-0.03).
const LAZY_ABOVE: usize = 600;

fn greedy(kind: PolicyKind, m: usize) -> PolicyUnderTest {
    if m > LAZY_ABOVE {
        PolicyUnderTest::Lazy(kind)
    } else {
        PolicyUnderTest::Greedy(kind)
    }
}

/// Figure 2: GREEDY vs LDS vs BASELINE without CIS.
pub fn fig02(reps: usize) -> Result<()> {
    let ms = [100usize, 200, 300, 500, 1000];
    let mut fig = FigureOutput::new(
        "fig02_greedy_vs_lds",
        &["m", "baseline", "GREEDY", "GREEDY_stderr", "LDS", "LDS_stderr"],
    );
    for &m in &ms {
        let spec = ExperimentSpec::section6(m, reps);
        let g = run_cell(&spec, greedy(PolicyKind::Greedy, m));
        let l = run_cell(&spec, PolicyUnderTest::Lds);
        fig.rowf(&[m as f64, g.baseline, g.mean, g.stderr, l.mean, l.stderr]);
    }
    fig.finish()?;
    Ok(())
}

/// Figure 3: GREEDY vs GREEDY-CIS with λ ~ Beta(.25,.25), ν = 0.
pub fn fig03(reps: usize) -> Result<()> {
    let ms = [100usize, 200, 300, 500, 1000];
    let mut fig = FigureOutput::new(
        "fig03_partial_observability",
        &["m", "baseline", "GREEDY", "GREEDY_stderr", "GREEDY-CIS", "GREEDY-CIS_stderr"],
    );
    for &m in &ms {
        let spec = ExperimentSpec::section6(m, reps).with_partial_cis();
        let g = run_cell(&spec, greedy(PolicyKind::Greedy, m));
        let c = run_cell(&spec, greedy(PolicyKind::GreedyCis, m));
        fig.rowf(&[m as f64, g.baseline, g.mean, g.stderr, c.mean, c.stderr]);
    }
    fig.finish()?;
    Ok(())
}

/// Figure 4: the full policy line-up with false positives,
/// m ∈ {100, 200, 500, 750, 1000, 10000}.
pub fn fig04(reps: usize) -> Result<()> {
    let ms = [100usize, 200, 500, 750, 1000, 10_000];
    let mut fig = FigureOutput::new(
        "fig04_false_positives",
        &[
            "m", "baseline",
            "GREEDY", "GREEDY-CIS", "GREEDY-NCIS", "G-NCIS-APPROX-1", "G-NCIS-APPROX-2",
            "GREEDY_se", "GREEDY-CIS_se", "GREEDY-NCIS_se", "APPROX-1_se", "APPROX-2_se",
        ],
    );
    for &m in &ms {
        // the m = 10000 point is heavy: scale reps down (documented in
        // EXPERIMENTS.md — the paper uses 100 reps on a cluster)
        let cell_reps = if m >= 10_000 { reps.clamp(1, 3) } else { reps };
        let spec = ExperimentSpec::section6(m, cell_reps)
            .with_partial_cis()
            .with_false_positives();
        let kinds = [
            PolicyKind::Greedy,
            PolicyKind::GreedyCis,
            PolicyKind::GreedyNcis,
            PolicyKind::NcisApprox(1),
            PolicyKind::NcisApprox(2),
        ];
        let mut row = vec![m as f64, f64::NAN];
        let mut ses = Vec::new();
        for kind in kinds {
            let cell = run_cell(&spec, greedy(kind, m));
            row[1] = cell.baseline;
            row.push(cell.mean);
            ses.push(cell.stderr);
        }
        row.extend(ses);
        fig.rowf(&row);
    }
    fig.finish()?;
    Ok(())
}

/// Figure 8 (Appendix C): delayed CIS; GREEDY-NCIS with instantaneous
/// signals vs delayed signals vs delayed + discard window (NCIS-D).
pub fn fig08(reps: usize) -> Result<()> {
    let ms = [100usize, 200, 500, 1000];
    let mut fig = FigureOutput::new(
        "fig08_delayed_cis",
        &[
            "m", "baseline", "NCIS_nodelay", "NCIS_delayed", "NCIS_D",
            "nodelay_se", "delayed_se", "d_se",
        ],
    );
    for &m in &ms {
        let base = ExperimentSpec::section6(m, reps).with_partial_cis().with_false_positives();
        // Appendix C: delay drawn from Poisson(6) counts at tick scale
        let delay = CisDelay::Poisson { mean: 6.0, unit: 1.0 / base.bandwidth };
        let no_delay = run_cell(&base, greedy(PolicyKind::GreedyNcis, m));
        let mut delayed_spec = base.clone();
        delayed_spec.delay = delay;
        let delayed = run_cell(&delayed_spec, greedy(PolicyKind::GreedyNcis, m));
        let mut d_spec = delayed_spec.clone();
        d_spec.discard_window = Some(5.0 / base.bandwidth); // T_DELAY = 5/R
        let with_discard = run_cell(&d_spec, greedy(PolicyKind::GreedyNcis, m));
        fig.rowf(&[
            m as f64,
            no_delay.baseline,
            no_delay.mean,
            delayed.mean,
            with_discard.mean,
            no_delay.stderr,
            delayed.stderr,
            with_discard.stderr,
        ]);
    }
    fig.finish()?;
    Ok(())
}
