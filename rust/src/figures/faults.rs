//! Degraded-mode experiment (no paper counterpart — the fault-injection
//! extension of the robustness story).
//!
//! GREEDY-NCIS under increasing fetch-failure severity: transient-error
//! probability sweeps 0 → 0.5 with a fixed timeout floor and correlated
//! host outages, once per retry policy (exponential backoff vs
//! immediate). Reported per severity step: freshness-under-failure
//! (accuracy the crawler still achieves), the wasted-bandwidth fraction,
//! the fraction of attempts that were retries, and the mean quarantined
//! count — the lanes DESIGN.md's failure-model section discusses.

use crate::benchkit::FigureOutput;
use crate::coordinator::builder::{CrawlerBuilder, Strategy};
use crate::fault::{simulate_faulty_with, FaultConfig, FaultModel, RetryPolicy};
use crate::figures::common::ExperimentSpec;
use crate::policy::PolicyKind;
use crate::rngkit::Rng;
use crate::sim::metrics::FaultRepAccumulator;
use crate::sim::{generate_traces, CisDelay, SimConfig, SimWorkspace};
use crate::Result;

/// Horizon of the experiment (shorter than §6.3: the sweep runs
/// 2 policies × 6 severities × reps full simulations).
const HORIZON: f64 = 200.0;
/// Bandwidth R.
const BANDWIDTH: f64 = 50.0;
/// Pages m.
const PAGES: usize = 500;
/// Host count for the round-robin fault topology.
const HOSTS: usize = 20;

/// The fault figure: per (retry policy, transient severity) cell,
/// freshness / wasted-bandwidth / retry-fraction / quarantine means
/// across reps. CSV: `target/figures/fig_faults_degradation.csv`.
pub fn fig_faults(reps: usize) -> Result<()> {
    let reps = reps.clamp(1, 10);
    let spec = ExperimentSpec::section6(PAGES, reps).with_partial_cis().with_false_positives();
    let mut rng = Rng::new(spec.seed);
    let inst = spec.gen_instance(&mut rng).normalized();
    let cfg = SimConfig::new(BANDWIDTH, HORIZON)?;

    let policies: [(&str, RetryPolicy); 2] = [
        ("backoff", RetryPolicy::default()),
        ("immediate", RetryPolicy::Immediate { max_attempts: 4 }),
    ];
    let mut fig = FigureOutput::new(
        "fig_faults_degradation",
        &[
            "transient_prob",
            "policy_backoff",
            "accuracy",
            "accuracy_se",
            "wasted_fraction",
            "retry_fraction",
            "quarantined_mean",
        ],
    );
    for (name, retry) in policies {
        for &severity in &[0.0, 0.05, 0.1, 0.2, 0.35, 0.5] {
            let mut fault_cfg = FaultConfig {
                transient_prob: severity,
                timeout_prob: 0.02 * severity.min(1.0),
                gone_prob: 0.0,
                hosts: HOSTS,
                outages: Vec::new(),
                seed: 0xFA17,
            };
            // a burst of correlated outages scaled with severity
            if severity > 0.0 {
                fault_cfg.add_correlated_outages(
                    (severity * 10.0).ceil() as usize,
                    HORIZON / 40.0,
                    HORIZON,
                    0xFA18,
                );
            }
            let mut acc = FaultRepAccumulator::new(HOSTS);
            let mut ws = SimWorkspace::new();
            let mut sched = CrawlerBuilder::new()
                .policy(PolicyKind::GreedyNcis)
                .strategy(Strategy::Exact)
                .pages(&inst.pages)
                .build()?;
            for rep in 0..reps {
                let mut trng = Rng::new(spec.seed ^ (0xFEE1 + rep as u64));
                let traces = generate_traces(&inst.pages, HORIZON, CisDelay::None, &mut trng);
                let mut model = FaultModel::new(FaultConfig {
                    seed: fault_cfg.seed ^ rep as u64,
                    ..fault_cfg.clone()
                })?;
                let res = simulate_faulty_with(
                    &mut ws,
                    &traces,
                    &cfg,
                    sched.as_mut(),
                    &mut model,
                    retry,
                );
                acc.push(&res);
            }
            let a = acc.accuracy();
            fig.rowf(&[
                severity,
                if name == "backoff" { 1.0 } else { 0.0 },
                a.mean,
                a.stderr,
                acc.wasted_fraction().mean,
                acc.retry_fraction().mean,
                acc.quarantined().mean,
            ]);
        }
    }
    fig.finish()?;
    Ok(())
}
