//! Appendix G (scaled): bandwidth saving from CIS at population scale.
//!
//! The paper's production experiment (1B URLs, 10k crawls/sec) reports
//! 10–20% refresh-bandwidth savings on CIS-covered hosts at equal or
//! better freshness. The laptop-scale analogue: on a semi-synthetic
//! population, find the bandwidth R' at which GREEDY-NCIS matches plain
//! GREEDY's accuracy at R — the saving is 1 − R'/R. Runs through the
//! sharded lazy coordinator (the same code path the streaming pipeline
//! uses).

use crate::benchkit::FigureOutput;
use crate::coordinator::shard::{run_sharded, ShardPlan};
use crate::dataset::{self, DatasetConfig};
use crate::policy::PolicyKind;
use crate::Result;

fn accuracy_at(
    pages: &[crate::params::PageParams],
    policy: PolicyKind,
    bandwidth: f64,
    horizon: f64,
    shards: usize,
    seed: u64,
) -> Result<f64> {
    let plan = ShardPlan::round_robin(pages.len(), shards);
    Ok(run_sharded(pages, &plan, policy, bandwidth, horizon, seed)?.accuracy)
}

/// Appendix-G scaled experiment. `n_urls` defaults to 50k via the bench.
pub fn appg(n_urls: usize, horizon: f64, shards: usize) -> Result<()> {
    let recs = dataset::generate(&DatasetConfig { n_urls, seed: 0xA9, ..Default::default() });
    let inst = dataset::to_instance(&recs, 0.0).normalized();
    // budget/URL ratio as in §6.7
    let r_full = 0.05 * n_urls as f64;
    let greedy_acc = accuracy_at(&inst.pages, PolicyKind::Greedy, r_full, horizon, shards, 31)?;
    let mut fig = FigureOutput::new(
        "appg_scale",
        &["bandwidth_frac", "greedy_at_full_R", "ncis_accuracy", "saving_achieved"],
    );
    // sweep reduced budgets for GREEDY-NCIS; find where it still matches
    let mut saving = 0.0f64;
    for &frac in &[1.0, 0.95, 0.9, 0.85, 0.8, 0.75] {
        let acc =
            accuracy_at(&inst.pages, PolicyKind::GreedyNcis, frac * r_full, horizon, shards, 31)?;
        let matched = acc >= greedy_acc;
        if matched {
            saving = saving.max(1.0 - frac);
        }
        fig.rowf(&[frac, greedy_acc, acc, if matched { 1.0 - frac } else { f64::NAN }]);
    }
    fig.finish()?;
    println!(
        "App G (scaled, {n_urls} URLs): GREEDY-NCIS matches GREEDY accuracy \
         with up to {:.0}% less bandwidth (paper: 10-20% on covered hosts)",
        saving * 100.0
    );
    Ok(())
}
