//! Regeneration of every figure in the paper (inventory in DESIGN.md).
//!
//! Each `figNN` function runs the corresponding experiment and writes
//! its series via [`crate::benchkit::FigureOutput`] (CSV under
//! `target/figures/` + aligned stdout table). Benches (`benches/`) and
//! the `figure` CLI subcommand are thin wrappers over these.

pub mod accuracy;
pub mod common;
pub mod dynamics;
pub mod estimators;
pub mod faults;
pub mod rates;
pub mod regret;
pub mod scale;
pub mod scenario;
pub mod semisynth;
pub mod serving;
pub mod valuefn;

pub use common::{ExperimentSpec, PolicyUnderTest};

/// Run one figure by id (`"1"`, `"2"`, …, `"appg"`). `reps` scales the
/// repetition count (the paper uses 100 / 10; see EXPERIMENTS.md for
/// the scaling rationale).
pub fn run_figure(id: &str, reps: usize) -> crate::Result<()> {
    match id {
        "1" => semisynth::fig01(100_000),
        "2" => accuracy::fig02(reps),
        "3" => accuracy::fig03(reps),
        "4" => accuracy::fig04(reps),
        "5" => semisynth::fig05(&semisynth::SemiSynthSpec {
            reps: reps.clamp(1, 10),
            ..Default::default()
        }),
        "6" => valuefn::fig06(),
        "7" => rates::fig07(reps),
        "8" => accuracy::fig08(reps),
        "9" => dynamics::fig09(reps),
        "10" => estimators::fig10(reps * 10),
        "11" => estimators::fig11(reps * 10),
        "12" | "13" => rates::fig12_13(reps),
        "14" => rates::fig14(reps),
        "appg" => scale::appg(20_000, 60.0, 4),
        "scenario" => scenario::fig_scenario(reps),
        "faults" => faults::fig_faults(reps),
        "serving" => serving::fig_serving(reps),
        "regret" => regret::fig_regret(reps),
        other => Err(crate::Error::Usage(format!(
            "unknown figure `{other}` (valid: 1-14, appg, scenario, faults, regret, serving)"
        ))),
    }
}
