//! Learned-knowledge regret experiment (no paper counterpart — the
//! oracle-free extension of the §1-footnote deployment story).
//!
//! The same GREEDY-NCIS scheduler runs every world twice: once with
//! oracle knowledge (ground-truth page parameters, the paper's setting)
//! and once with [`crate::Knowledge::Learned`] — cold-started from
//! uninformative priors, learning change rates and CIS quality purely
//! from crawl outcomes. The gap between the two rolling-freshness
//! timelines is the *regret of not knowing the world*, reported under:
//!
//! - a **static** world (pure cold-start: regret must shrink as
//!   estimates converge),
//! - a **drifting** world (diurnal Δ drift: the estimators chase a
//!   moving target),
//! - a **faulty** world (transient fetch errors + correlated host
//!   outages through the fault engine: failed fetches must not poison
//!   estimates).
//!
//! Every seed derives from the spec seed, so two same-seed runs emit
//! byte-identical CSV (pinned in `tests/cli_integration.rs`).

use crate::benchkit::FigureOutput;
use crate::coordinator::builder::{CrawlerBuilder, Knowledge, Strategy};
use crate::estimation::EstimatorConfig;
use crate::fault::{simulate_faulty_with, FaultConfig, FaultModel, RetryPolicy};
use crate::figures::common::ExperimentSpec;
use crate::figures::dynamics::resample;
use crate::policy::PolicyKind;
use crate::rngkit::Rng;
use crate::scenario::generators::add_diurnal_drift;
use crate::scenario::Scenario;
use crate::sim::{generate_traces, CisDelay, SimConfig, SimWorkspace};
use crate::Result;

/// Horizon of the experiment (long enough for cold-start convergence:
/// each page is fetched ~R·T/m = 20 times).
const HORIZON: f64 = 200.0;
/// Bandwidth R.
const BANDWIDTH: f64 = 40.0;
/// Pages m.
const PAGES: usize = 400;
/// Host count for the faulty world's topology.
const HOSTS: usize = 16;
/// Rolling-freshness window (requests).
const WINDOW: usize = 1000;

fn knob(knowledge: Knowledge, base: &CrawlerBuilder) -> CrawlerBuilder {
    base.clone().knowledge(knowledge)
}

/// Learned-mode configuration of the figure: default trust gates, the
/// figure's own master seed.
fn learned_cfg() -> EstimatorConfig {
    EstimatorConfig { seed: 0x4E57_ED42, ..EstimatorConfig::default() }
}

/// Mean rolling-freshness timeline over `reps` scenario repetitions.
fn mean_timeline(
    builder: &CrawlerBuilder,
    cfg: &SimConfig,
    grid: &[f64],
    reps: usize,
) -> Result<Vec<f64>> {
    let mut acc = vec![0.0f64; grid.len()];
    for rep in 0..reps {
        let res = builder.run_scenario(cfg, 0x4E67 ^ rep as u64)?;
        for (a, v) in acc.iter_mut().zip(resample(&res.timeline, grid)) {
            *a += v;
        }
    }
    Ok(acc.iter().map(|a| a / reps as f64).collect())
}

/// Mean rolling-freshness timeline through the fault engine.
fn mean_faulty_timeline(
    builder: &CrawlerBuilder,
    pages: &[crate::params::PageParams],
    cfg: &SimConfig,
    grid: &[f64],
    reps: usize,
    trace_seed: u64,
) -> Result<Vec<f64>> {
    let mut acc = vec![0.0f64; grid.len()];
    let mut ws = SimWorkspace::new();
    let mut sched = builder.build()?;
    for rep in 0..reps {
        let mut trng = Rng::new(trace_seed ^ (0xFEE1 + rep as u64));
        let traces = generate_traces(pages, HORIZON, CisDelay::None, &mut trng);
        let mut fault_cfg = FaultConfig {
            transient_prob: 0.2,
            timeout_prob: 0.02,
            gone_prob: 0.0,
            hosts: HOSTS,
            outages: Vec::new(),
            seed: 0xFA17 ^ rep as u64,
        };
        fault_cfg.add_correlated_outages(3, HORIZON / 40.0, HORIZON, 0xFA18 ^ rep as u64);
        let mut model = FaultModel::new(fault_cfg)?;
        let res = simulate_faulty_with(
            &mut ws,
            &traces,
            cfg,
            sched.as_mut(),
            &mut model,
            RetryPolicy::default(),
        );
        for (a, v) in acc.iter_mut().zip(resample(&res.sim.timeline, grid)) {
            *a += v;
        }
    }
    Ok(acc.iter().map(|a| a / reps as f64).collect())
}

/// The regret figure: per unit time, oracle vs learned rolling
/// freshness and their gap, under static / drifting / faulty worlds.
/// CSV: `target/figures/fig_regret.csv`.
pub fn fig_regret(reps: usize) -> Result<()> {
    let reps = reps.clamp(1, 10);
    let spec = ExperimentSpec::section6(PAGES, reps).with_partial_cis().with_false_positives();
    let mut rng = Rng::new(spec.seed);
    let inst = spec.gen_instance(&mut rng).normalized();

    let mut cfg = SimConfig::new(BANDWIDTH, HORIZON)?;
    cfg.timeline_window = Some(WINDOW);
    let grid: Vec<f64> = (1..=HORIZON as usize).map(|k| k as f64).collect();

    let static_world = Scenario::new(inst.pages.clone(), 0x4E61);
    let mut drift_world = Scenario::new(inst.pages.clone(), 0x4E62);
    add_diurnal_drift(&mut drift_world, 50.0, 0.5, 8, 0.3, HORIZON, 0x4E63);

    let base = CrawlerBuilder::new().policy(PolicyKind::GreedyNcis).strategy(Strategy::Exact);
    let learned = Knowledge::Learned(learned_cfg());

    let lane = |k: Knowledge, sc: &Scenario| {
        mean_timeline(&knob(k, &base).with_scenario(sc.clone()), &cfg, &grid, reps)
    };
    let static_oracle = lane(Knowledge::Oracle, &static_world)?;
    let static_learned = lane(learned, &static_world)?;
    let drift_oracle = lane(Knowledge::Oracle, &drift_world)?;
    let drift_learned = lane(learned, &drift_world)?;

    let faulty = |k: Knowledge| {
        mean_faulty_timeline(
            &knob(k, &base).pages(&inst.pages),
            &inst.pages,
            &cfg,
            &grid,
            reps,
            spec.seed,
        )
    };
    let faulty_oracle = faulty(Knowledge::Oracle)?;
    let faulty_learned = faulty(learned)?;

    let mut fig = FigureOutput::new(
        "fig_regret",
        &[
            "t",
            "static_oracle",
            "static_learned",
            "static_regret",
            "drift_oracle",
            "drift_learned",
            "drift_regret",
            "faulty_oracle",
            "faulty_learned",
            "faulty_regret",
        ],
    );
    for (k, &t) in grid.iter().enumerate() {
        fig.rowf(&[
            t,
            static_oracle[k],
            static_learned[k],
            static_oracle[k] - static_learned[k],
            drift_oracle[k],
            drift_learned[k],
            drift_oracle[k] - drift_learned[k],
            faulty_oracle[k],
            faulty_learned[k],
            faulty_oracle[k] - faulty_learned[k],
        ]);
    }
    fig.finish()?;
    Ok(())
}
