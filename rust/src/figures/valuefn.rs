//! Figure 6: the crawl-value function V(ι) and its j-term
//! approximations, with the ι → ∞ asymptote μ̃/Δ.

use crate::benchkit::FigureOutput;
use crate::params::{PageParams, ParamColumns};
use crate::policy::value;
use crate::Result;

/// Figure 6: V exact vs APPROX-{1,2,3} over an ι grid for a fixed,
/// strongly-signalled environment (small β ⇒ many active terms).
///
/// The sweep runs through the batched columnar kernel
/// ([`value::values_ncis_into`]) — the same evaluation path the native
/// schedulers use — with the single environment broadcast across the ι
/// grid via the page-gather indices (bit-identical to the scalar
/// `value_ncis` per point).
pub fn fig06() -> Result<()> {
    let p = PageParams { delta: 1.0, mu: 1.0, lam: 0.5, nu: 0.8 };
    let d = p.derive()?;
    let asymptote = d.mu / d.delta;
    let mut fig = FigureOutput::new(
        "fig06_value_function",
        &["iota", "V_exact", "V_approx1", "V_approx2", "V_approx3", "asymptote"],
    );
    let max_iota = 8.0 * d.beta.min(10.0);
    let steps = 200usize;
    let iotas: Vec<f64> = (0..=steps).map(|k| k as f64 / steps as f64 * max_iota).collect();
    let mut cols = ParamColumns::with_capacity(1);
    cols.push(&d);
    let pages = vec![0u32; iotas.len()]; // broadcast the one environment
    let mut curves = [
        vec![0.0; iotas.len()],
        vec![0.0; iotas.len()],
        vec![0.0; iotas.len()],
        vec![0.0; iotas.len()],
    ];
    for (out, terms) in curves.iter_mut().zip([value::MAX_TERMS, 1, 2, 3]) {
        value::values_ncis_into(out, &iotas, &pages, &cols, terms);
    }
    for (k, &iota) in iotas.iter().enumerate() {
        fig.rowf(&[iota, curves[0][k], curves[1][k], curves[2][k], curves[3][k], asymptote]);
    }
    fig.finish()?;
    Ok(())
}
