//! Figure 6: the crawl-value function V(ι) and its j-term
//! approximations, with the ι → ∞ asymptote μ̃/Δ.

use crate::benchkit::FigureOutput;
use crate::params::PageParams;
use crate::policy::value;
use crate::Result;

/// Figure 6: V exact vs APPROX-{1,2,3} over an ι grid for a fixed,
/// strongly-signalled environment (small β ⇒ many active terms).
pub fn fig06() -> Result<()> {
    let p = PageParams { delta: 1.0, mu: 1.0, lam: 0.5, nu: 0.8 };
    let d = p.derive().unwrap();
    let asymptote = d.mu / d.delta;
    let mut fig = FigureOutput::new(
        "fig06_value_function",
        &["iota", "V_exact", "V_approx1", "V_approx2", "V_approx3", "asymptote"],
    );
    let max_iota = 8.0 * d.beta.min(10.0);
    let steps = 200;
    for k in 0..=steps {
        let iota = k as f64 / steps as f64 * max_iota;
        fig.rowf(&[
            iota,
            value::value_ncis(iota, &d, value::MAX_TERMS),
            value::value_ncis(iota, &d, 1),
            value::value_ncis(iota, &d, 2),
            value::value_ncis(iota, &d, 3),
            asymptote,
        ]);
    }
    fig.finish()?;
    Ok(())
}
