//! Shared experiment machinery for the figure harness.

use crate::coordinator::crawler::{GreedyScheduler, LdsAdapter, ValueBackend};
use crate::coordinator::lazy::LazyGreedyScheduler;
use crate::params::{Instance, PageParams};
use crate::policy::PolicyKind;
use crate::rngkit::{self, Rng};
use crate::sim::engine::{Scheduler, SimConfig};
use crate::sim::metrics::RepAccumulator;
use crate::sim::{generate_traces, simulate, CisDelay};
use crate::solver;

/// §6.1 problem-instance specification.
#[derive(Debug, Clone)]
pub struct ExperimentSpec {
    /// Number of pages m.
    pub m: usize,
    /// Bandwidth R.
    pub bandwidth: f64,
    /// Horizon T.
    pub horizon: f64,
    /// Repetitions (paper: 100; benches default lower — see EXPERIMENTS.md).
    pub reps: usize,
    /// λ_i ~ Beta(a, b) when CIS are enabled, else λ = 0.
    pub lam_beta: Option<(f64, f64)>,
    /// ν_i ~ Unif(lo, hi) when false positives are enabled, else ν = 0.
    pub nu_range: Option<(f64, f64)>,
    /// Base RNG seed.
    pub seed: u64,
    /// CIS delivery delay model.
    pub delay: CisDelay,
    /// Appendix-C discard window.
    pub discard_window: Option<f64>,
}

impl ExperimentSpec {
    /// Defaults matching §6.3: Δ, μ ~ U[0,1], R = 100, T = 1000.
    pub fn section6(m: usize, reps: usize) -> Self {
        Self {
            m,
            bandwidth: 100.0,
            horizon: 1000.0,
            reps,
            lam_beta: None,
            nu_range: None,
            seed: 0x5EED,
            delay: CisDelay::None,
            discard_window: None,
        }
    }

    /// Enable §6.5-style partially-observable CIS (λ ~ Beta(.25,.25)).
    pub fn with_partial_cis(mut self) -> Self {
        self.lam_beta = Some((0.25, 0.25));
        self
    }

    /// Enable §6.6-style false positives (ν ~ Unif(.1,.6)).
    pub fn with_false_positives(mut self) -> Self {
        self.nu_range = Some((0.1, 0.6));
        self
    }

    /// Draw a problem instance (Δ, μ ~ U[0,1] as in §6.3).
    pub fn gen_instance(&self, rng: &mut Rng) -> Instance {
        let pages = (0..self.m)
            .map(|_| PageParams {
                delta: rng.range(1e-4, 1.0),
                mu: rng.range(1e-4, 1.0),
                lam: match self.lam_beta {
                    Some((a, b)) => rngkit::beta(rng, a, b),
                    None => 0.0,
                },
                nu: match self.nu_range {
                    Some((lo, hi)) => rng.range(lo, hi),
                    None => 0.0,
                },
            })
            .collect();
        Instance { pages, bandwidth: self.bandwidth }
    }
}

/// Which discrete policy implementation an experiment cell runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyUnderTest {
    /// Algorithm 1 with the given value function (exact argmax).
    Greedy(PolicyKind),
    /// Algorithm 1 via the §5.2 lazy scheduler.
    Lazy(PolicyKind),
    /// LDS over the no-CIS continuous optimum (Azar et al.).
    Lds,
}

impl PolicyUnderTest {
    /// Display name.
    pub fn name(&self) -> String {
        match self {
            PolicyUnderTest::Greedy(k) => k.name(),
            PolicyUnderTest::Lazy(k) => format!("{}-LAZY", k.name()),
            PolicyUnderTest::Lds => "LDS".into(),
        }
    }
}

/// Outcome of one experiment cell.
#[derive(Debug, Clone)]
pub struct CellResult {
    /// Policy display name.
    pub policy: String,
    /// Accuracy mean over reps.
    pub mean: f64,
    /// Accuracy stderr over reps.
    pub stderr: f64,
    /// Mean empirical per-page crawl rates across reps.
    pub mean_rates: Vec<f64>,
    /// BASELINE (optimal continuous no-CIS) analytical accuracy.
    pub baseline: f64,
    /// The instance the cell ran on (normalized importance).
    pub instance: Instance,
}

fn make_scheduler(
    put: PolicyUnderTest,
    inst: &Instance,
    no_cis_rates: &[f64],
) -> Box<dyn Scheduler> {
    match put {
        PolicyUnderTest::Greedy(kind) => {
            Box::new(GreedyScheduler::new(kind, &inst.pages, ValueBackend::Native))
        }
        PolicyUnderTest::Lazy(kind) => Box::new(LazyGreedyScheduler::new(kind, &inst.pages)),
        PolicyUnderTest::Lds => Box::new(LdsAdapter::new(no_cis_rates)),
    }
}

/// Run one experiment cell: a fixed instance (drawn from `spec` with
/// `spec.seed`), `spec.reps` trace realizations, one accuracy per rep.
pub fn run_cell(spec: &ExperimentSpec, put: PolicyUnderTest) -> CellResult {
    let mut irng = Rng::new(spec.seed);
    let inst = spec.gen_instance(&mut irng).normalized();
    let baseline = solver::baseline_accuracy(&inst).unwrap_or(f64::NAN);
    let no_cis_rates = match put {
        PolicyUnderTest::Lds => solver::solve_no_cis(&inst).map(|s| s.rates).unwrap_or_default(),
        _ => Vec::new(),
    };
    let mut acc = RepAccumulator::new(inst.pages.len());
    for rep in 0..spec.reps {
        let mut trng = Rng::new(spec.seed ^ (0xC0FFEE + rep as u64));
        let traces = generate_traces(&inst.pages, spec.horizon, spec.delay, &mut trng);
        let mut cfg = SimConfig::new(spec.bandwidth, spec.horizon);
        cfg.cis_discard_window = spec.discard_window;
        let mut sched = make_scheduler(put, &inst, &no_cis_rates);
        let res = simulate(&traces, &cfg, sched.as_mut());
        acc.push(res.accuracy, &res.empirical_rates(spec.horizon));
    }
    let s = acc.accuracy();
    CellResult {
        policy: put.name(),
        mean: s.mean,
        stderr: s.stderr,
        mean_rates: acc.mean_rates(),
        baseline,
        instance: inst,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cell_runs_and_reports() {
        let spec = ExperimentSpec {
            horizon: 60.0,
            bandwidth: 10.0,
            ..ExperimentSpec::section6(30, 3)
        };
        let r = run_cell(&spec, PolicyUnderTest::Greedy(PolicyKind::Greedy));
        assert!((0.0..=1.0).contains(&r.mean), "{}", r.mean);
        assert!((0.0..=1.0).contains(&r.baseline));
        assert_eq!(r.mean_rates.len(), 30);
    }

    #[test]
    fn lds_cell_runs() {
        let spec = ExperimentSpec {
            horizon: 60.0,
            bandwidth: 10.0,
            ..ExperimentSpec::section6(30, 2)
        };
        let r = run_cell(&spec, PolicyUnderTest::Lds);
        assert!((0.0..=1.0).contains(&r.mean));
    }

    #[test]
    fn cis_spec_generates_cis_params() {
        let spec = ExperimentSpec::section6(100, 1).with_partial_cis().with_false_positives();
        let mut rng = Rng::new(1);
        let inst = spec.gen_instance(&mut rng);
        assert!(inst.pages.iter().any(|p| p.lam > 0.1));
        assert!(inst.pages.iter().all(|p| (0.1..=0.6).contains(&p.nu)));
    }
}
