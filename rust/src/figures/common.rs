//! Shared experiment machinery for the figure harness.
//!
//! [`run_cell`] fans the repetitions of one experiment cell across
//! worker threads (same scoped-thread idiom as `coordinator/shard.rs`):
//! every repetition keeps its deterministic seed
//! `spec.seed ^ (0xC0FFEE + rep)` and results are merged in repetition
//! order, so the parallel output is bit-identical to a serial run
//! ([`run_cell_serial`]; the `parallel_cell_matches_serial_exactly` test
//! asserts it). Each worker owns a reusable [`SimWorkspace`], so a cell
//! performs O(threads) scratch allocations instead of O(reps).
//!
//! Schedulers are constructed through [`CrawlerBuilder`], so cells,
//! benches and the CLI all measure exactly the same construction path.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use crate::coordinator::builder::CrawlerBuilder;
use crate::params::{Instance, PageParams};
use crate::rngkit::{self, Rng};
use crate::sched::{CrawlScheduler, IdleScheduler};
use crate::sim::engine::SimConfig;
use crate::sim::metrics::RepAccumulator;
use crate::sim::{
    generate_traces, simulate_streamed_with, simulate_with, CisDelay, SimWorkspace,
    StreamedSource, TraceMode,
};
use crate::solver;

pub use crate::policy::PolicyUnderTest;

/// §6.1 problem-instance specification.
#[derive(Debug, Clone)]
pub struct ExperimentSpec {
    /// Number of pages m.
    pub m: usize,
    /// Bandwidth R.
    pub bandwidth: f64,
    /// Horizon T.
    pub horizon: f64,
    /// Repetitions (paper: 100; benches default lower — see EXPERIMENTS.md).
    pub reps: usize,
    /// λ_i ~ Beta(a, b) when CIS are enabled, else λ = 0.
    pub lam_beta: Option<(f64, f64)>,
    /// ν_i ~ Unif(lo, hi) when false positives are enabled, else ν = 0.
    pub nu_range: Option<(f64, f64)>,
    /// Base RNG seed.
    pub seed: u64,
    /// CIS delivery delay model.
    pub delay: CisDelay,
    /// Appendix-C discard window.
    pub discard_window: Option<f64>,
    /// How per-repetition event streams are produced. Default
    /// [`TraceMode::Streamed`]: cell workers sample events lazily in
    /// `O(m)` memory; [`TraceMode::Materialized`] keeps the pre-built
    /// traces of the oracle path (a different — seed-paired at the
    /// master level, but distinct — realization of the same process).
    pub trace_mode: TraceMode,
}

impl ExperimentSpec {
    /// Defaults matching §6.3: Δ, μ ~ U[0,1], R = 100, T = 1000.
    pub fn section6(m: usize, reps: usize) -> Self {
        Self {
            m,
            bandwidth: 100.0,
            horizon: 1000.0,
            reps,
            lam_beta: None,
            nu_range: None,
            seed: 0x5EED,
            delay: CisDelay::None,
            discard_window: None,
            trace_mode: TraceMode::default(),
        }
    }

    /// Override how event streams are produced (cells default to the
    /// streamed, `O(m)`-memory path).
    pub fn with_trace_mode(mut self, mode: TraceMode) -> Self {
        self.trace_mode = mode;
        self
    }

    /// Enable §6.5-style partially-observable CIS (λ ~ Beta(.25,.25)).
    pub fn with_partial_cis(mut self) -> Self {
        self.lam_beta = Some((0.25, 0.25));
        self
    }

    /// Enable §6.6-style false positives (ν ~ Unif(.1,.6)).
    pub fn with_false_positives(mut self) -> Self {
        self.nu_range = Some((0.1, 0.6));
        self
    }

    /// Draw a problem instance (Δ, μ ~ U[0,1] as in §6.3).
    pub fn gen_instance(&self, rng: &mut Rng) -> Instance {
        let pages = (0..self.m)
            .map(|_| PageParams {
                delta: rng.range(1e-4, 1.0),
                mu: rng.range(1e-4, 1.0),
                lam: match self.lam_beta {
                    Some((a, b)) => rngkit::beta(rng, a, b),
                    None => 0.0,
                },
                nu: match self.nu_range {
                    Some((lo, hi)) => rng.range(lo, hi),
                    None => 0.0,
                },
            })
            .collect();
        Instance { pages, bandwidth: self.bandwidth }
    }
}

/// Outcome of one experiment cell.
#[derive(Debug, Clone)]
pub struct CellResult {
    /// Policy display name.
    pub policy: String,
    /// Accuracy mean over reps.
    pub mean: f64,
    /// Accuracy stderr over reps.
    pub stderr: f64,
    /// Mean empirical per-page crawl rates across reps.
    pub mean_rates: Vec<f64>,
    /// BASELINE (optimal continuous no-CIS) analytical accuracy.
    pub baseline: f64,
    /// The instance the cell ran on (normalized importance), shared —
    /// not cloned per cell — so large-m sweeps don't copy page vectors.
    pub instance: Arc<Instance>,
}

/// Construct the scheduler a cell lane runs (shared with
/// `benches/perf.rs` so bench lanes measure exactly what [`run_cell`]
/// constructs). `no_cis_rates` feeds the LDS adapter and is ignored by
/// the greedy/lazy lanes.
pub fn make_scheduler(
    put: PolicyUnderTest,
    inst: &Instance,
    no_cis_rates: &[f64],
) -> Box<dyn CrawlScheduler + Send> {
    // degraded LDS path: if the continuous solver failed, the cell runs
    // the shared idle scheduler (no crawls) rather than aborting the
    // sweep — the builder itself rejects an empty-rate Lds as misuse
    if put == PolicyUnderTest::Lds && no_cis_rates.is_empty() {
        return Box::new(IdleScheduler);
    }
    CrawlerBuilder::new()
        .policy_under_test(put)
        .pages(&inst.pages)
        .lds_rates(no_cis_rates)
        .build()
        .unwrap_or_else(|e| panic!("cell scheduler construction failed: {e}"))
}

/// Worker threads [`run_cell`] uses to fan repetitions across cores.
/// `NCIS_THREADS` overrides; defaults to the machine's parallelism.
pub fn default_rep_threads() -> usize {
    std::env::var("NCIS_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
}

/// One repetition of a cell: deterministic per-rep seed, streaming
/// engine over the worker's reusable workspace. `spec.trace_mode`
/// picks the event path — streamed (default: lazy per-page sources,
/// `O(m)` memory) or materialized (pre-built traces through the replay
/// adapter). The worker's scheduler is reused across repetitions — the
/// engine fires `on_start`, which fully resets it (reuse == fresh is
/// parity-tested), so a cell pays scheduler construction once per
/// worker instead of once per rep.
fn run_rep(
    spec: &ExperimentSpec,
    inst: &Instance,
    rep: usize,
    ws: &mut SimWorkspace,
    sched: &mut dyn CrawlScheduler,
) -> (f64, Vec<f64>) {
    let mut trng = Rng::new(spec.seed ^ (0xC0FFEE + rep as u64));
    let mut cfg = SimConfig::new(spec.bandwidth, spec.horizon)
        .unwrap_or_else(|e| panic!("experiment spec bandwidth must be a valid crawl rate: {e}"));
    cfg.cis_discard_window = spec.discard_window;
    // both trace modes must reject a bad delay the same way (the
    // streamed constructor validates internally; the materialized
    // generator assumes validity)
    spec.delay
        .validate()
        .unwrap_or_else(|e| panic!("experiment spec delay must be valid: {e}"));
    let res = match spec.trace_mode {
        TraceMode::Materialized => {
            let traces = generate_traces(&inst.pages, spec.horizon, spec.delay, &mut trng);
            simulate_with(ws, &traces, &cfg, sched)
        }
        TraceMode::Streamed => {
            let source = StreamedSource::new(&inst.pages, spec.horizon, spec.delay, &mut trng)
                .unwrap_or_else(|e| panic!("experiment spec delay must be valid: {e}"));
            simulate_streamed_with(ws, source, &cfg, sched)
        }
    };
    (res.accuracy, res.empirical_rates(spec.horizon))
}

/// Run one experiment cell: a fixed instance (drawn from `spec` with
/// `spec.seed`), `spec.reps` trace realizations, one accuracy per rep.
/// Repetitions run in parallel (see [`run_cell_with_threads`]).
pub fn run_cell(spec: &ExperimentSpec, put: PolicyUnderTest) -> CellResult {
    run_cell_with_threads(spec, put, default_rep_threads())
}

/// [`run_cell`] pinned to one worker — the serial reference the parallel
/// driver is tested bit-identical against.
pub fn run_cell_serial(spec: &ExperimentSpec, put: PolicyUnderTest) -> CellResult {
    run_cell_with_threads(spec, put, 1)
}

/// Run one experiment cell with an explicit worker-thread count.
///
/// Work distribution is dynamic (an atomic rep counter), but every
/// repetition is fully determined by its seed and the results are merged
/// into the [`RepAccumulator`] in repetition order, so the outcome is
/// identical for every thread count.
pub fn run_cell_with_threads(
    spec: &ExperimentSpec,
    put: PolicyUnderTest,
    threads: usize,
) -> CellResult {
    let mut irng = Rng::new(spec.seed);
    let inst = Arc::new(spec.gen_instance(&mut irng).normalized());
    let baseline = solver::baseline_accuracy(&inst).unwrap_or(f64::NAN);
    let no_cis_rates = match put {
        PolicyUnderTest::Lds => solver::solve_no_cis(&inst).map(|s| s.rates).unwrap_or_default(),
        _ => Vec::new(),
    };
    let threads = threads.clamp(1, spec.reps.max(1));
    let mut results: Vec<Option<(f64, Vec<f64>)>> = vec![None; spec.reps];
    if threads <= 1 {
        let mut ws = SimWorkspace::new();
        let mut sched = make_scheduler(put, &inst, &no_cis_rates);
        for (rep, slot) in results.iter_mut().enumerate() {
            *slot = Some(run_rep(spec, &inst, rep, &mut ws, sched.as_mut()));
        }
    } else {
        let next = AtomicUsize::new(0);
        let next_ref = &next;
        let inst_ref = &*inst;
        let rates_ref = no_cis_rates.as_slice();
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..threads)
                .map(|_| {
                    scope.spawn(move || {
                        let mut ws = SimWorkspace::new();
                        let mut sched = make_scheduler(put, inst_ref, rates_ref);
                        let mut out: Vec<(usize, (f64, Vec<f64>))> = Vec::new();
                        loop {
                            let rep = next_ref.fetch_add(1, Ordering::Relaxed);
                            if rep >= spec.reps {
                                break;
                            }
                            out.push((rep, run_rep(spec, inst_ref, rep, &mut ws, sched.as_mut())));
                        }
                        out
                    })
                })
                .collect();
            for h in handles {
                // a rep worker panic carries the rep's own diagnostic —
                // surface it verbatim instead of masking it
                let rows = h.join().unwrap_or_else(|p| std::panic::resume_unwind(p));
                for (rep, r) in rows {
                    results[rep] = Some(r);
                }
            }
        });
    }
    let mut acc = RepAccumulator::new(inst.pages.len());
    for r in results {
        let Some((accuracy, rates)) = r else {
            unreachable!("every repetition index is claimed exactly once");
        };
        acc.push(accuracy, &rates);
    }
    let s = acc.accuracy();
    CellResult {
        policy: put.name(),
        mean: s.mean,
        stderr: s.stderr,
        mean_rates: acc.mean_rates(),
        baseline,
        instance: inst,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::PolicyKind;

    #[test]
    fn cell_runs_and_reports() {
        let spec = ExperimentSpec {
            horizon: 60.0,
            bandwidth: 10.0,
            ..ExperimentSpec::section6(30, 3)
        };
        let r = run_cell(&spec, PolicyUnderTest::Greedy(PolicyKind::Greedy));
        assert!((0.0..=1.0).contains(&r.mean), "{}", r.mean);
        assert!((0.0..=1.0).contains(&r.baseline));
        assert_eq!(r.mean_rates.len(), 30);
        assert_eq!(r.instance.pages.len(), 30);
    }

    #[test]
    fn lds_cell_runs() {
        let spec = ExperimentSpec {
            horizon: 60.0,
            bandwidth: 10.0,
            ..ExperimentSpec::section6(30, 2)
        };
        let r = run_cell(&spec, PolicyUnderTest::Lds);
        assert!((0.0..=1.0).contains(&r.mean));
    }

    #[test]
    fn parallel_cell_matches_serial_exactly() {
        let spec = ExperimentSpec {
            horizon: 40.0,
            bandwidth: 6.0,
            ..ExperimentSpec::section6(30, 5)
        }
        .with_partial_cis()
        .with_false_positives();
        for put in [
            PolicyUnderTest::Greedy(PolicyKind::GreedyNcis),
            PolicyUnderTest::Lazy(PolicyKind::GreedyNcis),
            PolicyUnderTest::Lds,
        ] {
            let serial = run_cell_serial(&spec, put);
            let parallel = run_cell_with_threads(&spec, put, 4);
            assert_eq!(
                serial.mean.to_bits(),
                parallel.mean.to_bits(),
                "{}: mean {} vs {}",
                put.name(),
                serial.mean,
                parallel.mean
            );
            assert_eq!(serial.stderr.to_bits(), parallel.stderr.to_bits(), "{}", put.name());
            assert_eq!(serial.mean_rates.len(), parallel.mean_rates.len());
            for (i, (a, b)) in serial.mean_rates.iter().zip(&parallel.mean_rates).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{}: rate[{i}]", put.name());
            }
            assert_eq!(serial.baseline.to_bits(), parallel.baseline.to_bits());
        }
    }

    #[test]
    fn thread_count_does_not_change_results() {
        let spec = ExperimentSpec {
            horizon: 30.0,
            bandwidth: 5.0,
            ..ExperimentSpec::section6(20, 7)
        };
        let reference = run_cell_serial(&spec, PolicyUnderTest::Greedy(PolicyKind::Greedy));
        for threads in [2usize, 3, 16] {
            let got =
                run_cell_with_threads(&spec, PolicyUnderTest::Greedy(PolicyKind::Greedy), threads);
            assert_eq!(reference.mean.to_bits(), got.mean.to_bits(), "threads={threads}");
        }
    }

    #[test]
    fn cis_spec_generates_cis_params() {
        let spec = ExperimentSpec::section6(100, 1).with_partial_cis().with_false_positives();
        let mut rng = Rng::new(1);
        let inst = spec.gen_instance(&mut rng);
        assert!(inst.pages.iter().any(|p| p.lam > 0.1));
        assert!(inst.pages.iter().all(|p| (0.1..=0.6).contains(&p.nu)));
    }
}
