//! Serving-fairness experiment (no paper counterpart — the request-side
//! extension of the freshness story).
//!
//! The crawl policies optimize freshness *at request time*; this figure
//! asks who actually gets that freshness. Heavy-tailed Zipf user
//! traffic (with a diurnal cycle and one mid-run flash crowd) is served
//! from the freshness cache while each policy crawls, and the
//! staleness-at-request distribution is broken down by CIS-quality
//! decile: decile 0 holds the worst-signalled tenth of the population,
//! decile 9 the best. GREEDY-NCIS's fairness claim is that its noise
//! model keeps the badly-signalled deciles' staleness comparable to the
//! well-signalled ones, where the naive CIS-trusting baseline starves
//! them and the CIS-blind baseline wastes bandwidth everywhere.
//!
//! CSV: `target/figures/fig_serving_fairness.csv`, one row per
//! (policy, quality decile) plus an overall row per policy at
//! `quality_decile = -1`.

use crate::benchkit::FigureOutput;
use crate::coordinator::builder::{CrawlerBuilder, Strategy};
use crate::figures::common::ExperimentSpec;
use crate::policy::PolicyKind;
use crate::rngkit::Rng;
use crate::serving::{RequestTraffic, ServingRepAccumulator, DECILES};
use crate::sim::SimConfig;
use crate::Result;

/// Horizon of the experiment (shorter than §6.3: the sweep runs
/// 3 policies × reps full served simulations).
const HORIZON: f64 = 200.0;
/// Bandwidth R.
const BANDWIDTH: f64 = 50.0;
/// Pages m.
const PAGES: usize = 500;
/// Aggregate base request rate.
const RATE: f64 = 40.0;
/// Zipf popularity exponent (page index = popularity rank).
const ZIPF_S: f64 = 1.1;

/// The serving-fairness figure: per (policy, CIS-quality decile) cell,
/// serve counts, mean staleness-at-request age and its p50/p95/p99,
/// merged across reps. CSV: `target/figures/fig_serving_fairness.csv`.
pub fn fig_serving(reps: usize) -> Result<()> {
    let reps = reps.clamp(1, 10);
    let spec = ExperimentSpec::section6(PAGES, reps).with_partial_cis().with_false_positives();
    let mut rng = Rng::new(spec.seed);
    let inst = spec.gen_instance(&mut rng).normalized();
    let cfg = SimConfig::new(BANDWIDTH, HORIZON)?;

    // numeric policy codes (CSV rows are f64): 0 = GREEDY-NCIS,
    // 1 = GREEDY (CIS-blind), 2 = GREEDY-CIS (naive trusting)
    let policies: [(f64, PolicyKind); 3] = [
        (0.0, PolicyKind::GreedyNcis),
        (1.0, PolicyKind::Greedy),
        (2.0, PolicyKind::GreedyCis),
    ];
    let mut fig = FigureOutput::new(
        "fig_serving_fairness",
        &[
            "policy",
            "quality_decile",
            "served",
            "mean_age",
            "p50",
            "p95",
            "p99",
            "stale_fraction_overall",
        ],
    );
    for (code, policy) in policies {
        let mut acc = ServingRepAccumulator::new();
        for rep in 0..reps {
            // per-rep traffic seed: an independent user-demand
            // realization per repetition, same demand for every policy
            let traffic =
                RequestTraffic::new(RATE, ZIPF_S, spec.seed ^ (0x7AFF * (rep as u64 + 1)))?
                    .with_diurnal(HORIZON / 4.0, 0.5)?
                    .with_flash(HORIZON * 0.3, HORIZON * 0.05, PAGES / 2, 3.0 * RATE)?;
            let builder = CrawlerBuilder::new()
                .policy(policy)
                .strategy(Strategy::Lazy)
                .pages(&inst.pages)
                .with_traffic(traffic);
            let (_res, metrics) = builder.run_traffic(&cfg, spec.seed ^ (0xFEE1 + rep as u64))?;
            acc.push(&metrics);
        }
        let totals = acc.totals();
        let sf = totals.stale_fraction();
        for (d, h) in totals.by_quality.iter().enumerate().take(DECILES) {
            fig.rowf(&[
                code,
                d as f64,
                h.count() as f64,
                h.mean(),
                h.quantile(0.5),
                h.quantile(0.95),
                h.quantile(0.99),
                sf,
            ]);
        }
        let o = &totals.overall;
        fig.rowf(&[
            code,
            -1.0,
            o.count() as f64,
            o.mean(),
            o.quantile(0.5),
            o.quantile(0.95),
            o.quantile(0.99),
            sf,
        ]);
    }
    fig.finish()?;
    Ok(())
}
