//! Figure 1 (CIS quality histograms) and Figure 5 (the §6.7
//! semi-synthetic experiment with corrupted precision/recall).

use crate::benchkit::FigureOutput;
use crate::coordinator::builder::{CrawlerBuilder, Strategy};
use crate::dataset::{self, DatasetConfig};
use crate::params::{Instance, PageParams};
use crate::policy::PolicyKind;
use crate::rngkit::Rng;
use crate::sim::engine::SimConfig;
use crate::sim::metrics::RepAccumulator;
use crate::sim::{generate_traces, simulate_with, CisDelay, SimWorkspace};
use crate::Result;

/// Figure 1: importance-weighted precision/recall histograms of the
/// synthesized sitemap-CIS population.
pub fn fig01(n_urls: usize) -> Result<()> {
    let recs = dataset::generate(&DatasetConfig { n_urls, ..Default::default() });
    let (hp, hr) = dataset::quality_histograms(&recs, 20);
    let mut fig = FigureOutput::new(
        "fig01_cis_quality",
        &["bin_mid", "precision_mass", "recall_mass"],
    );
    for ((mid, &pm), &rm) in hp.midpoints().iter().zip(&hp.mass).zip(&hr.mass) {
        fig.rowf(&[*mid, pm, rm]);
    }
    fig.finish()?;
    Ok(())
}

/// §6.7 protocol parameters (scaled; the paper runs 100k URLs at
/// budget 5000/step — we keep the budget/URL ratio but default to a
/// laptop-sized population; pass `--full` sized inputs via the CLI).
pub struct SemiSynthSpec {
    /// URLs to subsample.
    pub n_urls: usize,
    /// Crawls per time step (paper: 5000 at 100k URLs).
    pub budget: f64,
    /// Time steps (paper: 200).
    pub steps: f64,
    /// Repetitions (paper: 10).
    pub reps: usize,
    /// Seed.
    pub seed: u64,
}

impl Default for SemiSynthSpec {
    fn default() -> Self {
        // budget/URL ratio preserved: 5000/100k = 0.05
        Self { n_urls: 20_000, budget: 1000.0, steps: 200.0, reps: 3, seed: 0xF16 }
    }
}

/// Believed vs true environments: policies compute values from the
/// *corrupted* quality estimates (`believed_pages`) while events are
/// generated from the truth (`true_inst`).
fn run_policy(
    true_inst: &Instance,
    believed_pages: &[PageParams],
    kind: PolicyKind,
    spec: &SemiSynthSpec,
) -> Result<(f64, f64)> {
    let cfg = SimConfig::new(spec.budget, spec.steps)?;
    let mut acc = RepAccumulator::new(true_inst.pages.len());
    let mut ws = SimWorkspace::new();
    // one scheduler reused across reps: on_start resets it (the
    // scheduler_parity suite asserts reuse == fresh construction)
    let mut sched = CrawlerBuilder::new()
        .policy(kind)
        .strategy(Strategy::Lazy)
        .pages(believed_pages)
        .build()?;
    for rep in 0..spec.reps {
        let mut rng = Rng::new(spec.seed ^ (0xABCD + rep as u64));
        let traces = generate_traces(&true_inst.pages, spec.steps, CisDelay::None, &mut rng);
        let res = simulate_with(&mut ws, &traces, &cfg, sched.as_mut());
        acc.push(res.accuracy, &res.empirical_rates(spec.steps));
    }
    let s = acc.accuracy();
    Ok((s.mean, s.stderr))
}

/// Figure 5: GREEDY vs GREEDY-NCIS vs GREEDY-CIS+ on the semi-synthetic
/// population, with quality estimates corrupted at p ∈ {0, 0.1, 0.2}.
pub fn fig05(spec: &SemiSynthSpec) -> Result<()> {
    let population = dataset::generate(&DatasetConfig {
        n_urls: spec.n_urls * 2,
        seed: spec.seed,
        ..Default::default()
    });
    let mut rng = Rng::new(spec.seed ^ 0x5AB);
    let sample = dataset::subsample(&population, spec.n_urls, &mut rng);
    let true_inst = dataset::to_instance(&sample, spec.budget).normalized();
    let mut fig = FigureOutput::new(
        "fig05_semisynthetic",
        &[
            "corruption_p", "GREEDY", "GREEDY-NCIS", "GREEDY-CIS+",
            "GREEDY_se", "GREEDY-NCIS_se", "GREEDY-CIS+_se",
        ],
    );
    for &p in &[0.0, 0.1, 0.2] {
        let mut crng = Rng::new(spec.seed ^ 0xC0 ^ (p * 100.0) as u64);
        let believed_recs = dataset::corrupt(&sample, p, &mut crng);
        let believed_inst = dataset::to_instance(&believed_recs, spec.budget).normalized();
        let (g, g_se) = run_policy(&true_inst, &believed_inst.pages, PolicyKind::Greedy, spec)?;
        let (n, n_se) = run_policy(&true_inst, &believed_inst.pages, PolicyKind::GreedyNcis, spec)?;
        let (c, c_se) =
            run_policy(&true_inst, &believed_inst.pages, PolicyKind::GreedyCisPlus, spec)?;
        fig.rowf(&[p, g, n, c, g_se, n_se, c_se]);
    }
    fig.finish()?;
    Ok(())
}
