//! Dynamic-world accuracy experiment (no paper counterpart — the
//! scenario-engine extension of the Figure-9 adaptivity story).
//!
//! GREEDY-NCIS vs. the change-agnostic baselines under **churn + CIS
//! outage**: a §6.3-style population with partially-observable, noisy
//! CIS runs a world with steady page churn (ρ = 0.5% of pages per unit
//! time) and a full CIS blackout over the middle of the horizon.
//! Rolling accuracy timelines show (a) all policies absorbing churn
//! without re-planning — newborn pages enter the argmax as soon as
//! their hook fires — and (b) the NCIS lift collapsing onto GREEDY
//! while the feed is dark and recovering after it returns, with a
//! static-world GREEDY-NCIS lane quantifying the total dynamics cost.

use crate::benchkit::FigureOutput;
use crate::coordinator::builder::{CrawlerBuilder, Strategy};
use crate::figures::common::ExperimentSpec;
use crate::figures::dynamics::resample;
use crate::policy::PolicyKind;
use crate::rngkit::Rng;
use crate::scenario::generators::{add_steady_churn, BornPageSpec};
use crate::scenario::{PageSet, Scenario, WorldEvent};
use crate::sim::SimConfig;
use crate::Result;

/// Horizon of the experiment.
const HORIZON: f64 = 400.0;
/// Outage window (all pages): the middle quarter of the horizon.
const OUTAGE_START: f64 = 150.0;
const OUTAGE_LEN: f64 = 100.0;
/// Steady churn rate: fraction of the population turning over per unit
/// time.
const CHURN_RHO: f64 = 0.005;

fn mean_timeline(
    builder: &CrawlerBuilder,
    cfg: &SimConfig,
    grid: &[f64],
    reps: usize,
) -> Result<Vec<f64>> {
    let mut acc = vec![0.0f64; grid.len()];
    for rep in 0..reps {
        let res = builder.run_scenario(cfg, 0xD1CE ^ rep as u64)?;
        for (a, v) in acc.iter_mut().zip(resample(&res.timeline, grid)) {
            *a += v;
        }
    }
    Ok(acc.iter().map(|a| a / reps as f64).collect())
}

/// The four-lane body shared by [`fig_scenario`] and
/// [`fig_scenario_world`]: GREEDY-NCIS / GREEDY-CIS / GREEDY under the
/// dynamic world, plus GREEDY-NCIS in the matching static world (same
/// initial population and seed, empty timeline).
fn run_scenario_lanes(
    name: &str,
    dynamic: &Scenario,
    cfg: &SimConfig,
    reps: usize,
) -> Result<()> {
    let reps = reps.clamp(1, 10);
    let static_world = Scenario::new(dynamic.initial_pages().to_vec(), dynamic.seed());
    let grid: Vec<f64> = (1..=cfg.horizon as usize).map(|k| k as f64).collect();

    let lane = |policy: PolicyKind, sc: &Scenario| {
        let b = CrawlerBuilder::new()
            .policy(policy)
            .strategy(Strategy::Exact)
            .with_scenario(sc.clone());
        mean_timeline(&b, cfg, &grid, reps)
    };
    let ncis = lane(PolicyKind::GreedyNcis, dynamic)?;
    let cis = lane(PolicyKind::GreedyCis, dynamic)?;
    let greedy = lane(PolicyKind::Greedy, dynamic)?;
    let ncis_static = lane(PolicyKind::GreedyNcis, &static_world)?;

    let mut fig = FigureOutput::new(
        name,
        &["t", "greedy_ncis", "greedy_cis", "greedy", "greedy_ncis_static"],
    );
    for (k, &t) in grid.iter().enumerate() {
        fig.rowf(&[t, ncis[k], cis[k], greedy[k], ncis_static[k]]);
    }
    fig.finish()?;
    Ok(())
}

/// The churn + outage figure: m = 1000, R = 100, T = 400; rolling
/// accuracy (window 1000 requests) for GREEDY-NCIS / GREEDY-CIS /
/// GREEDY under the dynamic world, plus GREEDY-NCIS in the matching
/// static world. CSV: `target/figures/fig_scenario_churn_outage.csv`.
/// The equivalent DSL world (`tests/corpus/fig_scenario.world`) is
/// pinned bit-identical to this hand-built one in
/// `tests/world_fuzz.rs`.
pub fn fig_scenario(reps: usize) -> Result<()> {
    let spec = ExperimentSpec::section6(1000, 1).with_partial_cis().with_false_positives();
    let mut rng = Rng::new(spec.seed);
    let inst = spec.gen_instance(&mut rng).normalized();

    // the dynamic world: steady churn for the whole run + a total CIS
    // blackout over [150, 250)
    let mut dynamic = Scenario::new(inst.pages.clone(), 0x5CE7);
    add_steady_churn(&mut dynamic, CHURN_RHO, HORIZON, &BornPageSpec::default(), 0x5CE8);
    dynamic.push(
        OUTAGE_START,
        WorldEvent::CisOutage { pages: PageSet::All, duration: OUTAGE_LEN },
    );

    let mut cfg = SimConfig::new(spec.bandwidth, HORIZON)?;
    cfg.timeline_window = Some(1000);
    run_scenario_lanes("fig_scenario_churn_outage", &dynamic, &cfg, reps)
}

/// The same four-lane figure over a DSL-compiled world (`ncis-crawl
/// figure scenario --world FILE`): the dynamic lanes run the compiled
/// timeline; the static lane freezes its initial population. When the
/// world sets no `timeline_window`, the figure's default rolling window
/// of 1000 requests applies. CSV: `target/figures/fig_scenario_world.csv`.
pub fn fig_scenario_world(reps: usize, world: &crate::scenario::CompiledWorld) -> Result<()> {
    let mut cfg = world.sim_config()?;
    if cfg.timeline_window.is_none() {
        cfg.timeline_window = Some(1000);
    }
    run_scenario_lanes("fig_scenario_world", &world.scenario, &cfg, reps)
}
