//! Empirical-rate scatter figures: Fig 7 (GREEDY vs LDS, no CIS),
//! Fig 12/13 (GREEDY vs GREEDY-CIS colored by λ / Δ), Fig 14 (with
//! false positives, incl. GREEDY-NCIS).
//!
//! Output rows carry everything the paper's scatter plots show: the
//! BASELINE optimal rate, each policy's empirical rate, and the page's
//! λ and Δ (the color channels of Figs 12/13). A Pearson-correlation
//! summary per policy quantifies "dots on the diagonal".

use crate::benchkit::FigureOutput;
use crate::figures::common::{run_cell, ExperimentSpec, PolicyUnderTest};
use crate::policy::PolicyKind;
use crate::solver;
use crate::stats::pearson;
use crate::Result;

fn rate_scatter(
    name: &str,
    ms: &[usize],
    spec_of: impl Fn(usize) -> ExperimentSpec,
    kinds: &[PolicyKind],
) -> Result<()> {
    let mut cols = vec!["m", "page", "baseline_rate", "lam", "delta"];
    let kind_names: Vec<String> = kinds.iter().map(|k| k.name()).collect();
    cols.extend(kind_names.iter().map(String::as_str));
    let mut fig = FigureOutput::new(name, &cols);
    let mut summary = FigureOutput::new(&format!("{name}_summary"), &["m", "policy_idx", "pearson_r"]);
    for &m in ms {
        let spec = spec_of(m);
        // baseline rates from the no-CIS continuous optimum on the SAME instance
        let mut rng = crate::rngkit::Rng::new(spec.seed);
        let inst = spec.gen_instance(&mut rng).normalized();
        let baseline = solver::solve_no_cis(&inst)?;
        let mut per_policy_rates: Vec<Vec<f64>> = Vec::new();
        for &kind in kinds {
            let cell = run_cell(&spec, PolicyUnderTest::Greedy(kind));
            per_policy_rates.push(cell.mean_rates);
        }
        for i in 0..inst.pages.len() {
            let mut row = vec![
                m as f64,
                i as f64,
                baseline.rates[i],
                inst.pages[i].lam,
                inst.pages[i].delta,
            ];
            for rates in &per_policy_rates {
                row.push(rates[i]);
            }
            fig.rowf(&row);
        }
        for (k, rates) in per_policy_rates.iter().enumerate() {
            summary.rowf(&[m as f64, k as f64, pearson(&baseline.rates, rates)]);
        }
    }
    fig.finish()?;
    summary.finish()?;
    Ok(())
}

/// Figure 7: empirical rates of GREEDY and LDS vs the optimal rates
/// (no CIS), m ∈ {100, 500}.
pub fn fig07(reps: usize) -> Result<()> {
    // LDS needs its own runner (not a PolicyKind); emit GREEDY via the
    // shared helper and LDS inline.
    let ms = [100usize, 500];
    let mut fig = FigureOutput::new(
        "fig07_rates_no_cis",
        &["m", "page", "baseline_rate", "greedy_rate", "lds_rate"],
    );
    let mut summary =
        FigureOutput::new("fig07_rates_no_cis_summary", &["m", "greedy_r", "lds_r"]);
    for &m in &ms {
        let spec = ExperimentSpec::section6(m, reps);
        let mut rng = crate::rngkit::Rng::new(spec.seed);
        let inst = spec.gen_instance(&mut rng).normalized();
        let baseline = solver::solve_no_cis(&inst)?;
        let g = run_cell(&spec, PolicyUnderTest::Greedy(PolicyKind::Greedy));
        let l = run_cell(&spec, PolicyUnderTest::Lds);
        for i in 0..m {
            fig.rowf(&[
                m as f64,
                i as f64,
                baseline.rates[i],
                g.mean_rates[i],
                l.mean_rates[i],
            ]);
        }
        summary.rowf(&[
            m as f64,
            pearson(&baseline.rates, &g.mean_rates),
            pearson(&baseline.rates, &l.mean_rates),
        ]);
    }
    fig.finish()?;
    summary.finish()?;
    Ok(())
}

/// Figures 12/13: rates of GREEDY vs GREEDY-CIS under partial
/// observability (no false positives); λ and Δ columns are the two
/// color channels of the paper's plots.
pub fn fig12_13(reps: usize) -> Result<()> {
    rate_scatter(
        "fig12_13_rates_cis",
        &[100, 300],
        |m| ExperimentSpec::section6(m, reps).with_partial_cis(),
        &[PolicyKind::Greedy, PolicyKind::GreedyCis],
    )
}

/// Figure 14: rates with false positives present — GREEDY-CIS overdrives
/// pages with many false signals; GREEDY-NCIS does not.
pub fn fig14(reps: usize) -> Result<()> {
    rate_scatter(
        "fig14_rates_false_positives",
        &[100, 300],
        |m| ExperimentSpec::section6(m, reps).with_partial_cis().with_false_positives(),
        &[PolicyKind::Greedy, PolicyKind::GreedyCis, PolicyKind::GreedyNcis],
    )
}
