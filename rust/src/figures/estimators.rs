//! Figures 10/11 (Appendix E): bias of the naive vs MLE estimators of
//! CIS precision/recall.
//!
//! Protocol (per the appendix): precision, recall ~ U[0.2, 0.95];
//! expected change interval ~ U[2, 20] (Δ = 1/len); crawl rate between
//! 4× and ¼× of the change rate; horizon 100 000.

use crate::benchkit::FigureOutput;
use crate::estimation::{
    generate_observations, mle_precision_recall, naive_precision_recall,
};
use crate::params::PageParams;
use crate::rngkit::Rng;
use crate::stats::summarize;
use crate::Result;

struct BiasSample {
    true_prec: f64,
    true_rec: f64,
    est_prec: f64,
    est_rec: f64,
}

fn run_estimator(
    samples: usize,
    horizon: f64,
    seed: u64,
    estimator: impl Fn(&[crate::estimation::Observation]) -> (f64, f64),
) -> Vec<BiasSample> {
    let mut rng = Rng::new(seed);
    (0..samples)
        .map(|_| {
            let true_prec = rng.range(0.2, 0.95);
            let true_rec = rng.range(0.2, 0.95);
            let delta = 1.0 / rng.range(2.0, 20.0);
            let ratio = 4f64.powf(rng.range(-1.0, 1.0)); // ¼× .. 4×
            let page = PageParams::from_quality(delta, 0.1, true_prec, true_rec);
            let obs = generate_observations(&page, ratio * delta, horizon, &mut rng);
            let (p, r) = estimator(&obs);
            BiasSample { true_prec, true_rec, est_prec: p, est_rec: r }
        })
        .collect()
}

fn write_bias_figure(name: &str, samples: &[BiasSample]) -> Result<()> {
    let mut fig = FigureOutput::new(
        name,
        &["true_precision", "est_precision", "true_recall", "est_recall"],
    );
    for s in samples {
        fig.rowf(&[s.true_prec, s.est_prec, s.true_rec, s.est_rec]);
    }
    fig.finish()?;
    let prec_bias: Vec<f64> =
        samples.iter().filter(|s| s.est_prec.is_finite()).map(|s| s.est_prec - s.true_prec).collect();
    let rec_bias: Vec<f64> =
        samples.iter().filter(|s| s.est_rec.is_finite()).map(|s| s.est_rec - s.true_rec).collect();
    let (p, r) = (summarize(&prec_bias), summarize(&rec_bias));
    let mut sfig = FigureOutput::new(&format!("{name}_summary"), &["field_prec0_rec1", "mean_bias", "stderr"]);
    sfig.rowf(&[0.0, p.mean, p.stderr]);
    sfig.rowf(&[1.0, r.mean, r.stderr]);
    sfig.finish()?;
    Ok(())
}

/// Figure 10: the naive interval-counting estimator is visibly biased.
pub fn fig10(samples: usize) -> Result<()> {
    let s = run_estimator(samples.max(20), 100_000.0, 0xE57, naive_precision_recall);
    write_bias_figure("fig10_naive_estimator", &s)
}

/// Figure 11: the MLE estimator's bias is orders of magnitude smaller.
pub fn fig11(samples: usize) -> Result<()> {
    let s = run_estimator(samples.max(20), 100_000.0, 0xE58, |obs| {
        mle_precision_recall(obs, 60)
    });
    write_bias_figure("fig11_mle_estimator", &s)
}
