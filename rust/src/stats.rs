//! Small statistics toolkit: running moments, standard errors over
//! experiment repetitions, and (weighted) histograms for the Figure-1
//! style CIS-quality plots.

/// Mean / stderr summary of a sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    /// Sample size.
    pub n: usize,
    /// Sample mean.
    pub mean: f64,
    /// Standard error of the mean (0 for n < 2).
    pub stderr: f64,
}

/// Summarize a slice of repetition results.
pub fn summarize(xs: &[f64]) -> Summary {
    let n = xs.len();
    if n == 0 {
        return Summary { n: 0, mean: f64::NAN, stderr: f64::NAN };
    }
    let mean = xs.iter().sum::<f64>() / n as f64;
    if n < 2 {
        return Summary { n, mean, stderr: 0.0 };
    }
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n as f64 - 1.0);
    Summary { n, mean, stderr: (var / n as f64).sqrt() }
}

/// Weighted histogram over `[lo, hi]` with `bins` equal-width buckets,
/// normalized to total weight 1 (the paper's importance-weighted
/// precision/recall histograms of Figure 1).
#[derive(Debug, Clone)]
pub struct Histogram {
    /// Inclusive lower bound of the support.
    pub lo: f64,
    /// Inclusive upper bound of the support.
    pub hi: f64,
    /// Normalized bucket masses.
    pub mass: Vec<f64>,
}

impl Histogram {
    /// Build from (value, weight) pairs; out-of-range values clamp to the
    /// boundary buckets.
    pub fn weighted(values: &[f64], weights: &[f64], lo: f64, hi: f64, bins: usize) -> Self {
        assert_eq!(values.len(), weights.len());
        assert!(bins > 0 && hi > lo);
        let mut mass = vec![0.0; bins];
        let mut total = 0.0;
        for (&v, &w) in values.iter().zip(weights) {
            let frac = ((v - lo) / (hi - lo)).clamp(0.0, 1.0);
            let b = ((frac * bins as f64) as usize).min(bins - 1);
            mass[b] += w;
            total += w;
        }
        if total > 0.0 {
            for m in &mut mass {
                *m /= total;
            }
        }
        Self { lo, hi, mass }
    }

    /// Bucket midpoints.
    pub fn midpoints(&self) -> Vec<f64> {
        let bins = self.mass.len();
        let width = (self.hi - self.lo) / bins as f64;
        (0..bins).map(|b| self.lo + (b as f64 + 0.5) * width).collect()
    }

    /// Weighted quantile (inverse CDF over bucket masses).
    pub fn quantile(&self, q: f64) -> f64 {
        let q = q.clamp(0.0, 1.0);
        let mut acc = 0.0;
        let bins = self.mass.len();
        let width = (self.hi - self.lo) / bins as f64;
        for (b, &m) in self.mass.iter().enumerate() {
            if acc + m >= q {
                let frac = if m > 0.0 { (q - acc) / m } else { 0.5 };
                return self.lo + (b as f64 + frac) * width;
            }
            acc += m;
        }
        self.hi
    }
}

/// Pearson correlation (used to compare empirical vs optimal rates in the
/// Figure 7/12/13/14 scatter summaries).
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    if n < 2.0 {
        return f64::NAN;
    }
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (&x, &y) in xs.iter().zip(ys) {
        sxy += (x - mx) * (y - my);
        sxx += (x - mx) * (x - mx);
        syy += (y - my) * (y - my);
    }
    if sxx <= 0.0 || syy <= 0.0 {
        return f64::NAN;
    }
    sxy / (sxx * syy).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        // sample var = 5/3, stderr = sqrt(5/12)
        assert!((s.stderr - (5.0f64 / 12.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_degenerate() {
        assert!(summarize(&[]).mean.is_nan());
        let s = summarize(&[7.0]);
        assert_eq!(s.mean, 7.0);
        assert_eq!(s.stderr, 0.0);
    }

    #[test]
    fn histogram_masses_sum_to_one() {
        let v = [0.1, 0.5, 0.9, 0.9];
        let w = [1.0, 2.0, 3.0, 4.0];
        let h = Histogram::weighted(&v, &w, 0.0, 1.0, 10);
        assert!((h.mass.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!((h.mass[9] - 0.7).abs() < 1e-12);
    }

    #[test]
    fn histogram_quantile_monotone() {
        let v: Vec<f64> = (0..100).map(|i| i as f64 / 100.0).collect();
        let w = vec![1.0; 100];
        let h = Histogram::weighted(&v, &w, 0.0, 1.0, 20);
        let q25 = h.quantile(0.25);
        let q75 = h.quantile(0.75);
        assert!(q25 < q75);
        assert!((q25 - 0.25).abs() < 0.06);
        assert!((q75 - 0.75).abs() < 0.06);
    }

    #[test]
    fn pearson_perfect_correlation() {
        let x = [1.0, 2.0, 3.0];
        let y = [2.0, 4.0, 6.0];
        assert!((pearson(&x, &y) - 1.0).abs() < 1e-12);
        let yneg = [-2.0, -4.0, -6.0];
        assert!((pearson(&x, &yneg) + 1.0).abs() < 1e-12);
    }
}
