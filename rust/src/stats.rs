//! Small statistics toolkit: running moments, standard errors over
//! experiment repetitions, (weighted) histograms for the Figure-1
//! style CIS-quality plots, the shared bucket-mass quantile scan, and
//! the finite-support [`Zipf`] sampler behind heavy-tailed host sizes
//! and request popularity.

use crate::rngkit::RandomSource;

/// Mean / stderr summary of a sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    /// Sample size.
    pub n: usize,
    /// Sample mean.
    pub mean: f64,
    /// Standard error of the mean (0 for n < 2).
    pub stderr: f64,
}

/// Summarize a slice of repetition results.
pub fn summarize(xs: &[f64]) -> Summary {
    let n = xs.len();
    if n == 0 {
        return Summary { n: 0, mean: f64::NAN, stderr: f64::NAN };
    }
    let mean = xs.iter().sum::<f64>() / n as f64;
    if n < 2 {
        return Summary { n, mean, stderr: 0.0 };
    }
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n as f64 - 1.0);
    Summary { n, mean, stderr: (var / n as f64).sqrt() }
}

/// Weighted histogram over `[lo, hi]` with `bins` equal-width buckets,
/// normalized to total weight 1 (the paper's importance-weighted
/// precision/recall histograms of Figure 1).
#[derive(Debug, Clone)]
pub struct Histogram {
    /// Inclusive lower bound of the support.
    pub lo: f64,
    /// Inclusive upper bound of the support.
    pub hi: f64,
    /// Normalized bucket masses.
    pub mass: Vec<f64>,
}

impl Histogram {
    /// Build from (value, weight) pairs; out-of-range values clamp to the
    /// boundary buckets.
    pub fn weighted(values: &[f64], weights: &[f64], lo: f64, hi: f64, bins: usize) -> Self {
        assert_eq!(values.len(), weights.len());
        assert!(bins > 0 && hi > lo);
        let mut mass = vec![0.0; bins];
        let mut total = 0.0;
        for (&v, &w) in values.iter().zip(weights) {
            let frac = ((v - lo) / (hi - lo)).clamp(0.0, 1.0);
            let b = ((frac * bins as f64) as usize).min(bins - 1);
            mass[b] += w;
            total += w;
        }
        if total > 0.0 {
            for m in &mut mass {
                *m /= total;
            }
        }
        Self { lo, hi, mass }
    }

    /// Bucket midpoints.
    pub fn midpoints(&self) -> Vec<f64> {
        let bins = self.mass.len();
        let width = (self.hi - self.lo) / bins as f64;
        (0..bins).map(|b| self.lo + (b as f64 + 0.5) * width).collect()
    }

    /// Weighted quantile (inverse CDF over bucket masses).
    pub fn quantile(&self, q: f64) -> f64 {
        let q = q.clamp(0.0, 1.0);
        let bins = self.mass.len();
        let width = (self.hi - self.lo) / bins as f64;
        match cum_mass_bucket(self.mass.iter().copied(), q) {
            Some((b, frac)) => self.lo + (b as f64 + frac) * width,
            None => self.hi,
        }
    }
}

/// The shared inverse-CDF bucket scan behind every log/linear-bucket
/// quantile in the crate ([`Histogram::quantile`],
/// `metrics::DurationHisto::quantile_s`, the serving staleness
/// percentiles): walk the bucket masses until the cumulative mass
/// reaches `target` and return `(bucket, within-bucket fraction)` — or
/// `None` when the total mass never reaches the target (the caller
/// supplies its own upper-edge fallback). An empty bucket that closes
/// the gap reports the midpoint fraction `0.5`. Callers choosing a
/// conservative upper-edge convention simply ignore the fraction.
pub fn cum_mass_bucket(masses: impl IntoIterator<Item = f64>, target: f64) -> Option<(usize, f64)> {
    let mut acc = 0.0;
    for (b, m) in masses.into_iter().enumerate() {
        if acc + m >= target {
            let frac = if m > 0.0 { (target - acc) / m } else { 0.5 };
            return Some((b, frac));
        }
        acc += m;
    }
    None
}

/// Exact inverse-CDF sampler over the finite Zipf distribution
/// `P[k] ∝ (k+1)^{-s}` for `k ∈ 0..n`. Promoted from the ad-hoc
/// harmonic weights of `coordinator::hosts::zipf_host_sizes` (its
/// `s = 1` case) so host sizes and per-page request popularity draw
/// from one audited implementation. The unnormalized cumulative table
/// makes every draw one uniform + one binary search — no rejection, no
/// approximation — and sampling is deterministic given the caller's
/// seedable [`RandomSource`].
#[derive(Debug, Clone)]
pub struct Zipf {
    /// Unnormalized cumulative weights: `cdf[k] = Σ_{j≤k} (j+1)^{-s}`.
    cdf: Vec<f64>,
    /// Total unnormalized mass (last entry of `cdf`).
    total: f64,
}

impl Zipf {
    /// Zipf over ranks `0..n` with exponent `s ≥ 0` (`s = 0` is
    /// uniform). Panics on `n == 0` or a non-finite/negative `s` —
    /// both are construction-site bugs, not runtime conditions.
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0, "Zipf support must be non-empty");
        assert!(s.is_finite() && s >= 0.0, "Zipf exponent must be finite and >= 0, got {s}");
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 0..n {
            acc += ((k + 1) as f64).powf(-s);
            cdf.push(acc);
        }
        Self { cdf, total: acc }
    }

    /// Support size n.
    pub fn len(&self) -> usize {
        self.cdf.len()
    }

    /// Is the support empty (never true by construction)?
    pub fn is_empty(&self) -> bool {
        self.cdf.is_empty()
    }

    /// Probability mass of rank `k`.
    pub fn pmf(&self, k: usize) -> f64 {
        let lo = if k == 0 { 0.0 } else { self.cdf[k - 1] };
        (self.cdf[k] - lo) / self.total
    }

    /// Unnormalized weight of rank `k` (the raw `(k+1)^{-s}` mass —
    /// what `zipf_host_sizes` apportions before integer juggling).
    pub fn weight(&self, k: usize) -> f64 {
        let lo = if k == 0 { 0.0 } else { self.cdf[k - 1] };
        self.cdf[k] - lo
    }

    /// Draw one rank by exact inversion: `u ~ U[0, total)`, then the
    /// first bucket whose cumulative weight exceeds `u`. `rng.f64()`
    /// is in `[0, 1)`, so `u < total` and the partition point is
    /// always a valid rank.
    pub fn sample<R: RandomSource>(&self, rng: &mut R) -> usize {
        let u = rng.f64() * self.total;
        self.cdf.partition_point(|&c| c <= u).min(self.cdf.len() - 1)
    }
}

/// Pearson correlation (used to compare empirical vs optimal rates in the
/// Figure 7/12/13/14 scatter summaries).
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    if n < 2.0 {
        return f64::NAN;
    }
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (&x, &y) in xs.iter().zip(ys) {
        sxy += (x - mx) * (y - my);
        sxx += (x - mx) * (x - mx);
        syy += (y - my) * (y - my);
    }
    if sxx <= 0.0 || syy <= 0.0 {
        return f64::NAN;
    }
    sxy / (sxx * syy).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        // sample var = 5/3, stderr = sqrt(5/12)
        assert!((s.stderr - (5.0f64 / 12.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_degenerate() {
        assert!(summarize(&[]).mean.is_nan());
        let s = summarize(&[7.0]);
        assert_eq!(s.mean, 7.0);
        assert_eq!(s.stderr, 0.0);
    }

    #[test]
    fn histogram_masses_sum_to_one() {
        let v = [0.1, 0.5, 0.9, 0.9];
        let w = [1.0, 2.0, 3.0, 4.0];
        let h = Histogram::weighted(&v, &w, 0.0, 1.0, 10);
        assert!((h.mass.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!((h.mass[9] - 0.7).abs() < 1e-12);
    }

    #[test]
    fn histogram_quantile_monotone() {
        let v: Vec<f64> = (0..100).map(|i| i as f64 / 100.0).collect();
        let w = vec![1.0; 100];
        let h = Histogram::weighted(&v, &w, 0.0, 1.0, 20);
        let q25 = h.quantile(0.25);
        let q75 = h.quantile(0.75);
        assert!(q25 < q75);
        assert!((q25 - 0.25).abs() < 0.06);
        assert!((q75 - 0.75).abs() < 0.06);
    }

    #[test]
    fn pearson_perfect_correlation() {
        let x = [1.0, 2.0, 3.0];
        let y = [2.0, 4.0, 6.0];
        assert!((pearson(&x, &y) - 1.0).abs() < 1e-12);
        let yneg = [-2.0, -4.0, -6.0];
        assert!((pearson(&x, &yneg) + 1.0).abs() < 1e-12);
    }

    // ---- the shared bucket-mass quantile scan ----

    #[test]
    fn cum_mass_bucket_is_monotone_in_target() {
        let masses = [0.0, 0.3, 0.0, 0.5, 0.2];
        let mut prev = (0usize, 0.0f64);
        for step in 0..=20 {
            let q = step as f64 / 20.0;
            let (b, frac) = cum_mass_bucket(masses.iter().copied(), q)
                .unwrap_or((masses.len(), 0.0));
            let pos = b as f64 + frac;
            let prev_pos = prev.0 as f64 + prev.1;
            assert!(pos >= prev_pos - 1e-12, "q={q}: {pos} < {prev_pos}");
            prev = (b, frac);
        }
    }

    #[test]
    fn cum_mass_bucket_edge_buckets() {
        // target 0 lands in the first bucket even when it is empty
        assert_eq!(cum_mass_bucket([0.0, 1.0], 0.0), Some((0, 0.5)));
        // all mass in the last bucket: everything above 0 resolves there
        let (b, _) = cum_mass_bucket([0.0, 0.0, 1.0], 0.7).unwrap();
        assert_eq!(b, 2);
        // unreachable target: None, caller supplies the upper edge
        assert_eq!(cum_mass_bucket([0.2, 0.2], 0.9), None);
        // exact total is reachable (>= comparison, matching the
        // pre-dedupe scans in Histogram::quantile and quantile_s)
        assert_eq!(cum_mass_bucket([0.5, 0.5], 1.0).map(|(b, _)| b), Some(1));
    }

    // ---- the Zipf sampler ----

    #[test]
    fn zipf_pmf_sums_to_one_and_is_monotone() {
        for s in [0.0, 0.5, 1.0, 2.0] {
            let z = Zipf::new(50, s);
            let total: f64 = (0..z.len()).map(|k| z.pmf(k)).sum();
            assert!((total - 1.0).abs() < 1e-12, "s={s}: {total}");
            for k in 1..z.len() {
                assert!(z.pmf(k) <= z.pmf(k - 1) + 1e-15, "s={s}: pmf not monotone at {k}");
            }
        }
    }

    #[test]
    fn zipf_s1_matches_harmonic_weights() {
        // s = 1 reproduces the 1/(k+1) weights zipf_host_sizes used
        let z = Zipf::new(20, 1.0);
        for k in 0..20 {
            assert!((z.weight(k) - 1.0 / (k as f64 + 1.0)).abs() < 1e-12);
        }
    }

    #[test]
    fn zipf_marginals_match_pmf() {
        use crate::rngkit::Rng;
        let n = 16;
        let z = Zipf::new(n, 1.2);
        let draws = 200_000usize;
        let mut counts = vec![0usize; n];
        let mut rng = Rng::new(0xD1CE);
        for _ in 0..draws {
            counts[z.sample(&mut rng)] += 1;
        }
        for k in 0..n {
            let emp = counts[k] as f64 / draws as f64;
            let p = z.pmf(k);
            // 5-sigma binomial band, floored for tiny tail cells
            let tol = 5.0 * (p * (1.0 - p) / draws as f64).sqrt() + 1e-4;
            assert!((emp - p).abs() < tol, "rank {k}: emp {emp} vs pmf {p}");
        }
    }

    #[test]
    fn zipf_s0_is_uniform_and_sampling_is_deterministic() {
        use crate::rngkit::Rng;
        let z = Zipf::new(8, 0.0);
        for k in 0..8 {
            assert!((z.pmf(k) - 0.125).abs() < 1e-12);
        }
        let draw = |seed: u64| -> Vec<usize> {
            let mut rng = Rng::new(seed);
            (0..64).map(|_| z.sample(&mut rng)).collect()
        };
        assert_eq!(draw(7), draw(7));
        assert_ne!(draw(7), draw(8));
    }
}
