//! Low-discrepancy discrete scheduler (Azar et al. [1], Algorithm 3).
//!
//! Turns a continuous solution with per-page rates `ξ_i` (Σξ_i = R) into a
//! discrete schedule with one crawl per tick `t_j = j/R`, such that every
//! page's empirical rate tracks its target rate with discrepancy O(1):
//! page `i`'s k-th crawl is placed as close as possible to its ideal time
//! `(k + 1/2)/ξ_i`, by always serving the page whose next ideal time is
//! earliest (an EDF realization of the low-discrepancy sequence).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

#[derive(Debug, Clone, Copy)]
struct Due {
    ideal: f64,
    page: usize,
}

impl PartialEq for Due {
    fn eq(&self, other: &Self) -> bool {
        self.ideal == other.ideal && self.page == other.page
    }
}
impl Eq for Due {}
impl PartialOrd for Due {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Due {
    fn cmp(&self, other: &Self) -> Ordering {
        // min-heap on ideal time (BinaryHeap is a max-heap), tie-break on id
        other
            .ideal
            .partial_cmp(&self.ideal)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.page.cmp(&self.page))
    }
}

/// Low-discrepancy scheduler state.
#[derive(Debug)]
pub struct LdsScheduler {
    heap: BinaryHeap<Due>,
    period: Vec<f64>,
}

impl LdsScheduler {
    /// Build from per-page target rates; pages with rate ≤ `min_rate`
    /// never enter the schedule (the solver's "abandoned" pages).
    pub fn new(rates: &[f64]) -> Self {
        let mut heap = BinaryHeap::with_capacity(rates.len());
        let mut period = vec![f64::INFINITY; rates.len()];
        for (i, &xi) in rates.iter().enumerate() {
            if xi > 0.0 && xi.is_finite() {
                period[i] = 1.0 / xi;
                heap.push(Due { ideal: 0.5 / xi, page: i });
            }
        }
        Self { heap, period }
    }

    /// Page to crawl at the next tick.
    pub fn next(&mut self) -> Option<usize> {
        let due = self.heap.pop()?;
        let page = due.page;
        self.heap.push(Due { ideal: due.ideal + self.period[page], page });
        Some(page)
    }

    /// Generate the first `n` scheduled pages.
    pub fn schedule(&mut self, n: usize) -> Vec<usize> {
        (0..n).filter_map(|_| self.next()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empirical_rates_track_targets() {
        // rates summing to R=1; after N ticks page i should have
        // ~ rate_i * N / R crawls, within O(1) discrepancy.
        let rates = [0.5, 0.25, 0.125, 0.125];
        let mut lds = LdsScheduler::new(&rates);
        let n = 4000;
        let sched = lds.schedule(n);
        let mut counts = [0usize; 4];
        for &p in &sched {
            counts[p] += 1;
        }
        let total: f64 = rates.iter().sum();
        for (i, &c) in counts.iter().enumerate() {
            let want = rates[i] / total * n as f64;
            assert!(
                (c as f64 - want).abs() <= 2.0,
                "page {i}: {c} vs {want}"
            );
        }
    }

    #[test]
    fn discrepancy_bound_along_prefixes() {
        let rates = [0.6, 0.3, 0.1];
        let mut lds = LdsScheduler::new(&rates);
        let sched = lds.schedule(5000);
        let total: f64 = rates.iter().sum();
        let mut counts = [0f64; 3];
        for (j, &p) in sched.iter().enumerate() {
            counts[p] += 1.0;
            for i in 0..3 {
                let want = rates[i] / total * (j + 1) as f64;
                assert!(
                    (counts[i] - want).abs() <= 2.0,
                    "prefix {j}: page {i} count {} want {want}",
                    counts[i]
                );
            }
        }
    }

    #[test]
    fn crawl_spacing_is_near_period() {
        let rates = [0.9, 0.1];
        let mut lds = LdsScheduler::new(&rates);
        let sched = lds.schedule(1000);
        // page 1 has period 10 ticks; its occurrences should be spaced 8..12
        let pos: Vec<usize> = sched
            .iter()
            .enumerate()
            .filter(|(_, &p)| p == 1)
            .map(|(j, _)| j)
            .collect();
        for w in pos.windows(2) {
            let gap = w[1] - w[0];
            assert!((8..=12).contains(&gap), "gap {gap}");
        }
    }

    #[test]
    fn zero_rate_pages_never_scheduled() {
        let rates = [1.0, 0.0, f64::INFINITY.recip()]; // third is 0 too
        let mut lds = LdsScheduler::new(&rates);
        let sched = lds.schedule(100);
        assert!(sched.iter().all(|&p| p == 0));
    }

    #[test]
    fn empty_rates_yield_nothing() {
        let mut lds = LdsScheduler::new(&[]);
        assert!(lds.next().is_none());
    }
}
