//! Page parametrization: raw `(Δ, μ̃, λ, ν)` → derived `(α, β, γ)`.
//!
//! Mirrors `python/compile/kernels/ref.py::derived_params` exactly (same
//! clamps), so the rust-native f64 value function, the Pallas kernel and
//! the golden vectors all see the same environment.

use crate::error::{Error, Result};

/// Raw per-page model parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PageParams {
    /// Change-process rate Δ.
    pub delta: f64,
    /// Normalized importance μ̃ (request-rate weight).
    pub mu: f64,
    /// CIS recall λ ∈ [0, 1]: probability a change emits a signal.
    pub lam: f64,
    /// False-positive CIS rate ν ≥ 0.
    pub nu: f64,
}

impl PageParams {
    /// Validate and derive the `(α, β, γ)` parametrization.
    pub fn derive(&self) -> Result<DerivedParams> {
        self.validate()?;
        Ok(DerivedParams::from_raw(self))
    }

    /// Raw-parameter sanity checks.
    pub fn validate(&self) -> Result<()> {
        if !(self.delta > 0.0) || !self.delta.is_finite() {
            return Err(Error::InvalidParam(format!("delta must be > 0, got {}", self.delta)));
        }
        if !(0.0..=1.0).contains(&self.lam) {
            return Err(Error::InvalidParam(format!("lam must be in [0,1], got {}", self.lam)));
        }
        if self.nu < 0.0 || !self.nu.is_finite() {
            return Err(Error::InvalidParam(format!("nu must be >= 0, got {}", self.nu)));
        }
        if self.mu < 0.0 || !self.mu.is_finite() {
            return Err(Error::InvalidParam(format!("mu must be >= 0, got {}", self.mu)));
        }
        Ok(())
    }

    /// CIS precision `λΔ/γ` (1 if the page has no CIS at all).
    pub fn precision(&self) -> f64 {
        let gamma = self.lam * self.delta + self.nu;
        if gamma <= 0.0 {
            1.0
        } else {
            self.lam * self.delta / gamma
        }
    }

    /// CIS recall (= λ by definition).
    pub fn recall(&self) -> f64 {
        self.lam
    }

    /// Construct raw parameters from a (precision, recall) description of
    /// the page's CIS quality — the encoding used by the semi-synthetic
    /// dataset (§6.7): `λ = recall`, `ν = λΔ(1−prec)/prec`.
    pub fn from_quality(delta: f64, mu: f64, precision: f64, recall: f64) -> Self {
        let lam = recall.clamp(0.0, 1.0);
        let nu = if precision >= 1.0 || lam == 0.0 {
            // perfect precision (or no true signals): no false positives
            0.0
        } else {
            let p = precision.max(1e-3);
            lam * delta * (1.0 - p) / p
        };
        Self { delta, mu, lam, nu }
    }
}

/// Derived parametrization used by every value function.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DerivedParams {
    /// Unsignalled change rate α = (1−λ)Δ (clamped ≥ 1e-6·Δ).
    pub alpha: f64,
    /// Time-equivalent of one CIS, β = −log(ν/γ)/α (∞ when ν = 0).
    pub beta: f64,
    /// Observed CIS rate γ = λΔ + ν (0 means "no CIS at all").
    pub gamma: f64,
    /// False-positive rate ν.
    pub nu: f64,
    /// Change rate Δ.
    pub delta: f64,
    /// Normalized importance μ̃.
    pub mu: f64,
}

impl DerivedParams {
    /// Mirror of `ref.derived_params` (keep in sync with the oracle!).
    pub fn from_raw(p: &PageParams) -> Self {
        let gamma = p.lam * p.delta + p.nu;
        let alpha = ((1.0 - p.lam) * p.delta).max(1e-6 * p.delta.max(1e-30));
        // note the `.max(0.0)`: λ = 0 gives ν/γ = 1, ln = 0, and the
        // division produces β = −0.0 — which must be +0.0 so that
        // ι/β = +∞ (signals are worthless, every term stays active)
        let beta = if gamma > 0.0 && p.nu > 0.0 {
            (-(p.nu / gamma).max(1e-38).ln() / alpha).max(0.0)
        } else {
            f64::INFINITY
        };
        Self { alpha, beta, gamma, nu: p.nu, delta: p.delta, mu: p.mu }
    }

    /// β capped to the finite sentinel the f32 PJRT kernel expects.
    pub fn beta_capped(&self) -> f64 {
        self.beta.min(crate::runtime::BETA_CAP)
    }

    /// `log(ν/γ)` (≤ 0), the per-CIS freshness log-penalty; 0 when the
    /// page has no CIS process.
    pub fn log_fp_ratio(&self) -> f64 {
        if self.gamma > 0.0 && self.nu > 0.0 {
            (self.nu / self.gamma).ln()
        } else if self.gamma > 0.0 {
            // noiseless CIS: a signal certainly means a change
            f64::NEG_INFINITY
        } else {
            0.0
        }
    }

    /// Effective elapsed time τ_EFF = τ_ELAP + β·n_CIS (∞-safe).
    ///
    /// An environment with γ = 0 models "no CIS process at all" (the
    /// GREEDY belief): any observed signals are ignored rather than
    /// treated as β = ∞ saturation.
    pub fn effective_time(&self, tau_elap: f64, n_cis: u32) -> f64 {
        if n_cis == 0 || self.gamma <= 0.0 {
            tau_elap
        } else if self.beta.is_finite() {
            tau_elap + self.beta * n_cis as f64
        } else {
            f64::INFINITY
        }
    }

    /// P[page fresh | history] (eq. 1).
    pub fn freshness(&self, tau_elap: f64, n_cis: u32) -> f64 {
        let log_pen = self.log_fp_ratio();
        if n_cis > 0 && log_pen == f64::NEG_INFINITY {
            return 0.0;
        }
        (-self.alpha * tau_elap + n_cis as f64 * log_pen).exp()
    }
}

/// Struct-of-arrays storage for derived parameters: one flat `f64`
/// column per field, so batched evaluations stream six cache-friendly
/// columns instead of pointer-hopping a `Vec<DerivedParams>` of
/// interleaved structs. `get(i)` reconstructs the exact `DerivedParams`
/// that was pushed (fields are stored verbatim), so any scalar value
/// function evaluated on `get(i)` is bit-identical to one evaluated on
/// the original struct — the property the columnar-parity suite pins.
#[derive(Debug, Clone, Default)]
pub struct ParamColumns {
    /// Unsignalled change rates α.
    pub alpha: Vec<f64>,
    /// CIS time-equivalents β.
    pub beta: Vec<f64>,
    /// Observed CIS rates γ.
    pub gamma: Vec<f64>,
    /// False-positive rates ν.
    pub nu: Vec<f64>,
    /// Change rates Δ.
    pub delta: Vec<f64>,
    /// Normalized importance weights μ̃.
    pub mu: Vec<f64>,
}

impl ParamColumns {
    /// Empty columns with capacity for `n` pages.
    pub fn with_capacity(n: usize) -> Self {
        Self {
            alpha: Vec::with_capacity(n),
            beta: Vec::with_capacity(n),
            gamma: Vec::with_capacity(n),
            nu: Vec::with_capacity(n),
            delta: Vec::with_capacity(n),
            mu: Vec::with_capacity(n),
        }
    }

    /// Columnarize a slice of derived parameters.
    pub fn from_derived(envs: &[DerivedParams]) -> Self {
        let mut cols = Self::with_capacity(envs.len());
        for d in envs {
            cols.push(d);
        }
        cols
    }

    /// Number of pages.
    pub fn len(&self) -> usize {
        self.alpha.len()
    }

    /// Are the columns empty?
    pub fn is_empty(&self) -> bool {
        self.alpha.is_empty()
    }

    /// Append one page's parameters.
    pub fn push(&mut self, d: &DerivedParams) {
        self.alpha.push(d.alpha);
        self.beta.push(d.beta);
        self.gamma.push(d.gamma);
        self.nu.push(d.nu);
        self.delta.push(d.delta);
        self.mu.push(d.mu);
    }

    /// Overwrite page `i`'s parameters in place (the dynamic-world
    /// mutation path: parameter drift re-projects a page's columns
    /// without disturbing its neighbours or the column capacity).
    #[inline]
    pub fn set(&mut self, i: usize, d: &DerivedParams) {
        self.alpha[i] = d.alpha;
        self.beta[i] = d.beta;
        self.gamma[i] = d.gamma;
        self.nu[i] = d.nu;
        self.delta[i] = d.delta;
        self.mu[i] = d.mu;
    }

    /// Reconstruct page `i`'s parameters (bit-identical to the push).
    #[inline]
    pub fn get(&self, i: usize) -> DerivedParams {
        DerivedParams {
            alpha: self.alpha[i],
            beta: self.beta[i],
            gamma: self.gamma[i],
            nu: self.nu[i],
            delta: self.delta[i],
            mu: self.mu[i],
        }
    }

    /// Clear all columns (capacity preserved).
    pub fn clear(&mut self) {
        self.alpha.clear();
        self.beta.clear();
        self.gamma.clear();
        self.nu.clear();
        self.delta.clear();
        self.mu.clear();
    }
}

/// A full problem instance: one entry per page plus the global bandwidth.
#[derive(Debug, Clone)]
pub struct Instance {
    /// Raw page parameters (importance *not* yet normalized).
    pub pages: Vec<PageParams>,
    /// Global crawl bandwidth R (crawls per unit time).
    pub bandwidth: f64,
}

impl Instance {
    /// Sum of raw importance weights.
    pub fn total_mu(&self) -> f64 {
        self.pages.iter().map(|p| p.mu).sum()
    }

    /// Instance with importance normalized to μ̃_i = μ_i / Σμ.
    pub fn normalized(&self) -> Instance {
        let total = self.total_mu();
        let pages = self
            .pages
            .iter()
            .map(|p| PageParams { mu: if total > 0.0 { p.mu / total } else { 0.0 }, ..*p })
            .collect();
        Instance { pages, bandwidth: self.bandwidth }
    }

    /// Derived parameters for every page.
    pub fn derived(&self) -> Result<Vec<DerivedParams>> {
        self.pages.iter().map(|p| p.derive()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derive_basic() {
        let p = PageParams { delta: 1.0, mu: 0.5, lam: 0.6, nu: 0.3 };
        let d = p.derive().unwrap();
        assert!((d.gamma - 0.9).abs() < 1e-12);
        assert!((d.alpha - 0.4).abs() < 1e-12);
        let want_beta = -(0.3f64 / 0.9).ln() / 0.4;
        assert!((d.beta - want_beta).abs() < 1e-12);
    }

    #[test]
    fn derive_no_cis() {
        let d = PageParams { delta: 0.7, mu: 0.1, lam: 0.0, nu: 0.0 }.derive().unwrap();
        assert_eq!(d.gamma, 0.0);
        assert!(d.beta.is_infinite());
        assert!((d.alpha - 0.7).abs() < 1e-12);
    }

    #[test]
    fn derive_noiseless_cis() {
        let d = PageParams { delta: 1.0, mu: 0.1, lam: 0.8, nu: 0.0 }.derive().unwrap();
        assert!(d.beta.is_infinite());
        assert!((d.gamma - 0.8).abs() < 1e-12);
        assert_eq!(d.effective_time(2.0, 0), 2.0);
        assert_eq!(d.effective_time(2.0, 1), f64::INFINITY);
        assert_eq!(d.freshness(2.0, 1), 0.0);
    }

    #[test]
    fn lam_one_is_clamped() {
        let d = PageParams { delta: 1.0, mu: 0.1, lam: 1.0, nu: 0.2 }.derive().unwrap();
        assert!(d.alpha > 0.0 && d.alpha.is_finite());
        assert!(d.beta.is_finite());
    }

    #[test]
    fn validation_rejects_bad_params() {
        assert!(PageParams { delta: 0.0, mu: 0.1, lam: 0.5, nu: 0.1 }.derive().is_err());
        assert!(PageParams { delta: 1.0, mu: 0.1, lam: 1.5, nu: 0.1 }.derive().is_err());
        assert!(PageParams { delta: 1.0, mu: -0.1, lam: 0.5, nu: 0.1 }.derive().is_err());
        assert!(PageParams { delta: 1.0, mu: 0.1, lam: 0.5, nu: -0.1 }.derive().is_err());
    }

    #[test]
    fn precision_recall_roundtrip() {
        let p = PageParams::from_quality(0.8, 0.3, 0.4, 0.7);
        assert!((p.precision() - 0.4).abs() < 1e-9);
        assert!((p.recall() - 0.7).abs() < 1e-9);
    }

    #[test]
    fn quality_perfect_precision_means_no_fp() {
        let p = PageParams::from_quality(0.8, 0.3, 1.0, 0.7);
        assert_eq!(p.nu, 0.0);
    }

    #[test]
    fn freshness_eq1() {
        let d = PageParams { delta: 0.8, mu: 0.1, lam: 0.6, nu: 0.3 }.derive().unwrap();
        let want = (-d.alpha * 2.0f64).exp() * (0.3f64 / d.gamma).powi(2);
        assert!((d.freshness(2.0, 2) - want).abs() < 1e-12);
    }

    #[test]
    fn param_columns_round_trip_bit_identical() {
        let envs: Vec<DerivedParams> = [
            PageParams { delta: 1.0, mu: 0.5, lam: 0.6, nu: 0.3 },
            PageParams { delta: 0.7, mu: 0.1, lam: 0.0, nu: 0.0 }, // γ = 0, β = ∞
            PageParams { delta: 1.0, mu: 0.1, lam: 0.8, nu: 0.0 }, // noiseless β = ∞
            PageParams { delta: 0.4, mu: 0.9, lam: 0.0, nu: 0.2 }, // β = 0
        ]
        .iter()
        .map(|p| p.derive().unwrap())
        .collect();
        let cols = ParamColumns::from_derived(&envs);
        assert_eq!(cols.len(), envs.len());
        for (i, d) in envs.iter().enumerate() {
            let got = cols.get(i);
            assert_eq!(got.alpha.to_bits(), d.alpha.to_bits(), "alpha[{i}]");
            assert_eq!(got.beta.to_bits(), d.beta.to_bits(), "beta[{i}]");
            assert_eq!(got.gamma.to_bits(), d.gamma.to_bits(), "gamma[{i}]");
            assert_eq!(got.nu.to_bits(), d.nu.to_bits(), "nu[{i}]");
            assert_eq!(got.delta.to_bits(), d.delta.to_bits(), "delta[{i}]");
            assert_eq!(got.mu.to_bits(), d.mu.to_bits(), "mu[{i}]");
        }
        let mut cols = cols;
        cols.clear();
        assert!(cols.is_empty());
    }

    #[test]
    fn param_columns_set_overwrites_in_place() {
        let a = PageParams { delta: 1.0, mu: 0.5, lam: 0.6, nu: 0.3 }.derive().unwrap();
        let b = PageParams { delta: 0.2, mu: 0.9, lam: 0.1, nu: 0.05 }.derive().unwrap();
        let mut cols = ParamColumns::from_derived(&[a, a, a]);
        cols.set(1, &b);
        // target slot carries the new values bit-exactly...
        let got = cols.get(1);
        assert_eq!(got.alpha.to_bits(), b.alpha.to_bits());
        assert_eq!(got.beta.to_bits(), b.beta.to_bits());
        assert_eq!(got.gamma.to_bits(), b.gamma.to_bits());
        assert_eq!(got.mu.to_bits(), b.mu.to_bits());
        // ...and the neighbours are untouched
        for i in [0usize, 2] {
            assert_eq!(cols.get(i).alpha.to_bits(), a.alpha.to_bits(), "slot {i}");
            assert_eq!(cols.get(i).delta.to_bits(), a.delta.to_bits(), "slot {i}");
        }
        assert_eq!(cols.len(), 3);
    }

    #[test]
    fn normalization() {
        let inst = Instance {
            pages: vec![
                PageParams { delta: 1.0, mu: 3.0, lam: 0.0, nu: 0.0 },
                PageParams { delta: 1.0, mu: 1.0, lam: 0.0, nu: 0.0 },
            ],
            bandwidth: 10.0,
        };
        let n = inst.normalized();
        assert!((n.pages[0].mu - 0.75).abs() < 1e-12);
        assert!((n.total_mu() - 1.0).abs() < 1e-12);
    }
}
