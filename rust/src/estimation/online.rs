//! Streaming (online) estimator of the CIS quality parameters.
//!
//! A production crawler re-estimates `(α, αβ)` continuously as crawl
//! outcomes stream in (§1 footnote: "such parameters are continuously
//! estimated"; Appendix E fits from logged data). This estimator keeps a
//! bounded reservoir of recent observations per page and refits with a
//! few damped-Newton steps on every `refit_every`-th observation —
//! amortized O(1) per crawl, bounded memory, and it tracks drifting
//! signal quality: the reservoir is *time-biased* (every new
//! observation enters; once full it evicts a uniformly random slot), so
//! an observation's survival probability decays geometrically,
//! `(1 − 1/capacity)^k` after `k` further observations — the
//! exponential decay that downweights stale observations.

use crate::estimation::{mle_fit, Observation};
use crate::rngkit::Rng;

/// Online (reservoir + periodic refit) estimator for one page.
#[derive(Debug)]
pub struct OnlineEstimator {
    reservoir: Vec<Observation>,
    capacity: usize,
    seen: u64,
    refit_every: u64,
    rng: Rng,
    /// Current estimate (α̂, κ̂ = α̂β̂).
    pub theta: (f64, f64),
    /// Observed CIS rate (exponentially smoothed).
    pub gamma_hat: f64,
    refits: u64,
}

impl OnlineEstimator {
    /// New estimator with the given reservoir capacity.
    pub fn new(capacity: usize, refit_every: u64, seed: u64) -> Self {
        Self {
            reservoir: Vec::with_capacity(capacity),
            capacity,
            seen: 0,
            refit_every: refit_every.max(1),
            rng: Rng::new(seed),
            theta: (0.5, 0.5),
            gamma_hat: 0.0,
            refits: 0,
        }
    }

    /// Record one crawl outcome.
    pub fn observe(&mut self, obs: Observation) {
        self.seen += 1;
        // smoothed CIS rate
        let rate = if obs.tau > 0.0 { obs.n_cis / obs.tau } else { 0.0 };
        const A: f64 = 0.02;
        self.gamma_hat =
            if self.seen == 1 { rate } else { (1.0 - A) * self.gamma_hat + A * rate };
        // time-biased reservoir: the newest observation ALWAYS enters;
        // once full it evicts a uniformly random slot. Survival of an
        // old observation decays as (1 − 1/capacity)^k over the next k
        // observations, unlike uniform Vitter's-R where early
        // observations linger forever and drift tracking stalls.
        if self.reservoir.len() < self.capacity {
            self.reservoir.push(obs);
        } else {
            let j = self.rng.below(self.capacity as u64) as usize;
            self.reservoir[j] = obs;
        }
        if self.seen % self.refit_every == 0 && self.reservoir.len() >= 8 {
            self.theta = mle_fit(&self.reservoir, 25);
            self.refits += 1;
        }
    }

    /// Current (precision, recall) estimate.
    pub fn quality(&self) -> (f64, f64) {
        crate::estimation::quality_from_theta(self.theta.0, self.theta.1, self.gamma_hat)
    }

    /// Number of refits performed.
    pub fn refits(&self) -> u64 {
        self.refits
    }

    /// Number of observations seen.
    pub fn seen(&self) -> u64 {
        self.seen
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::estimation::generate_observations;
    use crate::params::PageParams;

    #[test]
    fn converges_to_truth_on_stationary_stream() {
        let page = PageParams::from_quality(0.3, 0.1, 0.55, 0.65);
        let mut rng = Rng::new(1);
        let obs = generate_observations(&page, 0.6, 60_000.0, &mut rng);
        let mut est = OnlineEstimator::new(2048, 500, 7);
        for o in obs {
            est.observe(o);
        }
        assert!(est.refits() > 10);
        let (p, r) = est.quality();
        assert!((p - 0.55).abs() < 0.08, "precision {p}");
        assert!((r - 0.65).abs() < 0.08, "recall {r}");
    }

    #[test]
    fn tracks_quality_drift() {
        // signal quality degrades midway; the estimate must move toward
        // the new regime (reservoir gradually flushes old observations)
        let good = PageParams::from_quality(0.3, 0.1, 0.8, 0.7);
        let bad = PageParams::from_quality(0.3, 0.1, 0.2, 0.7);
        let mut rng = Rng::new(2);
        let mut est = OnlineEstimator::new(512, 200, 8);
        for o in generate_observations(&good, 0.6, 20_000.0, &mut rng) {
            est.observe(o);
        }
        let (p_good, _) = est.quality();
        for _ in 0..6 {
            for o in generate_observations(&bad, 0.6, 20_000.0, &mut rng) {
                est.observe(o);
            }
        }
        let (p_after, _) = est.quality();
        // the time-biased reservoir flushes the good-regime sample in
        // ~capacity·ln(capacity) observations, so after 6 bad-regime
        // generations the estimate must sit AT the new regime, not
        // merely below the old one (the pre-fix uniform reservoir only
        // managed p_after < p_good - 0.2)
        assert!(
            p_after < 0.35,
            "estimate must converge to the new regime (0.2): {p_good} -> {p_after}"
        );
        assert!(
            p_after < p_good - 0.35,
            "estimate must follow the drift: {p_good} -> {p_after}"
        );
    }

    #[test]
    fn refit_cadence_and_counters_are_exact() {
        // 500 observations at refit_every = 50: a refit fires on
        // observations 50, 100, ..., 500 — exactly 10 — and seen()
        // counts every observation
        let page = PageParams::from_quality(0.4, 0.1, 0.6, 0.6);
        let mut rng = Rng::new(11);
        let obs = generate_observations(&page, 0.6, 60_000.0, &mut rng);
        assert!(obs.len() >= 500);
        let mut est = OnlineEstimator::new(64, 50, 13);
        for o in obs.into_iter().take(500) {
            est.observe(o);
        }
        assert_eq!(est.seen(), 500);
        assert_eq!(est.refits(), 10);
        // quality() is finite and in range once refits have happened
        let (p, r) = est.quality();
        assert!(p.is_finite() && r.is_finite(), "({p}, {r})");
        assert!((0.0..=1.0).contains(&r), "recall {r}");

        // refit_every = 0 clamps to 1 (refit gated only by the
        // 8-observation reservoir floor)
        let mut eager = OnlineEstimator::new(64, 0, 13);
        let mut rng = Rng::new(12);
        for o in generate_observations(&page, 0.6, 2_000.0, &mut rng).into_iter().take(10) {
            eager.observe(o);
        }
        assert_eq!(eager.seen(), 10);
        assert_eq!(eager.refits(), 3, "refits on observations 8, 9, 10");
    }

    #[test]
    fn no_refit_until_the_reservoir_floor() {
        // refit_every = 4 with only 7 observations: the cadence matches
        // at 4, but the 8-observation reservoir floor blocks the fit —
        // theta stays at its prior and refits() stays 0
        let page = PageParams::from_quality(0.4, 0.1, 0.6, 0.6);
        let mut rng = Rng::new(17);
        let obs = generate_observations(&page, 0.6, 2_000.0, &mut rng);
        let mut est = OnlineEstimator::new(64, 4, 19);
        let prior = est.theta;
        for o in obs.iter().take(7).copied() {
            est.observe(o);
        }
        assert_eq!(est.seen(), 7);
        assert_eq!(est.refits(), 0);
        assert_eq!(est.theta, prior, "theta untouched before the first refit");
        // the 8th observation crosses the floor; the next cadence hit
        // (observation 8, since 8 % 4 == 0) fits immediately
        est.observe(obs[7]);
        assert_eq!(est.refits(), 1);
        assert_ne!(est.theta, prior, "first refit moves theta off the prior");
    }

    #[test]
    fn bounded_memory() {
        let page = PageParams::from_quality(0.5, 0.1, 0.5, 0.5);
        let mut rng = Rng::new(3);
        let mut est = OnlineEstimator::new(64, 100, 9);
        for o in generate_observations(&page, 1.0, 20_000.0, &mut rng) {
            est.observe(o);
        }
        assert!(est.reservoir.len() <= 64);
        assert_eq!(est.seen(), 19_999);
    }
}
