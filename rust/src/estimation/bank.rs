//! The estimator bank: O(m) online learning of page parameters from
//! crawl outcomes alone.
//!
//! One [`Slot`] per page pairs a deterministic streaming change-rate
//! estimator ([`ChangeRateEstimator`], stochastic-approximation MLE on
//! the Bernoulli change observations `z ~ Ber(1 − e^{−Δτ})`) with the
//! reservoir-based [`OnlineEstimator`](super::online::OnlineEstimator)
//! for CIS (precision, recall). The bank is the learned-knowledge
//! scheduler's only source of beliefs: scenario ground truth never
//! enters (see `coordinator::learned`).
//!
//! Robustness invariants, pinned by tests:
//!
//! - **Trust gating** — a page whose Δ̂ confidence interval is still
//!   wide (Fisher-information proxy) schedules from the uninformative
//!   prior `EstimatorConfig::prior_delta`; a page whose estimated CIS
//!   quality misses the GREEDY-CIS+ thresholds
//!   ([`crate::policy::CIS_PLUS_MIN_PRECISION`] /
//!   [`crate::policy::CIS_PLUS_MIN_RECALL`]) has its CIS channel
//!   projected away (`λ = ν = 0`), so unreliable signals are ignored
//!   per page.
//! - **Divergence guardrails** — [`EstimatorBank::estimate`] never
//!   returns non-finite or out-of-range parameters: offending values
//!   are clamped and counted in [`EstimationStats`], never propagated.
//! - **Determinism** — every per-page reservoir seed derives from the
//!   master seed via [`Rng::split64`] sub-keys keyed by (page,
//!   generation); same seed + same event stream replays bit-identically
//!   ([`slot_seed`] is a pure function, no ad-hoc RNG constants).

use crate::estimation::online::OnlineEstimator;
use crate::estimation::Observation;
use crate::params::PageParams;
use crate::policy::{CIS_PLUS_MIN_PRECISION, CIS_PLUS_MIN_RECALL};
use crate::rngkit::Rng;

/// Hard floor for any projected change-rate estimate.
pub const DELTA_MIN: f64 = 1e-6;
/// Hard ceiling for any projected change-rate estimate.
pub const DELTA_MAX: f64 = 1e4;

/// Configuration of the learned-knowledge estimation loop.
///
/// Carried inside [`crate::Knowledge::Learned`]; `seed` is the master
/// seed all per-page estimator streams derive from.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EstimatorConfig {
    /// Master seed; per-page reservoir seeds are `split64` sub-keys.
    pub seed: u64,
    /// Uninformative prior change rate used before Δ̂ earns trust.
    pub prior_delta: f64,
    /// Minimum observations before any estimate may be trusted.
    pub min_obs: u64,
    /// Maximum relative CI half-width for Δ̂ to be trusted.
    pub max_rel_ci: f64,
    /// Maximum belief re-projections flushed per `select` tick.
    pub reproject_budget: usize,
    /// Per-page reservoir capacity of the CIS quality estimator.
    pub reservoir_capacity: usize,
    /// Refit cadence of the CIS quality estimator.
    pub refit_every: u64,
}

impl Default for EstimatorConfig {
    fn default() -> Self {
        Self {
            seed: 0xE571_AA7E,
            prior_delta: 0.1,
            min_obs: 8,
            max_rel_ci: 0.5,
            reproject_budget: 64,
            reservoir_capacity: 32,
            refit_every: 32,
        }
    }
}

/// Counters for everything the estimation loop absorbed or refused to
/// propagate. All exact (no sampling), so seeded runs can pin them.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct EstimationStats {
    /// Successful-fetch observations recorded.
    pub observations: u64,
    /// Failed fetches that were (correctly) NOT recorded as change
    /// observations.
    pub skipped_failed: u64,
    /// Non-finite estimates clamped before projection.
    pub clamped_nonfinite: u64,
    /// Out-of-range estimates clamped before projection.
    pub clamped_range: u64,
    /// Projections that fell back to the uninformative prior or gated
    /// the CIS channel off because an estimate had not earned trust.
    pub untrusted_fallbacks: u64,
    /// Belief re-projections pushed into the inner scheduler.
    pub reprojections: u64,
    /// Dirty pages left for a later tick by the re-projection budget.
    pub deferred: u64,
    /// Ground-truth parameter events withheld from the inner scheduler.
    pub suppressed_truth: u64,
}

/// Deterministic streaming MLE of a page's change rate Δ.
///
/// Stochastic-approximation ascent on the log-likelihood of
/// `z ~ Ber(1 − e^{−Δτ})`, updated multiplicatively in log-space so Δ̂
/// stays positive; the per-step learning rate decays as `1/k` down to a
/// floor of 0.05 so drifting rates keep being tracked. Accumulated
/// Fisher information provides the relative-CI trust proxy. No RNG —
/// the estimate is a pure fold over the observation stream.
#[derive(Debug, Clone, Copy)]
pub struct ChangeRateEstimator {
    delta: f64,
    n: u64,
    fisher: f64,
}

impl ChangeRateEstimator {
    /// Estimator starting from the (clamped) prior rate.
    pub fn new(prior_delta: f64) -> Self {
        let prior = if prior_delta.is_finite() { prior_delta } else { 0.1 };
        Self { delta: prior.clamp(DELTA_MIN, DELTA_MAX), n: 0, fisher: 0.0 }
    }

    /// Fold in one fetch outcome: the page was observed after interval
    /// `tau` and had (`changed = true`) or had not changed.
    /// Non-positive or non-finite intervals carry no rate information
    /// and are ignored.
    pub fn observe(&mut self, tau: f64, changed: bool) {
        if !tau.is_finite() || tau <= 0.0 {
            return;
        }
        self.n += 1;
        let x = (self.delta * tau).min(700.0);
        let e = (-x).exp();
        let p = (1.0 - e).max(1e-12); // P[changed in τ]
        let grad = if changed { tau * e / p } else { -tau };
        let eta = (1.0 / self.n as f64).max(0.05);
        // natural-gradient step in log Δ (d ll/d log Δ = grad·Δ),
        // clamped so one outlier interval cannot blow the estimate up
        let step = (eta * grad * self.delta).clamp(-0.5, 0.5);
        self.delta = (self.delta * step.exp()).clamp(DELTA_MIN, DELTA_MAX);
        self.fisher += tau * tau * e / p;
    }

    /// Current change-rate estimate (always within
    /// `[DELTA_MIN, DELTA_MAX]`).
    #[inline]
    pub fn delta_hat(&self) -> f64 {
        self.delta
    }

    /// Observations folded in.
    #[inline]
    pub fn n(&self) -> u64 {
        self.n
    }

    /// Relative CI half-width proxy `1/(Δ̂·√I)` from the accumulated
    /// Fisher information `I` (infinite before any information).
    pub fn rel_ci(&self) -> f64 {
        if self.fisher > 0.0 {
            1.0 / (self.delta * self.fisher.sqrt())
        } else {
            f64::INFINITY
        }
    }

    /// Has the estimate earned trust (enough observations AND a tight
    /// enough CI)?
    pub fn trusted(&self, min_obs: u64, max_rel_ci: f64) -> bool {
        self.n >= min_obs && self.rel_ci() <= max_rel_ci
    }
}

/// Per-page reservoir seed: a pure function of (master seed, slot,
/// lifecycle generation) via `split64` sub-keys, so replays are
/// bit-identical and recycled slots never reuse a stream.
fn slot_seed(master: u64, page: usize, generation: u32) -> u64 {
    let mut parent = Rng::new(master);
    let tag = (page as u64) ^ ((generation as u64) << 40);
    parent.split64(tag).next_u64()
}

#[derive(Debug)]
struct Slot {
    rate: ChangeRateEstimator,
    quality: OnlineEstimator,
    live: bool,
    generation: u32,
}

/// O(m) bank of per-page online estimators plus the shared divergence
/// counters.
#[derive(Debug)]
pub struct EstimatorBank {
    cfg: EstimatorConfig,
    slots: Vec<Slot>,
    stats: EstimationStats,
}

impl EstimatorBank {
    /// Bank over `m` pages, all cold.
    pub fn new(m: usize, cfg: EstimatorConfig) -> Self {
        let mut bank = Self { cfg, slots: Vec::new(), stats: EstimationStats::default() };
        bank.reset(m);
        bank
    }

    /// Re-dimension to `m` cold pages and zero the stats (the
    /// `on_start` contract: a reused bank is indistinguishable from a
    /// fresh one).
    pub fn reset(&mut self, m: usize) {
        self.slots.clear();
        self.slots.reserve(m);
        for page in 0..m {
            self.slots.push(self.fresh_slot(page, 0));
        }
        self.stats = EstimationStats::default();
    }

    fn fresh_slot(&self, page: usize, generation: u32) -> Slot {
        Slot {
            rate: ChangeRateEstimator::new(self.cfg.prior_delta),
            quality: OnlineEstimator::new(
                self.cfg.reservoir_capacity,
                self.cfg.refit_every,
                slot_seed(self.cfg.seed, page, generation),
            ),
            live: true,
            generation,
        }
    }

    /// Number of slots.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    /// Is the bank empty?
    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// The active configuration.
    pub fn config(&self) -> &EstimatorConfig {
        &self.cfg
    }

    /// Divergence / bookkeeping counters.
    pub fn stats(&self) -> &EstimationStats {
        &self.stats
    }

    pub(crate) fn stats_mut(&mut self) -> &mut EstimationStats {
        &mut self.stats
    }

    /// Record one successful fetch of `page`: interval `tau` since the
    /// previous fetch, `n_cis` signals delivered within it, and whether
    /// the content had `changed`. Ignored for retired slots (a
    /// quarantined page must stop producing observations).
    pub fn observe(&mut self, page: usize, tau: f64, n_cis: u32, changed: bool) {
        let Some(slot) = self.slots.get_mut(page) else { return };
        if !slot.live {
            return;
        }
        slot.rate.observe(tau, changed);
        slot.quality.observe(Observation {
            tau,
            n_cis: n_cis as f64,
            changed: if changed { 1.0 } else { 0.0 },
        });
        self.stats.observations += 1;
    }

    /// A fetch of `page` failed: no change observation may be recorded
    /// (the interval keeps running), only the counter moves.
    pub fn note_failed(&mut self, page: usize) {
        let _ = page;
        self.stats.skipped_failed += 1;
    }

    /// Slot `page` was born (or reborn): fresh estimators on a new
    /// `split64` sub-stream, so nothing of a previous occupant survives.
    pub fn add_page(&mut self, page: usize) {
        if page == self.slots.len() {
            self.slots.push(self.fresh_slot(page, 0));
        } else if let Some(slot) = self.slots.get(page) {
            let generation = slot.generation.wrapping_add(1);
            self.slots[page] = self.fresh_slot(page, generation);
        }
    }

    /// Slot `page` was retired (removal or quarantine): freeze it so no
    /// further observations land.
    pub fn remove_page(&mut self, page: usize) {
        if let Some(slot) = self.slots.get_mut(page) {
            slot.live = false;
        }
    }

    /// Is the slot currently live?
    pub fn is_live(&self, page: usize) -> bool {
        self.slots.get(page).is_some_and(|s| s.live)
    }

    /// Current raw change-rate estimate for `page` (trust-ungated).
    pub fn delta_hat(&self, page: usize) -> f64 {
        self.slots.get(page).map_or(self.cfg.prior_delta, |s| s.rate.delta_hat())
    }

    /// Observations folded into `page`'s change-rate estimator.
    pub fn rate_obs(&self, page: usize) -> u64 {
        self.slots.get(page).map_or(0, |s| s.rate.n())
    }

    /// Project `page`'s current beliefs into scheduler-ready
    /// parameters, applying trust gating and the divergence guardrails.
    /// `mu` is the page's (observable) importance weight. The returned
    /// parameters always pass [`PageParams::validate`].
    pub fn estimate(&mut self, page: usize, mu: f64) -> PageParams {
        let cfg = self.cfg;
        let mut fell_back = false;

        let mut mu = mu;
        if !mu.is_finite() || mu < 0.0 {
            self.stats.clamped_nonfinite += 1;
            mu = 0.0;
        }

        // change rate: trust-gated, clamped, never non-finite
        let (rate_trusted, raw_delta) = match self.slots.get(page) {
            Some(slot) => (slot.rate.trusted(cfg.min_obs, cfg.max_rel_ci), slot.rate.delta_hat()),
            None => (false, cfg.prior_delta),
        };
        let mut delta = if rate_trusted {
            raw_delta
        } else {
            fell_back = true;
            cfg.prior_delta
        };
        if !delta.is_finite() {
            self.stats.clamped_nonfinite += 1;
            delta = cfg.prior_delta;
        }
        if !(DELTA_MIN..=DELTA_MAX).contains(&delta) {
            self.stats.clamped_range += 1;
            delta = delta.clamp(DELTA_MIN, DELTA_MAX);
        }

        // CIS quality: estimated (precision, recall) must clear the
        // GREEDY-CIS+ thresholds or the signal channel is projected away
        let (mut p_hat, mut r_hat, quality_seen) = match self.slots.get(page) {
            Some(slot) => {
                let (p, r) = slot.quality.quality();
                (p, r, slot.quality.seen())
            }
            None => (0.0, 0.0, 0),
        };
        if !p_hat.is_finite() || !r_hat.is_finite() {
            self.stats.clamped_nonfinite += 1;
            p_hat = 0.0;
            r_hat = 0.0;
        }
        if !(0.0..=1.0).contains(&p_hat) || !(0.0..=1.0).contains(&r_hat) {
            self.stats.clamped_range += 1;
            p_hat = p_hat.clamp(0.0, 1.0);
            r_hat = r_hat.clamp(0.0, 1.0);
        }
        let cis_trusted = quality_seen >= cfg.min_obs
            && p_hat > CIS_PLUS_MIN_PRECISION
            && r_hat > CIS_PLUS_MIN_RECALL;

        let params = if cis_trusted {
            PageParams::from_quality(delta, mu, p_hat, r_hat)
        } else {
            fell_back = true;
            PageParams { delta, mu, lam: 0.0, nu: 0.0 }
        };
        if fell_back {
            self.stats.untrusted_fallbacks += 1;
        }
        if params.validate().is_err() {
            // unreachable by construction, but an estimate must NEVER
            // propagate an invalid belief — degrade to the pure prior
            self.stats.clamped_nonfinite += 1;
            return PageParams { delta: cfg.prior_delta, mu, lam: 0.0, nu: 0.0 };
        }
        params
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic synthetic fetch stream: periodic crawls of a page
    /// with true change rate `delta`, change outcomes drawn from the
    /// exact Bernoulli(1 − e^{−Δτ}).
    fn drive(est: &mut ChangeRateEstimator, delta: f64, tau: f64, n: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        let p = 1.0 - (-delta * tau).exp();
        for _ in 0..n {
            est.observe(tau, rng.bernoulli(p));
        }
    }

    #[test]
    fn change_rate_converges_on_stationary_stream() {
        let mut est = ChangeRateEstimator::new(0.1);
        drive(&mut est, 0.5, 1.0, 4000, 7);
        let d = est.delta_hat();
        assert!(d > 0.25 && d < 1.0, "delta_hat {d} vs truth 0.5");
        assert!(est.trusted(8, 0.5), "rel_ci {}", est.rel_ci());
    }

    #[test]
    fn change_rate_tracks_upward_drift() {
        let mut est = ChangeRateEstimator::new(0.1);
        drive(&mut est, 0.5, 1.0, 2000, 11);
        let before = est.delta_hat();
        drive(&mut est, 2.0, 1.0, 2000, 12);
        let after = est.delta_hat();
        assert!(after > before * 1.5, "must track drift: {before} -> {after}");
        assert!(after > 1.0 && after < 4.0, "after {after} vs truth 2.0");
    }

    #[test]
    fn change_rate_ignores_degenerate_intervals() {
        let mut est = ChangeRateEstimator::new(0.3);
        est.observe(0.0, true);
        est.observe(-1.0, true);
        est.observe(f64::NAN, true);
        est.observe(f64::INFINITY, false);
        assert_eq!(est.n(), 0);
        assert_eq!(est.delta_hat(), 0.3);
        assert!(!est.trusted(0, f64::INFINITY) || est.rel_ci().is_infinite());
    }

    #[test]
    fn change_rate_stays_clamped_under_adversarial_streams() {
        // all-changed pushes Δ̂ up: must stop at DELTA_MAX, stay finite
        let mut up = ChangeRateEstimator::new(1.0);
        for _ in 0..5000 {
            up.observe(1e6, true);
        }
        assert!(up.delta_hat().is_finite() && up.delta_hat() <= DELTA_MAX);
        // never-changed pushes Δ̂ down: must stop at DELTA_MIN
        let mut down = ChangeRateEstimator::new(1.0);
        for _ in 0..5000 {
            down.observe(1e6, false);
        }
        assert!(down.delta_hat() >= DELTA_MIN);
    }

    #[test]
    fn slot_seeds_are_deterministic_and_distinct() {
        assert_eq!(slot_seed(42, 3, 0), slot_seed(42, 3, 0));
        assert_ne!(slot_seed(42, 3, 0), slot_seed(42, 4, 0), "pages differ");
        assert_ne!(slot_seed(42, 3, 0), slot_seed(42, 3, 1), "generations differ");
        assert_ne!(slot_seed(42, 3, 0), slot_seed(43, 3, 0), "masters differ");
    }

    #[test]
    fn cold_bank_estimates_the_uninformative_prior() {
        let cfg = EstimatorConfig::default();
        let mut bank = EstimatorBank::new(4, cfg);
        let p = bank.estimate(2, 0.25);
        assert_eq!(p.delta, cfg.prior_delta);
        assert_eq!(p.mu, 0.25);
        assert_eq!((p.lam, p.nu), (0.0, 0.0), "cold CIS channel is gated off");
        assert!(p.validate().is_ok());
        assert_eq!(bank.stats().untrusted_fallbacks, 1);
        assert_eq!(bank.stats().clamped_nonfinite, 0);
    }

    #[test]
    fn estimate_guards_degenerate_mu() {
        let mut bank = EstimatorBank::new(1, EstimatorConfig::default());
        let p = bank.estimate(0, f64::NAN);
        assert_eq!(p.mu, 0.0);
        assert!(p.validate().is_ok());
        assert_eq!(bank.stats().clamped_nonfinite, 1);
        let p = bank.estimate(0, -3.0);
        assert_eq!(p.mu, 0.0);
        assert_eq!(bank.stats().clamped_nonfinite, 2);
    }

    #[test]
    fn trusted_rate_is_projected_untrusted_cis_is_not() {
        let cfg = EstimatorConfig::default();
        let mut bank = EstimatorBank::new(1, cfg);
        // feed enough clean observations for the rate gate to open; the
        // CIS channel (no signals ever) must stay gated
        let mut rng = Rng::new(5);
        let truth = 0.4;
        let p_change = 1.0 - (-truth * 1.0f64).exp();
        for _ in 0..3000 {
            bank.observe(0, 1.0, 0, rng.bernoulli(p_change));
        }
        let p = bank.estimate(0, 0.5);
        assert!(p.delta > 0.2 && p.delta < 0.8, "learned delta {}", p.delta);
        assert_ne!(p.delta, cfg.prior_delta, "rate gate must have opened");
        assert_eq!((p.lam, p.nu), (0.0, 0.0), "no-signal CIS stays off");
        assert_eq!(bank.stats().observations, 3000);
    }

    #[test]
    fn retired_slots_refuse_observations_and_rebirth_is_fresh() {
        let mut bank = EstimatorBank::new(2, EstimatorConfig::default());
        bank.observe(1, 1.0, 0, true);
        assert_eq!(bank.rate_obs(1), 1);
        bank.remove_page(1);
        assert!(!bank.is_live(1));
        bank.observe(1, 1.0, 0, true);
        assert_eq!(bank.rate_obs(1), 1, "retired slot must not absorb observations");
        assert_eq!(bank.stats().observations, 1);
        bank.add_page(1);
        assert!(bank.is_live(1));
        assert_eq!(bank.rate_obs(1), 0, "reborn slot starts cold");
    }

    #[test]
    fn reset_matches_fresh_bank() {
        let cfg = EstimatorConfig::default();
        let mut used = EstimatorBank::new(3, cfg);
        used.observe(0, 1.0, 2, true);
        used.note_failed(2);
        used.remove_page(1);
        used.reset(3);
        let mut fresh = EstimatorBank::new(3, cfg);
        assert_eq!(used.stats(), fresh.stats());
        for page in 0..3 {
            assert!(used.is_live(page));
            assert_eq!(used.rate_obs(page), 0);
            let (a, b) = (used.estimate(page, 0.1), fresh.estimate(page, 0.1));
            assert_eq!(a, b);
        }
    }

    #[test]
    fn failed_fetches_only_move_the_counter() {
        let mut bank = EstimatorBank::new(1, EstimatorConfig::default());
        for _ in 0..5 {
            bank.note_failed(0);
        }
        assert_eq!(bank.stats().skipped_failed, 5);
        assert_eq!(bank.stats().observations, 0);
        assert_eq!(bank.rate_obs(0), 0, "failures carry no change observation");
    }
}
