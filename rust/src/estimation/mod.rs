//! Appendix-E estimators for the CIS quality parameters.
//!
//! Observations are crawl intervals `(τ_ELAP, n_CIS, z)` where `z`
//! indicates whether the crawl found the content changed. Two estimators
//! of (precision, recall):
//!
//! - [`naive_precision_recall`] — the biased statistical estimator that
//!   treats intervals as if they were events (Figure 10);
//! - [`mle_fit`] — MLE of `θ = (α, αβ)` under
//!   `z ~ Ber(1 − exp(−⟨θ, (τ, n)⟩))`, then precision/recall recovered
//!   from `θ̂` and the observed CIS rate `γ̂` (Figure 11):
//!   `ν̂ = γ̂ e^{−κ̂}` (κ̂ = α̂β̂), `prec = 1 − e^{−κ̂}`,
//!   `Δ̂ = α̂ + γ̂(1 − e^{−κ̂})`, `recall = γ̂(1 − e^{−κ̂})/Δ̂`.

pub mod bank;
pub mod online;

pub use bank::{ChangeRateEstimator, EstimationStats, EstimatorBank, EstimatorConfig};

use crate::params::PageParams;
use crate::rngkit::{self, Rng};

/// One crawl-interval observation.
#[derive(Debug, Clone, Copy)]
pub struct Observation {
    /// Interval length (elapsed time between consecutive crawls).
    pub tau: f64,
    /// CIS delivered within the interval.
    pub n_cis: f64,
    /// 1.0 if the crawl found the content changed.
    pub changed: f64,
}

/// Generate the Appendix-E experimental protocol: a page with the given
/// quality crawled periodically at rate `crawl_rate` over `horizon`.
pub fn generate_observations(
    page: &PageParams,
    crawl_rate: f64,
    horizon: f64,
    rng: &mut Rng,
) -> Vec<Observation> {
    let changes = rngkit::poisson_process(rng, page.delta, horizon);
    let mut cis: Vec<f64> = Vec::new();
    for &t in &changes {
        if rng.bernoulli(page.lam) {
            cis.push(t);
        }
    }
    cis.extend(rngkit::poisson_process(rng, page.nu, horizon));
    cis.sort_unstable_by(f64::total_cmp);
    let period = 1.0 / crawl_rate;
    let mut out = Vec::new();
    let mut t_prev = 0.0;
    let mut ci = 0usize;
    let mut chi = 0usize;
    let mut t = period;
    while t < horizon {
        let mut n = 0.0;
        while ci < cis.len() && cis[ci] <= t {
            n += 1.0;
            ci += 1;
        }
        let mut changed = 0.0;
        while chi < changes.len() && changes[chi] <= t {
            changed = 1.0;
            chi += 1;
        }
        out.push(Observation { tau: t - t_prev, n_cis: n, changed });
        t_prev = t;
        t += period;
    }
    out
}

/// Empirical CIS rate γ̂ from the observations.
pub fn empirical_gamma(obs: &[Observation]) -> f64 {
    let total_cis: f64 = obs.iter().map(|o| o.n_cis).sum();
    let total_time: f64 = obs.iter().map(|o| o.tau).sum();
    if total_time > 0.0 {
        total_cis / total_time
    } else {
        0.0
    }
}

/// The naive interval-counting estimator of Appendix E (biased; Fig 10).
///
/// Degenerate streams yield finite conventional values instead of NaN
/// (the estimation loop must never propagate non-finite quality): with
/// no CIS-bearing intervals precision is 1.0 — matching
/// [`PageParams::precision`]'s no-signal convention — and with no
/// observed changes recall is 0.0.
pub fn naive_precision_recall(obs: &[Observation]) -> (f64, f64) {
    let both = obs.iter().filter(|o| o.n_cis > 0.0 && o.changed > 0.5).count() as f64;
    let with_cis = obs.iter().filter(|o| o.n_cis > 0.0).count() as f64;
    let with_change = obs.iter().filter(|o| o.changed > 0.5).count() as f64;
    let precision = if with_cis > 0.0 { both / with_cis } else { 1.0 };
    let recall = if with_change > 0.0 { both / with_change } else { 0.0 };
    (precision, recall)
}

/// Negative log-likelihood and its gradient/Hessian for `θ = (α, κ)`.
fn nll_grad_hess(theta: [f64; 2], obs: &[Observation]) -> (f64, [f64; 2], [[f64; 2]; 2]) {
    let mut nll = 0.0;
    let mut g = [0.0f64; 2];
    let mut h = [[0.0f64; 2]; 2];
    for o in obs {
        let x = [o.tau, o.n_cis];
        let s = theta[0] * x[0] + theta[1] * x[1];
        let p = (-s).exp().clamp(1e-12, 1.0 - 1e-12); // P[no change]
        if o.changed > 0.5 {
            // log(1 - p); d/ds log(1-p) = p/(1-p)
            nll -= (1.0 - p).ln();
            let w1 = p / (1.0 - p);
            let w2 = p / ((1.0 - p) * (1.0 - p)); // -d/ds w1
            for a in 0..2 {
                g[a] -= w1 * x[a];
                for b in 0..2 {
                    h[a][b] += w2 * x[a] * x[b];
                }
            }
        } else {
            // log p = -s
            nll += s;
            for (a, &xa) in x.iter().enumerate() {
                g[a] += xa;
            }
        }
    }
    (nll, g, h)
}

/// Damped-Newton MLE fit of `θ = (α, αβ)`. Native f64; the PJRT
/// `mle_step` artifact implements the identical update in f32.
pub fn mle_fit(obs: &[Observation], iters: usize) -> (f64, f64) {
    let mut theta = [0.5f64, 0.5f64];
    for _ in 0..iters {
        let (_, g, h) = nll_grad_hess(theta, obs);
        // solve (H + eps I) step = g
        let (a, b, c, d) = (h[0][0] + 1e-6, h[0][1], h[1][0], h[1][1] + 1e-6);
        let det = a * d - b * c;
        if det.abs() < 1e-30 {
            break;
        }
        let step = [(d * g[0] - b * g[1]) / det, (-c * g[0] + a * g[1]) / det];
        // clip the step to 50% relative (mirror of model.mle_step)
        let max_rel = (step[0].abs() / theta[0].abs().max(1e-8))
            .max(step[1].abs() / theta[1].abs().max(1e-8));
        let scale = (0.5 / max_rel.max(1e-12)).min(1.0);
        theta[0] = (theta[0] - scale * step[0]).max(1e-8);
        theta[1] = (theta[1] - scale * step[1]).max(1e-8);
    }
    (theta[0], theta[1])
}

/// Map `(α̂, κ̂)` + the observed CIS rate to (precision, recall).
pub fn quality_from_theta(alpha: f64, kappa: f64, gamma_hat: f64) -> (f64, f64) {
    let precision = 1.0 - (-kappa).exp();
    let signalled = gamma_hat * precision; // λ̂Δ̂ = γ̂ − ν̂
    let delta_hat = alpha + signalled;
    let recall = if delta_hat > 0.0 { (signalled / delta_hat).clamp(0.0, 1.0) } else { 0.0 };
    (precision, recall)
}

/// Full MLE pipeline: observations → (precision, recall) estimates.
pub fn mle_precision_recall(obs: &[Observation], iters: usize) -> (f64, f64) {
    let (alpha, kappa) = mle_fit(obs, iters);
    quality_from_theta(alpha, kappa, empirical_gamma(obs))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quality_page(precision: f64, recall: f64, delta: f64) -> PageParams {
        PageParams::from_quality(delta, 0.1, precision, recall)
    }

    #[test]
    fn observations_protocol_shape() {
        let mut rng = Rng::new(1);
        let p = quality_page(0.5, 0.6, 0.25);
        let obs = generate_observations(&p, 0.5, 1000.0, &mut rng);
        assert_eq!(obs.len(), 499);
        assert!(obs.iter().all(|o| (o.tau - 2.0).abs() < 1e-9));
    }

    #[test]
    fn naive_estimator_is_biased_upward_in_precision() {
        // long intervals make "CIS and change in same interval" likely
        // even when the CIS was false — the Figure-10 bias.
        let mut rng = Rng::new(2);
        let p = quality_page(0.3, 0.6, 0.5);
        let mut precs = Vec::new();
        for _ in 0..20 {
            let obs = generate_observations(&p, 0.25, 2000.0, &mut rng);
            let (prec, _) = naive_precision_recall(&obs);
            precs.push(prec);
        }
        let mean = precs.iter().sum::<f64>() / precs.len() as f64;
        assert!(mean > 0.45, "naive precision {mean} should be biased above 0.3");
    }

    #[test]
    fn mle_recovers_parameters() {
        let mut rng = Rng::new(3);
        let p = quality_page(0.5, 0.7, 0.4);
        let d = p.derive().unwrap();
        let mut obs = Vec::new();
        for _ in 0..4 {
            obs.extend(generate_observations(&p, 0.8, 25_000.0, &mut rng));
        }
        let (alpha, kappa) = mle_fit(&obs, 60);
        assert!((alpha - d.alpha).abs() < 0.05 * d.alpha.max(0.05), "alpha {alpha} vs {}", d.alpha);
        let want_kappa = d.alpha * d.beta;
        let kappa_tol = 0.08 * want_kappa.max(0.1);
        assert!((kappa - want_kappa).abs() < kappa_tol, "kappa {kappa} vs {want_kappa}");
    }

    #[test]
    fn mle_precision_recall_low_bias() {
        let mut rng = Rng::new(4);
        let (true_p, true_r) = (0.6, 0.5);
        let p = quality_page(true_p, true_r, 0.3);
        let mut obs = Vec::new();
        for _ in 0..4 {
            obs.extend(generate_observations(&p, 0.6, 25_000.0, &mut rng));
        }
        let (prec, rec) = mle_precision_recall(&obs, 60);
        assert!((prec - true_p).abs() < 0.05, "precision {prec} vs {true_p}");
        assert!((rec - true_r).abs() < 0.05, "recall {rec} vs {true_r}");
    }

    // --- degenerate-stream edge cases: no panics, no NaN propagation ---

    #[test]
    fn empty_observation_set_is_finite_everywhere() {
        let obs: [Observation; 0] = [];
        assert_eq!(empirical_gamma(&obs), 0.0);
        let (prec, rec) = naive_precision_recall(&obs);
        assert_eq!((prec, rec), (1.0, 0.0), "no-signal/no-change conventions");
        // mle_fit on zero observations: gradient/Hessian are zero, the
        // damped solve bails on the singular system and θ stays at the
        // (finite) prior
        let (alpha, kappa) = mle_fit(&obs, 25);
        assert!(alpha.is_finite() && kappa.is_finite(), "({alpha}, {kappa})");
        let (p, r) = quality_from_theta(alpha, kappa, empirical_gamma(&obs));
        assert!(p.is_finite() && r.is_finite(), "({p}, {r})");
        assert!((0.0..=1.0).contains(&r), "recall {r}");
    }

    #[test]
    fn all_changed_stream_stays_clamped_and_finite() {
        // every interval reports a change: the MLE wants α → ∞; the
        // relative step clip + positivity floor must keep θ finite
        let obs: Vec<Observation> =
            (0..64).map(|_| Observation { tau: 1.0, n_cis: 1.0, changed: 1.0 }).collect();
        let (alpha, kappa) = mle_fit(&obs, 50);
        assert!(alpha.is_finite() && alpha > 0.0, "alpha {alpha}");
        assert!(kappa.is_finite() && kappa > 0.0, "kappa {kappa}");
        let (p, r) = quality_from_theta(alpha, kappa, empirical_gamma(&obs));
        assert!(p.is_finite() && (0.0..=1.0).contains(&p), "precision {p}");
        assert!(r.is_finite() && (0.0..=1.0).contains(&r), "recall {r}");
        let (np, nr) = naive_precision_recall(&obs);
        assert_eq!((np, nr), (1.0, 1.0));
    }

    #[test]
    fn none_changed_stream_drives_theta_to_the_floor() {
        // no interval ever reports a change: the MLE pushes θ toward 0
        // and must stop at the 1e-8 positivity floor, never below
        let obs: Vec<Observation> =
            (0..64).map(|_| Observation { tau: 1.0, n_cis: 1.0, changed: 0.0 }).collect();
        let (alpha, kappa) = mle_fit(&obs, 200);
        assert!(alpha >= 1e-8 && alpha.is_finite(), "alpha {alpha}");
        assert!(kappa >= 1e-8 && kappa.is_finite(), "kappa {kappa}");
        assert!(alpha < 0.05, "alpha should collapse toward 0, got {alpha}");
        let (p, r) = quality_from_theta(alpha, kappa, empirical_gamma(&obs));
        assert!(p.is_finite() && r.is_finite(), "({p}, {r})");
        let (np, nr) = naive_precision_recall(&obs);
        assert_eq!(np, 0.0, "CIS present, never right");
        assert_eq!(nr, 0.0, "no changes: recall convention 0.0");
    }

    #[test]
    fn zero_tau_observations_do_not_poison_the_fit() {
        // instantaneous re-crawls contribute x = (0, n) rows; γ̂ must
        // not divide by the zero total time and the fit must stay finite
        let degenerate: Vec<Observation> =
            (0..16).map(|_| Observation { tau: 0.0, n_cis: 2.0, changed: 0.0 }).collect();
        assert_eq!(empirical_gamma(&degenerate), 0.0, "zero total time: γ̂ convention 0");
        let (alpha, kappa) = mle_fit(&degenerate, 25);
        assert!(alpha.is_finite() && kappa.is_finite(), "({alpha}, {kappa})");
        // mixed in with a healthy stream they are just weak rows
        let mut rng = Rng::new(41);
        let page = quality_page(0.5, 0.6, 0.3);
        let mut obs = generate_observations(&page, 0.6, 20_000.0, &mut rng);
        obs.extend(degenerate);
        let (p, r) = mle_precision_recall(&obs, 40);
        assert!(p.is_finite() && (0.0..=1.0).contains(&p), "precision {p}");
        assert!(r.is_finite() && (0.0..=1.0).contains(&r), "recall {r}");
    }

    #[test]
    fn gamma_zero_yields_no_signal_quality() {
        // γ̂ = 0 (no CIS ever observed): recall must be exactly 0 and
        // precision the analytic 1 − e^{−κ}, both finite — the trust
        // gate downstream then ignores the CIS channel entirely
        let (p, r) = quality_from_theta(0.3, 0.5, 0.0);
        assert!((p - (1.0 - (-0.5f64).exp())).abs() < 1e-12, "{p}");
        assert_eq!(r, 0.0);
        // γ̂ = 0 with α also at the floor: delta_hat > 0 still holds
        let (p2, r2) = quality_from_theta(1e-8, 1e-8, 0.0);
        assert!(p2.is_finite() && r2 == 0.0, "({p2}, {r2})");
    }

    #[test]
    fn quality_from_theta_roundtrip() {
        // construct a page, derive, and invert analytically
        let p = quality_page(0.45, 0.65, 0.5);
        let d = p.derive().unwrap();
        let kappa = d.alpha * d.beta;
        let (prec, rec) = quality_from_theta(d.alpha, kappa, d.gamma);
        assert!((prec - 0.45).abs() < 1e-6, "{prec}");
        assert!((rec - 0.65).abs() < 1e-6, "{rec}");
    }
}
