//! Aggregation of simulation outcomes across repetitions.

use crate::stats::{Summary, summarize};

/// Accuracy summary over repetitions of one (policy, instance) cell.
#[derive(Debug, Clone)]
pub struct AccuracyCell {
    /// Policy display name.
    pub policy: String,
    /// Number of pages m.
    pub m: usize,
    /// Accuracy summary over repetitions.
    pub accuracy: Summary,
}

/// Collects per-repetition accuracies and per-page crawl rates.
#[derive(Debug, Default, Clone)]
pub struct RepAccumulator {
    accuracies: Vec<f64>,
    /// Sum of empirical rates per page across reps (for mean rates).
    rate_sums: Vec<f64>,
    reps: usize,
}

impl RepAccumulator {
    /// New accumulator for `m` pages.
    pub fn new(m: usize) -> Self {
        Self { accuracies: Vec::new(), rate_sums: vec![0.0; m], reps: 0 }
    }

    /// Record one repetition.
    pub fn push(&mut self, accuracy: f64, empirical_rates: &[f64]) {
        assert_eq!(empirical_rates.len(), self.rate_sums.len());
        self.accuracies.push(accuracy);
        for (s, &r) in self.rate_sums.iter_mut().zip(empirical_rates) {
            *s += r;
        }
        self.reps += 1;
    }

    /// Accuracy summary.
    pub fn accuracy(&self) -> Summary {
        summarize(&self.accuracies)
    }

    /// Mean empirical rate per page.
    pub fn mean_rates(&self) -> Vec<f64> {
        if self.reps == 0 {
            return vec![f64::NAN; self.rate_sums.len()];
        }
        self.rate_sums.iter().map(|s| s / self.reps as f64).collect()
    }

    /// Number of repetitions recorded.
    pub fn reps(&self) -> usize {
        self.reps
    }
}

/// Degraded-mode aggregation across repetitions of a faulty cell:
/// freshness-under-failure (the accuracy the crawler still achieves
/// while fetches fail), the wasted-bandwidth fraction (ticks burnt on
/// failed attempts or forfeited on quarantined picks), and the per-host
/// retry histogram summed over reps. Companion to [`RepAccumulator`]
/// for [`crate::fault::FaultSimResult`] runs.
#[derive(Debug, Default, Clone)]
pub struct FaultRepAccumulator {
    accuracies: Vec<f64>,
    wasted_fractions: Vec<f64>,
    retry_fractions: Vec<f64>,
    quarantined: Vec<f64>,
    /// Per-host retry counts summed across reps.
    retries_per_host: Vec<u64>,
    reps: usize,
}

impl FaultRepAccumulator {
    /// New accumulator for a topology of `hosts` hosts.
    pub fn new(hosts: usize) -> Self {
        Self { retries_per_host: vec![0; hosts], ..Self::default() }
    }

    /// Record one repetition.
    pub fn push(&mut self, res: &crate::fault::FaultSimResult) {
        assert_eq!(res.faults.retries_per_host.len(), self.retries_per_host.len());
        self.accuracies.push(res.sim.accuracy);
        self.wasted_fractions.push(res.faults.wasted_fraction());
        let attempts = res.faults.attempts.max(1) as f64;
        self.retry_fractions.push(res.faults.retries as f64 / attempts);
        self.quarantined.push(res.faults.quarantined as f64);
        for (s, &r) in self.retries_per_host.iter_mut().zip(&res.faults.retries_per_host) {
            *s += r;
        }
        self.reps += 1;
    }

    /// Freshness-under-failure summary (accuracy across reps).
    pub fn accuracy(&self) -> Summary {
        summarize(&self.accuracies)
    }

    /// Wasted-bandwidth fraction summary.
    pub fn wasted_fraction(&self) -> Summary {
        summarize(&self.wasted_fractions)
    }

    /// Fraction of attempts that were retries, summarized across reps.
    pub fn retry_fraction(&self) -> Summary {
        summarize(&self.retry_fractions)
    }

    /// Quarantined-page count summary.
    pub fn quarantined(&self) -> Summary {
        summarize(&self.quarantined)
    }

    /// Mean retries per host across reps.
    pub fn mean_retries_per_host(&self) -> Vec<f64> {
        if self.reps == 0 {
            return vec![f64::NAN; self.retries_per_host.len()];
        }
        self.retries_per_host.iter().map(|&s| s as f64 / self.reps as f64).collect()
    }

    /// Number of repetitions recorded.
    pub fn reps(&self) -> usize {
        self.reps
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_accuracy_and_rates() {
        let mut acc = RepAccumulator::new(2);
        acc.push(0.8, &[1.0, 2.0]);
        acc.push(0.6, &[3.0, 4.0]);
        let s = acc.accuracy();
        assert_eq!(s.n, 2);
        assert!((s.mean - 0.7).abs() < 1e-12);
        assert_eq!(acc.mean_rates(), vec![2.0, 3.0]);
        assert_eq!(acc.reps(), 2);
    }

    #[test]
    #[should_panic]
    fn rate_length_mismatch_panics() {
        let mut acc = RepAccumulator::new(2);
        acc.push(0.8, &[1.0]);
    }

    #[test]
    fn fault_accumulator_summarizes_degraded_runs() {
        use crate::fault::{FaultSimResult, FaultStats};
        use crate::sim::engine::SimResult;
        let mk = |accuracy: f64, stats: FaultStats| FaultSimResult {
            sim: SimResult {
                accuracy,
                requests: 10,
                fresh_hits: 5,
                crawl_counts: vec![],
                ticks: 10,
                timeline: vec![],
            },
            faults: stats,
        };
        let mut s1 = FaultStats::new(2);
        s1.attempts = 10;
        s1.successes = 8;
        s1.transient_errors = 2;
        s1.retries = 2;
        s1.retries_per_host = vec![2, 0];
        let mut s2 = FaultStats::new(2);
        s2.attempts = 10;
        s2.successes = 6;
        s2.timeouts = 4;
        s2.retries = 4;
        s2.quarantined = 1;
        s2.retries_per_host = vec![1, 3];

        let mut acc = FaultRepAccumulator::new(2);
        acc.push(&mk(0.9, s1));
        acc.push(&mk(0.7, s2));
        assert_eq!(acc.reps(), 2);
        assert!((acc.accuracy().mean - 0.8).abs() < 1e-12);
        // wasted fractions: 2/10 and 4/10 → mean 0.3
        assert!((acc.wasted_fraction().mean - 0.3).abs() < 1e-12);
        assert!((acc.retry_fraction().mean - 0.3).abs() < 1e-12);
        assert!((acc.quarantined().mean - 0.5).abs() < 1e-12);
        assert_eq!(acc.mean_retries_per_host(), vec![1.5, 1.5]);
    }

    #[test]
    #[should_panic]
    fn fault_host_mismatch_panics() {
        use crate::fault::{FaultSimResult, FaultStats};
        use crate::sim::engine::SimResult;
        let mut acc = FaultRepAccumulator::new(3);
        acc.push(&FaultSimResult {
            sim: SimResult {
                accuracy: 0.5,
                requests: 0,
                fresh_hits: 0,
                crawl_counts: vec![],
                ticks: 0,
                timeline: vec![],
            },
            faults: FaultStats::new(2),
        });
    }
}
