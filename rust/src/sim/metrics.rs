//! Aggregation of simulation outcomes across repetitions.

use crate::stats::{Summary, summarize};

/// Accuracy summary over repetitions of one (policy, instance) cell.
#[derive(Debug, Clone)]
pub struct AccuracyCell {
    /// Policy display name.
    pub policy: String,
    /// Number of pages m.
    pub m: usize,
    /// Accuracy summary over repetitions.
    pub accuracy: Summary,
}

/// Collects per-repetition accuracies and per-page crawl rates.
#[derive(Debug, Default, Clone)]
pub struct RepAccumulator {
    accuracies: Vec<f64>,
    /// Sum of empirical rates per page across reps (for mean rates).
    rate_sums: Vec<f64>,
    reps: usize,
}

impl RepAccumulator {
    /// New accumulator for `m` pages.
    pub fn new(m: usize) -> Self {
        Self { accuracies: Vec::new(), rate_sums: vec![0.0; m], reps: 0 }
    }

    /// Record one repetition.
    pub fn push(&mut self, accuracy: f64, empirical_rates: &[f64]) {
        assert_eq!(empirical_rates.len(), self.rate_sums.len());
        self.accuracies.push(accuracy);
        for (s, &r) in self.rate_sums.iter_mut().zip(empirical_rates) {
            *s += r;
        }
        self.reps += 1;
    }

    /// Accuracy summary.
    pub fn accuracy(&self) -> Summary {
        summarize(&self.accuracies)
    }

    /// Mean empirical rate per page.
    pub fn mean_rates(&self) -> Vec<f64> {
        if self.reps == 0 {
            return vec![f64::NAN; self.rate_sums.len()];
        }
        self.rate_sums.iter().map(|s| s / self.reps as f64).collect()
    }

    /// Number of repetitions recorded.
    pub fn reps(&self) -> usize {
        self.reps
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_accuracy_and_rates() {
        let mut acc = RepAccumulator::new(2);
        acc.push(0.8, &[1.0, 2.0]);
        acc.push(0.6, &[3.0, 4.0]);
        let s = acc.accuracy();
        assert_eq!(s.n, 2);
        assert!((s.mean - 0.7).abs() < 1e-12);
        assert_eq!(acc.mean_rates(), vec![2.0, 3.0]);
        assert_eq!(acc.reps(), 2);
    }

    #[test]
    #[should_panic]
    fn rate_length_mismatch_panics() {
        let mut acc = RepAccumulator::new(2);
        acc.push(0.8, &[1.0]);
    }
}
