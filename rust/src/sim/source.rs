//! Lazy per-page event sourcing.
//!
//! The materialized path ([`crate::sim::events::generate_traces`])
//! realizes every change / CIS / request event for the whole horizon
//! before a repetition starts — peak memory `O(total events)` ≈
//! `O(m · T · rate)`. This module replaces that with **event
//! sourcing**: each page holds a [`PageEventSource`] cursor that
//! samples its *next* arrival on demand, exploiting the memoryless
//! property of the Poisson processes, so a repetition runs in `O(m)`
//! memory no matter the horizon.
//!
//! ## Substream keying
//!
//! Each page derives three independent compact RNG substreams from its
//! per-page generator (`master.split(i)`, the same per-page keying as
//! the materialized generator, then [`crate::rngkit::Rng::split64`]
//! sub-keys):
//!
//! - **changes** ([`SUB_CHANGES`]): change inter-arrivals *and* the
//!   per-change Bernoulli(λ) signal coins;
//! - **CIS false positives** ([`SUB_CIS`]): false-positive
//!   inter-arrivals and *every* delivery-delay draw (signalled and
//!   false-positive alike);
//! - **requests** ([`SUB_REQUESTS`]): request inter-arrivals.
//!
//! Putting the delay draws on the CIS substream makes the change
//! realization (arrivals + coins) *seed-paired across delay models*:
//! two sources built from the same master seed with different
//! [`CisDelay`]s see identical changes, which is what lets tests pin
//! "delays shift CIS later" as a paired, strictly-positive mean shift.
//!
//! ## The pending-buffer invariant
//!
//! Delivery delays can reorder signals: a change at `c₁ < c₂` may
//! deliver at `c₁ + d₁ > c₂ + d₂`. Deliveries therefore go through a
//! small per-page min-buffer ([`PendingCis`]) and the source only
//! emits its minimum once no *future* arrival can deliver earlier:
//! a delivery `d` is emittable when `d ≤ next_change` (every future
//! change delivers at or after its own arrival time) and the
//! false-positive stream has been drained past `d` (every remaining
//! false positive delivers at or after its arrival ≥ `nf > d`). By
//! Little's law the buffer holds ~`rate × mean delay` entries — `O(1)`
//! for every delay model the experiments use, and at most one entry
//! under [`CisDelay::None`].
//!
//! ## Exact replay
//!
//! [`ReplaySource`] is the same cursor interface over a pre-built
//! [`PageTrace`] — it emits exactly the materialized events in exactly
//! the order the pre-refactor engine merged them, which pins the
//! frontier-based merge engine bit-identical to its predecessor
//! (`tests/event_sourcing.rs`).

use crate::params::PageParams;
use crate::rngkit::{self, RandomSource, Rng, SplitMix64};
use crate::sim::engine::{KIND_CHANGE, KIND_CIS, KIND_REQUEST};
use crate::sim::events::{CisDelay, EventTraces, PageTrace};

/// How per-repetition event streams are produced.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TraceMode {
    /// Pre-materialize every event before the run (the
    /// parity/distribution oracle; peak memory `O(total events)`).
    Materialized,
    /// Lazy per-page event sourcing (`O(m)` memory; the default for
    /// experiment cells).
    #[default]
    Streamed,
}

/// Sub-key of the change substream (arrivals + signal coins).
pub const SUB_CHANGES: u64 = 0;
/// Sub-key of the CIS substream (false-positive arrivals + all delays).
pub const SUB_CIS: u64 = 1;
/// Sub-key of the request substream.
pub const SUB_REQUESTS: u64 = 2;

/// A per-page supplier of simulation events in `(time, kind)` order.
///
/// The merge engine ([`crate::sim::engine::simulate_source_with`])
/// keeps one pending `(time, kind)` pair per page in its SoA merge
/// frontier; `first` seeds that frontier and `advance` refills it
/// after the engine consumes an event. Implementations must emit each
/// page's events in non-decreasing `(time, kind-rank)` order with
/// kinds ranked change < CIS < request at equal times.
pub trait EventSource {
    /// Number of pages.
    fn len(&self) -> usize;

    /// No pages at all?
    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Begin page `i`'s stream and return its first event (`None` if
    /// the page has no events). Called once per page per run, before
    /// any `advance` for that page.
    fn first(&mut self, i: usize) -> Option<(f64, u8)>;

    /// Consume page `i`'s current event (whose kind the engine just
    /// popped) and return the next one.
    fn advance(&mut self, i: usize, kind: u8) -> Option<(f64, u8)>;
}

/// Per-page min-buffer of in-flight CIS deliveries, kept sorted
/// descending so the minimum is `O(1)` at the tail. Expected occupancy
/// is `rate × mean delay` (Little's law) — tiny for every experiment's
/// delay model — so linear insertion beats a heap here.
#[derive(Debug, Clone, Default)]
pub(crate) struct PendingCis(Vec<f64>);

impl PendingCis {
    #[inline]
    fn push(&mut self, t: f64) {
        // descending order: the `> t` prefix ends at the insert slot
        let pos = self.0.partition_point(|&x| x > t);
        self.0.insert(pos, t);
    }

    #[inline]
    fn peek(&self) -> Option<f64> {
        self.0.last().copied()
    }

    #[inline]
    fn pop(&mut self) -> Option<f64> {
        self.0.pop()
    }

    fn clear(&mut self) {
        self.0.clear();
    }

    #[cfg(test)]
    fn len(&self) -> usize {
        self.0.len()
    }
}

/// Next arrival of a rate-`rate` Poisson process after `from`, or
/// `INFINITY` when the process is off (`rate ≤ 0`) or the arrival
/// falls at/past the horizon (the stream ends, exactly like the
/// materialized generator stopping its arrival loop).
#[inline]
fn arrival<R: RandomSource>(rng: &mut R, rate: f64, from: f64, horizon: f64) -> f64 {
    if rate <= 0.0 {
        return f64::INFINITY;
    }
    let t = from + rngkit::exponential(rng, rate);
    if t < horizon {
        t
    } else {
        f64::INFINITY
    }
}

/// Lazy event cursor for one page: three compact RNG substreams, the
/// next arrival of each process, and the pending delivery buffer.
/// 112 bytes + the (usually empty) buffer — fixed per page, however
/// long the horizon.
#[derive(Debug, Clone)]
pub struct PageEventSource {
    ch: SplitMix64,
    fp: SplitMix64,
    rq: SplitMix64,
    delta: f64,
    mu: f64,
    lam: f64,
    nu: f64,
    /// Next change arrival (`INFINITY` = exhausted).
    na: f64,
    /// Next false-positive CIS arrival (`INFINITY` = exhausted).
    nf: f64,
    /// Next request arrival (`INFINITY` = exhausted).
    nr: f64,
    pending: PendingCis,
}

impl PageEventSource {
    /// New source for a page born (or re-parameterized) at `t0`,
    /// sampling over `[t0, horizon)`. With `t0 = 0` this is the
    /// whole-horizon stream. `delay` must be valid (the batch
    /// constructors validate; see [`CisDelay::validate`]).
    pub fn new(p: &PageParams, t0: f64, horizon: f64, delay: CisDelay, rng: &mut Rng) -> Self {
        let ch = rng.split64(SUB_CHANGES);
        let fp = rng.split64(SUB_CIS);
        let rq = rng.split64(SUB_REQUESTS);
        let mut src = Self {
            ch,
            fp,
            rq,
            delta: p.delta,
            mu: p.mu,
            lam: p.lam,
            nu: p.nu,
            na: f64::INFINITY,
            nf: f64::INFINITY,
            nr: f64::INFINITY,
            pending: PendingCis::default(),
        };
        if horizon - t0 > 0.0 {
            src.na = arrival(&mut src.ch, src.delta, t0, horizon);
            if src.na.is_finite() {
                src.roll_signal(horizon, delay);
            }
            src.nf = arrival(&mut src.fp, src.nu, t0, horizon);
            src.nr = arrival(&mut src.rq, src.mu, t0, horizon);
        }
        src
    }

    /// Draw the signal coin for the freshly generated change at
    /// `self.na` (coin on the change substream, delay on the CIS
    /// substream) and buffer its delivery if it lands in-horizon.
    #[inline]
    fn roll_signal(&mut self, horizon: f64, delay: CisDelay) {
        if self.ch.bernoulli(self.lam) {
            let d = self.na + delay.sample(&mut self.fp);
            if d < horizon {
                self.pending.push(d);
            }
        }
    }

    /// Current next event of this page, draining false-positive
    /// arrivals until the pending buffer's minimum is provably safe to
    /// emit (see the module docs' invariant). Candidates are checked
    /// in kind order, so equal-time events rank change < CIS < request.
    pub(crate) fn next(&mut self, horizon: f64, delay: CisDelay) -> Option<(f64, u8)> {
        loop {
            let gate = self.na.min(self.nr).min(self.pending.peek().unwrap_or(f64::INFINITY));
            if self.nf.is_finite() && self.nf <= gate {
                let arr = self.nf;
                let d = arr + delay.sample(&mut self.fp);
                if d < horizon {
                    self.pending.push(d);
                }
                self.nf = arrival(&mut self.fp, self.nu, arr, horizon);
            } else {
                break;
            }
        }
        let mut best: Option<(f64, u8)> = None;
        if self.na.is_finite() {
            best = Some((self.na, KIND_CHANGE));
        }
        if let Some(d) = self.pending.peek() {
            if best.map_or(true, |(bt, _)| d < bt) {
                best = Some((d, KIND_CIS));
            }
        }
        if self.nr.is_finite() && best.map_or(true, |(bt, _)| self.nr < bt) {
            best = Some((self.nr, KIND_REQUEST));
        }
        best
    }

    /// Consume the current event of `kind` (the one [`Self::next`]
    /// reported), sampling the following arrival of that process.
    pub(crate) fn consume(&mut self, kind: u8, horizon: f64, delay: CisDelay) {
        match kind {
            KIND_CHANGE => {
                debug_assert!(self.na.is_finite(), "consumed a change with none pending");
                self.na = arrival(&mut self.ch, self.delta, self.na, horizon);
                if self.na.is_finite() {
                    self.roll_signal(horizon, delay);
                }
            }
            KIND_REQUEST => {
                debug_assert!(self.nr.is_finite(), "consumed a request with none pending");
                self.nr = arrival(&mut self.rq, self.mu, self.nr, horizon);
            }
            _ => {
                let popped = self.pending.pop();
                debug_assert!(popped.is_some(), "consumed a CIS with none buffered");
            }
        }
    }

    /// Kill the stream: no further events (scenario retirement).
    pub(crate) fn kill(&mut self) {
        self.na = f64::INFINITY;
        self.nf = f64::INFINITY;
        self.nr = f64::INFINITY;
        self.pending.clear();
    }

    /// Scenario CIS-quality shift at time `t`: the change and request
    /// realizations are untouched (their substreams and next arrivals
    /// are preserved), the false-positive substream is re-seeded under
    /// the new `nu`, and in-flight deliveries of the old feed drop
    /// (the pending buffer clears — including the already-rolled
    /// signal of the not-yet-arrived next change; coins for changes
    /// generated after the shift use the new `lam`).
    pub(crate) fn shift_cis_quality(
        &mut self,
        lam: f64,
        nu: f64,
        t: f64,
        horizon: f64,
        rng: &mut Rng,
    ) {
        self.lam = lam;
        self.nu = nu;
        self.fp = rng.split64(SUB_CIS);
        self.pending.clear();
        self.nf = arrival(&mut self.fp, self.nu, t, horizon);
    }
}

/// Lazy event sourcing over a whole population — the streamed analogue
/// of [`EventTraces`]. Fixed `O(m)` state: one [`PageEventSource`] per
/// page.
#[derive(Debug, Clone)]
pub struct StreamedSource {
    sources: Vec<PageEventSource>,
    horizon: f64,
    delay: CisDelay,
}

impl StreamedSource {
    /// Build the per-page sources for an instance over `[0, horizon)`.
    /// Uses the same per-page master keying as
    /// [`crate::sim::events::generate_traces`] (`rng.split(i)`), so a
    /// caller's master RNG advances identically in both modes.
    pub fn new(
        pages: &[PageParams],
        horizon: f64,
        delay: CisDelay,
        rng: &mut Rng,
    ) -> crate::Result<Self> {
        delay.validate()?;
        let sources = pages
            .iter()
            .enumerate()
            .map(|(i, p)| {
                let mut prng = rng.split(i as u64);
                PageEventSource::new(p, 0.0, horizon, delay, &mut prng)
            })
            .collect();
        Ok(Self { sources, horizon, delay })
    }

    /// Horizon the streams cover.
    pub fn horizon(&self) -> f64 {
        self.horizon
    }

    /// Drain every page into materialized traces (consumes the
    /// source — streams are single-pass). Test/bench helper: the lazy
    /// path's events in trace form, for distributional comparisons and
    /// for forcing full generation in the memory benches.
    pub fn materialize(mut self) -> EventTraces {
        let horizon = self.horizon;
        let m = self.len();
        let mut pages = Vec::with_capacity(m);
        for i in 0..m {
            let mut tr = PageTrace::default();
            let mut ev = self.first(i);
            while let Some((t, k)) = ev {
                match k {
                    KIND_CHANGE => tr.changes.push(t),
                    KIND_CIS => tr.cis.push(t),
                    _ => tr.requests.push(t),
                }
                ev = self.advance(i, k);
            }
            pages.push(tr);
        }
        EventTraces { pages, horizon }
    }
}

impl EventSource for StreamedSource {
    fn len(&self) -> usize {
        self.sources.len()
    }

    fn first(&mut self, i: usize) -> Option<(f64, u8)> {
        self.sources[i].next(self.horizon, self.delay)
    }

    fn advance(&mut self, i: usize, kind: u8) -> Option<(f64, u8)> {
        let s = &mut self.sources[i];
        s.consume(kind, self.horizon, self.delay);
        s.next(self.horizon, self.delay)
    }
}

/// Exact replay of pre-built traces through the [`EventSource`]
/// interface: three cursors per page, advancing whichever stream the
/// consumed event came from. Emits events in exactly the `(time,
/// kind-rank)` per-page order of the pre-refactor engine's `push_next`,
/// pinning the frontier merge bit-identical to it.
#[derive(Debug)]
pub struct ReplaySource<'a> {
    pages: &'a [PageTrace],
    cursors: Vec<[usize; 3]>,
}

impl<'a> ReplaySource<'a> {
    /// Replay source with its own cursor storage.
    pub fn new(pages: &'a [PageTrace]) -> Self {
        Self::with_cursors(pages, Vec::new())
    }

    /// Replay source reusing a caller-owned cursor buffer (the
    /// workspace lends its pool so repetition loops stay
    /// allocation-free); reclaim it with [`Self::into_cursors`].
    pub fn with_cursors(pages: &'a [PageTrace], mut cursors: Vec<[usize; 3]>) -> Self {
        cursors.clear();
        cursors.resize(pages.len(), [0, 0, 0]);
        Self { pages, cursors }
    }

    /// Recover the cursor buffer for reuse.
    pub fn into_cursors(self) -> Vec<[usize; 3]> {
        self.cursors
    }

    /// Earliest pending event across the page's three streams,
    /// kind-rank tie-break (candidates checked in kind order, so an
    /// equal-time later kind never displaces an earlier one).
    #[inline]
    fn best(&self, i: usize) -> Option<(f64, u8)> {
        let p = &self.pages[i];
        let c = &self.cursors[i];
        let mut best: Option<(f64, u8)> = None;
        if let Some(&t) = p.changes.get(c[0]) {
            best = Some((t, KIND_CHANGE));
        }
        if let Some(&t) = p.cis.get(c[1]) {
            if best.map_or(true, |(bt, _)| t < bt) {
                best = Some((t, KIND_CIS));
            }
        }
        if let Some(&t) = p.requests.get(c[2]) {
            if best.map_or(true, |(bt, _)| t < bt) {
                best = Some((t, KIND_REQUEST));
            }
        }
        best
    }
}

impl EventSource for ReplaySource<'_> {
    fn len(&self) -> usize {
        self.pages.len()
    }

    fn first(&mut self, i: usize) -> Option<(f64, u8)> {
        // the cursor merge relies on each per-page stream being
        // time-sorted
        let p = &self.pages[i];
        debug_assert!(
            p.changes.windows(2).all(|w| w[0] <= w[1])
                && p.cis.windows(2).all(|w| w[0] <= w[1])
                && p.requests.windows(2).all(|w| w[0] <= w[1]),
            "page {i}: per-page event streams must be sorted by time"
        );
        self.cursors[i] = [0, 0, 0];
        self.best(i)
    }

    fn advance(&mut self, i: usize, kind: u8) -> Option<(f64, u8)> {
        self.cursors[i][kind as usize] += 1;
        self.best(i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn page(delta: f64, mu: f64, lam: f64, nu: f64) -> PageParams {
        PageParams { delta, mu, lam, nu }
    }

    fn drain(src: &mut StreamedSource, i: usize) -> Vec<(f64, u8)> {
        let mut out = Vec::new();
        let mut ev = src.first(i);
        while let Some((t, k)) = ev {
            out.push((t, k));
            ev = src.advance(i, k);
        }
        out
    }

    #[test]
    fn pending_buffer_keeps_min_at_tail() {
        let mut p = PendingCis::default();
        for &t in &[3.0, 1.0, 2.0, 0.5, 2.5] {
            p.push(t);
        }
        let mut drained = Vec::new();
        while let Some(t) = p.pop() {
            drained.push(t);
        }
        assert_eq!(drained, vec![0.5, 1.0, 2.0, 2.5, 3.0]);
        assert_eq!(p.len(), 0);
    }

    #[test]
    fn page_source_emits_sorted_events_with_kind_rank_ties() {
        let mut rng = Rng::new(7);
        let mut src = StreamedSource::new(
            &[page(1.0, 1.2, 0.7, 0.5)],
            80.0,
            CisDelay::Exponential { mean: 0.4 },
            &mut rng,
        )
        .unwrap();
        let evs = drain(&mut src, 0);
        assert!(!evs.is_empty());
        for w in evs.windows(2) {
            let (t0, k0) = w[0];
            let (t1, k1) = w[1];
            assert!(
                t0 < t1 || (t0 == t1 && k0 <= k1),
                "events out of (time, kind) order: ({t0}, {k0}) then ({t1}, {k1})"
            );
        }
        assert!(evs.iter().all(|&(t, _)| (0.0..80.0).contains(&t)));
    }

    #[test]
    fn zero_rates_produce_no_events_of_that_kind() {
        let mut rng = Rng::new(8);
        let mut src =
            StreamedSource::new(&[page(0.0, 0.0, 0.5, 0.0)], 100.0, CisDelay::None, &mut rng)
                .unwrap();
        assert!(drain(&mut src, 0).is_empty(), "all-off page must be silent");
        let mut rng = Rng::new(9);
        let mut src =
            StreamedSource::new(&[page(2.0, 0.0, 0.0, 0.0)], 100.0, CisDelay::None, &mut rng)
                .unwrap();
        let evs = drain(&mut src, 0);
        assert!(!evs.is_empty());
        assert!(evs.iter().all(|&(_, k)| k == KIND_CHANGE), "only changes expected");
    }

    #[test]
    fn instant_delay_pairs_cis_with_signalled_changes() {
        // λ=1, ν=0, no delay: every change emits a CIS at the exact
        // same instant, ordered change-then-CIS
        let mut rng = Rng::new(10);
        let mut src =
            StreamedSource::new(&[page(1.5, 0.0, 1.0, 0.0)], 60.0, CisDelay::None, &mut rng)
                .unwrap();
        let evs = drain(&mut src, 0);
        assert!(!evs.is_empty());
        assert_eq!(evs.len() % 2, 0, "changes and CIS must pair up");
        for pair in evs.chunks(2) {
            assert_eq!(pair[0].1, KIND_CHANGE);
            assert_eq!(pair[1].1, KIND_CIS);
            assert_eq!(pair[0].0.to_bits(), pair[1].0.to_bits());
        }
    }

    #[test]
    fn dead_window_is_empty() {
        let mut rng = Rng::new(11);
        let p = page(2.0, 2.0, 0.5, 0.5);
        let mut prng = rng.split(0);
        let mut s = PageEventSource::new(&p, 50.0, 50.0, CisDelay::None, &mut prng);
        assert!(s.next(50.0, CisDelay::None).is_none());
        let mut prng2 = rng.split(1);
        let mut s2 = PageEventSource::new(&p, 60.0, 50.0, CisDelay::None, &mut prng2);
        assert!(s2.next(50.0, CisDelay::None).is_none());
    }

    #[test]
    fn from_t0_events_live_in_their_window() {
        let mut rng = Rng::new(12);
        let p = page(2.0, 1.5, 0.5, 0.4);
        let mut prng = rng.split(0);
        let delay = CisDelay::Exponential { mean: 0.2 };
        let mut s = PageEventSource::new(&p, 30.0, 50.0, delay, &mut prng);
        let mut prev: Option<(f64, u8)> = None;
        while let Some((t, k)) = s.next(50.0, delay) {
            assert!((30.0..50.0).contains(&t), "event at {t} outside [30, 50)");
            if let Some((pt, pk)) = prev {
                assert!(pt < t || (pt == t && pk <= k), "out of order");
            }
            prev = Some((t, k));
            s.consume(k, 50.0, delay);
        }
        assert!(prev.is_some(), "window should contain events");
    }

    #[test]
    fn killed_source_emits_nothing() {
        let mut rng = Rng::new(13);
        let mut src =
            StreamedSource::new(&[page(1.0, 1.0, 0.5, 0.5)], 100.0, CisDelay::None, &mut rng)
                .unwrap();
        assert!(src.first(0).is_some());
        src.sources[0].kill();
        assert!(src.sources[0].next(100.0, CisDelay::None).is_none());
    }

    #[test]
    fn replay_source_walks_traces_in_merge_order() {
        let tr = PageTrace {
            changes: vec![1.0, 2.0, 5.0],
            cis: vec![1.0, 3.0],
            requests: vec![0.5, 2.0, 2.0, 6.0],
        };
        let pages = vec![tr];
        let mut src = ReplaySource::new(&pages);
        let mut out = Vec::new();
        let mut ev = src.first(0);
        while let Some((t, k)) = ev {
            out.push((t, k));
            ev = src.advance(0, k);
        }
        assert_eq!(
            out,
            vec![
                (0.5, KIND_REQUEST),
                (1.0, KIND_CHANGE),
                (1.0, KIND_CIS),
                (2.0, KIND_CHANGE),
                (2.0, KIND_REQUEST),
                (2.0, KIND_REQUEST),
                (3.0, KIND_CIS),
                (5.0, KIND_CHANGE),
                (6.0, KIND_REQUEST),
            ]
        );
        // cursor pool round-trips
        let pool = src.into_cursors();
        assert_eq!(pool.len(), 1);
        let src2 = ReplaySource::with_cursors(&pages, pool);
        assert_eq!(src2.cursors[0], [0, 0, 0]);
    }

    #[test]
    fn materialize_matches_a_second_drain() {
        let ps = [page(0.8, 1.0, 0.6, 0.3), page(1.2, 0.4, 0.2, 0.6)];
        let delay = CisDelay::Poisson { mean: 3.0, unit: 0.05 };
        let mut r1 = Rng::new(21);
        let mut r2 = Rng::new(21);
        let src1 = StreamedSource::new(&ps, 40.0, delay, &mut r1).unwrap();
        let mut src2 = StreamedSource::new(&ps, 40.0, delay, &mut r2).unwrap();
        let traces = src1.materialize();
        assert_eq!(traces.horizon, 40.0);
        for i in 0..ps.len() {
            let evs = drain(&mut src2, i);
            let tr = &traces.pages[i];
            let total = tr.changes.len() + tr.cis.len() + tr.requests.len();
            assert_eq!(evs.len(), total, "page {i}");
            assert!(tr.changes.windows(2).all(|w| w[0] <= w[1]));
            assert!(tr.cis.windows(2).all(|w| w[0] <= w[1]));
            assert!(tr.requests.windows(2).all(|w| w[0] <= w[1]));
        }
    }

    #[test]
    fn invalid_delay_is_rejected_at_construction() {
        let ps = [page(1.0, 1.0, 0.5, 0.5)];
        for delay in [
            CisDelay::Exponential { mean: 0.0 },
            CisDelay::Exponential { mean: -1.0 },
            CisDelay::Exponential { mean: f64::NAN },
            CisDelay::Poisson { mean: -1.0, unit: 0.1 },
            CisDelay::Poisson { mean: 6.0, unit: f64::NAN },
        ] {
            let mut rng = Rng::new(1);
            assert!(
                StreamedSource::new(&ps, 10.0, delay, &mut rng).is_err(),
                "{delay:?} must be rejected"
            );
        }
    }
}
