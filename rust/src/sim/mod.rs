//! Poisson event substrate and the discrete-tick crawl simulator.
//!
//! [`events`] generates per-page change / request / CIS event traces
//! (with optional CIS delivery delays, Appendix C); [`engine`] replays
//! them against a [`crate::sched::CrawlScheduler`] at tick times
//! `t_j = j/R` (supporting the Appendix-D bandwidth schedule changes),
//! pushing `on_cis`/`on_crawl` lifecycle events and accounting
//! freshness per request; [`metrics`] aggregates accuracy and empirical
//! crawl rates across repetitions.
//!
//! The engine is a streaming k-way merge over the per-page traces with
//! all scratch in a reusable [`SimWorkspace`]; [`simulate_reference`]
//! keeps the merged-sort implementation as the parity oracle and bench
//! baseline.

pub mod engine;
pub mod events;
pub mod metrics;

pub use engine::{
    simulate, simulate_reference, simulate_with, BandwidthSchedule, SimConfig, SimResult,
    SimWorkspace,
};
pub use events::{generate_page_trace_from, generate_traces, CisDelay, EventTraces, PageTrace};
