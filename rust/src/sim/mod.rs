//! Poisson event substrate and the discrete-tick crawl simulator.
//!
//! [`events`] generates per-page change / request / CIS event traces
//! (with optional CIS delivery delays, Appendix C); [`engine`] replays
//! them against a [`engine::Scheduler`] at tick times `t_j = j/R`
//! (supporting the Appendix-D bandwidth schedule changes) and accounts
//! freshness per request; [`metrics`] aggregates accuracy and empirical
//! crawl rates across repetitions.
//!
//! The engine is a streaming k-way merge over the per-page traces with
//! all scratch in a reusable [`SimWorkspace`]; [`simulate_reference`]
//! keeps the merged-sort implementation as the parity oracle and bench
//! baseline.

pub mod engine;
pub mod events;
pub mod metrics;

pub use engine::{
    PageState, Scheduler, SimConfig, SimResult, SimWorkspace, simulate, simulate_reference,
    simulate_with,
};
pub use events::{CisDelay, EventTraces, generate_traces};
