//! Poisson event substrate and the discrete-tick crawl simulator.
//!
//! [`events`] generates per-page change / request / CIS event traces
//! (with optional CIS delivery delays, Appendix C); [`source`] is the
//! lazy alternative — per-page [`source::PageEventSource`] cursors
//! that sample each next arrival on demand (`O(m)` memory instead of
//! `O(total events)`), plus the exact [`source::ReplaySource`] adapter
//! over pre-built traces; [`engine`] replays either against a
//! [`crate::sched::CrawlScheduler`] at tick times `t_j = j/R`
//! (supporting the Appendix-D bandwidth schedule changes), pushing
//! `on_cis`/`on_crawl` lifecycle events and accounting freshness per
//! request; [`metrics`] aggregates accuracy and empirical crawl rates
//! across repetitions.
//!
//! The engine is a streaming k-way merge over a flat per-page merge
//! frontier with all scratch in a reusable [`SimWorkspace`];
//! [`simulate_reference`] keeps the merged-sort implementation as the
//! parity oracle and bench baseline.

pub mod engine;
pub mod events;
pub mod metrics;
pub mod source;

pub use engine::{
    simulate, simulate_reference, simulate_served, simulate_served_with,
    simulate_source_served_traced_with, simulate_source_served_with, simulate_source_with,
    simulate_streamed, simulate_streamed_served_with, simulate_streamed_traced_with,
    simulate_streamed_with, simulate_traced_with, simulate_with, BandwidthSchedule, SimConfig,
    SimResult, SimWorkspace,
};
pub use events::{generate_page_trace_from, generate_traces, CisDelay, EventTraces, PageTrace};
pub use source::{EventSource, PageEventSource, ReplaySource, StreamedSource, TraceMode};
