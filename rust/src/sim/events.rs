//! Event-trace generation for a problem instance.
//!
//! Per page `i` (model of §3):
//! - change events ~ Poisson(Δ_i);
//! - each change emits a CIS with probability λ_i (recall);
//! - false-positive CIS ~ Poisson(ν_i);
//! - request events ~ Poisson(μ_i^raw) (raw, unnormalized rates);
//! - CIS delivery may be delayed (Appendix C).

use crate::error::Error;
use crate::params::PageParams;
use crate::rngkit::{self, RandomSource, Rng};

/// CIS delivery-delay model (Appendix C).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CisDelay {
    /// Signals are delivered instantaneously (the main-paper model).
    None,
    /// Exponential delay with the given mean.
    Exponential {
        /// Mean delay (must be positive and finite).
        mean: f64,
    },
    /// Poisson-distributed delay: `delay = Poisson(mean) * unit`
    /// (the Appendix-C experiment draws the delay "from the Poisson
    /// distribution with ν=6"; `unit` converts counts to time).
    Poisson {
        /// Mean of the Poisson count (must be ≥ 0 and finite).
        mean: f64,
        /// Time per count unit (must be ≥ 0 and finite).
        unit: f64,
    },
}

impl CisDelay {
    /// Check the model's parameters. Every entry point that accepts a
    /// delay from the outside calls this — the streamed constructors
    /// ([`crate::sim::StreamedSource::new`], the scenario streamed
    /// engine, `CisFeed`) and the materialized drivers
    /// (`figures::common::run_rep`, `CrawlerBuilder::run_scenario`) —
    /// so a bad mean surfaces as an error on both trace modes instead
    /// of the silent `mean.max(1e-12)` clamp [`Self::sample`] used to
    /// apply. Direct [`generate_traces`] callers own the check
    /// themselves.
    pub fn validate(&self) -> crate::Result<()> {
        match *self {
            CisDelay::None => Ok(()),
            CisDelay::Exponential { mean } => {
                if mean > 0.0 && mean.is_finite() {
                    Ok(())
                } else {
                    Err(Error::InvalidParam(format!(
                        "CisDelay::Exponential mean must be > 0 and finite, got {mean}"
                    )))
                }
            }
            CisDelay::Poisson { mean, unit } => {
                if mean >= 0.0 && mean.is_finite() && unit >= 0.0 && unit.is_finite() {
                    Ok(())
                } else {
                    Err(Error::InvalidParam(format!(
                        "CisDelay::Poisson mean/unit must be ≥ 0 and finite, \
                         got mean={mean} unit={unit}"
                    )))
                }
            }
        }
    }

    /// Sample one delivery delay. Parameters are assumed valid (see
    /// [`Self::validate`]); there is no silent clamping.
    pub(crate) fn sample<R: RandomSource>(&self, rng: &mut R) -> f64 {
        match *self {
            CisDelay::None => 0.0,
            CisDelay::Exponential { mean } => {
                debug_assert!(mean > 0.0 && mean.is_finite());
                rngkit::exponential(rng, 1.0 / mean)
            }
            CisDelay::Poisson { mean, unit } => {
                debug_assert!(mean >= 0.0 && unit >= 0.0);
                rngkit::poisson(rng, mean) as f64 * unit
            }
        }
    }
}

/// One page's generated events (all sorted by time).
#[derive(Debug, Clone, Default)]
pub struct PageTrace {
    /// True content-change times.
    pub changes: Vec<f64>,
    /// CIS delivery times (true + false signals merged, after delay).
    pub cis: Vec<f64>,
    /// Request times.
    pub requests: Vec<f64>,
}

/// All pages' traces for one repetition.
#[derive(Debug, Clone)]
pub struct EventTraces {
    /// Per-page traces.
    pub pages: Vec<PageTrace>,
    /// Horizon the traces cover.
    pub horizon: f64,
}

impl EventTraces {
    /// Total number of events of each kind (changes, cis, requests).
    pub fn counts(&self) -> (usize, usize, usize) {
        let c = self.pages.iter().map(|p| p.changes.len()).sum();
        let s = self.pages.iter().map(|p| p.cis.len()).sum();
        let r = self.pages.iter().map(|p| p.requests.len()).sum();
        (c, s, r)
    }
}

/// Generate traces for every page of an instance over `[0, horizon)`.
///
/// `request_rates` are the *raw* (unnormalized) μ_i; pass the raw
/// instance rates so request counts match the paper's ≈ m·T/2 events.
pub fn generate_traces(
    pages: &[PageParams],
    horizon: f64,
    delay: CisDelay,
    rng: &mut Rng,
) -> EventTraces {
    let traces = pages
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let mut prng = rng.split(i as u64);
            generate_page_trace(p, horizon, delay, &mut prng)
        })
        .collect();
    EventTraces { pages: traces, horizon }
}

fn generate_page_trace(
    p: &PageParams,
    horizon: f64,
    delay: CisDelay,
    rng: &mut Rng,
) -> PageTrace {
    generate_page_trace_from(p, 0.0, horizon, delay, rng)
}

/// Generate one page's events over `[t0, horizon)` — the dynamic-world
/// path: a page born (or re-parameterized) at `t0` gets a fresh
/// realization for the rest of the run. With `t0 = 0` this is exactly
/// the whole-horizon generator (identical draw order, and `x + 0.0`
/// is bit-exact for the strictly-positive Poisson arrival times), so
/// the static path delegates here.
pub fn generate_page_trace_from(
    p: &PageParams,
    t0: f64,
    horizon: f64,
    delay: CisDelay,
    rng: &mut Rng,
) -> PageTrace {
    let span = horizon - t0;
    if !(span > 0.0) {
        return PageTrace::default();
    }
    let mut changes = rngkit::poisson_process(rng, p.delta, span);
    for t in changes.iter_mut() {
        *t += t0;
    }
    let mut cis: Vec<f64> = Vec::new();
    // signalled changes
    for &t in &changes {
        if rng.bernoulli(p.lam) {
            let d = t + delay.sample(rng);
            if d < horizon {
                cis.push(d);
            }
        }
    }
    // false positives
    for t in rngkit::poisson_process(rng, p.nu, span) {
        let d = t0 + t + delay.sample(rng);
        if d < horizon {
            cis.push(d);
        }
    }
    // total_cmp: a NaN delivery time (impossible with validated delay
    // params, but this sort must never be the thing that panics) sorts
    // to the end instead of aborting the repetition
    cis.sort_unstable_by(f64::total_cmp);
    let mut requests = rngkit::poisson_process(rng, p.mu, span);
    for t in requests.iter_mut() {
        *t += t0;
    }
    PageTrace { changes, cis, requests }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn page(delta: f64, mu: f64, lam: f64, nu: f64) -> PageParams {
        PageParams { delta, mu, lam, nu }
    }

    #[test]
    fn counts_match_rates() {
        let mut rng = Rng::new(1);
        let pages: Vec<PageParams> = (0..50).map(|_| page(0.5, 0.8, 0.6, 0.2)).collect();
        let tr = generate_traces(&pages, 200.0, CisDelay::None, &mut rng);
        let (c, s, r) = tr.counts();
        // E[changes] = 50*0.5*200 = 5000; E[cis] = 50*(0.6*0.5+0.2)*200 = 5000
        // E[requests] = 50*0.8*200 = 8000
        assert!((c as f64 - 5000.0).abs() < 300.0, "changes {c}");
        assert!((s as f64 - 5000.0).abs() < 300.0, "cis {s}");
        assert!((r as f64 - 8000.0).abs() < 350.0, "requests {r}");
    }

    #[test]
    fn traces_sorted_and_in_horizon() {
        let mut rng = Rng::new(2);
        let tr = generate_traces(
            &[page(1.0, 1.0, 0.5, 0.5)],
            100.0,
            CisDelay::Exponential { mean: 0.5 },
            &mut rng,
        );
        let p = &tr.pages[0];
        for v in [&p.changes, &p.cis, &p.requests] {
            assert!(v.windows(2).all(|w| w[0] <= w[1]));
            assert!(v.iter().all(|&t| (0.0..100.0).contains(&t)));
        }
    }

    #[test]
    fn zero_recall_means_only_false_cis() {
        let mut rng = Rng::new(3);
        let tr = generate_traces(&[page(2.0, 0.1, 0.0, 0.3)], 500.0, CisDelay::None, &mut rng);
        let n = tr.pages[0].cis.len() as f64;
        assert!((n - 150.0).abs() < 40.0, "cis count {n}");
    }

    #[test]
    fn no_cis_when_lam_and_nu_zero() {
        let mut rng = Rng::new(4);
        let tr = generate_traces(&[page(2.0, 0.1, 0.0, 0.0)], 500.0, CisDelay::None, &mut rng);
        assert!(tr.pages[0].cis.is_empty());
    }

    #[test]
    fn delay_shifts_cis_later() {
        // Seed-paired: the lazy source draws change arrivals and signal
        // coins on the change substream and every delay on the CIS
        // substream, so the same seed gives the SAME signalled-change
        // realization under every delay model. With λ=1, ν=0 the
        // undelayed CIS are exactly the change times and the delayed
        // CIS are those same times plus i.i.d. delays — a paired,
        // strictly-positive mean shift (not the old `mean1 > mean0 - 5`
        // tautology).
        use crate::sim::source::StreamedSource;
        let pages = [page(1.0, 0.0, 1.0, 0.0)];
        let horizon = 200.0;
        let delay = CisDelay::Poisson { mean: 6.0, unit: 0.01 }; // E[shift] = 0.06
        let mut r0 = Rng::new(5);
        let mut r1 = Rng::new(5);
        let t0 = StreamedSource::new(&pages, horizon, CisDelay::None, &mut r0)
            .unwrap()
            .materialize();
        let t1 = StreamedSource::new(&pages, horizon, delay, &mut r1).unwrap().materialize();
        assert_eq!(
            t0.pages[0].changes, t1.pages[0].changes,
            "delay draws must not perturb the change substream"
        );
        let (a, b) = (&t0.pages[0].cis, &t1.pages[0].cis);
        assert_eq!(a, &t0.pages[0].changes, "λ=1, no delay: CIS are the change times");
        // horizon truncation can only drop late deliveries
        assert!(b.len() <= a.len());
        assert!(b.len() as f64 >= a.len() as f64 * 0.95, "unexpected truncation");
        // pointwise domination of order statistics: each delayed
        // delivery is its change time plus a non-negative delay
        for (k, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
            assert!(y >= x, "delayed CIS[{k}] = {y} earlier than undelayed {x}");
        }
        let n = b.len();
        let mean0: f64 = a[..n].iter().sum::<f64>() / n as f64;
        let mean1: f64 = b.iter().sum::<f64>() / n as f64;
        let shift = mean1 - mean0;
        assert!(shift > 0.0, "delay must shift the mean strictly later, got {shift}");
        assert!((shift - 0.06).abs() < 0.03, "mean shift {shift} far from E[delay]=0.06");
    }

    #[test]
    fn from_t0_zero_is_the_whole_horizon_generator() {
        // the static generator delegates to the from-t0 form; pin the
        // bit-identity the delegation relies on
        let p = page(1.0, 1.0, 0.5, 0.5);
        let mut a = Rng::new(11);
        let mut b = Rng::new(11);
        let whole = generate_traces(&[p], 50.0, CisDelay::Exponential { mean: 0.3 }, &mut a);
        let mut brng = b.split(0);
        let from0 =
            generate_page_trace_from(&p, 0.0, 50.0, CisDelay::Exponential { mean: 0.3 }, &mut brng);
        assert_eq!(whole.pages[0].changes, from0.changes);
        assert_eq!(whole.pages[0].cis, from0.cis);
        assert_eq!(whole.pages[0].requests, from0.requests);
    }

    #[test]
    fn from_t0_events_live_in_their_window() {
        let p = page(2.0, 1.5, 0.5, 0.4);
        let mut rng = Rng::new(12);
        let tr = generate_page_trace_from(&p, 30.0, 50.0, CisDelay::None, &mut rng);
        for v in [&tr.changes, &tr.cis, &tr.requests] {
            assert!(v.windows(2).all(|w| w[0] <= w[1]));
            assert!(v.iter().all(|&t| (30.0..50.0).contains(&t)), "event outside window");
        }
        // a zero-width (or inverted) window yields nothing
        let empty = generate_page_trace_from(&p, 50.0, 50.0, CisDelay::None, &mut rng);
        assert!(empty.changes.is_empty() && empty.cis.is_empty() && empty.requests.is_empty());
    }

    #[test]
    fn deterministic_for_seed() {
        let pages = [page(1.0, 1.0, 0.5, 0.5)];
        let mut a = Rng::new(9);
        let mut b = Rng::new(9);
        let ta = generate_traces(&pages, 50.0, CisDelay::None, &mut a);
        let tb = generate_traces(&pages, 50.0, CisDelay::None, &mut b);
        assert_eq!(ta.pages[0].changes, tb.pages[0].changes);
        assert_eq!(ta.pages[0].cis, tb.pages[0].cis);
    }
}
