//! Discrete-tick crawl simulator.
//!
//! Replays generated event traces against a
//! [`CrawlScheduler`](crate::sched::CrawlScheduler): one crawl per tick
//! (`t_j = j/R`, with `R` allowed to change over time per the
//! Appendix-D experiment), exact freshness accounting per request event,
//! and the Appendix-C CIS discard window. The engine is purely a
//! *driver*: it pushes `on_start` / `on_cis` / `on_crawl` lifecycle
//! events and asks `select(t)` at each tick — schedulers own their own
//! per-page state (see [`crate::sched`]), the engine only keeps what
//! freshness accounting and the discard window need.
//!
//! ## Streaming engine and the merge frontier
//!
//! The hot path is a *k-way streaming merge* over per-page event
//! sources ([`crate::sim::source::EventSource`]): each page has
//! exactly one live entry in a small binary min-heap keyed by `(time,
//! kind, page)`, regenerated only when it is popped (the engine
//! consumes the event, asks the page's source for its next one and
//! re-pushes) — the per-event work is one `advance` on the page's
//! source instead of re-deriving a minimum over three trace cursors.
//! The workspace additionally keeps a flat SoA **merge frontier**
//! (per-page pending `(time, kind)`) as debug-mode bookkeeping: debug
//! builds assert every popped entry against it, pinning the
//! one-live-entry-per-page invariant; release builds elide the stores
//! since heap entries carry the same pair. No merged global event
//! `Vec` is ever materialized and nothing is sorted per repetition.
//! All per-repetition scratch lives in a [`SimWorkspace`] that callers
//! reset-and-reuse across repetitions (the parallel cell driver in
//! `figures::common` gives one to each worker thread).
//!
//! Two sources drive the same loop:
//!
//! - [`simulate_with`] replays pre-materialized traces through a
//!   [`crate::sim::source::ReplaySource`] — bit-identical to the
//!   pre-frontier engine (same per-page emission order, same heap
//!   total order);
//! - [`simulate_streamed_with`] runs a
//!   [`crate::sim::source::StreamedSource`] that samples each page's
//!   next arrival on demand — `O(m)` memory for the whole repetition,
//!   however long the horizon.
//!
//! [`simulate_reference`] keeps the straightforward merged-sort
//! implementation: it is the parity oracle for the streaming engine and
//! the pre-change baseline lane of `benches/perf.rs`. All engines apply
//! simultaneous events in the same total order `(time, kind, page)` with
//! kinds ordered change < CIS < request, so replay outputs are
//! bit-identical across all three.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::error::Error;
use crate::params::PageParams;
use crate::rngkit::Rng;
use crate::sched::CrawlScheduler;
use crate::serving::ServingSession;
use crate::sim::events::{CisDelay, EventTraces};
use crate::sim::source::{EventSource, ReplaySource, StreamedSource};
use crate::util::OrdF64;

/// A bandwidth schedule: piecewise-constant R over time.
///
/// The segment invariants (first segment starts at 0, starts strictly
/// sorted, every rate positive and finite) are *enforced at
/// construction* — [`BandwidthSchedule::new`] returns `Err` on a bad
/// schedule instead of leaving the tick loop to divide by zero or run
/// backwards. The segment list is private so no caller can bypass the
/// check.
#[derive(Debug, Clone)]
pub struct BandwidthSchedule {
    /// `(start_time, rate)` segments, sorted by start time; the first
    /// segment starts at 0 (validated invariants).
    segments: Vec<(f64, f64)>,
}

impl BandwidthSchedule {
    /// Validated construction from `(start_time, rate)` segments.
    ///
    /// Errors unless: the list is non-empty, the first start is exactly
    /// 0, starts are strictly increasing and finite, and every rate is
    /// positive and finite.
    pub fn new(segments: Vec<(f64, f64)>) -> crate::Result<Self> {
        if segments.is_empty() {
            return Err(Error::InvalidParam(
                "bandwidth schedule needs at least one segment".into(),
            ));
        }
        if segments[0].0 != 0.0 {
            return Err(Error::InvalidParam(format!(
                "first bandwidth segment must start at 0, got {}",
                segments[0].0
            )));
        }
        for (k, &(start, rate)) in segments.iter().enumerate() {
            if !start.is_finite() {
                return Err(Error::InvalidParam(format!(
                    "bandwidth segment {k} start must be finite, got {start}"
                )));
            }
            if rate.is_nan() || rate <= 0.0 || !rate.is_finite() {
                return Err(Error::InvalidParam(format!(
                    "bandwidth segment {k} rate must be > 0 and finite, got {rate}"
                )));
            }
            if k > 0 && start <= segments[k - 1].0 {
                return Err(Error::InvalidParam(format!(
                    "bandwidth segment starts must be strictly increasing: \
                     segment {k} starts at {start} after {}",
                    segments[k - 1].0
                )));
            }
        }
        Ok(Self { segments })
    }

    /// Constant bandwidth. Errors unless `r` is positive and finite —
    /// the same validated construction as [`Self::new`] (this used to
    /// be the sim layer's last panic-on-bad-input constructor).
    pub fn constant(r: f64) -> crate::Result<Self> {
        Self::new(vec![(0.0, r)])
    }

    /// The validated `(start_time, rate)` segments.
    pub fn segments(&self) -> &[(f64, f64)] {
        &self.segments
    }

    /// Index of the segment in effect at time `t` (the last segment
    /// whose start is ≤ `t`; segment 0 for `t` before the first start).
    #[inline]
    pub fn segment_at(&self, t: f64) -> usize {
        // binary search instead of the old linear scan from index 0:
        // callers inside the tick loop additionally keep a monotone
        // cursor, but one-off queries stay O(log n)
        let idx = self.segments.partition_point(|&(start, _)| start <= t);
        idx.saturating_sub(1)
    }

    /// Rate in effect at time `t`.
    #[inline]
    pub fn rate_at(&self, t: f64) -> f64 {
        self.segments[self.segment_at(t)].1
    }
}

/// Simulation configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Bandwidth schedule (ticks at spacing `1/R(t)`).
    pub bandwidth: BandwidthSchedule,
    /// Horizon T.
    pub horizon: f64,
    /// Appendix-C discard window: CIS delivered within `window` after a
    /// crawl of the same page are dropped before reaching the scheduler.
    pub cis_discard_window: Option<f64>,
    /// If set, record a rolling-accuracy timeline over the last `k`
    /// requests, sampled at every tick (Appendix D / Figure 9).
    pub timeline_window: Option<usize>,
}

impl SimConfig {
    /// Constant-rate config with no extras. Errors when `r` is not a
    /// valid bandwidth (see [`BandwidthSchedule::constant`]).
    pub fn new(r: f64, horizon: f64) -> crate::Result<Self> {
        Ok(Self {
            bandwidth: BandwidthSchedule::constant(r)?,
            horizon,
            cis_discard_window: None,
            timeline_window: None,
        })
    }
}

/// Outcome of one simulated repetition.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Fraction of requests served fresh.
    pub accuracy: f64,
    /// Total request events.
    pub requests: u64,
    /// Requests that hit a fresh copy.
    pub fresh_hits: u64,
    /// Crawls per page.
    pub crawl_counts: Vec<u32>,
    /// Total ticks executed.
    pub ticks: u64,
    /// Optional (t, rolling accuracy) samples.
    pub timeline: Vec<(f64, f64)>,
}

impl SimResult {
    /// Empirical crawl rate per page (crawls / horizon).
    pub fn empirical_rates(&self, horizon: f64) -> Vec<f64> {
        self.crawl_counts.iter().map(|&c| c as f64 / horizon).collect()
    }
}

/// Event kinds in merge order: simultaneous events apply change-first,
/// request-last (a request at the exact instant of a change sees stale
/// content; both engines share this total order). `pub(crate)` because
/// the dynamic-world engine (`crate::scenario::engine`) and the event
/// sources (`crate::sim::source`) speak the same kind ranks — the
/// scenario engine extends the identical k-way merge with a
/// world-event stream and its empty-scenario run is pinned
/// bit-identical to [`simulate_with`].
pub(crate) const KIND_CHANGE: u8 = 0;
pub(crate) const KIND_CIS: u8 = 1;
pub(crate) const KIND_REQUEST: u8 = 2;

/// Reusable per-repetition scratch of the streaming engine.
///
/// Owns every allocation the merge engine needs: the engine-side
/// freshness state (dirty bits + last-crawl times for the discard
/// window), crawl counters, the rolling-accuracy ring, the k-way merge
/// heap, the SoA merge frontier (per-page next-event time/kind) and
/// the cursor pool lent to the replay adapter. `reset` clears without
/// releasing capacity, so a workspace threaded through `R` repetitions
/// of an `m`-page cell allocates O(m) once instead of O(E log E) work
/// and O(E) memory per repetition.
#[derive(Debug, Default)]
pub struct SimWorkspace {
    /// Last crawl time per page (drives the Appendix-C discard window).
    /// Fields are `pub(crate)` so the fault engine
    /// ([`crate::fault::engine`]) drives the identical merge loop over
    /// the same scratch.
    pub(crate) last_crawl: Vec<f64>,
    pub(crate) changed: Vec<bool>,
    pub(crate) crawl_counts: Vec<u32>,
    pub(crate) ring: Vec<bool>,
    pub(crate) heap: BinaryHeap<Reverse<(OrdF64, u8, u32)>>,
    /// Merge frontier, time column: page `i`'s pending event time
    /// (`INFINITY` = exhausted). Debug-mode bookkeeping only: heap
    /// entries carry the same `(time, kind)` pair, so release builds
    /// skip these stores entirely; debug builds use the columns to
    /// assert the one-live-entry-per-page invariant on every pop.
    pub(crate) frontier_time: Vec<f64>,
    /// Merge frontier, kind column (debug-mode bookkeeping, as above).
    pub(crate) frontier_kind: Vec<u8>,
    /// Cursor pool lent to [`ReplaySource`] between repetitions.
    pub(crate) cursor_pool: Vec<[usize; 3]>,
}

impl SimWorkspace {
    /// Empty workspace; buffers grow on first use and are reused after.
    pub fn new() -> Self {
        Self::default()
    }

    pub(crate) fn reset(&mut self, m: usize) {
        self.last_crawl.clear();
        self.last_crawl.resize(m, 0.0);
        self.changed.clear();
        self.changed.resize(m, false);
        self.crawl_counts.clear();
        self.crawl_counts.resize(m, 0);
        self.ring.clear();
        self.heap.clear();
        #[cfg(debug_assertions)]
        {
            self.frontier_time.clear();
            self.frontier_time.resize(m, f64::INFINITY);
            self.frontier_kind.clear();
            self.frontier_kind.resize(m, 0);
        }
    }

    /// Record page `i`'s pending frontier event (debug builds only —
    /// release builds rely on the heap entry alone).
    #[inline]
    pub(crate) fn set_frontier(&mut self, i: usize, ev: Option<(f64, u8)>) {
        #[cfg(debug_assertions)]
        {
            let (t, k) = ev.unwrap_or((f64::INFINITY, 0));
            self.frontier_time[i] = t;
            self.frontier_kind[i] = k;
        }
        #[cfg(not(debug_assertions))]
        let _ = (i, ev);
    }
}

/// Run one repetition of `scheduler` against `traces`.
///
/// Convenience wrapper over [`simulate_with`] with a throwaway
/// workspace; repetition loops should allocate one [`SimWorkspace`] and
/// reuse it.
pub fn simulate(
    traces: &EventTraces,
    cfg: &SimConfig,
    scheduler: &mut dyn CrawlScheduler,
) -> SimResult {
    let mut ws = SimWorkspace::new();
    simulate_with(&mut ws, traces, cfg, scheduler)
}

/// Run one repetition over pre-materialized traces using caller-owned
/// scratch: the traces replay through a [`ReplaySource`] (borrowing
/// the workspace's cursor pool), bit-identical to the pre-frontier
/// streaming engine.
pub fn simulate_with(
    ws: &mut SimWorkspace,
    traces: &EventTraces,
    cfg: &SimConfig,
    scheduler: &mut dyn CrawlScheduler,
) -> SimResult {
    let mut source =
        ReplaySource::with_cursors(&traces.pages, std::mem::take(&mut ws.cursor_pool));
    let res = simulate_source_with(ws, &mut source, cfg, scheduler);
    ws.cursor_pool = source.into_cursors();
    res
}

/// Run one repetition over a lazy [`StreamedSource`] (the `O(m)`-memory
/// path) using caller-owned scratch. The source is single-pass, so it
/// is taken **by value** — reusing one across repetitions (which would
/// silently yield a zero-event run) is a compile error; build a fresh
/// source per repetition (construction is the generation work).
pub fn simulate_streamed_with(
    ws: &mut SimWorkspace,
    mut source: StreamedSource,
    cfg: &SimConfig,
    scheduler: &mut dyn CrawlScheduler,
) -> SimResult {
    simulate_source_with(ws, &mut source, cfg, scheduler)
}

/// Convenience: build the lazy sources for `pages` from `rng` (same
/// per-page keying as `generate_traces`) and run one repetition — the
/// streamed analogue of `generate_traces` + [`simulate`].
pub fn simulate_streamed(
    pages: &[PageParams],
    cfg: &SimConfig,
    delay: CisDelay,
    rng: &mut Rng,
    scheduler: &mut dyn CrawlScheduler,
) -> crate::Result<SimResult> {
    let source = StreamedSource::new(pages, cfg.horizon, delay, rng)?;
    let mut ws = SimWorkspace::new();
    Ok(simulate_streamed_with(&mut ws, source, cfg, scheduler))
}

/// The merge engine, generic over the event source: seed the frontier
/// + heap with each page's first event, then replay in `(time, kind,
/// page)` order, regenerating a page's heap entry only when its
/// current entry is popped.
pub fn simulate_source_with<S: EventSource>(
    ws: &mut SimWorkspace,
    source: &mut S,
    cfg: &SimConfig,
    scheduler: &mut dyn CrawlScheduler,
) -> SimResult {
    simulate_source_served_with(ws, source, cfg, scheduler, None)
}

/// [`simulate_with`] with a serving layer attached: user requests from
/// the session's traffic stream are answered from its
/// [`crate::serving::FreshnessCache`] as the merge loop replays. Read
/// the results off the session afterwards
/// ([`ServingSession::metrics`]).
pub fn simulate_served_with(
    ws: &mut SimWorkspace,
    traces: &EventTraces,
    cfg: &SimConfig,
    scheduler: &mut dyn CrawlScheduler,
    serving: &mut ServingSession,
) -> SimResult {
    let mut source =
        ReplaySource::with_cursors(&traces.pages, std::mem::take(&mut ws.cursor_pool));
    let res = simulate_source_served_with(ws, &mut source, cfg, scheduler, Some(serving));
    ws.cursor_pool = source.into_cursors();
    res
}

/// [`simulate_served_with`] with a throwaway workspace.
pub fn simulate_served(
    traces: &EventTraces,
    cfg: &SimConfig,
    scheduler: &mut dyn CrawlScheduler,
    serving: &mut ServingSession,
) -> SimResult {
    let mut ws = SimWorkspace::new();
    simulate_served_with(&mut ws, traces, cfg, scheduler, serving)
}

/// [`simulate_streamed_with`] with a serving layer attached (the
/// `O(m)`-memory lazy path).
pub fn simulate_streamed_served_with(
    ws: &mut SimWorkspace,
    mut source: StreamedSource,
    cfg: &SimConfig,
    scheduler: &mut dyn CrawlScheduler,
    serving: &mut ServingSession,
) -> SimResult {
    simulate_source_served_with(ws, &mut source, cfg, scheduler, Some(serving))
}

/// The merge engine with an *optional* serving layer threaded through
/// the loop. `None` (or a session over empty traffic, whose pending
/// time is always `INFINITY`) takes exactly the branch structure of
/// the plain engine with zero extra RNG draws — the zero-traffic
/// bit-parity pinned by `tests/serving_parity.rs`. With traffic
/// attached, pending requests interleave by time; a request tied with
/// a trace event is served *after* it (so a request at a change's
/// exact instant sees the stale copy, matching the engine's own
/// `(time, kind, page)` total order, and a request at a tick time is
/// served before that tick's crawl).
pub fn simulate_source_served_with<S: EventSource>(
    ws: &mut SimWorkspace,
    source: &mut S,
    cfg: &SimConfig,
    scheduler: &mut dyn CrawlScheduler,
    serving: Option<&mut ServingSession>,
) -> SimResult {
    simulate_source_served_traced_with(ws, source, cfg, scheduler, serving, None)
}

/// [`simulate_served_with`] with an optional serving session AND an
/// optional decision-trace handle (see [`crate::trace`]) — the replay
/// analogue of [`simulate_streamed_traced_with`].
pub fn simulate_traced_with(
    ws: &mut SimWorkspace,
    traces: &EventTraces,
    cfg: &SimConfig,
    scheduler: &mut dyn CrawlScheduler,
    serving: Option<&mut ServingSession>,
    tr: Option<&crate::trace::TraceHandle>,
) -> SimResult {
    let mut source =
        ReplaySource::with_cursors(&traces.pages, std::mem::take(&mut ws.cursor_pool));
    let res = simulate_source_served_traced_with(ws, &mut source, cfg, scheduler, serving, tr);
    ws.cursor_pool = source.into_cursors();
    res
}

/// [`simulate_streamed_served_with`] generalized: optional serving
/// session, optional decision-trace handle.
pub fn simulate_streamed_traced_with(
    ws: &mut SimWorkspace,
    mut source: StreamedSource,
    cfg: &SimConfig,
    scheduler: &mut dyn CrawlScheduler,
    serving: Option<&mut ServingSession>,
    tr: Option<&crate::trace::TraceHandle>,
) -> SimResult {
    simulate_source_served_traced_with(ws, &mut source, cfg, scheduler, serving, tr)
}

/// The full merge engine: optional serving layer and optional trace
/// handle threaded through the loop. Tracing is strictly observational
/// — `tr` gates only event emission, wall-clock span timing and the
/// `--verbose` progress meter; it draws no RNG, adds no events to the
/// merge and never changes a pick, so traced and untraced runs are
/// bit-identical (pinned by `tests/trace_parity.rs`).
pub fn simulate_source_served_traced_with<S: EventSource>(
    ws: &mut SimWorkspace,
    source: &mut S,
    cfg: &SimConfig,
    scheduler: &mut dyn CrawlScheduler,
    mut serving: Option<&mut ServingSession>,
    tr: Option<&crate::trace::TraceHandle>,
) -> SimResult {
    use crate::trace::{self, SpanKind, TraceEvent};
    let m = source.len();
    ws.reset(m);
    scheduler.on_start(m);
    for i in 0..m {
        if let Some((t, k)) = source.first(i) {
            ws.set_frontier(i, Some((t, k)));
            ws.heap.push(Reverse((OrdF64(t), k, i as u32)));
        }
    }

    let mut fresh_hits = 0u64;
    let mut requests = 0u64;
    let mut ticks = 0u64;
    let mut ev_count = 0u64; // events applied (merge pops + serves)
    let mut timeline = Vec::new();
    // rolling window of request freshness bits
    let window = cfg.timeline_window.unwrap_or(0);
    let mut ring_pos = 0usize;
    let mut ring_fresh = 0usize;

    let segs = &cfg.bandwidth.segments;
    let mut seg = 0usize; // monotone segment cursor (no rescan per tick)
    let mut t = 0.0f64;
    loop {
        while seg + 1 < segs.len() && segs[seg + 1].0 <= t {
            seg += 1;
        }
        let r = segs[seg].1;
        let next_tick = t + 1.0 / r;
        if next_tick > cfg.horizon {
            break;
        }
        // apply events up to (and including) the tick time; pending
        // user requests interleave by time, serving after any trace
        // event they tie with
        let ev_t0 = trace::span_clock(tr);
        loop {
            if let Some(sv) = serving.as_deref_mut() {
                let ts = sv.next_time();
                if ts <= next_tick {
                    let te = match ws.heap.peek() {
                        Some(&Reverse((OrdF64(t), _, _))) => t,
                        None => f64::INFINITY,
                    };
                    if ts < te {
                        let (st, sp) = sv.pop().expect("pending request");
                        let fresh = sv.serve(sp, st, true);
                        ev_count += 1;
                        trace::emit(tr, || TraceEvent::Serve {
                            t: st,
                            page: sp as u32,
                            fresh: fresh == Some(true),
                            live: fresh.is_some(),
                        });
                        continue;
                    }
                }
            }
            let (et, kind, page) = match ws.heap.peek() {
                Some(&Reverse((OrdF64(et), kind, page))) => (et, kind, page),
                None => break,
            };
            if et > next_tick {
                break;
            }
            ws.heap.pop();
            ev_count += 1;
            let i = page as usize;
            // one live heap entry per page: the popped entry IS the
            // page's frontier
            debug_assert_eq!(ws.frontier_time[i].to_bits(), et.to_bits());
            debug_assert_eq!(ws.frontier_kind[i], kind);
            match kind {
                KIND_CHANGE => {
                    ws.changed[i] = true;
                    if let Some(sv) = serving.as_deref_mut() {
                        sv.on_change(i, et);
                    }
                }
                KIND_REQUEST => {
                    requests += 1;
                    let fresh = !ws.changed[i];
                    if fresh {
                        fresh_hits += 1;
                    }
                    if window > 0 {
                        if ws.ring.len() < window {
                            ws.ring.push(fresh);
                            if fresh {
                                ring_fresh += 1;
                            }
                        } else {
                            if ws.ring[ring_pos] {
                                ring_fresh -= 1;
                            }
                            ws.ring[ring_pos] = fresh;
                            if fresh {
                                ring_fresh += 1;
                            }
                            ring_pos = (ring_pos + 1) % window;
                        }
                    }
                }
                _ => {
                    // KIND_CIS
                    let keep = match cfg.cis_discard_window {
                        Some(w) => et - ws.last_crawl[i] >= w,
                        None => true,
                    };
                    if keep {
                        scheduler.on_cis(i, et);
                        trace::emit(tr, || TraceEvent::Cis { t: et, page });
                    }
                }
            }
            let next = source.advance(i, kind);
            ws.set_frontier(i, next);
            if let Some((nt, nk)) = next {
                ws.heap.push(Reverse((OrdF64(nt), nk, page)));
            }
        }
        trace::span_observe(tr, SpanKind::Events, ev_t0);
        // crawl at the tick
        t = next_tick;
        ticks += 1;
        let sel_t0 = trace::span_clock(tr);
        let pick = scheduler.select(t);
        trace::span_observe(tr, SpanKind::Select, sel_t0);
        if let Some(i) = pick {
            debug_assert!(i < m);
            let was_changed = ws.changed[i];
            scheduler.on_fetch_observed(i, t, was_changed);
            ws.changed[i] = false;
            ws.last_crawl[i] = t;
            ws.crawl_counts[i] += 1;
            scheduler.on_crawl(i, t);
            trace::emit(tr, || TraceEvent::Crawl { t, page: i as u32, changed: was_changed });
            if let Some(sv) = serving.as_deref_mut() {
                sv.on_crawl(i);
            }
        }
        trace::progress(tr, t, cfg.horizon, ev_count, m);
        if window > 0 && !ws.ring.is_empty() {
            timeline.push((t, ring_fresh as f64 / ws.ring.len() as f64));
        }
    }
    // drain remaining request/change events after the final tick,
    // still interleaved with user requests due before the horizon
    loop {
        if let Some(sv) = serving.as_deref_mut() {
            let ts = sv.next_time();
            if ts.is_finite() {
                let te = match ws.heap.peek() {
                    Some(&Reverse((OrdF64(t), _, _))) => t,
                    None => f64::INFINITY,
                };
                if ts < te {
                    let (st, sp) = sv.pop().expect("pending request");
                    let fresh = sv.serve(sp, st, true);
                    trace::emit(tr, || TraceEvent::Serve {
                        t: st,
                        page: sp as u32,
                        fresh: fresh == Some(true),
                        live: fresh.is_some(),
                    });
                    continue;
                }
            }
        }
        let (et, kind, page) = match ws.heap.pop() {
            Some(Reverse((OrdF64(et), kind, page))) => (et, kind, page),
            None => break,
        };
        let i = page as usize;
        match kind {
            KIND_CHANGE => {
                ws.changed[i] = true;
                if let Some(sv) = serving.as_deref_mut() {
                    sv.on_change(i, et);
                }
            }
            KIND_REQUEST => {
                requests += 1;
                if !ws.changed[i] {
                    fresh_hits += 1;
                }
            }
            _ => {}
        }
        let next = source.advance(i, kind);
        ws.set_frontier(i, next);
        if let Some((nt, nk)) = next {
            ws.heap.push(Reverse((OrdF64(nt), nk, page)));
        }
    }

    SimResult {
        accuracy: if requests > 0 { fresh_hits as f64 / requests as f64 } else { f64::NAN },
        requests,
        fresh_hits,
        crawl_counts: ws.crawl_counts.clone(),
        ticks,
        timeline,
    }
}

/// Straightforward reference engine: materialize the merged, time-sorted
/// event list (stable total order `(time, kind, page)`) and replay it.
///
/// This is the pre-change implementation, kept as (a) the parity oracle
/// the streaming engine is tested bit-identical against and (b) the
/// baseline lane of `benches/perf.rs`.
pub fn simulate_reference(
    traces: &EventTraces,
    cfg: &SimConfig,
    scheduler: &mut dyn CrawlScheduler,
) -> SimResult {
    let m = traces.pages.len();
    scheduler.on_start(m);
    // Build the merged, time-sorted event list once.
    let mut events: Vec<(f64, u8, u32)> = Vec::new();
    for (i, p) in traces.pages.iter().enumerate() {
        events.extend(p.changes.iter().map(|&t| (t, KIND_CHANGE, i as u32)));
        events.extend(p.cis.iter().map(|&t| (t, KIND_CIS, i as u32)));
        events.extend(p.requests.iter().map(|&t| (t, KIND_REQUEST, i as u32)));
    }
    // the key is a total order, so an unstable sort is equivalent — and
    // keeps this baseline's cost honest vs the true pre-change engine
    // (total_cmp orders non-NaN keys exactly like the old
    // partial_cmp().unwrap(), minus the NaN abort)
    events.sort_unstable_by(|a, b| {
        a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)).then(a.2.cmp(&b.2))
    });

    let mut last_crawl = vec![0.0f64; m];
    let mut changed = vec![false; m];
    let mut crawl_counts = vec![0u32; m];
    let mut fresh_hits = 0u64;
    let mut requests = 0u64;
    let mut ticks = 0u64;
    let mut timeline = Vec::new();
    let window = cfg.timeline_window.unwrap_or(0);
    let mut ring: Vec<bool> = Vec::with_capacity(window);
    let mut ring_pos = 0usize;
    let mut ring_fresh = 0usize;

    let mut ev = 0usize;
    let mut t = 0.0f64;
    loop {
        let r = cfg.bandwidth.rate_at(t);
        let next_tick = t + 1.0 / r;
        if next_tick > cfg.horizon {
            break;
        }
        while ev < events.len() && events[ev].0 <= next_tick {
            let (et, kind, page) = events[ev];
            let i = page as usize;
            match kind {
                KIND_CHANGE => changed[i] = true,
                KIND_REQUEST => {
                    requests += 1;
                    let fresh = !changed[i];
                    if fresh {
                        fresh_hits += 1;
                    }
                    if window > 0 {
                        if ring.len() < window {
                            ring.push(fresh);
                            if fresh {
                                ring_fresh += 1;
                            }
                        } else {
                            if ring[ring_pos] {
                                ring_fresh -= 1;
                            }
                            ring[ring_pos] = fresh;
                            if fresh {
                                ring_fresh += 1;
                            }
                            ring_pos = (ring_pos + 1) % window;
                        }
                    }
                }
                _ => {
                    let keep = match cfg.cis_discard_window {
                        Some(w) => et - last_crawl[i] >= w,
                        None => true,
                    };
                    if keep {
                        scheduler.on_cis(i, et);
                    }
                }
            }
            ev += 1;
        }
        t = next_tick;
        ticks += 1;
        if let Some(i) = scheduler.select(t) {
            debug_assert!(i < m);
            scheduler.on_fetch_observed(i, t, changed[i]);
            changed[i] = false;
            last_crawl[i] = t;
            crawl_counts[i] += 1;
            scheduler.on_crawl(i, t);
        }
        if window > 0 && !ring.is_empty() {
            timeline.push((t, ring_fresh as f64 / ring.len() as f64));
        }
    }
    while ev < events.len() {
        let (_, kind, page) = events[ev];
        if kind == KIND_REQUEST {
            requests += 1;
            if !changed[page as usize] {
                fresh_hits += 1;
            }
        } else if kind == KIND_CHANGE {
            changed[page as usize] = true;
        }
        ev += 1;
    }

    SimResult {
        accuracy: if requests > 0 { fresh_hits as f64 / requests as f64 } else { f64::NAN },
        requests,
        fresh_hits,
        crawl_counts,
        ticks,
        timeline,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::PageParams;
    use crate::rngkit::Rng;
    use crate::sched::PageTracker;
    use crate::sim::events::{generate_traces, CisDelay, PageTrace};

    /// Round-robin scheduler for engine-level tests.
    struct RoundRobin {
        m: usize,
        next: usize,
    }
    impl CrawlScheduler for RoundRobin {
        fn on_start(&mut self, m: usize) {
            self.m = m;
            self.next = 0;
        }
        fn select(&mut self, _t: f64) -> Option<usize> {
            let i = self.next;
            self.next = (self.next + 1) % self.m;
            Some(i)
        }
    }

    fn traces_from(pages: Vec<PageTrace>, horizon: f64) -> EventTraces {
        EventTraces { pages, horizon }
    }

    #[test]
    fn tick_count_matches_bandwidth() {
        let tr = traces_from(vec![PageTrace::default(); 3], 10.0);
        let cfg = SimConfig::new(5.0, 10.0).unwrap();
        let mut s = RoundRobin { m: 3, next: 0 };
        let res = simulate(&tr, &cfg, &mut s);
        assert_eq!(res.ticks, 50);
        let total: u32 = res.crawl_counts.iter().sum();
        assert_eq!(total, 50);
    }

    #[test]
    fn freshness_accounting_exact() {
        // page changes at t=1.5; requests at t=1.0 (fresh), t=1.6 (stale),
        // crawl at t=2.0 (R=0.5 -> ticks at 2.0, 4.0), request at 2.5 (fresh)
        let tr = traces_from(
            vec![PageTrace {
                changes: vec![1.5],
                cis: vec![],
                requests: vec![1.0, 1.6, 2.5],
            }],
            5.0,
        );
        let cfg = SimConfig::new(0.5, 5.0).unwrap();
        let mut s = RoundRobin { m: 1, next: 0 };
        let res = simulate(&tr, &cfg, &mut s);
        assert_eq!(res.requests, 3);
        assert_eq!(res.fresh_hits, 2);
    }

    /// Crawls page 0 every tick, recording its pending-CIS count first
    /// (exercises the event-driven on_cis/on_crawl bookkeeping).
    struct Capture {
        tracker: PageTracker,
        seen: Vec<u32>,
    }
    impl Capture {
        fn new() -> Self {
            Self { tracker: PageTracker::default(), seen: vec![] }
        }
    }
    impl CrawlScheduler for Capture {
        fn on_start(&mut self, m: usize) {
            self.tracker.reset(m);
        }
        fn on_cis(&mut self, page: usize, _t: f64) {
            self.tracker.on_cis(page);
        }
        fn on_crawl(&mut self, page: usize, t: f64) {
            self.tracker.on_crawl(page, t);
        }
        fn select(&mut self, _t: f64) -> Option<usize> {
            self.seen.push(self.tracker.n_cis(0));
            Some(0)
        }
    }

    #[test]
    fn cis_resets_on_crawl() {
        let tr = traces_from(
            vec![PageTrace { changes: vec![], cis: vec![0.4, 0.9, 1.4], requests: vec![] }],
            3.0,
        );
        let cfg = SimConfig::new(1.0, 3.0).unwrap();
        let mut s = Capture::new();
        let res = simulate(&tr, &cfg, &mut s);
        // tick at t=1: cis 0.4, 0.9 delivered -> n=2; crawl resets
        // tick at t=2: cis 1.4 -> n=1; tick at t=3: none -> 0
        assert_eq!(s.seen, vec![2, 1, 0]);
        assert_eq!(res.crawl_counts[0], 3);
    }

    #[test]
    fn discard_window_drops_fresh_cis() {
        // crawl happens at t=1,2,3; cis at 1.05 (within 0.2 of crawl@1 ->
        // dropped), cis at 2.5 (kept)
        let tr = traces_from(
            vec![PageTrace { changes: vec![], cis: vec![1.05, 2.5], requests: vec![] }],
            4.0,
        );
        let mut cfg = SimConfig::new(1.0, 4.0).unwrap();
        cfg.cis_discard_window = Some(0.2);
        let mut s = Capture::new();
        simulate(&tr, &cfg, &mut s);
        assert_eq!(s.seen, vec![0, 0, 1, 0]);
    }

    #[test]
    fn bandwidth_schedule_changes_tick_density() {
        let tr = traces_from(vec![PageTrace::default()], 10.0);
        let cfg = SimConfig {
            bandwidth: BandwidthSchedule::new(vec![(0.0, 1.0), (5.0, 10.0)]).unwrap(),
            horizon: 10.0,
            cis_discard_window: None,
            timeline_window: None,
        };
        let mut s = RoundRobin { m: 1, next: 0 };
        let res = simulate(&tr, &cfg, &mut s);
        // ~5 ticks in the first half, ~50 in the second
        assert!((res.ticks as i64 - 55).abs() <= 2, "{}", res.ticks);
    }

    #[test]
    fn bandwidth_schedule_validation_rejects_bad_inputs() {
        // the doc-comment invariants are now construction-time errors
        assert!(BandwidthSchedule::new(vec![]).is_err(), "empty");
        assert!(BandwidthSchedule::new(vec![(1.0, 5.0)]).is_err(), "first start nonzero");
        assert!(
            BandwidthSchedule::new(vec![(0.0, 5.0), (3.0, 2.0), (3.0, 4.0)]).is_err(),
            "duplicate start"
        );
        assert!(
            BandwidthSchedule::new(vec![(0.0, 5.0), (4.0, 2.0), (2.0, 4.0)]).is_err(),
            "unsorted starts"
        );
        assert!(BandwidthSchedule::new(vec![(0.0, 0.0)]).is_err(), "zero rate");
        assert!(BandwidthSchedule::new(vec![(0.0, -1.0)]).is_err(), "negative rate");
        assert!(BandwidthSchedule::new(vec![(0.0, f64::NAN)]).is_err(), "NaN rate");
        assert!(
            BandwidthSchedule::new(vec![(0.0, 1.0), (f64::INFINITY, 2.0)]).is_err(),
            "infinite start"
        );
        let ok = BandwidthSchedule::new(vec![(0.0, 1.0), (5.0, 10.0)]).unwrap();
        assert_eq!(ok.segments(), &[(0.0, 1.0), (5.0, 10.0)]);
    }

    #[test]
    fn constant_validates_like_new() {
        // the former assert is now an Err (no panic-on-bad-input
        // constructors left in the sim layer)
        assert!(BandwidthSchedule::constant(0.0).is_err(), "zero rate");
        assert!(BandwidthSchedule::constant(-3.0).is_err(), "negative rate");
        assert!(BandwidthSchedule::constant(f64::NAN).is_err(), "NaN rate");
        assert!(BandwidthSchedule::constant(f64::INFINITY).is_err(), "infinite rate");
        assert_eq!(BandwidthSchedule::constant(2.5).unwrap().segments(), &[(0.0, 2.5)]);
        assert!(SimConfig::new(0.0, 10.0).is_err(), "SimConfig::new propagates");
    }

    #[test]
    fn rate_at_piecewise_constant_semantics() {
        let s = BandwidthSchedule::new(vec![(0.0, 1.0), (5.0, 10.0), (8.0, 2.0)]).unwrap();
        // before / at / inside / boundary-inclusive / past-the-end
        assert_eq!(s.rate_at(-1.0), 1.0); // clamps to the first segment
        assert_eq!(s.rate_at(0.0), 1.0);
        assert_eq!(s.rate_at(4.999), 1.0);
        assert_eq!(s.rate_at(5.0), 10.0); // boundary belongs to the new segment
        assert_eq!(s.rate_at(7.9), 10.0);
        assert_eq!(s.rate_at(8.0), 2.0);
        assert_eq!(s.rate_at(1e9), 2.0);
        assert_eq!(BandwidthSchedule::constant(3.0).unwrap().rate_at(42.0), 3.0);
    }

    #[test]
    fn timeline_rolls_over_requests() {
        let tr = traces_from(
            vec![PageTrace {
                changes: vec![0.1],
                cis: vec![],
                requests: (1..100).map(|i| i as f64 * 0.1).collect(),
            }],
            10.0,
        );
        let mut cfg = SimConfig::new(1.0, 10.0).unwrap();
        cfg.timeline_window = Some(10);
        let mut s = RoundRobin { m: 1, next: 0 };
        let res = simulate(&tr, &cfg, &mut s);
        assert!(!res.timeline.is_empty());
        for &(_, acc) in &res.timeline {
            assert!((0.0..=1.0).contains(&acc));
        }
    }

    #[test]
    fn accuracy_is_one_with_no_changes() {
        let tr = traces_from(
            vec![PageTrace { changes: vec![], cis: vec![], requests: vec![1.0, 2.0] }],
            5.0,
        );
        let cfg = SimConfig::new(1.0, 5.0).unwrap();
        let mut s = RoundRobin { m: 1, next: 0 };
        let res = simulate(&tr, &cfg, &mut s);
        assert_eq!(res.accuracy, 1.0);
    }

    // ---- streaming vs reference parity ----

    /// Deterministic state-dependent scheduler: exercises tau_elap and
    /// n_cis so any divergence in event application order or state
    /// bookkeeping cascades into different crawl choices.
    struct StateScore {
        tracker: PageTracker,
    }
    impl StateScore {
        fn new() -> Self {
            Self { tracker: PageTracker::default() }
        }
    }
    impl CrawlScheduler for StateScore {
        fn on_start(&mut self, m: usize) {
            self.tracker.reset(m);
        }
        fn on_cis(&mut self, page: usize, _t: f64) {
            self.tracker.on_cis(page);
        }
        fn on_crawl(&mut self, page: usize, t: f64) {
            self.tracker.on_crawl(page, t);
        }
        fn select(&mut self, t: f64) -> Option<usize> {
            let mut best = f64::NEG_INFINITY;
            let mut arg = None;
            for i in 0..self.tracker.len() {
                let v = self.tracker.tau_elap(i, t) + 3.7 * self.tracker.n_cis(i) as f64;
                if v > best {
                    best = v;
                    arg = Some(i);
                }
            }
            arg
        }
    }

    fn random_traces(seed: u64, m: usize, horizon: f64, delay: CisDelay) -> EventTraces {
        let mut rng = Rng::new(seed);
        let pages: Vec<PageParams> = (0..m)
            .map(|_| PageParams {
                delta: rng.range(0.05, 1.5),
                mu: rng.range(0.05, 1.5),
                lam: rng.f64(),
                nu: rng.range(0.0, 0.8),
            })
            .collect();
        let mut trng = Rng::new(seed ^ 0xDEAD);
        generate_traces(&pages, horizon, delay, &mut trng)
    }

    fn assert_bit_identical(a: &SimResult, b: &SimResult, ctx: &str) {
        assert_eq!(a.accuracy.to_bits(), b.accuracy.to_bits(), "{ctx}: accuracy");
        assert_eq!(a.requests, b.requests, "{ctx}: requests");
        assert_eq!(a.fresh_hits, b.fresh_hits, "{ctx}: fresh_hits");
        assert_eq!(a.crawl_counts, b.crawl_counts, "{ctx}: crawl_counts");
        assert_eq!(a.ticks, b.ticks, "{ctx}: ticks");
        assert_eq!(a.timeline.len(), b.timeline.len(), "{ctx}: timeline length");
        for (k, (x, y)) in a.timeline.iter().zip(&b.timeline).enumerate() {
            assert_eq!(x.0.to_bits(), y.0.to_bits(), "{ctx}: timeline[{k}].t");
            assert_eq!(x.1.to_bits(), y.1.to_bits(), "{ctx}: timeline[{k}].acc");
        }
    }

    #[test]
    fn streaming_matches_reference_on_randomized_traces() {
        for seed in 0..6u64 {
            let horizon = 40.0;
            let delay = if seed % 2 == 0 {
                CisDelay::None
            } else {
                CisDelay::Exponential { mean: 0.3 }
            };
            let tr = random_traces(seed, 25, horizon, delay);
            let mut cfg = SimConfig::new(4.0, horizon).unwrap();
            if seed % 3 == 0 {
                cfg.cis_discard_window = Some(0.15);
            }
            cfg.timeline_window = Some(16);
            let a = simulate(&tr, &cfg, &mut StateScore::new());
            let b = simulate_reference(&tr, &cfg, &mut StateScore::new());
            assert_bit_identical(&a, &b, &format!("seed {seed}"));
        }
    }

    #[test]
    fn streaming_matches_reference_under_bandwidth_schedule() {
        let tr = random_traces(77, 30, 30.0, CisDelay::None);
        let cfg = SimConfig {
            bandwidth: BandwidthSchedule::new(vec![(0.0, 3.0), (10.0, 9.0), (20.0, 2.0)])
                .unwrap(),
            horizon: 30.0,
            cis_discard_window: Some(0.1),
            timeline_window: Some(8),
        };
        let a = simulate(&tr, &cfg, &mut StateScore::new());
        let b = simulate_reference(&tr, &cfg, &mut StateScore::new());
        assert_bit_identical(&a, &b, "schedule");
    }

    #[test]
    fn streaming_matches_reference_with_lazy_scheduler() {
        use crate::coordinator::lazy::LazyGreedyScheduler;
        use crate::policy::PolicyKind;
        let mut rng = Rng::new(5);
        let pages: Vec<PageParams> = (0..60)
            .map(|_| PageParams {
                delta: rng.range(0.05, 1.0),
                mu: rng.range(0.05, 1.0),
                lam: rng.f64(),
                nu: rng.range(0.1, 0.6),
            })
            .collect();
        let mut trng = Rng::new(6);
        let tr = generate_traces(&pages, 60.0, CisDelay::None, &mut trng);
        let cfg = SimConfig::new(5.0, 60.0).unwrap();
        let mut s1 = LazyGreedyScheduler::new(PolicyKind::GreedyNcis, &pages);
        let mut s2 = LazyGreedyScheduler::new(PolicyKind::GreedyNcis, &pages);
        let a = simulate(&tr, &cfg, &mut s1);
        let b = simulate_reference(&tr, &cfg, &mut s2);
        assert_bit_identical(&a, &b, "lazy scheduler");
    }

    #[test]
    fn workspace_reuse_is_equivalent_to_fresh() {
        let mut ws = SimWorkspace::new();
        for seed in [1u64, 2, 3] {
            // different sizes per rep: reset must fully re-dimension
            let m = 10 + 7 * seed as usize;
            let tr = random_traces(seed, m, 25.0, CisDelay::None);
            let mut cfg = SimConfig::new(3.0, 25.0).unwrap();
            cfg.timeline_window = Some(12);
            let reused = simulate_with(&mut ws, &tr, &cfg, &mut StateScore::new());
            let fresh = simulate(&tr, &cfg, &mut StateScore::new());
            assert_bit_identical(&reused, &fresh, &format!("reuse seed {seed}"));
        }
    }

    #[test]
    fn scheduler_reuse_is_equivalent_to_fresh() {
        // the on_start contract: one scheduler instance reused across
        // repetitions must behave exactly like a fresh one
        let mut reused = StateScore::new();
        for seed in [4u64, 5, 6] {
            let m = 8 + 5 * seed as usize;
            let tr = random_traces(seed, m, 20.0, CisDelay::None);
            let cfg = SimConfig::new(3.0, 20.0).unwrap();
            let a = simulate(&tr, &cfg, &mut reused);
            let b = simulate(&tr, &cfg, &mut StateScore::new());
            assert_bit_identical(&a, &b, &format!("scheduler reuse seed {seed}"));
        }
    }

    #[test]
    fn simultaneous_events_apply_change_before_request() {
        // change and request at the exact same time: the request must see
        // stale content in BOTH engines (shared (time, kind, page) order)
        let tr = traces_from(
            vec![PageTrace { changes: vec![1.0], cis: vec![1.0], requests: vec![1.0] }],
            2.0,
        );
        // no tick before t=2 -> no crawl before events
        let cfg = SimConfig::new(0.25, 2.0).unwrap();
        let a = simulate(&tr, &cfg, &mut StateScore::new());
        let b = simulate_reference(&tr, &cfg, &mut StateScore::new());
        assert_eq!(a.requests, 1);
        assert_eq!(a.fresh_hits, 0);
        assert_bit_identical(&a, &b, "simultaneous");
    }

    // ---- streamed (lazy event sourcing) engine ----

    #[test]
    fn streamed_engine_runs_and_accounts_consistently() {
        // the lazy path is a different (seed-paired) realization, so no
        // bit-comparison with the replay engines — but the accounting
        // invariants and scale must hold
        let mut rng = Rng::new(41);
        let pages: Vec<PageParams> = (0..50)
            .map(|_| PageParams {
                delta: rng.range(0.05, 1.0),
                mu: rng.range(0.05, 1.0),
                lam: rng.f64(),
                nu: rng.range(0.1, 0.6),
            })
            .collect();
        let mut cfg = SimConfig::new(5.0, 40.0).unwrap();
        cfg.timeline_window = Some(16);
        let mut trng = Rng::new(42);
        let res =
            simulate_streamed(&pages, &cfg, CisDelay::None, &mut trng, &mut StateScore::new())
                .unwrap();
        assert_eq!(res.ticks, 200);
        assert!(res.fresh_hits <= res.requests);
        assert!((0.0..=1.0).contains(&res.accuracy));
        assert_eq!(res.crawl_counts.len(), pages.len());
        assert_eq!(res.crawl_counts.iter().map(|&c| c as u64).sum::<u64>(), res.ticks);
        assert!(!res.timeline.is_empty());
    }

    #[test]
    fn streamed_engine_is_deterministic_and_reuses_workspace() {
        let mut rng = Rng::new(43);
        let pages: Vec<PageParams> = (0..30)
            .map(|_| PageParams {
                delta: rng.range(0.05, 1.0),
                mu: rng.range(0.05, 1.0),
                lam: rng.f64(),
                nu: rng.range(0.1, 0.6),
            })
            .collect();
        let cfg = SimConfig::new(4.0, 30.0).unwrap();
        let delay = CisDelay::Exponential { mean: 0.3 };
        let run_fresh = |seed: u64| {
            let mut trng = Rng::new(seed);
            simulate_streamed(&pages, &cfg, delay, &mut trng, &mut StateScore::new()).unwrap()
        };
        let a = run_fresh(7);
        let b = run_fresh(7);
        assert_bit_identical(&a, &b, "streamed determinism");
        // workspace reuse across a replay rep and a streamed rep
        let mut ws = SimWorkspace::new();
        let tr = random_traces(9, 30, 30.0, CisDelay::None);
        let _ = simulate_with(&mut ws, &tr, &cfg, &mut StateScore::new());
        let mut trng = Rng::new(7);
        let src = StreamedSource::new(&pages, cfg.horizon, delay, &mut trng).unwrap();
        let c = simulate_streamed_with(&mut ws, src, &cfg, &mut StateScore::new());
        assert_bit_identical(&a, &c, "streamed via reused workspace");
    }
}
