//! Discrete-tick crawl simulator.
//!
//! Replays generated event traces against a [`Scheduler`]: one crawl per
//! tick (`t_j = j/R`, with `R` allowed to change over time per the
//! Appendix-D experiment), exact freshness accounting per request event,
//! and the Appendix-C CIS discard window.

use crate::sim::events::EventTraces;

/// Scheduler-visible state of one page.
#[derive(Debug, Clone, Copy)]
pub struct PageState {
    /// Time of the last crawl (0 initially; all pages start fresh).
    pub last_crawl: f64,
    /// CIS delivered since the last crawl (after the discard window).
    pub n_cis: u32,
}

impl PageState {
    /// Elapsed time since the last crawl.
    #[inline]
    pub fn tau_elap(&self, t: f64) -> f64 {
        t - self.last_crawl
    }
}

/// A discrete crawling policy driven by the simulator.
pub trait Scheduler {
    /// Page to crawl at tick time `t` (None = idle tick).
    fn select(&mut self, t: f64, states: &[PageState]) -> Option<usize>;
    /// Notification: a CIS for `page` was delivered at time `t` (after
    /// the engine's discard window was applied).
    fn on_cis(&mut self, _page: usize, _t: f64, _states: &[PageState]) {}
    /// Notification: `page` was crawled at time `t`.
    fn on_crawl(&mut self, _page: usize, _t: f64, _states: &[PageState]) {}
    /// Policy name for reports.
    fn name(&self) -> String {
        "scheduler".into()
    }
}

/// A bandwidth schedule: piecewise-constant R over time.
#[derive(Debug, Clone)]
pub struct BandwidthSchedule {
    /// `(start_time, rate)` segments, sorted by start time; the first
    /// segment must start at 0.
    pub segments: Vec<(f64, f64)>,
}

impl BandwidthSchedule {
    /// Constant bandwidth.
    pub fn constant(r: f64) -> Self {
        Self { segments: vec![(0.0, r)] }
    }

    /// Rate in effect at time `t`.
    pub fn rate_at(&self, t: f64) -> f64 {
        let mut r = self.segments[0].1;
        for &(start, rate) in &self.segments {
            if t >= start {
                r = rate;
            } else {
                break;
            }
        }
        r
    }
}

/// Simulation configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Bandwidth schedule (ticks at spacing `1/R(t)`).
    pub bandwidth: BandwidthSchedule,
    /// Horizon T.
    pub horizon: f64,
    /// Appendix-C discard window: CIS delivered within `window` after a
    /// crawl of the same page are dropped before reaching the scheduler.
    pub cis_discard_window: Option<f64>,
    /// If set, record a rolling-accuracy timeline over the last `k`
    /// requests, sampled at every tick (Appendix D / Figure 9).
    pub timeline_window: Option<usize>,
}

impl SimConfig {
    /// Constant-rate config with no extras.
    pub fn new(r: f64, horizon: f64) -> Self {
        Self {
            bandwidth: BandwidthSchedule::constant(r),
            horizon,
            cis_discard_window: None,
            timeline_window: None,
        }
    }
}

/// Outcome of one simulated repetition.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Fraction of requests served fresh.
    pub accuracy: f64,
    /// Total request events.
    pub requests: u64,
    /// Requests that hit a fresh copy.
    pub fresh_hits: u64,
    /// Crawls per page.
    pub crawl_counts: Vec<u32>,
    /// Total ticks executed.
    pub ticks: u64,
    /// Optional (t, rolling accuracy) samples.
    pub timeline: Vec<(f64, f64)>,
}

impl SimResult {
    /// Empirical crawl rate per page (crawls / horizon).
    pub fn empirical_rates(&self, horizon: f64) -> Vec<f64> {
        self.crawl_counts.iter().map(|&c| c as f64 / horizon).collect()
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum EventKind {
    Change,
    Cis,
    Request,
}

/// Run one repetition of `scheduler` against `traces`.
pub fn simulate(
    traces: &EventTraces,
    cfg: &SimConfig,
    scheduler: &mut dyn Scheduler,
) -> SimResult {
    let m = traces.pages.len();
    // Build the merged, time-sorted event list once.
    let mut events: Vec<(f64, EventKind, u32)> = Vec::new();
    for (i, p) in traces.pages.iter().enumerate() {
        events.extend(p.changes.iter().map(|&t| (t, EventKind::Change, i as u32)));
        events.extend(p.cis.iter().map(|&t| (t, EventKind::Cis, i as u32)));
        events.extend(p.requests.iter().map(|&t| (t, EventKind::Request, i as u32)));
    }
    events.sort_unstable_by(|a, b| a.0.partial_cmp(&b.0).unwrap());

    let mut states = vec![PageState { last_crawl: 0.0, n_cis: 0 }; m];
    let mut changed = vec![false; m];
    let mut crawl_counts = vec![0u32; m];
    let mut fresh_hits = 0u64;
    let mut requests = 0u64;
    let mut ticks = 0u64;
    let mut timeline = Vec::new();
    // rolling window of request freshness bits
    let window = cfg.timeline_window.unwrap_or(0);
    let mut ring: Vec<bool> = Vec::with_capacity(window);
    let mut ring_pos = 0usize;
    let mut ring_fresh = 0usize;

    let mut ev = 0usize;
    let mut t = 0.0f64;
    loop {
        let r = cfg.bandwidth.rate_at(t);
        let next_tick = t + 1.0 / r;
        if next_tick > cfg.horizon {
            break;
        }
        // apply events up to (and including) the tick time
        while ev < events.len() && events[ev].0 <= next_tick {
            let (et, kind, page) = events[ev];
            let i = page as usize;
            match kind {
                EventKind::Change => changed[i] = true,
                EventKind::Request => {
                    requests += 1;
                    let fresh = !changed[i];
                    if fresh {
                        fresh_hits += 1;
                    }
                    if window > 0 {
                        if ring.len() < window {
                            ring.push(fresh);
                            if fresh {
                                ring_fresh += 1;
                            }
                        } else {
                            if ring[ring_pos] {
                                ring_fresh -= 1;
                            }
                            ring[ring_pos] = fresh;
                            if fresh {
                                ring_fresh += 1;
                            }
                            ring_pos = (ring_pos + 1) % window;
                        }
                    }
                }
                EventKind::Cis => {
                    let keep = match cfg.cis_discard_window {
                        Some(w) => et - states[i].last_crawl >= w,
                        None => true,
                    };
                    if keep {
                        states[i].n_cis = states[i].n_cis.saturating_add(1);
                        scheduler.on_cis(i, et, &states);
                    }
                }
            }
            ev += 1;
        }
        // crawl at the tick
        t = next_tick;
        ticks += 1;
        if let Some(i) = scheduler.select(t, &states) {
            debug_assert!(i < m);
            changed[i] = false;
            states[i] = PageState { last_crawl: t, n_cis: 0 };
            crawl_counts[i] += 1;
            scheduler.on_crawl(i, t, &states);
        }
        if window > 0 && !ring.is_empty() {
            timeline.push((t, ring_fresh as f64 / ring.len() as f64));
        }
    }
    // drain remaining request events after the final tick
    while ev < events.len() {
        let (_, kind, page) = events[ev];
        if kind == EventKind::Request {
            requests += 1;
            if !changed[page as usize] {
                fresh_hits += 1;
            }
        } else if kind == EventKind::Change {
            changed[page as usize] = true;
        }
        ev += 1;
    }

    SimResult {
        accuracy: if requests > 0 { fresh_hits as f64 / requests as f64 } else { f64::NAN },
        requests,
        fresh_hits,
        crawl_counts,
        ticks,
        timeline,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::events::PageTrace;

    /// Round-robin scheduler for engine-level tests.
    struct RoundRobin {
        m: usize,
        next: usize,
    }
    impl Scheduler for RoundRobin {
        fn select(&mut self, _t: f64, _s: &[PageState]) -> Option<usize> {
            let i = self.next;
            self.next = (self.next + 1) % self.m;
            Some(i)
        }
    }

    fn traces_from(pages: Vec<PageTrace>, horizon: f64) -> EventTraces {
        EventTraces { pages, horizon }
    }

    #[test]
    fn tick_count_matches_bandwidth() {
        let tr = traces_from(vec![PageTrace::default(); 3], 10.0);
        let cfg = SimConfig::new(5.0, 10.0);
        let mut s = RoundRobin { m: 3, next: 0 };
        let res = simulate(&tr, &cfg, &mut s);
        assert_eq!(res.ticks, 50);
        let total: u32 = res.crawl_counts.iter().sum();
        assert_eq!(total, 50);
    }

    #[test]
    fn freshness_accounting_exact() {
        // page changes at t=1.5; requests at t=1.0 (fresh), t=1.6 (stale),
        // crawl at t=2.0 (R=0.5 -> ticks at 2.0, 4.0), request at 2.5 (fresh)
        let tr = traces_from(
            vec![PageTrace {
                changes: vec![1.5],
                cis: vec![],
                requests: vec![1.0, 1.6, 2.5],
            }],
            5.0,
        );
        let cfg = SimConfig::new(0.5, 5.0);
        let mut s = RoundRobin { m: 1, next: 0 };
        let res = simulate(&tr, &cfg, &mut s);
        assert_eq!(res.requests, 3);
        assert_eq!(res.fresh_hits, 2);
    }

    #[test]
    fn cis_resets_on_crawl() {
        struct Capture {
            seen: Vec<u32>,
        }
        impl Scheduler for Capture {
            fn select(&mut self, _t: f64, s: &[PageState]) -> Option<usize> {
                self.seen.push(s[0].n_cis);
                Some(0)
            }
        }
        let tr = traces_from(
            vec![PageTrace { changes: vec![], cis: vec![0.4, 0.9, 1.4], requests: vec![] }],
            3.0,
        );
        let cfg = SimConfig::new(1.0, 3.0);
        let mut s = Capture { seen: vec![] };
        let res = simulate(&tr, &cfg, &mut s);
        // tick at t=1: cis 0.4, 0.9 delivered -> n=2; crawl resets
        // tick at t=2: cis 1.4 -> n=1; tick at t=3: none -> 0
        assert_eq!(s.seen, vec![2, 1, 0]);
        assert_eq!(res.crawl_counts[0], 3);
    }

    #[test]
    fn discard_window_drops_fresh_cis() {
        struct Capture {
            seen: Vec<u32>,
        }
        impl Scheduler for Capture {
            fn select(&mut self, _t: f64, s: &[PageState]) -> Option<usize> {
                self.seen.push(s[0].n_cis);
                Some(0)
            }
        }
        // crawl happens at t=1,2,3; cis at 1.05 (within 0.2 of crawl@1 ->
        // dropped), cis at 2.5 (kept)
        let tr = traces_from(
            vec![PageTrace { changes: vec![], cis: vec![1.05, 2.5], requests: vec![] }],
            4.0,
        );
        let mut cfg = SimConfig::new(1.0, 4.0);
        cfg.cis_discard_window = Some(0.2);
        let mut s = Capture { seen: vec![] };
        simulate(&tr, &cfg, &mut s);
        assert_eq!(s.seen, vec![0, 0, 1, 0]);
    }

    #[test]
    fn bandwidth_schedule_changes_tick_density() {
        let tr = traces_from(vec![PageTrace::default()], 10.0);
        let cfg = SimConfig {
            bandwidth: BandwidthSchedule {
                segments: vec![(0.0, 1.0), (5.0, 10.0)],
            },
            horizon: 10.0,
            cis_discard_window: None,
            timeline_window: None,
        };
        let mut s = RoundRobin { m: 1, next: 0 };
        let res = simulate(&tr, &cfg, &mut s);
        // ~5 ticks in the first half, ~50 in the second
        assert!((res.ticks as i64 - 55).abs() <= 2, "{}", res.ticks);
    }

    #[test]
    fn timeline_rolls_over_requests() {
        let tr = traces_from(
            vec![PageTrace {
                changes: vec![0.1],
                cis: vec![],
                requests: (1..100).map(|i| i as f64 * 0.1).collect(),
            }],
            10.0,
        );
        let mut cfg = SimConfig::new(1.0, 10.0);
        cfg.timeline_window = Some(10);
        let mut s = RoundRobin { m: 1, next: 0 };
        let res = simulate(&tr, &cfg, &mut s);
        assert!(!res.timeline.is_empty());
        for &(_, acc) in &res.timeline {
            assert!((0.0..=1.0).contains(&acc));
        }
    }

    #[test]
    fn accuracy_is_one_with_no_changes() {
        let tr = traces_from(
            vec![PageTrace { changes: vec![], cis: vec![], requests: vec![1.0, 2.0] }],
            5.0,
        );
        let cfg = SimConfig::new(1.0, 5.0);
        let mut s = RoundRobin { m: 1, next: 0 };
        let res = simulate(&tr, &cfg, &mut s);
        assert_eq!(res.accuracy, 1.0);
    }
}
