//! Dataset and trace persistence (CSV), for reproducible experiment
//! pipelines: generate once, re-run policies against identical inputs,
//! and exchange populations with external analysis tooling.

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

use crate::dataset::UrlRecord;
use crate::error::{Error, Result};
use crate::sim::events::{EventTraces, PageTrace};

/// Write URL records as CSV.
pub fn write_records(path: &Path, records: &[UrlRecord]) -> Result<()> {
    let mut f = BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "importance,delta,declared,precision,recall,has_cis")?;
    for r in records {
        writeln!(
            f,
            "{},{},{},{},{},{}",
            r.importance, r.delta, r.declared as u8, r.precision, r.recall, r.has_cis as u8
        )?;
    }
    Ok(())
}

/// Read URL records from CSV.
pub fn read_records(path: &Path) -> Result<Vec<UrlRecord>> {
    let f = BufReader::new(std::fs::File::open(path)?);
    let mut out = Vec::new();
    for (ln, line) in f.lines().enumerate() {
        let line = line?;
        if ln == 0 || line.trim().is_empty() {
            continue;
        }
        let c: Vec<&str> = line.split(',').collect();
        if c.len() != 6 {
            return Err(Error::InvalidParam(format!("line {}: expected 6 columns", ln + 1)));
        }
        let parse = |s: &str, what: &str| -> Result<f64> {
            s.parse().map_err(|_| Error::InvalidParam(format!("line {}: bad {what}", ln + 1)))
        };
        out.push(UrlRecord {
            importance: parse(c[0], "importance")?,
            delta: parse(c[1], "delta")?,
            declared: c[2] == "1",
            precision: parse(c[3], "precision")?,
            recall: parse(c[4], "recall")?,
            has_cis: c[5] == "1",
        });
    }
    Ok(out)
}

/// Write event traces as CSV rows `(page, kind, time)` with
/// `kind ∈ {change, cis, request}`.
pub fn write_traces(path: &Path, traces: &EventTraces) -> Result<()> {
    let mut f = BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "# horizon {}", traces.horizon)?;
    writeln!(f, "page,kind,time")?;
    for (i, p) in traces.pages.iter().enumerate() {
        for &t in &p.changes {
            writeln!(f, "{i},change,{t}")?;
        }
        for &t in &p.cis {
            writeln!(f, "{i},cis,{t}")?;
        }
        for &t in &p.requests {
            writeln!(f, "{i},request,{t}")?;
        }
    }
    Ok(())
}

/// Read event traces back (must know the page count).
pub fn read_traces(path: &Path, pages: usize) -> Result<EventTraces> {
    let f = BufReader::new(std::fs::File::open(path)?);
    let mut out = EventTraces { pages: vec![PageTrace::default(); pages], horizon: 0.0 };
    for (ln, line) in f.lines().enumerate() {
        let line = line?;
        if let Some(h) = line.strip_prefix("# horizon ") {
            out.horizon = h
                .trim()
                .parse()
                .map_err(|_| Error::InvalidParam(format!("line {}: bad horizon", ln + 1)))?;
            continue;
        }
        if line.starts_with("page,") || line.trim().is_empty() {
            continue;
        }
        let c: Vec<&str> = line.split(',').collect();
        if c.len() != 3 {
            return Err(Error::InvalidParam(format!("line {}: expected 3 columns", ln + 1)));
        }
        let page: usize = c[0]
            .parse()
            .map_err(|_| Error::InvalidParam(format!("line {}: bad page", ln + 1)))?;
        if page >= pages {
            return Err(Error::InvalidParam(format!("line {}: page {page} out of range", ln + 1)));
        }
        let t: f64 = c[2]
            .parse()
            .map_err(|_| Error::InvalidParam(format!("line {}: bad time", ln + 1)))?;
        match c[1] {
            "change" => out.pages[page].changes.push(t),
            "cis" => out.pages[page].cis.push(t),
            "request" => out.pages[page].requests.push(t),
            other => {
                return Err(Error::InvalidParam(format!("line {}: kind `{other}`", ln + 1)));
            }
        }
    }
    // events were written grouped per page and in time order, but be
    // defensive: re-sort
    for p in &mut out.pages {
        p.changes.sort_unstable_by(f64::total_cmp);
        p.cis.sort_unstable_by(f64::total_cmp);
        p.requests.sort_unstable_by(f64::total_cmp);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{generate, DatasetConfig};
    use crate::params::PageParams;
    use crate::rngkit::Rng;
    use crate::sim::{generate_traces, CisDelay};

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("ncis_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn records_roundtrip() {
        let recs = generate(&DatasetConfig { n_urls: 500, seed: 3, ..Default::default() });
        let path = tmp("records.csv");
        write_records(&path, &recs).unwrap();
        let back = read_records(&path).unwrap();
        assert_eq!(back.len(), recs.len());
        for (a, b) in recs.iter().zip(&back) {
            assert_eq!(a.importance, b.importance);
            assert_eq!(a.delta, b.delta);
            assert_eq!(a.declared, b.declared);
            assert_eq!(a.precision, b.precision);
            assert_eq!(a.has_cis, b.has_cis);
        }
    }

    #[test]
    fn traces_roundtrip() {
        let pages: Vec<PageParams> = (0..10)
            .map(|i| PageParams { delta: 0.3 + 0.05 * i as f64, mu: 0.5, lam: 0.5, nu: 0.2 })
            .collect();
        let mut rng = Rng::new(4);
        let traces = generate_traces(&pages, 50.0, CisDelay::None, &mut rng);
        let path = tmp("traces.csv");
        write_traces(&path, &traces).unwrap();
        let back = read_traces(&path, 10).unwrap();
        assert_eq!(back.horizon, 50.0);
        for (a, b) in traces.pages.iter().zip(&back.pages) {
            assert_eq!(a.changes, b.changes);
            assert_eq!(a.cis, b.cis);
            assert_eq!(a.requests, b.requests);
        }
    }

    #[test]
    fn read_errors() {
        let path = tmp("bad.csv");
        std::fs::write(&path, "importance,delta\n1,2\n").unwrap();
        assert!(read_records(&path).is_err());
        let path2 = tmp("bad_traces.csv");
        std::fs::write(&path2, "page,kind,time\n99,change,1.0\n").unwrap();
        assert!(read_traces(&path2, 10).is_err());
        let path3 = tmp("bad_kind.csv");
        std::fs::write(&path3, "page,kind,time\n0,banana,1.0\n").unwrap();
        assert!(read_traces(&path3, 10).is_err());
    }
}
