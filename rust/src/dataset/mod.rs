//! Semi-synthetic dataset substrate (§2, §6.7).
//!
//! The paper builds on the (non-public) Kolobov et al. 2019 Bing dataset:
//! 18.5M URLs with importance, empirical change rates, and a ~5% subset
//! labelled as having "perfect" sitemap CIS — re-weighted with the
//! paper's own (confidential) precision/recall measurements (Figure 1:
//! importance-weighted precision mostly < 0.2, recall < 0.5, very few
//! pages above 0.8/0.8).
//!
//! We synthesize a population with the same *marginals*, which is all
//! §6.7 consumes: heavy-tailed importance (PageRank-like), log-normal
//! change rates, a `frac_declared` subset carrying the upper-tail CIS
//! quality, everyone else the lower tail, plus the corruption model
//! `q ← (1−p)q + p·ξ, ξ ~ U(0,1)` used in Figure 5.

pub mod io;

use crate::params::{Instance, PageParams};
use crate::rngkit::{self, Rng};
use crate::stats::Histogram;

/// Generation parameters for the synthetic population.
#[derive(Debug, Clone)]
pub struct DatasetConfig {
    /// Number of URLs.
    pub n_urls: usize,
    /// RNG seed.
    pub seed: u64,
    /// Fraction of URLs with "declared" (dataset-labelled perfect) CIS.
    pub frac_declared: f64,
    /// Pareto tail index of the importance distribution.
    pub importance_tail: f64,
    /// Log-normal (mu, sigma) of the change-rate distribution.
    pub delta_lognormal: (f64, f64),
}

impl Default for DatasetConfig {
    fn default() -> Self {
        Self {
            n_urls: 100_000,
            seed: 20250710,
            frac_declared: 0.05,
            importance_tail: 1.2,
            delta_lognormal: (-1.0, 1.0),
        }
    }
}

/// One synthesized URL record.
#[derive(Debug, Clone, Copy)]
pub struct UrlRecord {
    /// Importance weight (unnormalized).
    pub importance: f64,
    /// Change rate Δ.
    pub delta: f64,
    /// Whether the URL is in the "declared perfect CIS" subset.
    pub declared: bool,
    /// CIS precision (possibly corrupted downstream).
    pub precision: f64,
    /// CIS recall.
    pub recall: f64,
    /// Whether the URL has any CIS at all.
    pub has_cis: bool,
}

/// Lower-tail CIS quality (the bottom 95% of the Figure-1 histograms):
/// precision centered ≈ 0.17, recall ≈ 0.45.
fn sample_low_quality(rng: &mut Rng) -> (f64, f64) {
    let precision = rngkit::beta(rng, 1.3, 6.0);
    let recall = rngkit::beta(rng, 2.2, 2.7);
    (precision, recall)
}

/// Upper-tail CIS quality (the top 5%): precision/recall ≳ 0.7.
fn sample_high_quality(rng: &mut Rng) -> (f64, f64) {
    let precision = 0.7 + 0.3 * rngkit::beta(rng, 3.0, 1.4);
    let recall = 0.6 + 0.4 * rngkit::beta(rng, 3.0, 1.6);
    (precision, recall)
}

/// Generate the synthetic population.
pub fn generate(cfg: &DatasetConfig) -> Vec<UrlRecord> {
    let mut rng = Rng::new(cfg.seed);
    let n_declared = (cfg.n_urls as f64 * cfg.frac_declared).round() as usize;
    let declared_set = rng.sample_indices(cfg.n_urls, n_declared);
    let mut declared = vec![false; cfg.n_urls];
    for &i in &declared_set {
        declared[i] = true;
    }
    (0..cfg.n_urls)
        .map(|i| {
            let importance = rngkit::pareto(&mut rng, 1.0, cfg.importance_tail);
            let delta = rngkit::lognormal(&mut rng, cfg.delta_lognormal.0, cfg.delta_lognormal.1)
                .clamp(1e-3, 10.0);
            // only declared pages + a slice of others actually emit CIS
            // (4% adoption in the real dataset; declared ⊂ has_cis)
            let has_cis = declared[i] || rng.bernoulli(0.1);
            let (precision, recall) = if !has_cis {
                (0.0, 0.0)
            } else if declared[i] {
                sample_high_quality(&mut rng)
            } else {
                sample_low_quality(&mut rng)
            };
            UrlRecord { importance, delta, declared: declared[i], precision, recall, has_cis }
        })
        .collect()
}

/// Figure-5 corruption: mix uniform noise into the *believed* quality
/// (the environment keeps the true values):
/// `q ← (1−p)·q + p·ξ`, `ξ ~ U(0, 1)` (independently per field).
pub fn corrupt(records: &[UrlRecord], p: f64, rng: &mut Rng) -> Vec<UrlRecord> {
    records
        .iter()
        .map(|r| {
            if !r.has_cis {
                return *r;
            }
            let xi_p = rng.f64();
            let xi_r = rng.f64();
            UrlRecord {
                precision: ((1.0 - p) * r.precision + p * xi_p).clamp(0.0, 1.0),
                recall: ((1.0 - p) * r.recall + p * xi_r).clamp(0.0, 1.0),
                ..*r
            }
        })
        .collect()
}

/// Subsample `k` URLs uniformly (the §6.7 protocol subsamples 100k).
pub fn subsample(records: &[UrlRecord], k: usize, rng: &mut Rng) -> Vec<UrlRecord> {
    let idx = rng.sample_indices(records.len(), k.min(records.len()));
    idx.into_iter().map(|i| records[i]).collect()
}

/// Convert records to a crawl [`Instance`] (raw importance as request
/// rate; CIS parameters from quality).
pub fn to_instance(records: &[UrlRecord], bandwidth: f64) -> Instance {
    let pages = records
        .iter()
        .map(|r| {
            if r.has_cis {
                PageParams::from_quality(r.delta, r.importance, r.precision, r.recall)
            } else {
                PageParams { delta: r.delta, mu: r.importance, lam: 0.0, nu: 0.0 }
            }
        })
        .collect();
    Instance { pages, bandwidth }
}

/// Importance-weighted precision/recall histograms over pages with CIS —
/// the Figure-1 measurement.
pub fn quality_histograms(records: &[UrlRecord], bins: usize) -> (Histogram, Histogram) {
    let with: Vec<&UrlRecord> = records.iter().filter(|r| r.has_cis).collect();
    let prec: Vec<f64> = with.iter().map(|r| r.precision).collect();
    let rec: Vec<f64> = with.iter().map(|r| r.recall).collect();
    let w: Vec<f64> = with.iter().map(|r| r.importance).collect();
    (
        Histogram::weighted(&prec, &w, 0.0, 1.0, bins),
        Histogram::weighted(&rec, &w, 0.0, 1.0, bins),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Vec<UrlRecord> {
        generate(&DatasetConfig { n_urls: 20_000, seed: 1, ..Default::default() })
    }

    #[test]
    fn declared_fraction_matches() {
        let recs = small();
        let frac = recs.iter().filter(|r| r.declared).count() as f64 / recs.len() as f64;
        assert!((frac - 0.05).abs() < 0.005, "{frac}");
    }

    #[test]
    fn declared_pages_have_high_quality() {
        let recs = small();
        for r in recs.iter().filter(|r| r.declared) {
            assert!(r.precision >= 0.7 && r.recall >= 0.6, "{r:?}");
            assert!(r.has_cis);
        }
    }

    #[test]
    fn population_marginals_match_figure1() {
        // importance-weighted medians: precision < 0.2ish, recall < 0.5
        let recs = small();
        let (hp, hr) = quality_histograms(&recs, 20);
        let prec_med = hp.quantile(0.5);
        let rec_med = hr.quantile(0.5);
        assert!(prec_med < 0.35, "precision median {prec_med}");
        assert!((0.25..0.75).contains(&rec_med), "recall median {rec_med}");
        // few pages above 0.8/0.8 overall
        let both_high = recs
            .iter()
            .filter(|r| r.has_cis && r.precision > 0.8 && r.recall > 0.8)
            .count() as f64
            / recs.len() as f64;
        assert!(both_high < 0.05, "{both_high}");
    }

    #[test]
    fn corruption_moves_quality_toward_uniform() {
        let recs = small();
        let mut rng = Rng::new(7);
        let c = corrupt(&recs, 0.2, &mut rng);
        assert_eq!(c.len(), recs.len());
        let moved = recs
            .iter()
            .zip(&c)
            .filter(|(a, b)| a.has_cis && (a.precision != b.precision))
            .count();
        assert!(moved > 0);
        // p=0 is identity
        let mut rng = Rng::new(8);
        let c0 = corrupt(&recs, 0.0, &mut rng);
        for (a, b) in recs.iter().zip(&c0) {
            assert_eq!(a.precision, b.precision);
        }
    }

    #[test]
    fn subsample_size_and_membership() {
        let recs = small();
        let mut rng = Rng::new(9);
        let sub = subsample(&recs, 1000, &mut rng);
        assert_eq!(sub.len(), 1000);
    }

    #[test]
    fn to_instance_valid_params() {
        let recs = small();
        let inst = to_instance(&recs[..1000], 100.0);
        for p in &inst.pages {
            p.validate().unwrap();
        }
        // pages without CIS have lam = nu = 0
        for (r, p) in recs[..1000].iter().zip(&inst.pages) {
            if !r.has_cis {
                assert_eq!(p.lam, 0.0);
                assert_eq!(p.nu, 0.0);
            }
        }
    }
}
