//! CLI subcommand dispatch (kept out of `main.rs` so integration tests
//! can drive the commands in-process).

use std::path::Path;

use crate::cli::Args;
use crate::coordinator::builder::{CrawlerBuilder, Strategy};
use crate::coordinator::pipeline::{run_pipeline_streamed, CisFeed, PipelineConfig};
use crate::error::{Error, Result};
use crate::fault::{simulate_faulty_traced_with, FaultConfig, FaultModel, RetryPolicy};
use crate::figures::common::{run_cell, ExperimentSpec};
use crate::policy::{parse_policy, PolicyKind, PolicyUnderTest};
use crate::rngkit::Rng;
use crate::scenario::fuzz::{run_fuzz, FuzzConfig};
use crate::scenario::generators::{add_steady_churn, BornPageSpec};
use crate::scenario::{parse_world, CompiledWorld, Scenario, WorldSpec};
use crate::serving::RequestTraffic;
use crate::sim::{generate_traces, CisDelay, SimConfig, SimWorkspace};
use crate::solver;
use crate::trace::TraceHandle;

const USAGE: &str = "\
ncis-crawl <command> [options]

commands:
  simulate     run one policy on a synthetic instance
               --m N --r R --horizon T --reps K --policy NAME [--cis] [--fp] [--seed S]
  solve        optimal continuous policy for a synthetic instance
               --m N --r R [--cis] [--fp] [--seed S]
  dataset      generate + describe the semi-synthetic population
               --n N [--seed S]
  estimate     Appendix-E estimator demo
               --precision P --recall R [--seed S]
  serve-shards streaming sharded coordinator demo
               --m N --shards S --r R --horizon T
  figure       regenerate a paper figure: figure <id> [--reps K]
               (ids: 1,2,3,4,5,6,7,8,9,10,11,12,14, appg, scenario, faults, regret, serving)
               figure scenario also accepts --world FILE (DSL world)
  trace        run one traced repetition, emit the flight-recorder JSONL
               --m N --r R --horizon T --policy NAME [--scenario] [--faults]
               [--serve RATE] [--cap N] [--seed S] [--out FILE]
               [--verbose] [--stride N] [--world FILE]
  world        parse + compile a scenario-DSL world file, print a summary
               world <file> [--render]
  fuzz         randomized world fuzzing with replay + invariant checks
               [--worlds N] [--seed S] [--budget-secs T] [--out DIR]

policies: GREEDY | GREEDY-CIS | GREEDY-NCIS | G-NCIS-APPROX-1 |
          G-NCIS-APPROX-2 | GREEDY-CIS+ | LDS  (suffix -LAZY for §5.2)
";

fn cmd_simulate(args: &Args) -> Result<()> {
    let mut spec = ExperimentSpec::section6(
        args.usize_or("m", 100)?,
        args.usize_or("reps", 5)?,
    );
    spec.bandwidth = args.f64_or("r", 100.0)?;
    spec.horizon = args.f64_or("horizon", 1000.0)?;
    spec.seed = args.u64_or("seed", 0x5EED)?;
    if args.has_flag("cis") {
        spec = spec.with_partial_cis();
    }
    if args.has_flag("fp") {
        spec = spec.with_false_positives();
    }
    let put = parse_policy(args.opt("policy").unwrap_or("GREEDY-NCIS"))?;
    let cell = run_cell(&spec, put);
    println!(
        "policy={} m={} R={} T={} reps={}",
        cell.policy, spec.m, spec.bandwidth, spec.horizon, spec.reps
    );
    println!("accuracy = {:.4} ± {:.4}", cell.mean, cell.stderr);
    println!("baseline (optimal continuous, no CIS) = {:.4}", cell.baseline);
    Ok(())
}

fn cmd_solve(args: &Args) -> Result<()> {
    let mut spec = ExperimentSpec::section6(args.usize_or("m", 100)?, 1);
    spec.bandwidth = args.f64_or("r", 100.0)?;
    spec.seed = args.u64_or("seed", 0x5EED)?;
    if args.has_flag("cis") {
        spec = spec.with_partial_cis();
    }
    if args.has_flag("fp") {
        spec = spec.with_false_positives();
    }
    let mut rng = Rng::new(spec.seed);
    let inst = spec.gen_instance(&mut rng).normalized();
    let no_cis = solver::solve_no_cis(&inst)?;
    println!("no-CIS optimum:   objective={:.4}  lambda={:.6}", no_cis.objective, no_cis.lambda);
    if args.has_flag("cis") || args.has_flag("fp") {
        let envs = inst.derived()?;
        let with = solver::solve_with_cis(&inst, &envs, crate::policy::value::MAX_TERMS)?;
        println!("with-CIS optimum: objective={:.4}  lambda={:.6}", with.objective, with.lambda);
    }
    let spent: f64 = no_cis.rates.iter().sum();
    println!("budget spent: {spent:.2} / {}", inst.bandwidth);
    Ok(())
}

fn cmd_dataset(args: &Args) -> Result<()> {
    let cfg = crate::dataset::DatasetConfig {
        n_urls: args.usize_or("n", 100_000)?,
        seed: args.u64_or("seed", 20250710)?,
        ..Default::default()
    };
    let recs = crate::dataset::generate(&cfg);
    let with_cis = recs.iter().filter(|r| r.has_cis).count();
    let declared = recs.iter().filter(|r| r.declared).count();
    let (hp, hr) = crate::dataset::quality_histograms(&recs, 10);
    println!("urls={} with_cis={} declared={}", recs.len(), with_cis, declared);
    println!("importance-weighted precision median: {:.3}", hp.quantile(0.5));
    println!("importance-weighted recall median:    {:.3}", hr.quantile(0.5));
    Ok(())
}

fn cmd_estimate(args: &Args) -> Result<()> {
    let precision = args.f64_or("precision", 0.5)?;
    let recall = args.f64_or("recall", 0.6)?;
    let seed = args.u64_or("seed", 1)?;
    let page = crate::params::PageParams::from_quality(0.4, 0.1, precision, recall);
    let mut rng = Rng::new(seed);
    let obs = crate::estimation::generate_observations(&page, 0.8, 100_000.0, &mut rng);
    let (np, nr) = crate::estimation::naive_precision_recall(&obs);
    let (mp, mr) = crate::estimation::mle_precision_recall(&obs, 60);
    println!("true      precision={precision:.3} recall={recall:.3}");
    println!("naive     precision={np:.3} recall={nr:.3}");
    println!("MLE       precision={mp:.3} recall={mr:.3}");
    Ok(())
}

fn cmd_serve_shards(args: &Args) -> Result<()> {
    let m = args.usize_or("m", 10_000)?;
    let shards = args.usize_or("shards", 4)?;
    let r = args.f64_or("r", 1000.0)?;
    let horizon = args.f64_or("horizon", 20.0)?;
    let mut rng = Rng::new(args.u64_or("seed", 42)?);
    let spec = ExperimentSpec::section6(m, 1).with_partial_cis().with_false_positives();
    let inst = spec.gen_instance(&mut rng).normalized();
    // lazy CIS feed: O(m) state, generative per-page signals (coins +
    // false positives) instead of a pre-drawn hazard-rate stream
    let feed = CisFeed::new(&inst.pages, horizon, crate::sim::CisDelay::None, &mut rng)?;
    let cfg = PipelineConfig { shards, queue_depth: 256, bandwidth: r, horizon };
    // per-shard schedulers are stamped from this template
    let scheduler = CrawlerBuilder::new()
        .policy(PolicyKind::GreedyNcis)
        .strategy(Strategy::Lazy);
    let report = run_pipeline_streamed(&inst.pages, &scheduler, feed, &[], &cfg)?;
    println!(
        "shards={} crawls={} cis={} backpressure_stalls={} wall={:?}",
        shards, report.total_crawls, report.cis_applied, report.backpressure_stalls, report.wall
    );
    println!(
        "throughput: {:.0} crawls/s (simulated R={r}/s over T={horizon})",
        report.total_crawls as f64 / report.wall.as_secs_f64()
    );
    Ok(())
}

/// Run a config-file-defined experiment sweep: every `policies` entry on
/// a shared instance spec, accuracy vs the analytical baseline.
fn cmd_experiment(args: &Args) -> Result<()> {
    let path = args
        .opt("config")
        .ok_or_else(|| Error::Usage("experiment requires --config <file>".into()))?;
    let cfg = crate::config::Config::load(Path::new(path))?;
    let mut spec = ExperimentSpec::section6(
        cfg.usize_or("instance.m", 100),
        cfg.usize_or("reps", 5),
    );
    spec.bandwidth = cfg.f64_or("instance.bandwidth", 100.0);
    spec.horizon = cfg.f64_or("instance.horizon", 1000.0);
    spec.seed = cfg.f64_or("instance.seed", 0x5EED as f64) as u64;
    if let Some(ab) = cfg.get("instance.lambda_beta").and_then(|v| v.as_f64_array()) {
        if ab.len() == 2 {
            spec.lam_beta = Some((ab[0], ab[1]));
        }
    }
    if let Some(nr) = cfg.get("instance.nu_range").and_then(|v| v.as_f64_array()) {
        if nr.len() == 2 {
            spec.nu_range = Some((nr[0], nr[1]));
        }
    }
    let policies: Vec<String> = match cfg.get("policies") {
        Some(crate::config::Value::Array(vs)) => vs
            .iter()
            .map(|v| {
                v.as_str()
                    .map(String::from)
                    .ok_or_else(|| Error::Config("policies must be strings".into()))
            })
            .collect::<Result<_>>()?,
        _ => vec!["GREEDY".into(), "GREEDY-NCIS".into()],
    };
    println!(
        "experiment `{}`: m={} R={} T={} reps={}",
        cfg.str_or("title", path),
        spec.m,
        spec.bandwidth,
        spec.horizon,
        spec.reps
    );
    for name in policies {
        let put = parse_policy(&name)?;
        let cell = run_cell(&spec, put);
        println!(
            "  {:<18} accuracy = {:.4} ± {:.4}   (baseline {:.4})",
            cell.policy, cell.mean, cell.stderr, cell.baseline
        );
    }
    Ok(())
}

/// One traced repetition on a synthetic instance: every decision,
/// lifecycle transition and serve lands in a bounded flight recorder,
/// drained to JSONL (stdout or `--out`) after the run. The summary
/// goes to stderr so a piped `trace | jq` sees only event lines.
fn cmd_trace(args: &Args) -> Result<()> {
    use std::io::Write;

    let m = args.usize_or("m", 200)?;
    let r = args.f64_or("r", 50.0)?;
    let horizon = args.f64_or("horizon", 50.0)?;
    let seed = args.u64_or("seed", 0x7ACE)?;
    let cap = args.usize_or("cap", 65_536)?;
    let put = parse_policy(args.opt("policy").unwrap_or("GREEDY-NCIS"))?;
    // the trace lanes run through CrawlerBuilder; map the policy name
    // onto its strategy (LDS has no decision trace — its picks are a
    // precomputed low-discrepancy sequence, not per-tick argmaxes)
    let (policy, strategy) = match put {
        PolicyUnderTest::Greedy(k) => (k, Strategy::Exact),
        PolicyUnderTest::Lazy(k) => (k, Strategy::Lazy),
        other => {
            return Err(Error::Usage(format!(
                "trace: policy {} is not traceable — use a GREEDY variant",
                other.name()
            )))
        }
    };
    let mut rng = Rng::new(seed);
    let spec = ExperimentSpec::section6(m, 1).with_partial_cis().with_false_positives();
    let inst = spec.gen_instance(&mut rng).normalized();
    let cfg = SimConfig::new(r, horizon)?;

    let mut handle = TraceHandle::recorder(cap);
    if args.has_flag("verbose") {
        handle = handle.with_progress(args.u64_or("stride", 1_000)?);
    }

    let crawls: u64;
    if let Some(path) = args.opt("world") {
        // DSL-world lane: the compiled world supplies population,
        // timeline and (when declared) traffic; --m/--r/--horizon are
        // ignored in favor of the file
        let world = parse_world(&std::fs::read_to_string(path)?)?;
        let mut b = world
            .crawler()
            .policy(policy)
            .strategy(strategy)
            .with_trace(handle.clone());
        if world.traffic.is_none() {
            b = b.with_traffic(RequestTraffic::off());
        }
        let (res, metrics) = b.run_traffic(&world.sim_config()?, seed)?;
        crawls = res.crawl_counts.iter().map(|&c| c as u64).sum();
        eprintln!(
            "world lane: m={} events={} served={}",
            world.initial_pages().len(),
            world.scenario.events().len(),
            metrics.served
        );
    } else if args.has_flag("faults") {
        // fault lane: the traced degraded-mode engine, moderate severity
        let mut sched = CrawlerBuilder::new()
            .policy(policy)
            .strategy(strategy)
            .pages(&inst.pages)
            .with_trace(handle.clone())
            .build()?;
        let traces = generate_traces(&inst.pages, horizon, CisDelay::None, &mut rng);
        let mut model = FaultModel::new(FaultConfig {
            transient_prob: 0.1,
            timeout_prob: 0.05,
            gone_prob: 0.002,
            seed: seed ^ 0xFA17,
            ..FaultConfig::none()
        })?;
        let mut ws = SimWorkspace::new();
        let res = simulate_faulty_traced_with(
            &mut ws,
            &traces,
            &cfg,
            sched.as_mut(),
            &mut model,
            RetryPolicy::default(),
            Some(&handle),
        );
        crawls = res.sim.crawl_counts.iter().map(|&c| c as u64).sum();
        eprintln!(
            "fault lane: attempts={} retries={} quarantined={}",
            res.faults.attempts, res.faults.retries, res.faults.quarantined
        );
    } else {
        let mut b = CrawlerBuilder::new()
            .policy(policy)
            .strategy(strategy)
            .pages(&inst.pages)
            .with_trace(handle.clone());
        if args.has_flag("scenario") {
            // dynamic lane: steady churn over the whole horizon
            let mut sc = Scenario::new(inst.pages.clone(), seed ^ 0x5C);
            add_steady_churn(&mut sc, 0.02, horizon, &BornPageSpec::default(), seed ^ 0x5D);
            b = b.with_scenario(sc);
        }
        let rate = args.f64_or("serve", 0.0)?;
        let traffic = if rate > 0.0 {
            RequestTraffic::new(rate, 1.1, seed ^ 0x5E)?
        } else {
            RequestTraffic::off()
        };
        let (res, metrics) = b.with_traffic(traffic).run_traffic(&cfg, seed)?;
        crawls = res.crawl_counts.iter().map(|&c| c as u64).sum();
        if metrics.served > 0 {
            eprintln!(
                "serving lane: served={} fresh={} stale={}",
                metrics.served, metrics.fresh_serves, metrics.stale_serves
            );
        }
    }

    let jsonl = handle.drain_jsonl();
    let events = jsonl.lines().count();
    match args.opt("out") {
        Some(path) => std::fs::write(path, &jsonl)?,
        None => std::io::stdout().lock().write_all(jsonl.as_bytes())?,
    }
    let dropped = handle
        .recorder_arc()
        .map(|rec| {
            rec.lock().unwrap_or_else(std::sync::PoisonError::into_inner).dropped()
        })
        .unwrap_or(0);
    eprintln!("trace: {events} events held ({crawls} crawls, {dropped} overwritten, cap {cap})");
    Ok(())
}

fn cmd_figure(args: &Args) -> Result<()> {
    let id = args
        .positionals
        .first()
        .map(String::as_str)
        .ok_or_else(|| Error::Usage("figure <id> required".into()))?;
    let reps = args.usize_or("reps", 10)?;
    if let Some(path) = args.opt("world") {
        if id != "scenario" {
            return Err(Error::Usage(
                "--world is only supported for `figure scenario`".into(),
            ));
        }
        let world = parse_world(&std::fs::read_to_string(path)?)?;
        return crate::figures::scenario::fig_scenario_world(reps, &world);
    }
    crate::figures::run_figure(id, reps)
}

/// Parse + compile a DSL world file and print what it contains;
/// `--render` echoes the canonical form (the round-trip fixpoint).
fn cmd_world(args: &Args) -> Result<()> {
    let path = args
        .positionals
        .first()
        .ok_or_else(|| Error::Usage("world <file> required".into()))?;
    let text = std::fs::read_to_string(path)?;
    let spec = WorldSpec::parse(&text)?;
    if args.has_flag("render") {
        print!("{}", spec.render());
        return Ok(());
    }
    let world: CompiledWorld = spec.compile()?;
    println!(
        "world: m={} horizon={} bandwidth={} events={} directives={}",
        world.initial_pages().len(),
        world.horizon,
        world.bandwidth,
        world.scenario.events().len(),
        spec.directives().len()
    );
    match &world.faults {
        Some(fc) => println!(
            "faults: transient={} timeout={} gone={} hosts={} outage_windows={}",
            fc.transient_prob, fc.timeout_prob, fc.gone_prob, fc.hosts, fc.outages.len()
        ),
        None => println!("faults: none"),
    }
    match &world.traffic {
        Some(tr) => println!(
            "traffic: rate={} zipf={} diurnal={} flashes={}",
            tr.rate(),
            tr.zipf_s(),
            tr.diurnal().is_some(),
            tr.flashes().len()
        ),
        None => println!("traffic: none"),
    }
    Ok(())
}

/// Run a fuzz campaign; violations are written as repro bundles
/// (`fuzz-<seed>.world` / `.jsonl` / `.txt`) under `--out` and turn the
/// exit status nonzero so CI fails loudly.
fn cmd_fuzz(args: &Args) -> Result<()> {
    let cfg = FuzzConfig {
        worlds: args.usize_or("worlds", 200)?,
        start_seed: args.u64_or("seed", 1)?,
        budget: match args.f64_or("budget-secs", 0.0)? {
            t if t > 0.0 => Some(std::time::Duration::from_secs_f64(t)),
            _ => None,
        },
    };
    let out_dir = Path::new(args.opt("out").unwrap_or("target/fuzz"));
    let outcome = run_fuzz(&cfg);
    println!(
        "fuzz: {} worlds, {} lanes (each replayed twice), {} violations",
        outcome.worlds,
        outcome.lanes,
        outcome.violations.len()
    );
    if outcome.clean() {
        return Ok(());
    }
    std::fs::create_dir_all(out_dir)?;
    for v in &outcome.violations {
        let base = out_dir.join(format!("fuzz-{:016x}", v.seed));
        std::fs::write(base.with_extension("world"), &v.dsl)?;
        std::fs::write(base.with_extension("jsonl"), &v.flight_jsonl)?;
        std::fs::write(base.with_extension("txt"), v.to_string())?;
        eprintln!("violation: seed 0x{:x}: {}", v.seed, v.message);
    }
    Err(Error::Runtime(format!(
        "fuzz found {} violation(s); repro bundles in {}",
        outcome.violations.len(),
        out_dir.display()
    )))
}

/// Dispatch a parsed command line.
pub fn run_cli(args: &Args) -> Result<()> {
    // first use of the runtime logs artifacts state; keep CLI quiet otherwise
    match args.command.as_deref() {
        Some("simulate") => cmd_simulate(args),
        Some("experiment") => cmd_experiment(args),
        Some("solve") => cmd_solve(args),
        Some("dataset") => cmd_dataset(args),
        Some("estimate") => cmd_estimate(args),
        Some("serve-shards") => cmd_serve_shards(args),
        Some("trace") => cmd_trace(args),
        Some("figure") => cmd_figure(args),
        Some("world") => cmd_world(args),
        Some("fuzz") => cmd_fuzz(args),
        Some("report") => {
            let path = args
                .positionals
                .first()
                .ok_or_else(|| Error::Usage("report <figure-csv> required".into()))?;
            let table = crate::report::Table::load(Path::new(path))?;
            println!("{}", crate::report::render_chart(&table, 72, 18));
            Ok(())
        }
        Some("artifacts") => {
            let dir = Path::new(args.opt("dir").unwrap_or("artifacts"));
            let engine = crate::runtime::PjrtEngine::load(dir)?;
            println!("loaded {:?}", engine);
            for (t, b) in engine.crawl_configs() {
                println!("  crawl_value terms={t} batch={b}");
            }
            Ok(())
        }
        Some(other) => Err(Error::Usage(format!("unknown command `{other}`\n{USAGE}"))),
        None => {
            println!("{USAGE}");
            Ok(())
        }
    }
}
