//! Special functions underlying the crawl-value formulas.
//!
//! Everything in Theorem 1 is built from the *normalized residual of the
//! i-th Taylor approximation of exp*:
//!
//! ```text
//! R^i(x) = (exp(x) - Σ_{j≤i} x^j/j!) / exp(x)
//!        = 1 - exp(-x) Σ_{j≤i} x^j/j!
//!        = P(i+1, x)                 (regularized lower incomplete gamma)
//! ```
//!
//! [`exp_residual`] mirrors the Python oracle (`python/compile/kernels/
//! ref.py::exp_residual`) branch-for-branch so rust-vs-python golden
//! tests agree to f64 accuracy; [`gamma_p`] is an independent general
//! implementation (series + continued fraction, Numerical-Recipes style)
//! used to cross-check it.

/// Natural log of the gamma function (Lanczos approximation, |err| < 2e-10).
pub fn ln_gamma(x: f64) -> f64 {
    // Lanczos g=7, n=9 coefficients.
    const COEF: [f64; 9] = [
        0.99999999999980993,
        676.5203681218851,
        -1259.1392167224028,
        771.32342877765313,
        -176.61502916214059,
        12.507343278686905,
        -0.13857109526572012,
        9.9843695780195716e-6,
        1.5056327351493116e-7,
    ];
    if x < 0.5 {
        // reflection formula
        return std::f64::consts::PI.ln()
            - (std::f64::consts::PI * x).sin().ln()
            - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = COEF[0];
    let t = x + 7.5;
    for (i, &c) in COEF.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

/// Regularized lower incomplete gamma `P(a, x)`.
///
/// Series expansion for `x < a + 1`, Lentz continued fraction otherwise.
pub fn gamma_p(a: f64, x: f64) -> f64 {
    assert!(a > 0.0, "gamma_p requires a > 0, got {a}");
    if x <= 0.0 {
        return 0.0;
    }
    if x < a + 1.0 {
        // series: P(a,x) = x^a e^-x / Γ(a) Σ_{n>=0} x^n / (a (a+1) ... (a+n))
        let mut ap = a;
        let mut sum = 1.0 / a;
        let mut del = sum;
        for _ in 0..500 {
            ap += 1.0;
            del *= x / ap;
            sum += del;
            if del.abs() < sum.abs() * 1e-16 {
                break;
            }
        }
        (sum * (-x + a * x.ln() - ln_gamma(a)).exp()).clamp(0.0, 1.0)
    } else {
        // continued fraction for Q(a,x), then P = 1 - Q (modified Lentz)
        let fpmin = 1e-300;
        let mut b = x + 1.0 - a;
        let mut c = 1.0 / fpmin;
        let mut d = 1.0 / b;
        let mut h = d;
        for i in 1..500 {
            let an = -(i as f64) * (i as f64 - a);
            b += 2.0;
            d = an * d + b;
            if d.abs() < fpmin {
                d = fpmin;
            }
            c = b + an / c;
            if c.abs() < fpmin {
                c = fpmin;
            }
            d = 1.0 / d;
            let del = d * c;
            h *= del;
            if (del - 1.0).abs() < 1e-16 {
                break;
            }
        }
        let q = (-x + a * x.ln() - ln_gamma(a)).exp() * h;
        (1.0 - q).clamp(0.0, 1.0)
    }
}

/// Reciprocals 1/j for the residual inner loops (a divide per term would
/// dominate the scheduler hot path; see EXPERIMENTS.md §Perf).
const INV: [f64; 96] = {
    let mut t = [0.0f64; 96];
    let mut j = 1usize;
    while j < 96 {
        t[j] = 1.0 / j as f64;
        j += 1;
    }
    t
};

/// `R^i(x)`: normalized residual of the i-th Taylor approximation of exp.
///
/// Two-branch scheme identical to the Python oracle: direct
/// `1 - e^{-x} Σ_{j≤i} x^j/j!` for `x ≥ 0.5`, 12-term tail series below
/// (avoids catastrophic cancellation for small `x`). Negative `x` (which
/// arises only from masked-out terms upstream) returns 0.
pub fn exp_residual(i: u32, x: f64) -> f64 {
    if x < 0.0 {
        return 0.0;
    }
    if x >= 0.5 {
        // large-x early out: for x ≥ 2i + 60 the Poisson left tail
        // Q(i+1, x) = P[Pois(x) ≤ i] ≤ e^{-x}(ex/i)^i < 1e-20, i.e.
        // R^i(x) = 1 to f64 accuracy — and, crucially, the direct sum
        // below would overflow (x^i/i! → ∞, times e^{-x} → 0·∞ = NaN)
        // for the huge effective times produced by λ → 1 pages.
        if x > 2.0 * i as f64 + 60.0 {
            return 1.0;
        }
        let mut term = 1.0;
        let mut s = 1.0;
        for j in 1..=i as usize {
            term *= x * INV[j];
            s += term;
        }
        (1.0 - (-x).exp() * s).clamp(0.0, 1.0)
    } else {
        // R^i(x) = e^{-x} x^{i+1}/(i+1)! (1 + x/(i+2) + x^2/((i+2)(i+3)) + ...)
        let mut fact = 1.0;
        for j in 1..=(i + 1) {
            fact *= j as f64;
        }
        let lead = x.powi(i as i32 + 1) / fact;
        let mut ser = 0.0;
        let mut t = 1.0;
        for k in 0..12usize {
            if k > 0 {
                t *= x * INV[i as usize + 1 + k];
            }
            ser += t;
        }
        ((-x).exp() * lead * ser).clamp(0.0, 1.0)
    }
}

/// Fused pair `(R^i(x), R^i(y))` — one inner loop with two accumulators
/// for the crawl-value hot path, where every term needs the residual at
/// both `γ·off` and `(α+γ)·off`. Semantics identical to two
/// [`exp_residual`] calls.
#[inline]
pub fn exp_residual_pair(i: u32, x: f64, y: f64) -> (f64, f64) {
    // fall back to the scalar path when either argument is outside the
    // shared direct-branch regime
    let bound = 2.0 * i as f64 + 60.0;
    if x < 0.5 || y < 0.5 || x > bound || y > bound {
        return (exp_residual(i, x), exp_residual(i, y));
    }
    let mut tx = 1.0;
    let mut ty = 1.0;
    let mut sx = 1.0;
    let mut sy = 1.0;
    for j in 1..=i as usize {
        tx *= x * INV[j];
        ty *= y * INV[j];
        sx += tx;
        sy += ty;
    }
    (
        (1.0 - (-x).exp() * sx).clamp(0.0, 1.0),
        (1.0 - (-y).exp() * sy).clamp(0.0, 1.0),
    )
}

/// Sum of residuals with a SHARED argument:
/// `Σ_{i=0}^{n-1} c_i R^i(x)` for geometric coefficients `c_i = c₀ rᶦ`,
/// using one `exp` and one running partial sum (the β = 0 fast path of
/// the crawl value — pages whose signals carry no information, λ = 0,
/// hit every term with the same argument).
///
/// Returns `(Σ c_i R^i(x), Σ R^i(x))` — the w-style and ψ-style sums.
pub fn exp_residual_geom_sum(n: u32, x: f64, c0: f64, r: f64, y: f64) -> (f64, f64) {
    // w-sum uses argument y, psi-sum uses argument x (they differ:
    // ψ terms take γι, w terms take (α+γ)ι).
    debug_assert!(x >= 0.0 && y >= 0.0);
    let n = n as usize;
    let ex = (-x).exp();
    let ey = (-y).exp();
    let mut sx = 0.0; // partial sum Σ_{j≤i} x^j/j!
    let mut sy = 0.0;
    let mut tx = 1.0;
    let mut ty = 1.0;
    let mut psi = 0.0;
    let mut w = 0.0;
    let mut coef = c0;
    for i in 0..n {
        if i > 0 {
            tx *= x * INV[i];
            ty *= y * INV[i];
        }
        sx += tx;
        sy += ty;
        // R^i = 1 - e^{-x} S_i, computed stably via the clamp (the
        // small-x cancellation regime matters little here because the
        // terms are *summed* against O(1) siblings)
        let rx = (1.0 - ex * sx).clamp(0.0, 1.0);
        let ry = (1.0 - ey * sy).clamp(0.0, 1.0);
        psi += rx;
        w += coef * ry;
        coef *= r;
    }
    (w, psi)
}

/// Derivative of `R^i` from identity (3): `d/dx R^i(x) = x^i e^{-x} / i!`.
pub fn exp_residual_deriv(i: u32, x: f64) -> f64 {
    if x < 0.0 {
        return 0.0;
    }
    let mut fact = 1.0;
    for j in 1..=i {
        fact *= j as f64;
    }
    x.powi(i as i32) * (-x).exp() / fact
}

/// Inverse of `R^1` (strictly increasing on `[0, ∞)` onto `[0, 1)`),
/// solved by bisection. Used by the no-CIS continuous solver where the
/// KKT condition reads `R^1(Δ/ξ) = ΛΔ/μ`.
pub fn inv_exp_residual1(y: f64) -> f64 {
    assert!((0.0..1.0).contains(&y), "inv_exp_residual1 domain: {y}");
    if y == 0.0 {
        return 0.0;
    }
    let (mut lo, mut hi) = (0.0f64, 1.0f64);
    while exp_residual(1, hi) < y {
        hi *= 2.0;
        if hi > 1e12 {
            return hi;
        }
    }
    for _ in 0..200 {
        let mid = 0.5 * (lo + hi);
        if exp_residual(1, mid) < y {
            lo = mid;
        } else {
            hi = mid;
        }
        if hi - lo < 1e-14 * hi.max(1.0) {
            break;
        }
    }
    0.5 * (lo + hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ln_gamma_known_values() {
        // Γ(1)=1, Γ(2)=1, Γ(5)=24, Γ(0.5)=√π
        assert!((ln_gamma(1.0)).abs() < 1e-10);
        assert!((ln_gamma(2.0)).abs() < 1e-10);
        assert!((ln_gamma(5.0) - 24f64.ln()).abs() < 1e-10);
        assert!((ln_gamma(0.5) - 0.5 * std::f64::consts::PI.ln()).abs() < 1e-10);
    }

    #[test]
    fn residual_matches_gamma_p() {
        for i in 0..8u32 {
            for &x in &[1e-6, 1e-3, 0.1, 0.4999, 0.5, 0.5001, 1.0, 5.0, 30.0] {
                let r = exp_residual(i, x);
                let p = gamma_p(i as f64 + 1.0, x);
                assert!(
                    (r - p).abs() < 1e-10,
                    "R^{i}({x}) = {r} vs P = {p}"
                );
            }
        }
    }

    #[test]
    fn residual_bounds_and_monotonicity() {
        for i in 0..6u32 {
            let mut prev = 0.0;
            for k in 0..200 {
                let x = k as f64 * 0.25;
                let r = exp_residual(i, x);
                assert!((0.0..=1.0).contains(&r));
                assert!(r + 1e-12 >= prev, "R^{i} must be nondecreasing");
                prev = r;
                // decreasing in order
                assert!(exp_residual(i + 1, x) <= r + 1e-12);
            }
        }
    }

    #[test]
    fn residual_derivative_identity() {
        for i in 0..5u32 {
            for &x in &[0.05f64, 0.3, 0.7, 2.0, 10.0] {
                let h = 1e-6 * x.max(1.0);
                let num = (exp_residual(i, x + h) - exp_residual(i, x - h)) / (2.0 * h);
                let exact = exp_residual_deriv(i, x);
                assert!(
                    (num - exact).abs() < 1e-5 * exact.max(1e-8),
                    "i={i} x={x}: {num} vs {exact}"
                );
            }
        }
    }

    #[test]
    fn residual_closed_forms() {
        // R^0(x) = 1 - e^-x
        for &x in &[0.1, 1.0, 4.0] {
            assert!((exp_residual(0, x) - (1.0 - (-x).exp())).abs() < 1e-12);
        }
        // R^1(x) = 1 - e^-x (1 + x)
        for &x in &[0.6f64, 2.0] {
            let want = 1.0 - (-x).exp() * (1.0 + x);
            assert!((exp_residual(1, x) - want).abs() < 1e-12);
        }
    }

    #[test]
    fn small_x_no_cancellation() {
        // direct f64 evaluation of R^1(1e-8) would lose ~8 digits
        let r = exp_residual(1, 1e-8);
        let exact = 0.5e-16; // x^2/2 to leading order
        assert!((r - exact).abs() < 1e-19, "{r}");
    }

    #[test]
    fn inverse_residual_roundtrip() {
        for &y in &[1e-6, 1e-3, 0.1, 0.5, 0.9, 0.999] {
            let x = inv_exp_residual1(y);
            assert!((exp_residual(1, x) - y).abs() < 1e-9, "y={y} x={x}");
        }
    }
}
