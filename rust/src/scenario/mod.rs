//! Dynamic-world scenario engine: scripted page churn, parameter
//! drift, CIS outages and bandwidth shifts over the streaming
//! simulator.
//!
//! Every other simulation in the crate runs a *frozen* world: a fixed
//! page population with stationary `(Δ, μ, λ, ν)` for the whole
//! horizon. The paper's adaptivity claim — the crawler "automatically
//! adapts to the new optimal solution … without centralized
//! computation" — is only exercised there for bandwidth steps. This
//! module makes the harsher production regimes first-class,
//! reproducible workloads:
//!
//! - a [`Scenario`] is a deterministic, seedable timeline of
//!   [`WorldEvent`]s over an initial population;
//! - [`engine::simulate_scenario_with`] merges that world-event stream
//!   into the simulator's k-way event merge, regenerating per-page
//!   event streams when truth parameters change mid-run and recycling
//!   page slots with generation counters (an empty scenario is pinned
//!   **bit-identical** to the static engine — `tests/scenario_parity.rs`);
//! - [`generators`] provides composable canonical stress patterns:
//!   steady churn at rate ρ, flash-crowd bursts, diurnal drift and
//!   correlated host-level CIS outages;
//! - schedulers participate through the three dynamic lifecycle hooks
//!   on [`crate::sched::CrawlScheduler`] (`on_page_added`,
//!   `on_page_removed`, `on_params_changed`), and
//!   [`crate::CrawlerBuilder::with_scenario`] runs any policy ×
//!   strategy × backend combination against a dynamic world.
//!
//! ## Information contract
//!
//! Not every world event is visible to the crawler, by design:
//!
//! | event | scheduler notified? | rationale |
//! |---|---|---|
//! | [`WorldEvent::PageBorn`] | yes (`on_page_added`) | frontier discovery is observable |
//! | [`WorldEvent::PageRetired`] | yes (`on_page_removed`) | dead URLs are observable (404s) |
//! | [`WorldEvent::ParamsChanged`] | yes (`on_params_changed`) | models a re-estimation pipeline surfacing new parameters |
//! | [`WorldEvent::CisQualityShift`] | **no** | a silently degrading ping feed — beliefs go stale, exactly the stress motivating online re-estimation |
//! | [`WorldEvent::CisOutage`] | **no** | a dark feed delivers nothing; the crawler cannot distinguish "no signals" from "no changes" |
//! | [`WorldEvent::BandwidthChange`] | no (drives tick spacing) | same observability as the Appendix-D experiment |
//!
//! Worlds also have a concrete syntax: the [`dsl`] module parses a
//! small line-oriented config format that composes the generators (and
//! the fault / serving layers) into named adversarial archetypes, the
//! [`invariants`] module packages the engine's conservation laws as a
//! reusable [`invariants::WorldAudit`], and the [`fuzz`] module drives
//! randomized DSL worlds through every engine twice, demanding
//! bit-identical replay (see DESIGN.md §12).

pub mod dsl;
pub mod engine;
pub mod fuzz;
pub mod generators;
pub mod invariants;

pub use dsl::{bit_identical, parse_world, CompiledWorld, DslError, WorldSpec};
pub use engine::{
    simulate_scenario, simulate_scenario_served_with, simulate_scenario_streamed,
    simulate_scenario_streamed_served_with, simulate_scenario_streamed_traced_with,
    simulate_scenario_streamed_with, simulate_scenario_traced_with, simulate_scenario_with,
    ScenarioStats, ScenarioWorkspace,
};
pub use fuzz::{run_fuzz, FuzzConfig, FuzzOutcome, FuzzViolation};
pub use invariants::WorldAudit;

use crate::params::PageParams;
use crate::sim::CisDelay;

/// A set of page slots a world event applies to.
#[derive(Debug, Clone, PartialEq)]
pub enum PageSet {
    /// Every page live at the event time.
    All,
    /// An explicit list of slot indices (dead slots are skipped).
    Pages(Vec<usize>),
}

impl PageSet {
    /// Does the set name `page` (membership only — liveness is the
    /// engine's concern)?
    pub fn contains(&self, page: usize) -> bool {
        match self {
            PageSet::All => true,
            PageSet::Pages(v) => v.contains(&page),
        }
    }
}

/// One scripted change to the world, applied at its [`TimedEvent`]
/// time in `(time, script order)` order, *before* any trace event at
/// the same time.
#[derive(Debug, Clone, PartialEq)]
pub enum WorldEvent {
    /// A page is born. The engine assigns it the most recently retired
    /// slot (LIFO recycling) or grows the population by one; its event
    /// streams are generated over `[t, horizon)` from the scenario
    /// seed, and `on_page_added` fires with the assigned slot.
    PageBorn {
        /// Raw parameters of the new page.
        params: PageParams,
    },
    /// Slot `page` dies: its remaining events are discarded, it can
    /// never be crawled again, and the slot becomes recyclable.
    PageRetired {
        /// Slot to retire.
        page: usize,
    },
    /// The true parameters of `page` shift: its *future* event streams
    /// are regenerated under `params` (the realization changes, the
    /// past does not) and `on_params_changed` fires.
    ParamsChanged {
        /// Slot whose parameters shift.
        page: usize,
        /// The new raw parameters.
        params: PageParams,
    },
    /// The CIS feed quality of `page` shifts: future CIS are re-drawn
    /// with recall `lam` and false-positive rate `nu` against the
    /// page's *existing* future change realization (changes and
    /// requests are untouched). The scheduler is NOT notified — its
    /// beliefs silently go stale.
    CisQualityShift {
        /// Slot whose feed degrades/improves.
        page: usize,
        /// New recall λ ∈ [0, 1].
        lam: f64,
        /// New false-positive rate ν ≥ 0.
        nu: f64,
    },
    /// The CIS feed for `pages` goes dark for `duration`: every CIS
    /// delivery in the window is dropped before reaching the scheduler
    /// (overlapping outages extend the window). A [`PageSet::All`]
    /// blackout also covers pages born while it is active; a
    /// [`PageSet::Pages`] outage affects exactly the listed live slots.
    /// Silent.
    CisOutage {
        /// Affected pages.
        pages: PageSet,
        /// Outage length.
        duration: f64,
    },
    /// Crawl bandwidth changes to `rate` from this time on, spliced
    /// into the run's [`crate::sim::engine::BandwidthSchedule`] with
    /// latest-directive-wins semantics.
    BandwidthChange {
        /// New tick rate R (> 0, finite).
        rate: f64,
    },
}

/// A world event with its application time.
#[derive(Debug, Clone)]
pub struct TimedEvent {
    /// Application time (≥ 0, finite).
    pub t: f64,
    /// The event.
    pub event: WorldEvent,
}

/// A deterministic, seedable timeline of world events over an initial
/// population. Events are kept sorted by time with stable script order
/// among equal times; the `seed` drives every event stream the engine
/// regenerates (births, drifts, quality shifts), so a scenario
/// replayed from the same seed is bit-identical.
#[derive(Debug, Clone)]
pub struct Scenario {
    initial: Vec<PageParams>,
    events: Vec<TimedEvent>,
    seed: u64,
    delay: CisDelay,
}

impl Scenario {
    /// A scenario over `initial` pages with no events yet. `seed`
    /// drives all regenerated event streams.
    pub fn new(initial: Vec<PageParams>, seed: u64) -> Self {
        Self { initial, events: Vec::new(), seed, delay: CisDelay::None }
    }

    /// CIS delivery-delay model applied to regenerated streams
    /// (default: [`CisDelay::None`]). Pass the same model to the
    /// initial-trace generation for a uniform world.
    pub fn with_delay(mut self, delay: CisDelay) -> Self {
        self.delay = delay;
        self
    }

    /// Append an event at time `t`, keeping the timeline sorted
    /// (stable: equal times preserve push order). Panics on a
    /// non-finite/negative time or a non-positive bandwidth rate —
    /// scenarios are scripts, and a malformed directive is a bug at
    /// the script site, not a runtime condition.
    pub fn push(&mut self, t: f64, event: WorldEvent) {
        Self::validate_event(t, &event);
        // stable upper-bound insertion: equal-time events keep push order
        let at = self.events.partition_point(|e| e.t <= t);
        self.events.insert(at, TimedEvent { t, event });
    }

    /// Append a whole batch in one pass: every event is validated,
    /// appended, and the timeline is re-sorted with one stable sort —
    /// O((n+k)·log(n+k)) instead of the O(n·k) of repeated
    /// [`Self::push`] inserts. Equal-time semantics match `push`:
    /// existing events keep their order, batch events land after them
    /// and keep batch order. Generators emitting thousands of events
    /// go through here.
    pub fn push_many(&mut self, batch: impl IntoIterator<Item = (f64, WorldEvent)>) {
        for (t, event) in batch {
            Self::validate_event(t, &event);
            self.events.push(TimedEvent { t, event });
        }
        // stable: preserves existing order and batch order at equal times
        self.events.sort_by(|a, b| a.t.total_cmp(&b.t));
    }

    fn validate_event(t: f64, event: &WorldEvent) {
        assert!(t.is_finite() && t >= 0.0, "world event time must be finite and >= 0, got {t}");
        match event {
            WorldEvent::BandwidthChange { rate } => assert!(
                *rate > 0.0 && rate.is_finite(),
                "bandwidth change rate must be > 0 and finite, got {rate}"
            ),
            WorldEvent::CisOutage { duration, .. } => assert!(
                *duration > 0.0 && duration.is_finite(),
                "outage duration must be > 0 and finite, got {duration}"
            ),
            WorldEvent::PageBorn { params } | WorldEvent::ParamsChanged { params, .. } => {
                if let Err(e) = params.validate() {
                    panic!("world event page params invalid: {e}");
                }
            }
            WorldEvent::CisQualityShift { lam, nu, .. } => {
                assert!(
                    (0.0..=1.0).contains(lam),
                    "quality shift recall must be in [0,1], got {lam}"
                );
                assert!(
                    *nu >= 0.0 && nu.is_finite(),
                    "quality shift false-positive rate must be >= 0 and finite, got {nu}"
                );
            }
            WorldEvent::PageRetired { .. } => {}
        }
    }

    /// Builder-style [`Self::push`].
    pub fn at(mut self, t: f64, event: WorldEvent) -> Self {
        self.push(t, event);
        self
    }

    /// The initial page population.
    pub fn initial_pages(&self) -> &[PageParams] {
        &self.initial
    }

    /// The sorted event timeline.
    pub fn events(&self) -> &[TimedEvent] {
        &self.events
    }

    /// Seed driving regenerated event streams.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// CIS delay model for regenerated streams.
    pub fn delay(&self) -> CisDelay {
        self.delay
    }

    /// Does the timeline contain no events (a static world)?
    pub fn is_static(&self) -> bool {
        self.events.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn page() -> PageParams {
        PageParams { delta: 0.5, mu: 0.5, lam: 0.3, nu: 0.1 }
    }

    #[test]
    fn timeline_stays_sorted_with_stable_ties() {
        let sc = Scenario::new(vec![page()], 1)
            .at(5.0, WorldEvent::PageRetired { page: 0 })
            .at(1.0, WorldEvent::BandwidthChange { rate: 2.0 })
            .at(5.0, WorldEvent::PageBorn { params: page() })
            .at(3.0, WorldEvent::CisOutage { pages: PageSet::All, duration: 1.0 });
        let times: Vec<f64> = sc.events().iter().map(|e| e.t).collect();
        assert_eq!(times, vec![1.0, 3.0, 5.0, 5.0]);
        // equal-time events preserve push order: retire before birth
        assert!(matches!(sc.events()[2].event, WorldEvent::PageRetired { .. }));
        assert!(matches!(sc.events()[3].event, WorldEvent::PageBorn { .. }));
    }

    #[test]
    #[should_panic(expected = "world event time")]
    fn rejects_bad_event_time() {
        Scenario::new(vec![page()], 1).push(f64::NAN, WorldEvent::PageRetired { page: 0 });
    }

    #[test]
    #[should_panic(expected = "bandwidth change rate")]
    fn rejects_bad_bandwidth_rate() {
        Scenario::new(vec![page()], 1).push(1.0, WorldEvent::BandwidthChange { rate: 0.0 });
    }

    #[test]
    #[should_panic(expected = "page params invalid")]
    fn rejects_invalid_born_page_params() {
        let bad = PageParams { delta: 0.0, mu: 0.5, lam: 0.3, nu: 0.1 };
        Scenario::new(vec![page()], 1).push(1.0, WorldEvent::PageBorn { params: bad });
    }

    #[test]
    #[should_panic(expected = "quality shift recall")]
    fn rejects_out_of_range_quality_shift() {
        Scenario::new(vec![page()], 1)
            .push(1.0, WorldEvent::CisQualityShift { page: 0, lam: 1.3, nu: 0.1 });
    }

    #[test]
    fn page_set_membership() {
        assert!(PageSet::All.contains(7));
        let s = PageSet::Pages(vec![1, 3]);
        assert!(s.contains(3) && !s.contains(2));
    }
}
