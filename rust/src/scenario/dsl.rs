//! Line-oriented world-configuration DSL: adversarial scenarios as
//! small text files.
//!
//! Every stress world the crate ships — the figure harness's churn +
//! blackout run, the fault sweep's correlated-outage severities, the
//! serving figure's flash-crowd traffic — is hand-assembled from the
//! same building blocks: an `ExperimentSpec` population, a [`Scenario`]
//! with generator calls, a [`FaultConfig`] and a [`RequestTraffic`].
//! This module gives those compositions a concrete syntax, so a world
//! is a reviewable artifact (checked into `tests/corpus/`, passed to
//! `--world`, mutated by the fuzzer) instead of a code path:
//!
//! ```text
//! # fig_scenario's churn + blackout world
//! world horizon=400.0 bandwidth=100.0 scenario_seed=0x5ce7
//! pages section6 m=1000 seed=0x5eed partial_cis false_positives normalized
//! churn rho=0.005 seed=0x5ce8
//! outage t=150.0 duration=100.0 pages=all
//! ```
//!
//! The parser is hand-rolled (the crate's zero-dependency discipline —
//! same idiom as [`crate::cli::Args`] and [`crate::config`]): one
//! directive per line, `#` comments, whitespace-separated `key=value`
//! tokens plus bare flags. Errors carry 1-based line *and* column
//! context ([`DslError`]) and the parser never panics on malformed
//! input — every constraint [`Scenario::push`] or a generator would
//! `assert!` on is pre-validated here and surfaced as `Err`.
//!
//! [`WorldSpec::compile`] replays the directives **in file order**
//! through the exact generator entry points the figures call
//! ([`add_steady_churn`], [`FaultConfig::add_correlated_outages`], …),
//! so a DSL world and its hand-constructed twin are bit-identical —
//! `tests/world_fuzz.rs` pins all three shipped figure worlds.
//! [`WorldSpec::render`] emits the canonical form; parse → render →
//! parse is the identity (every numeric field is printed in Rust's
//! shortest round-trip notation).
//!
//! ## Grammar
//!
//! | directive | fields | compiles to |
//! |---|---|---|
//! | `world` | `horizon= bandwidth= scenario_seed= [timeline_window=]` | [`SimConfig`] + [`Scenario`] seed (must be first) |
//! | `pages section6` | `m= [seed=] [partial_cis] [false_positives] [normalized]` | §6.3 population via `ExperimentSpec` (must be second) |
//! | `pages zipf` | `m= s= [seed=] [partial_cis] [false_positives] [normalized]` | heavy-tailed population, μᵢ ∝ (i+1)⁻ˢ |
//! | `churn` | `rho= [horizon=] [seed=]` | [`add_steady_churn`] |
//! | `flash` | `t= duration= frac= mu_factor= [delta_factor=] [seed=]` | [`add_flash_crowd`] |
//! | `drift` | `period= amplitude= samples= frac= [horizon=] [seed=]` | [`add_diurnal_drift`] |
//! | `outage` | `t= duration= [pages=all\|i,j,k]` | one [`WorldEvent::CisOutage`] |
//! | `host_outages` | `hosts= n= mean= [horizon=] [seed=]` | [`generators::add_correlated_outages`](add_correlated_outages) |
//! | `adversarial_cis` | `t= [frac=] lam= nu=` | [`WorldEvent::CisQualityShift`] on the top-μ `frac` of pages |
//! | `bandwidth` | `t= rate=` | one [`WorldEvent::BandwidthChange`] |
//! | `regions` | `t= interval= rates=a,b,c` | staggered `BandwidthChange` steps (multi-region failover) |
//! | `faults` | `transient= timeout= [gone=] [hosts=] [seed=]` | [`FaultConfig`] (≤ 1) |
//! | `fault_outages` | `n= mean= [horizon=] [seed=]` | [`FaultConfig::add_correlated_outages`] |
//! | `fault_window` | `host= start= end=` | one explicit [`HostOutage`] (overlaps rejected) |
//! | `retry` | `backoff` \| `immediate max_attempts=` | [`RetryPolicy`] (≤ 1) |
//! | `traffic` | `rate= zipf= [seed=]` | [`RequestTraffic`] (≤ 1) |
//! | `diurnal` | `period= amplitude=` | [`RequestTraffic::with_diurnal`] |
//! | `request_flash` | `t= duration= page= extra=` | [`RequestTraffic::with_flash`] |

use std::fmt;
use std::fmt::Write as _;

use crate::coordinator::builder::CrawlerBuilder;
use crate::fault::{FaultConfig, HostOutage, RetryPolicy};
use crate::figures::common::ExperimentSpec;
use crate::params::{Instance, PageParams};
use crate::rngkit::{self, Rng};
use crate::scenario::generators::{
    add_correlated_outages, add_diurnal_drift, add_flash_crowd, add_steady_churn, BornPageSpec,
};
use crate::scenario::{PageSet, Scenario, WorldEvent};
use crate::serving::RequestTraffic;
use crate::sim::SimConfig;

/// A parse or compile failure with 1-based source position.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DslError {
    /// 1-based line of the offending directive.
    pub line: usize,
    /// 1-based column of the offending token (1 = the directive name).
    pub col: usize,
    /// What went wrong.
    pub msg: String,
}

impl fmt::Display for DslError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "world config: line {}, col {}: {}", self.line, self.col, self.msg)
    }
}

impl std::error::Error for DslError {}

impl From<DslError> for crate::error::Error {
    fn from(e: DslError) -> Self {
        crate::error::Error::Config(e.to_string())
    }
}

/// How `pages` draws the initial population.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PageModel {
    /// §6.3 draws through [`ExperimentSpec::gen_instance`]: Δ, μ ~
    /// U[1e-4, 1).
    Section6,
    /// Heavy-tailed popularity: Δ as §6.3, μᵢ ∝ (i + 1)⁻ˢ (page index =
    /// popularity rank, matching the Zipf request model).
    Zipf {
        /// Tail exponent s > 0.
        s: f64,
    },
}

/// Retry policy selector (`retry` directive).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RetrySpec {
    /// [`RetryPolicy::default`]'s exponential backoff.
    Backoff,
    /// [`RetryPolicy::Immediate`] with the given attempt budget.
    Immediate {
        /// Consecutive failures tolerated before quarantine.
        max_attempts: u32,
    },
}

/// One parsed directive, in source order.
#[derive(Debug, Clone, PartialEq)]
pub enum Directive {
    /// `world horizon= bandwidth= scenario_seed= [timeline_window=]`.
    World {
        /// Horizon T > 0.
        horizon: f64,
        /// Initial bandwidth R > 0.
        bandwidth: f64,
        /// Seed of every event stream the scenario engine regenerates.
        scenario_seed: u64,
        /// Rolling-accuracy window ([`SimConfig::timeline_window`]).
        timeline_window: Option<usize>,
    },
    /// `pages <model> m= seed= [partial_cis] [false_positives]
    /// [normalized]`.
    Pages {
        /// Draw model.
        model: PageModel,
        /// Population size m ≥ 1.
        m: usize,
        /// Instance seed.
        seed: u64,
        /// λ ~ Beta(0.25, 0.25) (else λ = 0).
        partial_cis: bool,
        /// ν ~ U[0.1, 0.6) (else ν = 0).
        false_positives: bool,
        /// Normalize importance to μ̃ᵢ = μᵢ / Σμ.
        normalized: bool,
    },
    /// `churn rho= [horizon=] [seed=]`.
    Churn {
        /// Population turnover rate ρ ≥ 0 per unit time.
        rho: f64,
        /// Churn horizon (default: world horizon).
        horizon: Option<f64>,
        /// Generator seed.
        seed: u64,
    },
    /// `flash t= duration= frac= mu_factor= [delta_factor=] [seed=]`.
    Flash {
        /// Surge start.
        t: f64,
        /// Surge length > 0.
        duration: f64,
        /// Fraction of the population surged, in [0, 1].
        frac: f64,
        /// Importance multiplier ∈ [0, 1e6].
        mu_factor: f64,
        /// Change-rate multiplier ∈ [1e-6, 1e6] (default 1).
        delta_factor: f64,
        /// Generator seed.
        seed: u64,
    },
    /// `drift period= amplitude= samples= frac= [horizon=] [seed=]`.
    Drift {
        /// Cycle period > 0.
        period: f64,
        /// Relative Δ swing, |a| < 1.
        amplitude: f64,
        /// Re-pin samples per cycle ≥ 1.
        samples: usize,
        /// Fraction of pages drifting, in [0, 1].
        frac: f64,
        /// Drift horizon (default: world horizon).
        horizon: Option<f64>,
        /// Generator seed.
        seed: u64,
    },
    /// `outage t= duration= [pages=all|i,j,k]`.
    Outage {
        /// Outage start.
        t: f64,
        /// Outage length > 0.
        duration: f64,
        /// Affected slots (`None` = every live page).
        pages: Option<Vec<usize>>,
    },
    /// `host_outages hosts= n= mean= [horizon=] [seed=]`.
    HostOutages {
        /// Round-robin host count ≥ 1.
        hosts: usize,
        /// Number of outage windows.
        n: usize,
        /// Mean (exponential) outage length > 0.
        mean: f64,
        /// Start-time horizon (default: world horizon).
        horizon: Option<f64>,
        /// Generator seed.
        seed: u64,
    },
    /// `adversarial_cis t= [frac=] lam= nu=` — silently degrade the
    /// CIS feeds of the most-popular pages (highest μ), the worst-case
    /// quality attack: exactly where freshness matters most, recall
    /// collapses and false positives spike with no notification.
    AdversarialCis {
        /// Attack time.
        t: f64,
        /// Top-μ fraction attacked, in (0, 1] (default 0.1 — the top
        /// popularity decile).
        frac: f64,
        /// Degraded recall λ ∈ [0, 1].
        lam: f64,
        /// Degraded false-positive rate ν ≥ 0.
        nu: f64,
    },
    /// `bandwidth t= rate=`.
    Bandwidth {
        /// Step time.
        t: f64,
        /// New rate R > 0.
        rate: f64,
    },
    /// `regions t= interval= rates=a,b,c` — a multi-region capacity
    /// schedule: region k's (cumulative) rate lands at `t + k·interval`
    /// as one `BandwidthChange` step each, modeling staged failover or
    /// region-by-region rollout of crawl capacity.
    Regions {
        /// First step time.
        t: f64,
        /// Stagger between steps > 0.
        interval: f64,
        /// Per-step total rates, each > 0.
        rates: Vec<f64>,
    },
    /// `faults transient= timeout= [gone=] [hosts=] [seed=]`.
    Faults {
        /// Transient-error probability ∈ [0, 1].
        transient: f64,
        /// Timeout probability ∈ [0, 1].
        timeout: f64,
        /// Permanently-gone probability ∈ [0, 1].
        gone: f64,
        /// Round-robin host count ≥ 1.
        hosts: usize,
        /// Fault-substream master seed.
        seed: u64,
    },
    /// `fault_outages n= mean= [horizon=] [seed=]`.
    FaultOutages {
        /// Number of fetch-outage windows.
        n: usize,
        /// Mean window length > 0.
        mean: f64,
        /// Start-time horizon (default: world horizon).
        horizon: Option<f64>,
        /// Generator seed.
        seed: u64,
    },
    /// `fault_window host= start= end=` — one explicit fetch-outage
    /// window; windows on the same host must not overlap.
    FaultWindow {
        /// Darkened host.
        host: usize,
        /// Window start ≥ 0.
        start: f64,
        /// Window end > start.
        end: f64,
    },
    /// `retry backoff` | `retry immediate max_attempts=`.
    Retry(RetrySpec),
    /// `traffic rate= zipf= [seed=]`.
    Traffic {
        /// Aggregate base request rate ≥ 0.
        rate: f64,
        /// Zipf popularity exponent ≥ 0.
        zipf: f64,
        /// Traffic seed.
        seed: u64,
    },
    /// `diurnal period= amplitude=`.
    Diurnal {
        /// Cycle period > 0.
        period: f64,
        /// Rate modulation depth ∈ [0, 1].
        amplitude: f64,
    },
    /// `request_flash t= duration= page= extra=`.
    RequestFlash {
        /// Flash start.
        t: f64,
        /// Flash length > 0.
        duration: f64,
        /// Targeted page slot.
        page: usize,
        /// Additional request rate > 0.
        extra: f64,
    },
}

/// A parsed world file: directives in source order plus their source
/// lines (for compile-time error context). Equality compares the
/// directives only, so a rendered canonical form (comments stripped,
/// defaults explicit) still equals its source.
#[derive(Debug, Clone)]
pub struct WorldSpec {
    directives: Vec<Directive>,
    lines: Vec<usize>,
}

impl PartialEq for WorldSpec {
    fn eq(&self, other: &Self) -> bool {
        self.directives == other.directives
    }
}

/// A compiled world: everything [`CrawlerBuilder`] and the fault engine
/// consume, produced by [`WorldSpec::compile`].
#[derive(Debug, Clone)]
pub struct CompiledWorld {
    /// Horizon T.
    pub horizon: f64,
    /// Initial bandwidth R.
    pub bandwidth: f64,
    /// Rolling-accuracy window.
    pub timeline_window: Option<usize>,
    /// The world timeline over its initial population.
    pub scenario: Scenario,
    /// Fetch-failure model, when a `faults` block is present.
    pub faults: Option<FaultConfig>,
    /// Retry policy for the fault lane.
    pub retry: RetryPolicy,
    /// Request-side traffic, when a `traffic` block is present.
    pub traffic: Option<RequestTraffic>,
}

impl CompiledWorld {
    /// The initial page population.
    pub fn initial_pages(&self) -> &[PageParams] {
        self.scenario.initial_pages()
    }

    /// The run configuration (`bandwidth`, `horizon`,
    /// `timeline_window`).
    pub fn sim_config(&self) -> crate::Result<SimConfig> {
        let mut cfg = SimConfig::new(self.bandwidth, self.horizon)?;
        cfg.timeline_window = self.timeline_window;
        Ok(cfg)
    }

    /// A [`CrawlerBuilder`] pre-wired with this world's scenario and
    /// (when present) its traffic; callers add policy / strategy /
    /// knowledge.
    pub fn crawler(&self) -> CrawlerBuilder {
        let mut b = CrawlerBuilder::new().with_scenario(self.scenario.clone());
        if let Some(t) = &self.traffic {
            b = b.with_traffic(t.clone());
        }
        b
    }
}

/// Parse and compile in one step.
pub fn parse_world(text: &str) -> Result<CompiledWorld, DslError> {
    WorldSpec::parse(text)?.compile()
}

/// Bitwise scenario equality: seeds, delay model, initial parameters
/// and every timeline event compare by `f64::to_bits`, the same
/// criterion the replay tests use. [`Scenario`] deliberately has no
/// `PartialEq` (semantic float equality would be a trap); this is the
/// explicit, exact form the DSL pin tests and the fuzzer's round-trip
/// check need.
pub fn bit_identical(a: &Scenario, b: &Scenario) -> bool {
    fn feq(x: f64, y: f64) -> bool {
        x.to_bits() == y.to_bits()
    }
    fn peq(x: &PageParams, y: &PageParams) -> bool {
        feq(x.delta, y.delta) && feq(x.mu, y.mu) && feq(x.lam, y.lam) && feq(x.nu, y.nu)
    }
    fn eeq(x: &WorldEvent, y: &WorldEvent) -> bool {
        use WorldEvent::*;
        match (x, y) {
            (PageBorn { params: p }, PageBorn { params: q }) => peq(p, q),
            (PageRetired { page: p }, PageRetired { page: q }) => p == q,
            (ParamsChanged { page: i, params: p }, ParamsChanged { page: j, params: q }) => {
                i == j && peq(p, q)
            }
            (
                CisQualityShift { page: i, lam: l1, nu: n1 },
                CisQualityShift { page: j, lam: l2, nu: n2 },
            ) => i == j && feq(*l1, *l2) && feq(*n1, *n2),
            (CisOutage { pages: p, duration: d1 }, CisOutage { pages: q, duration: d2 }) => {
                p == q && feq(*d1, *d2)
            }
            (BandwidthChange { rate: r1 }, BandwidthChange { rate: r2 }) => feq(*r1, *r2),
            _ => false,
        }
    }
    a.seed() == b.seed()
        && a.delay() == b.delay()
        && a.initial_pages().len() == b.initial_pages().len()
        && a.initial_pages().iter().zip(b.initial_pages()).all(|(x, y)| peq(x, y))
        && a.events().len() == b.events().len()
        && a.events()
            .iter()
            .zip(b.events())
            .all(|(x, y)| feq(x.t, y.t) && eeq(&x.event, &y.event))
}

// ---------------------------------------------------------------- parsing

#[derive(Clone, Copy)]
struct Tok<'a> {
    text: &'a str,
    col: usize,
}

fn tokenize(line: &str) -> Vec<Tok<'_>> {
    let body = match line.find('#') {
        Some(i) => &line[..i],
        None => line,
    };
    let bytes = body.as_bytes();
    let mut toks = Vec::new();
    let mut i = 0;
    while i < bytes.len() {
        if bytes[i].is_ascii_whitespace() {
            i += 1;
            continue;
        }
        let start = i;
        while i < bytes.len() && !bytes[i].is_ascii_whitespace() {
            i += 1;
        }
        toks.push(Tok { text: &body[start..i], col: start + 1 });
    }
    toks
}

/// Field extractor for one directive line: `key=value` tokens and bare
/// flags are consumed as they are recognized; anything left over at
/// [`Fields::finish`] is trailing garbage and fails with its column.
struct Fields<'a> {
    line: usize,
    toks: Vec<Option<Tok<'a>>>,
}

impl<'a> Fields<'a> {
    fn new(line: usize, toks: &[Tok<'a>]) -> Self {
        Self { line, toks: toks.iter().copied().map(Some).collect() }
    }

    fn err(&self, col: usize, msg: impl Into<String>) -> DslError {
        DslError { line: self.line, col, msg: msg.into() }
    }

    fn take(&mut self, key: &str) -> Option<(usize, &'a str)> {
        for slot in self.toks.iter_mut() {
            if let Some(t) = slot {
                if let Some(rest) = t.text.strip_prefix(key) {
                    if let Some(v) = rest.strip_prefix('=') {
                        let col = t.col + key.len() + 1;
                        *slot = None;
                        return Some((col, v));
                    }
                }
            }
        }
        None
    }

    fn flag(&mut self, name: &str) -> bool {
        for slot in self.toks.iter_mut() {
            if slot.map(|t| t.text == name).unwrap_or(false) {
                *slot = None;
                return true;
            }
        }
        false
    }

    fn f64_raw(&self, col: usize, key: &str, v: &str) -> Result<f64, DslError> {
        v.parse::<f64>()
            .map_err(|_| self.err(col, format!("`{key}` expects a number, got `{v}`")))
    }

    /// Required f64 with a constraint predicate; `what` names the
    /// constraint in the error ("a finite number >= 0", …).
    fn f64_where(
        &mut self,
        key: &str,
        what: &str,
        pred: impl Fn(f64) -> bool,
    ) -> Result<f64, DslError> {
        match self.take(key) {
            None => Err(self.err(1, format!("missing required `{key}=`"))),
            Some((col, v)) => {
                let x = self.f64_raw(col, key, v)?;
                if pred(x) {
                    Ok(x)
                } else {
                    Err(self.err(col, format!("`{key}` must be {what}, got {v}")))
                }
            }
        }
    }

    /// Optional f64 with a constraint; `None` when absent.
    fn f64_opt_where(
        &mut self,
        key: &str,
        what: &str,
        pred: impl Fn(f64) -> bool,
    ) -> Result<Option<f64>, DslError> {
        match self.take(key) {
            None => Ok(None),
            Some((col, v)) => {
                let x = self.f64_raw(col, key, v)?;
                if pred(x) {
                    Ok(Some(x))
                } else {
                    Err(self.err(col, format!("`{key}` must be {what}, got {v}")))
                }
            }
        }
    }

    fn f64_or_where(
        &mut self,
        key: &str,
        default: f64,
        what: &str,
        pred: impl Fn(f64) -> bool,
    ) -> Result<f64, DslError> {
        Ok(self.f64_opt_where(key, what, pred)?.unwrap_or(default))
    }

    fn u64_or(&mut self, key: &str, default: u64) -> Result<u64, DslError> {
        match self.take(key) {
            None => Ok(default),
            Some((col, v)) => parse_u64(v)
                .ok_or_else(|| self.err(col, format!("`{key}` expects an integer, got `{v}`"))),
        }
    }

    fn usize_where(
        &mut self,
        key: &str,
        what: &str,
        pred: impl Fn(usize) -> bool,
    ) -> Result<usize, DslError> {
        match self.take(key) {
            None => Err(self.err(1, format!("missing required `{key}=`"))),
            Some((col, v)) => {
                let x = v
                    .parse::<usize>()
                    .map_err(|_| self.err(col, format!("`{key}` expects an integer, got `{v}`")))?;
                if pred(x) {
                    Ok(x)
                } else {
                    Err(self.err(col, format!("`{key}` must be {what}, got {v}")))
                }
            }
        }
    }

    fn usize_opt_where(
        &mut self,
        key: &str,
        what: &str,
        pred: impl Fn(usize) -> bool,
    ) -> Result<Option<usize>, DslError> {
        match self.take(key) {
            None => Ok(None),
            Some((col, v)) => {
                let x = v
                    .parse::<usize>()
                    .map_err(|_| self.err(col, format!("`{key}` expects an integer, got `{v}`")))?;
                if pred(x) {
                    Ok(Some(x))
                } else {
                    Err(self.err(col, format!("`{key}` must be {what}, got {v}")))
                }
            }
        }
    }

    /// `pages=all` → `None`; `pages=1,2,3` → sorted-as-written list.
    fn page_set(&mut self) -> Result<Option<Vec<usize>>, DslError> {
        match self.take("pages") {
            None => Ok(None),
            Some((_, "all")) => Ok(None),
            Some((col, v)) => {
                let mut out = Vec::new();
                for part in v.split(',') {
                    let p = part.parse::<usize>().map_err(|_| {
                        self.err(col, format!("`pages` expects `all` or indices, got `{v}`"))
                    })?;
                    out.push(p);
                }
                Ok(Some(out))
            }
        }
    }

    fn f64_list(&mut self, key: &str) -> Result<Vec<f64>, DslError> {
        match self.take(key) {
            None => Err(self.err(1, format!("missing required `{key}=`"))),
            Some((col, v)) => {
                let mut out = Vec::new();
                for part in v.split(',') {
                    let x = self.f64_raw(col, key, part)?;
                    if !(x > 0.0 && x.is_finite()) {
                        return Err(self.err(
                            col,
                            format!("`{key}` entries must be positive and finite, got {part}"),
                        ));
                    }
                    out.push(x);
                }
                Ok(out)
            }
        }
    }

    fn finish(self) -> Result<(), DslError> {
        for t in self.toks.into_iter().flatten() {
            return Err(DslError {
                line: self.line,
                col: t.col,
                msg: format!("unexpected trailing `{}`", t.text),
            });
        }
        Ok(())
    }
}

fn parse_u64(v: &str) -> Option<u64> {
    if let Some(hex) = v.strip_prefix("0x").or_else(|| v.strip_prefix("0X")) {
        u64::from_str_radix(hex, 16).ok()
    } else {
        v.parse::<u64>().ok()
    }
}

// shared constraint predicates + their error phrasing
const FIN_POS: (&str, fn(f64) -> bool) = ("positive and finite", |x| x > 0.0 && x.is_finite());
const FIN_NONNEG: (&str, fn(f64) -> bool) = ("finite and >= 0", |x| x >= 0.0 && x.is_finite());
const UNIT: (&str, fn(f64) -> bool) = ("in [0, 1]", |x| (0.0..=1.0).contains(&x));

impl WorldSpec {
    /// Parse a world file. Malformed input — unknown directives,
    /// NaN/negative/out-of-range values, trailing garbage — returns
    /// `Err` with line and column context; this function never panics.
    pub fn parse(text: &str) -> Result<Self, DslError> {
        let mut directives = Vec::new();
        let mut lines = Vec::new();
        for (i, raw) in text.lines().enumerate() {
            let line = i + 1;
            let toks = tokenize(raw);
            let Some(head) = toks.first().copied() else { continue };
            let mut f = Fields::new(line, &toks[1..]);
            let d = match head.text {
                "world" => Directive::World {
                    horizon: f.f64_where("horizon", FIN_POS.0, FIN_POS.1)?,
                    bandwidth: f.f64_where("bandwidth", FIN_POS.0, FIN_POS.1)?,
                    scenario_seed: f.u64_or("scenario_seed", 0)?,
                    timeline_window: f.usize_opt_where("timeline_window", "at least 1", |x| {
                        x >= 1
                    })?,
                },
                "pages" => parse_pages(&mut f)?,
                "churn" => Directive::Churn {
                    rho: f.f64_where("rho", FIN_NONNEG.0, FIN_NONNEG.1)?,
                    horizon: f.f64_opt_where("horizon", FIN_POS.0, FIN_POS.1)?,
                    seed: f.u64_or("seed", 0)?,
                },
                "flash" => Directive::Flash {
                    t: f.f64_where("t", FIN_NONNEG.0, FIN_NONNEG.1)?,
                    duration: f.f64_where("duration", FIN_POS.0, FIN_POS.1)?,
                    frac: f.f64_where("frac", UNIT.0, UNIT.1)?,
                    // factor bounds keep the scaled parameters inside
                    // PageParams::validate's domain (Δ·factor stays
                    // positive and finite for any §6.3 draw)
                    mu_factor: f.f64_where("mu_factor", "in [0, 1e6]", |x| {
                        (0.0..=1e6).contains(&x)
                    })?,
                    delta_factor: f.f64_or_where(
                        "delta_factor",
                        1.0,
                        "in [1e-6, 1e6]",
                        |x| (1e-6..=1e6).contains(&x),
                    )?,
                    seed: f.u64_or("seed", 0)?,
                },
                "drift" => Directive::Drift {
                    period: f.f64_where("period", FIN_POS.0, FIN_POS.1)?,
                    amplitude: f.f64_where("amplitude", "in (-1, 1)", |x| {
                        x.is_finite() && x.abs() < 1.0
                    })?,
                    samples: f.usize_where("samples", "at least 1", |x| x >= 1)?,
                    frac: f.f64_where("frac", UNIT.0, UNIT.1)?,
                    horizon: f.f64_opt_where("horizon", FIN_POS.0, FIN_POS.1)?,
                    seed: f.u64_or("seed", 0)?,
                },
                "outage" => Directive::Outage {
                    t: f.f64_where("t", FIN_NONNEG.0, FIN_NONNEG.1)?,
                    duration: f.f64_where("duration", FIN_POS.0, FIN_POS.1)?,
                    pages: f.page_set()?,
                },
                "host_outages" => Directive::HostOutages {
                    hosts: f.usize_where("hosts", "at least 1", |x| x >= 1)?,
                    n: f.usize_where("n", "an integer", |_| true)?,
                    mean: f.f64_where("mean", FIN_POS.0, FIN_POS.1)?,
                    horizon: f.f64_opt_where("horizon", FIN_POS.0, FIN_POS.1)?,
                    seed: f.u64_or("seed", 0)?,
                },
                "adversarial_cis" => Directive::AdversarialCis {
                    t: f.f64_where("t", FIN_NONNEG.0, FIN_NONNEG.1)?,
                    frac: f.f64_or_where("frac", 0.1, "in (0, 1]", |x| {
                        x > 0.0 && x <= 1.0
                    })?,
                    lam: f.f64_where("lam", UNIT.0, UNIT.1)?,
                    nu: f.f64_where("nu", FIN_NONNEG.0, FIN_NONNEG.1)?,
                },
                "bandwidth" => Directive::Bandwidth {
                    t: f.f64_where("t", FIN_NONNEG.0, FIN_NONNEG.1)?,
                    rate: f.f64_where("rate", FIN_POS.0, FIN_POS.1)?,
                },
                "regions" => Directive::Regions {
                    t: f.f64_where("t", FIN_NONNEG.0, FIN_NONNEG.1)?,
                    interval: f.f64_where("interval", FIN_POS.0, FIN_POS.1)?,
                    rates: f.f64_list("rates")?,
                },
                "faults" => Directive::Faults {
                    transient: f.f64_where("transient", UNIT.0, UNIT.1)?,
                    timeout: f.f64_where("timeout", UNIT.0, UNIT.1)?,
                    gone: f.f64_or_where("gone", 0.0, UNIT.0, UNIT.1)?,
                    hosts: f.usize_opt_where("hosts", "at least 1", |x| x >= 1)?.unwrap_or(1),
                    seed: f.u64_or("seed", 0)?,
                },
                "fault_outages" => Directive::FaultOutages {
                    n: f.usize_where("n", "an integer", |_| true)?,
                    mean: f.f64_where("mean", FIN_POS.0, FIN_POS.1)?,
                    horizon: f.f64_opt_where("horizon", FIN_POS.0, FIN_POS.1)?,
                    seed: f.u64_or("seed", 0)?,
                },
                "fault_window" => {
                    let host = f.usize_where("host", "an integer", |_| true)?;
                    let start = f.f64_where("start", FIN_NONNEG.0, FIN_NONNEG.1)?;
                    let end = f.f64_where("end", FIN_POS.0, FIN_POS.1)?;
                    if end <= start {
                        return Err(f.err(
                            1,
                            format!("fault_window end ({end}) must be after start ({start})"),
                        ));
                    }
                    Directive::FaultWindow { host, start, end }
                }
                "retry" => parse_retry(&mut f)?,
                "traffic" => Directive::Traffic {
                    rate: f.f64_where("rate", FIN_NONNEG.0, FIN_NONNEG.1)?,
                    zipf: f.f64_where("zipf", FIN_NONNEG.0, FIN_NONNEG.1)?,
                    seed: f.u64_or("seed", 0)?,
                },
                "diurnal" => Directive::Diurnal {
                    period: f.f64_where("period", FIN_POS.0, FIN_POS.1)?,
                    amplitude: f.f64_where("amplitude", UNIT.0, UNIT.1)?,
                },
                "request_flash" => Directive::RequestFlash {
                    t: f.f64_where("t", FIN_NONNEG.0, FIN_NONNEG.1)?,
                    duration: f.f64_where("duration", FIN_POS.0, FIN_POS.1)?,
                    page: f.usize_where("page", "an integer", |_| true)?,
                    extra: f.f64_where("extra", FIN_POS.0, FIN_POS.1)?,
                },
                other => {
                    return Err(DslError {
                        line,
                        col: head.col,
                        msg: format!("unknown directive `{other}`"),
                    })
                }
            };
            f.finish()?;
            directives.push(d);
            lines.push(line);
        }
        Ok(Self { directives, lines })
    }

    /// The parsed directives, in source order.
    pub fn directives(&self) -> &[Directive] {
        &self.directives
    }

    /// Canonical text form: one line per directive, defaults made
    /// explicit, numbers in shortest round-trip notation, seeds in
    /// hex. `parse(render(spec)) == spec` — the `dsl_round_trip`
    /// property in `tests/world_fuzz.rs` fuzzes this identity.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for d in &self.directives {
            render_directive(d, &mut out);
        }
        out
    }

    /// Compile the directives, in file order, into a runnable world.
    /// Structural rules checked here: `world` first, `pages` second,
    /// each of `world`/`pages`/`faults`/`retry`/`traffic`/`diurnal` at
    /// most once, page indices in range, traffic modifiers after
    /// `traffic`, fault directives after `faults`, and explicit fetch
    /// outage windows non-overlapping per host.
    pub fn compile(&self) -> Result<CompiledWorld, DslError> {
        let at = |idx: usize| self.lines.get(idx).copied().unwrap_or(1);
        let fail = |idx: usize, msg: String| DslError { line: at(idx), col: 1, msg };

        let Some(Directive::World { horizon, bandwidth, scenario_seed, timeline_window }) =
            self.directives.first()
        else {
            return Err(DslError {
                line: 1,
                col: 1,
                msg: "the first directive must be `world`".into(),
            });
        };
        let (world_horizon, bandwidth, timeline_window) =
            (*horizon, *bandwidth, *timeline_window);
        let pages = match self.directives.get(1) {
            Some(Directive::Pages { model, m, seed, partial_cis, false_positives, normalized }) => {
                build_pages(*model, *m, *seed, *partial_cis, *false_positives, *normalized)
            }
            _ => {
                return Err(DslError {
                    line: self.lines.get(1).copied().unwrap_or(1),
                    col: 1,
                    msg: "the second directive must be `pages`".into(),
                })
            }
        };
        let m = pages.len();

        let mut scenario = Scenario::new(pages.clone(), *scenario_seed);
        let mut faults: Option<FaultConfig> = None;
        let mut explicit_windows: Vec<HostOutage> = Vec::new();
        let mut retry: Option<RetryPolicy> = None;
        let mut traffic: Option<RequestTraffic> = None;
        let mut have_diurnal = false;

        for (idx, d) in self.directives.iter().enumerate().skip(2) {
            match d {
                Directive::World { .. } => {
                    return Err(fail(idx, "duplicate `world` directive".into()))
                }
                Directive::Pages { .. } => {
                    return Err(fail(idx, "duplicate `pages` directive".into()))
                }
                Directive::Churn { rho, horizon, seed } => add_steady_churn(
                    &mut scenario,
                    *rho,
                    horizon.unwrap_or(world_horizon),
                    &BornPageSpec::default(),
                    *seed,
                ),
                Directive::Flash { t, duration, frac, mu_factor, delta_factor, seed } => {
                    add_flash_crowd(
                        &mut scenario,
                        *t,
                        *duration,
                        *frac,
                        *mu_factor,
                        *delta_factor,
                        *seed,
                    )
                }
                Directive::Drift { period, amplitude, samples, frac, horizon, seed } => {
                    add_diurnal_drift(
                        &mut scenario,
                        *period,
                        *amplitude,
                        *samples,
                        *frac,
                        horizon.unwrap_or(world_horizon),
                        *seed,
                    )
                }
                Directive::Outage { t, duration, pages: set } => {
                    let set = match set {
                        None => PageSet::All,
                        Some(v) => {
                            if let Some(&p) = v.iter().find(|&&p| p >= m) {
                                return Err(fail(
                                    idx,
                                    format!("outage page {p} out of range (m = {m})"),
                                ));
                            }
                            PageSet::Pages(v.clone())
                        }
                    };
                    scenario.push(*t, WorldEvent::CisOutage { pages: set, duration: *duration });
                }
                Directive::HostOutages { hosts, n, mean, horizon, seed } => add_correlated_outages(
                    &mut scenario,
                    *hosts,
                    *n,
                    *mean,
                    horizon.unwrap_or(world_horizon),
                    *seed,
                ),
                Directive::AdversarialCis { t, frac, lam, nu } => {
                    // rank by importance, highest μ first, index as the
                    // deterministic tie-break; shift the top `frac`
                    let mut order: Vec<usize> = (0..m).collect();
                    order.sort_by(|&a, &b| {
                        pages[b].mu.total_cmp(&pages[a].mu).then(a.cmp(&b))
                    });
                    let k = ((m as f64) * frac).ceil() as usize;
                    let mut chosen: Vec<usize> = order.into_iter().take(k.min(m)).collect();
                    chosen.sort_unstable();
                    for page in chosen {
                        scenario
                            .push(*t, WorldEvent::CisQualityShift { page, lam: *lam, nu: *nu });
                    }
                }
                Directive::Bandwidth { t, rate } => {
                    scenario.push(*t, WorldEvent::BandwidthChange { rate: *rate });
                }
                Directive::Regions { t, interval, rates } => {
                    for (k, &rate) in rates.iter().enumerate() {
                        scenario.push(
                            t + interval * k as f64,
                            WorldEvent::BandwidthChange { rate },
                        );
                    }
                }
                Directive::Faults { transient, timeout, gone, hosts, seed } => {
                    if faults.is_some() {
                        return Err(fail(idx, "duplicate `faults` directive".into()));
                    }
                    faults = Some(FaultConfig {
                        transient_prob: *transient,
                        timeout_prob: *timeout,
                        gone_prob: *gone,
                        hosts: *hosts,
                        outages: Vec::new(),
                        seed: *seed,
                    });
                }
                Directive::FaultOutages { n, mean, horizon, seed } => {
                    let cfg = faults.as_mut().ok_or_else(|| {
                        fail(idx, "`fault_outages` requires a prior `faults` directive".into())
                    })?;
                    cfg.add_correlated_outages(*n, *mean, horizon.unwrap_or(world_horizon), *seed);
                }
                Directive::FaultWindow { host, start, end } => {
                    let cfg = faults.as_mut().ok_or_else(|| {
                        fail(idx, "`fault_window` requires a prior `faults` directive".into())
                    })?;
                    if *host >= cfg.hosts {
                        return Err(fail(
                            idx,
                            format!("fault_window host {host} out of range (hosts {})", cfg.hosts),
                        ));
                    }
                    let w = HostOutage { host: *host, start: *start, end: *end };
                    if let Some(prev) = explicit_windows
                        .iter()
                        .find(|p| p.host == w.host && w.start < p.end && p.start < w.end)
                    {
                        return Err(fail(
                            idx,
                            format!(
                                "overlapping outage windows for host {}: [{}, {}) and [{}, {})",
                                w.host, prev.start, prev.end, w.start, w.end
                            ),
                        ));
                    }
                    explicit_windows.push(w);
                    cfg.outages.push(w);
                }
                Directive::Retry(spec) => {
                    if retry.is_some() {
                        return Err(fail(idx, "duplicate `retry` directive".into()));
                    }
                    retry = Some(match *spec {
                        RetrySpec::Backoff => RetryPolicy::default(),
                        RetrySpec::Immediate { max_attempts } => {
                            RetryPolicy::Immediate { max_attempts }
                        }
                    });
                }
                Directive::Traffic { rate, zipf, seed } => {
                    if traffic.is_some() {
                        return Err(fail(idx, "duplicate `traffic` directive".into()));
                    }
                    traffic = Some(
                        RequestTraffic::new(*rate, *zipf, *seed)
                            .map_err(|e| fail(idx, e.to_string()))?,
                    );
                }
                Directive::Diurnal { period, amplitude } => {
                    let t = traffic.take().ok_or_else(|| {
                        fail(idx, "`diurnal` requires a prior `traffic` directive".into())
                    })?;
                    if have_diurnal {
                        return Err(fail(idx, "duplicate `diurnal` directive".into()));
                    }
                    have_diurnal = true;
                    traffic = Some(
                        t.with_diurnal(*period, *amplitude)
                            .map_err(|e| fail(idx, e.to_string()))?,
                    );
                }
                Directive::RequestFlash { t, duration, page, extra } => {
                    if *page >= m {
                        return Err(fail(
                            idx,
                            format!("request_flash page {page} out of range (m = {m})"),
                        ));
                    }
                    let tr = traffic.take().ok_or_else(|| {
                        fail(idx, "`request_flash` requires a prior `traffic` directive".into())
                    })?;
                    traffic = Some(
                        tr.with_flash(*t, *duration, *page, *extra)
                            .map_err(|e| fail(idx, e.to_string()))?,
                    );
                }
            }
        }
        if let Some(cfg) = &faults {
            cfg.validate().map_err(|e| fail(0, e.to_string()))?;
        }
        Ok(CompiledWorld {
            horizon: world_horizon,
            bandwidth,
            timeline_window,
            scenario,
            faults,
            retry: retry.unwrap_or_default(),
            traffic,
        })
    }
}

fn parse_pages(f: &mut Fields<'_>) -> Result<Directive, DslError> {
    // the model is a bare sub-kind token, not key=value
    let model = if f.flag("section6") {
        PageModel::Section6
    } else if f.flag("zipf") {
        PageModel::Zipf { s: f.f64_where("s", FIN_POS.0, FIN_POS.1)? }
    } else {
        return Err(f.err(1, "pages expects a model: `section6` or `zipf`"));
    };
    Ok(Directive::Pages {
        model,
        m: f.usize_where("m", "at least 1", |x| x >= 1)?,
        seed: f.u64_or("seed", 0x5EED)?,
        partial_cis: f.flag("partial_cis"),
        false_positives: f.flag("false_positives"),
        normalized: f.flag("normalized"),
    })
}

fn parse_retry(f: &mut Fields<'_>) -> Result<Directive, DslError> {
    if f.flag("backoff") {
        Ok(Directive::Retry(RetrySpec::Backoff))
    } else if f.flag("immediate") {
        let max = f.usize_where("max_attempts", "at least 1", |x| x >= 1)?;
        Ok(Directive::Retry(RetrySpec::Immediate { max_attempts: max as u32 }))
    } else {
        Err(f.err(1, "retry expects a policy: `backoff` or `immediate max_attempts=N`"))
    }
}

fn build_pages(
    model: PageModel,
    m: usize,
    seed: u64,
    partial_cis: bool,
    false_positives: bool,
    normalized: bool,
) -> Vec<PageParams> {
    let inst = match model {
        PageModel::Section6 => {
            // exactly the figure harness's construction, so a DSL world
            // is bit-identical to its hand-built twin
            let mut spec = ExperimentSpec::section6(m, 1);
            spec.seed = seed;
            if partial_cis {
                spec = spec.with_partial_cis();
            }
            if false_positives {
                spec = spec.with_false_positives();
            }
            spec.gen_instance(&mut Rng::new(spec.seed))
        }
        PageModel::Zipf { s } => {
            let mut rng = Rng::new(seed);
            let pages = (0..m)
                .map(|i| PageParams {
                    delta: rng.range(1e-4, 1.0),
                    lam: if partial_cis { rngkit::beta(&mut rng, 0.25, 0.25) } else { 0.0 },
                    nu: if false_positives { rng.range(0.1, 0.6) } else { 0.0 },
                    mu: 1.0 / ((i + 1) as f64).powf(s),
                })
                .collect();
            Instance { pages, bandwidth: 0.0 }
        }
    };
    if normalized {
        inst.normalized().pages
    } else {
        inst.pages
    }
}

fn render_directive(d: &Directive, out: &mut String) {
    // infallible: fmt::Write on String cannot fail
    let _ = match d {
        Directive::World { horizon, bandwidth, scenario_seed, timeline_window } => {
            let _ = write!(
                out,
                "world horizon={horizon:?} bandwidth={bandwidth:?} scenario_seed=0x{scenario_seed:x}"
            );
            if let Some(w) = timeline_window {
                let _ = write!(out, " timeline_window={w}");
            }
            writeln!(out)
        }
        Directive::Pages { model, m, seed, partial_cis, false_positives, normalized } => {
            match model {
                PageModel::Section6 => {
                    let _ = write!(out, "pages section6 m={m} seed=0x{seed:x}");
                }
                PageModel::Zipf { s } => {
                    let _ = write!(out, "pages zipf s={s:?} m={m} seed=0x{seed:x}");
                }
            }
            for (on, name) in [
                (partial_cis, "partial_cis"),
                (false_positives, "false_positives"),
                (normalized, "normalized"),
            ] {
                if **on {
                    let _ = write!(out, " {name}");
                }
            }
            writeln!(out)
        }
        Directive::Churn { rho, horizon, seed } => {
            let _ = write!(out, "churn rho={rho:?}");
            if let Some(h) = horizon {
                let _ = write!(out, " horizon={h:?}");
            }
            writeln!(out, " seed=0x{seed:x}")
        }
        Directive::Flash { t, duration, frac, mu_factor, delta_factor, seed } => writeln!(
            out,
            "flash t={t:?} duration={duration:?} frac={frac:?} mu_factor={mu_factor:?} \
             delta_factor={delta_factor:?} seed=0x{seed:x}"
        ),
        Directive::Drift { period, amplitude, samples, frac, horizon, seed } => {
            let _ = write!(
                out,
                "drift period={period:?} amplitude={amplitude:?} samples={samples} frac={frac:?}"
            );
            if let Some(h) = horizon {
                let _ = write!(out, " horizon={h:?}");
            }
            writeln!(out, " seed=0x{seed:x}")
        }
        Directive::Outage { t, duration, pages } => {
            let _ = write!(out, "outage t={t:?} duration={duration:?} pages=");
            match pages {
                None => {
                    let _ = write!(out, "all");
                }
                Some(v) => {
                    for (k, p) in v.iter().enumerate() {
                        let _ = write!(out, "{}{p}", if k > 0 { "," } else { "" });
                    }
                }
            }
            writeln!(out)
        }
        Directive::HostOutages { hosts, n, mean, horizon, seed } => {
            let _ = write!(out, "host_outages hosts={hosts} n={n} mean={mean:?}");
            if let Some(h) = horizon {
                let _ = write!(out, " horizon={h:?}");
            }
            writeln!(out, " seed=0x{seed:x}")
        }
        Directive::AdversarialCis { t, frac, lam, nu } => {
            writeln!(out, "adversarial_cis t={t:?} frac={frac:?} lam={lam:?} nu={nu:?}")
        }
        Directive::Bandwidth { t, rate } => writeln!(out, "bandwidth t={t:?} rate={rate:?}"),
        Directive::Regions { t, interval, rates } => {
            let _ = write!(out, "regions t={t:?} interval={interval:?} rates=");
            for (k, r) in rates.iter().enumerate() {
                let _ = write!(out, "{}{r:?}", if k > 0 { "," } else { "" });
            }
            writeln!(out)
        }
        Directive::Faults { transient, timeout, gone, hosts, seed } => writeln!(
            out,
            "faults transient={transient:?} timeout={timeout:?} gone={gone:?} hosts={hosts} \
             seed=0x{seed:x}"
        ),
        Directive::FaultOutages { n, mean, horizon, seed } => {
            let _ = write!(out, "fault_outages n={n} mean={mean:?}");
            if let Some(h) = horizon {
                let _ = write!(out, " horizon={h:?}");
            }
            writeln!(out, " seed=0x{seed:x}")
        }
        Directive::FaultWindow { host, start, end } => {
            writeln!(out, "fault_window host={host} start={start:?} end={end:?}")
        }
        Directive::Retry(RetrySpec::Backoff) => writeln!(out, "retry backoff"),
        Directive::Retry(RetrySpec::Immediate { max_attempts }) => {
            writeln!(out, "retry immediate max_attempts={max_attempts}")
        }
        Directive::Traffic { rate, zipf, seed } => {
            writeln!(out, "traffic rate={rate:?} zipf={zipf:?} seed=0x{seed:x}")
        }
        Directive::Diurnal { period, amplitude } => {
            writeln!(out, "diurnal period={period:?} amplitude={amplitude:?}")
        }
        Directive::RequestFlash { t, duration, page, extra } => writeln!(
            out,
            "request_flash t={t:?} duration={duration:?} page={page} extra={extra:?}"
        ),
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI: &str = "\
# a small but fully-loaded world
world horizon=40.0 bandwidth=5.0 scenario_seed=0x5ce7 timeline_window=100
pages zipf s=1.1 m=24 seed=0x5eed partial_cis false_positives normalized
churn rho=0.01 seed=0x5ce8
flash t=8.0 duration=4.0 frac=0.25 mu_factor=6.0 delta_factor=2.0 seed=0x9
drift period=10.0 amplitude=0.4 samples=4 frac=0.5 seed=0xa
outage t=15.0 duration=5.0 pages=all
outage t=2.0 duration=1.0 pages=1,3,5
host_outages hosts=4 n=3 mean=2.0 seed=0xb
adversarial_cis t=20.0 frac=0.1 lam=0.05 nu=0.9
bandwidth t=30.0 rate=8.0
regions t=33.0 interval=2.0 rates=3.0,6.0,9.0
faults transient=0.1 timeout=0.02 gone=0.001 hosts=4 seed=0xfa17
fault_outages n=2 mean=3.0 seed=0xfa18
fault_window host=1 start=5.0 end=7.0
retry immediate max_attempts=3
traffic rate=6.0 zipf=1.1 seed=0x7aff
diurnal period=10.0 amplitude=0.5
request_flash t=12.0 duration=3.0 page=12 extra=20.0
";

    fn err(text: &str) -> DslError {
        match WorldSpec::parse(text).and_then(|s| s.compile()) {
            Ok(_) => panic!("expected a parse/compile error for:\n{text}"),
            Err(e) => e,
        }
    }

    #[test]
    fn full_grammar_parses_and_compiles() {
        let w = parse_world(MINI).unwrap();
        assert_eq!(w.initial_pages().len(), 24);
        assert!(!w.scenario.is_static());
        let fc = w.faults.as_ref().unwrap();
        assert_eq!(fc.hosts, 4);
        // 2 generated + 1 explicit fetch-outage windows
        assert_eq!(fc.outages.len(), 3);
        assert_eq!(w.retry, RetryPolicy::Immediate { max_attempts: 3 });
        let tr = w.traffic.as_ref().unwrap();
        assert_eq!(tr.diurnal(), Some((10.0, 0.5)));
        assert_eq!(tr.flashes().len(), 1);
        assert_eq!(w.timeline_window, Some(100));
        // importance was normalized
        let total: f64 = w.initial_pages().iter().map(|p| p.mu).sum();
        assert!((total - 1.0).abs() < 1e-9);
    }

    #[test]
    fn round_trip_is_identity() {
        let spec = WorldSpec::parse(MINI).unwrap();
        let rendered = spec.render();
        let again = WorldSpec::parse(&rendered).unwrap();
        assert_eq!(spec, again, "parse → render → parse must be the identity");
        // and the rendered form is a fixpoint of render itself
        assert_eq!(rendered, again.render());
        // compiled worlds agree bit-for-bit
        let (a, b) = (spec.compile().unwrap(), again.compile().unwrap());
        assert!(bit_identical(&a.scenario, &b.scenario));
    }

    #[test]
    fn unknown_directive_reports_position() {
        let e = err("world horizon=10.0 bandwidth=1.0\npages section6 m=4\nwibble x=1\n");
        assert_eq!((e.line, e.col), (3, 1));
        assert!(e.msg.contains("unknown directive `wibble`"), "{e}");
    }

    #[test]
    fn nan_and_negative_rates_are_rejected_not_panicked() {
        let e = err("world horizon=nan bandwidth=1.0\npages section6 m=4\n");
        assert_eq!(e.line, 1);
        assert!(e.msg.contains("horizon"), "{e}");
        let e = err("world horizon=10.0 bandwidth=1.0\npages section6 m=4\nchurn rho=-0.5\n");
        assert_eq!(e.line, 3);
        assert!(e.msg.contains("rho"), "{e}");
        let e = err(
            "world horizon=10.0 bandwidth=1.0\npages section6 m=4\nbandwidth t=1.0 rate=-2.0\n",
        );
        assert_eq!(e.line, 3);
        assert!(e.msg.contains("rate"), "{e}");
    }

    #[test]
    fn error_column_points_at_the_value() {
        let e = err("world horizon=10.0 bandwidth=oops\npages section6 m=4\n");
        // column of the value inside `bandwidth=oops`
        assert_eq!((e.line, e.col), (1, 30));
        assert!(e.msg.contains("expects a number"), "{e}");
    }

    #[test]
    fn trailing_garbage_is_rejected_with_its_column() {
        let e = err("world horizon=10.0 bandwidth=1.0 surprise\npages section6 m=4\n");
        assert_eq!((e.line, e.col), (1, 34));
        assert!(e.msg.contains("unexpected trailing `surprise`"), "{e}");
    }

    #[test]
    fn overlapping_fault_windows_are_rejected() {
        let e = err("world horizon=10.0 bandwidth=1.0\npages section6 m=4\n\
                     faults transient=0.1 timeout=0.0 hosts=2\n\
                     fault_window host=1 start=1.0 end=3.0\n\
                     fault_window host=1 start=2.0 end=4.0\n");
        assert_eq!(e.line, 5);
        assert!(e.msg.contains("overlapping outage windows for host 1"), "{e}");
        // disjoint windows and other hosts are fine
        assert!(parse_world(
            "world horizon=10.0 bandwidth=1.0\npages section6 m=4\n\
             faults transient=0.1 timeout=0.0 hosts=2\n\
             fault_window host=1 start=1.0 end=3.0\n\
             fault_window host=1 start=3.0 end=4.0\n\
             fault_window host=0 start=2.0 end=4.0\n"
        )
        .is_ok());
    }

    #[test]
    fn structural_rules_are_enforced() {
        assert!(err("pages section6 m=4\n").msg.contains("must be `world`"));
        assert!(err("world horizon=10.0 bandwidth=1.0\nchurn rho=0.1\n")
            .msg
            .contains("must be `pages`"));
        assert!(err("world horizon=10.0 bandwidth=1.0\npages section6 m=4\n\
                     diurnal period=5.0 amplitude=0.5\n")
            .msg
            .contains("requires a prior `traffic`"));
        assert!(err("world horizon=10.0 bandwidth=1.0\npages section6 m=4\n\
                     fault_outages n=1 mean=2.0\n")
            .msg
            .contains("requires a prior `faults`"));
        assert!(err("world horizon=10.0 bandwidth=1.0\npages section6 m=4\n\
                     outage t=1.0 duration=1.0 pages=9\n")
            .msg
            .contains("out of range"));
        assert!(err("world horizon=10.0 bandwidth=1.0\npages section6 m=4\n\
                     world horizon=9.0 bandwidth=1.0\n")
            .msg
            .contains("duplicate `world`"));
    }

    #[test]
    fn missing_required_field_is_reported() {
        let e = err("world horizon=10.0\npages section6 m=4\n");
        assert_eq!(e.line, 1);
        assert!(e.msg.contains("missing required `bandwidth=`"), "{e}");
    }

    #[test]
    fn adversarial_cis_hits_the_top_importance_decile() {
        let text = "world horizon=10.0 bandwidth=1.0 scenario_seed=0x1\n\
                    pages zipf s=1.0 m=20 seed=0x2\n\
                    adversarial_cis t=1.0 frac=0.1 lam=0.0 nu=2.0\n";
        let w = parse_world(text).unwrap();
        // Zipf importance is rank order: the top decile of m=20 is
        // pages {0, 1}
        let shifted: Vec<usize> = w
            .scenario
            .events()
            .iter()
            .filter_map(|e| match e.event {
                WorldEvent::CisQualityShift { page, .. } => Some(page),
                _ => None,
            })
            .collect();
        assert_eq!(shifted, vec![0, 1]);
    }

    #[test]
    fn regions_compile_to_staggered_bandwidth_steps() {
        let text = "world horizon=10.0 bandwidth=1.0\npages section6 m=4\n\
                    regions t=2.0 interval=1.5 rates=3.0,6.0\n";
        let w = parse_world(text).unwrap();
        let steps: Vec<(f64, f64)> = w
            .scenario
            .events()
            .iter()
            .filter_map(|e| match e.event {
                WorldEvent::BandwidthChange { rate } => Some((e.t, rate)),
                _ => None,
            })
            .collect();
        assert_eq!(steps, vec![(2.0, 3.0), (3.5, 6.0)]);
    }

    #[test]
    fn hex_and_decimal_seeds_both_parse() {
        let a = parse_world("world horizon=10.0 bandwidth=1.0 scenario_seed=0x10\n\
                             pages section6 m=4\n")
            .unwrap();
        let b = parse_world("world horizon=10.0 bandwidth=1.0 scenario_seed=16\n\
                             pages section6 m=4\n")
            .unwrap();
        assert_eq!(a.scenario.seed(), 16);
        assert!(bit_identical(&a.scenario, &b.scenario));
    }
}
