//! Deterministic world fuzzer: random DSL worlds → every engine →
//! replay twice → audit.
//!
//! Each seed expands, via [`gen_world_dsl`], into a random world file
//! within the DSL's validity envelope (the generator and the parser
//! share constraints, so generation failing to parse is itself a
//! finding). The world then runs through every engine lane it
//! activates — materialized and streamed scenario replay, the serving
//! loop when traffic is present, the fault engine when a failure model
//! is present, and the learned-knowledge decorator — and every lane
//! runs **twice from identical fresh state**. Any fingerprint
//! divergence between the two runs is a determinism bug (the property
//! the whole evaluation's rep/CI machinery rests on); any
//! [`WorldAudit`](crate::scenario::invariants::WorldAudit) failure is
//! a conservation bug. Either way the run's flight recorder is dumped
//! through [`crate::trace::verify_or_dump`], so a [`FuzzViolation`] is
//! a self-contained repro bundle: the seed, the exact DSL text, the
//! violated law, and the last [`DUMP_WINDOW`](crate::trace) decisions
//! before the violation as JSONL.
//!
//! Drivers: the `fuzz` CLI subcommand (CI's `fuzz-smoke` step) and
//! `tests/world_fuzz.rs` (seed-corpus replay + a smoke range).

use std::fmt;

use crate::coordinator::builder::{Knowledge, Strategy};
use crate::estimation::EstimatorConfig;
use crate::fault::{simulate_faulty_traced_with, FaultModel, FaultSimResult};
use crate::policy::PolicyKind;
use crate::rngkit::Rng;
use crate::scenario::dsl::{bit_identical, WorldSpec};
use crate::scenario::invariants::WorldAudit;
use crate::serving::ServingMetrics;
use crate::sim::{generate_traces, CisDelay, SimResult, SimWorkspace, TraceMode};
use crate::trace::{self, TraceHandle};

/// Flight-recorder capacity per fuzz lane (events kept for the dump).
const RECORDER_CAP: usize = 4096;
/// Stop a fuzz campaign after this many violations: past a handful the
/// rest are almost certainly the same bug, and each bundle is large.
const MAX_VIOLATIONS: usize = 8;

/// A self-contained failure bundle: everything needed to reproduce and
/// diagnose one violated run without re-fuzzing.
#[derive(Debug, Clone)]
pub struct FuzzViolation {
    /// The world seed (replay with `fuzz --seed <seed> --worlds 1`).
    pub seed: u64,
    /// The exact DSL text of the offending world.
    pub dsl: String,
    /// Which lane and which law broke.
    pub message: String,
    /// The lane's last flight-recorder events as JSONL (empty when the
    /// failure precedes any engine run).
    pub flight_jsonl: String,
}

impl fmt::Display for FuzzViolation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "seed 0x{:x}: {}", self.seed, self.message)?;
        writeln!(f, "--- world ---")?;
        write!(f, "{}", self.dsl)
    }
}

/// Campaign parameters for [`run_fuzz`].
#[derive(Debug, Clone)]
pub struct FuzzConfig {
    /// Number of worlds to generate and run.
    pub worlds: usize,
    /// First seed; world `k` uses `start_seed + k`.
    pub start_seed: u64,
    /// Optional wall-clock budget; the campaign stops cleanly at the
    /// first world boundary past it (CI time-boxing).
    pub budget: Option<std::time::Duration>,
}

impl Default for FuzzConfig {
    fn default() -> Self {
        Self { worlds: 200, start_seed: 1, budget: None }
    }
}

/// What a campaign did and found.
#[derive(Debug, Clone, Default)]
pub struct FuzzOutcome {
    /// Worlds actually run (≤ `cfg.worlds` under a budget).
    pub worlds: usize,
    /// Engine lanes exercised across all worlds (each lane = two full
    /// replayed runs).
    pub lanes: u64,
    /// Every violation found, in seed order.
    pub violations: Vec<FuzzViolation>,
}

impl FuzzOutcome {
    /// True when every world replayed identically and every audit held.
    pub fn clean(&self) -> bool {
        self.violations.is_empty()
    }
}

/// Run a fuzz campaign. Deterministic for a fixed config (the budget
/// can only truncate the seed range, never reorder it).
pub fn run_fuzz(cfg: &FuzzConfig) -> FuzzOutcome {
    let start = std::time::Instant::now();
    let mut out = FuzzOutcome::default();
    for k in 0..cfg.worlds {
        if let Some(budget) = cfg.budget {
            if start.elapsed() >= budget {
                break;
            }
        }
        if out.violations.len() >= MAX_VIOLATIONS {
            break;
        }
        let seed = cfg.start_seed.wrapping_add(k as u64);
        match fuzz_world(seed) {
            Ok(lanes) => out.lanes += lanes,
            Err(v) => out.violations.push(*v),
        }
        out.worlds += 1;
    }
    out
}

/// Expand `seed` into a random world file. Always within the DSL's
/// validity envelope: if the output fails to parse, that mismatch is a
/// bug the fuzz tests surface directly.
pub fn gen_world_dsl(seed: u64) -> String {
    use std::fmt::Write as _;
    let mut rng = Rng::new(seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(1));
    let mut out = String::new();

    let horizon = rng.range(20.0, 60.0);
    let bandwidth = rng.range(2.0, 20.0);
    let m = 20 + rng.below(61) as usize;
    let _ = write!(
        out,
        "world horizon={horizon:?} bandwidth={bandwidth:?} scenario_seed=0x{:x}",
        rng.next_u64()
    );
    if rng.bernoulli(0.5) {
        let _ = write!(out, " timeline_window={}", 1 + rng.below(200));
    }
    let _ = writeln!(out);

    if rng.bernoulli(0.5) {
        let _ = write!(out, "pages section6 m={m} seed=0x{:x}", rng.next_u64());
    } else {
        let s = rng.range(0.6, 1.6);
        let _ = write!(out, "pages zipf s={s:?} m={m} seed=0x{:x}", rng.next_u64());
    }
    for flag in ["partial_cis", "false_positives", "normalized"] {
        if rng.bernoulli(0.5) {
            let _ = write!(out, " {flag}");
        }
    }
    let _ = writeln!(out);

    // 0–4 world-dynamics directives drawn from the full catalog
    for _ in 0..rng.below(5) {
        match rng.below(8) {
            0 => {
                let rho = rng.range(0.0, 0.05);
                let _ = writeln!(out, "churn rho={rho:?} seed=0x{:x}", rng.next_u64());
            }
            1 => {
                let t = rng.range(0.0, horizon * 0.5);
                let d = rng.range(1.0, horizon * 0.25);
                let frac = rng.range(0.0, 0.5);
                let muf = rng.range(0.5, 10.0);
                let df = rng.range(0.5, 4.0);
                let _ = writeln!(
                    out,
                    "flash t={t:?} duration={d:?} frac={frac:?} mu_factor={muf:?} \
                     delta_factor={df:?} seed=0x{:x}",
                    rng.next_u64()
                );
            }
            2 => {
                let period = rng.range(5.0, 20.0);
                let amp = rng.range(-0.8, 0.8);
                let samples = 1 + rng.below(6);
                let frac = rng.range(0.0, 1.0);
                let _ = writeln!(
                    out,
                    "drift period={period:?} amplitude={amp:?} samples={samples} frac={frac:?} \
                     seed=0x{:x}",
                    rng.next_u64()
                );
            }
            3 => {
                let t = rng.range(0.0, horizon * 0.75);
                let d = rng.range(0.5, horizon * 0.25);
                let _ = write!(out, "outage t={t:?} duration={d:?} pages=");
                if rng.bernoulli(0.5) {
                    let _ = writeln!(out, "all");
                } else {
                    let k = 1 + rng.below(8) as usize;
                    let chosen = rng.sample_indices(m, k.min(m));
                    for (i, p) in chosen.iter().enumerate() {
                        let _ = write!(out, "{}{p}", if i > 0 { "," } else { "" });
                    }
                    let _ = writeln!(out);
                }
            }
            4 => {
                let hosts = 1 + rng.below(8);
                let n = rng.below(5);
                let mean = rng.range(0.5, horizon * 0.125);
                let _ = writeln!(
                    out,
                    "host_outages hosts={hosts} n={n} mean={mean:?} seed=0x{:x}",
                    rng.next_u64()
                );
            }
            5 => {
                let t = rng.range(0.0, horizon);
                let frac = rng.range(0.01, 0.3);
                let lam = rng.range(0.0, 1.0);
                let nu = rng.range(0.0, 3.0);
                let _ = writeln!(
                    out,
                    "adversarial_cis t={t:?} frac={frac:?} lam={lam:?} nu={nu:?}"
                );
            }
            6 => {
                let t = rng.range(0.0, horizon);
                let rate = rng.range(1.0, 30.0);
                let _ = writeln!(out, "bandwidth t={t:?} rate={rate:?}");
            }
            _ => {
                let t = rng.range(0.0, horizon * 0.5);
                let interval = rng.range(0.5, 5.0);
                let n = 2 + rng.below(3);
                let _ = write!(out, "regions t={t:?} interval={interval:?} rates=");
                for i in 0..n {
                    let r = rng.range(1.0, 30.0);
                    let _ = write!(out, "{}{r:?}", if i > 0 { "," } else { "" });
                }
                let _ = writeln!(out);
            }
        }
    }

    if rng.bernoulli(0.5) {
        let transient = rng.range(0.0, 0.4);
        let timeout = rng.range(0.0, 0.1);
        let gone = rng.range(0.0, 0.02);
        let hosts = 1 + rng.below(16);
        let _ = writeln!(
            out,
            "faults transient={transient:?} timeout={timeout:?} gone={gone:?} hosts={hosts} \
             seed=0x{:x}",
            rng.next_u64()
        );
        if rng.bernoulli(0.5) {
            let n = 1 + rng.below(4);
            let mean = rng.range(0.5, horizon * 0.125);
            let _ = writeln!(
                out,
                "fault_outages n={n} mean={mean:?} seed=0x{:x}",
                rng.next_u64()
            );
        }
        if rng.bernoulli(0.3) {
            // a single explicit window can never self-overlap
            let host = rng.below(hosts);
            let start = rng.range(0.0, horizon * 0.75);
            let end = start + rng.range(0.5, horizon * 0.25);
            let _ = writeln!(out, "fault_window host={host} start={start:?} end={end:?}");
        }
        if rng.bernoulli(0.5) {
            if rng.bernoulli(0.5) {
                let _ = writeln!(out, "retry backoff");
            } else {
                let _ = writeln!(out, "retry immediate max_attempts={}", 1 + rng.below(6));
            }
        }
    }

    if rng.bernoulli(0.7) {
        let rate = rng.range(0.0, 20.0);
        let zipf = rng.range(0.0, 1.5);
        let _ = writeln!(
            out,
            "traffic rate={rate:?} zipf={zipf:?} seed=0x{:x}",
            rng.next_u64()
        );
        if rng.bernoulli(0.5) {
            let period = rng.range(2.0, 20.0);
            let amp = rng.range(0.0, 1.0);
            let _ = writeln!(out, "diurnal period={period:?} amplitude={amp:?}");
        }
        if rng.bernoulli(0.4) {
            let t = rng.range(0.0, horizon * 0.75);
            let d = rng.range(0.5, horizon * 0.25);
            let page = rng.below(m as u64);
            let extra = rng.range(1.0, 50.0);
            let _ = writeln!(
                out,
                "request_flash t={t:?} duration={d:?} page={page} extra={extra:?}"
            );
        }
    }
    out
}

/// Fuzz one seed: generate, parse, round-trip, compile, audit the
/// timeline, then run and replay every active engine lane. Returns the
/// number of lanes exercised, or the first violation.
pub fn fuzz_world(seed: u64) -> Result<u64, Box<FuzzViolation>> {
    let dsl = gen_world_dsl(seed);
    let fail = |tr: Option<&TraceHandle>, msg: String| violation(seed, &dsl, tr, msg);

    // parse + canonical round-trip: parse → render → parse is identity
    let spec = match WorldSpec::parse(&dsl) {
        Ok(s) => s,
        Err(e) => return Err(fail(None, format!("generated DSL failed to parse: {e}"))),
    };
    let rendered = spec.render();
    let again = match WorldSpec::parse(&rendered) {
        Ok(a) => a,
        Err(e) => return Err(fail(None, format!("canonical render failed to re-parse: {e}"))),
    };
    if again != spec {
        return Err(fail(None, "round-trip changed the parsed directives".to_string()));
    }
    let world = match spec.compile() {
        Ok(w) => w,
        Err(e) => return Err(fail(None, format!("generated DSL failed to compile: {e}"))),
    };
    let twin = match again.compile() {
        Ok(w) => w,
        Err(e) => return Err(fail(None, format!("canonical twin failed to compile: {e}"))),
    };
    if !bit_identical(&world.scenario, &twin.scenario) {
        return Err(fail(None, "round-trip world is not bit-identical".to_string()));
    }

    // static timeline audit before anything runs
    let mut audit = WorldAudit::new();
    audit.audit_timeline(&world.scenario);
    if let Err(msg) = audit.into_result() {
        return Err(fail(None, format!("timeline audit: {msg}")));
    }

    let mut lanes = 0u64;

    // scenario lanes: materialized and streamed replay, plus the
    // learned-knowledge decorator on the streamed path
    let scenario_lanes: [(&str, TraceMode, Knowledge); 3] = [
        ("scenario/materialized", TraceMode::Materialized, Knowledge::Oracle),
        ("scenario/streamed", TraceMode::Streamed, Knowledge::Oracle),
        (
            "scenario/learned",
            TraceMode::Streamed,
            Knowledge::Learned(EstimatorConfig::default()),
        ),
    ];
    for (label, mode, knowledge) in scenario_lanes {
        let run = |k: Knowledge| -> crate::Result<(TraceHandle, SimResult)> {
            let tr = TraceHandle::recorder(RECORDER_CAP);
            let r = world
                .crawler()
                .policy(PolicyKind::GreedyNcis)
                .strategy(Strategy::Lazy)
                .trace_mode(mode)
                .knowledge(k)
                .with_trace(tr.clone())
                .run_scenario(&world.sim_config()?, seed ^ 0xA11CE)?;
            Ok((tr, r))
        };
        let (tr1, r1) = match run(knowledge) {
            Ok(x) => x,
            Err(e) => return Err(fail(None, format!("{label}: engine error: {e}"))),
        };
        let (_, r2) = match run(knowledge) {
            Ok(x) => x,
            Err(e) => return Err(fail(Some(&tr1), format!("{label}: replay engine error: {e}"))),
        };
        if fp_sim(&r1) != fp_sim(&r2) {
            return Err(fail(
                Some(&tr1),
                format!("{label}: replay diverged (run fingerprints differ)"),
            ));
        }
        let mut audit = WorldAudit::new();
        audit.audit_sim(label, &r1);
        if let Err(msg) = audit.into_result() {
            return Err(fail(Some(&tr1), msg));
        }
        lanes += 1;
    }

    // serving lane, when the world carries request traffic
    if world.traffic.is_some() {
        let label = "serving";
        let run = || -> crate::Result<(TraceHandle, SimResult, ServingMetrics)> {
            let tr = TraceHandle::recorder(RECORDER_CAP);
            let (r, m) = world
                .crawler()
                .policy(PolicyKind::GreedyCis)
                .strategy(Strategy::Lazy)
                .with_trace(tr.clone())
                .run_traffic(&world.sim_config()?, seed ^ 0x5E4F)?;
            Ok((tr, r, m))
        };
        let (tr1, r1, m1) = match run() {
            Ok(x) => x,
            Err(e) => return Err(fail(None, format!("{label}: engine error: {e}"))),
        };
        let (_, r2, m2) = match run() {
            Ok(x) => x,
            Err(e) => return Err(fail(Some(&tr1), format!("{label}: replay engine error: {e}"))),
        };
        if fp_sim(&r1) != fp_sim(&r2) || fp_serving(&m1) != fp_serving(&m2) || m1 != m2 {
            return Err(fail(Some(&tr1), format!("{label}: replay diverged")));
        }
        let mut audit = WorldAudit::new();
        audit.audit_sim(label, &r1);
        audit.audit_serving(label, &m1);
        if let Err(msg) = audit.into_result() {
            return Err(fail(Some(&tr1), msg));
        }
        lanes += 1;
    }

    // fault lane, when the world carries a failure model
    if let Some(fc) = &world.faults {
        let label = "faults";
        let cfg = world.sim_config().map_err(|e| fail(None, e.to_string()))?;
        let run = || -> crate::Result<(TraceHandle, FaultSimResult)> {
            let mut trng = Rng::new(seed ^ 0xFA57);
            let traces =
                generate_traces(world.initial_pages(), world.horizon, CisDelay::None, &mut trng);
            let mut sched = crate::coordinator::builder::CrawlerBuilder::new()
                .policy(PolicyKind::GreedyNcis)
                .strategy(Strategy::Exact)
                .pages(world.initial_pages())
                .build()?;
            let mut model = FaultModel::new(fc.clone())?;
            let tr = TraceHandle::recorder(RECORDER_CAP);
            let mut ws = SimWorkspace::new();
            let r = simulate_faulty_traced_with(
                &mut ws,
                &traces,
                &cfg,
                sched.as_mut(),
                &mut model,
                world.retry,
                Some(&tr),
            );
            Ok((tr, r))
        };
        let (tr1, r1) = match run() {
            Ok(x) => x,
            Err(e) => return Err(fail(None, format!("{label}: engine error: {e}"))),
        };
        let (_, r2) = match run() {
            Ok(x) => x,
            Err(e) => return Err(fail(Some(&tr1), format!("{label}: replay engine error: {e}"))),
        };
        if fp_faults(&r1) != fp_faults(&r2) {
            return Err(fail(Some(&tr1), format!("{label}: replay diverged")));
        }
        let mut audit = WorldAudit::new();
        audit.audit_faults(label, &r1, world.initial_pages().len());
        if let Err(msg) = audit.into_result() {
            return Err(fail(Some(&tr1), msg));
        }
        lanes += 1;
    }

    Ok(lanes)
}

fn violation(seed: u64, dsl: &str, tr: Option<&TraceHandle>, msg: String) -> Box<FuzzViolation> {
    let mut buf = Vec::new();
    // always-on dump: cond=false routes the message through the flight
    // recorder so the bundle carries the final decisions
    let _ = trace::verify_or_dump(false, tr, &mut buf, &msg);
    Box::new(FuzzViolation {
        seed,
        dsl: dsl.to_string(),
        message: msg,
        flight_jsonl: String::from_utf8_lossy(&buf).into_owned(),
    })
}

// ------------------------------------------------------------ fingerprints

/// FNV-1a over little-endian words: cheap, deterministic, and
/// collision-safe enough for equality-of-replay checks (any divergence
/// at all is a bug; we never compare across different inputs).
struct Fp(u64);

impl Fp {
    fn new() -> Self {
        Fp(0xcbf2_9ce4_8422_2325)
    }
    fn u64(&mut self, x: u64) {
        for b in x.to_le_bytes() {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    fn f64(&mut self, x: f64) {
        self.u64(x.to_bits());
    }
}

fn fp_sim(r: &SimResult) -> u64 {
    let mut h = Fp::new();
    h.f64(r.accuracy);
    h.u64(r.requests);
    h.u64(r.fresh_hits);
    h.u64(r.ticks);
    h.u64(r.crawl_counts.len() as u64);
    for &c in &r.crawl_counts {
        h.u64(c as u64);
    }
    h.u64(r.timeline.len() as u64);
    for &(t, v) in &r.timeline {
        h.f64(t);
        h.f64(v);
    }
    h.0
}

fn fp_serving(m: &ServingMetrics) -> u64 {
    let mut h = Fp::new();
    h.u64(m.served);
    h.u64(m.fresh_serves);
    h.u64(m.stale_serves);
    h.u64(m.dead_serves);
    h.u64(m.overall.count());
    if m.overall.count() > 0 {
        h.f64(m.overall.mean());
    }
    for histo in m.by_quality.iter().chain(m.by_popularity.iter()) {
        h.u64(histo.count());
    }
    h.0
}

fn fp_faults(r: &FaultSimResult) -> u64 {
    let mut h = Fp::new();
    h.u64(fp_sim(&r.sim));
    let f = &r.faults;
    h.u64(f.attempts);
    h.u64(f.successes);
    h.u64(f.transient_errors);
    h.u64(f.timeouts);
    h.u64(f.gone);
    h.u64(f.retries);
    h.u64(f.quarantined);
    h.u64(f.forfeited_ticks);
    h.u64(f.idle_ticks);
    for &x in &f.retries_per_host {
        h.u64(x);
    }
    h.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generated_dsl_is_deterministic_per_seed() {
        assert_eq!(gen_world_dsl(42), gen_world_dsl(42));
        assert_ne!(gen_world_dsl(42), gen_world_dsl(43));
    }

    #[test]
    fn generated_dsl_always_parses_and_round_trips() {
        for seed in 0..64 {
            let dsl = gen_world_dsl(seed);
            let spec = WorldSpec::parse(&dsl)
                .unwrap_or_else(|e| panic!("seed {seed}: generated DSL rejected: {e}\n{dsl}"));
            let again = WorldSpec::parse(&spec.render()).unwrap();
            assert_eq!(spec, again, "seed {seed}: round-trip not identity");
            spec.compile()
                .unwrap_or_else(|e| panic!("seed {seed}: compile failed: {e}\n{dsl}"));
        }
    }

    #[test]
    fn fuzz_smoke_is_clean() {
        // a slice of the CI campaign: every lane replays identically
        // and every audit holds
        let out = run_fuzz(&FuzzConfig { worlds: 12, start_seed: 1, budget: None });
        assert_eq!(out.worlds, 12);
        assert!(out.lanes >= 36, "scenario lanes always run");
        assert!(
            out.clean(),
            "violations:\n{}",
            out.violations.iter().map(|v| v.to_string()).collect::<Vec<_>>().join("\n")
        );
    }

    #[test]
    fn violation_bundle_is_self_contained() {
        let v = violation(7, "world horizon=1.0 bandwidth=1.0\n", None, "law broke".into());
        assert_eq!(v.seed, 7);
        assert!(v.dsl.contains("horizon=1.0"));
        assert!(v.message.contains("law broke"));
        let shown = v.to_string();
        assert!(shown.contains("seed 0x7") && shown.contains("--- world ---"));
    }

    #[test]
    fn budget_truncates_cleanly() {
        let out = run_fuzz(&FuzzConfig {
            worlds: 1000,
            start_seed: 1,
            budget: Some(std::time::Duration::from_millis(0)),
        });
        assert_eq!(out.worlds, 0);
        assert!(out.clean());
    }
}
