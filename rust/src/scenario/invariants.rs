//! Reusable engine-invariant audits ([`WorldAudit`]).
//!
//! Every lane of the fuzzer (`scenario::fuzz`), the corpus replay
//! tests, and the `fuzz` CLI subcommand check the same small set of
//! conservation laws and sanity bounds after each run. Collecting them
//! here — instead of scattering ad-hoc `assert!`s through test files —
//! means a new engine entry point gets the full battery by calling one
//! method, and a violation carries a labelled message suitable for
//! [`crate::trace::verify_or_dump`]'s flight-recorder bundle.
//!
//! The laws:
//!
//! - **Timeline well-formedness** ([`WorldAudit::audit_timeline`]):
//!   event times are finite, non-negative, and non-decreasing; every
//!   event targets a live slot under the engine's LIFO slot-recycling
//!   discipline (no post-retirement `ParamsChanged` / quality shifts /
//!   double retirement); parameters, rates, and durations are in
//!   domain.
//! - **Crawl accounting** ([`WorldAudit::audit_sim`]): accuracy is a
//!   probability (or NaN only when no requests arrived), fresh hits
//!   never exceed requests, total crawls never exceed ticks, and the
//!   rolling-accuracy timeline is time-ordered with values in [0, 1].
//! - **Bandwidth conservation** ([`WorldAudit::audit_faults`]): every
//!   tick is spent exactly once — `successes + failures + forfeited +
//!   idle == ticks` — plus quarantine arithmetic (quarantined ≤ m,
//!   retries ≤ attempts, per-host retries sum to the total).
//! - **Serving conservation** ([`WorldAudit::audit_serving`]): live
//!   serves split exactly into fresh + stale, the age histogram saw
//!   exactly one observation per live serve, and observed ages are
//!   finite and non-negative.
//! - **Suppression arithmetic** ([`WorldAudit::audit_stats`]): event
//!   counters are consistent with the compiled timeline (no skipped
//!   events for DSL-generated worlds, which only ever target live
//!   slots).

use crate::fault::FaultSimResult;
use crate::scenario::{PageSet, Scenario, ScenarioStats, WorldEvent};
use crate::serving::ServingMetrics;
use crate::sim::SimResult;

/// An accumulating invariant checker: run audits, then collect the
/// violation messages (empty = all laws held).
#[derive(Debug, Default)]
pub struct WorldAudit {
    violations: Vec<String>,
}

impl WorldAudit {
    /// A fresh audit with no recorded violations.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a violation when `cond` is false. The message closure
    /// only runs on failure.
    pub fn check(&mut self, cond: bool, msg: impl FnOnce() -> String) {
        if !cond {
            self.violations.push(msg());
        }
    }

    /// True when every audited law held.
    pub fn ok(&self) -> bool {
        self.violations.is_empty()
    }

    /// The recorded violation messages, in audit order.
    pub fn violations(&self) -> &[String] {
        &self.violations
    }

    /// `Ok(())` when clean, else all messages joined with `"; "`.
    pub fn into_result(self) -> Result<(), String> {
        if self.violations.is_empty() {
            Ok(())
        } else {
            Err(self.violations.join("; "))
        }
    }

    /// Static timeline audit: replays the scenario's event list
    /// against a model of the engine's LIFO slot recycling and flags
    /// any event that the engine would have to skip or that would trip
    /// a validation assert.
    pub fn audit_timeline(&mut self, sc: &Scenario) {
        let mut live: Vec<bool> = vec![true; sc.initial_pages().len()];
        let mut free: Vec<usize> = Vec::new();
        let mut prev_t = 0.0_f64;
        for (k, ev) in sc.events().iter().enumerate() {
            self.check(ev.t.is_finite() && ev.t >= 0.0, || {
                format!("event {k}: non-finite or negative time {}", ev.t)
            });
            self.check(ev.t >= prev_t, || {
                format!("event {k}: time {} precedes previous {prev_t} (not monotone)", ev.t)
            });
            if ev.t.is_finite() {
                prev_t = prev_t.max(ev.t);
            }
            match &ev.event {
                WorldEvent::PageBorn { params } => {
                    self.check(params.validate().is_ok(), || {
                        format!("event {k}: born page has invalid params {params:?}")
                    });
                    // LIFO recycling: reuse the most recently freed slot
                    match free.pop() {
                        Some(slot) => live[slot] = true,
                        None => live.push(true),
                    }
                }
                WorldEvent::PageRetired { page } => {
                    let alive = live.get(*page).copied().unwrap_or(false);
                    self.check(alive, || {
                        format!("event {k}: retirement targets dead or unborn slot {page}")
                    });
                    if alive {
                        live[*page] = false;
                        free.push(*page);
                    }
                }
                WorldEvent::ParamsChanged { page, params } => {
                    self.check(live.get(*page).copied().unwrap_or(false), || {
                        format!("event {k}: ParamsChanged targets dead slot {page}")
                    });
                    self.check(params.validate().is_ok(), || {
                        format!("event {k}: ParamsChanged carries invalid params {params:?}")
                    });
                }
                WorldEvent::CisQualityShift { page, lam, nu } => {
                    self.check(live.get(*page).copied().unwrap_or(false), || {
                        format!("event {k}: CisQualityShift targets dead slot {page}")
                    });
                    self.check((0.0..=1.0).contains(lam), || {
                        format!("event {k}: shifted lam {lam} outside [0, 1]")
                    });
                    self.check(nu.is_finite() && *nu >= 0.0, || {
                        format!("event {k}: shifted nu {nu} invalid")
                    });
                }
                WorldEvent::CisOutage { pages, duration } => {
                    self.check(duration.is_finite() && *duration > 0.0, || {
                        format!("event {k}: outage duration {duration} invalid")
                    });
                    if let PageSet::Pages(list) = pages {
                        for &p in list {
                            self.check(live.get(p).copied().unwrap_or(false), || {
                                format!("event {k}: outage names dead or unborn slot {p}")
                            });
                        }
                    }
                }
                WorldEvent::BandwidthChange { rate } => {
                    self.check(rate.is_finite() && *rate > 0.0, || {
                        format!("event {k}: bandwidth rate {rate} invalid")
                    });
                }
            }
        }
    }

    /// Crawl-side accounting on a finished run.
    pub fn audit_sim(&mut self, label: &str, r: &SimResult) {
        if r.requests == 0 {
            // accuracy is NaN by contract when nothing was requested
            self.check(r.fresh_hits == 0, || {
                format!("{label}: fresh_hits {} with zero requests", r.fresh_hits)
            });
        } else {
            self.check(
                r.accuracy.is_finite() && (0.0..=1.0).contains(&r.accuracy),
                || format!("{label}: accuracy {} outside [0, 1]", r.accuracy),
            );
            self.check(r.fresh_hits <= r.requests, || {
                format!("{label}: fresh_hits {} exceed requests {}", r.fresh_hits, r.requests)
            });
        }
        let crawls: u64 = r.crawl_counts.iter().map(|&c| c as u64).sum();
        self.check(crawls <= r.ticks, || {
            format!("{label}: total crawls {crawls} exceed ticks {}", r.ticks)
        });
        let mut prev = f64::NEG_INFINITY;
        for &(t, v) in &r.timeline {
            self.check(t.is_finite() && t >= prev, || {
                format!("{label}: timeline time {t} not monotone (prev {prev})")
            });
            self.check(v.is_finite() && (0.0..=1.0).contains(&v), || {
                format!("{label}: timeline accuracy {v} at t={t} outside [0, 1]")
            });
            if t.is_finite() {
                prev = t;
            }
        }
    }

    /// Event-counter arithmetic. DSL-compiled worlds only emit events
    /// that target live slots (the static audit proves it), so the
    /// engine must never have skipped one; staleness of pick counters
    /// must be bounded by the events that can cause them.
    pub fn audit_stats(&mut self, label: &str, sc: &Scenario, st: &ScenarioStats) {
        self.check(st.skipped_events == 0, || {
            format!("{label}: engine skipped {} timeline events", st.skipped_events)
        });
        let (mut births, mut retirements, mut shifts, mut quality, mut outages) =
            (0u64, 0u64, 0u64, 0u64, 0u64);
        for ev in sc.events() {
            match ev.event {
                WorldEvent::PageBorn { .. } => births += 1,
                WorldEvent::PageRetired { .. } => retirements += 1,
                WorldEvent::ParamsChanged { .. } => shifts += 1,
                WorldEvent::CisQualityShift { .. } => quality += 1,
                WorldEvent::CisOutage { .. } => outages += 1,
                WorldEvent::BandwidthChange { .. } => {}
            }
        }
        self.check(st.births <= births, || {
            format!("{label}: {} births counted, timeline holds {births}", st.births)
        });
        self.check(st.retirements <= retirements, || {
            format!("{label}: {} retirements counted, timeline holds {retirements}", st.retirements)
        });
        self.check(st.param_shifts <= shifts, || {
            format!("{label}: {} param shifts counted, timeline holds {shifts}", st.param_shifts)
        });
        self.check(st.quality_shifts <= quality, || {
            format!("{label}: {} quality shifts counted, timeline has {quality}", st.quality_shifts)
        });
        self.check(st.outages <= outages, || {
            format!("{label}: {} outages counted, timeline holds {outages}", st.outages)
        });
        // a stale pick needs a retirement to have created staleness
        self.check(retirements > 0 || st.stale_picks == 0, || {
            format!("{label}: {} stale picks with no retirements", st.stale_picks)
        });
        // suppression needs at least one outage window
        self.check(outages > 0 || st.cis_suppressed == 0, || {
            format!("{label}: {} suppressed CIS with no outages", st.cis_suppressed)
        });
    }

    /// Serving conservation: dead serves are tracked apart from
    /// `served`, live serves split exactly into fresh + stale, and the
    /// overall age histogram saw one observation per live serve.
    pub fn audit_serving(&mut self, label: &str, m: &ServingMetrics) {
        self.check(m.fresh_serves + m.stale_serves == m.served, || {
            format!(
                "{label}: fresh {} + stale {} != served {}",
                m.fresh_serves, m.stale_serves, m.served
            )
        });
        self.check(m.overall.count() == m.served, || {
            format!(
                "{label}: age histogram count {} != served {}",
                m.overall.count(),
                m.served
            )
        });
        if m.served > 0 {
            let mean = m.overall.mean();
            self.check(mean.is_finite() && mean >= 0.0, || {
                format!("{label}: mean served age {mean} invalid")
            });
        }
        let by_quality: u64 = m.by_quality.iter().map(|h| h.count()).sum();
        self.check(by_quality == m.served, || {
            format!("{label}: quality-decile counts sum to {by_quality}, served {}", m.served)
        });
        let by_popularity: u64 = m.by_popularity.iter().map(|h| h.count()).sum();
        self.check(by_popularity == m.served, || {
            format!("{label}: popularity-decile counts sum to {by_popularity}, served {}", m.served)
        });
    }

    /// Bandwidth conservation and quarantine arithmetic for a fault
    /// run over an `m`-page population.
    pub fn audit_faults(&mut self, label: &str, r: &FaultSimResult, m: usize) {
        let f = &r.faults;
        let spent = f.successes + f.failures() + f.forfeited_ticks + f.idle_ticks;
        self.check(spent == r.sim.ticks, || {
            format!(
                "{label}: bandwidth not conserved: {} + {} + {} + {} = {spent} != ticks {}",
                f.successes,
                f.failures(),
                f.forfeited_ticks,
                f.idle_ticks,
                r.sim.ticks
            )
        });
        self.check(f.attempts == f.successes + f.failures(), || {
            format!(
                "{label}: attempts {} != successes {} + failures {}",
                f.attempts,
                f.successes,
                f.failures()
            )
        });
        self.check(f.retries <= f.attempts, || {
            format!("{label}: retries {} exceed attempts {}", f.retries, f.attempts)
        });
        self.check(f.quarantined <= m as u64, || {
            format!("{label}: quarantined {} pages out of {m}", f.quarantined)
        });
        let per_host: u64 = f.retries_per_host.iter().sum();
        self.check(per_host == f.retries, || {
            format!("{label}: per-host retries sum to {per_host}, total {}", f.retries)
        });
        self.audit_sim(label, &r.sim);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::PageParams;

    fn page() -> PageParams {
        PageParams { delta: 0.2, mu: 0.1, lam: 0.5, nu: 0.1 }
    }

    #[test]
    fn clean_timeline_passes() {
        let mut sc = Scenario::new(vec![page(), page()], 7);
        sc.push(1.0, WorldEvent::PageRetired { page: 0 });
        sc.push(2.0, WorldEvent::PageBorn { params: page() });
        sc.push(3.0, WorldEvent::ParamsChanged { page: 0, params: page() });
        let mut audit = WorldAudit::new();
        audit.audit_timeline(&sc);
        assert!(audit.ok(), "unexpected violations: {:?}", audit.violations());
    }

    #[test]
    fn post_retirement_event_is_flagged() {
        // Scenario::push validates values, not liveness — the audit
        // models slot recycling on top, so a shift on a retired slot
        // (with no intervening birth) must be caught here.
        let mut sc = Scenario::new(vec![page(), page()], 7);
        sc.push(1.0, WorldEvent::PageRetired { page: 1 });
        sc.push(2.0, WorldEvent::CisQualityShift { page: 1, lam: 0.0, nu: 1.0 });
        let mut audit = WorldAudit::new();
        audit.audit_timeline(&sc);
        assert!(!audit.ok());
        assert!(audit.violations()[0].contains("dead slot 1"), "{:?}", audit.violations());
    }

    #[test]
    fn lifo_recycling_is_modelled() {
        // retire 0 then 1; next birth must land in slot 1 (LIFO), so a
        // follow-up event on slot 1 is legal while slot 0 stays dead
        let mut sc = Scenario::new(vec![page(), page()], 7);
        sc.push(1.0, WorldEvent::PageRetired { page: 0 });
        sc.push(2.0, WorldEvent::PageRetired { page: 1 });
        sc.push(3.0, WorldEvent::PageBorn { params: page() });
        sc.push(4.0, WorldEvent::ParamsChanged { page: 1, params: page() });
        let mut audit = WorldAudit::new();
        audit.audit_timeline(&sc);
        assert!(audit.ok(), "{:?}", audit.violations());

        let mut bad = Scenario::new(vec![page(), page()], 7);
        bad.push(1.0, WorldEvent::PageRetired { page: 0 });
        bad.push(2.0, WorldEvent::PageRetired { page: 1 });
        bad.push(3.0, WorldEvent::PageBorn { params: page() });
        bad.push(4.0, WorldEvent::ParamsChanged { page: 0, params: page() });
        let mut audit = WorldAudit::new();
        audit.audit_timeline(&bad);
        assert!(!audit.ok());
    }

    #[test]
    fn double_retirement_is_flagged() {
        let mut sc = Scenario::new(vec![page()], 7);
        sc.push(1.0, WorldEvent::PageRetired { page: 0 });
        sc.push(2.0, WorldEvent::PageRetired { page: 0 });
        let mut audit = WorldAudit::new();
        audit.audit_timeline(&sc);
        assert!(!audit.ok());
        assert!(audit.violations()[0].contains("dead or unborn slot 0"));
    }

    #[test]
    fn sim_audit_accepts_empty_and_flags_overcount() {
        let clean = SimResult {
            accuracy: f64::NAN,
            requests: 0,
            fresh_hits: 0,
            crawl_counts: vec![1, 2],
            ticks: 5,
            timeline: vec![(1.0, 0.5), (2.0, 0.75)],
        };
        let mut audit = WorldAudit::new();
        audit.audit_sim("clean", &clean);
        assert!(audit.ok(), "{:?}", audit.violations());

        let bad = SimResult { fresh_hits: 9, requests: 4, accuracy: 0.5, ..clean };
        let mut audit = WorldAudit::new();
        audit.audit_sim("bad", &bad);
        assert!(!audit.ok());
        assert!(audit.violations()[0].contains("fresh_hits 9 exceed requests 4"));
    }

    #[test]
    fn into_result_joins_messages() {
        let mut audit = WorldAudit::new();
        audit.check(false, || "first".into());
        audit.check(false, || "second".into());
        let err = audit.into_result().unwrap_err();
        assert_eq!(err, "first; second");
        assert!(WorldAudit::new().into_result().is_ok());
    }
}
